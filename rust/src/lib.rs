//! # systemds-rs
//!
//! A from-scratch reproduction of the system described in
//! *"Costing Generated Runtime Execution Plans for Large-Scale Machine
//! Learning Programs"* (M. Boehm, 2015) — the SystemML cost model — built
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! The library contains the full compilation chain the paper's cost model
//! depends on:
//!
//! 1. [`dml`] — an R-like declarative ML language frontend (lexer, parser,
//!    AST, validation).
//! 2. [`ir`] — high-level operators (HOPs) organised into program blocks,
//!    static rewrites (constant folding, branch removal, algebraic
//!    simplification, CSE), inter-procedural size propagation, operation
//!    memory estimates, and execution-type selection (CP vs MR).
//! 3. [`lop`] — low-level physical operator selection (`tsmm`, `mapmm`,
//!    `cpmm`, `rmm`, …) under memory and block-size constraints.
//! 4. [`rtprog`] — generation of executable runtime programs for three
//!    execution backends ([`rtprog::ExecBackend`]: single-node CP, hybrid
//!    CP/MR, hybrid CP/Spark): CP instructions, MR-job instructions
//!    assembled by the piggybacking algorithm, and Spark jobs assembled
//!    as lazily fused stage DAGs ([`rtprog::sparkify`]).
//! 5. [`cost`] — **the paper's contribution**: a white-box analytical cost
//!    model that costs generated runtime plans in a single pass, tracking
//!    live-variable sizes and in-memory state, and linearising IO, latency
//!    and compute into a single estimated-execution-time measure — with
//!    per-framework job models for MR ([`cost::mr`]) and Spark
//!    ([`cost::spark`]).
//! 6. [`cp`] / [`mr`] — a hybrid runtime: single-node in-memory control
//!    program and a deterministic MapReduce cluster simulator (the
//!    substitute for the paper's Hadoop testbed).
//! 7. [`runtime`] — the PJRT bridge that loads AOT-compiled XLA artifacts
//!    (JAX/Pallas, built once by `make artifacts`) for the compute hot path.
//! 8. [`feedback`] — measured-execution feedback: runs compiled plans
//!    with per-block instrumentation, records measured-vs-predicted cost
//!    keyed by structural block hashes, and calibrates the cost
//!    constants online via robust regression, with Q-error tracked as a
//!    first-class accuracy metric ([`api::calibrate`]).
//! 9. [`opt`] — cost-model consumers: the global data flow optimizer
//!    ([`opt::gdf`], enumerating per-cut block size / format /
//!    partitioning / backend properties into restructured plans), the
//!    parallel grid resource optimizer with Pareto frontier
//!    ([`opt::resource`]), plan comparison, and the batched parallel
//!    scenario-sweep engine ([`opt::sweep`]) that costs ClusterConfig ×
//!    data-size grids into ranked comparison tables — all routed through
//!    one incremental evaluation core ([`opt::evaluate`]) with memoized
//!    `Arc`-shared compiles and block-level cost caching
//!    ([`cost::cache`]).
//!
//! The high-level entry points live in [`api`]: compile a DML script into a
//! runtime plan, cost it against a cluster configuration, explain it at any
//! compilation level, execute it, verify it ([`api::verify_plan`]), or
//! [`api::sweep`] a whole scenario grid. Static plan verification lives in
//! [`analysis`]: a three-pass dataflow / shape-and-memory / cost-invariant
//! audit over generated runtime plans.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod api;
pub mod artifact;
pub mod conf;
pub mod cost;
pub mod cp;
pub mod dml;
pub mod feedback;
pub mod ir;
pub mod lop;
pub mod matrix;
pub mod mr;
pub mod opt;
pub mod rtprog;
pub mod runtime;
pub mod serve;
pub mod util;

pub use api::{
    compile, optimize_global_dataflow, optimize_resources, sweep, CompileOptions,
    CompiledProgram, ExecBackend, Scenario,
};
pub use conf::{ClusterConfig, CostConstants, SystemConfig};
