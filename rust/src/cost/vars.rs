//! Live-variable state tracking (paper §3.2): sizes plus in-memory state.
//!
//! "Persistent read inputs and MR job outputs are known to be on HDFS,
//! while all in-memory instructions change the state of their inputs and
//! output to in-memory. … if a persistent dataset is used by two in-memory
//! instructions, only the first instruction will pay the costs of reading
//! the input."
//!
//! `cpvar` aliases share one underlying data entry, so touching `X` also
//! marks its alias `pREADX` in-memory.

use std::collections::HashMap;

use crate::matrix::{Format, MatrixCharacteristics};

/// Physical residence of a matrix variable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataState {
    /// Serialized on (simulated) HDFS; first in-memory use pays read IO.
    Hdfs,
    /// Resident in the CP buffer pool.
    Mem,
}

/// Underlying data entry (shared between aliases).
#[derive(Clone, Debug)]
pub struct DataInfo {
    /// Size metadata (dims, blocking, nnz) of the tracked matrix.
    pub mc: MatrixCharacteristics,
    /// Serialized format on HDFS (drives read/write bandwidth choice).
    pub format: Format,
    /// Current physical residence (HDFS vs buffer pool).
    pub state: DataState,
}

/// Symbol table of live variables → shared data entries.
#[derive(Clone, Debug, Default)]
pub struct VarTracker {
    names: HashMap<String, usize>,
    data: Vec<DataInfo>,
}

impl VarTracker {
    /// Register a variable (createvar): temps start with no on-disk data
    /// (state Mem until an MR job writes them), persistent reads are HDFS.
    pub fn create(&mut self, name: &str, mc: MatrixCharacteristics, format: Format, on_hdfs: bool) {
        let id = self.data.len();
        self.data.push(DataInfo {
            mc,
            format,
            state: if on_hdfs { DataState::Hdfs } else { DataState::Mem },
        });
        self.names.insert(name.to_string(), id);
    }

    /// Alias `dst` to `src` (cpvar).
    pub fn alias(&mut self, src: &str, dst: &str) {
        if let Some(&id) = self.names.get(src) {
            self.names.insert(dst.to_string(), id);
        }
    }

    /// Remove a name binding (rmvar). Underlying data stays for aliases.
    pub fn remove(&mut self, name: &str) {
        self.names.remove(name);
    }

    /// Look up the shared data entry of a variable.
    pub fn get(&self, name: &str) -> Option<&DataInfo> {
        self.names.get(name).map(|&id| &self.data[id])
    }

    /// Mutable lookup of the shared data entry of a variable.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut DataInfo> {
        let id = *self.names.get(name)?;
        Some(&mut self.data[id])
    }

    /// Characteristics, or unknown when untracked.
    pub fn mc(&self, name: &str) -> MatrixCharacteristics {
        self.get(name).map(|d| d.mc).unwrap_or_else(MatrixCharacteristics::unknown)
    }

    /// Mark a variable (and aliases) in-memory; returns the previous state.
    pub fn touch_mem(&mut self, name: &str) -> Option<DataState> {
        let d = self.get_mut(name)?;
        let prev = d.state;
        d.state = DataState::Mem;
        Some(prev)
    }

    /// Mark a variable as HDFS-resident (MR job outputs / exports).
    pub fn set_hdfs(&mut self, name: &str) {
        if let Some(d) = self.get_mut(name) {
            d.state = DataState::Hdfs;
        }
    }

    /// Update characteristics (e.g. once an MR job defines the output).
    pub fn set_mc(&mut self, name: &str, mc: MatrixCharacteristics) {
        if let Some(d) = self.get_mut(name) {
            d.mc = mc;
        }
    }

    /// Merge two trackers after a conditional: a variable stays in-memory
    /// only if both branches leave it in memory (conservative IO costing).
    pub fn merge(&mut self, other: &VarTracker) {
        let names: Vec<String> = self.names.keys().cloned().collect();
        for name in names {
            let ours = self.get(&name).map(|d| d.state);
            let theirs = other.get(&name).map(|d| d.state);
            if let (Some(DataState::Mem), Some(DataState::Hdfs)) = (ours, theirs) {
                self.set_hdfs(&name);
            }
        }
        for (name, &oid) in &other.names {
            if !self.names.contains_key(name) {
                let info = other.data[oid].clone();
                let id = self.data.len();
                self.data.push(info);
                self.names.insert(name.clone(), id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> MatrixCharacteristics {
        MatrixCharacteristics::dense(100, 100, 100)
    }

    #[test]
    fn first_toucher_pays_then_memory() {
        let mut t = VarTracker::default();
        t.create("pREADX", mc(), Format::BinaryBlock, true);
        t.alias("pREADX", "X");
        assert_eq!(t.touch_mem("X"), Some(DataState::Hdfs)); // pays IO
        assert_eq!(t.touch_mem("X"), Some(DataState::Mem)); // free
        // alias shares state
        assert_eq!(t.get("pREADX").unwrap().state, DataState::Mem);
    }

    #[test]
    fn rmvar_keeps_alias_data() {
        let mut t = VarTracker::default();
        t.create("a", mc(), Format::BinaryBlock, false);
        t.alias("a", "b");
        t.remove("a");
        assert!(t.get("a").is_none());
        assert!(t.get("b").is_some());
    }

    #[test]
    fn merge_demotes_memory_state() {
        let mut a = VarTracker::default();
        a.create("x", mc(), Format::BinaryBlock, false);
        let mut b = VarTracker::default();
        b.create("x", mc(), Format::BinaryBlock, true);
        a.merge(&b);
        assert_eq!(a.get("x").unwrap().state, DataState::Hdfs);
    }

    #[test]
    fn unknown_variable_is_unknown_mc() {
        let t = VarTracker::default();
        assert!(!t.mc("nope").dims_known());
    }
}
