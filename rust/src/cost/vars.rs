//! Live-variable state tracking (paper §3.2): sizes plus in-memory state.
//!
//! "Persistent read inputs and MR job outputs are known to be on HDFS,
//! while all in-memory instructions change the state of their inputs and
//! output to in-memory. … if a persistent dataset is used by two in-memory
//! instructions, only the first instruction will pay the costs of reading
//! the input."
//!
//! `cpvar` aliases share one underlying data entry, so touching `X` also
//! marks its alias `pREADX` in-memory.

use std::collections::HashMap;

use crate::matrix::{Format, MatrixCharacteristics};

/// Physical residence of a matrix variable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataState {
    /// Serialized on (simulated) HDFS; first in-memory use pays read IO.
    Hdfs,
    /// Resident in the CP buffer pool.
    Mem,
}

/// Underlying data entry (shared between aliases).
#[derive(Clone, Debug)]
pub struct DataInfo {
    /// Size metadata (dims, blocking, nnz) of the tracked matrix.
    pub mc: MatrixCharacteristics,
    /// Serialized format on HDFS (drives read/write bandwidth choice).
    pub format: Format,
    /// Current physical residence (HDFS vs buffer pool).
    pub state: DataState,
}

/// Symbol table of live variables → shared data entries.
#[derive(Clone, Debug, Default)]
pub struct VarTracker {
    names: HashMap<String, usize>,
    data: Vec<DataInfo>,
}

impl VarTracker {
    /// Register a variable (createvar): temps start with no on-disk data
    /// (state Mem until an MR job writes them), persistent reads are HDFS.
    pub fn create(&mut self, name: &str, mc: MatrixCharacteristics, format: Format, on_hdfs: bool) {
        let id = self.data.len();
        self.data.push(DataInfo {
            mc,
            format,
            state: if on_hdfs { DataState::Hdfs } else { DataState::Mem },
        });
        self.names.insert(name.to_string(), id);
    }

    /// Alias `dst` to `src` (cpvar).
    pub fn alias(&mut self, src: &str, dst: &str) {
        if let Some(&id) = self.names.get(src) {
            self.names.insert(dst.to_string(), id);
        }
    }

    /// Remove a name binding (rmvar). Underlying data stays for aliases.
    pub fn remove(&mut self, name: &str) {
        self.names.remove(name);
    }

    /// Look up the shared data entry of a variable.
    pub fn get(&self, name: &str) -> Option<&DataInfo> {
        self.names.get(name).map(|&id| &self.data[id])
    }

    /// Mutable lookup of the shared data entry of a variable.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut DataInfo> {
        let id = *self.names.get(name)?;
        Some(&mut self.data[id])
    }

    /// Characteristics, or unknown when untracked.
    pub fn mc(&self, name: &str) -> MatrixCharacteristics {
        self.get(name).map(|d| d.mc).unwrap_or_else(MatrixCharacteristics::unknown)
    }

    /// Mark a variable (and aliases) in-memory; returns the previous state.
    pub fn touch_mem(&mut self, name: &str) -> Option<DataState> {
        let d = self.get_mut(name)?;
        let prev = d.state;
        d.state = DataState::Mem;
        Some(prev)
    }

    /// Mark a variable as HDFS-resident (MR job outputs / exports).
    pub fn set_hdfs(&mut self, name: &str) {
        if let Some(d) = self.get_mut(name) {
            d.state = DataState::Hdfs;
        }
    }

    /// Update characteristics (e.g. once an MR job defines the output).
    pub fn set_mc(&mut self, name: &str, mc: MatrixCharacteristics) {
        if let Some(d) = self.get_mut(name) {
            d.mc = mc;
        }
    }

    /// Feed a canonical fingerprint of the live-variable state into `h`
    /// (the state component of the block-level cost-cache key, see
    /// [`crate::cost::cache`]). Covers every live name in sorted order,
    /// its alias group (aliases share a canonical entry id, so `cpvar`
    /// sharing is part of the fingerprint), and the shared entry's
    /// dimensions, on-disk format and HDFS-vs-memory residence — i.e.
    /// everything the §3.2 costing pass can observe. Two trackers with
    /// equal fingerprints are indistinguishable to `cost_program`,
    /// regardless of hash-map iteration order or dead entries.
    pub fn hash_state<H: std::hash::Hasher>(&self, h: &mut H) {
        let mut names: Vec<(&str, usize)> =
            self.names.iter().map(|(n, &id)| (n.as_str(), id)).collect();
        names.sort_unstable_by(|a, b| a.0.cmp(b.0));
        let mut canon: HashMap<usize, usize> = HashMap::with_capacity(names.len());
        for (name, id) in names {
            h.write(name.as_bytes());
            h.write_u8(0xff); // name terminator (names never contain 0xff)
            let next = canon.len();
            h.write_usize(*canon.entry(id).or_insert(next));
            let d = &self.data[id];
            h.write_i64(d.mc.rows);
            h.write_i64(d.mc.cols);
            h.write_i64(d.mc.brows);
            h.write_i64(d.mc.bcols);
            h.write_i64(d.mc.nnz);
            h.write_u8(match d.format {
                Format::BinaryBlock => 0,
                Format::TextCell => 1,
                Format::Csv => 2,
            });
            h.write_u8(match d.state {
                DataState::Hdfs => 0,
                DataState::Mem => 1,
            });
        }
    }

    /// Copy of this tracker retaining only the live bindings, with the
    /// shared data entries renumbered (alias structure preserved). The
    /// `data` vector otherwise grows monotonically — `rmvar` only unbinds
    /// names — so the block-level cost cache stores compacted snapshots
    /// to keep hit-replay cost proportional to the live variables, not to
    /// every temp ever created. Observationally identical to `self` for
    /// costing: same names, same shared entries, same states.
    pub fn compacted(&self) -> VarTracker {
        let mut names: Vec<(&String, usize)> = self.names.iter().map(|(n, &id)| (n, id)).collect();
        // sorted order makes the renumbering (and thus the clone layout)
        // deterministic regardless of hash-map iteration order
        names.sort_unstable_by(|a, b| a.0.cmp(b.0));
        let mut out = VarTracker::default();
        let mut renumber: HashMap<usize, usize> = HashMap::with_capacity(names.len());
        for (name, id) in names {
            let new_id = match renumber.get(&id) {
                Some(&nid) => nid,
                None => {
                    let nid = out.data.len();
                    out.data.push(self.data[id].clone());
                    renumber.insert(id, nid);
                    nid
                }
            };
            out.names.insert(name.clone(), new_id);
        }
        out
    }

    /// Flatten the live bindings into `(name, canonical entry id, data)`
    /// rows for serialization (the cost-cache snapshot artifact,
    /// [`crate::artifact::snapshot`]). Names are sorted and entry ids are
    /// renumbered in first-occurrence order — the same canonicalization
    /// as [`Self::compacted`] and [`Self::hash_state`] — so the export is
    /// deterministic and aliases stay visible as shared ids.
    pub(crate) fn export_entries(&self) -> Vec<(String, usize, DataInfo)> {
        let mut names: Vec<(&String, usize)> = self.names.iter().map(|(n, &id)| (n, id)).collect();
        names.sort_unstable_by(|a, b| a.0.cmp(b.0));
        let mut canon: HashMap<usize, usize> = HashMap::with_capacity(names.len());
        let mut out = Vec::with_capacity(names.len());
        for (name, id) in names {
            let next = canon.len();
            let cid = *canon.entry(id).or_insert(next);
            out.push((name.clone(), cid, self.data[id].clone()));
        }
        out
    }

    /// Rebuild a tracker from [`Self::export_entries`] rows. Rows sharing
    /// an entry id share one underlying [`DataInfo`] (alias structure
    /// round-trips); the first row of each id supplies the data. The
    /// result fingerprints ([`Self::hash_state`]) identically to the
    /// exported tracker.
    pub(crate) fn from_entries(entries: &[(String, usize, DataInfo)]) -> VarTracker {
        let mut out = VarTracker::default();
        let mut renumber: HashMap<usize, usize> = HashMap::with_capacity(entries.len());
        for (name, id, info) in entries {
            let new_id = match renumber.get(id) {
                Some(&nid) => nid,
                None => {
                    let nid = out.data.len();
                    out.data.push(info.clone());
                    renumber.insert(*id, nid);
                    nid
                }
            };
            out.names.insert(name.clone(), new_id);
        }
        out
    }

    /// Merge two trackers after a conditional: a variable stays in-memory
    /// only if both branches leave it in memory (conservative IO costing).
    pub fn merge(&mut self, other: &VarTracker) {
        let names: Vec<String> = self.names.keys().cloned().collect();
        for name in names {
            let ours = self.get(&name).map(|d| d.state);
            let theirs = other.get(&name).map(|d| d.state);
            if let (Some(DataState::Mem), Some(DataState::Hdfs)) = (ours, theirs) {
                self.set_hdfs(&name);
            }
        }
        for (name, &oid) in &other.names {
            if !self.names.contains_key(name) {
                let info = other.data[oid].clone();
                let id = self.data.len();
                self.data.push(info);
                self.names.insert(name.clone(), id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> MatrixCharacteristics {
        MatrixCharacteristics::dense(100, 100, 100)
    }

    #[test]
    fn first_toucher_pays_then_memory() {
        let mut t = VarTracker::default();
        t.create("pREADX", mc(), Format::BinaryBlock, true);
        t.alias("pREADX", "X");
        assert_eq!(t.touch_mem("X"), Some(DataState::Hdfs)); // pays IO
        assert_eq!(t.touch_mem("X"), Some(DataState::Mem)); // free
        // alias shares state
        assert_eq!(t.get("pREADX").unwrap().state, DataState::Mem);
    }

    #[test]
    fn rmvar_keeps_alias_data() {
        let mut t = VarTracker::default();
        t.create("a", mc(), Format::BinaryBlock, false);
        t.alias("a", "b");
        t.remove("a");
        assert!(t.get("a").is_none());
        assert!(t.get("b").is_some());
    }

    #[test]
    fn merge_demotes_memory_state() {
        let mut a = VarTracker::default();
        a.create("x", mc(), Format::BinaryBlock, false);
        let mut b = VarTracker::default();
        b.create("x", mc(), Format::BinaryBlock, true);
        a.merge(&b);
        assert_eq!(a.get("x").unwrap().state, DataState::Hdfs);
    }

    #[test]
    fn unknown_variable_is_unknown_mc() {
        let t = VarTracker::default();
        assert!(!t.mc("nope").dims_known());
    }

    /// Export/import round-trips aliasing and residence state and
    /// preserves the canonical fingerprint (the snapshot-replay contract).
    #[test]
    fn export_import_round_trips_fingerprint() {
        let mut t = VarTracker::default();
        t.create("pREADX", mc(), Format::BinaryBlock, true);
        t.alias("pREADX", "X");
        t.create("w", mc(), Format::TextCell, false);
        t.touch_mem("w");
        let rows = t.export_entries();
        assert_eq!(rows.len(), 3, "one row per live name");
        let back = VarTracker::from_entries(&rows);
        fn fp(t: &VarTracker) -> u64 {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            t.hash_state(&mut h);
            std::hash::Hasher::finish(&h)
        }
        assert_eq!(fp(&t), fp(&back));
        // aliasing survives the round trip: touching X warms pREADX
        let mut b2 = back.clone();
        b2.touch_mem("X");
        assert_eq!(b2.get("pREADX").unwrap().state, DataState::Mem);
    }

    /// Compaction drops dead entries, keeps aliasing, and fingerprints
    /// identically to the original (the cost-cache replay contract).
    #[test]
    fn compacted_preserves_live_state_and_fingerprint() {
        let mut t = VarTracker::default();
        for i in 0..50 {
            t.create(&format!("dead{i}"), mc(), Format::BinaryBlock, false);
            t.remove(&format!("dead{i}"));
        }
        t.create("x", mc(), Format::BinaryBlock, true);
        t.alias("x", "y");
        t.create("z", mc(), Format::BinaryBlock, false);
        let c = t.compacted();
        assert_eq!(c.data.len(), 2, "dead entries dropped");
        assert_eq!(c.get("x").unwrap().state, DataState::Hdfs);
        // aliasing survives: touching x warms y
        let mut c2 = c.clone();
        c2.touch_mem("x");
        assert_eq!(c2.get("y").unwrap().state, DataState::Mem);
        // canonical fingerprints agree
        fn fp(t: &VarTracker) -> u64 {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            t.hash_state(&mut h);
            std::hash::Hasher::finish(&h)
        }
        assert_eq!(fp(&t), fp(&c));
    }
}
