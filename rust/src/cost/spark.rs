//! Spark-job instruction costing: the Eq.-1 linearisation of §3.3 applied
//! to lazily fused stage DAGs instead of piggybacked MR jobs.
//!
//! The structure mirrors [`crate::cost::mr`] — both backends share the
//! white-box FLOP models ([`crate::cost::flops`]) and the IO primitives
//! (HDFS read/write, export of in-memory inputs) — but the framework
//! terms differ where Spark's execution model differs from Hadoop's:
//!
//! * **Latency**: one driver-side job submission (~1 s, no container
//!   startup) plus a per-stage scheduling barrier, with per-task launch
//!   ~30× cheaper than an MR task JVM. This is the term that flips
//!   multi-iteration loops to Spark (Kaoudi et al. 2017).
//! * **Broadcast**: torrent broadcast costs ~size/bandwidth once —
//!   executors fetch blocks from peers in parallel — where the MR
//!   distributed cache is re-read by every map task.
//! * **Shuffle**: two passes (sorted write, network read+merge) instead
//!   of MR's three (map write, transfer, reduce merge).

use super::flops;
use super::mr::{inst_flops, output_groups, resolve_mcs};
use super::vars::{DataState, VarTracker};
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::matrix::{Format, MatrixCharacteristics};
use crate::rtprog::*;

/// Full cost breakdown of one Spark job. All time components are in
/// seconds, already normalised by the effective degree of parallelism of
/// their phase (the §3.3 scaled minimum, shared with the MR model).
#[derive(Clone, Debug, Default)]
pub struct SparkJobCost {
    /// Tasks of the narrow scan stage: `Σ ⌈M'(input)/hdfs_block⌉`.
    pub n_tasks: usize,
    /// Number of stages in the fused DAG.
    pub n_stages: usize,
    /// Shuffle partitions of wide stages (0 when the job is narrow-only).
    pub n_shuffle_tasks: usize,
    /// Job submission + stage scheduling + task launch, normalised.
    pub latency: f64,
    /// Export of in-memory inputs to HDFS (hybrid-plan data exchange).
    pub export: f64,
    /// HDFS read of scan inputs (broadcast inputs excluded).
    pub hdfs_read: f64,
    /// Torrent broadcast of broadcast variables (once, not per task).
    pub broadcast: f64,
    /// Stage compute (FLOPs / clock / effective parallelism).
    pub exec: f64,
    /// Shuffle across wide boundaries: sorted write + network read.
    pub shuffle: f64,
    /// HDFS write of job outputs (× replication factor).
    pub hdfs_write: f64,
}

impl SparkJobCost {
    /// Total job seconds: the sum of every component above.
    pub fn total(&self) -> f64 {
        self.latency
            + self.export
            + self.hdfs_read
            + self.broadcast
            + self.exec
            + self.shuffle
            + self.hdfs_write
    }

    /// Figure-5-style annotation for the costed EXPLAIN.
    pub fn annotate(&self) -> String {
        use crate::util::fmt::fmt_secs;
        format!(
            "# C=[{}] ntasks={} nstages={} latency=[{}] hdfsread=[{}] exec=[{}] bcast=[{}] shuffle=[{}] hdfswrite=[{}]",
            fmt_secs(self.total()),
            self.n_tasks,
            self.n_stages,
            fmt_secs(self.latency),
            fmt_secs(self.hdfs_read),
            fmt_secs(self.exec),
            fmt_secs(self.broadcast),
            fmt_secs(self.shuffle),
            fmt_secs(self.hdfs_write),
        )
    }
}

/// Cost one Spark job and update variable states (outputs land on HDFS).
pub fn cost_spark_job(
    j: &SparkJob,
    t: &mut VarTracker,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
) -> SparkJobCost {
    let mut c = SparkJobCost::default();

    // ---- export in-memory inputs to HDFS (hybrid-plan data exchange;
    // identical to the MR model: the data must leave the driver heap)
    for v in &j.inputs {
        if let Some(info) = t.get(v) {
            if info.state == DataState::Mem {
                let size = info.mc.serialized_size(Format::BinaryBlock);
                if size.is_finite() {
                    c.export += size / k.hdfs_write_binaryblock;
                }
                t.set_hdfs(v);
            }
        }
    }

    // ---- task counts
    let input_mc: Vec<MatrixCharacteristics> = j.inputs.iter().map(|v| t.mc(v)).collect();
    let mut n_tasks = 0usize;
    for (v, mc) in j.inputs.iter().zip(&input_mc) {
        if j.broadcasts.contains(v) {
            continue;
        }
        let size = mc.serialized_size(Format::BinaryBlock);
        if size.is_finite() {
            n_tasks += (size / cc.hdfs_block_bytes).ceil() as usize;
        }
    }
    c.n_tasks = n_tasks.max(1);
    c.n_stages = j.stages.len().max(1);
    let wide_stages = j.stages.iter().filter(|s| s.wide).count();
    c.n_shuffle_tasks = if wide_stages > 0 {
        let max_groups = j
            .stages
            .iter()
            .filter(|s| s.wide)
            .flat_map(|s| &s.insts)
            .map(|i| output_groups(i, cfg))
            .max()
            .unwrap_or(1);
        j.num_reducers.min(max_groups).max(1)
    } else {
        0
    };

    // ---- effective parallelism: scaled minimum of executor slots and
    // task count (§3.3, shared with the MR model's dop_scale)
    let k_slots = cc.k_spark();
    let k_narrow = ((k_slots.min(c.n_tasks) as f64) * k.dop_scale).max(1.0);
    let k_wide = if c.n_shuffle_tasks > 0 {
        ((k_slots.min(c.n_shuffle_tasks) as f64) * k.dop_scale).max(1.0)
    } else {
        1.0
    };

    // ---- latency: job submit + stage barriers + task launches
    c.latency = k.spark_job_latency
        + k.spark_stage_latency * c.n_stages as f64
        + k.spark_task_latency * (c.n_tasks as f64 / k_narrow)
        + k.spark_task_latency
            * (c.n_shuffle_tasks as f64 * wide_stages as f64 / k_wide);

    // ---- HDFS read of scan inputs (broadcast inputs read separately)
    for (v, mc) in j.inputs.iter().zip(&input_mc) {
        if j.broadcasts.contains(v) {
            continue;
        }
        let size = mc.serialized_size(Format::BinaryBlock);
        if size.is_finite() {
            c.hdfs_read += size / k.hdfs_read_binaryblock / k_narrow;
        }
    }

    // ---- torrent broadcast: executors fetch blocks from peers in
    // parallel, so one broadcast costs ~size/bandwidth once — the Spark
    // advantage over the per-task distributed-cache re-read
    for v in &j.broadcasts {
        let size = t.mc(v).serialized_size(Format::BinaryBlock);
        if size.is_finite() {
            c.broadcast += size / k.spark_broadcast_bw;
        }
    }

    // ---- stage compute + shuffle volumes
    let inst_mc = resolve_mcs(&input_mc, j.all_insts());
    let unknown = MatrixCharacteristics::unknown;
    let mut shuffle_bytes = 0.0;
    for stage in &j.stages {
        let k_eff = if stage.wide { k_wide } else { k_narrow };
        for inst in &stage.insts {
            match &inst.op {
                MrOp::Agg { .. } => {
                    // final aggregation of per-task partials
                    let partial =
                        inst_mc.get(&inst.output).copied().unwrap_or_else(unknown);
                    let n_partials = if inst.inputs[0] < j.inputs.len() {
                        let total =
                            input_mc[inst.inputs[0]].serialized_size(Format::BinaryBlock);
                        let each =
                            partial.serialized_size(Format::BinaryBlock).max(1.0);
                        if total.is_finite() {
                            shuffle_bytes += total;
                            (total / each).max(1.0)
                        } else {
                            1.0
                        }
                    } else {
                        let size = partial.serialized_size(Format::BinaryBlock);
                        if size.is_finite() {
                            shuffle_bytes += c.n_tasks as f64 * size;
                        }
                        c.n_tasks as f64
                    };
                    c.exec += flops::agg_kahan(n_partials, &partial) / (cc.clock_hz * k.flop_efficiency) / k_wide;
                }
                MrOp::Cpmm | MrOp::Rmm => {
                    // shuffle join: both sides repartition by the
                    // contraction key, multiply happens post-shuffle
                    let a = inst
                        .inputs
                        .first()
                        .and_then(|i| inst_mc.get(i))
                        .copied()
                        .unwrap_or_else(unknown);
                    let b = inst
                        .inputs
                        .get(1)
                        .and_then(|i| inst_mc.get(i))
                        .copied()
                        .unwrap_or_else(unknown);
                    for &i in &inst.inputs {
                        if let Some(mc) = inst_mc.get(&i) {
                            let size = mc.serialized_size(Format::BinaryBlock);
                            if size.is_finite() {
                                shuffle_bytes += size;
                            }
                        }
                    }
                    c.exec += flops::matmult(&a, &b) / (cc.clock_hz * k.flop_efficiency) / k_wide;
                }
                MrOp::Binary(_) if stage.wide => {
                    // reduce-side elementwise join: both inputs
                    // repartition by block key before the zip
                    for &i in &inst.inputs {
                        if let Some(mc) = inst_mc.get(&i) {
                            let size = mc.serialized_size(Format::BinaryBlock);
                            if size.is_finite() {
                                shuffle_bytes += size;
                            }
                        }
                    }
                    c.exec += inst_flops(inst, &inst_mc) / (cc.clock_hz * k.flop_efficiency) / k_wide;
                }
                _ => {
                    c.exec += inst_flops(inst, &inst_mc) / (cc.clock_hz * k.flop_efficiency) / k_eff;
                }
            }
        }
    }

    // ---- shuffle: sorted write to local disk + network read/merge
    // (two passes; MR pays a third for the reduce-side merge-sort)
    if shuffle_bytes > 0.0 {
        c.shuffle = shuffle_bytes
            * (1.0 / k.spark_shuffle_write + 1.0 / k.spark_shuffle_read)
            / k_narrow;
    }

    // ---- HDFS write of outputs
    for (v, &ri) in j.outputs.iter().zip(&j.result_indices) {
        let mc = inst_mc.get(&ri).copied().unwrap_or_else(|| t.mc(v));
        let size = mc.serialized_size(Format::BinaryBlock);
        if size.is_finite() {
            c.hdfs_write += size * j.replication as f64
                / k.hdfs_write_binaryblock
                / if c.n_shuffle_tasks > 0 { k_wide } else { k_narrow };
        }
        t.set_mc(v, mc);
        t.set_hdfs(v);
    }

    c
}

/// [`cost_spark_job`] expanded to its expectation under a failure model —
/// the Spark twin of [`crate::cost::mr::cost_mr_job_faults`]: geometric
/// retries multiply per-task work terms, the expected exponential backoff
/// is added to the latency term once per task wave, and the straggler
/// tail inflates the last wave's share of the compute term. Spark
/// re-schedules failed tasks inside running executors, so retries pay no
/// extra container startup (the latency term is not retried) — but the
/// per-attempt failure probability is typically *higher* than MR's
/// (lineage-recomputation on executor loss re-runs whole stages), which
/// is what makes retry-heavy Spark plans lose to CP under chaos. With
/// [`FaultProfile::none`] the breakdown is bitwise-identical to
/// [`cost_spark_job`].
pub fn cost_spark_job_faults(
    j: &SparkJob,
    t: &mut VarTracker,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fp: &FaultProfile,
) -> SparkJobCost {
    let mut c = cost_spark_job(j, t, cfg, cc, k);
    if fp.is_none() {
        return c;
    }
    let p = fp.spark_fail_p;
    let retry = fp.expected_attempts(p);
    let tail = fp.straggler_tail();
    // mirror cost_spark_job's effective-parallelism math to count waves
    let k_slots = cc.k_spark();
    let k_narrow = ((k_slots.min(c.n_tasks) as f64) * k.dop_scale).max(1.0);
    let k_wide = if c.n_shuffle_tasks > 0 {
        ((k_slots.min(c.n_shuffle_tasks) as f64) * k.dop_scale).max(1.0)
    } else {
        1.0
    };
    let narrow_waves = (c.n_tasks as f64 / k_narrow).ceil().max(1.0);
    let wide_waves = if c.n_shuffle_tasks > 0 {
        (c.n_shuffle_tasks as f64 / k_wide).ceil().max(1.0)
    } else {
        0.0
    };
    // geometric retries redo per-task work
    c.hdfs_read *= retry;
    c.broadcast *= retry;
    c.exec *= retry;
    c.shuffle *= retry;
    c.hdfs_write *= retry;
    // speculative backup copies duplicate the straggling fraction's work
    if fp.speculative && fp.straggler_frac > 0.0 {
        c.exec *= 1.0 + fp.straggler_frac;
    }
    // straggler tail: the last wave finishes at the straggler's pace
    c.exec += c.exec / narrow_waves * (tail - 1.0);
    // expected backoff wait, paid once per wave per stage class
    c.latency += fp.expected_backoff(p) * (narrow_waves + wide_waves);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::mr::{cost_mr_job, MrJobCost};

    fn paper_env() -> (SystemConfig, ClusterConfig, CostConstants) {
        (SystemConfig::default(), ClusterConfig::paper_cluster(), CostConstants::default())
    }

    /// The XL1 wave as a fused Spark job (the sparkify mirror of the
    /// Figure-3 MR job): tsmm + r' + mapmm narrow, two ak+ wide.
    fn xl1_spark_job() -> (SparkJob, VarTracker) {
        let x_mc = MatrixCharacteristics::dense(100_000_000, 1_000, 1000);
        let y_mc = MatrixCharacteristics::dense(100_000_000, 1, 1000);
        let a_mc = MatrixCharacteristics::new(1000, 1000, 1000, -1);
        let tx_mc = MatrixCharacteristics::dense(1_000, 100_000_000, 1000);
        let b_mc = MatrixCharacteristics::new(1000, 1, 1000, -1);
        let mut t = VarTracker::default();
        t.create("X", x_mc, Format::BinaryBlock, true);
        t.create("y", y_mc, Format::BinaryBlock, true);
        t.create("_mVar5", a_mc, Format::BinaryBlock, false);
        t.create("_mVar6", b_mc, Format::BinaryBlock, false);
        let job = SparkJob {
            inputs: vec!["X".into(), "y".into()],
            broadcasts: vec!["y".into()],
            stages: vec![
                SparkStage {
                    wide: false,
                    insts: vec![
                        MrInst {
                            op: MrOp::Tsmm { left: true },
                            inputs: vec![0],
                            output: 2,
                            mc: a_mc,
                        },
                        MrInst { op: MrOp::Transpose, inputs: vec![0], output: 3, mc: tx_mc },
                        MrInst {
                            op: MrOp::MapMM { right_part: true },
                            inputs: vec![3, 1],
                            output: 4,
                            mc: b_mc,
                        },
                    ],
                },
                SparkStage {
                    wide: true,
                    insts: vec![
                        MrInst {
                            op: MrOp::Agg { kahan: true },
                            inputs: vec![2],
                            output: 5,
                            mc: a_mc,
                        },
                        MrInst {
                            op: MrOp::Agg { kahan: true },
                            inputs: vec![4],
                            output: 6,
                            mc: b_mc,
                        },
                    ],
                },
            ],
            outputs: vec!["_mVar5".into(), "_mVar6".into()],
            result_indices: vec![5, 6],
            num_reducers: 12,
            replication: 1,
        };
        (job, t)
    }

    /// The identical wave as the Figure-3 MR job, for latency comparison.
    fn xl1_mr_cost() -> MrJobCost {
        let x_mc = MatrixCharacteristics::dense(100_000_000, 1_000, 1000);
        let y_mc = MatrixCharacteristics::dense(100_000_000, 1, 1000);
        let a_mc = MatrixCharacteristics::new(1000, 1000, 1000, -1);
        let tx_mc = MatrixCharacteristics::dense(1_000, 100_000_000, 1000);
        let b_mc = MatrixCharacteristics::new(1000, 1, 1000, -1);
        let mut t = VarTracker::default();
        t.create("X", x_mc, Format::BinaryBlock, true);
        t.create("_mVar3", y_mc, Format::BinaryBlock, true);
        t.create("_mVar5", a_mc, Format::BinaryBlock, false);
        t.create("_mVar6", b_mc, Format::BinaryBlock, false);
        let job = MrJob {
            job_type: JobType::Gmr,
            inputs: vec!["X".into(), "_mVar3".into()],
            dcache: vec!["_mVar3".into()],
            map_insts: vec![
                MrInst { op: MrOp::Tsmm { left: true }, inputs: vec![0], output: 2, mc: a_mc },
                MrInst { op: MrOp::Transpose, inputs: vec![0], output: 3, mc: tx_mc },
                MrInst {
                    op: MrOp::MapMM { right_part: true },
                    inputs: vec![3, 1],
                    output: 4,
                    mc: b_mc,
                },
            ],
            shuffle_insts: vec![],
            agg_insts: vec![
                MrInst { op: MrOp::Agg { kahan: true }, inputs: vec![2], output: 5, mc: a_mc },
                MrInst { op: MrOp::Agg { kahan: true }, inputs: vec![4], output: 6, mc: b_mc },
            ],
            other_insts: vec![],
            outputs: vec!["_mVar5".into(), "_mVar6".into()],
            result_indices: vec![5, 6],
            num_reducers: 12,
            replication: 1,
        };
        let (cfg, cc, k) = paper_env();
        cost_mr_job(&job, &mut t, &cfg, &cc, &k)
    }

    #[test]
    fn xl1_spark_job_task_counts() {
        let (job, mut t) = xl1_spark_job();
        let (cfg, cc, k) = paper_env();
        let c = cost_spark_job(&job, &mut t, &cfg, &cc, &k);
        // Figure 5's nmap = 5967 includes 6 splits of the dcache'd y; the
        // Spark scan excludes broadcast variables, leaving X's 5961.
        assert_eq!(c.n_tasks, 5961, "X splits only (broadcasts excluded)");
        assert_eq!(c.n_stages, 2);
        assert_eq!(c.n_shuffle_tasks, 1, "1x1-block outputs bound reducers");
        assert!(c.total().is_finite() && c.total() > 0.0);
    }

    #[test]
    fn spark_latency_far_below_mr_for_identical_wave() {
        let (job, mut t) = xl1_spark_job();
        let (cfg, cc, k) = paper_env();
        let sp = cost_spark_job(&job, &mut t, &cfg, &cc, &k);
        let mr = xl1_mr_cost();
        assert!(
            sp.latency < mr.latency / 10.0,
            "spark latency {} vs mr {}",
            sp.latency,
            mr.latency
        );
        // compute terms are comparable (same slots, same FLOP models)
        assert!((sp.exec - (mr.map_exec + mr.red_exec)).abs() / (mr.map_exec + mr.red_exec) < 0.2);
        // and the whole job is cheaper on Spark
        assert!(sp.total() < mr.total(), "{} < {}", sp.total(), mr.total());
    }

    #[test]
    fn broadcast_cheaper_than_dcache_reread() {
        let (job, mut t) = xl1_spark_job();
        let (cfg, cc, k) = paper_env();
        let sp = cost_spark_job(&job, &mut t, &cfg, &cc, &k);
        let mr = xl1_mr_cost();
        assert!(sp.broadcast < mr.dcache_read, "{} < {}", sp.broadcast, mr.dcache_read);
    }

    #[test]
    fn outputs_marked_hdfs_after_job() {
        let (job, mut t) = xl1_spark_job();
        let (cfg, cc, k) = paper_env();
        cost_spark_job(&job, &mut t, &cfg, &cc, &k);
        assert_eq!(t.get("_mVar5").unwrap().state, DataState::Hdfs);
        assert_eq!(t.get("_mVar6").unwrap().state, DataState::Hdfs);
    }

    #[test]
    fn in_memory_inputs_pay_export() {
        let (job, mut t) = xl1_spark_job();
        let (cfg, cc, k) = paper_env();
        t.touch_mem("X");
        let c = cost_spark_job(&job, &mut t, &cfg, &cc, &k);
        assert!(c.export > 1000.0, "800GB export is expensive: {}", c.export);
    }

    #[test]
    fn latency_no_longer_dominates_tiny_jobs() {
        // The MR model's 20 s floor dwarfs tiny jobs; Spark's ~1.65 s
        // floor does not (the Kaoudi et al. backend-flip mechanism).
        let mc = MatrixCharacteristics::dense(100, 100, 100);
        let mut t = VarTracker::default();
        t.create("X", mc, Format::BinaryBlock, true);
        t.create("out", mc, Format::BinaryBlock, false);
        let job = SparkJob {
            inputs: vec!["X".into()],
            broadcasts: vec![],
            stages: vec![SparkStage {
                wide: false,
                insts: vec![MrInst { op: MrOp::Transpose, inputs: vec![0], output: 1, mc }],
            }],
            outputs: vec!["out".into()],
            result_indices: vec![1],
            num_reducers: 12,
            replication: 1,
        };
        let (cfg, cc, k) = paper_env();
        let c = cost_spark_job(&job, &mut t, &cfg, &cc, &k);
        assert!(c.latency < 2.0, "spark floor is small: {}", c.latency);
        assert!(c.total() < 5.0);
    }

    #[test]
    fn none_fault_profile_is_bitwise_identity() {
        let (job, mut t1) = xl1_spark_job();
        let (_, mut t2) = xl1_spark_job();
        let (cfg, cc, k) = paper_env();
        let base = cost_spark_job(&job, &mut t1, &cfg, &cc, &k);
        let none = cost_spark_job_faults(&job, &mut t2, &cfg, &cc, &k, &FaultProfile::none());
        assert_eq!(base.total().to_bits(), none.total().to_bits());
        assert_eq!(base.exec.to_bits(), none.exec.to_bits());
        assert_eq!(base.latency.to_bits(), none.latency.to_bits());
    }

    #[test]
    fn chaos_hits_spark_harder_than_mr_per_attempt() {
        // The chaos profile's spark_fail_p > mr_fail_p models lineage
        // recomputation; the relative inflation of the Spark exec term
        // must exceed MR's under the same profile.
        let fp = FaultProfile::chaos();
        let (job, mut t1) = xl1_spark_job();
        let (_, mut t2) = xl1_spark_job();
        let (cfg, cc, k) = paper_env();
        let base = cost_spark_job(&job, &mut t1, &cfg, &cc, &k);
        let chaos = cost_spark_job_faults(&job, &mut t2, &cfg, &cc, &k, &fp);
        assert!(chaos.total() > base.total());
        assert!(chaos.exec > base.exec);
        assert!(chaos.latency > base.latency, "backoff adds latency");
        let spark_inflation = chaos.exec / base.exec;
        assert!(
            spark_inflation >= fp.expected_attempts(fp.spark_fail_p),
            "retries then tail: {spark_inflation}"
        );
        assert!(
            fp.expected_attempts(fp.spark_fail_p) > fp.expected_attempts(fp.mr_fail_p),
            "chaos prices Spark attempts as more failure-prone"
        );
    }
}
