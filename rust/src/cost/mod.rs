//! The paper's contribution: a white-box analytical cost model that costs
//! *generated runtime plans* (§3). A single pass in execution order tracks
//! live-variable sizes and in-memory state, computes a time estimate per
//! instruction (latency + IO + compute, linearised into seconds), and
//! aggregates over control flow with Eq. 1:
//!
//! ```text
//! T̂(b) = w_b · Σ T̂(cᵢ),   w_b = ⌈N̂/k⌉ (parfor) | N̂ (for/while)
//!                               | 1/|c(n)| (if) | 1 (otherwise)
//! ```
//!
//! `C(P, cc) = T̂(P)`.
//!
//! This module is kept `missing_docs`-clean: every public item carries
//! rustdoc (checked by the lint below; see docs/ARCHITECTURE.md for the
//! narrative version of the model).

#![warn(missing_docs)]

pub mod cache;
pub mod flops;
pub mod mr;
pub mod spark;
pub mod vars;

use std::sync::Arc;

use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::ir::BinOp;
use crate::matrix::{Format, MatrixCharacteristics};
use crate::rtprog::*;
use cache::{BlockHash, CostCache, ProgramHashes};
use vars::{DataState, VarTracker};

/// Cost of one instruction, split IO / compute (Figure 4's `C=[io, comp]`).
#[derive(Clone, Debug, Default)]
pub struct InstCost {
    /// IO seconds: HDFS reads of cold inputs plus persistent writes.
    pub io: f64,
    /// Portion of `io` that is persistent-write time (`io - io_write` is
    /// read time). MR/Spark jobs carry their own read/write split in the
    /// per-job breakdown instead. Used by [`crate::feedback`] to attribute
    /// block cost to the read vs write bandwidth constants.
    pub io_write: f64,
    /// Compute seconds: `max(FLOPs/clock, bytes/mem_bw)` (§3.3).
    pub compute: f64,
    /// MR jobs carry a full breakdown instead.
    pub mr: Option<mr::MrJobCost>,
    /// Spark jobs carry a stage-DAG breakdown instead.
    pub spark: Option<spark::SparkJobCost>,
}

impl InstCost {
    /// Total seconds (MR/Spark breakdown total, or `io + compute`).
    pub fn total(&self) -> f64 {
        match (&self.mr, &self.spark) {
            (Some(m), _) => m.total(),
            (_, Some(s)) => s.total(),
            _ => self.io + self.compute,
        }
    }
}

/// Cost annotation tree, parallel to the runtime program structure.
#[derive(Clone, Debug)]
pub enum CostNode {
    /// A program block (generic/if/for/while/fcall) with its Eq.-1
    /// weighted total and child annotations.
    Block {
        /// Display label, e.g. `GENERIC (lines 1-3)`.
        label: String,
        /// Weighted total seconds for the block (Eq. 1).
        total: f64,
        /// Child annotations (instructions and nested blocks).
        children: Vec<CostNode>,
    },
    /// One instruction with its rendered text and cost split.
    Inst {
        /// SystemML-style instruction string.
        rendered: String,
        /// IO/compute (or MR breakdown) cost of the instruction.
        cost: InstCost,
    },
}

impl CostNode {
    /// Total seconds of this node.
    pub fn total(&self) -> f64 {
        match self {
            CostNode::Block { total, .. } => *total,
            CostNode::Inst { cost, .. } => cost.total(),
        }
    }
}

/// Full cost report for a program.
#[derive(Clone, Debug)]
pub struct CostReport {
    /// `C(P, cc)` — estimated execution time in seconds.
    pub total: f64,
    /// Per-block cost annotations in program order (Figures 4/5).
    pub nodes: Vec<CostNode>,
}

/// Cost a runtime program against a cluster configuration (the paper's
/// `C(P, cc) = T̂(P)`).
pub fn cost_program(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
) -> CostReport {
    cost_with(rt, None, cfg, cc, k, &FaultProfile::none(), true, None)
}

/// [`cost_program`] under a failure model: distributed-job terms are
/// expanded to their retry-aware expectation (geometric retries, backoff
/// latency, straggler tail — see [`mr::cost_mr_job_faults`]). With
/// [`FaultProfile::none`] this is bitwise-identical to [`cost_program`].
pub fn cost_program_faults(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fault: &FaultProfile,
) -> CostReport {
    cost_with(rt, None, cfg, cc, k, fault, true, None)
}

/// [`cost_program`] with block-level cost caching: subtrees whose
/// structural hash, incoming variable-state fingerprint and relevant
/// configuration knobs match an earlier costing are replayed from
/// `cache` instead of being re-walked. `hashes` must be the
/// [`cache::program_hashes`] of this exact `rt` (compute once per
/// compiled plan). Produces a bitwise-identical [`CostReport`] to the
/// uncached path; see [`cache`] for the key design.
pub fn cost_program_cached(
    rt: &RtProgram,
    hashes: &ProgramHashes,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    cache: &CostCache,
) -> CostReport {
    cost_with(rt, Some(hashes), cfg, cc, k, &FaultProfile::none(), true, Some(cache))
}

/// [`cost_program_cached`] under a failure model (see
/// [`cost_program_faults`]); the fault profile participates in the knob
/// fingerprint for distributed blocks, so faulty and fault-free entries
/// share one [`CostCache`] without aliasing.
pub fn cost_program_cached_faults(
    rt: &RtProgram,
    hashes: &ProgramHashes,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fault: &FaultProfile,
    cache: &CostCache,
) -> CostReport {
    cost_with(rt, Some(hashes), cfg, cc, k, fault, true, Some(cache))
}

/// Totals-only costing: identical arithmetic to [`cost_program`] (the
/// returned value is bitwise equal to `cost_program(..).total`) but no
/// per-instruction annotation nodes are materialised and no instruction
/// text is rendered — the fast path for optimizers that only rank by
/// `C(P, cc)`.
pub fn cost_total(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
) -> f64 {
    cost_with(rt, None, cfg, cc, k, &FaultProfile::none(), false, None).total
}

/// [`cost_total`] under a failure model (see [`cost_program_faults`]).
pub fn cost_total_faults(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fault: &FaultProfile,
) -> f64 {
    cost_with(rt, None, cfg, cc, k, fault, false, None).total
}

/// [`cost_total`] with block-level cost caching (see
/// [`cost_program_cached`]); the fast path the candidate evaluator
/// ([`crate::opt::evaluate`]) runs every optimizer through.
pub fn cost_total_cached(
    rt: &RtProgram,
    hashes: &ProgramHashes,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    cache: &CostCache,
) -> f64 {
    cost_with(rt, Some(hashes), cfg, cc, k, &FaultProfile::none(), false, Some(cache)).total
}

/// [`cost_total_cached`] under a failure model. The fault profile is part
/// of the knob fingerprint for distributed blocks (see
/// [`cache::hash_knobs`]), so faulty and fault-free cache entries never
/// alias and both can share one [`CostCache`].
pub fn cost_total_cached_faults(
    rt: &RtProgram,
    hashes: &ProgramHashes,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fault: &FaultProfile,
    cache: &CostCache,
) -> f64 {
    cost_with(rt, Some(hashes), cfg, cc, k, fault, false, Some(cache)).total
}

fn cost_with(
    rt: &RtProgram,
    hashes: Option<&ProgramHashes>,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fault: &FaultProfile,
    emit_nodes: bool,
    cache: Option<&CostCache>,
) -> CostReport {
    let mut est = Estimator {
        cfg,
        cc,
        k,
        fault,
        funcs: &rt.funcs,
        call_stack: Vec::new(),
        emit_nodes,
        cache,
        func_hashes: hashes.map(|h| &h.funcs),
        knob_fps: [None; 16],
    };
    let mut tracker = VarTracker::default();
    let (total, nodes) =
        est.cost_blocks(&rt.blocks, hashes.map(|h| h.blocks.as_slice()), &mut tracker);
    CostReport { total, nodes }
}

struct Estimator<'a> {
    cfg: &'a SystemConfig,
    cc: &'a ClusterConfig,
    k: &'a CostConstants,
    /// Failure model applied to distributed-job terms; the identity
    /// profile (`FaultProfile::none()`) skips the fault arithmetic
    /// structurally, keeping totals bitwise-identical to a fault-unaware
    /// walk.
    fault: &'a FaultProfile,
    funcs: &'a std::collections::BTreeMap<String, RtFunction>,
    call_stack: Vec<String>,
    /// Materialise `CostNode` annotations (labels, rendered instruction
    /// text, children)? The totals-only mode skips all of it; every
    /// f64 accumulation is shared between the modes so totals stay
    /// bitwise identical.
    emit_nodes: bool,
    cache: Option<&'a CostCache>,
    func_hashes: Option<&'a std::collections::BTreeMap<String, Vec<BlockHash>>>,
    /// Per-walk memo of the knob fingerprints, indexed by the low four
    /// feature bits (parfor/unknown-iters/MR/Spark): the configuration
    /// never changes within a walk, so each of the ≤16 fingerprints is
    /// hashed at most once instead of twice per block lookup.
    knob_fps: [Option<(u64, u64)>; 16],
}

impl<'a> Estimator<'a> {
    /// Format a block label only when annotations are materialised.
    fn lbl(&self, f: impl FnOnce() -> String) -> String {
        if self.emit_nodes {
            f()
        } else {
            String::new()
        }
    }

    /// `hashes`, when present, is the [`BlockHash`] forest aligned
    /// one-to-one with `blocks` (same invariant recursively below).
    fn cost_blocks(
        &mut self,
        blocks: &[RtBlock],
        hashes: Option<&[BlockHash]>,
        t: &mut VarTracker,
    ) -> (f64, Vec<CostNode>) {
        let mut total = 0.0;
        let mut nodes = Vec::new();
        for (i, b) in blocks.iter().enumerate() {
            let node = self.cost_block(b, hashes.map(|h| &h[i]), t);
            total += node.total();
            if self.emit_nodes {
                nodes.push(node);
            }
        }
        (total, nodes)
    }

    /// Lazily hash the knob fingerprint for one feature combination.
    fn knob_fp(&mut self, feats: u8) -> (u64, u64) {
        let idx = (feats & 0x0F) as usize;
        if let Some(fp) = self.knob_fps[idx] {
            return fp;
        }
        let fp = cache::knob_fingerprint(
            feats & 0x0F,
            self.emit_nodes,
            self.cfg,
            self.cc,
            self.k,
            self.fault,
        );
        self.knob_fps[idx] = Some(fp);
        fp
    }

    /// Cache wrapper around [`Self::cost_block_inner`]: a hit replays the
    /// stored annotation and tracker state; a miss costs the block and
    /// stores both. Keys cover the full observable input (see
    /// [`cache::cache_key`]), so hits are bitwise-exact replays. The
    /// stored tracker is compacted to its live bindings, so replaying it
    /// is O(live variables), not O(every temp ever created).
    fn cost_block(&mut self, b: &RtBlock, bh: Option<&BlockHash>, t: &mut VarTracker) -> CostNode {
        if let (Some(cache), Some(bh)) = (self.cache, bh) {
            if bh.cacheable() {
                let knobs = self.knob_fp(bh.feats);
                let key = cache::cache_key(bh, t, knobs);
                if let Some(entry) = cache.get(&key) {
                    *t = entry.tracker.clone();
                    return entry.node.clone();
                }
                let node = self.cost_block_inner(b, Some(bh), t);
                cache.insert(
                    key,
                    Arc::new(cache::CachedBlockCost {
                        node: node.clone(),
                        tracker: t.compacted(),
                    }),
                );
                return node;
            }
        }
        self.cost_block_inner(b, bh, t)
    }

    fn cost_block_inner(
        &mut self,
        b: &RtBlock,
        bh: Option<&BlockHash>,
        t: &mut VarTracker,
    ) -> CostNode {
        match b {
            RtBlock::Generic { insts, lines, .. } => {
                let mut children = Vec::new();
                let mut total = 0.0;
                for inst in insts {
                    let cost = self.cost_inst(inst, t);
                    total += cost.total();
                    if self.emit_nodes {
                        children.push(CostNode::Inst {
                            rendered: explain::render_inst(inst),
                            cost,
                        });
                    }
                }
                CostNode::Block {
                    label: self.lbl(|| format!("GENERIC (lines {}-{})", lines.0, lines.1)),
                    total,
                    children,
                }
            }
            RtBlock::If { pred, then_blocks, else_blocks, lines } => {
                // Eq. 1: weighted sum over branches, w = 1/|c(n)|.
                let (pt, mut children) = self.cost_insts(&pred.insts, t);
                let mut then_t = t.clone();
                let (tt, tn) = self.cost_blocks(
                    then_blocks,
                    bh.map(|b| &b.children[..then_blocks.len()]),
                    &mut then_t,
                );
                let mut else_t = t.clone();
                let (et, en) = self.cost_blocks(
                    else_blocks,
                    bh.map(|b| &b.children[then_blocks.len()..]),
                    &mut else_t,
                );
                // Both arms have two successors (then + else/fall-through);
                // a missing else is an empty branch costing 0, so the
                // weighted total collapses to pt + tt/2.
                let total = if else_blocks.is_empty() {
                    pt + tt / 2.0
                } else {
                    pt + (tt + et) / 2.0
                };
                children.extend(tn);
                children.extend(en);
                then_t.merge(&else_t);
                *t = then_t;
                CostNode::Block {
                    label: self.lbl(|| format!("IF (lines {}-{})", lines.0, lines.1)),
                    total,
                    children,
                }
            }
            RtBlock::For { from, to, by, body, parfor, known_trip, lines, .. } => {
                let mut pred_cost = 0.0;
                let mut children = Vec::new();
                for p in [Some(from), Some(to), by.as_ref()].into_iter().flatten() {
                    let (c, n) = self.cost_insts(&p.insts, t);
                    pred_cost += c;
                    children.extend(n);
                }
                let n_iter = known_trip.unwrap_or(self.cfg.unknown_iterations).max(0.0);
                // Eq. 1: parfor scales by ceil(N/k). The divisor is floored
                // at 1 so a degenerate `k_local == 0` (rejected by
                // `ClusterConfig::validate`, but cost_program can be called
                // directly) yields a serial weight instead of `inf`.
                let w = if *parfor {
                    (n_iter / self.cc.k_local.max(1) as f64).ceil()
                } else {
                    n_iter
                };
                // Loop read-cost correction (§3.2): the first iteration pays
                // persistent reads, subsequent iterations see warm state.
                let body_hashes = bh.map(|b| b.children.as_slice());
                let mut first_t = t.clone();
                let (first, body_nodes) = self.cost_blocks(body, body_hashes, &mut first_t);
                let (steady, _) = self.cost_blocks(body, body_hashes, &mut first_t);
                let total = pred_cost
                    + if w >= 1.0 { first + (w - 1.0) * steady } else { w * first };
                children.extend(body_nodes);
                // With w < 1 the body may never run, so the warmed state
                // cannot be committed outright: merge conservatively (like
                // If branches) so reads that may not have happened are
                // still charged to later uses.
                if w >= 1.0 {
                    *t = first_t;
                } else {
                    first_t.merge(t);
                    *t = first_t;
                }
                let kind = if *parfor { "PARFOR" } else { "FOR" };
                CostNode::Block {
                    label: self
                        .lbl(|| format!("{kind} (lines {}-{}) [N={n_iter}, w={w}]", lines.0, lines.1)),
                    total,
                    children,
                }
            }
            RtBlock::While { pred, body, lines } => {
                let (pt, mut children) = self.cost_insts(&pred.insts, t);
                let n_iter = self.cfg.unknown_iterations.max(0.0);
                let body_hashes = bh.map(|b| b.children.as_slice());
                let mut first_t = t.clone();
                let (first, body_nodes) = self.cost_blocks(body, body_hashes, &mut first_t);
                let (steady, _) = self.cost_blocks(body, body_hashes, &mut first_t);
                // Predicate evaluated each iteration (N̂ + the final false
                // check). The body follows the same first/steady §3.2 split
                // as For: with N̂ < 1 it scales down to N̂·first instead of
                // charging one full first iteration — a zero-iteration
                // While costs only its predicate.
                let total = pt * (n_iter + 1.0)
                    + if n_iter >= 1.0 { first + (n_iter - 1.0) * steady } else { n_iter * first };
                children.extend(body_nodes);
                // As with For: only commit the warmed tracker state when
                // the body is actually charged; otherwise merge, so a
                // zero-trip loop does not make later reads free.
                if n_iter >= 1.0 {
                    *t = first_t;
                } else {
                    first_t.merge(t);
                    *t = first_t;
                }
                CostNode::Block {
                    label: self.lbl(|| format!("WHILE (lines {}-{}) [N̂={n_iter}]", lines.0, lines.1)),
                    total,
                    children,
                }
            }
            RtBlock::FCall { fname, args, outputs, lines } => {
                // Function call stack prevents cycles (§3.2).
                if self.call_stack.contains(fname) {
                    return CostNode::Block {
                        label: self.lbl(|| {
                            format!("FCALL {fname} (recursive, lines {}-{})", lines.0, lines.1)
                        }),
                        total: 0.0,
                        children: vec![],
                    };
                }
                let Some(f) = self.funcs.get(fname) else {
                    return CostNode::Block {
                        label: self.lbl(|| format!("FCALL {fname} (unknown)")),
                        total: 0.0,
                        children: vec![],
                    };
                };
                self.call_stack.push(fname.clone());
                // bind arguments into a fresh tracker
                let mut ft = VarTracker::default();
                for (p, a) in f.params.iter().zip(args.iter()) {
                    if let Some(info) = t.get(a) {
                        ft.create(p, info.mc, info.format, info.state == DataState::Hdfs);
                    }
                }
                let fh = self.func_hashes.and_then(|m| m.get(fname)).map(|v| v.as_slice());
                let (total, children) = self.cost_blocks(&f.blocks, fh, &mut ft);
                self.call_stack.pop();
                for (caller, callee) in outputs.iter().zip(f.outputs.iter()) {
                    if let Some(info) = ft.get(callee) {
                        t.create(caller, info.mc, info.format, info.state == DataState::Hdfs);
                    }
                }
                CostNode::Block {
                    label: self.lbl(|| format!("FCALL {fname} (lines {}-{})", lines.0, lines.1)),
                    total,
                    children,
                }
            }
        }
    }

    fn cost_insts(&mut self, insts: &[Instr], t: &mut VarTracker) -> (f64, Vec<CostNode>) {
        let mut total = 0.0;
        let mut nodes = Vec::new();
        for inst in insts {
            let cost = self.cost_inst(inst, t);
            total += cost.total();
            if self.emit_nodes {
                nodes.push(CostNode::Inst { rendered: explain::render_inst(inst), cost });
            }
        }
        (total, nodes)
    }

    /// Cost one instruction and update the live-variable state.
    fn cost_inst(&mut self, inst: &Instr, t: &mut VarTracker) -> InstCost {
        let book = InstCost { compute: self.k.bookkeeping, ..InstCost::default() };
        match inst {
            Instr::CreateVar { var, temp, format, mc, .. } => {
                t.create(var, *mc, *format, !*temp);
                book
            }
            Instr::AssignVar { .. } => book,
            Instr::CpVar { src, dst } => {
                t.alias(src, dst);
                book
            }
            Instr::RmVar { vars } => {
                for v in vars {
                    t.remove(v);
                }
                InstCost::default() // not counted (display-suppressed)
            }
            Instr::Cp(c) => self.cost_cp(c, t),
            Instr::MrJob(j) => {
                let jc = mr::cost_mr_job_faults(j, t, self.cfg, self.cc, self.k, self.fault);
                InstCost { mr: Some(jc), ..InstCost::default() }
            }
            Instr::SparkJob(j) => {
                let jc =
                    spark::cost_spark_job_faults(j, t, self.cfg, self.cc, self.k, self.fault);
                InstCost { spark: Some(jc), ..InstCost::default() }
            }
        }
    }

    /// CP instruction: IO time (state-dependent) + compute time
    /// `max(mem-bandwidth, FLOPs/clock)` (§3.3).
    fn cost_cp(&mut self, c: &CpInst, t: &mut VarTracker) -> InstCost {
        let mut io = 0.0;
        // Inputs: HDFS-resident matrices pay format-specific read time once.
        for inp in &c.inputs {
            if let Operand::Mat(name) = inp {
                let info = t.get(name).cloned();
                if let Some(info) = info {
                    if info.state == DataState::Hdfs {
                        io += self.read_time(&info.mc, info.format);
                    }
                    t.touch_mem(name);
                }
            }
        }
        let in_mc: Vec<MatrixCharacteristics> = c
            .inputs
            .iter()
            .map(|o| match o {
                Operand::Mat(n) => t.mc(n),
                _ => MatrixCharacteristics::scalar(),
            })
            .collect();
        let out_mc = match &c.output {
            Operand::Mat(n) => t.mc(n),
            _ => MatrixCharacteristics::scalar(),
        };
        let unknown = MatrixCharacteristics::unknown;
        let a = in_mc.first().copied().unwrap_or_else(unknown);
        let b = in_mc.get(1).copied().unwrap_or_else(unknown);
        let mut flops = match &c.op {
            CpOp::Tsmm { .. } => flops::tsmm(&a),
            CpOp::MatMult => flops::matmult(&a, &b),
            CpOp::Transpose => flops::transpose(&a),
            CpOp::Diag => flops::diag(&a),
            CpOp::Rand { .. } => flops::rand(&out_mc),
            CpOp::Seq { .. } => flops::rand(&out_mc),
            CpOp::Binary(BinOp::Solve) => flops::solve(&a, &b),
            CpOp::Binary(op) => {
                let shape = if a.dims_known() && !a.is_scalar() { a } else { b };
                flops::binary(*op, &if out_mc.dims_known() { out_mc } else { shape })
            }
            CpOp::Unary(op) => flops::unary(*op, &a),
            CpOp::AggUnary(op, _) => flops::agg_unary(*op, &a),
            CpOp::Append => flops::append(&out_mc),
            CpOp::Partition => flops::partition(&a),
            CpOp::Write { format, .. } => match format {
                Format::TextCell | Format::Csv => flops::text_write(&a),
                Format::BinaryBlock => flops::transpose(&a), // copy cost
            },
            CpOp::Print => 1.0,
        };
        // multi-threaded CP ops exploit local parallelism for the heavy
        // kernels (matmult family); SystemML 2015-era CP ops were largely
        // single-threaded, which the paper's figures reflect -> factor 1.
        flops = flops.max(0.0);
        let mem_bytes: f64 = in_mc
            .iter()
            .chain(std::iter::once(&out_mc))
            .map(|m| m.mem_estimate(self.cfg.sparse_threshold))
            .filter(|m| m.is_finite())
            .sum();
        let compute = (flops / (self.cc.clock_hz * self.k.flop_efficiency))
            .max(mem_bytes / self.k.mem_bw);

        // Output IO: persistent writes / partition copies.
        let mut io_write = 0.0;
        match &c.op {
            CpOp::Write { format, .. } => {
                io_write += self.write_time(&a, *format);
            }
            CpOp::Partition => {
                // writes the partitioned copy back to HDFS
                io_write += self.write_time(&a, Format::BinaryBlock);
                if let Operand::Mat(out) = &c.output {
                    t.set_hdfs(out);
                }
            }
            _ => {}
        }
        // outputs of in-memory instructions are in-memory
        if let Operand::Mat(out) = &c.output {
            if !matches!(c.op, CpOp::Partition) {
                t.touch_mem(out);
            }
        }
        InstCost { io: io + io_write, io_write, compute, ..InstCost::default() }
    }

    fn read_time(&self, mc: &MatrixCharacteristics, format: Format) -> f64 {
        let size = mc.serialized_size(format);
        if !size.is_finite() {
            return 0.0; // unknowns cannot be costed (§3.5)
        }
        let bw = match format {
            Format::BinaryBlock => self.k.hdfs_read_binaryblock,
            _ => self.k.hdfs_read_text,
        };
        size / bw
    }

    fn write_time(&self, mc: &MatrixCharacteristics, format: Format) -> f64 {
        let size = mc.serialized_size(format);
        if !size.is_finite() {
            return 0.0;
        }
        let bw = match format {
            Format::BinaryBlock => self.k.hdfs_write_binaryblock,
            _ => self.k.hdfs_write_text,
        };
        size / bw
    }
}

// ---------------------------------------------------------------------
// Persistent-read IO floor (grid-optimizer pruning bound)
// ---------------------------------------------------------------------

/// A compile-free lower bound on `C(P, cc)`: the **persistent-read IO
/// floor**. Any plan generated for a script that touches each of its
/// persistent inputs at least once (outside conditionals and zero-trip
/// loops — true of straight-line read-then-iterate ML scripts like the
/// LinReg family) must read those bytes at least once, through *some*
/// read path. The floor prices each input through the cheapest path the
/// given backend offers and sums:
///
/// * **CP path** — single-threaded HDFS read at the input's format
///   bandwidth (`cost_cp` charges exactly this on first touch).
/// * **MR paths** — parallel map-side HDFS scan, or the distributed
///   cache (which streams `min(size, partition)` bytes per task and so
///   gains at most a `hdfs_block/partition` amplification). Effective
///   parallelism is bounded by `slots · dop_scale` (the §3.3 scaled
///   minimum), floored at 1.
/// * **Spark paths** — parallel executor scan, or one torrent broadcast
///   at `spark_broadcast_bw` (costed once, not per task).
///
/// Used by [`crate::opt::resource`] to prune grid points that can never
/// reach the Pareto frontier without compiling them; `tests/resource.rs`
/// property-checks `floor <= cost_program(..).total` across random
/// scenario sizes, cluster shapes and backends.
pub fn read_io_floor(
    inputs: &[(MatrixCharacteristics, Format)],
    backend: ExecBackend,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
) -> f64 {
    let k_map_ub = (cc.effective_k_map() as f64 * k.dop_scale).max(1.0);
    let k_spark_ub = (cc.k_spark() as f64 * k.dop_scale).max(1.0);
    let dcache_amp = (cc.hdfs_block_bytes / cfg.partition_bytes).max(1.0);
    let mut floor = 0.0;
    for (mc, fmt) in inputs {
        let cp_size = mc.serialized_size(*fmt);
        if !cp_size.is_finite() {
            continue; // unknowns cannot be costed (§3.5)
        }
        let cp_bw = match fmt {
            Format::BinaryBlock => k.hdfs_read_binaryblock,
            _ => k.hdfs_read_text,
        };
        let cp_floor = cp_size / cp_bw;
        let bb = mc.serialized_size(Format::BinaryBlock);
        let dist_floor = match backend {
            ExecBackend::Cp => f64::INFINITY,
            ExecBackend::Mr => {
                let throughput = (k.hdfs_read_binaryblock * k_map_ub)
                    .max(k.dcache_read * dcache_amp * k_map_ub);
                bb / throughput
            }
            ExecBackend::Spark => {
                let throughput =
                    (k.hdfs_read_binaryblock * k_spark_ub).max(k.spark_broadcast_bw);
                bb / throughput
            }
        };
        floor += cp_floor.min(dist_floor);
    }
    floor
}

// ---------------------------------------------------------------------
// Cost-annotated EXPLAIN (Figures 4 and 5)
// ---------------------------------------------------------------------

/// Render the cost-annotated runtime plan (paper Figures 4/5).
pub fn explain_costed(report: &CostReport) -> String {
    use crate::util::fmt::fmt_secs;
    let mut out = format!("PROGRAM                              # total cost C={}\n", fmt_secs(report.total));
    out.push_str("--MAIN PROGRAM\n");
    fn walk(nodes: &[CostNode], out: &mut String, indent: usize) {
        for n in nodes {
            match n {
                CostNode::Block { label, total, children } => {
                    out.push_str(&format!(
                        "{}{label}  # C={}\n",
                        "-".repeat(indent),
                        crate::util::fmt::fmt_secs(*total)
                    ));
                    walk(children, out, indent + 2);
                }
                CostNode::Inst { rendered, cost } => {
                    let annot = match (&cost.mr, &cost.spark) {
                        (Some(m), _) => m.annotate(),
                        (_, Some(s)) => s.annotate(),
                        _ => format!(
                            "# C=[{}, {}]",
                            crate::util::fmt::fmt_secs(cost.io),
                            crate::util::fmt::fmt_secs(cost.compute)
                        ),
                    };
                    let first_line = rendered.lines().next().unwrap_or("");
                    out.push_str(&format!("{}{first_line}  {annot}\n", "-".repeat(indent)));
                    for extra in rendered.lines().skip(1) {
                        out.push_str(&format!("{}{extra}\n", "-".repeat(indent)));
                    }
                }
            }
        }
    }
    walk(&report.nodes, &mut out, 4);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{CompileOptions, Scenario};

    fn cost_scenario(s: Scenario) -> CostReport {
        let opts = CompileOptions::default();
        let c = s.compile(&opts);
        cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default())
    }

    #[test]
    fn xs_total_cost_matches_figure4() {
        // Figure 4: total C = 3.31 s.
        let r = cost_scenario(Scenario::xs());
        assert!(
            (r.total - 3.31).abs() < 0.25,
            "XS total {} != paper 3.31s",
            r.total
        );
    }

    #[test]
    fn xs_tsmm_dominates() {
        // Figure 4 discussion: tsmm computation dominates; next heavy
        // hitters are the initial read of X and solve.
        let r = cost_scenario(Scenario::xs());
        let mut inst_costs: Vec<(String, f64)> = Vec::new();
        fn collect(nodes: &[CostNode], out: &mut Vec<(String, f64)>) {
            for n in nodes {
                match n {
                    CostNode::Block { children, .. } => collect(children, out),
                    CostNode::Inst { rendered, cost } => {
                        out.push((rendered.clone(), cost.total()))
                    }
                }
            }
        }
        collect(&r.nodes, &mut inst_costs);
        inst_costs.sort_by(|a, b| b.1.total_cmp(&a.1));
        assert!(inst_costs[0].0.contains("tsmm"), "top: {:?}", &inst_costs[..3]);
        assert!(inst_costs[1].0.contains("solve"), "{:?}", &inst_costs[..3]);
        // tsmm io ~0.51, compute ~2.33
        let tsmm = &inst_costs[0];
        assert!((tsmm.1 - 2.83).abs() < 0.1, "tsmm total {}", tsmm.1);
    }

    #[test]
    fn xl1_total_cost_matches_figure5() {
        // Figure 5: total C = 606.9 s, MR job 589.8 s.
        let r = cost_scenario(Scenario::xl1());
        assert!(
            (r.total - 606.9).abs() < 45.0,
            "XL1 total {} != paper 606.9s",
            r.total
        );
    }

    #[test]
    fn xl1_mr_breakdown_matches_figure5() {
        let r = cost_scenario(Scenario::xl1());
        let mut mr_cost = None;
        fn find_mr(nodes: &[CostNode], out: &mut Option<mr::MrJobCost>) {
            for n in nodes {
                match n {
                    CostNode::Block { children, .. } => find_mr(children, out),
                    CostNode::Inst { cost, .. } => {
                        if let Some(m) = &cost.mr {
                            *out = Some(m.clone());
                        }
                    }
                }
            }
        }
        find_mr(&r.nodes, &mut mr_cost);
        let m = mr_cost.expect("XL1 has an MR job");
        // Figure 5: nmap=5967, nred=1, latency 144.5, hdfsread 70.7,
        // mapexec 324.7, dcread 12.6, shuffle 19.7, redexec 11.1.
        assert_eq!(m.n_map, 5967, "nmap");
        assert_eq!(m.n_red, 1, "nred");
        assert!((m.latency - 144.5).abs() < 8.0, "latency {}", m.latency);
        assert!((m.hdfs_read - 70.7).abs() < 4.0, "hdfsread {}", m.hdfs_read);
        assert!((m.map_exec - 324.7).abs() < 16.0, "mapexec {}", m.map_exec);
        assert!((m.dcache_read - 12.6).abs() < 2.0, "dcread {}", m.dcache_read);
        assert!((m.shuffle - 19.7).abs() < 4.0, "shuffle {}", m.shuffle);
        assert!((m.red_exec - 11.1).abs() < 2.0, "redexec {}", m.red_exec);
        assert!((m.total() - 589.8).abs() < 30.0, "job total {}", m.total());
    }

    #[test]
    fn first_use_pays_io_second_is_free() {
        // §3.2: "only the first instruction will pay the costs of reading".
        let r = cost_scenario(Scenario::xs());
        let mut costs = Vec::new();
        fn collect(nodes: &[CostNode], out: &mut Vec<(String, f64)>) {
            for n in nodes {
                match n {
                    CostNode::Block { children, .. } => collect(children, out),
                    CostNode::Inst { rendered, cost } => out.push((rendered.clone(), cost.io)),
                }
            }
        }
        collect(&r.nodes, &mut costs);
        let tsmm_io = costs.iter().find(|(s, _)| s.contains("tsmm")).unwrap().1;
        let bamm_io = costs.iter().find(|(s, _)| s.contains("ba+*")).unwrap().1;
        assert!(tsmm_io > 0.4, "tsmm pays X read: {tsmm_io}");
        assert_eq!(bamm_io, 0.0, "ba+* reuses in-memory X");
    }

    #[test]
    fn for_loop_scales_body_cost() {
        use crate::api::compile_with_meta;
        let src = "X = read($1);\ns = 0;\nfor (i in 1:10) { s = s + sum(X); }\nwrite(s, $4);";
        let opts = CompileOptions::default();
        let sc = Scenario::xs();
        let c = compile_with_meta(src, &sc.args(), &sc.meta(1000), &opts).unwrap();
        let r = cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default());
        // body ~ sum over 1e7 cells * 4 / 2.15e9 = 18.6ms; 10 iters ~186ms
        // plus one X read 0.51s (first iteration only!)
        assert!(r.total > 0.5 + 0.15, "total {}", r.total);
        assert!(r.total < 0.5 + 0.35, "read cost must not repeat: {}", r.total);
    }

    #[test]
    fn parfor_divides_by_parallelism() {
        use crate::api::compile_with_meta;
        let mk = |parfor: &str| {
            let src = format!(
                "X = read($1);\ns = 0;\n{parfor} (i in 1:24) {{ s = s + sum(X); }}\nwrite(s, $4);"
            );
            let opts = CompileOptions::default();
            let sc = Scenario::xs();
            let c = compile_with_meta(&src, &sc.args(), &sc.meta(1000), &opts).unwrap();
            cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default()).total
        };
        let serial = mk("for");
        let parallel = mk("parfor");
        assert!(parallel < serial, "parfor {parallel} < for {serial}");
    }

    #[test]
    fn while_uses_unknown_iteration_constant() {
        use crate::api::compile_with_meta;
        let src = "s = 1;\nwhile (s < 10) { s = s * 2; }\nwrite(s, $4);";
        let opts = CompileOptions::default();
        let sc = Scenario::xs();
        let c = compile_with_meta(src, &sc.args(), &sc.meta(1000), &opts).unwrap();
        let r = cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default());
        assert!(r.total > 0.0);
        let label_ok = r.nodes.iter().any(|n| match n {
            CostNode::Block { label, .. } => label.contains("WHILE") && label.contains("=10"),
            _ => false,
        });
        assert!(label_ok, "{:?}", r.nodes);
    }

    #[test]
    fn recursive_function_costing_terminates() {
        use crate::api::compile_with_meta;
        let src = r#"
f = function(a) return (b) { b = f(a); }
x = 3;
y = f(x);
write(y, $4);
"#;
        let opts = CompileOptions::default();
        let sc = Scenario::xs();
        let c = compile_with_meta(src, &sc.args(), &sc.meta(1000), &opts).unwrap();
        let r = cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default());
        assert!(r.total.is_finite());
    }

    #[test]
    fn explain_costed_matches_figure4_format() {
        let r = cost_scenario(Scenario::xs());
        let text = explain_costed(&r);
        assert!(text.contains("total cost C="), "{text}");
        assert!(text.contains("# C=["));
        assert!(text.contains("CP tsmm"));
    }

    /// Build a program of one If block whose then-branch (and optionally
    /// else-branch) holds a deterministic-cost rand instruction.
    fn if_program(with_else: bool) -> RtProgram {
        use crate::matrix::{Format, MatrixCharacteristics};
        let mc = MatrixCharacteristics::dense(2000, 2000, 1000);
        let branch = || {
            vec![RtBlock::Generic {
                insts: vec![
                    Instr::CreateVar {
                        var: "_mVar2".into(),
                        path: "scratch/t".into(),
                        temp: true,
                        format: Format::BinaryBlock,
                        mc,
                    },
                    Instr::Cp(CpInst {
                        op: CpOp::Rand { min: 0.0, max: 1.0, sparsity: 1.0, seed: 7 },
                        inputs: vec![],
                        output: Operand::Mat("_mVar2".into()),
                    }),
                ],
                lines: (2, 2),
                recompile: false,
            }]
        };
        let mut prog = RtProgram::default();
        prog.blocks.push(RtBlock::If {
            pred: PredProg::default(),
            then_blocks: branch(),
            else_blocks: if with_else { branch() } else { vec![] },
            lines: (1, 3),
        });
        prog
    }

    /// §3 Eq. 1, missing-else arm: the empty else branch costs 0, so the
    /// If total is pt + tt/2 — half the cost of the then-branch alone.
    #[test]
    fn if_without_else_costs_half_the_then_branch() {
        let prog = if_program(false);
        let opts = CompileOptions::default();
        let r = cost_program(&prog, &opts.cfg, &opts.cc.0, &CostConstants::default());
        // reference: the then-branch as a standalone program
        let mut solo = RtProgram::default();
        let RtBlock::If { then_blocks, .. } = &prog.blocks[0] else { unreachable!() };
        solo.blocks = then_blocks.clone();
        let solo_cost =
            cost_program(&solo, &opts.cfg, &opts.cc.0, &CostConstants::default()).total;
        assert!(solo_cost > 0.0);
        assert!(
            (r.total - solo_cost / 2.0).abs() <= 1e-12 * solo_cost,
            "if-without-else {} != then/2 {}",
            r.total,
            solo_cost / 2.0
        );
    }

    /// §3 Eq. 1, both-arms case: w = 1/2 over two populated branches.
    #[test]
    fn if_with_else_averages_both_branches() {
        let prog = if_program(true);
        let opts = CompileOptions::default();
        let r = cost_program(&prog, &opts.cfg, &opts.cc.0, &CostConstants::default());
        let mut solo = RtProgram::default();
        let RtBlock::If { then_blocks, .. } = &prog.blocks[0] else { unreachable!() };
        solo.blocks = then_blocks.clone();
        let solo_cost =
            cost_program(&solo, &opts.cfg, &opts.cc.0, &CostConstants::default()).total;
        // both branches are identical, so (tt + et)/2 == tt
        assert!(
            (r.total - solo_cost).abs() <= 1e-12 * solo_cost,
            "if-with-else {} != then {}",
            r.total,
            solo_cost
        );
    }

    /// The totals-only fast path and the cached paths must be bitwise
    /// identical to the annotated walk (the invariant every optimizer
    /// now depends on; `tests/costcache.rs` covers the full matrix).
    #[test]
    fn totals_only_and_cached_paths_match_full_costing_bitwise() {
        let k = CostConstants::default();
        for s in [Scenario::xs(), Scenario::xl1()] {
            let opts = CompileOptions::default();
            let c = s.compile(&opts);
            let full = cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &k);
            let fast = cost_total(&c.runtime, &opts.cfg, &opts.cc.0, &k);
            assert_eq!(full.total.to_bits(), fast.to_bits(), "{}", s.name);
            let hashes = cache::program_hashes(&c.runtime);
            let cc_cache = cache::CostCache::default();
            let cold =
                cost_program_cached(&c.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &cc_cache);
            let warm =
                cost_program_cached(&c.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &cc_cache);
            assert_eq!(full.total.to_bits(), cold.total.to_bits(), "{} cold", s.name);
            assert_eq!(full.total.to_bits(), warm.total.to_bits(), "{} warm", s.name);
            assert!(cc_cache.stats().hits > 0, "warm pass must hit the cache");
            let fast_cached =
                cost_total_cached(&c.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &cc_cache);
            assert_eq!(full.total.to_bits(), fast_cached.to_bits(), "{} totals", s.name);
            // warm annotated replay renders the identical costed EXPLAIN
            assert_eq!(explain_costed(&full), explain_costed(&warm), "{}", s.name);
        }
    }

    /// The tentpole identity guarantee: under `FaultProfile::none()` the
    /// fault-aware entry points are bitwise-identical to the fault-unaware
    /// ones, cached or not, and the rendered EXPLAIN matches byte-for-byte.
    #[test]
    fn none_fault_profile_costs_bitwise_identical() {
        let k = CostConstants::default();
        let none = FaultProfile::none();
        for s in [Scenario::xs(), Scenario::xl1()] {
            let opts = CompileOptions::default();
            let c = s.compile(&opts);
            let base = cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &k);
            let faulty = cost_program_faults(&c.runtime, &opts.cfg, &opts.cc.0, &k, &none);
            assert_eq!(base.total.to_bits(), faulty.total.to_bits(), "{}", s.name);
            assert_eq!(explain_costed(&base), explain_costed(&faulty), "{}", s.name);
            assert_eq!(
                cost_total(&c.runtime, &opts.cfg, &opts.cc.0, &k).to_bits(),
                cost_total_faults(&c.runtime, &opts.cfg, &opts.cc.0, &k, &none).to_bits(),
                "{}",
                s.name
            );
            let hashes = cache::program_hashes(&c.runtime);
            let cache = cache::CostCache::default();
            let cached =
                cost_total_cached_faults(&c.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &none, &cache);
            assert_eq!(base.total.to_bits(), cached.to_bits(), "{} cached", s.name);
        }
    }

    /// A nonzero profile inflates distributed plans but leaves pure-CP
    /// plans untouched — failures are priced only where tasks can fail.
    #[test]
    fn chaos_profile_inflates_distributed_but_not_cp() {
        let k = CostConstants::default();
        let chaos = FaultProfile::chaos();
        let opts = CompileOptions::default();
        // XS compiles pure-CP: no MR/Spark job instructions to fail
        let xs = Scenario::xs().compile(&opts);
        let xs_base = cost_total(&xs.runtime, &opts.cfg, &opts.cc.0, &k);
        let xs_chaos = cost_total_faults(&xs.runtime, &opts.cfg, &opts.cc.0, &k, &chaos);
        assert_eq!(xs_base.to_bits(), xs_chaos.to_bits(), "CP plans have no fault terms");
        // XL1 carries the Figure-5 MR job: chaos must cost strictly more
        let xl1 = Scenario::xl1().compile(&opts);
        let xl1_base = cost_total(&xl1.runtime, &opts.cfg, &opts.cc.0, &k);
        let xl1_chaos = cost_total_faults(&xl1.runtime, &opts.cfg, &opts.cc.0, &k, &chaos);
        assert!(xl1_chaos > xl1_base, "{xl1_chaos} > {xl1_base}");
        assert!(xl1_chaos.is_finite());
        // cached fault-aware costing replays bitwise, and shares a cache
        // with fault-free entries without aliasing
        let hashes = cache::program_hashes(&xl1.runtime);
        let cache = cache::CostCache::default();
        let cold = cost_total_cached_faults(
            &xl1.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &chaos, &cache,
        );
        let free = cost_total_cached(&xl1.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &cache);
        let warm = cost_total_cached_faults(
            &xl1.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &chaos, &cache,
        );
        assert_eq!(cold.to_bits(), xl1_chaos.to_bits());
        assert_eq!(warm.to_bits(), xl1_chaos.to_bits());
        assert_eq!(free.to_bits(), xl1_base.to_bits(), "fault-free entries must not alias");
    }

    #[test]
    fn cheaper_scenario_costs_less() {
        let xs = cost_scenario(Scenario::xs()).total;
        let xl1 = cost_scenario(Scenario::xl1()).total;
        let xl4 = cost_scenario(Scenario::xl4()).total;
        assert!(xs < xl1 && xl1 < xl4, "{xs} < {xl1} < {xl4}");
    }

    fn while_block_total(report: &CostReport) -> f64 {
        report
            .nodes
            .iter()
            .find_map(|n| match n {
                CostNode::Block { label, total, .. } if label.contains("WHILE") => Some(*total),
                _ => None,
            })
            .expect("program has a WHILE block")
    }

    /// §3 Eq. 1 consistency fix: a While with `N̂ = 0` charges only its
    /// predicate, matching the For branch's `w · first` scaling for
    /// `w < 1` — it must not charge one full first-iteration body (which
    /// here would include the 0.51 s read of X).
    #[test]
    fn while_with_zero_unknown_iterations_costs_only_predicate() {
        use crate::api::compile_with_meta;
        let src = "X = read($1);\ns = 1;\nwhile (s < 10) { s = s + sum(X); }\nwrite(s, $4);";
        let sc = Scenario::xs();
        let opts = CompileOptions::default();
        let c = compile_with_meta(src, &sc.args(), &sc.meta(1000), &opts).unwrap();
        let mut cfg = opts.cfg.clone();
        cfg.unknown_iterations = 0.0;
        let zero = cost_program(&c.runtime, &cfg, &opts.cc.0, &CostConstants::default());
        cfg.unknown_iterations = 10.0;
        let ten = cost_program(&c.runtime, &cfg, &opts.cc.0, &CostConstants::default());
        let (w0, w10) = (while_block_total(&zero), while_block_total(&ten));
        assert!(w0 < 0.01, "zero-iteration While must cost ~predicate only, got {w0}");
        assert!(w10 > 0.5, "10-iteration While pays the X read, got {w10}");
        // fractional N̂ scales the first-iteration body down, like For
        cfg.unknown_iterations = 0.5;
        let half = cost_program(&c.runtime, &cfg, &opts.cc.0, &CostConstants::default());
        let wh = while_block_total(&half);
        assert!(w0 < wh && wh < w10, "{w0} < {wh} < {w10}");
    }

    /// A zero-trip loop must not warm the read tracker either: a
    /// post-loop use of X still pays the cold HDFS read, so the program
    /// total never drops below the persistent-read floor the grid
    /// optimizer prunes with.
    #[test]
    fn zero_trip_while_does_not_warm_later_reads() {
        use crate::api::compile_with_meta;
        let src = "X = read($1);\ns = 1;\nwhile (s < 10) { s = s + sum(X); }\nz = sum(X);\nwrite(z, $4);";
        let sc = Scenario::xs();
        let opts = CompileOptions::default();
        let c = compile_with_meta(src, &sc.args(), &sc.meta(1000), &opts).unwrap();
        let mut cfg = opts.cfg.clone();
        cfg.unknown_iterations = 0.0;
        let r = cost_program(&c.runtime, &cfg, &opts.cc.0, &CostConstants::default());
        assert!(
            r.total > 0.5,
            "post-loop sum(X) must pay the 0.51s cold read, got {}",
            r.total
        );
        let inputs = vec![(
            crate::matrix::MatrixCharacteristics::dense(sc.x_rows, sc.x_cols, 1000),
            Format::BinaryBlock,
        )];
        let floor = read_io_floor(
            &inputs,
            crate::rtprog::ExecBackend::Mr,
            &cfg,
            &opts.cc.0,
            &CostConstants::default(),
        );
        assert!(floor <= r.total, "floor {floor} > cost {}", r.total);
    }

    /// `k_local == 0` must not turn the parfor weight into `inf`
    /// (`ClusterConfig::validate` rejects it upstream, but cost_program
    /// is callable directly).
    #[test]
    fn parfor_with_zero_k_local_stays_finite() {
        use crate::api::compile_with_meta;
        let src =
            "X = read($1);\ns = 0;\nparfor (i in 1:24) { s = s + sum(X); }\nwrite(s, $4);";
        let sc = Scenario::xs();
        let opts = CompileOptions::default();
        let c = compile_with_meta(src, &sc.args(), &sc.meta(1000), &opts).unwrap();
        let mut cc = opts.cc.0.clone();
        cc.k_local = 0;
        let r = cost_program(&c.runtime, &opts.cfg, &cc, &CostConstants::default());
        assert!(r.total.is_finite(), "k_local=0 must degrade to serial, got {}", r.total);
    }

    /// The pruning bound is a true lower bound on the paper scenarios,
    /// and CP's single-threaded floor dominates the distributed floors.
    #[test]
    fn read_io_floor_bounds_scenario_costs() {
        use crate::rtprog::ExecBackend;
        let cfg = SystemConfig::default();
        let cc = ClusterConfig::paper_cluster();
        let k = CostConstants::default();
        for s in Scenario::all() {
            let inputs = vec![
                (
                    crate::matrix::MatrixCharacteristics::dense(s.x_rows, s.x_cols, 1000),
                    Format::BinaryBlock,
                ),
                (
                    crate::matrix::MatrixCharacteristics::dense(s.x_rows, 1, 1000),
                    Format::BinaryBlock,
                ),
            ];
            for backend in ExecBackend::all() {
                let opts = CompileOptions { backend, ..Default::default() };
                let c = s.compile(&opts);
                let total = cost_program(&c.runtime, &cfg, &cc, &k).total;
                let floor = read_io_floor(&inputs, backend, &cfg, &cc, &k);
                assert!(
                    floor <= total,
                    "{} {}: floor {floor} > cost {total}",
                    s.name,
                    backend.name()
                );
                assert!(floor > 0.0);
            }
            let cp = read_io_floor(&inputs, ExecBackend::Cp, &cfg, &cc, &k);
            let mr = read_io_floor(&inputs, ExecBackend::Mr, &cfg, &cc, &k);
            assert!(mr < cp, "distributed reads beat the single-threaded floor");
        }
    }
}
