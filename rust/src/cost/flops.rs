//! White-box FLOP models per instruction (paper §3.3, Eq. 2 family).
//!
//! Floating-point requirements are counted as multiply-accumulate
//! operations with operation-specific correction factors, calibrated so
//! that the paper's Figure 4/5 compute times reproduce at a 2.15 GHz
//! effective clock (DESIGN.md §Constants-calibration):
//!
//! * `tsmm`:  `MMD_corr · m · n² · s` with `MMD_corr = 0.5` (symmetry —
//!   "only half the computation"), sparse `MMS_corr · m · n² · s²`.
//! * `ba+*`:  `m · k · n · s` MACs.
//! * `solve`: `n³` (LU + triangular solves).
//! * elementwise/unary: `cells · c_op` with small per-op constants.

use crate::ir::{AggOp, BinOp, UnOp};
use crate::matrix::MatrixCharacteristics;

/// tsmm correction, dense (Eq. 2).
pub const MMD_CORR: f64 = 0.5;
/// tsmm correction, sparse (Eq. 2).
pub const MMS_CORR: f64 = 0.5;
/// rand generation cost per cell (cycles).
pub const RAND_CORR: f64 = 8.0;
/// partition cost per cell (copy + block regrouping).
pub const PART_CORR: f64 = 137.0;
/// text serialisation cost per cell (number formatting).
pub const TEXT_CORR: f64 = 430.0;
/// Kahan-compensated addition (ak+) cost per cell [4].
pub const KAHAN_CORR: f64 = 4.0;

fn cells(mc: &MatrixCharacteristics) -> f64 {
    mc.cells().unwrap_or(0.0)
}

/// FLOPs of a transpose-self matmult over X (m x n, sparsity s).
pub fn tsmm(x: &MatrixCharacteristics) -> f64 {
    if !x.dims_known() {
        return 0.0;
    }
    let (m, n, s) = (x.rows as f64, x.cols as f64, x.sparsity());
    if s < 0.4 {
        MMS_CORR * m * n * n * s * s
    } else {
        MMD_CORR * m * n * n * s
    }
}

/// FLOPs of a general matmult A(m x k) * B(k x n): MAC count.
pub fn matmult(a: &MatrixCharacteristics, b: &MatrixCharacteristics) -> f64 {
    if !a.dims_known() || !b.dims_known() {
        return 0.0;
    }
    a.rows as f64 * a.cols as f64 * b.cols as f64 * a.sparsity()
}

/// FLOPs of `solve(A, b)` (LU with partial pivoting + substitutions).
pub fn solve(a: &MatrixCharacteristics, b: &MatrixCharacteristics) -> f64 {
    if !a.dims_known() {
        return 0.0;
    }
    let n = a.cols as f64;
    let r = if b.dims_known() { b.cols as f64 } else { 1.0 };
    n * n * n + n * n * r
}

/// FLOPs of a transpose (per-cell move).
pub fn transpose(x: &MatrixCharacteristics) -> f64 {
    cells(x)
}

/// FLOPs of diag (touches the diagonal / vector only).
pub fn diag(x: &MatrixCharacteristics) -> f64 {
    if x.rows < 0 {
        0.0
    } else {
        x.rows as f64
    }
}

/// FLOPs of rand/matrix datagen.
pub fn rand(out: &MatrixCharacteristics) -> f64 {
    cells(out) * RAND_CORR
}

/// FLOPs of a partition op (row-block-wise regrouping).
pub fn partition(x: &MatrixCharacteristics) -> f64 {
    cells(x) * PART_CORR
}

/// FLOPs of an elementwise binary op over the output shape.
pub fn binary(op: BinOp, out: &MatrixCharacteristics) -> f64 {
    let c = cells(out);
    match op {
        BinOp::Pow => c * 20.0, // pow is much heavier than +/*
        BinOp::Div => c * 4.0,
        _ => c,
    }
}

/// FLOPs of an elementwise unary op.
pub fn unary(op: UnOp, out: &MatrixCharacteristics) -> f64 {
    let c = cells(out);
    match op {
        UnOp::Exp | UnOp::Log => c * 20.0,
        UnOp::Sqrt => c * 8.0,
        _ => c,
    }
}

/// FLOPs of a unary aggregate over the input.
pub fn agg_unary(op: AggOp, input: &MatrixCharacteristics) -> f64 {
    let c = cells(input);
    match op {
        AggOp::Sum | AggOp::Mean => c * KAHAN_CORR, // uak+ uses Kahan
        AggOp::Trace => input.rows.max(0) as f64 * KAHAN_CORR,
        _ => c,
    }
}

/// FLOPs of the final `ak+` aggregation over `n_partials` partial results
/// of the given shape.
pub fn agg_kahan(n_partials: f64, partial: &MatrixCharacteristics) -> f64 {
    n_partials * cells(partial) * KAHAN_CORR
}

/// FLOPs of append (copy cost).
pub fn append(out: &MatrixCharacteristics) -> f64 {
    cells(out)
}

/// FLOPs of serialising to text (write textcell/csv).
pub fn text_write(x: &MatrixCharacteristics) -> f64 {
    cells(x) * TEXT_CORR
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLOCK: f64 = 2.15e9;

    #[test]
    fn tsmm_flops_match_figure4() {
        // XS: X 1e4 x 1e3 dense -> 0.5 * 1e4 * 1e6 = 5e9 MACs = 2.33 s.
        let x = MatrixCharacteristics::dense(10_000, 1_000, 1000);
        let f = tsmm(&x);
        assert_eq!(f, 5e9);
        let t = f / CLOCK;
        assert!((t - 2.32).abs() < 0.01, "t={t}");
    }

    #[test]
    fn tsmm_sparse_uses_squared_sparsity() {
        let mut x = MatrixCharacteristics::dense(10_000, 1_000, 1000);
        x.nnz = 1_000_000; // s = 0.1
        let f = tsmm(&x);
        assert_eq!(f, 0.5 * 1e4 * 1e6 * 0.01);
    }

    #[test]
    fn solve_flops_match_figure4() {
        // 1000x1000 solve -> ~1e9+1e6 MACs = 0.466 s.
        let a = MatrixCharacteristics::dense(1000, 1000, 1000);
        let b = MatrixCharacteristics::dense(1000, 1, 1000);
        let t = solve(&a, &b) / CLOCK;
        assert!((t - 0.466).abs() < 0.01, "t={t}");
    }

    #[test]
    fn matvec_flops_match_figure4() {
        // y'X: 1 x 1e4 times 1e4 x 1e3 -> 1e7 MACs = 0.00465 s.
        let a = MatrixCharacteristics::dense(1, 10_000, 1000);
        let b = MatrixCharacteristics::dense(10_000, 1_000, 1000);
        let t = matmult(&a, &b) / CLOCK;
        assert!((t - 0.00465).abs() < 1e-4, "t={t}");
    }

    #[test]
    fn elementwise_add_matches_figure4() {
        // 1000x1000 add -> 1e6 ops = 4.65e-4 s.
        let o = MatrixCharacteristics::dense(1000, 1000, 1000);
        let t = binary(BinOp::Add, &o) / CLOCK;
        assert!((t - 4.65e-4).abs() < 1e-5);
    }

    #[test]
    fn rand_matches_figure4() {
        // 1000x1 rand -> 8e3 cycles = 3.7e-6 s.
        let o = MatrixCharacteristics::dense(1000, 1, 1000);
        let t = rand(&o) / CLOCK;
        assert!((t - 3.7e-6).abs() < 2e-7, "t={t}");
    }

    #[test]
    fn unknown_dims_cost_zero() {
        // §3.5: unknowns cannot be costed -> 0 (documented underestimation)
        let u = MatrixCharacteristics::unknown();
        assert_eq!(tsmm(&u), 0.0);
        assert_eq!(matmult(&u, &u), 0.0);
        assert_eq!(binary(BinOp::Add, &u), 0.0);
    }
}
