//! MR-job instruction costing (paper §3.3): job/task latency, in-memory
//! variable export, map read/compute/write, distributed-cache read,
//! shuffle, reduce compute, and final HDFS write — each normalised by the
//! *effective degree of parallelism* (a scaled minimum of available slots
//! and the number of tasks).

use super::vars::{DataState, VarTracker};
use super::flops;
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::matrix::{Format, MatrixCharacteristics};
use crate::rtprog::*;

/// Full cost breakdown of one MR job (the annotations of Figure 5).
/// All time components are in seconds, already normalised by the
/// effective degree of parallelism of their phase.
#[derive(Clone, Debug, Default)]
pub struct MrJobCost {
    /// Number of map tasks: `Σ ⌈M'(input)/hdfs_block⌉` (Figure 5 `nmap`).
    pub n_map: usize,
    /// Number of reduce tasks, bounded by distinct output groups.
    pub n_red: usize,
    /// job + task latency, normalised by effective parallelism
    pub latency: f64,
    /// export of in-memory inputs to HDFS
    pub export: f64,
    /// HDFS read of map inputs (dcache inputs excluded).
    pub hdfs_read: f64,
    /// Distributed-cache read of broadcast inputs, per task.
    pub dcache_read: f64,
    /// Map-phase compute (FLOPs / clock / effective map parallelism).
    pub map_exec: f64,
    /// Shuffle: map write + transfer + reduce merge (3 passes, §3.4).
    pub shuffle: f64,
    /// Reduce-phase compute (aggregations, cpmm partial products).
    pub red_exec: f64,
    /// HDFS write of job outputs (× replication factor).
    pub hdfs_write: f64,
}

impl MrJobCost {
    /// Total job seconds: the sum of every component above.
    pub fn total(&self) -> f64 {
        self.latency
            + self.export
            + self.hdfs_read
            + self.dcache_read
            + self.map_exec
            + self.shuffle
            + self.red_exec
            + self.hdfs_write
    }

    /// Figure-5-style annotation.
    pub fn annotate(&self) -> String {
        use crate::util::fmt::fmt_secs;
        format!(
            "# C=[{}] nmap={} nred={} latency=[{}] hdfsread=[{}] mapexec=[{}] dcread=[{}] shuffle=[{}] redexec=[{}] hdfswrite=[{}]",
            fmt_secs(self.total()),
            self.n_map,
            self.n_red,
            fmt_secs(self.latency),
            fmt_secs(self.hdfs_read),
            fmt_secs(self.map_exec),
            fmt_secs(self.dcache_read),
            fmt_secs(self.shuffle),
            fmt_secs(self.red_exec),
            fmt_secs(self.hdfs_write),
        )
    }
}

/// Cost one MR job and update variable states (outputs land on HDFS).
pub fn cost_mr_job(
    j: &MrJob,
    t: &mut VarTracker,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
) -> MrJobCost {
    let mut c = MrJobCost::default();

    // ---- export in-memory inputs to HDFS (hybrid-plan data exchange)
    for v in &j.inputs {
        if let Some(info) = t.get(v) {
            if info.state == DataState::Mem {
                let size = info.mc.serialized_size(Format::BinaryBlock);
                if size.is_finite() {
                    c.export += size / k.hdfs_write_binaryblock;
                }
                t.set_hdfs(v);
            }
        }
    }

    // ---- task counts
    let input_mc: Vec<MatrixCharacteristics> = j.inputs.iter().map(|v| t.mc(v)).collect();
    let mut n_map = 0usize;
    for (v, mc) in j.inputs.iter().zip(&input_mc) {
        let size = mc.serialized_size(Format::BinaryBlock);
        if size.is_finite() {
            let _ = v;
            n_map += (size / cc.hdfs_block_bytes).ceil() as usize;
        }
    }
    c.n_map = n_map.max(1);
    // reducers: bounded by the number of distinct output groups (blocks)
    let has_reduce =
        !j.shuffle_insts.is_empty() || !j.agg_insts.is_empty() || !j.other_insts.is_empty();
    c.n_red = if has_reduce {
        let max_groups = j
            .agg_insts
            .iter()
            .chain(&j.shuffle_insts)
            .chain(&j.other_insts)
            .map(|i| output_groups(i, cfg))
            .max()
            .unwrap_or(1);
        j.num_reducers.min(max_groups).max(1)
    } else {
        0
    };

    // ---- effective parallelism: "scaled minimum of k_m and #tasks" (§3.3)
    let k_map_eff =
        ((cc.effective_k_map().min(c.n_map) as f64) * k.dop_scale).max(1.0);
    let k_red_eff = if c.n_red > 0 {
        ((cc.effective_k_reduce().min(c.n_red) as f64) * k.dop_scale).max(1.0)
    } else {
        1.0
    };

    // ---- latency
    c.latency = k.job_latency
        + k.task_latency * (c.n_map as f64 / k_map_eff)
        + k.task_latency * (c.n_red as f64 / k_red_eff);

    // ---- HDFS read of map inputs (dcache inputs read separately)
    for (v, mc) in j.inputs.iter().zip(&input_mc) {
        if j.dcache.contains(v) {
            continue;
        }
        let size = mc.serialized_size(Format::BinaryBlock);
        if size.is_finite() {
            c.hdfs_read += size / k.hdfs_read_binaryblock / k_map_eff;
        }
    }

    // ---- distributed-cache read: partitions are read on demand per task
    for v in &j.dcache {
        let mc = t.mc(v);
        let size = mc.serialized_size(Format::BinaryBlock);
        if size.is_finite() {
            let per_task = size.min(cfg.partition_bytes);
            c.dcache_read += c.n_map as f64 * per_task / k.dcache_read / k_map_eff;
        }
    }

    // ---- map compute
    let inst_mc = resolve_mcs(&input_mc, j.all_insts());
    for inst in j.map_insts.iter().chain(&j.shuffle_insts) {
        c.map_exec += inst_flops(inst, &inst_mc) / (cc.clock_hz * k.flop_efficiency) / k_map_eff;
    }

    // ---- shuffle: map write + transfer + reduce merge (3 passes, §3.4)
    let mut shuffle_bytes = 0.0;
    for agg in &j.agg_insts {
        // each map task emits one combined partial of the aggregate shape
        let partial = inst_mc.get(&agg.output).or_else(|| inst_mc.get(&agg.inputs[0]));
        if let Some(mc) = partial {
            let size = mc.serialized_size(Format::BinaryBlock);
            if size.is_finite() {
                // aggregations of job inputs (cpmm follow-up): the full
                // input is shuffled, not per-task partials
                if agg.inputs[0] < j.inputs.len() {
                    shuffle_bytes += input_mc[agg.inputs[0]]
                        .serialized_size(Format::BinaryBlock)
                        .min(f64::MAX);
                } else {
                    shuffle_bytes += c.n_map as f64 * size;
                }
            }
        }
    }
    for sh in &j.shuffle_insts {
        // cpmm/rmm shuffle both inputs entirely
        for &i in &sh.inputs {
            if let Some(mc) = inst_mc.get(&i) {
                let size = mc.serialized_size(Format::BinaryBlock);
                if size.is_finite() {
                    shuffle_bytes += size;
                }
            }
        }
    }
    for ot in &j.other_insts {
        for &i in &ot.inputs {
            if let Some(mc) = inst_mc.get(&i) {
                let size = mc.serialized_size(Format::BinaryBlock);
                if size.is_finite() {
                    shuffle_bytes += size;
                }
            }
        }
    }
    let shuffle_par = if c.n_red > 0 { k_map_eff } else { 1.0 };
    c.shuffle = 3.0 * shuffle_bytes / k.shuffle_bw / shuffle_par;

    // ---- reduce compute
    for agg in &j.agg_insts {
        let partial = inst_mc.get(&agg.output).copied().unwrap_or_else(MatrixCharacteristics::unknown);
        let n_partials = if agg.inputs[0] < j.inputs.len() {
            // aggregating a prior job's full output: partials = blocks rows
            let in_mc = input_mc[agg.inputs[0]];
            let total = in_mc.serialized_size(Format::BinaryBlock);
            let each = partial.serialized_size(Format::BinaryBlock).max(1.0);
            if total.is_finite() {
                (total / each).max(1.0)
            } else {
                1.0
            }
        } else {
            c.n_map as f64
        };
        c.red_exec += flops::agg_kahan(n_partials, &partial) / (cc.clock_hz * k.flop_efficiency) / k_red_eff;
    }
    for sh in &j.shuffle_insts {
        // cpmm multiply happens reduce-side
        let a = inst_mc.get(&sh.inputs[0]).copied().unwrap_or_else(MatrixCharacteristics::unknown);
        let b = inst_mc
            .get(sh.inputs.get(1).unwrap_or(&usize::MAX))
            .copied()
            .unwrap_or_else(MatrixCharacteristics::unknown);
        c.red_exec += flops::matmult(&a, &b) / (cc.clock_hz * k.flop_efficiency) / k_red_eff;
    }
    for ot in &j.other_insts {
        let a = inst_mc.get(&ot.output).copied().unwrap_or_else(MatrixCharacteristics::unknown);
        c.red_exec += a.cells().unwrap_or(0.0) / (cc.clock_hz * k.flop_efficiency) / k_red_eff;
    }

    // ---- HDFS write of outputs
    for (v, &ri) in j.outputs.iter().zip(&j.result_indices) {
        let mc = inst_mc.get(&ri).copied().unwrap_or_else(|| t.mc(v));
        let size = mc.serialized_size(Format::BinaryBlock);
        if size.is_finite() {
            c.hdfs_write +=
                size * j.replication as f64 / k.hdfs_write_binaryblock / k_red_eff.max(1.0);
        }
        // output state: on HDFS with the instruction's characteristics
        t.set_mc(v, mc);
        t.set_hdfs(v);
    }

    c
}

/// [`cost_mr_job`] expanded to its expectation under a failure model
/// (the retry-aware extension of Eq. 1):
///
/// * **Geometric retries** — every per-task work term (HDFS read, dcache
///   read, map/reduce compute, shuffle, output write) is multiplied by
///   `E[attempts] = (1 - p^m)/(1 - p)`, the truncated form of the
///   geometric `1/(1-p)`: a failed attempt redoes the task's work from
///   scratch.
/// * **Backoff latency** — retries wait `backoff_base · 2^(a-1)` before
///   re-running; the expected wait is added to the latency term once per
///   task *wave* (`⌈n_tasks / k_eff⌉` waves per phase), since tasks
///   within a wave back off concurrently.
/// * **Straggler tail** — a phase does not finish until its slowest
///   last-wave task does, so the last wave's share of each compute term
///   (`term / waves`) is inflated by the straggler tail multiplier.
///   Speculative execution caps the observable slowdown (see
///   [`FaultProfile::straggler_tail`]) but pays the duplicate work of
///   the backup copies.
///
/// With [`FaultProfile::none`] the fault arithmetic is skipped entirely,
/// so the breakdown is bitwise-identical to [`cost_mr_job`].
pub fn cost_mr_job_faults(
    j: &MrJob,
    t: &mut VarTracker,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fp: &FaultProfile,
) -> MrJobCost {
    let mut c = cost_mr_job(j, t, cfg, cc, k);
    if fp.is_none() {
        return c;
    }
    let p = fp.mr_fail_p;
    let retry = fp.expected_attempts(p);
    let tail = fp.straggler_tail();
    // mirror cost_mr_job's effective-parallelism math to count waves
    let k_map_eff = ((cc.effective_k_map().min(c.n_map) as f64) * k.dop_scale).max(1.0);
    let k_red_eff = if c.n_red > 0 {
        ((cc.effective_k_reduce().min(c.n_red) as f64) * k.dop_scale).max(1.0)
    } else {
        1.0
    };
    let map_waves = (c.n_map as f64 / k_map_eff).ceil().max(1.0);
    let red_waves = if c.n_red > 0 { (c.n_red as f64 / k_red_eff).ceil().max(1.0) } else { 0.0 };
    // geometric retries redo per-task work
    c.hdfs_read *= retry;
    c.dcache_read *= retry;
    c.map_exec *= retry;
    c.shuffle *= retry;
    c.red_exec *= retry;
    c.hdfs_write *= retry;
    // speculative backup copies duplicate the straggling fraction's work
    if fp.speculative && fp.straggler_frac > 0.0 {
        let dup = 1.0 + fp.straggler_frac;
        c.map_exec *= dup;
        c.red_exec *= dup;
    }
    // straggler tail: the last wave finishes at the straggler's pace
    c.map_exec += c.map_exec / map_waves * (tail - 1.0);
    if red_waves > 0.0 {
        c.red_exec += c.red_exec / red_waves * (tail - 1.0);
    }
    // expected backoff wait, paid once per wave per phase
    c.latency += fp.expected_backoff(p) * (map_waves + red_waves);
    c
}

/// Resolve per-byte-index characteristics: job inputs then instruction
/// outputs. Shared with the Spark cost model ([`crate::cost::spark`]),
/// which uses the same byte-index dataflow encoding.
pub(crate) fn resolve_mcs<'a>(
    input_mc: &[MatrixCharacteristics],
    insts: impl Iterator<Item = &'a MrInst>,
) -> std::collections::HashMap<usize, MatrixCharacteristics> {
    let mut m = std::collections::HashMap::new();
    for (i, mc) in input_mc.iter().enumerate() {
        m.insert(i, *mc);
    }
    for inst in insts {
        m.insert(inst.output, inst.mc);
    }
    m
}

/// Number of distinct output groups (blocks) of a reduce-side instruction,
/// which bounds useful reducer parallelism.
pub(crate) fn output_groups(inst: &MrInst, _cfg: &SystemConfig) -> usize {
    let rb = inst.mc.row_blocks();
    let cb = inst.mc.col_blocks();
    if rb < 0 || cb < 0 {
        return usize::MAX; // unknown: don't constrain
    }
    (rb as usize).saturating_mul(cb as usize).max(1)
}

/// FLOPs of one MR instruction given resolved input characteristics.
/// Shared with the Spark cost model (Spark stages reuse [`MrInst`]).
pub(crate) fn inst_flops(
    inst: &MrInst,
    mcs: &std::collections::HashMap<usize, MatrixCharacteristics>,
) -> f64 {
    let unknown = MatrixCharacteristics::unknown;
    let in0 = inst.inputs.first().and_then(|i| mcs.get(i)).copied().unwrap_or_else(unknown);
    let in1 = inst.inputs.get(1).and_then(|i| mcs.get(i)).copied().unwrap_or_else(unknown);
    match &inst.op {
        MrOp::Tsmm { .. } => flops::tsmm(&in0),
        MrOp::MapMM { .. } => flops::matmult(&in0, &in1),
        MrOp::Cpmm | MrOp::Rmm => {
            // partial products computed in reduce; map side only tags
            0.0
        }
        MrOp::Transpose => flops::transpose(&in0),
        MrOp::Diag => flops::diag(&in0),
        MrOp::DataGen { rows, cols, .. } => {
            flops::rand(&MatrixCharacteristics::new(*rows, *cols, 1000, -1))
        }
        MrOp::Binary(op) | MrOp::ScalarBin { op, .. } => flops::binary(*op, &inst.mc),
        MrOp::Unary(op) => flops::unary(*op, &in0),
        MrOp::AggUnaryMap(op, _) => flops::agg_unary(*op, &in0),
        MrOp::Agg { .. } => 0.0, // costed in red_exec
        MrOp::Append { .. } => flops::append(&inst.mc),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_env() -> (SystemConfig, ClusterConfig, CostConstants) {
        (SystemConfig::default(), ClusterConfig::paper_cluster(), CostConstants::default())
    }

    fn xl1_job() -> (MrJob, VarTracker) {
        // Hand-built Figure-3 job: inputs [X, _mVar3(y, partitioned)].
        let x_mc = MatrixCharacteristics::dense(100_000_000, 1_000, 1000);
        let y_mc = MatrixCharacteristics::dense(100_000_000, 1, 1000);
        let a_mc = MatrixCharacteristics::new(1000, 1000, 1000, -1);
        let tx_mc = MatrixCharacteristics::dense(1_000, 100_000_000, 1000);
        let b_mc = MatrixCharacteristics::new(1000, 1, 1000, -1);
        let mut t = VarTracker::default();
        t.create("X", x_mc, Format::BinaryBlock, true);
        t.create("_mVar3", y_mc, Format::BinaryBlock, true);
        t.create("_mVar5", a_mc, Format::BinaryBlock, false);
        t.create("_mVar6", b_mc, Format::BinaryBlock, false);
        let job = MrJob {
            job_type: JobType::Gmr,
            inputs: vec!["X".into(), "_mVar3".into()],
            dcache: vec!["_mVar3".into()],
            map_insts: vec![
                MrInst { op: MrOp::Tsmm { left: true }, inputs: vec![0], output: 2, mc: a_mc },
                MrInst { op: MrOp::Transpose, inputs: vec![0], output: 3, mc: tx_mc },
                MrInst {
                    op: MrOp::MapMM { right_part: true },
                    inputs: vec![3, 1],
                    output: 4,
                    mc: b_mc,
                },
            ],
            shuffle_insts: vec![],
            agg_insts: vec![
                MrInst { op: MrOp::Agg { kahan: true }, inputs: vec![2], output: 5, mc: a_mc },
                MrInst { op: MrOp::Agg { kahan: true }, inputs: vec![4], output: 6, mc: b_mc },
            ],
            other_insts: vec![],
            outputs: vec!["_mVar5".into(), "_mVar6".into()],
            result_indices: vec![5, 6],
            num_reducers: 12,
            replication: 1,
        };
        (job, t)
    }

    #[test]
    fn xl1_job_breakdown_matches_figure5() {
        let (job, mut t) = xl1_job();
        let (cfg, cc, k) = paper_env();
        let c = cost_mr_job(&job, &mut t, &cfg, &cc, &k);
        assert_eq!(c.n_map, 5967, "Figure 5: nmap=5967");
        assert_eq!(c.n_red, 1, "Figure 5: nred=1");
        assert!((c.latency - 144.5).abs() < 8.0, "latency {}", c.latency);
        assert!((c.hdfs_read - 70.7).abs() < 3.0, "hdfsread {}", c.hdfs_read);
        assert!((c.map_exec - 324.7).abs() < 15.0, "mapexec {}", c.map_exec);
        assert!((c.dcache_read - 12.6).abs() < 2.0, "dcread {}", c.dcache_read);
        assert!((c.shuffle - 19.7).abs() < 4.0, "shuffle {}", c.shuffle);
        assert!((c.red_exec - 11.1).abs() < 2.0, "redexec {}", c.red_exec);
        assert!(c.hdfs_write < 0.5, "hdfswrite {}", c.hdfs_write);
        assert!((c.total() - 589.8).abs() < 25.0, "total {}", c.total());
    }

    #[test]
    fn outputs_marked_hdfs_after_job() {
        let (job, mut t) = xl1_job();
        let (cfg, cc, k) = paper_env();
        cost_mr_job(&job, &mut t, &cfg, &cc, &k);
        assert_eq!(t.get("_mVar5").unwrap().state, DataState::Hdfs);
        assert_eq!(t.get("_mVar6").unwrap().state, DataState::Hdfs);
    }

    #[test]
    fn in_memory_inputs_pay_export() {
        let (job, mut t) = xl1_job();
        let (cfg, cc, k) = paper_env();
        // pretend X is in memory (hybrid plan data exchange)
        t.touch_mem("X");
        let c = cost_mr_job(&job, &mut t, &cfg, &cc, &k);
        assert!(c.export > 1000.0, "800GB export is expensive: {}", c.export);
    }

    #[test]
    fn latency_dominates_tiny_jobs() {
        let mc = MatrixCharacteristics::dense(100, 100, 100);
        let mut t = VarTracker::default();
        t.create("X", mc, Format::BinaryBlock, true);
        t.create("out", mc, Format::BinaryBlock, false);
        let job = MrJob {
            job_type: JobType::Gmr,
            inputs: vec!["X".into()],
            dcache: vec![],
            map_insts: vec![MrInst { op: MrOp::Transpose, inputs: vec![0], output: 1, mc }],
            shuffle_insts: vec![],
            agg_insts: vec![],
            other_insts: vec![],
            outputs: vec!["out".into()],
            result_indices: vec![1],
            num_reducers: 12,
            replication: 1,
        };
        let (cfg, cc, k) = paper_env();
        let c = cost_mr_job(&job, &mut t, &cfg, &cc, &k);
        assert!(c.latency >= 20.0, "job latency floor");
        assert!(c.latency / c.total() > 0.95);
    }

    #[test]
    fn none_fault_profile_is_bitwise_identity() {
        let (job, mut t1) = xl1_job();
        let (_, mut t2) = xl1_job();
        let (cfg, cc, k) = paper_env();
        let base = cost_mr_job(&job, &mut t1, &cfg, &cc, &k);
        let none = cost_mr_job_faults(&job, &mut t2, &cfg, &cc, &k, &FaultProfile::none());
        assert_eq!(base.total().to_bits(), none.total().to_bits());
        assert_eq!(base.latency.to_bits(), none.latency.to_bits());
        assert_eq!(base.map_exec.to_bits(), none.map_exec.to_bits());
    }

    #[test]
    fn chaos_profile_inflates_every_retried_term() {
        let (job, mut t1) = xl1_job();
        let (_, mut t2) = xl1_job();
        let (cfg, cc, k) = paper_env();
        let base = cost_mr_job(&job, &mut t1, &cfg, &cc, &k);
        let chaos = cost_mr_job_faults(&job, &mut t2, &cfg, &cc, &k, &FaultProfile::chaos());
        assert!(chaos.total() > base.total());
        assert!(chaos.hdfs_read > base.hdfs_read, "retries re-read inputs");
        assert!(chaos.map_exec > base.map_exec, "retries + tail redo compute");
        assert!(chaos.latency > base.latency, "backoff adds latency");
        // expectation stays finite and sane
        assert!(chaos.total().is_finite());
        let fp = FaultProfile::chaos();
        let bound = fp.expected_attempts(fp.mr_fail_p) * fp.straggler_tail()
            * (1.0 + fp.straggler_frac);
        assert!(chaos.map_exec <= base.map_exec * bound * (1.0 + 1e-12));
    }

    #[test]
    fn speculation_caps_the_tail_but_pays_duplicate_work() {
        let (job, mut t1) = xl1_job();
        let (_, mut t2) = xl1_job();
        let (cfg, cc, k) = paper_env();
        let eager = FaultProfile { speculative: true, ..FaultProfile::chaos() };
        let lazy = FaultProfile::chaos();
        let with_spec = cost_mr_job_faults(&job, &mut t1, &cfg, &cc, &k, &eager);
        let without = cost_mr_job_faults(&job, &mut t2, &cfg, &cc, &k, &lazy);
        // both price the same retries; they differ only in tail-vs-duplicate
        assert!(with_spec.total().is_finite() && without.total().is_finite());
        assert_ne!(with_spec.map_exec.to_bits(), without.map_exec.to_bits());
    }
}
