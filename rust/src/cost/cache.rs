//! Block-level **cost caching** — the incremental half of the costing
//! engine.
//!
//! Every optimizer in this codebase (the scenario sweep, the grid
//! resource optimizer and the global data flow optimizer) costs large
//! families of closely related runtime plans: candidates typically
//! differ in a single knob or a single program cut, yet
//! [`super::cost_program`] walks every block of every candidate from
//! scratch. This module caches the cost of one [`RtBlock`] subtree under
//! a key that captures *everything* the §3 costing pass can observe:
//!
//! 1. **Structural block hash** — a 128-bit hash over the entire block
//!    subtree (instructions, operands, matrix characteristics, line
//!    numbers, nested blocks), precomputed once per compiled plan by
//!    [`program_hashes`].
//! 2. **Variable-state fingerprint** — a canonical hash of the incoming
//!    [`VarTracker`]: every live name (sorted), its alias group, and the
//!    shared entry's dimensions / format / HDFS-vs-memory residence
//!    (see [`VarTracker::hash_state`]). The §3.2 first-read accounting
//!    makes block cost state-dependent, so the fingerprint is part of
//!    the key rather than an invalidation afterthought.
//! 3. **Relevant configuration knobs** — only the [`SystemConfig`] /
//!    [`ClusterConfig`] / [`CostConstants`] fields the block can
//!    actually read, selected by per-block feature flags: `k_local`
//!    enters the key only for parfor blocks, `unknown_iterations` only
//!    for loops without a static trip count, the MR slot geometry and
//!    latencies only for blocks containing MR jobs, and the Spark
//!    executor geometry and latencies only for blocks containing Spark
//!    jobs. Grid points that vary a knob no block reads (e.g. `k_local`
//!    on a plan without parfor) therefore hit the cache outright.
//!
//! A hit replays both outputs of costing a block: the [`CostNode`]
//! annotation *and* the updated variable-state tracker. Because the key
//! covers the full observable input, cached and uncached costing are
//! bitwise identical (`tests/costcache.rs` property-checks this on every
//! bundled script × backend × thread count).
//!
//! Function-call blocks are never cached: their cost depends on the
//! callee body, which lives outside the block's structural hash. The
//! `NOCACHE` flag propagates to every ancestor containing an `FCall`.
//!
//! The cache is sharded (8 × `Mutex<HashMap>`) so concurrent costing
//! workers ([`crate::util::par`]) contend rarely, and bounded by a FIFO
//! per-shard eviction policy (insertion order approximates cost-walk
//! order, so the oldest entries are the least likely to recur within an
//! optimizer run).

use std::collections::hash_map::DefaultHasher;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::fmt::Write as _;
use std::hash::Hasher;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::vars::VarTracker;
use super::CostNode;
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::rtprog::{Instr, RtBlock, RtProgram};

// ---------------------------------------------------------------------
// Feature flags: which knob groups a block subtree can read
// ---------------------------------------------------------------------

/// Block contains a parfor loop (reads `cc.k_local`).
pub(crate) const F_PARFOR: u8 = 1 << 0;
/// Block contains a loop without a static trip count (reads
/// `cfg.unknown_iterations`).
pub(crate) const F_UNKNOWN_ITERS: u8 = 1 << 1;
/// Block contains an MR-job instruction (reads the MR knob group).
pub(crate) const F_MR: u8 = 1 << 2;
/// Block contains a Spark-job instruction (reads the Spark knob group).
pub(crate) const F_SPARK: u8 = 1 << 3;
/// Block contains a function call somewhere in its subtree: its cost
/// depends on state outside the structural hash, so it is never cached.
pub(crate) const F_NOCACHE: u8 = 1 << 4;

fn insts_feats(insts: &[Instr]) -> u8 {
    let mut f = 0;
    for i in insts {
        match i {
            Instr::MrJob(_) => f |= F_MR,
            Instr::SparkJob(_) => f |= F_SPARK,
            _ => {}
        }
    }
    f
}

// ---------------------------------------------------------------------
// Structural hashing
// ---------------------------------------------------------------------

/// FNV-1a 64-bit — the second, independent hash function backing the
/// 128-bit keys (the first is the std `DefaultHasher`).
struct Fnv(u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Fnv {
    fn new() -> Self {
        Fnv(FNV_OFFSET)
    }
}

impl Hasher for Fnv {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }
}

/// `fmt::Write` adapter feeding the formatted bytes into two hashers at
/// once; hashing the `Debug` rendering covers every field of the runtime
/// instruction structures (including `f64` payloads) without a hand
/// written per-variant walk that could silently miss one.
struct TwoHashers<'a>(&'a mut DefaultHasher, &'a mut Fnv);

impl std::fmt::Write for TwoHashers<'_> {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        self.0.write(s.as_bytes());
        self.1.write(s.as_bytes());
        Ok(())
    }
}

fn hash_debug<T: std::fmt::Debug>(v: &T) -> (u64, u64) {
    let mut h1 = DefaultHasher::new();
    let mut h2 = Fnv::new();
    let _ = write!(TwoHashers(&mut h1, &mut h2), "{v:?}");
    (h1.finish(), h2.finish())
}

/// Structural hash of one runtime block subtree plus the feature flags
/// selecting its relevant configuration knobs. Children mirror the order
/// the estimator walks nested blocks (then-blocks followed by
/// else-blocks for `If`; the body for loops).
#[derive(Clone, Debug)]
pub struct BlockHash {
    pub(crate) h1: u64,
    pub(crate) h2: u64,
    pub(crate) feats: u8,
    pub(crate) children: Vec<BlockHash>,
}

impl BlockHash {
    pub(crate) fn cacheable(&self) -> bool {
        self.feats & F_NOCACHE == 0
    }
}

fn hash_block(b: &RtBlock) -> BlockHash {
    let children: Vec<BlockHash> = match b {
        RtBlock::Generic { .. } | RtBlock::FCall { .. } => Vec::new(),
        RtBlock::If { then_blocks, else_blocks, .. } => {
            then_blocks.iter().chain(else_blocks).map(hash_block).collect()
        }
        RtBlock::For { body, .. } | RtBlock::While { body, .. } => {
            body.iter().map(hash_block).collect()
        }
    };
    let mut feats = match b {
        RtBlock::Generic { insts, .. } => insts_feats(insts),
        RtBlock::If { pred, .. } => insts_feats(&pred.insts),
        RtBlock::For { from, to, by, parfor, known_trip, .. } => {
            let mut f = insts_feats(&from.insts) | insts_feats(&to.insts);
            if let Some(by) = by {
                f |= insts_feats(&by.insts);
            }
            if *parfor {
                f |= F_PARFOR;
            }
            if known_trip.is_none() {
                f |= F_UNKNOWN_ITERS;
            }
            f
        }
        RtBlock::While { pred, .. } => insts_feats(&pred.insts) | F_UNKNOWN_ITERS,
        RtBlock::FCall { .. } => F_NOCACHE,
    };
    for c in &children {
        feats |= c.feats;
    }
    let (h1, h2) = hash_debug(b);
    BlockHash { h1, h2, feats, children }
}

/// Precomputed structural hashes of a whole runtime program: one
/// [`BlockHash`] tree per top-level block plus one per function body
/// block. Computed **once per compiled plan** (the evaluator stores it
/// alongside the `Arc`-shared plan in its memo), so repeated costings of
/// the same plan pay no hashing beyond the per-lookup state/knob
/// fingerprints.
#[derive(Clone, Debug, Default)]
pub struct ProgramHashes {
    pub(crate) blocks: Vec<BlockHash>,
    pub(crate) funcs: BTreeMap<String, Vec<BlockHash>>,
    pub(crate) root: (u64, u64),
    pub(crate) feats: u8,
}

impl ProgramHashes {
    /// 128-bit structural hash of the whole program — equal hashes mean
    /// structurally identical plans (used by the evaluator to skip
    /// re-costing duplicate candidates).
    pub fn root(&self) -> (u64, u64) {
        self.root
    }

    /// Union of every block's knob-relevance feature flags.
    pub(crate) fn feats(&self) -> u8 {
        self.feats
    }

    /// `(h1, h2)` structural fingerprints of the top-level blocks, aligned
    /// one-to-one with the program's `blocks` (and therefore with the
    /// per-block [`crate::cost::CostReport`] nodes). This is the key space
    /// [`crate::feedback`] uses to join per-block cost predictions with
    /// measured execution times.
    pub fn block_roots(&self) -> Vec<(u64, u64)> {
        self.blocks.iter().map(|b| (b.h1, b.h2)).collect()
    }
}

/// Compute the structural hash tree of a runtime program. Call once per
/// compiled plan and reuse across costings (see
/// [`super::cost_program_cached`]).
pub fn program_hashes(rt: &RtProgram) -> ProgramHashes {
    let blocks: Vec<BlockHash> = rt.blocks.iter().map(hash_block).collect();
    let funcs: BTreeMap<String, Vec<BlockHash>> = rt
        .funcs
        .iter()
        .map(|(n, f)| (n.clone(), f.blocks.iter().map(hash_block).collect()))
        .collect();
    let mut h1 = DefaultHasher::new();
    let mut h2 = Fnv::new();
    let mut feats = 0u8;
    for b in &blocks {
        h1.write_u64(b.h1);
        h1.write_u64(b.h2);
        h2.write_u64(b.h1);
        h2.write_u64(b.h2);
        feats |= b.feats;
    }
    for (name, bs) in &funcs {
        h1.write(name.as_bytes());
        h2.write(name.as_bytes());
        for b in bs {
            h1.write_u64(b.h1);
            h1.write_u64(b.h2);
            h2.write_u64(b.h1);
            h2.write_u64(b.h2);
            feats |= b.feats;
        }
    }
    ProgramHashes { blocks, funcs, root: (h1.finish(), h2.finish()), feats }
}

// ---------------------------------------------------------------------
// Knob fingerprints
// ---------------------------------------------------------------------

/// Feed the configuration knobs selected by `feats` into `h`. The base
/// group (clock, memory bandwidth, bookkeeping constant, sparsity
/// threshold, HDFS read/write bandwidths) is read by every instruction
/// path and always included; the loop / parfor / MR / Spark groups are
/// included only when the block's feature flags say the block can read
/// them. This is what lets cost-only axes that a block ignores (most
/// prominently `k_local` on plans without parfor) share cache entries.
pub(crate) fn hash_knobs<H: Hasher>(
    feats: u8,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fp: &FaultProfile,
    h: &mut H,
) {
    fn f64b<H: Hasher>(h: &mut H, v: f64) {
        h.write_u64(v.to_bits());
    }
    // base group: every instruction path. Every CostConstants field the
    // cost arithmetic can read must appear in some group — online
    // calibration (`crate::feedback`) rewrites constants in place, and a
    // missing field here would turn cached block costs stale.
    f64b(h, cc.clock_hz);
    f64b(h, k.flop_efficiency);
    f64b(h, k.mem_bw);
    f64b(h, k.bookkeeping);
    f64b(h, cfg.sparse_threshold);
    f64b(h, k.hdfs_read_binaryblock);
    f64b(h, k.hdfs_read_text);
    f64b(h, k.hdfs_write_binaryblock);
    f64b(h, k.hdfs_write_text);
    f64b(h, k.local_read);
    f64b(h, k.local_write);
    if feats & F_UNKNOWN_ITERS != 0 {
        f64b(h, cfg.unknown_iterations);
    }
    if feats & F_PARFOR != 0 {
        h.write_usize(cc.k_local);
    }
    if feats & (F_MR | F_SPARK) != 0 {
        f64b(h, cc.hdfs_block_bytes);
        f64b(h, k.dop_scale);
    }
    if feats & F_MR != 0 {
        h.write_usize(cc.k_map);
        h.write_usize(cc.k_reduce);
        h.write_usize(cc.nodes);
        h.write_usize(cc.vcores_per_node);
        f64b(h, cc.yarn_mem_per_node);
        f64b(h, cc.map_heap_bytes);
        f64b(h, cc.reduce_heap_bytes);
        f64b(h, k.job_latency);
        f64b(h, k.task_latency);
        f64b(h, cfg.partition_bytes);
        f64b(h, k.dcache_read);
        f64b(h, k.shuffle_bw);
    }
    if feats & F_SPARK != 0 {
        h.write_usize(cc.spark_executors);
        h.write_usize(cc.spark_executor_cores);
        f64b(h, k.spark_job_latency);
        f64b(h, k.spark_stage_latency);
        f64b(h, k.spark_task_latency);
        f64b(h, k.spark_shuffle_write);
        f64b(h, k.spark_shuffle_read);
        f64b(h, k.spark_broadcast_bw);
    }
    // fault knob group: only distributed-job blocks read the fault model,
    // and the identity profile contributes nothing — fingerprints under
    // `FaultProfile::none()` are bitwise-identical to a fault-unaware
    // build, so pre-existing cost-cache snapshots keep replaying, while
    // faulty and fault-free entries can never alias.
    if !fp.is_none() && feats & (F_MR | F_SPARK) != 0 {
        h.write_u8(1); // group marker
        f64b(h, fp.mr_fail_p);
        f64b(h, fp.spark_fail_p);
        f64b(h, fp.straggler_frac);
        f64b(h, fp.straggler_slowdown);
        h.write_usize(fp.max_attempts);
        f64b(h, fp.backoff_base);
        h.write_u8(fp.speculative as u8);
    }
}

/// 128-bit fingerprint of the configuration knobs a whole program can
/// read (the per-program analogue of the per-block knob hash). Two
/// candidates with equal [`ProgramHashes::root`] and equal context
/// fingerprints have bitwise-identical cost; the evaluator uses this to
/// skip re-costing duplicates.
pub(crate) fn hash_context(
    feats: u8,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fp: &FaultProfile,
) -> (u64, u64) {
    let mut h1 = DefaultHasher::new();
    let mut h2 = Fnv::new();
    hash_knobs(feats, cfg, cc, k, fp, &mut h1);
    hash_knobs(feats, cfg, cc, k, fp, &mut h2);
    (h1.finish(), h2.finish())
}

// ---------------------------------------------------------------------
// The cache
// ---------------------------------------------------------------------

/// Full cache key of one block costing: structural block hash ×
/// variable-state fingerprint × relevant knob fingerprint (each 128-bit,
/// each produced by two independent hash functions).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) struct CacheKey {
    block: (u64, u64),
    state: (u64, u64),
    knobs: (u64, u64),
}

/// 128-bit fingerprint of the knobs selected by `feats` plus the
/// costing mode. `emit_nodes` distinguishes the full-annotation entries
/// from the totals-only entries (the two modes store different
/// [`CostNode`] payloads and must never alias). Constant for one costing
/// walk per `feats` value — the estimator memoizes the (at most 16)
/// fingerprints per walk instead of re-hashing per block lookup.
pub(crate) fn knob_fingerprint(
    feats: u8,
    emit_nodes: bool,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fp: &FaultProfile,
) -> (u64, u64) {
    let mut k1 = DefaultHasher::new();
    let mut k2 = Fnv::new();
    k1.write_u8(emit_nodes as u8);
    k2.write_u8(emit_nodes as u8);
    hash_knobs(feats, cfg, cc, k, fp, &mut k1);
    hash_knobs(feats, cfg, cc, k, fp, &mut k2);
    (k1.finish(), k2.finish())
}

/// Build the lookup key for costing `bh` with incoming tracker state `t`
/// under the (memoized) knob fingerprint of the block's feature flags.
pub(crate) fn cache_key(bh: &BlockHash, t: &VarTracker, knobs: (u64, u64)) -> CacheKey {
    let mut s1 = DefaultHasher::new();
    let mut s2 = Fnv::new();
    t.hash_state(&mut s1);
    t.hash_state(&mut s2);
    CacheKey { block: (bh.h1, bh.h2), state: (s1.finish(), s2.finish()), knobs }
}

/// Both outputs of costing a block: the annotation subtree and the
/// variable-state tracker as it stands *after* the block. A hit replays
/// both, which is exactly what re-costing the block would produce.
pub(crate) struct CachedBlockCost {
    pub(crate) node: CostNode,
    pub(crate) tracker: VarTracker,
}

/// One totals-only cache entry in serializable form: the six 64-bit key
/// words (block hash, state fingerprint, knob fingerprint — two words
/// each), the cached block total and the compacted post-block variable
/// state. The unit the cost-cache snapshot artifact
/// ([`crate::artifact::snapshot`]) persists.
#[derive(Clone, Debug)]
pub(crate) struct ExportedEntry {
    pub(crate) key: [u64; 6],
    pub(crate) total: f64,
    pub(crate) vars: Vec<(String, usize, super::vars::DataInfo)>,
}

#[derive(Default)]
struct Shard {
    map: HashMap<CacheKey, Arc<CachedBlockCost>>,
    order: VecDeque<CacheKey>,
}

const SHARDS: usize = 8;

/// Thread-safe, bounded, block-level cost cache (see the module docs for
/// the key design). Share one instance across every costing of a
/// candidate family — the evaluator ([`crate::opt::evaluate`]) holds one
/// per run by default and accepts a caller-provided instance for
/// cross-run reuse (the steady-state the perf bench measures).
pub struct CostCache {
    shards: [Mutex<Shard>; SHARDS],
    per_shard_capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for CostCache {
    fn default() -> Self {
        CostCache::new(Self::DEFAULT_CAPACITY)
    }
}

impl CostCache {
    /// Default total entry capacity — generous for every bundled
    /// workload (an optimizer run touches a few thousand distinct
    /// (block, state, knobs) keys) while bounding memory.
    pub const DEFAULT_CAPACITY: usize = 65_536;

    /// Cache bounded to roughly `capacity` entries (rounded up to a
    /// multiple of the shard count; at least one entry per shard).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        let mut per_shard = cap / SHARDS;
        if cap % SHARDS != 0 {
            per_shard += 1;
        }
        CostCache {
            shards: std::array::from_fn(|_| Mutex::new(Shard::default())),
            per_shard_capacity: per_shard,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &CacheKey) -> &Mutex<Shard> {
        // mix all three components: the dominant multiplicity in real
        // workloads is one block under many (state, knob) variants, which
        // block-only sharding would funnel into a single mutex
        &self.shards[((key.block.1 ^ key.state.1 ^ key.knobs.1) as usize) % SHARDS]
    }

    pub(crate) fn get(&self, key: &CacheKey) -> Option<Arc<CachedBlockCost>> {
        let guard = self.shard(key).lock().unwrap_or_else(|e| e.into_inner());
        let hit = guard.map.get(key).cloned();
        drop(guard);
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    pub(crate) fn insert(&self, key: CacheKey, val: Arc<CachedBlockCost>) {
        let mut guard = self.shard(&key).lock().unwrap_or_else(|e| e.into_inner());
        if guard.map.insert(key, val).is_none() {
            guard.order.push_back(key);
            while guard.map.len() > self.per_shard_capacity {
                match guard.order.pop_front() {
                    Some(old) => {
                        guard.map.remove(&old);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    None => break,
                }
            }
        }
    }

    /// Export every *totals-only* entry as `(key words, total, post-block
    /// variable state)` rows, sorted by key so the export is
    /// deterministic regardless of shard layout or insertion order.
    ///
    /// Only totals-only entries (the `emit_nodes = false` fast path every
    /// optimizer runs through) are exported: their [`CostNode`] payload
    /// is a flat `Block { label: "", total, children: [] }`, so the full
    /// replay state is one `f64` plus the compacted tracker. Full
    /// annotation entries carry rendered instruction trees and are
    /// cheap to recompute relative to their serialized size; because the
    /// costing mode participates in the knob fingerprint, dropping them
    /// can never alias a totals-only lookup onto a stale annotation.
    pub(crate) fn export_totals(&self) -> Vec<ExportedEntry> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let guard = shard.lock().unwrap_or_else(|e| e.into_inner());
            for (key, val) in &guard.map {
                if let CostNode::Block { label, total, children } = &val.node {
                    if label.is_empty() && children.is_empty() {
                        out.push(ExportedEntry {
                            key: [
                                key.block.0,
                                key.block.1,
                                key.state.0,
                                key.state.1,
                                key.knobs.0,
                                key.knobs.1,
                            ],
                            total: *total,
                            vars: val.tracker.export_entries(),
                        });
                    }
                }
            }
        }
        out.sort_unstable_by_key(|e| e.key);
        out
    }

    /// Merge exported rows back in through the normal sharded insert, so
    /// the FIFO capacity bound keeps holding (a snapshot larger than the
    /// cache evicts its oldest rows instead of overflowing). Returns how
    /// many rows were inserted.
    pub(crate) fn import_totals(&self, entries: &[ExportedEntry]) -> usize {
        for e in entries {
            let key = CacheKey {
                block: (e.key[0], e.key[1]),
                state: (e.key[2], e.key[3]),
                knobs: (e.key[4], e.key[5]),
            };
            let node =
                CostNode::Block { label: String::new(), total: e.total, children: Vec::new() };
            let tracker = VarTracker::from_entries(&e.vars);
            self.insert(key, Arc::new(CachedBlockCost { node, tracker }));
        }
        entries.len()
    }

    /// Snapshot of the hit/miss/eviction counters and current size.
    pub fn stats(&self) -> CacheStats {
        let entries = self
            .shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).map.len())
            .sum();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
            capacity: self.per_shard_capacity * SHARDS,
        }
    }
}

/// Cache counters, either absolute ([`CostCache::stats`]) or as a
/// per-run delta ([`CacheStats::since`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to cost the block.
    pub misses: u64,
    /// Entries dropped by the FIFO capacity bound.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Total entry capacity (shard capacity × shard count).
    pub capacity: usize,
}

impl CacheStats {
    /// Hit rate over the counted lookups (0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counter delta relative to an earlier snapshot (entries/capacity
    /// are reported as-of-now, not differenced).
    pub fn since(&self, baseline: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(baseline.hits),
            misses: self.misses.saturating_sub(baseline.misses),
            evictions: self.evictions.saturating_sub(baseline.evictions),
            entries: self.entries,
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::InstCost;
    use crate::matrix::{Format, MatrixCharacteristics};

    fn dummy_val(tag: &str) -> Arc<CachedBlockCost> {
        Arc::new(CachedBlockCost {
            node: CostNode::Inst { rendered: tag.to_string(), cost: InstCost::default() },
            tracker: VarTracker::default(),
        })
    }

    /// Keys crafted to land in one shard: shard choice xors the second
    /// word of each component, so `block.1 == state.1` with zero knobs
    /// cancels to shard 0 while `block.0` keeps the keys distinct.
    fn key_in_shard0(i: u64) -> CacheKey {
        CacheKey { block: (i, i), state: (i, i), knobs: (0, 0) }
    }

    #[test]
    fn fifo_eviction_within_capacity() {
        // capacity 2 -> 1 entry per shard; two same-shard inserts evict
        // the older one, FIFO.
        let cache = CostCache::new(2);
        let (k1, k2) = (key_in_shard0(1), key_in_shard0(2));
        cache.insert(k1, dummy_val("a"));
        cache.insert(k2, dummy_val("b"));
        assert!(cache.get(&k1).is_none(), "k1 must be evicted first (FIFO)");
        assert!(cache.get(&k2).is_some());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.entries <= s.capacity, "{s:?}");
    }

    #[test]
    fn reinsert_does_not_duplicate_order_queue() {
        let cache = CostCache::new(2);
        let k = key_in_shard0(1);
        cache.insert(k, dummy_val("a"));
        cache.insert(k, dummy_val("b")); // overwrite, no second order slot
        let other = key_in_shard0(2);
        cache.insert(other, dummy_val("c"));
        // exactly one eviction: k (the single queued entry)
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get(&k).is_none());
        assert!(cache.get(&other).is_some());
    }

    #[test]
    fn stats_count_hits_and_misses() {
        let cache = CostCache::new(64);
        let k = key_in_shard0(1);
        assert!(cache.get(&k).is_none());
        cache.insert(k, dummy_val("a"));
        assert!(cache.get(&k).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        let d = cache.stats().since(&s);
        assert_eq!((d.hits, d.misses), (0, 0));
    }

    #[test]
    fn structural_hash_distinguishes_blocks_and_is_stable() {
        let mk = |rows: i64| RtBlock::Generic {
            insts: vec![Instr::CreateVar {
                var: "x".into(),
                path: "p".into(),
                temp: true,
                format: Format::BinaryBlock,
                mc: MatrixCharacteristics::dense(rows, 10, 10),
            }],
            lines: (1, 1),
            recompile: false,
        };
        let a1 = hash_block(&mk(100));
        let a2 = hash_block(&mk(100));
        let b = hash_block(&mk(101));
        assert_eq!((a1.h1, a1.h2), (a2.h1, a2.h2), "hashing must be deterministic");
        assert_ne!((a1.h1, a1.h2), (b.h1, b.h2), "different blocks must differ");
        assert_eq!(a1.feats, 0, "plain CP block reads only the base knobs");
    }

    #[test]
    fn feature_flags_select_knob_groups() {
        let cfg = SystemConfig::default();
        let k = CostConstants::default();
        let fp = FaultProfile::none();
        let cc1 = ClusterConfig::paper_cluster();
        let mut cc2 = cc1.clone();
        cc2.k_local = 7; // parfor-only knob
        // without the parfor flag the two clusters fingerprint equal...
        assert_eq!(hash_context(0, &cfg, &cc1, &k, &fp), hash_context(0, &cfg, &cc2, &k, &fp));
        // ...with it they differ
        assert_ne!(
            hash_context(F_PARFOR, &cfg, &cc1, &k, &fp),
            hash_context(F_PARFOR, &cfg, &cc2, &k, &fp)
        );
        // clock is in the base group: always observable
        let mut cc3 = cc1.clone();
        cc3.clock_hz *= 2.0;
        assert_ne!(hash_context(0, &cfg, &cc1, &k, &fp), hash_context(0, &cfg, &cc3, &k, &fp));
        // spark knobs only observable with the spark flag
        let mut cc4 = cc1.clone();
        cc4.spark_executors = 99;
        assert_eq!(
            hash_context(F_MR, &cfg, &cc1, &k, &fp),
            hash_context(F_MR, &cfg, &cc4, &k, &fp)
        );
        assert_ne!(
            hash_context(F_SPARK, &cfg, &cc1, &k, &fp),
            hash_context(F_SPARK, &cfg, &cc4, &k, &fp)
        );
    }

    /// The fault knob group fingerprints only for distributed blocks under
    /// a non-identity profile: `FaultProfile::none()` must be bitwise
    /// invisible (pre-existing cost-cache snapshots keep replaying), while
    /// faulty and fault-free entries must never alias.
    #[test]
    fn fault_profile_selects_knob_group() {
        let cfg = SystemConfig::default();
        let k = CostConstants::default();
        let cc = ClusterConfig::paper_cluster();
        let none = FaultProfile::none();
        let chaos = FaultProfile::chaos();
        // CP-only blocks never observe the fault model, whatever profile
        assert_eq!(hash_context(0, &cfg, &cc, &k, &none), hash_context(0, &cfg, &cc, &k, &chaos));
        assert_eq!(
            hash_context(F_PARFOR, &cfg, &cc, &k, &none),
            hash_context(F_PARFOR, &cfg, &cc, &k, &chaos)
        );
        // distributed blocks under a nonzero profile fingerprint apart
        for feats in [F_MR, F_SPARK, F_MR | F_SPARK] {
            assert_ne!(
                hash_context(feats, &cfg, &cc, &k, &none),
                hash_context(feats, &cfg, &cc, &k, &chaos),
                "feats={feats}"
            );
        }
        // every fault field is observable once the group is active
        for tweak in [
            |f: &mut FaultProfile| f.mr_fail_p = 0.11,
            |f: &mut FaultProfile| f.spark_fail_p = 0.22,
            |f: &mut FaultProfile| f.straggler_frac = 0.33,
            |f: &mut FaultProfile| f.straggler_slowdown = 5.0,
            |f: &mut FaultProfile| f.max_attempts = 7,
            |f: &mut FaultProfile| f.backoff_base = 0.75,
            |f: &mut FaultProfile| f.speculative = true,
        ] {
            let mut fp2 = chaos.clone();
            tweak(&mut fp2);
            assert_ne!(
                hash_context(F_MR, &cfg, &cc, &k, &chaos),
                hash_context(F_MR, &cfg, &cc, &k, &fp2)
            );
        }
    }

    /// Every constant online calibration can rewrite must be observable in
    /// the base knob group, whatever the feature flags — otherwise a
    /// calibrated `CostConstants` replays stale cached block costs.
    #[test]
    fn calibrated_constants_always_fingerprint() {
        let cfg = SystemConfig::default();
        let cc = ClusterConfig::paper_cluster();
        let k1 = CostConstants::default();
        let fp = FaultProfile::none();
        for feats in [0u8, F_PARFOR, F_MR, F_SPARK, F_MR | F_SPARK] {
            let base = hash_context(feats, &cfg, &cc, &k1, &fp);
            let mut k2 = k1.clone();
            k2.flop_efficiency = 2.0;
            assert_ne!(
                base,
                hash_context(feats, &cfg, &cc, &k2, &fp),
                "flop_efficiency, feats={feats}"
            );
            let mut k3 = k1.clone();
            k3.local_read *= 2.0;
            assert_ne!(base, hash_context(feats, &cfg, &cc, &k3, &fp), "local_read, feats={feats}");
            let mut k4 = k1.clone();
            k4.local_write *= 2.0;
            assert_ne!(
                base,
                hash_context(feats, &cfg, &cc, &k4, &fp),
                "local_write, feats={feats}"
            );
        }
    }

    #[test]
    fn tracker_fingerprint_sees_aliasing_and_state() {
        let mc = MatrixCharacteristics::dense(100, 100, 100);
        let fp = |t: &VarTracker| {
            let mut h = Fnv::new();
            t.hash_state(&mut h);
            h.finish()
        };
        // aliased pair vs two independent entries with identical fields
        let mut aliased = VarTracker::default();
        aliased.create("x", mc, Format::BinaryBlock, true);
        aliased.alias("x", "y");
        let mut split = VarTracker::default();
        split.create("x", mc, Format::BinaryBlock, true);
        split.create("y", mc, Format::BinaryBlock, true);
        assert_ne!(fp(&aliased), fp(&split), "alias structure must be part of the key");
        // residence state flips the fingerprint
        let mut warm = VarTracker::default();
        warm.create("x", mc, Format::BinaryBlock, true);
        warm.alias("x", "y");
        warm.touch_mem("x");
        assert_ne!(fp(&aliased), fp(&warm));
        // identical construction order -> identical fingerprint
        let mut again = VarTracker::default();
        again.create("x", mc, Format::BinaryBlock, true);
        again.alias("x", "y");
        assert_eq!(fp(&aliased), fp(&again));
    }

    #[test]
    fn fcall_blocks_are_not_cacheable_and_poison_ancestors() {
        let fcall = RtBlock::FCall {
            fname: "f".into(),
            args: vec![],
            outputs: vec![],
            lines: (1, 1),
        };
        let h = hash_block(&fcall);
        assert!(!h.cacheable());
        let parent = RtBlock::While {
            pred: Default::default(),
            body: vec![fcall],
            lines: (1, 2),
        };
        let hp = hash_block(&parent);
        assert!(!hp.cacheable(), "NOCACHE must propagate upward");
        assert!(hp.feats & F_UNKNOWN_ITERS != 0);
    }
}
