//! The serve line protocol: newline-delimited requests of
//! space-separated `key=value` tokens, answered by exactly one response
//! line of the same shape.
//!
//! Grammar (one request per line):
//!
//! ```text
//! request  := pair (SP pair)* NL
//! pair     := key "=" value          ; value = first "=" onward, no SP
//! key      := cmd | id | scenario | script | iters | backend
//!           | budget_ms | budget_candidates | heaps
//! ```
//!
//! `cmd` is required (`optimize | sweep | gdf | verify | stats`); every
//! other key is optional. Blank lines and `#` comments are skipped.
//! Responses always carry `ok=`, and successful optimizer responses
//! carry `level=` (the ladder rung that answered) and `downgrade=`
//! (reason-code trail, [`DOWNGRADE_NONE`] at full fidelity). Error
//! responses carry `code=` (one of the `CODE_*` constants) and a
//! sanitized `detail=`. An `id=` pair is echoed back verbatim, first.

use crate::rtprog::ExecBackend;

/// Request line could not be parsed into `key=value` pairs.
pub const CODE_MALFORMED: &str = "malformed";
/// A key outside the protocol vocabulary.
pub const CODE_UNKNOWN_KEY: &str = "unknown-key";
/// A key given more than once.
pub const CODE_DUPLICATE_KEY: &str = "duplicate-key";
/// `cmd=` value outside `optimize|sweep|gdf|verify|stats`.
pub const CODE_UNKNOWN_CMD: &str = "unknown-cmd";
/// A required key (e.g. `scenario=` on optimizer requests) is absent.
pub const CODE_MISSING_KEY: &str = "missing-key";
/// A value failed validation (non-numeric budget, bad backend, ...).
pub const CODE_BAD_VALUE: &str = "bad-value";
/// `scenario=` names no bundled Table-1 scenario.
pub const CODE_UNKNOWN_SCENARIO: &str = "unknown-scenario";
/// The optimizer itself failed (compile error, non-finite cost).
pub const CODE_OPTIMIZER_ERROR: &str = "optimizer-error";
/// Request exceeded the line-length or field-count cap. The transport
/// discards the oversized bytes instead of buffering them, so one
/// hostile client cannot balloon daemon memory.
pub const CODE_REQUEST_TOO_LARGE: &str = "request-too-large";

/// Hard cap on one request line, bytes (excluding the newline). Lines
/// beyond it are drained and answered with
/// [`CODE_REQUEST_TOO_LARGE`] — never accumulated in memory.
pub const MAX_LINE_BYTES: usize = 8192;
/// Hard cap on `key=value` tokens in one request line.
pub const MAX_FIELDS: usize = 64;

/// `downgrade=` value when the request was answered at full fidelity.
pub const DOWNGRADE_NONE: &str = "none";

/// Ladder-rung names reported in `level=`.
pub const LEVEL_FULL: &str = "full";
/// See [`LEVEL_FULL`]: the backend-argmin fallback rung.
pub const LEVEL_SWEEP: &str = "sweep";
/// See [`LEVEL_FULL`]: the terminal cached/default rung.
pub const LEVEL_CACHED: &str = "cached";

/// The five request kinds.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ReqCmd {
    /// Backend argmin for one scenario (the cheapest decision).
    Optimize,
    /// Full cluster-grid sweep ([`crate::opt::sweep`]).
    Sweep,
    /// Global data flow enumeration ([`crate::opt::gdf`]).
    Gdf,
    /// Static plan verification ([`crate::analysis`]).
    Verify,
    /// Observability counters; never touches the optimizers.
    Stats,
}

impl ReqCmd {
    /// All request kinds, in stats-reporting order.
    pub const ALL: [ReqCmd; 5] =
        [ReqCmd::Optimize, ReqCmd::Sweep, ReqCmd::Gdf, ReqCmd::Verify, ReqCmd::Stats];

    /// Wire name.
    pub fn name(&self) -> &'static str {
        match self {
            ReqCmd::Optimize => "optimize",
            ReqCmd::Sweep => "sweep",
            ReqCmd::Gdf => "gdf",
            ReqCmd::Verify => "verify",
            ReqCmd::Stats => "stats",
        }
    }

    /// Index into per-command counter arrays.
    pub fn index(&self) -> usize {
        match self {
            ReqCmd::Optimize => 0,
            ReqCmd::Sweep => 1,
            ReqCmd::Gdf => 2,
            ReqCmd::Verify => 3,
            ReqCmd::Stats => 4,
        }
    }

    fn parse(s: &str) -> Option<ReqCmd> {
        Self::ALL.into_iter().find(|c| c.name() == s)
    }
}

/// Which bundled DML script a request targets.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ReqScript {
    /// Direct-solve LinReg (`linreg_ds`), the default.
    Ds,
    /// Iterative conjugate-gradient LinReg (`linreg_cg`).
    Cg,
}

impl ReqScript {
    /// Wire name.
    pub fn name(&self) -> &'static str {
        match self {
            ReqScript::Ds => "ds",
            ReqScript::Cg => "cg",
        }
    }
}

/// A parsed, validated request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client correlation token, echoed first in the response.
    pub id: Option<String>,
    /// Request kind.
    pub cmd: ReqCmd,
    /// Table-1 scenario name (required for every kind except `stats`).
    pub scenario: Option<String>,
    /// Script selector (default `ds`).
    pub script: ReqScript,
    /// CG iteration count (default 20; ignored by `ds`).
    pub iters: usize,
    /// Backend for `verify` requests (default MR).
    pub backend: Option<ExecBackend>,
    /// Wall-clock budget in milliseconds.
    pub budget_ms: Option<u64>,
    /// Candidate-count budget.
    pub budget_candidates: Option<u64>,
    /// Heap axis in MB for `sweep` requests.
    pub heaps: Vec<f64>,
}

/// A request-level failure: machine-readable `code` plus sanitized
/// human detail.
#[derive(Clone, Debug)]
pub struct ProtocolError {
    /// One of the `CODE_*` constants.
    pub code: &'static str,
    /// Free-text diagnostic (sanitized before rendering).
    pub detail: String,
}

impl ProtocolError {
    fn new(code: &'static str, detail: impl Into<String>) -> Self {
        ProtocolError { code, detail: detail.into() }
    }
}

/// Extract the `id=` value from a raw request line without full
/// parsing, so even malformed requests echo their correlation token.
pub fn peek_id(line: &str) -> Option<String> {
    line.split_whitespace().find_map(|tok| tok.strip_prefix("id=")).map(sanitize)
}

/// Replace whitespace with `-` and `=` with `:` so a free-text
/// diagnostic stays one well-formed `key=value` token.
pub fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| match c {
            c if c.is_whitespace() => '-',
            '=' => ':',
            c => c,
        })
        .collect()
}

/// Parse and validate one request line. Blank/comment filtering is the
/// caller's job; `line` must be non-empty.
pub fn parse_request(line: &str) -> Result<Request, ProtocolError> {
    if line.len() > MAX_LINE_BYTES {
        return Err(ProtocolError::new(
            CODE_REQUEST_TOO_LARGE,
            format!("request line is {} bytes (cap {MAX_LINE_BYTES})", line.len()),
        ));
    }
    if line.split_whitespace().count() > MAX_FIELDS {
        return Err(ProtocolError::new(
            CODE_REQUEST_TOO_LARGE,
            format!(
                "request has {} fields (cap {MAX_FIELDS})",
                line.split_whitespace().count()
            ),
        ));
    }
    let mut req = Request {
        id: None,
        cmd: ReqCmd::Stats,
        scenario: None,
        script: ReqScript::Ds,
        iters: 20,
        backend: None,
        budget_ms: None,
        budget_candidates: None,
        heaps: vec![2048.0],
    };
    let mut cmd: Option<ReqCmd> = None;
    let mut script: Option<ReqScript> = None;
    let mut seen: Vec<&str> = Vec::new();
    for tok in line.split_whitespace() {
        let Some((key, value)) = tok.split_once('=') else {
            return Err(ProtocolError::new(
                CODE_MALFORMED,
                format!("token '{tok}' is not key=value"),
            ));
        };
        if value.is_empty() {
            return Err(ProtocolError::new(CODE_MALFORMED, format!("empty value for '{key}'")));
        }
        if seen.contains(&key) {
            return Err(ProtocolError::new(
                CODE_DUPLICATE_KEY,
                format!("key '{key}' given twice"),
            ));
        }
        match key {
            "cmd" => {
                cmd = Some(ReqCmd::parse(value).ok_or_else(|| {
                    ProtocolError::new(CODE_UNKNOWN_CMD, format!("unknown cmd '{value}'"))
                })?);
            }
            "id" => req.id = Some(sanitize(value)),
            "scenario" => req.scenario = Some(value.to_string()),
            "script" => {
                script = Some(match value {
                    "ds" => ReqScript::Ds,
                    "cg" => ReqScript::Cg,
                    _ => {
                        return Err(ProtocolError::new(
                            CODE_BAD_VALUE,
                            format!("script '{value}' (expected ds or cg)"),
                        ))
                    }
                });
            }
            "iters" => match value.parse::<usize>() {
                Ok(n) if n >= 1 => req.iters = n,
                _ => {
                    return Err(ProtocolError::new(
                        CODE_BAD_VALUE,
                        format!("iters '{value}' (expected a positive integer)"),
                    ))
                }
            },
            "backend" => {
                req.backend = Some(ExecBackend::parse(value).ok_or_else(|| {
                    ProtocolError::new(
                        CODE_BAD_VALUE,
                        format!("backend '{value}' (expected cp, mr or spark)"),
                    )
                })?);
            }
            "budget_ms" => match value.parse::<u64>() {
                Ok(n) => req.budget_ms = Some(n),
                _ => {
                    return Err(ProtocolError::new(
                        CODE_BAD_VALUE,
                        format!("budget_ms '{value}' (expected a non-negative integer)"),
                    ))
                }
            },
            "budget_candidates" => match value.parse::<u64>() {
                Ok(n) => req.budget_candidates = Some(n),
                _ => {
                    return Err(ProtocolError::new(
                        CODE_BAD_VALUE,
                        format!("budget_candidates '{value}' (expected a non-negative integer)"),
                    ))
                }
            },
            "heaps" => {
                let mut heaps = Vec::new();
                for part in value.split(',').filter(|p| !p.is_empty()) {
                    match part.parse::<f64>() {
                        Ok(x) if x.is_finite() && x > 0.0 => heaps.push(x),
                        _ => {
                            return Err(ProtocolError::new(
                                CODE_BAD_VALUE,
                                format!("heaps entry '{part}' (expected positive MB)"),
                            ))
                        }
                    }
                }
                if heaps.is_empty() {
                    return Err(ProtocolError::new(CODE_BAD_VALUE, "heaps list is empty"));
                }
                req.heaps = heaps;
            }
            _ => {
                return Err(ProtocolError::new(
                    CODE_UNKNOWN_KEY,
                    format!("unknown key '{key}'"),
                ))
            }
        }
        seen.push(key);
    }
    let Some(cmd) = cmd else {
        return Err(ProtocolError::new(CODE_MISSING_KEY, "cmd is required"));
    };
    req.cmd = cmd;
    if let Some(s) = script {
        req.script = s;
    }
    if req.cmd != ReqCmd::Stats && req.scenario.is_none() {
        return Err(ProtocolError::new(
            CODE_MISSING_KEY,
            format!("scenario is required for cmd={}", cmd.name()),
        ));
    }
    Ok(req)
}

/// An ordered `key=value` response line under construction. Field order
/// is fixed by insertion order, so rendered responses are byte-stable.
#[derive(Clone, Debug, Default)]
pub struct Response {
    fields: Vec<(&'static str, String)>,
}

impl Response {
    /// Successful response skeleton: `ok=true cmd=<name>`.
    pub fn ok(cmd: ReqCmd) -> Self {
        let mut r = Response::default();
        r.push("ok", "true");
        r.push("cmd", cmd.name());
        r
    }

    /// Error response: `ok=false code=<code> detail=<sanitized>`.
    pub fn error(code: &'static str, detail: &str) -> Self {
        let mut r = Response::default();
        r.push("ok", "false");
        r.push("code", code);
        r.push("detail", sanitize(detail));
        r
    }

    /// Append a field (values are sanitized to stay token-safe).
    pub fn push(&mut self, key: &'static str, value: impl AsRef<str>) {
        self.fields.push((key, sanitize(value.as_ref())));
    }

    /// Append a cost field as both a human-readable fixed-point value
    /// and the exact bit pattern (`<key>_bits`, 16 hex digits) for
    /// bitwise-equality assertions.
    pub fn push_cost(&mut self, key: &'static str, secs: f64) {
        self.fields.push((key, format!("{secs:.6}")));
        match key {
            "cost" => self.fields.push(("cost_bits", format!("{:016x}", secs.to_bits()))),
            _ => self.fields.push(("bits", format!("{:016x}", secs.to_bits()))),
        }
    }

    /// Look up a field by key (tests and the stats recorder use this).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.fields.iter().find(|(k, _)| *k == key).map(|(_, v)| v.as_str())
    }

    /// Render the response line, echoing `id` first when present (no
    /// trailing newline).
    pub fn render(&self, id: Option<&str>) -> String {
        let mut out = String::new();
        if let Some(id) = id {
            out.push_str("id=");
            out.push_str(&sanitize(id));
        }
        for (k, v) in &self.fields {
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(k);
            out.push('=');
            out.push_str(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_request() {
        let r = parse_request(
            "cmd=gdf id=r1 scenario=XL1 script=cg iters=10 budget_ms=250 budget_candidates=64",
        )
        .unwrap();
        assert_eq!(r.cmd, ReqCmd::Gdf);
        assert_eq!(r.id.as_deref(), Some("r1"));
        assert_eq!(r.scenario.as_deref(), Some("XL1"));
        assert_eq!(r.script, ReqScript::Cg);
        assert_eq!(r.iters, 10);
        assert_eq!(r.budget_ms, Some(250));
        assert_eq!(r.budget_candidates, Some(64));
    }

    #[test]
    fn rejects_malformed_and_unknown() {
        assert_eq!(parse_request("optimize now").unwrap_err().code, CODE_MALFORMED);
        assert_eq!(parse_request("cmd=optimize flavor=red").unwrap_err().code, CODE_UNKNOWN_KEY);
        assert_eq!(parse_request("cmd=explode scenario=XS").unwrap_err().code, CODE_UNKNOWN_CMD);
        assert_eq!(parse_request("scenario=XS").unwrap_err().code, CODE_MISSING_KEY);
        assert_eq!(parse_request("cmd=optimize").unwrap_err().code, CODE_MISSING_KEY);
        assert_eq!(
            parse_request("cmd=optimize scenario=XS iters=zero").unwrap_err().code,
            CODE_BAD_VALUE
        );
        assert_eq!(
            parse_request("cmd=stats cmd=stats").unwrap_err().code,
            CODE_DUPLICATE_KEY
        );
    }

    #[test]
    fn oversized_requests_get_a_stable_code() {
        // byte cap: a single huge token
        let long = format!("cmd=stats pad={}", "x".repeat(MAX_LINE_BYTES));
        assert_eq!(parse_request(&long).unwrap_err().code, CODE_REQUEST_TOO_LARGE);
        // field cap: many tiny duplicate-looking tokens (the size check
        // must fire before duplicate-key validation walks them all)
        let wide = ["k=v"; MAX_FIELDS + 1].join(" ");
        assert_eq!(parse_request(&wide).unwrap_err().code, CODE_REQUEST_TOO_LARGE);
        // exactly at the field cap the normal validation applies
        let at_cap = ["k=v"; MAX_FIELDS].join(" ");
        assert_eq!(parse_request(&at_cap).unwrap_err().code, CODE_DUPLICATE_KEY);
    }

    #[test]
    fn id_survives_malformed_lines() {
        assert_eq!(peek_id("cmd=? id=x7 what").as_deref(), Some("x7"));
        assert_eq!(peek_id("cmd=stats"), None);
    }

    #[test]
    fn response_renders_in_insertion_order() {
        let mut r = Response::ok(ReqCmd::Optimize);
        r.push("level", LEVEL_FULL);
        r.push_cost("cost", 1.5);
        assert_eq!(
            r.render(Some("a")),
            format!("id=a ok=true cmd=optimize level=full cost=1.500000 cost_bits={:016x}", 1.5f64.to_bits())
        );
    }

    #[test]
    fn sanitize_keeps_tokens_wellformed() {
        assert_eq!(sanitize("two words\tand=eq"), "two-words-and:eq");
    }
}
