//! **Optimizer-as-a-service**: the long-lived multi-tenant daemon
//! behind `repro serve`.
//!
//! The paper's thesis is that costing generated runtime plans is cheap
//! enough for a higher-level optimizer to invoke constantly; this
//! module makes that literal — one warm process answers streams of
//! `optimize | sweep | gdf | verify | stats` requests off **one shared,
//! sharded [`PlanMemo`](crate::opt::evaluate::PlanMemo) +
//! [`CostCache`](crate::cost::cache::CostCache)**, so the steady state
//! is thousands of cached decisions per second (measured by
//! `benches/serve.rs` → `BENCH_SERVE.json`).
//!
//! Three layers:
//!
//! * [`protocol`] — the newline-delimited `key=value` request/response
//!   grammar, error codes, and byte-stable response rendering.
//! * [`daemon`] — [`ServeState`]: shared caches, per-request
//!   evaluators, the budget-driven **one-way downgrade ladder**
//!   (full → sweep → cached, with machine-readable `downgrade=` reason
//!   codes), and `--warm-cache` / `--profile` artifact boot.
//! * [`stats`] — observability counters (requests, downgrades by
//!   reason, cache hit/miss, p50/p99 latency) behind the `stats`
//!   request.
//!
//! Transport is pluggable and trivial: [`serve_lines`] runs the
//! stdin/stdout session (requests strictly sequential, one response
//! line per request line, flushed immediately), [`serve_tcp`] accepts
//! concurrent TCP connections, one thread per connection, all sharing
//! one [`ServeState`]. `--threads` controls only the per-request
//! evaluator fan-out — responses are byte-stable across thread counts
//! (`tests/serve.rs` asserts this).

#![warn(missing_docs)]

pub mod daemon;
pub mod protocol;
pub mod stats;

pub use daemon::{ServeOptions, ServeState};
pub use protocol::{Request, Response};
pub use stats::ServeStats;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// Run a line-oriented serve session: read request lines from `input`,
/// write one response line per request to `output` (flushed after each,
/// so pipes see responses promptly). Requests are handled strictly in
/// order; blank lines and `#` comments are skipped. Returns when the
/// input reaches EOF.
pub fn serve_lines(
    state: &ServeState,
    input: impl BufRead,
    mut output: impl Write,
) -> std::io::Result<()> {
    for line in input.lines() {
        let line = line?;
        if let Some(resp) = state.handle_line(&line) {
            output.write_all(resp.as_bytes())?;
            output.write_all(b"\n")?;
            output.flush()?;
        }
    }
    Ok(())
}

/// Accept TCP connections forever, one handler thread per connection,
/// every connection sharing `state` (and therefore the one memo/cache).
/// Each connection speaks the same line protocol as [`serve_lines`] and
/// ends at client EOF. Accept errors on one connection are logged to
/// stderr and do not take the daemon down.
pub fn serve_tcp(state: Arc<ServeState>, listener: TcpListener) -> std::io::Result<()> {
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                let state = Arc::clone(&state);
                std::thread::spawn(move || {
                    if let Err(e) = serve_connection(&state, stream) {
                        eprintln!("serve: connection {peer}: {e}");
                    }
                });
            }
            Err(e) => eprintln!("serve: accept failed: {e}"),
        }
    }
}

fn serve_connection(state: &ServeState, stream: TcpStream) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    serve_lines(state, reader, stream)
}
