//! **Optimizer-as-a-service**: the long-lived multi-tenant daemon
//! behind `repro serve`.
//!
//! The paper's thesis is that costing generated runtime plans is cheap
//! enough for a higher-level optimizer to invoke constantly; this
//! module makes that literal — one warm process answers streams of
//! `optimize | sweep | gdf | verify | stats` requests off **one shared,
//! sharded [`PlanMemo`](crate::opt::evaluate::PlanMemo) +
//! [`CostCache`](crate::cost::cache::CostCache)**, so the steady state
//! is thousands of cached decisions per second (measured by
//! `benches/serve.rs` → `BENCH_SERVE.json`).
//!
//! Three layers:
//!
//! * [`protocol`] — the newline-delimited `key=value` request/response
//!   grammar, error codes, and byte-stable response rendering.
//! * [`daemon`] — [`ServeState`]: shared caches, per-request
//!   evaluators, the budget-driven **one-way downgrade ladder**
//!   (full → sweep → cached, with machine-readable `downgrade=` reason
//!   codes), and `--warm-cache` / `--profile` / `--spill-argmin`
//!   artifact boot.
//! * [`stats`] — observability counters (requests, downgrades by
//!   reason, cache hit/miss, p50/p99 latency) behind the `stats`
//!   request.
//!
//! Transport is pluggable and hardened against misbehaving clients:
//! [`serve_lines`] runs the line session (requests strictly
//! sequential, one response line per request line, flushed
//! immediately) with **bounded line buffering** — a line longer than
//! [`protocol::MAX_LINE_BYTES`] is drained without buffering and
//! answered with a [`protocol::CODE_REQUEST_TOO_LARGE`] error instead
//! of growing memory without limit. [`serve_tcp`] accepts concurrent
//! TCP connections, one thread per connection, all sharing one
//! [`ServeState`]; each socket gets the `--idle-timeout` read deadline
//! (a silent client is closed cleanly, never pinning a handler thread
//! forever), and [`serve_tcp_until`] adds a graceful drain: stop
//! accepting when the shutdown flag flips, then join the in-flight
//! handlers so every accepted request is answered. `--threads`
//! controls only the per-request evaluator fan-out — responses are
//! byte-stable across thread counts (`tests/serve.rs` asserts this).

#![warn(missing_docs)]

pub mod daemon;
pub mod protocol;
pub mod stats;

pub use daemon::{ServeOptions, ServeState};
pub use protocol::{Request, Response};
pub use stats::ServeStats;

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// One bounded read: a line within the cap, or the byte count of an
/// oversized line that was drained without being buffered.
enum BoundedLine {
    /// A complete line (newline stripped) of at most `cap` bytes.
    Line(String),
    /// The line exceeded the cap; it was consumed from the reader (so
    /// the session can continue at the next line) but never buffered
    /// beyond the cap. Carries the full line length in bytes.
    Oversized(usize),
}

/// Read one newline-terminated line, buffering at most `cap` bytes.
///
/// `BufRead::lines` buffers an entire line before returning it, so one
/// client sending an unbounded line grows daemon memory without limit.
/// This reader works chunk-by-chunk off `fill_buf`/`consume`: once the
/// running total passes `cap` the partial buffer is dropped and the
/// remainder of the line is drained (counted, not stored). Returns
/// `None` at clean EOF; a final line without a trailing newline is
/// still delivered.
fn read_line_bounded(
    input: &mut impl BufRead,
    cap: usize,
) -> std::io::Result<Option<BoundedLine>> {
    let mut buf: Vec<u8> = Vec::new();
    let mut total = 0usize;
    let mut oversized = false;
    let mut saw_any = false;
    loop {
        // The chunk borrow must end before `consume`, so compute how
        // much to take (and copy what we keep) inside this block.
        let (take, done) = {
            let chunk = input.fill_buf()?;
            if chunk.is_empty() {
                break; // EOF — deliver whatever the line holds so far
            }
            saw_any = true;
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => (pos + 1, true),
                None => (chunk.len(), false),
            }
        };
        let content = if done { take - 1 } else { take };
        if !oversized {
            if total + content > cap {
                oversized = true;
                buf.clear();
            } else {
                let chunk = input.fill_buf()?;
                buf.extend_from_slice(&chunk[..content]);
            }
        }
        total += content;
        input.consume(take);
        if done {
            return Ok(Some(finish_line(buf, total, oversized)));
        }
    }
    if !saw_any {
        return Ok(None);
    }
    Ok(Some(finish_line(buf, total, oversized)))
}

fn finish_line(mut buf: Vec<u8>, total: usize, oversized: bool) -> BoundedLine {
    if oversized {
        return BoundedLine::Oversized(total);
    }
    if buf.last() == Some(&b'\r') {
        buf.pop(); // match `BufRead::lines`: CRLF clients see the same grammar
    }
    BoundedLine::Line(String::from_utf8_lossy(&buf).into_owned())
}

/// Run a line-oriented serve session: read request lines from `input`,
/// write one response line per request to `output` (flushed after each,
/// so pipes see responses promptly). Requests are handled strictly in
/// order; blank lines and `#` comments are skipped. Lines longer than
/// [`protocol::MAX_LINE_BYTES`] are drained without buffering and
/// answered with a stable [`protocol::CODE_REQUEST_TOO_LARGE`] error —
/// the session continues at the next line. Returns when the input
/// reaches EOF.
pub fn serve_lines(
    state: &ServeState,
    mut input: impl BufRead,
    mut output: impl Write,
) -> std::io::Result<()> {
    loop {
        let resp = match read_line_bounded(&mut input, protocol::MAX_LINE_BYTES)? {
            None => return Ok(()),
            Some(BoundedLine::Line(line)) => match state.handle_line(&line) {
                Some(resp) => resp,
                None => continue,
            },
            Some(BoundedLine::Oversized(bytes)) => Response::error(
                protocol::CODE_REQUEST_TOO_LARGE,
                &format!(
                    "request line is {bytes} bytes (cap {})",
                    protocol::MAX_LINE_BYTES
                ),
            )
            .render(None),
        };
        output.write_all(resp.as_bytes())?;
        output.write_all(b"\n")?;
        output.flush()?;
    }
}

/// Accept TCP connections forever, one handler thread per connection,
/// every connection sharing `state` (and therefore the one memo/cache).
/// Each connection speaks the same line protocol as [`serve_lines`] and
/// ends at client EOF or after the `--idle-timeout` read deadline.
/// Accept errors on one connection are logged to stderr and do not take
/// the daemon down.
pub fn serve_tcp(state: Arc<ServeState>, listener: TcpListener) -> std::io::Result<()> {
    serve_tcp_until(state, listener, Arc::new(AtomicBool::new(false)))
}

/// [`serve_tcp`] with a graceful drain: accept connections until
/// `shutdown` flips to `true`, then stop accepting and **join every
/// in-flight handler thread** before returning — accepted requests are
/// answered, never dropped mid-response. The accept loop polls the flag
/// at ~10ms granularity (non-blocking accept), so shutdown latency is
/// the longest in-flight request, not a blocked `accept(2)`.
pub fn serve_tcp_until(
    state: Arc<ServeState>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                // Handler I/O is blocking (with an optional read
                // deadline); only the accept loop polls.
                if let Err(e) = stream.set_nonblocking(false) {
                    eprintln!("serve: connection {peer}: {e}");
                    continue;
                }
                let state = Arc::clone(&state);
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = serve_connection(&state, stream) {
                        eprintln!("serve: connection {peer}: {e}");
                    }
                }));
                handles.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                eprintln!("serve: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn serve_connection(state: &ServeState, stream: TcpStream) -> std::io::Result<()> {
    if let Some(deadline) = state.idle_timeout() {
        stream.set_read_timeout(Some(deadline))?;
    }
    let reader = BufReader::new(stream.try_clone()?);
    match serve_lines(state, reader, stream) {
        // An idle-timeout expiry is a clean close, not a failure: the
        // client simply went silent past the `--idle-timeout` deadline.
        Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => Ok(()),
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn read_all(text: &str, cap: usize) -> Vec<Result<String, usize>> {
        let mut input = Cursor::new(text.as_bytes().to_vec());
        let mut out = Vec::new();
        while let Some(line) = read_line_bounded(&mut input, cap).unwrap() {
            out.push(match line {
                BoundedLine::Line(s) => Ok(s),
                BoundedLine::Oversized(n) => Err(n),
            });
        }
        out
    }

    #[test]
    fn bounded_reader_matches_lines_semantics_within_cap() {
        assert_eq!(
            read_all("a\nbb\r\n\nfinal-no-newline", 64),
            vec![
                Ok("a".to_string()),
                Ok("bb".to_string()),
                Ok(String::new()),
                Ok("final-no-newline".to_string()),
            ]
        );
        assert_eq!(read_all("", 64), Vec::<Result<String, usize>>::new());
    }

    #[test]
    fn oversized_lines_are_drained_not_buffered() {
        let long = "x".repeat(100);
        let text = format!("{long}\nok\n");
        // The oversized line reports its full length and the session
        // resumes cleanly at the next line.
        assert_eq!(read_all(&text, 16), vec![Err(100), Ok("ok".to_string())]);
        // A line exactly at the cap is delivered whole.
        let exact = "y".repeat(16);
        let text = format!("{exact}\n");
        assert_eq!(read_all(&text, 16), vec![Ok(exact)]);
        // One byte over — even without a trailing newline — is refused.
        assert_eq!(read_all(&"z".repeat(17), 16), vec![Err(17)]);
    }

    #[test]
    fn serve_lines_answers_oversized_requests_with_a_stable_code() {
        let state = ServeState::new(&ServeOptions::default()).unwrap();
        let giant = format!("id=r1 cmd=stats pad={}\n", "p".repeat(protocol::MAX_LINE_BYTES));
        let input = Cursor::new(format!("{giant}id=r2 cmd=stats\n").into_bytes());
        let mut out = Vec::new();
        serve_lines(&state, input, &mut out).unwrap();
        let out = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2, "{out}");
        assert!(
            lines[0].contains("ok=false")
                && lines[0].contains(&format!("code={}", protocol::CODE_REQUEST_TOO_LARGE)),
            "{}",
            lines[0]
        );
        assert!(lines[1].starts_with("id=r2 ok=true"), "{}", lines[1]);
    }
}
