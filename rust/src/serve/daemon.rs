//! The daemon state machine: shared caches, per-request evaluators, the
//! budget-driven downgrade ladder, and the request handlers.
//!
//! One [`ServeState`] lives for the whole daemon process. Every request
//! gets a *fresh* [`Evaluator`] over the state's shared
//! [`PlanMemo`] + [`CostCache`], so per-run state (duplicate-cost table,
//! budget) is request-isolated while compiled plans and block costs are
//! shared across requests and connections. Failed or over-budget
//! requests never publish partial state: the memo and cache only ever
//! gain entries from completed compiles/costings.
//!
//! ## The downgrade ladder
//!
//! Optimizer requests (`optimize | sweep | gdf`) descend a deterministic
//! one-way ladder when their [`Budget`] trips:
//!
//! | rung | `level=` | `optimize` | `sweep` | `gdf` |
//! |------|----------|-----------|---------|-------|
//! | 1 | `full`   | backend argmin | full cluster grid | full GDF enumeration |
//! | 2 | `sweep`  | —         | backend argmin | backend argmin |
//! | 3 | `cached` | argmin-table lookup, else un-budgeted default plan | same | same |
//!
//! Rungs are attempted in order; a budget error records its reason code
//! (`deadline` / `candidates`, in the `downgrade=` trail) and drops one
//! rung — never back up. The terminal `cached` rung runs with **no**
//! budget attached, so every request that parses returns a valid plan.
//! The candidate-count check is clock-free and the deadline check with
//! `budget_ms=0` trips before any work, so forced downgrades replay
//! with identical reason codes.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::{
    compile_with_meta, linreg_cg_args, verify_plan, ClusterConfigOpt, CompileOptions,
    CompiledProgram, Scenario, LINREG_CG, LINREG_DS,
};
use crate::artifact::{Artifact, ArgminRow, ArgminTable};
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::cost::cache::{CacheStats, CostCache};
use crate::lop::SelectionHints;
use crate::matrix::Format;
use crate::opt::evaluate::{budget_error_reason, Budget, Candidate, CostContext, Evaluator, PlanMemo};
use crate::opt::gdf::{optimize_with as gdf_optimize_with, GdfSpec};
use crate::opt::sweep::{
    heap_clock_clusters, plan_signature, sweep_with, DataScenario, SweepSpec,
};
use crate::rtprog::ExecBackend;
use crate::serve::protocol::{
    parse_request, peek_id, ReqCmd, ReqScript, Request, Response, CODE_OPTIMIZER_ERROR,
    CODE_UNKNOWN_SCENARIO, DOWNGRADE_NONE, LEVEL_CACHED, LEVEL_FULL, LEVEL_SWEEP,
};
use crate::serve::stats::ServeStats;
use crate::util::par;

/// Daemon startup configuration (`repro serve` flags).
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    /// Evaluator worker threads per request (0 = all cores).
    pub threads: usize,
    /// Keep the shared block-level cost cache (`false` =
    /// `--no-cost-cache`).
    pub no_cost_cache: bool,
    /// Pre-load a [`crate::artifact::CacheSnapshot`] into the shared
    /// cache at boot (`--warm-cache`).
    pub warm_cache: Option<PathBuf>,
    /// Replace the default cost constants with a
    /// [`crate::artifact::CalibrationProfile`]'s (`--profile`).
    pub profile: Option<PathBuf>,
    /// Failure profile every optimizer request is costed under
    /// (`--fault-profile`). The default [`FaultProfile::none`] keeps all
    /// answers bitwise-identical to fault-free costing.
    pub fault: FaultProfile,
    /// Spill the backend-argmin table to this path after every insert
    /// and reload it at boot (`--spill-argmin`). Reloaded keys answer
    /// the terminal ladder rung with `source=persisted`.
    pub spill_argmin: Option<PathBuf>,
    /// Per-connection idle read timeout in milliseconds for TCP serving
    /// (`--idle-timeout`); `0` disables the timeout.
    pub idle_timeout_ms: u64,
}

/// A remembered backend-argmin decision (the terminal ladder rung's
/// lookup table). Only backend-argmin rungs (`optimize` full, the
/// `sweep` fallback rung) write entries — their semantics are uniform:
/// best backend for one scenario × script × iteration count on the
/// default configuration.
#[derive(Clone, Copy, Debug)]
struct ArgminEntry {
    backend: ExecBackend,
    cost_secs: f64,
    cp: usize,
    mr: usize,
    spark: usize,
    /// Whether the entry was reloaded from a `--spill-argmin` artifact
    /// rather than decided by this process (`source=persisted`).
    persisted: bool,
}

/// Long-lived, shareable daemon state: one compile memo, one cost
/// cache, one calibrated constants set, and the observability counters.
pub struct ServeState {
    memo: Arc<PlanMemo>,
    cache: Option<Arc<CostCache>>,
    constants: CostConstants,
    fault: FaultProfile,
    spill: Option<PathBuf>,
    persisted_entries: usize,
    idle_timeout_ms: u64,
    threads: usize,
    warm_entries: usize,
    calibrated: bool,
    stats: Mutex<ServeStats>,
    argmins: Mutex<HashMap<String, ArgminEntry>>,
}

impl ServeState {
    /// Boot the daemon state, loading `--warm-cache` / `--profile`
    /// artifacts (checksummed, regenerate-don't-trust — see
    /// [`crate::artifact`]).
    pub fn new(opts: &ServeOptions) -> Result<ServeState, String> {
        let threads =
            if opts.threads == 0 { par::default_threads() } else { opts.threads };
        let mut warm_entries = 0usize;
        let cache = if opts.no_cost_cache {
            if opts.warm_cache.is_some() {
                return Err("--warm-cache: incompatible with --no-cost-cache".into());
            }
            None
        } else {
            match &opts.warm_cache {
                None => Some(Arc::new(CostCache::default())),
                Some(path) => match crate::api::load_artifact(path)? {
                    Artifact::CacheSnapshot(snap) => {
                        warm_entries = snap.len();
                        Some(snap.into_cache())
                    }
                    other => {
                        return Err(format!(
                            "--warm-cache: {} holds a '{}' artifact, expected 'costcache'",
                            path.display(),
                            other.kind()
                        ))
                    }
                },
            }
        };
        let (constants, calibrated) = match &opts.profile {
            None => (CostConstants::default(), false),
            Some(path) => match crate::api::load_artifact(path)? {
                Artifact::Profile(p) => (p.constants().clone(), true),
                other => {
                    return Err(format!(
                        "--profile: {} holds a '{}' artifact, expected 'profile'",
                        path.display(),
                        other.kind()
                    ))
                }
            },
        };
        opts.fault
            .validate()
            .map_err(|e| format!("--fault-profile: {e}"))?;
        // Reload a spilled argmin table, regenerate-don't-trust: a
        // missing file is a cold start, a table decided under different
        // constants or a different failure profile is discarded (its
        // decisions would be priced wrong, not just stale), and any
        // other artifact kind at the path is a hard boot error.
        let mut argmins: HashMap<String, ArgminEntry> = HashMap::new();
        let mut persisted_entries = 0usize;
        if let Some(path) = &opts.spill_argmin {
            if path.exists() {
                match crate::api::load_artifact(path)? {
                    Artifact::Argmin(table) => {
                        if table.context_matches(&constants, &opts.fault) {
                            for row in &table.rows {
                                argmins.insert(
                                    row.key.clone(),
                                    ArgminEntry {
                                        backend: row.backend,
                                        cost_secs: row.cost_secs,
                                        cp: row.cp,
                                        mr: row.mr,
                                        spark: row.spark,
                                        persisted: true,
                                    },
                                );
                            }
                            persisted_entries = argmins.len();
                        }
                    }
                    other => {
                        return Err(format!(
                            "--spill-argmin: {} holds a '{}' artifact, expected 'argmin'",
                            path.display(),
                            other.kind()
                        ))
                    }
                }
            }
        }
        Ok(ServeState {
            memo: Arc::new(PlanMemo::new()),
            cache,
            constants,
            fault: opts.fault.clone(),
            spill: opts.spill_argmin.clone(),
            persisted_entries,
            idle_timeout_ms: opts.idle_timeout_ms,
            threads,
            warm_entries,
            calibrated,
            stats: Mutex::new(ServeStats::default()),
            argmins: Mutex::new(argmins),
        })
    }

    /// One-line boot banner (stderr, so stdout stays pure protocol).
    pub fn boot_summary(&self) -> String {
        let mut banner = format!(
            "serve: ready threads={} cache={} constants={}",
            self.threads,
            match (&self.cache, self.warm_entries) {
                (None, _) => "off".to_string(),
                (Some(_), 0) => "on".to_string(),
                (Some(_), n) => format!("on(warm={n})"),
            },
            if self.calibrated { "calibrated" } else { "default" }
        );
        if !self.fault.is_none() {
            banner.push_str(" fault=on");
        }
        if self.spill.is_some() {
            banner.push_str(&format!(" argmin=persisted({})", self.persisted_entries));
        }
        banner
    }

    /// The shared cost cache (`None` under `--no-cost-cache`).
    pub fn cache(&self) -> Option<Arc<CostCache>> {
        self.cache.clone()
    }

    /// Per-connection idle read timeout (`--idle-timeout`), or `None`
    /// when disabled (`0`). Transport code applies this to sockets so a
    /// silent client cannot pin a handler thread forever.
    pub fn idle_timeout(&self) -> Option<std::time::Duration> {
        if self.idle_timeout_ms == 0 {
            None
        } else {
            Some(std::time::Duration::from_millis(self.idle_timeout_ms))
        }
    }

    /// Absolute shared-cache counters (zeros when caching is off).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_deref().map(CostCache::stats).unwrap_or_default()
    }

    /// The shared compile memo.
    pub fn memo(&self) -> Arc<PlanMemo> {
        Arc::clone(&self.memo)
    }

    /// Snapshot of the observability counters.
    pub fn stats_snapshot(&self) -> ServeStats {
        self.lock_stats().clone()
    }

    fn lock_stats(&self) -> std::sync::MutexGuard<'_, ServeStats> {
        self.stats.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_argmins(&self) -> std::sync::MutexGuard<'_, HashMap<String, ArgminEntry>> {
        self.argmins.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// A fresh per-request evaluator over the shared memo + cache.
    fn evaluator(&self) -> Evaluator {
        Evaluator::with_parts(self.threads, Arc::clone(&self.memo), self.cache.clone())
    }

    /// Handle one raw input line. Returns the rendered response line, or
    /// `None` for blank lines and `#` comments.
    pub fn handle_line(&self, line: &str) -> Option<String> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return None;
        }
        let t0 = Instant::now();
        let id = peek_id(line);
        let (cmd, resp, reasons) = match parse_request(line) {
            Err(e) => (None, Response::error(e.code, &e.detail), Vec::new()),
            Ok(req) => {
                let (resp, reasons) = self.answer(&req);
                (Some(req.cmd), resp, reasons)
            }
        };
        let ok = resp.get("ok") == Some("true");
        let us = t0.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
        self.lock_stats().record(cmd, ok, &reasons, us);
        Some(resp.render(id.as_deref()))
    }

    /// Dispatch one parsed request; returns the response plus the
    /// downgrade-reason trail (for the stats counters).
    fn answer(&self, req: &Request) -> (Response, Vec<&'static str>) {
        match req.cmd {
            ReqCmd::Stats => (self.stats_response(), Vec::new()),
            ReqCmd::Verify => (self.verify_response(req), Vec::new()),
            ReqCmd::Optimize | ReqCmd::Sweep | ReqCmd::Gdf => self.ladder(req),
        }
    }

    // -----------------------------------------------------------------
    // The downgrade ladder
    // -----------------------------------------------------------------

    fn ladder(&self, req: &Request) -> (Response, Vec<&'static str>) {
        let Some(scenario) = self.scenario_of(req) else {
            let detail =
                format!("unknown scenario '{}'", req.scenario.as_deref().unwrap_or(""));
            return (Response::error(CODE_UNKNOWN_SCENARIO, &detail), Vec::new());
        };
        let budget = (req.budget_ms.is_some() || req.budget_candidates.is_some())
            .then(|| Budget::new(req.budget_ms, req.budget_candidates));
        let mut eval = self.evaluator();
        eval.set_budget(budget);
        let mut reasons: Vec<&'static str> = Vec::new();

        // Rung 1: full fidelity.
        let full = match req.cmd {
            ReqCmd::Optimize => self
                .backend_argmin(req, &scenario, &mut eval)
                .map(|a| self.argmin_response(req, &scenario, LEVEL_FULL, &[], a)),
            ReqCmd::Sweep => self.full_sweep(req, &scenario, &mut eval),
            ReqCmd::Gdf => self.full_gdf(req, &scenario, &mut eval),
            _ => unreachable!("ladder only handles optimizer requests"),
        };
        match full {
            Ok(resp) => return (resp, reasons),
            Err(e) => match budget_error_reason(&e) {
                Some(r) => reasons.push(r),
                None => return (Response::error(CODE_OPTIMIZER_ERROR, &e), reasons),
            },
        }

        // Rung 2: backend argmin (sweep/gdf only — it *is* rung 1 for
        // optimize requests).
        if req.cmd != ReqCmd::Optimize {
            match self.backend_argmin(req, &scenario, &mut eval) {
                Ok(a) => {
                    let resp =
                        self.argmin_response(req, &scenario, LEVEL_SWEEP, &reasons, a);
                    return (resp, reasons);
                }
                Err(e) => match budget_error_reason(&e) {
                    Some(r) => reasons.push(r),
                    None => return (Response::error(CODE_OPTIMIZER_ERROR, &e), reasons),
                },
            }
        }

        // Rung 3: cached argmin — never budgeted, always answers.
        eval.set_budget(None);
        match self.cached_answer(req, &scenario, &mut eval) {
            Ok(resp) => (resp, reasons),
            Err(e) => (Response::error(CODE_OPTIMIZER_ERROR, &e), reasons),
        }
    }

    fn scenario_of(&self, req: &Request) -> Option<Scenario> {
        let name = req.scenario.as_deref()?;
        Scenario::all().into_iter().find(|s| s.name.eq_ignore_ascii_case(name))
    }

    fn argmin_key(req: &Request, scenario: &Scenario) -> String {
        let iters = match req.script {
            ReqScript::Ds => 0,
            ReqScript::Cg => req.iters,
        };
        format!("{}|{}|{}", scenario.name, req.script.name(), iters)
    }

    /// Evaluate the three backends of one scenario on the default
    /// configuration and return the argmin (ties break toward the
    /// CP → MR → Spark enumeration order).
    fn backend_argmin(
        &self,
        req: &Request,
        scenario: &Scenario,
        eval: &mut Evaluator,
    ) -> Result<ArgminEntry, String> {
        let (script, args) = script_and_args(req);
        let dscen = DataScenario::from(scenario);
        let cands: Vec<BackendCand> = ExecBackend::all()
            .into_iter()
            .map(|backend| BackendCand {
                script,
                args: args.clone(),
                scenario: dscen.clone(),
                backend,
                cfg: SystemConfig::default(),
                cc: ClusterConfig::paper_cluster(),
                hints: SelectionHints::default(),
                constants: self.constants.clone(),
                fault: self.fault.clone(),
            })
            .collect();
        eval.begin_run();
        let evaluated = eval.evaluate(&cands)?;
        let best = (0..evaluated.len())
            .min_by(|&a, &b| evaluated[a].cost_secs.total_cmp(&evaluated[b].cost_secs))
            .expect("three backends evaluated");
        let ev = &evaluated[best];
        let entry = ArgminEntry {
            backend: cands[best].backend,
            cost_secs: ev.cost_secs,
            cp: ev.cp_insts,
            mr: ev.mr_jobs,
            spark: ev.spark_jobs,
            persisted: false,
        };
        self.lock_argmins().insert(Self::argmin_key(req, scenario), entry);
        self.spill_argmins();
        Ok(entry)
    }

    /// Spill the argmin table to the `--spill-argmin` path (atomic
    /// tmp+rename). Fail-soft: the decision was already made and the
    /// response must still go out, so a spill error is reported on
    /// stderr instead of failing the request — the next insert retries.
    fn spill_argmins(&self) {
        let Some(path) = &self.spill else { return };
        let rows: Vec<ArgminRow> = self
            .lock_argmins()
            .iter()
            .map(|(key, e)| ArgminRow {
                key: key.clone(),
                backend: e.backend,
                cost_secs: e.cost_secs,
                cp: e.cp,
                mr: e.mr,
                spark: e.spark,
            })
            .collect();
        let table = ArgminTable::new(self.constants.clone(), self.fault.clone(), rows);
        if let Err(e) = crate::artifact::save(path, &Artifact::Argmin(table)) {
            eprintln!("serve: argmin spill failed: {e}");
        }
    }

    fn argmin_response(
        &self,
        req: &Request,
        scenario: &Scenario,
        level: &'static str,
        reasons: &[&'static str],
        a: ArgminEntry,
    ) -> Response {
        let mut r = self.response_head(req, scenario, level, reasons);
        r.push("backend", a.backend.name());
        r.push_cost("cost", a.cost_secs);
        r.push("cp", a.cp.to_string());
        r.push("mr", a.mr.to_string());
        r.push("spark", a.spark.to_string());
        r
    }

    fn full_sweep(
        &self,
        req: &Request,
        scenario: &Scenario,
        eval: &mut Evaluator,
    ) -> Result<Response, String> {
        let (script, args) = script_and_args(req);
        let spec = SweepSpec {
            script: script.to_string(),
            args,
            clusters: heap_clock_clusters(&req.heaps),
            scenarios: vec![DataScenario::from(scenario)],
            cfg: SystemConfig::default(),
            hints: SelectionHints::default(),
            constants: self.constants.clone(),
            fault: self.fault.clone(),
            backends: ExecBackend::all().to_vec(),
            cost_cache: true,
            threads: self.threads,
            verify: false,
        };
        let report = sweep_with(&spec, eval)?;
        let best = &report.cells[report.ranking[0]];
        let mut r = self.response_head(req, scenario, LEVEL_FULL, &[]);
        r.push("cells", report.cells.len().to_string());
        r.push("best_cluster", &best.cluster);
        r.push("backend", &best.backend);
        r.push_cost("cost", best.cost_secs);
        r.push("cp", best.cp_insts.to_string());
        r.push("mr", best.mr_jobs.to_string());
        r.push("spark", best.spark_jobs.to_string());
        Ok(r)
    }

    fn full_gdf(
        &self,
        req: &Request,
        scenario: &Scenario,
        eval: &mut Evaluator,
    ) -> Result<Response, String> {
        let dscen = DataScenario::from(scenario);
        let mut spec = match req.script {
            ReqScript::Cg => GdfSpec::linreg_cg(dscen, req.iters),
            ReqScript::Ds => GdfSpec::new(LINREG_DS, scenario.args(), dscen),
        };
        spec.constants = self.constants.clone();
        spec.fault = self.fault.clone();
        spec.threads = self.threads;
        let report = gdf_optimize_with(&spec, eval)?;
        let best = report.best();
        let mut r = self.response_head(req, scenario, LEVEL_FULL, &[]);
        r.push("candidates", report.candidates.len().to_string());
        r.push("blocksize", best.blocksize.to_string());
        r.push("format", best.format.name());
        r.push("partition_mb", fmt_mb_axis(best.partition_mb));
        r.push(
            "groups",
            best.groups.iter().map(|b| b.name()).collect::<Vec<_>>().join(","),
        );
        r.push_cost("cost", best.cost_secs);
        r.push("improvement_pct", format!("{:.2}", report.improvement_pct()));
        Ok(r)
    }

    /// The terminal rung: answer from the argmin table when this
    /// scenario × script × iters was decided before, else compile and
    /// cost the single default-backend plan — with no budget attached,
    /// so it always completes.
    fn cached_answer(
        &self,
        req: &Request,
        scenario: &Scenario,
        eval: &mut Evaluator,
    ) -> Result<Response, String> {
        let (source, entry) =
            match self.lock_argmins().get(&Self::argmin_key(req, scenario)).copied() {
                Some(entry) if entry.persisted => ("persisted", entry),
                Some(entry) => ("argmin-table", entry),
                None => ("default-plan", self.default_plan(req, scenario, eval)?),
            };
        let reasons: Vec<&'static str> = Vec::new();
        let mut r = self.response_head(req, scenario, LEVEL_CACHED, &reasons);
        r.push("source", source);
        r.push("backend", entry.backend.name());
        r.push("blocksize", SystemConfig::default().blocksize.to_string());
        r.push("format", Format::BinaryBlock.name());
        r.push_cost("cost", entry.cost_secs);
        r.push("cp", entry.cp.to_string());
        r.push("mr", entry.mr.to_string());
        r.push("spark", entry.spark.to_string());
        Ok(r)
    }

    fn default_plan(
        &self,
        req: &Request,
        scenario: &Scenario,
        eval: &mut Evaluator,
    ) -> Result<ArgminEntry, String> {
        let (script, args) = script_and_args(req);
        let cand = BackendCand {
            script,
            args,
            scenario: DataScenario::from(scenario),
            backend: ExecBackend::Mr,
            cfg: SystemConfig::default(),
            cc: ClusterConfig::paper_cluster(),
            hints: SelectionHints::default(),
            constants: self.constants.clone(),
            fault: self.fault.clone(),
        };
        eval.begin_run();
        let evaluated = eval.evaluate(std::slice::from_ref(&cand))?;
        let ev = &evaluated[0];
        Ok(ArgminEntry {
            backend: cand.backend,
            cost_secs: ev.cost_secs,
            cp: ev.cp_insts,
            mr: ev.mr_jobs,
            spark: ev.spark_jobs,
            persisted: false,
        })
    }

    /// Common response prefix: ladder level, downgrade trail, request
    /// echo. All fields here are bitwise deterministic across thread
    /// counts and interleavings (wall-clock and cache counters live in
    /// `stats` only).
    fn response_head(
        &self,
        req: &Request,
        scenario: &Scenario,
        level: &'static str,
        reasons: &[&'static str],
    ) -> Response {
        let mut r = Response::ok(req.cmd);
        r.push("level", level);
        r.push(
            "downgrade",
            if reasons.is_empty() { DOWNGRADE_NONE.to_string() } else { reasons.join(",") },
        );
        r.push("scenario", scenario.name);
        r.push("script", req.script.name());
        if req.script == ReqScript::Cg {
            r.push("iters", req.iters.to_string());
        }
        r
    }

    // -----------------------------------------------------------------
    // verify + stats
    // -----------------------------------------------------------------

    fn verify_response(&self, req: &Request) -> Response {
        let Some(scenario) = self.scenario_of(req) else {
            let detail =
                format!("unknown scenario '{}'", req.scenario.as_deref().unwrap_or(""));
            return Response::error(CODE_UNKNOWN_SCENARIO, &detail);
        };
        let backend = req.backend.unwrap_or(ExecBackend::Mr);
        let compiled = match self.compile_default(req, &scenario, backend) {
            Ok(c) => c,
            Err(e) => return Response::error(CODE_OPTIMIZER_ERROR, &e),
        };
        let opts = CompileOptions { backend, ..Default::default() };
        let report = verify_plan(&compiled, &opts);
        let mut r = self.response_head(req, &scenario, LEVEL_FULL, &[]);
        r.push("backend", backend.name());
        r.push("blocks", report.blocks.to_string());
        r.push("diagnostics", report.diagnostics.len().to_string());
        r.push("errors", report.errors().to_string());
        r.push("warnings", report.warnings().to_string());
        r.push("clean", if report.is_clean() { "true" } else { "false" });
        r
    }

    fn compile_default(
        &self,
        req: &Request,
        scenario: &Scenario,
        backend: ExecBackend,
    ) -> Result<CompiledProgram, String> {
        let (script, args) = script_and_args(req);
        let opts = CompileOptions {
            backend,
            cc: ClusterConfigOpt(ClusterConfig::paper_cluster()),
            ..Default::default()
        };
        compile_with_meta(script, &args, &scenario.meta(opts.cfg.blocksize), &opts)
    }

    /// `stats` never touches the optimizers; its counters describe the
    /// requests handled *before* it (the stats request itself is
    /// recorded after its response is built).
    fn stats_response(&self) -> Response {
        let stats = self.stats_snapshot();
        let cache = self.cache_stats();
        let mut r = Response::ok(ReqCmd::Stats);
        r.push("downgrade", DOWNGRADE_NONE);
        r.push("requests", stats.requests.to_string());
        r.push("served", stats.ok.to_string());
        r.push("errors", stats.errors.to_string());
        for cmd in ReqCmd::ALL {
            r.push(cmd.name(), stats.by_cmd[cmd.index()].to_string());
        }
        r.push("downgraded", stats.downgraded.to_string());
        r.push("downgrade_deadline", stats.downgrade_deadline.to_string());
        r.push("downgrade_candidates", stats.downgrade_candidates.to_string());
        r.push("cache_hits", cache.hits.to_string());
        r.push("cache_misses", cache.misses.to_string());
        r.push("cache_hit_rate", format!("{:.3}", cache.hit_rate()));
        r.push("cache_entries", cache.entries.to_string());
        r.push("distinct_plans", self.memo.distinct().to_string());
        r.push("argmin_entries", self.lock_argmins().len().to_string());
        r.push("p50_us", stats.latency_percentile_us(50.0).to_string());
        r.push("p99_us", stats.latency_percentile_us(99.0).to_string());
        r.push("threads", self.threads.to_string());
        r
    }
}

/// The bundled script + `$N` bindings a request targets.
fn script_and_args(req: &Request) -> (&'static str, HashMap<usize, String>) {
    match req.script {
        ReqScript::Ds => (LINREG_DS, Scenario::xs().args()),
        ReqScript::Cg => (LINREG_CG, linreg_cg_args(req.iters)),
    }
}

/// Megabyte axis rendering that keeps fractional entries (`32`, `0.5`).
fn fmt_mb_axis(mb: f64) -> String {
    if mb.fract() == 0.0 {
        format!("{}", mb as i64)
    } else {
        format!("{mb}")
    }
}

/// One scenario × backend on the default configuration, viewed as an
/// evaluator candidate — the serve-side adapter behind the backend
/// argmin and default-plan rungs.
struct BackendCand {
    script: &'static str,
    args: HashMap<usize, String>,
    scenario: DataScenario,
    backend: ExecBackend,
    cfg: SystemConfig,
    cc: ClusterConfig,
    hints: SelectionHints,
    constants: CostConstants,
    fault: FaultProfile,
}

impl Candidate for BackendCand {
    fn signature(&self) -> String {
        plan_signature(
            self.script,
            &self.args,
            &self.cfg,
            &self.hints,
            &self.cc,
            &self.scenario,
            self.backend,
        )
    }
    fn compile(&self) -> Result<CompiledProgram, String> {
        let opts = CompileOptions {
            cfg: self.cfg.clone(),
            cc: ClusterConfigOpt(self.cc.clone()),
            hints: self.hints.clone(),
            backend: self.backend,
        };
        compile_with_meta(
            self.script,
            &self.args,
            &self.scenario.meta(self.cfg.blocksize),
            &opts,
        )
    }
    fn context(&self) -> CostContext<'_> {
        CostContext {
            cfg: &self.cfg,
            cc: &self.cc,
            constants: &self.constants,
            fault: &self.fault,
        }
    }
    fn label(&self) -> String {
        format!("{}@{}", self.scenario.name, self.backend.name())
    }
}
