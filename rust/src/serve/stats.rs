//! Serve-side observability counters: request/outcome totals, downgrade
//! reasons, and a bounded latency reservoir for p50/p99.

use crate::serve::protocol::ReqCmd;

/// Latency samples kept (ring buffer — old samples are overwritten once
/// the daemon has served this many requests).
const LATENCY_CAP: usize = 65_536;

/// Mutable counter state behind the daemon's stats mutex.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Total requests handled (including failed ones).
    pub requests: u64,
    /// Requests answered `ok=true`.
    pub ok: u64,
    /// Requests answered `ok=false`.
    pub errors: u64,
    /// Per-command totals, indexed by [`ReqCmd::index`] (parse failures
    /// with no recognizable command count toward none of them).
    pub by_cmd: [u64; 5],
    /// Requests that were answered below full fidelity.
    pub downgraded: u64,
    /// Downgrade steps taken because the wall-clock budget expired.
    pub downgrade_deadline: u64,
    /// Downgrade steps taken because the candidate budget was exceeded.
    pub downgrade_candidates: u64,
    lat_us: Vec<u64>,
    lat_pos: usize,
}

impl ServeStats {
    /// Record one handled request: its command (when the line parsed
    /// far enough to know it), outcome, downgrade-reason trail and
    /// handling latency.
    pub fn record(&mut self, cmd: Option<ReqCmd>, ok: bool, reasons: &[&str], latency_us: u64) {
        self.requests += 1;
        if ok {
            self.ok += 1;
        } else {
            self.errors += 1;
        }
        if let Some(cmd) = cmd {
            self.by_cmd[cmd.index()] += 1;
        }
        if !reasons.is_empty() {
            self.downgraded += 1;
        }
        for r in reasons {
            match *r {
                "deadline" => self.downgrade_deadline += 1,
                "candidates" => self.downgrade_candidates += 1,
                _ => {}
            }
        }
        if self.lat_us.len() < LATENCY_CAP {
            self.lat_us.push(latency_us);
        } else {
            self.lat_us[self.lat_pos] = latency_us;
            self.lat_pos = (self.lat_pos + 1) % LATENCY_CAP;
        }
    }

    /// Nearest-rank latency percentile in microseconds over the
    /// retained reservoir (0 when nothing was recorded yet). `p` is in
    /// percent, e.g. `50.0` or `99.0`.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        if self.lat_us.is_empty() {
            return 0;
        }
        let mut sorted = self.lat_us.clone();
        sorted.sort_unstable();
        let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
        sorted[rank.clamp(1, sorted.len()) - 1]
    }

    /// Latency samples currently retained.
    pub fn latency_samples(&self) -> usize {
        self.lat_us.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut s = ServeStats::default();
        s.record(Some(ReqCmd::Optimize), true, &[], 100);
        s.record(Some(ReqCmd::Gdf), true, &["deadline", "deadline"], 300);
        s.record(None, false, &[], 10);
        assert_eq!(s.requests, 3);
        assert_eq!(s.ok, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.by_cmd[ReqCmd::Optimize.index()], 1);
        assert_eq!(s.by_cmd[ReqCmd::Gdf.index()], 1);
        assert_eq!(s.downgraded, 1);
        assert_eq!(s.downgrade_deadline, 2);
        assert_eq!(s.downgrade_candidates, 0);
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let mut s = ServeStats::default();
        for v in [50u64, 10, 40, 20, 30] {
            s.record(None, true, &[], v);
        }
        assert_eq!(s.latency_percentile_us(50.0), 30);
        assert_eq!(s.latency_percentile_us(99.0), 50);
        assert_eq!(ServeStats::default().latency_percentile_us(50.0), 0);
    }
}
