//! CP (control program) runtime: symbol table, matrix objects with lazy
//! IO through a size-bounded buffer pool, and the instruction interpreter
//! in [`interp`].

pub mod bufferpool;
pub mod interp;

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::error::{anyhow, Result};

use crate::ir::Lit;
use crate::matrix::{io, DenseMatrix, Format, MatrixCharacteristics};
use bufferpool::BufferPool;

/// A matrix variable: metadata plus a data key into the buffer pool and an
/// optional backing file (persistent input or eviction file).
#[derive(Clone, Debug)]
pub struct MatrixObject {
    /// Buffer-pool key shared between aliases (cpvar).
    pub key: String,
    pub mc: MatrixCharacteristics,
    pub format: Format,
    /// Backing file to (re)load from.
    pub path: Option<String>,
}

/// Runtime values.
#[derive(Clone, Debug)]
pub enum Value {
    Matrix(MatrixObject),
    Scalar(Lit),
}

impl Value {
    pub fn as_scalar(&self) -> Result<&Lit> {
        match self {
            Value::Scalar(l) => Ok(l),
            Value::Matrix(m) => Err(anyhow!("expected scalar, found matrix {}", m.key)),
        }
    }
}

/// Symbol table of live variables.
#[derive(Clone, Debug, Default)]
pub struct SymbolTable {
    pub vars: HashMap<String, Value>,
}

impl SymbolTable {
    pub fn set(&mut self, name: &str, v: Value) {
        self.vars.insert(name.to_string(), v);
    }

    pub fn get(&self, name: &str) -> Result<&Value> {
        self.vars.get(name).ok_or_else(|| anyhow!("undefined variable '{name}'"))
    }

    pub fn remove(&mut self, name: &str) {
        self.vars.remove(name);
    }

    pub fn matrix(&self, name: &str) -> Result<&MatrixObject> {
        match self.get(name)? {
            Value::Matrix(m) => Ok(m),
            Value::Scalar(_) => Err(anyhow!("variable '{name}' is a scalar, expected matrix")),
        }
    }

    /// Fetch matrix data, reading from the backing file if not pooled.
    pub fn matrix_data(&self, name: &str, pool: &mut BufferPool) -> Result<Arc<DenseMatrix>> {
        let obj = self.matrix(name)?.clone();
        if let Some(data) = pool.get(&obj.key) {
            return Ok(data);
        }
        let path = obj
            .path
            .clone()
            .or_else(|| pool.eviction_path(&obj.key))
            .ok_or_else(|| anyhow!("no data for matrix '{name}' (key {})", obj.key))?;
        let data = Arc::new(io::read_matrix(&path)?);
        pool.put(&obj.key, data.clone())?;
        Ok(data)
    }

    /// Store freshly computed data for a matrix variable.
    pub fn bind_matrix(
        &mut self,
        name: &str,
        data: Arc<DenseMatrix>,
        blocksize: i64,
        pool: &mut BufferPool,
    ) -> Result<()> {
        let mc = data.characteristics_of(blocksize);
        // reuse the declared key if createvar ran before, else derive one
        let key = match self.vars.get(name) {
            Some(Value::Matrix(m)) => m.key.clone(),
            _ => format!("data_{name}_{}", pool.fresh_id()),
        };
        pool.put(&key, data)?;
        self.set(
            name,
            Value::Matrix(MatrixObject { key, mc, format: Format::BinaryBlock, path: None }),
        );
        Ok(())
    }
}

/// Helper trait naming mismatch avoidance.
trait Characteristics {
    fn characteristics_of(&self, blocksize: i64) -> MatrixCharacteristics;
}

impl Characteristics for DenseMatrix {
    fn characteristics_of(&self, blocksize: i64) -> MatrixCharacteristics {
        MatrixCharacteristics::new(self.rows as i64, self.cols as i64, blocksize, self.nnz() as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symbol_table_scalar_roundtrip() {
        let mut s = SymbolTable::default();
        s.set("x", Value::Scalar(Lit::Int(42)));
        assert_eq!(s.get("x").unwrap().as_scalar().unwrap(), &Lit::Int(42));
        assert!(s.matrix("x").is_err());
        s.remove("x");
        assert!(s.get("x").is_err());
    }

    #[test]
    fn bind_and_fetch_matrix() {
        let mut s = SymbolTable::default();
        let mut pool = BufferPool::new(1 << 30, std::env::temp_dir().join("sysds_pool_t1"));
        let m = Arc::new(DenseMatrix::rand(10, 10, 0.0, 1.0, 1.0, 1));
        s.bind_matrix("A", m.clone(), 1000, &mut pool).unwrap();
        let got = s.matrix_data("A", &mut pool).unwrap();
        assert_eq!(&*got, &*m);
        assert_eq!(s.matrix("A").unwrap().mc.rows, 10);
    }

    #[test]
    fn lazy_read_from_file() {
        let dir = std::env::temp_dir().join(format!("sysds_cp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m1").to_string_lossy().to_string();
        let m = DenseMatrix::rand(20, 5, -1.0, 1.0, 1.0, 3);
        io::write_binary_block(&path, &m, 10).unwrap();
        let mut s = SymbolTable::default();
        s.set(
            "X",
            Value::Matrix(MatrixObject {
                key: "k1".into(),
                mc: MatrixCharacteristics::dense(20, 5, 10),
                format: Format::BinaryBlock,
                path: Some(path),
            }),
        );
        let mut pool = BufferPool::new(1 << 30, dir.join("scratch"));
        let got = s.matrix_data("X", &mut pool).unwrap();
        assert_eq!(&*got, &m);
        // second fetch comes from the pool
        assert!(pool.get("k1").is_some());
    }
}
