//! Runtime-program interpreter: executes the hybrid CP/MR plan. CP
//! instructions run in-process (hot ops dispatch to AOT-compiled PJRT
//! kernels when an artifact matches, else the native Rust kernels); MR-job
//! instructions run on the deterministic MapReduce simulator.

use std::sync::Arc;
use std::time::Instant;

use crate::util::error::{anyhow, bail, Context, Result};

use super::bufferpool::BufferPool;
use super::{MatrixObject, SymbolTable, Value};
use crate::conf::{ClusterConfig, SystemConfig};
use crate::ir::{AggDir, AggOp, BinOp, Lit, UnOp};
use crate::matrix::{io, ops, DenseMatrix, Format};
use crate::mr;
use crate::rtprog::*;
use crate::runtime::{kernel_key, KernelRegistry};

/// Execution statistics.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub cp_insts: usize,
    pub mr_jobs: usize,
    pub map_tasks: usize,
    pub shuffle_bytes: f64,
    pub hdfs_read_bytes: f64,
    pub hdfs_write_bytes: f64,
    pub pjrt_calls: usize,
    pub pool_evictions: usize,
    pub elapsed_secs: f64,
    /// Injected map-task attempts that failed and were re-executed
    /// (zero unless fault injection is armed; see
    /// [`Executor::set_fault_injection`]).
    pub failed_attempts: usize,
    /// Injected straggler tasks.
    pub straggler_tasks: usize,
    /// Speculative backup copies launched for stragglers.
    pub speculative_copies: usize,
    /// Simulated seconds of retry backoff and straggler tail accrued to
    /// the delay ledger (accounted, never slept).
    pub fault_delay_secs: f64,
}

/// The interpreter.
pub struct Executor<'a> {
    pub cfg: &'a SystemConfig,
    pub cc: &'a ClusterConfig,
    pub registry: Option<&'a KernelRegistry>,
    pub pool: BufferPool,
    pub symbols: SymbolTable,
    pub stats: ExecStats,
    funcs: std::collections::BTreeMap<String, RtFunction>,
    threads: usize,
    /// Adaptive PJRT-vs-native dispatch decisions per kernel key.
    dispatch: std::collections::HashMap<String, bool>,
    /// Fault-injection profile (none = faithful execution).
    pub(crate) fault: crate::conf::FaultProfile,
    /// Base seed of the counter-mode fault RNG (see
    /// [`crate::util::rng::fault_roll`]).
    pub(crate) fault_seed: u64,
    /// Monotone per-run distributed-job counter: the `job` key of the
    /// fault RNG, so replays are bitwise-stable for a fixed seed no
    /// matter how `--threads` schedules the task pool.
    pub(crate) fault_jobs: u64,
    /// Whether the job currently being simulated came from a Spark
    /// instruction (selects `spark_fail_p` over `mr_fail_p`).
    pub(crate) fault_spark: bool,
}

impl<'a> Executor<'a> {
    pub fn new(
        cfg: &'a SystemConfig,
        cc: &'a ClusterConfig,
        registry: Option<&'a KernelRegistry>,
        scratch: std::path::PathBuf,
    ) -> Self {
        let capacity = (cfg.mem_budget_ratio * cc.cp_heap_bytes) as usize;
        Executor {
            cfg,
            cc,
            registry,
            pool: BufferPool::new(capacity, scratch),
            symbols: SymbolTable::default(),
            stats: ExecStats::default(),
            funcs: Default::default(),
            threads: cc.k_local.max(1),
            dispatch: Default::default(),
            fault: crate::conf::FaultProfile::none(),
            fault_seed: 0,
            fault_jobs: 0,
            fault_spark: false,
        }
    }

    /// Arm deterministic fault injection: every subsequent distributed
    /// job draws task failures and stragglers from the counter-mode RNG
    /// keyed `(seed, job, task, attempt)` — bitwise-identical schedules
    /// for a fixed seed across thread counts and re-runs. Pass
    /// [`crate::conf::FaultProfile::none`] to disarm.
    pub fn set_fault_injection(&mut self, profile: crate::conf::FaultProfile, seed: u64) {
        self.fault = profile;
        self.fault_seed = seed;
        self.fault_jobs = 0;
    }

    /// Execute a whole runtime program; returns the stats.
    pub fn run(&mut self, rt: &RtProgram) -> Result<ExecStats> {
        self.funcs = rt.funcs.clone();
        let t0 = Instant::now();
        self.exec_blocks(&rt.blocks)?;
        self.stats.elapsed_secs =
            t0.elapsed().as_secs_f64() + self.stats.fault_delay_secs;
        self.stats.pool_evictions = self.pool.evictions;
        Ok(self.stats.clone())
    }

    /// Execute a whole runtime program like [`Executor::run`], additionally
    /// timing each top-level block: the returned vector is aligned
    /// one-to-one with `rt.blocks` (and therefore with the per-block
    /// [`crate::cost::CostReport`] nodes and the structural block hashes of
    /// [`crate::cost::cache::program_hashes`]). This is the measurement
    /// feed for the `crate::feedback` calibration loop.
    pub fn run_instrumented(&mut self, rt: &RtProgram) -> Result<(ExecStats, Vec<f64>)> {
        self.funcs = rt.funcs.clone();
        let t0 = Instant::now();
        let mut block_secs = Vec::with_capacity(rt.blocks.len());
        for b in &rt.blocks {
            let tb = Instant::now();
            let ledger0 = self.stats.fault_delay_secs;
            self.exec_block(b)?;
            // Injected retry backoff is accounted, never slept: fold the
            // block's ledger delta into its measured wall time so the
            // calibration loop sees what a real cluster would have
            // waited (zero when fault injection is disarmed).
            block_secs.push(
                tb.elapsed().as_secs_f64() + (self.stats.fault_delay_secs - ledger0),
            );
        }
        self.stats.elapsed_secs =
            t0.elapsed().as_secs_f64() + self.stats.fault_delay_secs;
        self.stats.pool_evictions = self.pool.evictions;
        Ok((self.stats.clone(), block_secs))
    }

    fn exec_blocks(&mut self, blocks: &[RtBlock]) -> Result<()> {
        for b in blocks {
            self.exec_block(b)?;
        }
        Ok(())
    }

    fn exec_block(&mut self, b: &RtBlock) -> Result<()> {
        match b {
            RtBlock::Generic { insts, .. } => {
                for i in insts {
                    self.exec_inst(i)?;
                }
                Ok(())
            }
            RtBlock::If { pred, then_blocks, else_blocks, .. } => {
                if self.eval_pred_bool(pred)? {
                    self.exec_blocks(then_blocks)
                } else {
                    self.exec_blocks(else_blocks)
                }
            }
            RtBlock::For { var, from, to, by, body, .. } => {
                let from = self.eval_pred_num(from)?;
                let to = self.eval_pred_num(to)?;
                let by = match by {
                    Some(p) => self.eval_pred_num(p)?,
                    None => {
                        if from <= to {
                            1.0
                        } else {
                            -1.0
                        }
                    }
                };
                if by == 0.0 {
                    bail!("for loop with zero step");
                }
                let mut i = from;
                while (by > 0.0 && i <= to) || (by < 0.0 && i >= to) {
                    self.symbols.set(var, Value::Scalar(Lit::Int(i as i64)));
                    self.exec_blocks(body)?;
                    i += by;
                }
                Ok(())
            }
            RtBlock::While { pred, body, .. } => {
                let mut guard = 0u64;
                while self.eval_pred_bool(pred)? {
                    self.exec_blocks(body)?;
                    guard += 1;
                    if guard > 10_000_000 {
                        bail!("while loop exceeded 1e7 iterations");
                    }
                }
                Ok(())
            }
            RtBlock::FCall { fname, args, outputs, .. } => {
                let f = self
                    .funcs
                    .get(fname)
                    .cloned()
                    .ok_or_else(|| anyhow!("unknown function '{fname}'"))?;
                // bind arguments into a fresh scope
                let saved = std::mem::take(&mut self.symbols);
                for (p, a) in f.params.iter().zip(args.iter()) {
                    let v = saved.get(a)?.clone();
                    self.symbols.set(p, v);
                }
                let res = self.exec_blocks(&f.blocks);
                let fscope = std::mem::replace(&mut self.symbols, saved);
                res?;
                for (caller, callee) in outputs.iter().zip(f.outputs.iter()) {
                    let v = fscope.get(callee)?.clone();
                    self.symbols.set(caller, v);
                }
                Ok(())
            }
        }
    }

    fn eval_pred_bool(&mut self, p: &PredProg) -> Result<bool> {
        let v = self.eval_pred(p)?;
        v.as_bool().ok_or_else(|| anyhow!("predicate is not boolean: {v:?}"))
    }

    fn eval_pred_num(&mut self, p: &PredProg) -> Result<f64> {
        let v = self.eval_pred(p)?;
        v.as_f64().ok_or_else(|| anyhow!("loop bound is not numeric: {v:?}"))
    }

    fn eval_pred(&mut self, p: &PredProg) -> Result<Lit> {
        for i in &p.insts {
            self.exec_inst(i)?;
        }
        let op = p.result.as_ref().ok_or_else(|| anyhow!("predicate without result"))?;
        self.operand_scalar(op)
    }

    fn operand_scalar(&self, op: &Operand) -> Result<Lit> {
        match op {
            Operand::Lit(l) => Ok(l.clone()),
            Operand::Scalar(name, _) | Operand::Mat(name) => {
                Ok(self.symbols.get(name)?.as_scalar()?.clone())
            }
        }
    }

    fn operand_matrix(&mut self, op: &Operand) -> Result<Arc<DenseMatrix>> {
        match op {
            Operand::Mat(name) => {
                let data = self.symbols.matrix_data(name, &mut self.pool)?;
                Ok(data)
            }
            other => bail!("expected matrix operand, found {other:?}"),
        }
    }

    fn operand_f64(&self, op: &Operand) -> Result<f64> {
        self.operand_scalar(op)?
            .as_f64()
            .ok_or_else(|| anyhow!("operand is not numeric"))
    }

    /// Execute one instruction.
    pub fn exec_inst(&mut self, inst: &Instr) -> Result<()> {
        match inst {
            Instr::CreateVar { var, path, temp, format, mc } => {
                self.symbols.set(
                    var,
                    Value::Matrix(MatrixObject {
                        key: format!("{var}#{}", self.pool.fresh_id()),
                        mc: *mc,
                        format: *format,
                        path: if *temp { None } else { Some(path.clone()) },
                    }),
                );
                Ok(())
            }
            Instr::AssignVar { lit, var } => {
                self.symbols.set(var, Value::Scalar(lit.clone()));
                Ok(())
            }
            Instr::CpVar { src, dst } => {
                let v = self.symbols.get(src)?.clone();
                self.symbols.set(dst, v);
                Ok(())
            }
            Instr::RmVar { vars } => {
                for v in vars {
                    if let Ok(Value::Matrix(m)) = self.symbols.get(v).cloned() {
                        // only drop pooled data when no alias still uses it
                        let shared = self
                            .symbols
                            .vars
                            .iter()
                            .filter(|(n, val)| {
                                n.as_str() != v
                                    && matches!(val, Value::Matrix(o) if o.key == m.key)
                            })
                            .count();
                        if shared == 0 {
                            self.pool.remove(&m.key);
                        }
                    }
                    self.symbols.remove(v);
                }
                Ok(())
            }
            Instr::Cp(c) => {
                self.stats.cp_insts += 1;
                self.exec_cp(c).with_context(|| format!("CP {}", c.op.code()))
            }
            Instr::MrJob(j) => {
                self.stats.mr_jobs += 1;
                self.fault_spark = false;
                let report = mr::simulate(j, self)?;
                self.absorb_job_report(&report);
                Ok(())
            }
            Instr::SparkJob(j) => {
                // Execution shim: a fused stage DAG shares the byte-index
                // dataflow of an MR job, so the deterministic cluster
                // simulator runs its phase-classified equivalent (costing
                // uses the native Spark model, never this conversion).
                self.stats.mr_jobs += 1;
                self.fault_spark = true;
                let report = mr::simulate(&j.as_mr_job(), self)?;
                self.fault_spark = false;
                self.absorb_job_report(&report);
                Ok(())
            }
        }
    }

    fn absorb_job_report(&mut self, report: &mr::MrRunReport) {
        self.stats.map_tasks += report.map_tasks;
        self.stats.shuffle_bytes += report.shuffle_bytes;
        self.stats.hdfs_read_bytes += report.input_bytes;
        self.stats.failed_attempts += report.failed_attempts;
        self.stats.straggler_tasks += report.stragglers;
        self.stats.speculative_copies += report.speculative_copies;
        self.stats.fault_delay_secs += report.fault_delay_secs;
    }

    /// Try the PJRT kernel registry; fall back to native Rust kernels.
    ///
    /// Adaptive dispatch: the first time a key is seen, both paths run and
    /// are timed; subsequent calls use the winner (on TPU-class PJRT
    /// backends the artifact wins; on the CPU plugin the SIMD-unrolled
    /// native kernels often do — see EXPERIMENTS.md §Perf).
    fn kernel_or<F>(&mut self, op: &str, inputs: &[&DenseMatrix], native: F) -> DenseMatrix
    where
        F: FnOnce(usize) -> DenseMatrix,
    {
        let Some(reg) = self.registry else { return native(self.threads) };
        let shapes: Vec<(usize, usize)> = inputs.iter().map(|m| (m.rows, m.cols)).collect();
        let key = kernel_key(op, &shapes);
        if !reg.has(&key) {
            return native(self.threads);
        }
        let decision = self.dispatch.get(&key).copied().or_else(|| reg.preference(&key));
        match decision {
            Some(true) => {
                if let Some(Ok(out)) = reg.execute(&key, inputs) {
                    self.stats.pjrt_calls += 1;
                    return out;
                }
                native(self.threads)
            }
            Some(false) => native(self.threads),
            None => {
                // race both once (excluding PJRT compile time: warm first)
                let _ = reg.execute(&key, inputs);
                let t0 = Instant::now();
                let pjrt = reg.execute(&key, inputs);
                let t_pjrt = t0.elapsed();
                let t0 = Instant::now();
                let nat = native(self.threads);
                let t_native = t0.elapsed();
                let prefer_pjrt = matches!(pjrt, Some(Ok(_))) && t_pjrt < t_native;
                self.dispatch.insert(key.clone(), prefer_pjrt);
                reg.set_preference(&key, prefer_pjrt);
                if prefer_pjrt {
                    self.stats.pjrt_calls += 1;
                    if let Some(Ok(out)) = pjrt {
                        return out;
                    }
                }
                nat
            }
        }
    }

    fn exec_cp(&mut self, c: &CpInst) -> Result<()> {
        let out_name = c
            .output
            .name()
            .ok_or_else(|| anyhow!("instruction output must be a variable"))?
            .to_string();
        // scalar-only operations
        let all_scalar = c.inputs.iter().all(|o| !matches!(o, Operand::Mat(_)));
        match &c.op {
            CpOp::Binary(op) if all_scalar => {
                let a = self.operand_scalar(&c.inputs[0])?;
                let b = self.operand_scalar(&c.inputs[1])?;
                let r = op.fold(&a, &b).ok_or_else(|| anyhow!("cannot fold {}", op.code()))?;
                self.symbols.set(&out_name, Value::Scalar(r));
                return Ok(());
            }
            CpOp::Unary(op) if all_scalar && !matches!(op, UnOp::CastMatrix) => {
                let a = self.operand_scalar(&c.inputs[0])?;
                let r = op.fold(&a).ok_or_else(|| anyhow!("cannot fold {}", op.code()))?;
                self.symbols.set(&out_name, Value::Scalar(r));
                return Ok(());
            }
            CpOp::Print => {
                match &c.inputs[0] {
                    Operand::Lit(l) => println!("{}", l.render()),
                    Operand::Scalar(n, _) => {
                        println!("{}", self.symbols.get(n)?.as_scalar()?.render())
                    }
                    Operand::Mat(n) => {
                        let m = self.symbols.matrix_data(n, &mut self.pool)?;
                        println!("matrix {}x{} (nnz {})", m.rows, m.cols, m.nnz());
                    }
                }
                self.symbols.set(&out_name, Value::Scalar(Lit::Bool(true)));
                return Ok(());
            }
            _ => {}
        }

        let blocksize = self.cfg.blocksize;
        let result: DenseMatrix = match &c.op {
            CpOp::Tsmm { left } => {
                let x = self.operand_matrix(&c.inputs[0])?;
                if *left {
                    self.kernel_or("tsmm", &[&x], |t| ops::tsmm_left(&x, t))
                } else {
                    let xt = ops::transpose(&x);
                    self.kernel_or("tsmm", &[&xt], |t| ops::tsmm_left(&xt, t))
                }
            }
            CpOp::MatMult => {
                let a = self.operand_matrix(&c.inputs[0])?;
                let b = self.operand_matrix(&c.inputs[1])?;
                self.kernel_or("matmult", &[&a, &b], |t| ops::matmult(&a, &b, t))
            }
            CpOp::Transpose => {
                let a = self.operand_matrix(&c.inputs[0])?;
                ops::transpose(&a)
            }
            CpOp::Diag => {
                let a = self.operand_matrix(&c.inputs[0])?;
                ops::diag(&a)
            }
            CpOp::Rand { min, max, sparsity, seed } => {
                let rows = self.operand_f64(&c.inputs[0])? as usize;
                let cols = self.operand_f64(&c.inputs[1])? as usize;
                if min == max {
                    DenseMatrix::filled(rows, cols, *min)
                } else {
                    let s = if *seed < 0 { 0xC0FFEE } else { *seed as u64 };
                    DenseMatrix::rand(rows, cols, *min, *max, *sparsity, s)
                }
            }
            CpOp::Seq { from, to, by } => {
                let n = (((to - from) / by).floor() + 1.0).max(0.0) as usize;
                let values = (0..n).map(|i| from + *by * i as f64).collect();
                DenseMatrix::from_vec(n, 1, values)
            }
            CpOp::Binary(BinOp::Solve) => {
                let a = self.operand_matrix(&c.inputs[0])?;
                let b = self.operand_matrix(&c.inputs[1])?;
                self.kernel_or("solve", &[&a, &b], |_| {
                    ops::solve(&a, &b).expect("solve failed")
                })
            }
            CpOp::Binary(op) => {
                let f = bin_fn(*op)?;
                match (&c.inputs[0], &c.inputs[1]) {
                    (Operand::Mat(_), Operand::Mat(_)) => {
                        let a = self.operand_matrix(&c.inputs[0])?;
                        let b = self.operand_matrix(&c.inputs[1])?;
                        if a.rows == b.rows && a.cols == b.cols {
                            ops::ewise(&a, &b, f)
                        } else {
                            broadcast_ewise(&a, &b, f)?
                        }
                    }
                    (Operand::Mat(_), s) => {
                        let a = self.operand_matrix(&c.inputs[0])?;
                        let sv = self.operand_f64(s)?;
                        ops::ewise_scalar(&a, sv, f)
                    }
                    (s, Operand::Mat(_)) => {
                        let b = self.operand_matrix(&c.inputs[1])?;
                        let sv = self.operand_f64(s)?;
                        ops::ewise_scalar(&b, sv, |x, y| f(y, x))
                    }
                    _ => unreachable!("scalar-scalar handled above"),
                }
            }
            CpOp::Unary(op) => {
                let a = self.operand_matrix(&c.inputs[0])?;
                match op {
                    UnOp::CastMatrix => (*a).clone(),
                    _ => ops::unary(&a, un_fn(*op)?),
                }
            }
            CpOp::AggUnary(op, dir) => {
                let a = self.operand_matrix(&c.inputs[0])?;
                let out = agg_exec(*op, *dir, &a)?;
                match out {
                    AggResult::Scalar(v) => {
                        self.symbols.set(&out_name, Value::Scalar(Lit::Double(v)));
                        return Ok(());
                    }
                    AggResult::Matrix(m) => m,
                }
            }
            CpOp::Append => {
                let a = self.operand_matrix(&c.inputs[0])?;
                let b = self.operand_matrix(&c.inputs[1])?;
                ops::cbind(&a, &b)
            }
            CpOp::Partition => {
                // materialise the partitioned broadcast copy to scratch
                let a = self.operand_matrix(&c.inputs[0])?;
                (*a).clone()
            }
            CpOp::Write { path, format } => {
                // scalar writes persist a 1x1 matrix
                if !matches!(&c.inputs[0], Operand::Mat(n) if matches!(self.symbols.get(n), Ok(Value::Matrix(_))))
                {
                    if let Ok(l) = self.operand_scalar(&c.inputs[0]) {
                        let v = l.as_f64().unwrap_or(f64::NAN);
                        io::write_textcell(path, &DenseMatrix::from_vec(1, 1, vec![v]))?;
                        self.stats.hdfs_write_bytes += 8.0;
                        self.symbols.set(&out_name, Value::Scalar(Lit::Bool(true)));
                        return Ok(());
                    }
                }
                let a = self.operand_matrix(&c.inputs[0])?;
                match format {
                    Format::BinaryBlock => {
                        io::write_binary_block(path, &a, blocksize as usize)?
                    }
                    _ => io::write_textcell(path, &a)?,
                }
                self.stats.hdfs_write_bytes += (a.values.len() * 8) as f64;
                self.symbols.set(&out_name, Value::Scalar(Lit::Bool(true)));
                return Ok(());
            }
            CpOp::Print => unreachable!("handled above"),
        };
        self.symbols.bind_matrix(&out_name, Arc::new(result), blocksize, &mut self.pool)?;
        Ok(())
    }
}

/// Broadcast elementwise op: column-vector against matrix and vice versa.
fn broadcast_ewise(
    a: &DenseMatrix,
    b: &DenseMatrix,
    f: impl Fn(f64, f64) -> f64,
) -> Result<DenseMatrix> {
    if b.cols == 1 && b.rows == a.rows {
        let mut out = DenseMatrix::zeros(a.rows, a.cols);
        for r in 0..a.rows {
            let bv = b.values[r];
            for c in 0..a.cols {
                out.set(r, c, f(a.get(r, c), bv));
            }
        }
        Ok(out)
    } else if b.rows == 1 && b.cols == a.cols {
        let mut out = DenseMatrix::zeros(a.rows, a.cols);
        for r in 0..a.rows {
            for c in 0..a.cols {
                out.set(r, c, f(a.get(r, c), b.values[c]));
            }
        }
        Ok(out)
    } else {
        bail!("incompatible shapes {}x{} vs {}x{}", a.rows, a.cols, b.rows, b.cols)
    }
}

pub(crate) fn bin_fn(op: BinOp) -> Result<fn(f64, f64) -> f64> {
    Ok(match op {
        BinOp::Add => |a, b| a + b,
        BinOp::Sub => |a, b| a - b,
        BinOp::Mul => |a, b| a * b,
        BinOp::Div => |a, b| a / b,
        BinOp::Pow => |a: f64, b| a.powf(b),
        BinOp::Min => f64::min,
        BinOp::Max => f64::max,
        BinOp::Lt => |a, b| (a < b) as i64 as f64,
        BinOp::Gt => |a, b| (a > b) as i64 as f64,
        BinOp::Le => |a, b| (a <= b) as i64 as f64,
        BinOp::Ge => |a, b| (a >= b) as i64 as f64,
        BinOp::Eq => |a, b| (a == b) as i64 as f64,
        BinOp::Ne => |a, b| (a != b) as i64 as f64,
        BinOp::And => |a, b| ((a != 0.0) && (b != 0.0)) as i64 as f64,
        BinOp::Or => |a, b| ((a != 0.0) || (b != 0.0)) as i64 as f64,
        BinOp::Mod => |a: f64, b: f64| a - (a / b).floor() * b,
        BinOp::IntDiv => |a: f64, b: f64| (a / b).floor(),
        BinOp::Solve => bail!("solve is not elementwise"),
    })
}

pub(crate) fn un_fn(op: UnOp) -> Result<fn(f64) -> f64> {
    Ok(match op {
        UnOp::Sqrt => f64::sqrt,
        UnOp::Abs => f64::abs,
        UnOp::Exp => f64::exp,
        UnOp::Log => f64::ln,
        UnOp::Round => f64::round,
        UnOp::Floor => f64::floor,
        UnOp::Ceil => f64::ceil,
        UnOp::Sign => f64::signum,
        UnOp::Neg => |x| -x,
        UnOp::Not => |x| (x == 0.0) as i64 as f64,
        other => bail!("unary {} is not elementwise", other.code()),
    })
}

pub(crate) enum AggResult {
    Scalar(f64),
    Matrix(DenseMatrix),
}

pub(crate) fn agg_exec(op: AggOp, dir: AggDir, a: &DenseMatrix) -> Result<AggResult> {
    Ok(match (op, dir) {
        (AggOp::Sum, AggDir::All) => AggResult::Scalar(ops::sum(a)),
        (AggOp::Mean, AggDir::All) => {
            AggResult::Scalar(ops::sum(a) / (a.rows * a.cols).max(1) as f64)
        }
        (AggOp::Min, AggDir::All) => {
            AggResult::Scalar(a.values.iter().copied().fold(f64::INFINITY, f64::min))
        }
        (AggOp::Max, AggDir::All) => {
            AggResult::Scalar(a.values.iter().copied().fold(f64::NEG_INFINITY, f64::max))
        }
        (AggOp::Trace, AggDir::All) => {
            AggResult::Scalar((0..a.rows.min(a.cols)).map(|i| a.get(i, i)).sum())
        }
        (AggOp::Nnz, AggDir::All) => AggResult::Scalar(a.nnz() as f64),
        (AggOp::Sum, AggDir::Row) => AggResult::Matrix(ops::row_sums(a)),
        (AggOp::Sum, AggDir::Col) => AggResult::Matrix(ops::col_sums(a)),
        (AggOp::Mean, AggDir::Row) => {
            let mut m = ops::row_sums(a);
            let n = a.cols.max(1) as f64;
            m.values.iter_mut().for_each(|v| *v /= n);
            AggResult::Matrix(m)
        }
        (AggOp::Mean, AggDir::Col) => {
            let mut m = ops::col_sums(a);
            let n = a.rows.max(1) as f64;
            m.values.iter_mut().for_each(|v| *v /= n);
            AggResult::Matrix(m)
        }
        (op, dir) => bail!("unsupported aggregate {op:?}/{dir:?}"),
    })
}
