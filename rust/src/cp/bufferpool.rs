//! Size-bounded buffer pool with LRU eviction to local scratch files.
//!
//! The paper treats the buffer pool as a black box in the cost model
//! (§3.5: "we currently view the buffer pool as black box and only
//! consider its total size") — the runtime implements a real one so the
//! cost-accuracy experiments exercise genuine eviction behaviour.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::util::error::Result;

use crate::matrix::{io, DenseMatrix};

struct Entry {
    data: Arc<DenseMatrix>,
    bytes: usize,
    /// LRU tick of last access.
    tick: u64,
}

/// LRU buffer pool.
pub struct BufferPool {
    capacity: usize,
    used: usize,
    tick: u64,
    next_id: u64,
    scratch: PathBuf,
    entries: HashMap<String, Entry>,
    /// Keys evicted to scratch files.
    evicted: HashMap<String, String>,
    /// Statistics: number of evictions performed.
    pub evictions: usize,
}

impl BufferPool {
    pub fn new(capacity_bytes: usize, scratch: PathBuf) -> Self {
        BufferPool {
            capacity: capacity_bytes,
            used: 0,
            tick: 0,
            next_id: 0,
            scratch,
            entries: HashMap::new(),
            evicted: HashMap::new(),
            evictions: 0,
        }
    }

    pub fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    /// Current resident bytes.
    pub fn used_bytes(&self) -> usize {
        self.used
    }

    /// Fetch (and LRU-touch) an entry; falls back to reloading an evicted
    /// entry from its scratch file.
    pub fn get(&mut self, key: &str) -> Option<Arc<DenseMatrix>> {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(key) {
            e.tick = self.tick;
            return Some(e.data.clone());
        }
        if let Some(path) = self.evicted.get(key).cloned() {
            if let Ok(m) = io::read_binary_block(&path) {
                let data = Arc::new(m);
                let _ = self.put(key, data.clone());
                return Some(data);
            }
        }
        None
    }

    /// Path of the eviction file, if this key was spilled.
    pub fn eviction_path(&self, key: &str) -> Option<String> {
        self.evicted.get(key).cloned()
    }

    /// Insert data, evicting least-recently-used entries if over capacity.
    pub fn put(&mut self, key: &str, data: Arc<DenseMatrix>) -> Result<()> {
        let bytes = data.values.len() * 8 + 64;
        self.tick += 1;
        if let Some(old) = self.entries.remove(key) {
            self.used -= old.bytes;
        }
        self.entries.insert(key.to_string(), Entry { data, bytes, tick: self.tick });
        self.used += bytes;
        self.evict_to_fit(key)?;
        Ok(())
    }

    pub fn remove(&mut self, key: &str) {
        if let Some(e) = self.entries.remove(key) {
            self.used -= e.bytes;
        }
        self.evicted.remove(key);
    }

    fn evict_to_fit(&mut self, protect: &str) -> Result<()> {
        while self.used > self.capacity && self.entries.len() > 1 {
            // find LRU victim (not the just-inserted key)
            let victim = self
                .entries
                .iter()
                .filter(|(k, _)| k.as_str() != protect)
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            let e = self.entries.remove(&victim).unwrap();
            self.used -= e.bytes;
            std::fs::create_dir_all(&self.scratch)?;
            let path = self
                .scratch
                .join(format!("evict_{victim}_{}", self.tick))
                .to_string_lossy()
                .to_string();
            io::write_binary_block(&path, &e.data, 1024)?;
            self.evicted.insert(victim, path);
            self.evictions += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("sysds_bp_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn put_get_within_capacity() {
        let mut p = BufferPool::new(1 << 20, scratch("a"));
        let m = Arc::new(DenseMatrix::rand(10, 10, 0.0, 1.0, 1.0, 1));
        p.put("x", m.clone()).unwrap();
        assert_eq!(&*p.get("x").unwrap(), &*m);
        assert_eq!(p.evictions, 0);
    }

    #[test]
    fn eviction_spills_and_restores() {
        // capacity fits ~one 100x100 matrix (80KB)
        let mut p = BufferPool::new(100_000, scratch("b"));
        let a = Arc::new(DenseMatrix::rand(100, 100, 0.0, 1.0, 1.0, 1));
        let b = Arc::new(DenseMatrix::rand(100, 100, 0.0, 1.0, 1.0, 2));
        p.put("a", a.clone()).unwrap();
        p.put("b", b.clone()).unwrap();
        assert!(p.evictions >= 1, "a must be spilled");
        // a restores transparently from the eviction file
        let got = p.get("a").unwrap();
        assert_eq!(&*got, &*a);
    }

    #[test]
    fn lru_order_respected() {
        let mut p = BufferPool::new(170_000, scratch("c"));
        let a = Arc::new(DenseMatrix::rand(100, 100, 0.0, 1.0, 1.0, 1));
        let b = Arc::new(DenseMatrix::rand(100, 100, 0.0, 1.0, 1.0, 2));
        p.put("a", a).unwrap();
        p.put("b", b).unwrap();
        // touch a so b becomes LRU
        p.get("a");
        let c = Arc::new(DenseMatrix::rand(100, 100, 0.0, 1.0, 1.0, 3));
        p.put("c", c).unwrap();
        assert!(p.eviction_path("b").is_some(), "b was LRU");
        assert!(p.eviction_path("a").is_none());
    }

    #[test]
    fn remove_frees_space() {
        let mut p = BufferPool::new(1 << 20, scratch("d"));
        let m = Arc::new(DenseMatrix::rand(10, 10, 0.0, 1.0, 1.0, 1));
        p.put("x", m).unwrap();
        let used = p.used_bytes();
        p.remove("x");
        assert!(p.used_bytes() < used);
        assert!(p.get("x").is_none());
    }
}
