//! Resource optimization over a **joint configuration grid** (paper §1:
//! the cost model exists to power "advanced optimizers like resource
//! optimization"). Because plan *shape* changes with budgets (CP vs MR
//! vs Spark, mapmm vs cpmm), cost is not monotone in resources and a
//! search over generated plans is required — exactly why the paper's
//! analytical cost model exists (R1).
//!
//! [`optimize_grid`] enumerates the joint space
//!
//! ```text
//! client/task heap × Spark executor memory × worker nodes × k_local × backend
//! ```
//!
//! and evaluates it with three scaling levers:
//!
//! 1. **Plan-signature memoization** (via the unified evaluation core,
//!    [`crate::opt::evaluate`]): node counts and `k_local` never change
//!    plan shape, so points differing only on those axes are compiled
//!    once and costed many times — and when the plan cannot observe the
//!    differing knob at all (`k_local` without parfor), the evaluator
//!    skips the re-costing outright and the block-level cost cache
//!    ([`crate::cost::cache`]) covers partial overlaps.
//! 2. **Parallel evaluation**: distinct compiles and all point costings
//!    fan out over [`crate::util::par`].
//! 3. **Lower-bound pruning**: points are processed in budget-ascending
//!    waves; a point whose persistent-read IO floor
//!    ([`crate::cost::read_io_floor`]) already exceeds the best time
//!    found at a strictly smaller budget is *dominated* — it can reach
//!    neither the argmin nor the Pareto frontier — and is skipped
//!    without compiling or costing.
//!
//! The result is both the cost-argmin configuration and the **Pareto
//! frontier** of (resource budget, estimated time) trade-offs, where the
//! budget is the linearised cluster-memory measure
//! `client heap + worker-memory · nodes` (worker memory is the task heap
//! on MR, the executor heap on Spark, and zero on single-node CP).
//!
//! Entry points: [`optimize_grid`] / [`crate::api::optimize_resources`],
//! the `repro resource --grid ...` subcommand, and the legacy
//! single-axis [`optimize`] / [`optimize_backend`] heap sweeps.

use std::collections::HashMap;
use std::time::Instant;

use crate::api::{compile_with_meta, ClusterConfigOpt, CompileOptions, CompiledProgram};
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig, MB};
use crate::cost;
use crate::ir::build::MetaProvider;
use crate::lop::SelectionHints;
use crate::matrix::{Format, MatrixCharacteristics};
use crate::rtprog::ExecBackend;
use crate::util::fmt::fmt_secs;
use crate::util::par;

use super::evaluate::{Candidate, CostContext, Evaluator};
use super::sweep::{plan_signature, DataScenario};

// ---------------------------------------------------------------------
// Grid specification
// ---------------------------------------------------------------------

/// Joint resource-configuration grid for one script + data scenario.
///
/// The five axes are crossed, with two backend-aware reductions that
/// keep the grid free of duplicate points: the executor-memory axis
/// only applies to Spark points (it is plan- and cost-neutral for CP
/// and MR), and the node axis collapses to a single worker for CP
/// points (a CP plan runs on the client alone).
#[derive(Clone, Debug)]
pub struct ResourceGrid {
    /// DML source compiled per distinct plan shape.
    pub script: String,
    /// `$N` command-line bindings for the script.
    pub args: HashMap<usize, String>,
    /// Persistent-input metadata (also drives the pruning floor).
    pub scenario: DataScenario,
    /// Base cluster; each grid point patches the axis fields onto it
    /// (see [`ClusterConfig::with_heap_mb`] and friends).
    pub base: ClusterConfig,
    /// Compiler/system configuration shared by all points.
    pub cfg: SystemConfig,
    /// Physical-operator selection hints shared by all points.
    pub hints: SelectionHints,
    /// Cost-model constants shared by all points.
    pub constants: CostConstants,
    /// Failure profile shared by all points (`repro resource
    /// --fault-profile`). [`FaultProfile::none`] is a bitwise no-op; a
    /// nonzero profile prices retries, backoff, and straggler tails into
    /// every distributed point, shifting the argmin and the Pareto
    /// frontier toward retry-free CP configurations.
    pub fault: FaultProfile,
    /// Client/task heap axis, MB (plan-shaping: §2 memory budgets).
    pub heaps_mb: Vec<f64>,
    /// Spark executor-memory axis, MB (plan-shaping on Spark only:
    /// broadcast feasibility).
    pub exec_mem_mb: Vec<f64>,
    /// Worker-node axis (cost-only: scales slots/executors).
    pub nodes: Vec<usize>,
    /// Control-program parallelism axis `k_l` (cost-only: parfor).
    pub k_local: Vec<usize>,
    /// Backend axis (CP / MR / Spark plan families).
    pub backends: Vec<ExecBackend>,
    /// Skip compiling points whose read floor proves them dominated.
    /// Disable to force-cost every point (the frontier and argmin are
    /// identical either way; `tests/resource.rs` asserts so).
    pub prune: bool,
    /// Enable the block-level cost cache ([`crate::cost::cache`]).
    /// Results are bitwise identical either way; disable only for A/B
    /// measurements (`repro resource --no-cost-cache`).
    pub cost_cache: bool,
    /// Worker threads; `0` = available parallelism.
    pub threads: usize,
    /// Statically verify the argmin point's plan ([`crate::analysis`])
    /// after the search (`repro resource --verify`). Error-severity
    /// diagnostics fail the optimization; the report carries the audit.
    pub verify: bool,
}

impl ResourceGrid {
    /// Grid with the default axes (3 heaps × 2 executor memories ×
    /// 2 node counts × 2 `k_local` values × all 3 backends = 42 points,
    /// 12 distinct plan shapes) on the paper cluster.
    pub fn new(
        script: impl Into<String>,
        args: HashMap<usize, String>,
        scenario: DataScenario,
    ) -> Self {
        ResourceGrid {
            script: script.into(),
            args,
            scenario,
            base: ClusterConfig::paper_cluster(),
            cfg: SystemConfig::default(),
            hints: SelectionHints::default(),
            constants: CostConstants::default(),
            fault: FaultProfile::none(),
            heaps_mb: vec![512.0, 2048.0, 8192.0],
            exec_mem_mb: vec![2048.0, 20480.0],
            nodes: vec![2, 6],
            k_local: vec![6, 24],
            backends: ExecBackend::all().to_vec(),
            prune: true,
            cost_cache: true,
            threads: 0,
            verify: false,
        }
    }

    /// Reject empty or degenerate axes and configurations before any
    /// compile, so NaN costs become diagnostics instead of panics.
    pub fn validate(&self) -> Result<(), String> {
        self.base.validate()?;
        self.constants.validate()?;
        self.fault.validate()?;
        let non_empty = |name: &str, len: usize| {
            if len == 0 {
                Err(format!("empty resource grid axis: {name}"))
            } else {
                Ok(())
            }
        };
        non_empty("heaps_mb", self.heaps_mb.len())?;
        non_empty("exec_mem_mb", self.exec_mem_mb.len())?;
        non_empty("nodes", self.nodes.len())?;
        non_empty("k_local", self.k_local.len())?;
        non_empty("backends", self.backends.len())?;
        for &h in &self.heaps_mb {
            if !(h.is_finite() && h > 0.0) {
                return Err(format!("invalid heap axis value {h} MB (must be finite and > 0)"));
            }
        }
        for &x in &self.exec_mem_mb {
            if !(x.is_finite() && x > 0.0) {
                return Err(format!(
                    "invalid executor-memory axis value {x} MB (must be finite and > 0)"
                ));
            }
        }
        if self.nodes.contains(&0) {
            return Err("invalid node axis value 0 (must be >= 1)".to_string());
        }
        if self.k_local.contains(&0) {
            return Err("invalid k_local axis value 0 (must be >= 1)".to_string());
        }
        Ok(())
    }

    /// The enumerated axis tuples `(heap, exec_mem, nodes, k_local,
    /// backend)` in deterministic grid order, with the backend-aware
    /// axis reductions applied (executor memory varies on Spark points
    /// only; CP points run on a single worker).
    fn enumerate(&self) -> Vec<(f64, f64, usize, usize, ExecBackend)> {
        let base_xm = self.base.spark_executor_mem_bytes / MB;
        let mut out = Vec::new();
        for &h in &self.heaps_mb {
            for &b in &self.backends {
                let xms: &[f64] = if b == ExecBackend::Spark {
                    &self.exec_mem_mb
                } else {
                    std::slice::from_ref(&base_xm)
                };
                let single_node = [1usize];
                let nodes: &[usize] =
                    if b == ExecBackend::Cp { &single_node } else { &self.nodes };
                for &xm in xms {
                    for &n in nodes {
                        for &kl in &self.k_local {
                            out.push((h, xm, n, kl, b));
                        }
                    }
                }
            }
        }
        out
    }

    /// Number of grid points after the backend-aware axis reductions.
    pub fn point_count(&self) -> usize {
        self.enumerate().len()
    }
}

/// Compact `heap/xmem/nodes/k_l/backend` label shared by grid points
/// and wave-loop diagnostics (the prune-equivalence tests compare these
/// across runs, so there is exactly one format).
fn point_label(
    heap_mb: f64,
    exec_mem_mb: f64,
    nodes: usize,
    k_local: usize,
    backend: ExecBackend,
) -> String {
    format!(
        "heap={}MB xmem={}MB nodes={} k_l={} backend={}",
        heap_mb as i64,
        exec_mem_mb as i64,
        nodes,
        k_local,
        backend.name()
    )
}

/// Linearised resource budget of one point, in MB: the client heap plus
/// the per-node worker-memory commitment times the node count (task
/// heap on MR, executor heap on Spark, no workers on single-node CP).
fn budget_mb(heap_mb: f64, exec_mem_mb: f64, nodes: usize, backend: ExecBackend) -> f64 {
    match backend {
        ExecBackend::Cp => heap_mb,
        ExecBackend::Mr => heap_mb + heap_mb * nodes as f64,
        ExecBackend::Spark => heap_mb + exec_mem_mb * nodes as f64,
    }
}

// ---------------------------------------------------------------------
// Results
// ---------------------------------------------------------------------

/// One grid point: its axis values, budget, pruning floor, and (unless
/// pruned) the estimated time and plan statistics.
#[derive(Clone, Debug)]
pub struct GridPoint {
    /// Client/task heap, MB.
    pub heap_mb: f64,
    /// Spark executor memory, MB (the base value on CP/MR points).
    pub exec_mem_mb: f64,
    /// Worker nodes (1 on CP points).
    pub nodes: usize,
    /// Control-program parallelism `k_l`.
    pub k_local: usize,
    /// Execution backend of the point's plan family.
    pub backend: ExecBackend,
    /// Linearised resource budget (client heap + worker memory · nodes).
    pub budget_mb: f64,
    /// Persistent-read IO floor — the pruning lower bound.
    pub floor_secs: f64,
    /// Estimated execution time `C(P, cc)`; `None` when the point was
    /// pruned (its floor proved it dominated).
    pub cost_secs: Option<f64>,
    /// CP instruction count of the generated plan (0 when pruned).
    pub cp_insts: usize,
    /// MR-job count of the generated plan.
    pub mr_jobs: usize,
    /// Spark-job count of the generated plan.
    pub spark_jobs: usize,
    /// Whether the point reused a plan compiled for an earlier point.
    pub plan_reused: bool,
}

impl GridPoint {
    /// Whether the point was skipped by lower-bound pruning.
    pub fn pruned(&self) -> bool {
        self.cost_secs.is_none()
    }

    /// Compact `heap/xmem/nodes/k_l/backend` label for diagnostics.
    pub fn label(&self) -> String {
        point_label(self.heap_mb, self.exec_mem_mb, self.nodes, self.k_local, self.backend)
    }
}

/// Result of a grid optimization: every point, the argmin, and the
/// Pareto frontier of (budget, time).
#[derive(Clone, Debug)]
pub struct ResourceReport {
    /// All points in grid-enumeration order.
    pub points: Vec<GridPoint>,
    /// Index (into `points`) of the cost-argmin point.
    pub best: usize,
    /// Indices of the non-dominated points, budget-ascending (and
    /// therefore time-descending — see [`Self::frontier_table`]).
    pub frontier: Vec<usize>,
    /// Distinct plan shapes compiled (== compile+cost invocations that
    /// actually compiled; strictly less than the grid size whenever the
    /// cost-only axes have more than one value).
    pub distinct_plans: usize,
    /// Costed points that reused a memoized plan.
    pub memo_hits: usize,
    /// Points skipped by lower-bound pruning.
    pub pruned: usize,
    /// Wall-clock seconds spent in the optimization.
    pub wall_secs: f64,
    /// Worker threads used.
    pub threads: usize,
    /// Static verification of the argmin point's plan, present when the
    /// spec asked for it. Always clean — a dirty argmin fails the
    /// optimization instead.
    pub verify: Option<crate::analysis::VerifyReport>,
}

impl ResourceReport {
    /// The cost-argmin point.
    pub fn best(&self) -> &GridPoint {
        &self.points[self.best]
    }

    /// Frontier points in budget-ascending order.
    pub fn frontier_points(&self) -> impl Iterator<Item = &GridPoint> {
        self.frontier.iter().map(move |&i| &self.points[i])
    }

    /// Aligned Pareto-frontier table: budget-ascending rows with
    /// strictly decreasing estimated time (non-domination made visible).
    /// Executor memory is shown only where it matters (Spark points).
    pub fn frontier_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:>10} {:>9} {:>10} {:>6} {:>8} {:<8} {:>5} {:>12}\n",
            "budget", "heap", "exec-mem", "nodes", "k_local", "backend", "jobs", "est. time"
        ));
        out.push_str(&"-".repeat(76));
        out.push('\n');
        for p in self.frontier_points() {
            let xm = if p.backend == ExecBackend::Spark {
                format!("{}MB", p.exec_mem_mb as i64)
            } else {
                "-".to_string()
            };
            out.push_str(&format!(
                "{:>8}MB {:>7}MB {:>10} {:>6} {:>8} {:<8} {:>5} {:>12}\n",
                p.budget_mb as i64,
                p.heap_mb as i64,
                xm,
                p.nodes,
                p.k_local,
                p.backend.name(),
                p.mr_jobs + p.spark_jobs,
                fmt_secs(p.cost_secs.unwrap_or(f64::NAN)),
            ));
        }
        out
    }

    /// One-line execution summary (includes wall time — not part of the
    /// deterministic tables).
    pub fn summary(&self) -> String {
        format!(
            "explored {} grid points in {:.3}s on {} threads; {} distinct plans compiled, {} memoized, {} pruned by the read floor; frontier size {}",
            self.points.len(),
            self.wall_secs,
            self.threads,
            self.distinct_plans,
            self.memo_hits,
            self.pruned,
            self.frontier.len()
        )
    }
}

// ---------------------------------------------------------------------
// The grid optimizer
// ---------------------------------------------------------------------

struct RawPoint {
    heap_mb: f64,
    exec_mem_mb: f64,
    nodes: usize,
    k_local: usize,
    backend: ExecBackend,
    cc: ClusterConfig,
    budget_mb: f64,
    floor_secs: f64,
}

impl RawPoint {
    fn label(&self) -> String {
        point_label(self.heap_mb, self.exec_mem_mb, self.nodes, self.k_local, self.backend)
    }
}

/// One surviving grid point viewed as an evaluator candidate. Points
/// that differ only on cost-only axes share a plan signature (compiled
/// once); points whose plan additionally cannot observe the differing
/// knob (e.g. `k_local` on a parfor-free plan) also share the *cost*
/// via the evaluator's duplicate-cost skip.
struct PointCand<'a> {
    spec: &'a ResourceGrid,
    meta: &'a crate::ir::build::StaticMeta,
    raw: &'a RawPoint,
}

impl Candidate for PointCand<'_> {
    fn signature(&self) -> String {
        plan_signature(
            &self.spec.script,
            &self.spec.args,
            &self.spec.cfg,
            &self.spec.hints,
            &self.raw.cc,
            &self.spec.scenario,
            self.raw.backend,
        )
    }
    fn compile(&self) -> Result<CompiledProgram, String> {
        compile_point(self.spec, self.meta, self.raw)
    }
    fn context(&self) -> CostContext<'_> {
        CostContext {
            cfg: &self.spec.cfg,
            cc: &self.raw.cc,
            constants: &self.spec.constants,
            fault: &self.spec.fault,
        }
    }
    fn label(&self) -> String {
        format!("grid point {} — degenerate configuration", self.raw.label())
    }
}

fn compile_point(
    spec: &ResourceGrid,
    meta: &crate::ir::build::StaticMeta,
    raw: &RawPoint,
) -> Result<CompiledProgram, String> {
    let opts = CompileOptions {
        cfg: spec.cfg.clone(),
        cc: ClusterConfigOpt(raw.cc.clone()),
        hints: spec.hints.clone(),
        backend: raw.backend,
    };
    compile_with_meta(&spec.script, &spec.args, meta, &opts).map_err(|e| {
        format!(
            "compile failed for grid point heap={}MB backend={}: {e}",
            raw.heap_mb as i64,
            raw.backend.name()
        )
    })
}

/// Evaluate the joint resource grid: enumerate points, prune dominated
/// ones via the read floor, compile once per distinct plan signature
/// (parallel, memoized), cost every surviving point concurrently, and
/// return the argmin plus the (budget, time) Pareto frontier. See the
/// module docs for the wave pipeline.
pub fn optimize_grid(spec: &ResourceGrid) -> Result<ResourceReport, String> {
    let threads = if spec.threads == 0 { par::default_threads() } else { spec.threads };
    let mut eval = if spec.cost_cache {
        Evaluator::new(threads)
    } else {
        Evaluator::without_cost_cache(threads)
    };
    optimize_grid_with(spec, &mut eval)
}

/// [`optimize_grid`] over a caller-provided evaluator: reruns keep the
/// compile memo and cost cache warm, and a cache pre-loaded from a
/// [`crate::artifact::CacheSnapshot`] (`--warm-cache`) replays earlier
/// block costings from disk. `spec.threads`/`spec.cost_cache` are
/// ignored — the evaluator already fixes both.
pub fn optimize_grid_with(
    spec: &ResourceGrid,
    eval: &mut Evaluator,
) -> Result<ResourceReport, String> {
    let t0 = Instant::now();
    spec.validate()?;
    let threads = eval.threads();
    let meta = spec.scenario.meta(spec.cfg.blocksize);
    let floor_inputs: Vec<(MatrixCharacteristics, Format)> = spec
        .scenario
        .inputs
        .iter()
        .map(|&(_, r, c)| {
            (MatrixCharacteristics::dense(r, c, spec.cfg.blocksize), Format::BinaryBlock)
        })
        .collect();

    let raw: Vec<RawPoint> = spec
        .enumerate()
        .into_iter()
        .map(|(h, xm, n, kl, b)| {
            let cc = spec
                .base
                .clone()
                .with_heap_mb(h)
                .with_executor_mem_mb(xm)
                .with_nodes(n)
                .with_k_local(kl);
            let floor_secs =
                cost::read_io_floor(&floor_inputs, b, &spec.cfg, &cc, &spec.constants);
            RawPoint {
                heap_mb: h,
                exec_mem_mb: xm,
                nodes: n,
                k_local: kl,
                backend: b,
                budget_mb: budget_mb(h, xm, n, b),
                floor_secs,
                cc,
            }
        })
        .collect();

    // Budget-ascending wave order (ties keep enumeration order, so the
    // whole pipeline is deterministic regardless of thread count).
    let mut order: Vec<usize> = (0..raw.len()).collect();
    order.sort_by(|&a, &b| raw[a].budget_mb.total_cmp(&raw[b].budget_mb).then(a.cmp(&b)));

    eval.begin_run();
    // per point: (cost, cp_insts, mr_jobs, spark_jobs, plan_reused)
    let mut costed: Vec<Option<(f64, usize, usize, usize, bool)>> = vec![None; raw.len()];
    // `Arc`-shared plan per costed point, kept so `--verify` can audit
    // the argmin without recompiling it.
    let mut plans: Vec<Option<std::sync::Arc<CompiledProgram>>> = vec![None; raw.len()];
    let mut best_time = f64::INFINITY;
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j < order.len() && raw[order[j]].budget_mb == raw[order[i]].budget_mb {
            j += 1;
        }
        // A point whose floor meets the best time achieved at a strictly
        // smaller budget is dominated: skip compile + cost entirely.
        let survivors: Vec<usize> = order[i..j]
            .iter()
            .copied()
            .filter(|&p| !spec.prune || raw[p].floor_secs < best_time)
            .collect();
        let cands: Vec<PointCand> =
            survivors.iter().map(|&p| PointCand { spec, meta: &meta, raw: &raw[p] }).collect();
        let wave = eval.evaluate(&cands)?;
        for (s, &p) in survivors.iter().enumerate() {
            let ev = &wave[s];
            costed[p] =
                Some((ev.cost_secs, ev.cp_insts, ev.mr_jobs, ev.spark_jobs, ev.plan_reused));
            plans[p] = Some(std::sync::Arc::clone(&ev.plan));
            if ev.cost_secs < best_time {
                best_time = ev.cost_secs;
            }
        }
        i = j;
    }

    let points: Vec<GridPoint> = raw
        .iter()
        .enumerate()
        .map(|(p, r)| {
            let c = costed[p];
            GridPoint {
                heap_mb: r.heap_mb,
                exec_mem_mb: r.exec_mem_mb,
                nodes: r.nodes,
                k_local: r.k_local,
                backend: r.backend,
                budget_mb: r.budget_mb,
                floor_secs: r.floor_secs,
                cost_secs: c.map(|(t, ..)| t),
                cp_insts: c.map_or(0, |(_, cp, ..)| cp),
                mr_jobs: c.map_or(0, |(_, _, mr, _, _)| mr),
                spark_jobs: c.map_or(0, |(_, _, _, sp, _)| sp),
                plan_reused: c.is_some_and(|(.., reused)| reused),
            }
        })
        .collect();

    // Argmin over costed points; ties resolve to the smallest budget
    // (then enumeration order) so the report is deterministic.
    let best = points
        .iter()
        .enumerate()
        .filter_map(|(i, p)| p.cost_secs.map(|c| (i, c, p.budget_mb)))
        .min_by(|a, b| a.1.total_cmp(&b.1).then(a.2.total_cmp(&b.2)).then(a.0.cmp(&b.0)))
        .map(|(i, ..)| i)
        .ok_or("no grid point could be costed")?;

    // Pareto frontier: budget-ascending sweep keeping strict time
    // improvements — the result is non-dominated by construction.
    let mut by_budget: Vec<usize> = (0..points.len()).filter(|&i| !points[i].pruned()).collect();
    by_budget.sort_by(|&a, &b| {
        points[a]
            .budget_mb
            .total_cmp(&points[b].budget_mb)
            .then(points[a].cost_secs.unwrap().total_cmp(&points[b].cost_secs.unwrap()))
            .then(a.cmp(&b))
    });
    let mut frontier = Vec::new();
    let mut best_so_far = f64::INFINITY;
    for idx in by_budget {
        let c = points[idx].cost_secs.unwrap();
        if c < best_so_far {
            frontier.push(idx);
            best_so_far = c;
        }
    }

    let verify = if spec.verify {
        let plan = plans[best].as_ref().expect("argmin points are costed, so their plan is kept");
        let report = crate::analysis::verify_faults(
            &plan.runtime,
            &spec.cfg,
            &raw[best].cc,
            &spec.constants,
            &spec.fault,
            raw[best].backend,
        );
        if !report.is_clean() {
            return Err(format!(
                "plan verification failed for argmin point ({}): {} error(s)\n{}",
                raw[best].label(),
                report.errors(),
                report.render()
            ));
        }
        Some(report)
    } else {
        None
    };

    let n_costed = points.iter().filter(|p| !p.pruned()).count();
    // counted from the reuse flags, not `costed - distinct`: a shared
    // memo (serve daemon) may hold more plans than this run costed
    let memo_hits = costed.iter().flatten().filter(|c| c.4).count();
    Ok(ResourceReport {
        pruned: points.len() - n_costed,
        memo_hits,
        distinct_plans: eval.distinct_plans(),
        best,
        frontier,
        points,
        wall_secs: t0.elapsed().as_secs_f64(),
        threads,
        verify,
    })
}

// ---------------------------------------------------------------------
// Legacy single-axis heap sweep (compat shims over the same costing)
// ---------------------------------------------------------------------

/// One evaluated configuration of the legacy heap sweep.
#[derive(Clone, Debug)]
pub struct ResourcePoint {
    /// Client/task heap size in bytes.
    pub heap_bytes: f64,
    /// Estimated execution time.
    pub cost_secs: f64,
    /// Number of MR jobs in the generated plan.
    pub mr_jobs: usize,
    /// Number of Spark jobs in the generated plan (Spark backend).
    pub spark_jobs: usize,
}

/// Result of the legacy heap sweep: every evaluated point (in sweep
/// order) plus the argmin. For the joint grid with a Pareto frontier
/// see [`optimize_grid`].
#[derive(Clone, Debug)]
pub struct ResourceChoice {
    /// The cost-argmin point.
    pub best: ResourcePoint,
    /// Every evaluated point, in the order of `heaps_mb`.
    pub points: Vec<ResourcePoint>,
}

/// Sweep client+task heap sizes and return the cost-optimal
/// configuration (MR backend; see [`optimize_backend`] for the backend
/// axis and [`optimize_grid`] for the joint grid).
pub fn optimize(
    src: &str,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    base_cc: &ClusterConfig,
    heaps_mb: &[f64],
) -> Result<ResourceChoice, String> {
    optimize_backend(src, args, meta, base_cc, heaps_mb, ExecBackend::Mr)
}

/// Backend-parameterised heap sweep: generate and cost the plan per
/// heap size for the given backend. On the Spark backend the executor
/// memory scales with the heap axis (preserving the base ratio), so
/// broadcast-feasibility flips are part of the search space.
///
/// The base configuration is validated up front — a zero `cp_heap_bytes`
/// used to silently poison every Spark point with NaN through the
/// executor-memory ratio, and NaN costs then panicked the `min_by`
/// ranking; both now surface as diagnostics.
pub fn optimize_backend(
    src: &str,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    base_cc: &ClusterConfig,
    heaps_mb: &[f64],
    backend: ExecBackend,
) -> Result<ResourceChoice, String> {
    base_cc.validate()?;
    let constants = CostConstants::default();
    // safe: validate() guarantees cp_heap_bytes > 0
    let spark_exec_ratio = base_cc.spark_executor_mem_bytes / base_cc.cp_heap_bytes;
    let mut points = Vec::new();
    for &h in heaps_mb {
        if !(h.is_finite() && h > 0.0) {
            return Err(format!("invalid heap sweep value {h} MB (must be finite and > 0)"));
        }
        let mut cc = base_cc.clone().with_heap_mb(h);
        cc.spark_executor_mem_bytes = h * MB * spark_exec_ratio;
        let opts = CompileOptions {
            cc: ClusterConfigOpt(cc.clone()),
            backend,
            ..Default::default()
        };
        let compiled = compile_with_meta(src, args, meta, &opts)?;
        let report = cost::cost_program(&compiled.runtime, &opts.cfg, &cc, &constants);
        if !report.total.is_finite() {
            return Err(format!(
                "non-finite cost estimate ({}) at heap {h} MB on backend {}",
                report.total,
                backend.name()
            ));
        }
        points.push(ResourcePoint {
            heap_bytes: h * MB,
            cost_secs: report.total,
            mr_jobs: compiled.runtime.mr_job_count(),
            spark_jobs: compiled.runtime.spark_job_count(),
        });
    }
    let best = points
        .iter()
        .min_by(|a, b| a.cost_secs.total_cmp(&b.cost_secs))
        .cloned()
        .ok_or("empty sweep")?;
    Ok(ResourceChoice { best, points })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Scenario;

    #[test]
    fn larger_heap_moves_xs_plans_to_cp() {
        // With a tiny heap even XS needs MR; larger heaps give CP plans
        // with far lower estimated cost.
        let s = Scenario::xs();
        let choice = optimize(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &[64.0, 2048.0],
        )
        .unwrap();
        assert_eq!(choice.points.len(), 2);
        let small = &choice.points[0];
        let large = &choice.points[1];
        assert!(small.mr_jobs > 0, "64MB heap forces MR");
        assert_eq!(large.mr_jobs, 0, "2GB heap keeps XS in CP");
        assert!(large.cost_secs < small.cost_secs);
        assert_eq!(choice.best.heap_bytes, 2048.0 * MB);
    }

    #[test]
    fn spark_backend_sweep_produces_spark_jobs() {
        let s = Scenario::xl1();
        let choice = optimize_backend(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &[2048.0],
            ExecBackend::Spark,
        )
        .unwrap();
        assert_eq!(choice.points[0].mr_jobs, 0);
        assert!(choice.points[0].spark_jobs > 0);
    }

    #[test]
    fn points_preserve_sweep_order() {
        let s = Scenario::xs();
        let choice = optimize(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &[128.0, 512.0, 2048.0],
        )
        .unwrap();
        let heaps: Vec<f64> = choice.points.iter().map(|p| p.heap_bytes / MB).collect();
        assert_eq!(heaps, vec![128.0, 512.0, 2048.0]);
    }

    #[test]
    fn zero_heap_base_is_rejected_not_nan() {
        // Regression: `spark_exec_ratio = exec_mem / cp_heap` with a zero
        // client heap used to poison every Spark point with NaN.
        let s = Scenario::xs();
        let mut cc = ClusterConfig::paper_cluster();
        cc.cp_heap_bytes = 0.0;
        let err = optimize_backend(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &cc,
            &[512.0],
            ExecBackend::Spark,
        )
        .unwrap_err();
        assert!(err.contains("cp_heap_bytes"), "{err}");
    }

    #[test]
    fn zero_k_local_base_is_rejected() {
        let s = Scenario::xs();
        let mut cc = ClusterConfig::paper_cluster();
        cc.k_local = 0;
        assert!(optimize(s.script(), &s.args(), &s.meta(1000), &cc, &[512.0]).is_err());
    }

    fn xs_grid() -> ResourceGrid {
        let s = Scenario::xs();
        let mut g = ResourceGrid::new(s.script(), s.args(), DataScenario::from(&s));
        g.threads = 2;
        g
    }

    #[test]
    fn default_grid_spans_every_axis() {
        let g = xs_grid();
        // 3 heaps x (cp: 2 k_l) + (mr: 2 nodes x 2 k_l) + (spark: 2 xmem
        // x 2 nodes x 2 k_l) = 3 x (2 + 4 + 8) = 42 points
        assert_eq!(g.point_count(), 42);
        let r = optimize_grid(&g).unwrap();
        assert_eq!(r.points.len(), 42);
        // memoization: cost-only axes (nodes, k_local) share compiles
        assert!(r.distinct_plans < r.points.len() - r.pruned);
        assert!(r.memo_hits > 0);
    }

    #[test]
    fn grid_rejects_empty_and_degenerate_axes() {
        let mut g = xs_grid();
        g.heaps_mb.clear();
        assert!(optimize_grid(&g).is_err());
        let mut g = xs_grid();
        g.k_local = vec![0];
        assert!(optimize_grid(&g).is_err());
        let mut g = xs_grid();
        g.heaps_mb = vec![f64::NAN];
        assert!(optimize_grid(&g).is_err());
        let mut g = xs_grid();
        g.base.cp_heap_bytes = 0.0;
        assert!(optimize_grid(&g).is_err());
    }

    #[test]
    fn frontier_is_sorted_and_non_dominated() {
        let r = optimize_grid(&xs_grid()).unwrap();
        let f: Vec<&GridPoint> = r.frontier_points().collect();
        assert!(!f.is_empty());
        for w in f.windows(2) {
            assert!(w[0].budget_mb < w[1].budget_mb, "budget must strictly increase");
            assert!(
                w[0].cost_secs.unwrap() > w[1].cost_secs.unwrap(),
                "time must strictly decrease"
            );
        }
        // the argmin is always on the frontier (it is undominated on time)
        assert!(r.frontier.contains(&r.best));
        assert_eq!(r.best().cost_secs, f.last().unwrap().cost_secs);
    }

    #[test]
    fn verify_flag_audits_the_argmin_point() {
        let mut g = xs_grid();
        g.verify = true;
        let r = optimize_grid(&g).unwrap();
        let v = r.verify.as_ref().expect("verify requested");
        assert!(v.is_clean(), "{}", v.render());
        assert_eq!(v.backend, r.best().backend);
        g.verify = false;
        assert!(optimize_grid(&g).unwrap().verify.is_none());
    }

    #[test]
    fn fault_profile_shifts_distributed_points_only() {
        // a 64 MB heap forces XS onto distributed plans, so the grid is
        // guaranteed to cost at least one point with MR/Spark jobs
        let mut g = xs_grid();
        g.heaps_mb = vec![64.0, 2048.0];
        g.prune = false;
        let base = optimize_grid(&g).unwrap();
        g.fault = FaultProfile::chaos();
        let chaos = optimize_grid(&g).unwrap();
        // pruning depends on costs, so compare unpruned-in-both points
        let mut saw_inflated = false;
        for (a, c) in base.points.iter().zip(&chaos.points) {
            let (Some(ca), Some(cc_)) = (a.cost_secs, c.cost_secs) else { continue };
            if c.mr_jobs + c.spark_jobs == 0 {
                assert_eq!(ca.to_bits(), cc_.to_bits(), "{}", c.label());
            } else {
                assert!(cc_ > ca, "{} not inflated", c.label());
                saw_inflated = true;
            }
        }
        assert!(saw_inflated, "grid should cost at least one distributed point");
        // XS fits the heap: failure pricing cannot dethrone the CP argmin
        assert_eq!(chaos.best().backend, ExecBackend::Cp);
        // degenerate profiles are rejected up front
        g.fault.straggler_slowdown = 0.5;
        assert!(optimize_grid(&g).unwrap_err().contains("FaultProfile"));
    }

    #[test]
    fn xs_grid_argmin_is_a_cp_plan() {
        // 80 MB XS fits any 2 GB+ heap: single-node CP wins outright and
        // with the smallest budget.
        let r = optimize_grid(&xs_grid()).unwrap();
        assert_eq!(r.best().backend, ExecBackend::Cp);
        assert_eq!(r.best().mr_jobs + r.best().spark_jobs, 0);
    }
}
