//! Resource optimization: pick the memory configuration minimising the
//! estimated execution time `C(P, cc)` — because plan *shape* changes with
//! budgets (CP vs MR, mapmm vs cpmm), cost is not monotone in resources and
//! a search over generated plans is required (exactly why the paper's
//! analytical cost model exists, R1).

use std::collections::HashMap;

use crate::api::{compile_with_meta, CompileOptions};
use crate::conf::{ClusterConfig, CostConstants, MB};
use crate::cost;
use crate::ir::build::MetaProvider;

/// One evaluated configuration.
#[derive(Clone, Debug)]
pub struct ResourcePoint {
    /// Client/task heap size in bytes.
    pub heap_bytes: f64,
    /// Estimated execution time.
    pub cost_secs: f64,
    /// Number of MR jobs in the generated plan.
    pub mr_jobs: usize,
}

/// Result of the sweep.
#[derive(Clone, Debug)]
pub struct ResourceChoice {
    pub best: ResourcePoint,
    pub frontier: Vec<ResourcePoint>,
}

/// Sweep client+task heap sizes and return the cost-optimal configuration.
pub fn optimize(
    src: &str,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    base_cc: &ClusterConfig,
    heaps_mb: &[f64],
) -> Result<ResourceChoice, String> {
    let mut frontier = Vec::new();
    for &h in heaps_mb {
        let mut cc = base_cc.clone();
        cc.cp_heap_bytes = h * MB;
        cc.map_heap_bytes = h * MB;
        cc.reduce_heap_bytes = h * MB;
        let opts = CompileOptions {
            cc: crate::api::ClusterConfigOpt(cc.clone()),
            ..Default::default()
        };
        let compiled = compile_with_meta(src, args, meta, &opts)?;
        let report =
            cost::cost_program(&compiled.runtime, &opts.cfg, &cc, &CostConstants::default());
        frontier.push(ResourcePoint {
            heap_bytes: h * MB,
            cost_secs: report.total,
            mr_jobs: compiled.runtime.mr_job_count(),
        });
    }
    let best = frontier
        .iter()
        .min_by(|a, b| a.cost_secs.partial_cmp(&b.cost_secs).unwrap())
        .cloned()
        .ok_or("empty sweep")?;
    Ok(ResourceChoice { best, frontier })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Scenario;

    #[test]
    fn larger_heap_moves_xs_plans_to_cp() {
        // With a tiny heap even XS needs MR; larger heaps give CP plans
        // with far lower estimated cost.
        let s = Scenario::xs();
        let choice = optimize(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &[64.0, 2048.0],
        )
        .unwrap();
        assert_eq!(choice.frontier.len(), 2);
        let small = &choice.frontier[0];
        let large = &choice.frontier[1];
        assert!(small.mr_jobs > 0, "64MB heap forces MR");
        assert_eq!(large.mr_jobs, 0, "2GB heap keeps XS in CP");
        assert!(large.cost_secs < small.cost_secs);
        assert_eq!(choice.best.heap_bytes, 2048.0 * MB);
    }

    #[test]
    fn frontier_preserves_sweep_order() {
        let s = Scenario::xs();
        let choice = optimize(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &[128.0, 512.0, 2048.0],
        )
        .unwrap();
        let heaps: Vec<f64> = choice.frontier.iter().map(|p| p.heap_bytes / MB).collect();
        assert_eq!(heaps, vec![128.0, 512.0, 2048.0]);
    }
}
