//! Resource optimization: pick the memory configuration minimising the
//! estimated execution time `C(P, cc)` — because plan *shape* changes with
//! budgets (CP vs MR, mapmm vs cpmm), cost is not monotone in resources and
//! a search over generated plans is required (exactly why the paper's
//! analytical cost model exists, R1).

use std::collections::HashMap;

use crate::api::{compile_with_meta, CompileOptions};
use crate::conf::{ClusterConfig, CostConstants, MB};
use crate::cost;
use crate::ir::build::MetaProvider;
use crate::rtprog::ExecBackend;

/// One evaluated configuration.
#[derive(Clone, Debug)]
pub struct ResourcePoint {
    /// Client/task heap size in bytes.
    pub heap_bytes: f64,
    /// Estimated execution time.
    pub cost_secs: f64,
    /// Number of MR jobs in the generated plan.
    pub mr_jobs: usize,
    /// Number of Spark jobs in the generated plan (Spark backend).
    pub spark_jobs: usize,
}

/// Result of the sweep.
#[derive(Clone, Debug)]
pub struct ResourceChoice {
    pub best: ResourcePoint,
    pub frontier: Vec<ResourcePoint>,
}

/// Sweep client+task heap sizes and return the cost-optimal configuration
/// (MR backend; see [`optimize_backend`] for the backend axis).
pub fn optimize(
    src: &str,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    base_cc: &ClusterConfig,
    heaps_mb: &[f64],
) -> Result<ResourceChoice, String> {
    optimize_backend(src, args, meta, base_cc, heaps_mb, ExecBackend::Mr)
}

/// Backend-parameterised heap sweep: generate and cost the plan per heap
/// size for the given backend. On the Spark backend the executor memory
/// scales with the heap axis too, so broadcast-feasibility flips are part
/// of the search space.
pub fn optimize_backend(
    src: &str,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    base_cc: &ClusterConfig,
    heaps_mb: &[f64],
    backend: ExecBackend,
) -> Result<ResourceChoice, String> {
    let spark_exec_ratio = base_cc.spark_executor_mem_bytes / base_cc.cp_heap_bytes;
    let mut frontier = Vec::new();
    for &h in heaps_mb {
        let mut cc = base_cc.clone();
        cc.cp_heap_bytes = h * MB;
        cc.map_heap_bytes = h * MB;
        cc.reduce_heap_bytes = h * MB;
        cc.spark_executor_mem_bytes = h * MB * spark_exec_ratio;
        let opts = CompileOptions {
            cc: crate::api::ClusterConfigOpt(cc.clone()),
            backend,
            ..Default::default()
        };
        let compiled = compile_with_meta(src, args, meta, &opts)?;
        let report =
            cost::cost_program(&compiled.runtime, &opts.cfg, &cc, &CostConstants::default());
        frontier.push(ResourcePoint {
            heap_bytes: h * MB,
            cost_secs: report.total,
            mr_jobs: compiled.runtime.mr_job_count(),
            spark_jobs: compiled.runtime.spark_job_count(),
        });
    }
    let best = frontier
        .iter()
        .min_by(|a, b| a.cost_secs.partial_cmp(&b.cost_secs).unwrap())
        .cloned()
        .ok_or("empty sweep")?;
    Ok(ResourceChoice { best, frontier })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Scenario;

    #[test]
    fn larger_heap_moves_xs_plans_to_cp() {
        // With a tiny heap even XS needs MR; larger heaps give CP plans
        // with far lower estimated cost.
        let s = Scenario::xs();
        let choice = optimize(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &[64.0, 2048.0],
        )
        .unwrap();
        assert_eq!(choice.frontier.len(), 2);
        let small = &choice.frontier[0];
        let large = &choice.frontier[1];
        assert!(small.mr_jobs > 0, "64MB heap forces MR");
        assert_eq!(large.mr_jobs, 0, "2GB heap keeps XS in CP");
        assert!(large.cost_secs < small.cost_secs);
        assert_eq!(choice.best.heap_bytes, 2048.0 * MB);
    }

    #[test]
    fn spark_backend_sweep_produces_spark_jobs() {
        let s = Scenario::xl1();
        let choice = optimize_backend(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &[2048.0],
            ExecBackend::Spark,
        )
        .unwrap();
        assert_eq!(choice.frontier[0].mr_jobs, 0);
        assert!(choice.frontier[0].spark_jobs > 0);
    }

    #[test]
    fn frontier_preserves_sweep_order() {
        let s = Scenario::xs();
        let choice = optimize(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &[128.0, 512.0, 2048.0],
        )
        .unwrap();
        let heaps: Vec<f64> = choice.frontier.iter().map(|p| p.heap_bytes / MB).collect();
        assert_eq!(heaps, vec![128.0, 512.0, 2048.0]);
    }
}
