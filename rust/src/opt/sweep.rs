//! Batched, parallel **scenario-sweep costing engine** — the paper's
//! Table-1 workflow, automated and scaled.
//!
//! The cost model's whole point (§1) is ranking *alternative* runtime
//! plans across scenarios, which only pays off when many plan/config
//! combinations can be costed cheaply. [`sweep`] takes a DML script plus
//! a grid of [`NamedCluster`] × [`DataScenario`] cells and:
//!
//! 1. computes a **plan signature** per cell — the exact subset of
//!    inputs that can influence the *shape* of the generated runtime
//!    plan (data dimensions, block size, memory budgets, partition
//!    size, reducer/replication settings, operator hints). Cluster
//!    knobs that only affect *cost*, never plan shape (clock rate,
//!    map/reduce slots, HDFS block size, node counts), are excluded;
//! 2. routes the grid through the **unified candidate evaluator**
//!    ([`crate::opt::evaluate`]): one memoized parallel compile per
//!    distinct signature (`Arc`-shared plans), duplicate-cost skipping,
//!    and block-level cost caching ([`crate::cost::cache`]) on the
//!    totals-only costing fast path;
//! 3. costs **every** cell against its own full cluster configuration
//!    (so two clusters sharing a plan still get distinct cost
//!    estimates);
//! 4. returns a [`SweepReport`] with a deterministic cheapest-first
//!    ranking and a ready-to-print comparison table.
//!
//! Entry points: [`sweep`] (parallel + memoized + cached),
//! [`sweep_serial`] (reference implementation: one `compile` + `cost`
//! per cell, no memoization and no caching — the baseline the `sweep`
//! bench compares against), and the `repro sweep` CLI subcommand /
//! [`crate::api::sweep`] wrapper.

use std::collections::HashMap;
use std::time::Instant;

use crate::api::{
    compile_with_meta, linreg_cg_args, ClusterConfigOpt, CompileOptions, CompiledProgram,
    Scenario, LINREG_CG, LINREG_DS,
};
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig, MB};
use crate::cost;
use crate::ir::build::StaticMeta;
use crate::lop::SelectionHints;
use crate::matrix::{Format, MatrixCharacteristics};
use crate::rtprog::ExecBackend;
use crate::util::fmt::{fmt_dim, fmt_secs};
use crate::util::par;

use super::evaluate::{Candidate, CostContext, Evaluated, Evaluator};

/// A cluster configuration with a display name, one axis of the grid.
#[derive(Clone, Debug)]
pub struct NamedCluster {
    /// Label used in the ranked table (e.g. `paper-2048MB`).
    pub name: String,
    /// Full cluster characteristics passed to compilation and costing.
    pub cc: ClusterConfig,
}

impl NamedCluster {
    /// Name a cluster configuration.
    pub fn new(name: impl Into<String>, cc: ClusterConfig) -> Self {
        NamedCluster { name: name.into(), cc }
    }
}

/// A data-size scenario, the other axis of the grid: static metadata for
/// every persistent input the script `read()`s.
#[derive(Clone, Debug)]
pub struct DataScenario {
    /// Label used in the ranked table (e.g. `XL1`).
    pub name: String,
    /// `(read path, rows, cols)` per persistent input, dense binary-block.
    pub inputs: Vec<(String, i64, i64)>,
}

impl DataScenario {
    /// Scenario over explicit `(path, rows, cols)` inputs.
    pub fn new(name: impl Into<String>, inputs: Vec<(String, i64, i64)>) -> Self {
        DataScenario { name: name.into(), inputs }
    }

    /// LinReg-shaped scenario: `data/X` is `rows x cols`, `data/y` is
    /// `rows x 1` (the paper's Table-1 convention).
    pub fn linreg(name: impl Into<String>, rows: i64, cols: i64) -> Self {
        DataScenario {
            name: name.into(),
            inputs: vec![
                ("data/X".to_string(), rows, cols),
                ("data/y".to_string(), rows, 1),
            ],
        }
    }

    /// Total input cells across all inputs (proxy for problem size).
    pub fn total_cells(&self) -> f64 {
        self.inputs.iter().map(|&(_, r, c)| r as f64 * c as f64).sum()
    }

    /// Static metadata for compilation at the given block size.
    pub fn meta(&self, blocksize: i64) -> StaticMeta {
        self.meta_fmt(blocksize, Format::BinaryBlock)
    }

    /// Static metadata at an explicit block size *and* on-disk format —
    /// the two per-cut data-flow properties the global data flow
    /// optimizer ([`crate::opt::gdf`]) enumerates. [`Self::meta`] is the
    /// binary-block default.
    pub fn meta_fmt(&self, blocksize: i64, format: Format) -> StaticMeta {
        let mut m = StaticMeta::default();
        for (path, r, c) in &self.inputs {
            m = m.with(path, MatrixCharacteristics::dense(*r, *c, blocksize), format);
        }
        m
    }
}

impl From<&Scenario> for DataScenario {
    fn from(s: &Scenario) -> Self {
        DataScenario::linreg(s.name, s.x_rows, s.x_cols)
    }
}

/// Build the standard heap × clock cluster grid: for every heap size,
/// a `paper-<N>MB` variant of the paper cluster with all three heaps set
/// to `N` MB, plus a `fast-<N>MB` sibling with double the clock rate.
/// The fast sibling differs only in a cost-side knob, so it shares plan
/// signatures with its paper twin (exercising compile memoization).
/// Used by [`SweepSpec::linreg_default`], the `repro sweep` CLI, the
/// sweep tests and the sweep bench.
pub fn heap_clock_clusters(heaps_mb: &[f64]) -> Vec<NamedCluster> {
    let mut clusters = Vec::with_capacity(heaps_mb.len() * 2);
    for &heap_mb in heaps_mb {
        let mut cc = ClusterConfig::paper_cluster();
        cc.cp_heap_bytes = heap_mb * MB;
        cc.map_heap_bytes = heap_mb * MB;
        cc.reduce_heap_bytes = heap_mb * MB;
        clusters.push(NamedCluster::new(format!("paper-{}MB", heap_mb as i64), cc.clone()));
        cc.clock_hz *= 2.0;
        clusters.push(NamedCluster::new(format!("fast-{}MB", heap_mb as i64), cc));
    }
    clusters
}

/// Full sweep specification: script + argument bindings + the grid.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// DML source to compile per cell.
    pub script: String,
    /// `$N` command-line bindings for the script.
    pub args: HashMap<usize, String>,
    /// Cluster axis of the grid.
    pub clusters: Vec<NamedCluster>,
    /// Data-size axis of the grid.
    pub scenarios: Vec<DataScenario>,
    /// Compiler/system configuration shared by all cells.
    pub cfg: SystemConfig,
    /// Physical-operator selection hints shared by all cells.
    pub hints: SelectionHints,
    /// Cost-model constants shared by all cells.
    pub constants: CostConstants,
    /// Failure profile shared by all cells (`repro sweep
    /// --fault-profile`). [`FaultProfile::none`] keeps every estimate
    /// bitwise-identical to fault-free costing; a nonzero profile prices
    /// retries, backoff, and straggler tails into distributed cells.
    pub fault: FaultProfile,
    /// Execution-backend axis of the grid (CP / MR / Spark plan
    /// families; `repro sweep --backends cp,mr,spark`).
    pub backends: Vec<ExecBackend>,
    /// Enable the block-level cost cache ([`crate::cost::cache`]).
    /// Results are bitwise identical either way; disable only for A/B
    /// measurements (`repro sweep --no-cost-cache`, the costcache
    /// bench).
    pub cost_cache: bool,
    /// Worker threads; `0` = available parallelism.
    pub threads: usize,
    /// Statically verify the winning cell's plan ([`crate::analysis`])
    /// after ranking (`repro sweep --verify`). Error-severity
    /// diagnostics fail the sweep; the report carries the audit.
    pub verify: bool,
}

impl SweepSpec {
    /// The default grid for the LinReg DS running example: the paper's
    /// five Table-1 data scenarios × eight cluster configurations (four
    /// heap sizes, each in a normal and a double-clock variant — the
    /// clock variant shares plan shapes with its sibling, exercising the
    /// compile memoization) × the MR backend. 40 cells, 20 distinct plan
    /// shapes.
    pub fn linreg_default() -> Self {
        SweepSpec {
            script: LINREG_DS.to_string(),
            args: Scenario::xs().args(),
            clusters: heap_clock_clusters(&[512.0, 1024.0, 2048.0, 4096.0]),
            scenarios: Scenario::all().iter().map(DataScenario::from).collect(),
            cfg: SystemConfig::default(),
            hints: SelectionHints::default(),
            constants: CostConstants::default(),
            fault: FaultProfile::none(),
            backends: vec![ExecBackend::Mr],
            cost_cache: true,
            threads: 0,
            verify: false,
        }
    }

    /// The iterative LinReg CG grid: the loop-heavy script where per-job
    /// latency dominates distributed plans, swept across all three
    /// backends by default (`--script cg`). `iterations` binds the CG
    /// loop's trip count (`$3`).
    pub fn linreg_cg(iterations: usize) -> Self {
        SweepSpec {
            script: LINREG_CG.to_string(),
            args: linreg_cg_args(iterations),
            backends: ExecBackend::all().to_vec(),
            ..Self::linreg_default()
        }
    }

    /// Number of grid cells.
    pub fn cell_count(&self) -> usize {
        self.clusters.len() * self.scenarios.len() * self.backends.len().max(1)
    }
}

/// One costed grid cell.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Cluster label.
    pub cluster: String,
    /// Scenario label.
    pub scenario: String,
    /// Backend label (`cp`, `mr`, `spark`).
    pub backend: String,
    /// Rows of the scenario's first input (display).
    pub x_rows: i64,
    /// Cols of the scenario's first input (display).
    pub x_cols: i64,
    /// Total input cells of the scenario.
    pub input_cells: f64,
    /// CP instruction count of the generated plan.
    pub cp_insts: usize,
    /// MR-job count of the generated plan.
    pub mr_jobs: usize,
    /// Spark-job count of the generated plan.
    pub spark_jobs: usize,
    /// Estimated execution time `C(P, cc)` in seconds.
    pub cost_secs: f64,
    /// Plan-shape signature this cell compiled (or reused) under.
    pub plan_sig: String,
    /// Whether this cell reused a plan compiled for an earlier cell.
    pub plan_reused: bool,
}

/// Result of a sweep: costed cells plus a deterministic ranking.
#[derive(Clone, Debug)]
pub struct SweepReport {
    /// All cells in grid (cluster-major) order.
    pub cells: Vec<SweepCell>,
    /// Indices into `cells`, cheapest first; ties broken by scenario
    /// then cluster name so the ranking is fully deterministic.
    pub ranking: Vec<usize>,
    /// Number of distinct plan shapes compiled.
    pub distinct_plans: usize,
    /// Cells that reused a memoized plan (`cells.len() - distinct_plans`).
    pub memo_hits: usize,
    /// Wall-clock seconds spent in the sweep.
    pub wall_secs: f64,
    /// Worker threads used.
    pub threads: usize,
    /// Static verification of the winning (rank-1) cell's plan, present
    /// when the spec asked for it. Always clean — a dirty winner fails
    /// the sweep instead.
    pub verify: Option<crate::analysis::VerifyReport>,
}

impl SweepReport {
    /// Cells in ranked (cheapest-first) order.
    pub fn ranked(&self) -> impl Iterator<Item = &SweepCell> {
        self.ranking.iter().map(move |&i| &self.cells[i])
    }

    /// Ranked plan-comparison table (deterministic — no timings). The
    /// `jobs` column counts distributed jobs (MR or Spark, per backend).
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<5} {:<10} {:<14} {:<7} {:>15} {:>5} {:>5} {:>12} {:>6}\n",
            "rank", "scenario", "cluster", "backend", "X dims", "jobs", "CP", "est. cost", "plan"
        ));
        out.push_str(&"-".repeat(92));
        out.push('\n');
        for (rank, c) in self.ranked().enumerate() {
            out.push_str(&format!(
                "{:<5} {:<10} {:<14} {:<7} {:>7}x{:<7} {:>5} {:>5} {:>12} {:>6}\n",
                rank + 1,
                c.scenario,
                c.cluster,
                c.backend,
                fmt_dim(c.x_rows),
                fmt_dim(c.x_cols),
                c.mr_jobs + c.spark_jobs,
                c.cp_insts,
                fmt_secs(c.cost_secs),
                if c.plan_reused { "memo" } else { "fresh" }
            ));
        }
        out
    }

    /// One-line execution summary (includes wall time — not part of the
    /// deterministic table).
    pub fn summary(&self) -> String {
        format!(
            "costed {} cells in {:.3}s on {} threads; {} distinct plan shapes compiled, {} memoized",
            self.cells.len(),
            self.wall_secs,
            self.threads,
            self.distinct_plans,
            self.memo_hits
        )
    }
}

/// Signature of everything that can influence the *shape* of the
/// generated plan for one cell. Two cells with equal signatures compile
/// to identical runtime plans, so the compile is shared between them.
///
/// Includes: input dims, the execution backend (CP/MR/Spark plan
/// families differ structurally), block size, sparse threshold,
/// memory-budget ratio, the three heap sizes (budgets drive CP-vs-MR
/// selection and mapmm feasibility), the Spark executor memory (drives
/// broadcast feasibility on the Spark backend), partition size, reducer
/// count, replication, unknown-iteration constant, and the selection
/// hints. Excludes the cost-only knobs: clock rate, slot counts,
/// node/vcore/YARN geometry, HDFS block size, and `k_local`.
///
/// Shared with the grid resource optimizer ([`crate::opt::resource`]),
/// whose node/`k_local` axes are cost-only and therefore memo-friendly.
///
/// The leading `sc<hash>` component fingerprints the script text and
/// its `$N` bindings: one [`crate::opt::evaluate::PlanMemo`] may back
/// requests over *different* scripts (the serve daemon shares a memo
/// across all requests), so plan identity must cover the program
/// source, not just its configuration.
pub(crate) fn plan_signature(
    script: &str,
    args: &HashMap<usize, String>,
    cfg: &SystemConfig,
    hints: &SelectionHints,
    cc: &ClusterConfig,
    scenario: &DataScenario,
    backend: ExecBackend,
) -> String {
    let mut sig = format!("sc{:016x};", script_fingerprint(script, args));
    for (path, r, c) in &scenario.inputs {
        sig.push_str(&format!("{path}={r}x{c};"));
    }
    sig.push_str(&format!(
        "be{};bs{};st{};ratio{};cp{};map{};red{};sx{};part{};nr{};rep{};ui{};h{}{}{}",
        backend.name(),
        cfg.blocksize,
        cfg.sparse_threshold,
        cfg.mem_budget_ratio,
        cc.cp_heap_bytes,
        cc.map_heap_bytes,
        cc.reduce_heap_bytes,
        cc.spark_executor_mem_bytes,
        cfg.partition_bytes,
        cfg.num_reducers,
        cfg.replication,
        cfg.unknown_iterations,
        hints.force_cpmm as u8,
        hints.force_rmm as u8,
        hints.no_transpose_rewrite as u8
    ));
    sig
}

/// Order-independent fingerprint of a script's source text and its
/// `$N` bindings (the plan-identity component of [`plan_signature`]).
pub(crate) fn script_fingerprint(script: &str, args: &HashMap<usize, String>) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    script.hash(&mut h);
    let mut bound: Vec<(&usize, &String)> = args.iter().collect();
    bound.sort();
    for (k, v) in bound {
        k.hash(&mut h);
        v.hash(&mut h);
    }
    h.finish()
}

/// One grid cell viewed as an evaluator candidate (the adapter the
/// unified evaluation core consumes).
struct CellCand<'a> {
    spec: &'a SweepSpec,
    ci: usize,
    si: usize,
    bi: usize,
}

impl Candidate for CellCand<'_> {
    fn signature(&self) -> String {
        plan_signature(
            &self.spec.script,
            &self.spec.args,
            &self.spec.cfg,
            &self.spec.hints,
            &self.spec.clusters[self.ci].cc,
            &self.spec.scenarios[self.si],
            self.spec.backends[self.bi],
        )
    }
    fn compile(&self) -> Result<CompiledProgram, String> {
        compile_cell(self.spec, self.ci, self.si, self.bi)
    }
    fn context(&self) -> CostContext<'_> {
        CostContext {
            cfg: &self.spec.cfg,
            cc: &self.spec.clusters[self.ci].cc,
            constants: &self.spec.constants,
            fault: &self.spec.fault,
        }
    }
    fn label(&self) -> String {
        format!(
            "scenario '{}' on cluster '{}' backend '{}'",
            self.spec.scenarios[self.si].name,
            self.spec.clusters[self.ci].name,
            self.spec.backends[self.bi].name()
        )
    }
}

fn compile_cell(
    spec: &SweepSpec,
    ci: usize,
    si: usize,
    bi: usize,
) -> Result<CompiledProgram, String> {
    let opts = CompileOptions {
        cfg: spec.cfg.clone(),
        cc: ClusterConfigOpt(spec.clusters[ci].cc.clone()),
        hints: spec.hints.clone(),
        backend: spec.backends[bi],
    };
    compile_with_meta(
        &spec.script,
        &spec.args,
        &spec.scenarios[si].meta(spec.cfg.blocksize),
        &opts,
    )
    .map_err(|e| {
        format!(
            "compile failed for cluster '{}' scenario '{}' backend '{}': {e}",
            spec.clusters[ci].name,
            spec.scenarios[si].name,
            spec.backends[bi].name()
        )
    })
}

fn grid_of(spec: &SweepSpec) -> Vec<(usize, usize, usize)> {
    let mut grid = Vec::with_capacity(spec.cell_count());
    for ci in 0..spec.clusters.len() {
        for si in 0..spec.scenarios.len() {
            for bi in 0..spec.backends.len() {
                grid.push((ci, si, bi));
            }
        }
    }
    grid
}

fn cost_cell(
    spec: &SweepSpec,
    ci: usize,
    si: usize,
    bi: usize,
    prog: &CompiledProgram,
    sig: &str,
    reused: bool,
) -> SweepCell {
    let report = cost::cost_program_faults(
        &prog.runtime,
        &spec.cfg,
        &spec.clusters[ci].cc,
        &spec.constants,
        &spec.fault,
    );
    let (cp, mr, sp) = prog.runtime.size3();
    let sc = &spec.scenarios[si];
    SweepCell {
        cluster: spec.clusters[ci].name.clone(),
        scenario: sc.name.clone(),
        backend: spec.backends[bi].name().to_string(),
        x_rows: sc.inputs.first().map(|&(_, r, _)| r).unwrap_or(0),
        x_cols: sc.inputs.first().map(|&(_, _, c)| c).unwrap_or(0),
        input_cells: sc.total_cells(),
        cp_insts: cp,
        mr_jobs: mr,
        spark_jobs: sp,
        cost_secs: report.total,
        plan_sig: sig.to_string(),
        plan_reused: reused,
    }
}

fn rank(cells: &[SweepCell]) -> Vec<usize> {
    let mut ranking: Vec<usize> = (0..cells.len()).collect();
    ranking.sort_by(|&a, &b| {
        cells[a]
            .cost_secs
            .total_cmp(&cells[b].cost_secs)
            .then_with(|| cells[a].scenario.cmp(&cells[b].scenario))
            .then_with(|| cells[a].cluster.cmp(&cells[b].cluster))
            // backends that tie on cost rank single-node first (`cp` <
            // `mr` < `spark`): when the data fits the heap all three
            // backends agree on the pure-CP plan, and the table should
            // put the backend with no framework overhead on top.
            .then_with(|| cells[a].backend.cmp(&cells[b].backend))
    });
    ranking
}

/// Reject empty grids and degenerate cluster/constant configurations
/// before any compile: a zero heap or zero disk bandwidth would
/// otherwise surface as NaN costs deep inside the ranking.
fn validate_spec(spec: &SweepSpec) -> Result<(), String> {
    if spec.clusters.is_empty() || spec.scenarios.is_empty() || spec.backends.is_empty() {
        return Err("empty sweep grid (no clusters, scenarios or backends)".to_string());
    }
    for c in &spec.clusters {
        c.cc.validate().map_err(|e| format!("cluster '{}': {e}", c.name))?;
    }
    spec.constants.validate()?;
    spec.fault.validate()
}

/// Reject non-finite cost estimates with a diagnostic naming the cell
/// instead of letting NaN poison the (total_cmp) ranking.
fn check_finite(cells: &[SweepCell]) -> Result<(), String> {
    for c in cells {
        if !c.cost_secs.is_finite() {
            return Err(format!(
                "non-finite cost estimate ({}) for scenario '{}' on cluster '{}' backend '{}'",
                c.cost_secs, c.scenario, c.cluster, c.backend
            ));
        }
    }
    Ok(())
}

/// Build a [`SweepCell`] from the evaluator's outcome for one cell.
fn cell_from_eval(spec: &SweepSpec, ci: usize, si: usize, bi: usize, ev: &Evaluated) -> SweepCell {
    let sc = &spec.scenarios[si];
    SweepCell {
        cluster: spec.clusters[ci].name.clone(),
        scenario: sc.name.clone(),
        backend: spec.backends[bi].name().to_string(),
        x_rows: sc.inputs.first().map(|&(_, r, _)| r).unwrap_or(0),
        x_cols: sc.inputs.first().map(|&(_, _, c)| c).unwrap_or(0),
        input_cells: sc.total_cells(),
        cp_insts: ev.cp_insts,
        mr_jobs: ev.mr_jobs,
        spark_jobs: ev.spark_jobs,
        cost_secs: ev.cost_secs,
        plan_sig: ev.sig.to_string(),
        plan_reused: ev.plan_reused,
    }
}

/// Run the sweep through the unified candidate evaluator
/// ([`crate::opt::evaluate`]): compile once per distinct plan shape
/// (parallel, `Arc`-shared), cost every cell concurrently through the
/// block-level cost cache, and rank. See the module docs for the
/// pipeline; [`sweep_serial`] is the unmemoized serial reference.
pub fn sweep(spec: &SweepSpec) -> Result<SweepReport, String> {
    let threads = if spec.threads == 0 { par::default_threads() } else { spec.threads };
    let mut eval = if spec.cost_cache {
        Evaluator::new(threads)
    } else {
        Evaluator::without_cost_cache(threads)
    };
    sweep_with(spec, &mut eval)
}

/// [`sweep`] over a caller-provided evaluator: reruns keep the compile
/// memo and cost cache warm, and a cache pre-loaded from a
/// [`crate::artifact::CacheSnapshot`] (`--warm-cache`) replays earlier
/// block costings from disk. `spec.threads`/`spec.cost_cache` are
/// ignored — the evaluator already fixes both.
pub fn sweep_with(spec: &SweepSpec, eval: &mut Evaluator) -> Result<SweepReport, String> {
    let t0 = Instant::now();
    validate_spec(spec)?;
    let threads = eval.threads();
    let grid = grid_of(spec);
    let cands: Vec<CellCand> =
        grid.iter().map(|&(ci, si, bi)| CellCand { spec, ci, si, bi }).collect();
    eval.begin_run();
    let evaluated = eval.evaluate(&cands)?;
    let cells: Vec<SweepCell> = grid
        .iter()
        .zip(&evaluated)
        .map(|(&(ci, si, bi), ev)| cell_from_eval(spec, ci, si, bi, ev))
        .collect();

    let ranking = rank(&cells);
    let verify = if spec.verify {
        let win = ranking[0];
        let (ci, _, bi) = grid[win];
        let report = crate::analysis::verify_faults(
            &evaluated[win].plan.runtime,
            &spec.cfg,
            &spec.clusters[ci].cc,
            &spec.constants,
            &spec.fault,
            spec.backends[bi],
        );
        if !report.is_clean() {
            return Err(format!(
                "plan verification failed for winning cell (scenario '{}' on cluster '{}' \
                 backend '{}'): {} error(s)\n{}",
                cells[win].scenario,
                cells[win].cluster,
                cells[win].backend,
                report.errors(),
                report.render()
            ));
        }
        Some(report)
    } else {
        None
    };
    // counted from the reuse flags, not `cells - distinct`: a shared
    // memo (serve daemon) may hold more plans than this run's cells
    let memo_hits = evaluated.iter().filter(|e| e.plan_reused).count();
    Ok(SweepReport {
        memo_hits,
        distinct_plans: eval.distinct_plans(),
        cells,
        ranking,
        wall_secs: t0.elapsed().as_secs_f64(),
        threads,
        verify,
    })
}

/// Serial reference: one full `compile` + `cost` per cell, no plan
/// memoization and no worker threads. Produces bit-identical cells and
/// ranking to [`sweep`] (compilation is deterministic); exists as the
/// baseline for the `sweep` bench and as a cross-check in tests.
pub fn sweep_serial(spec: &SweepSpec) -> Result<SweepReport, String> {
    let t0 = Instant::now();
    validate_spec(spec)?;
    let grid = grid_of(spec);
    let sigs: Vec<String> = grid
        .iter()
        .map(|&(ci, si, bi)| {
            plan_signature(
                &spec.script,
                &spec.args,
                &spec.cfg,
                &spec.hints,
                &spec.clusters[ci].cc,
                &spec.scenarios[si],
                spec.backends[bi],
            )
        })
        .collect();
    let mut seen: HashMap<&str, usize> = HashMap::new();
    let mut distinct_plans = 0usize;
    let mut cells = Vec::with_capacity(grid.len());
    for (i, &(ci, si, bi)) in grid.iter().enumerate() {
        let prog = compile_cell(spec, ci, si, bi)?;
        let reused = match seen.get(sigs[i].as_str()) {
            Some(_) => true,
            None => {
                seen.insert(sigs[i].as_str(), i);
                distinct_plans += 1;
                false
            }
        };
        cells.push(cost_cell(spec, ci, si, bi, &prog, &sigs[i], reused));
    }
    check_finite(&cells)?;
    let ranking = rank(&cells);
    Ok(SweepReport {
        memo_hits: cells.len() - distinct_plans,
        distinct_plans,
        cells,
        ranking,
        wall_secs: t0.elapsed().as_secs_f64(),
        threads: 1,
        verify: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        let mut spec = SweepSpec::linreg_default();
        spec.scenarios = vec![
            DataScenario::linreg("XS", 10_000, 1_000),
            DataScenario::linreg("XL1", 100_000_000, 1_000),
        ];
        spec.clusters.truncate(4); // paper-512MB, fast-512MB, paper-1024MB, fast-1024MB
        spec
    }

    #[test]
    fn default_grid_is_large_enough() {
        let spec = SweepSpec::linreg_default();
        assert!(spec.cell_count() >= 12, "acceptance floor: {}", spec.cell_count());
        assert_eq!(spec.cell_count(), 40);
    }

    #[test]
    fn clock_only_variants_share_plan_signatures() {
        let spec = tiny_spec();
        let r = sweep(&spec).unwrap();
        assert_eq!(r.cells.len(), 8);
        // fast-* differs from paper-* only in clock -> plans shared
        assert_eq!(r.distinct_plans, 4, "{:#?}", r.cells);
        assert_eq!(r.memo_hits, 4);
        // but cost estimates still differ where compute matters (XS is
        // compute-dominated by tsmm)
        let cost_of = |cl: &str, sc: &str| {
            r.cells
                .iter()
                .find(|c| c.cluster == cl && c.scenario == sc)
                .unwrap()
                .cost_secs
        };
        assert!(cost_of("fast-1024MB", "XS") < cost_of("paper-1024MB", "XS"));
    }

    #[test]
    fn first_occurrence_is_fresh_later_reuses() {
        let r = sweep(&tiny_spec()).unwrap();
        // cluster-major order: paper-512MB cells come first and compile
        // fresh; the fast-512MB cells reuse them
        for c in &r.cells {
            if c.cluster.starts_with("paper-512") {
                assert!(!c.plan_reused, "{c:?}");
            }
            if c.cluster.starts_with("fast-512") {
                assert!(c.plan_reused, "{c:?}");
            }
        }
    }

    #[test]
    fn ranking_is_cheapest_first() {
        let r = sweep(&tiny_spec()).unwrap();
        let costs: Vec<f64> = r.ranked().map(|c| c.cost_secs).collect();
        assert!(costs.windows(2).all(|w| w[0] <= w[1]), "{costs:?}");
        // XS on the fastest cluster must beat every XL1 cell
        let first = r.ranked().next().unwrap();
        assert_eq!(first.scenario, "XS");
    }

    #[test]
    fn table_lists_every_cell_once() {
        let r = sweep(&tiny_spec()).unwrap();
        let table = r.table();
        // header + separator + one row per cell
        assert_eq!(table.lines().count(), 2 + r.cells.len(), "{table}");
        assert!(table.contains("est. cost"));
        assert!(table.contains("memo"));
        assert!(table.contains("fresh"));
    }

    #[test]
    fn empty_grid_is_an_error() {
        let mut spec = tiny_spec();
        spec.scenarios.clear();
        assert!(sweep(&spec).is_err());
        assert!(sweep_serial(&spec).is_err());
        let mut spec = tiny_spec();
        spec.backends.clear();
        assert!(sweep(&spec).is_err());
        assert!(sweep_serial(&spec).is_err());
    }

    #[test]
    fn degenerate_cluster_is_rejected_not_ranked() {
        // NaN-safe ranking: a zero heap used to reach `min_by` as NaN
        // costs; now it is rejected at the entry point with a diagnostic.
        let mut spec = tiny_spec();
        spec.clusters[0].cc.cp_heap_bytes = 0.0;
        let err = sweep(&spec).unwrap_err();
        assert!(err.contains("cp_heap_bytes"), "{err}");
        assert!(sweep_serial(&spec).is_err());
        let mut spec = tiny_spec();
        spec.clusters[1].cc.k_local = 0;
        let err = sweep(&spec).unwrap_err();
        assert!(err.contains("k_local"), "{err}");
        let mut spec = tiny_spec();
        spec.constants.hdfs_read_binaryblock = 0.0;
        assert!(sweep(&spec).is_err());
    }

    #[test]
    fn verify_flag_audits_the_winning_cell() {
        let mut spec = tiny_spec();
        spec.verify = true;
        let r = sweep(&spec).unwrap();
        let v = r.verify.as_ref().expect("verify requested");
        assert!(v.is_clean(), "{}", v.render());
        assert_eq!(v.backend.name(), r.ranked().next().unwrap().backend);
        // without the flag no audit is run
        spec.verify = false;
        assert!(sweep(&spec).unwrap().verify.is_none());
    }

    #[test]
    fn fault_profile_prices_failures_in_both_sweep_paths() {
        // none() must be a bitwise no-op relative to the default spec.
        let base = sweep(&tiny_spec()).unwrap();
        let mut spec = tiny_spec();
        spec.fault = FaultProfile::none();
        let none = sweep(&spec).unwrap();
        for (a, b) in base.cells.iter().zip(&none.cells) {
            assert_eq!(a.cost_secs.to_bits(), b.cost_secs.to_bits(), "{a:?}");
        }
        // chaos inflates MR cells, leaves pure-CP cells untouched, and
        // the serial reference stays bitwise-equal to the parallel path.
        spec.fault = FaultProfile::chaos();
        let chaos = sweep(&spec).unwrap();
        let chaos_serial = sweep_serial(&spec).unwrap();
        for ((b, c), cs) in base.cells.iter().zip(&chaos.cells).zip(&chaos_serial.cells) {
            assert_eq!(c.cost_secs.to_bits(), cs.cost_secs.to_bits(), "{c:?}");
            if c.mr_jobs + c.spark_jobs == 0 {
                assert_eq!(b.cost_secs.to_bits(), c.cost_secs.to_bits(), "{c:?}");
            } else {
                assert!(c.cost_secs > b.cost_secs, "{c:?} vs {b:?}");
            }
        }
        // a degenerate profile is rejected at the entry point
        spec.fault.mr_fail_p = 1.5;
        assert!(sweep(&spec).unwrap_err().contains("FaultProfile"));
    }

    #[test]
    fn backend_axis_multiplies_grid_and_plans() {
        let mut spec = tiny_spec();
        spec.backends = ExecBackend::all().to_vec();
        assert_eq!(spec.cell_count(), 24);
        let r = sweep(&spec).unwrap();
        assert_eq!(r.cells.len(), 24);
        // 4 (cluster-heap x scenario) plan shapes per backend
        assert_eq!(r.distinct_plans, 12, "{:#?}", r.cells);
        // every backend appears in the table
        let table = r.table();
        assert!(table.contains("backend"));
        for b in ExecBackend::all() {
            assert!(r.cells.iter().any(|c| c.backend == b.name()), "{}", b.name());
        }
    }
}
