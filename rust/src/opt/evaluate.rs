//! The **unified candidate evaluator** — the shared core all three
//! optimizers (scenario sweep, grid resource optimizer, global data flow
//! optimizer) route their candidate fan-out through.
//!
//! Each optimizer enumerates a family of candidates (grid cells, grid
//! points, data-flow configurations) and needs the same four-stage
//! pipeline per batch:
//!
//! 1. **Signature dedupe** — candidates whose plan-shape signature was
//!    already seen share one compiled plan (the memoization the sweep
//!    engine introduced, now `Arc`-shared instead of referenced by
//!    index into an optimizer-local store).
//! 2. **Memoized parallel compile** — distinct missing signatures fan
//!    out over the scoped thread pool; each compiled plan is paired with
//!    its precomputed structural hash tree
//!    ([`crate::cost::cache::program_hashes`]), so later costings pay no
//!    per-plan hashing.
//! 3. **Duplicate-cost skip** — two candidates with structurally
//!    identical plans *and* identical cost-relevant configuration knobs
//!    (e.g. GDF candidates on the partition axis whose plans contain no
//!    MR job, or resource grid points that differ only in `k_local` on
//!    a parfor-free plan) have bitwise-identical cost; only the first
//!    occurrence in a run is costed, the rest copy its result.
//! 4. **Cached concurrent costing + NaN checks** — surviving candidates
//!    are costed through the block-level cost cache
//!    ([`crate::cost::cache::CostCache`]) on the totals-only fast path,
//!    and non-finite estimates surface as diagnostics naming the
//!    candidate instead of poisoning a ranking downstream.
//!
//! Every stage preserves bitwise determinism: results are independent of
//! thread count and of whether the cache or the duplicate skip fired
//! (`tests/costcache.rs` asserts this across optimizers).
//!
//! Two pieces serve the multi-tenant daemon ([`crate::serve`]):
//!
//! - [`PlanMemo`] is internally synchronized (sharded mutexes, like
//!   [`CostCache`]) so one memo can back many concurrent evaluators via
//!   [`Evaluator::with_parts`]; only completed, valid compiles are ever
//!   published, so a failed batch never poisons sharers.
//! - [`Budget`] is a cooperative wall-clock/candidate-count bound checked
//!   between candidate evaluations ([`Evaluator::set_budget`]); exhaustion
//!   surfaces as a [`BUDGET_ERROR_PREFIX`]-tagged error whose
//!   machine-readable reason [`budget_error_reason`] recovers.

use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::CompiledProgram;
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::cost::{self, cache};
use crate::cost::cache::{CacheStats, CostCache, ProgramHashes};
use crate::util::par;

/// Borrowed costing context of one candidate: the three configuration
/// objects `cost_program` reads. Different candidates of one batch may
/// carry different contexts (the sweep costs one shared plan under many
/// clusters; GDF costs each candidate under its base `SystemConfig`).
#[derive(Clone, Copy)]
pub struct CostContext<'a> {
    /// Compiler/system configuration the candidate is costed under.
    pub cfg: &'a SystemConfig,
    /// Cluster characteristics `cc` of the candidate.
    pub cc: &'a ClusterConfig,
    /// White-box cost-model constants.
    pub constants: &'a CostConstants,
    /// Failure profile the candidate is costed under. `FaultProfile::none()`
    /// keeps costing bitwise-identical to the fault-free model; a nonzero
    /// profile prices geometric retries, backoff, and straggler tails into
    /// every distributed job (and into the cost-cache knob fingerprint, so
    /// faulty and fault-free entries never alias).
    pub fault: &'a FaultProfile,
}

/// One candidate of a batch evaluation. Implementations are thin
/// adapters over each optimizer's native candidate representation.
pub trait Candidate: Sync {
    /// Plan-shape signature: equal signatures must compile to identical
    /// runtime plans (the memoization contract).
    fn signature(&self) -> String;
    /// Compile the candidate's runtime plan (called once per distinct
    /// signature, possibly on a worker thread).
    fn compile(&self) -> Result<CompiledProgram, String>;
    /// The configuration the candidate is costed against.
    fn context(&self) -> CostContext<'_>;
    /// Label used in diagnostics (e.g. the non-finite-cost error).
    fn label(&self) -> String;
}

/// Outcome of evaluating one candidate.
#[derive(Clone)]
pub struct Evaluated {
    /// The compiled plan, shared (`Arc`) with every candidate of equal
    /// signature instead of cloned per consumer.
    pub plan: Arc<CompiledProgram>,
    /// Whether the plan was reused from an earlier candidate rather than
    /// compiled for this one.
    pub plan_reused: bool,
    /// Estimated execution time `C(P, cc)` in seconds (always finite —
    /// non-finite estimates abort the batch with a diagnostic).
    pub cost_secs: f64,
    /// CP instruction count of the plan.
    pub cp_insts: usize,
    /// MR-job count of the plan.
    pub mr_jobs: usize,
    /// Spark-job count of the plan.
    pub spark_jobs: usize,
    /// Whether costing was skipped because an earlier candidate of this
    /// run had a structurally identical plan under identical
    /// cost-relevant knobs (the result is a bitwise copy).
    pub cost_reused: bool,
    /// The candidate's plan signature (shared allocation).
    pub sig: Arc<str>,
}

#[derive(Clone, Copy)]
struct CostStats {
    total: f64,
    cp: usize,
    mr: usize,
    sp: usize,
}

/// Duplicate-cost key: 128-bit structural program hash × 128-bit
/// cost-relevant knob fingerprint. The fingerprint covers the
/// [`CostConstants`], so candidates re-costed after online calibration
/// ([`crate::feedback`]) never alias their pre-calibration entries.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct CostKey(u64, u64, u64, u64);

/// Stable machine-readable prefix every budget-exhaustion error starts
/// with; the remainder begins with the reason code (`deadline` or
/// `candidates`). See [`budget_error_reason`].
pub const BUDGET_ERROR_PREFIX: &str = "budget-exceeded:";

/// Reason code for a wall-clock budget expiry.
pub const BUDGET_REASON_DEADLINE: &str = "deadline";
/// Reason code for a candidate-count budget expiry.
pub const BUDGET_REASON_CANDIDATES: &str = "candidates";

/// Recover the machine-readable reason code from a budget-exhaustion
/// error string (`None` when the error is not a budget error).
pub fn budget_error_reason(err: &str) -> Option<&'static str> {
    let rest = err.strip_prefix(BUDGET_ERROR_PREFIX)?;
    if rest.starts_with(BUDGET_REASON_DEADLINE) {
        Some(BUDGET_REASON_DEADLINE)
    } else if rest.starts_with(BUDGET_REASON_CANDIDATES) {
        Some(BUDGET_REASON_CANDIDATES)
    } else {
        None
    }
}

/// Cooperative per-request resource bound: an optional wall-clock
/// deadline and an optional candidate-count ceiling, shared (`Arc`)
/// between the request handler and the evaluator it drives.
///
/// Checks happen *between* candidate evaluations — before each batch and
/// between per-candidate costings inside a batch — so a running costing
/// is never interrupted mid-block and every published cache entry stays
/// valid. The candidate check is clock-free and therefore fully
/// deterministic: a batch is rejected iff `charged + batch > max`,
/// where `charged` counts candidates of previously *completed* batches.
/// When both bounds would trip at once the candidate reason wins, so
/// replayed request streams report identical reason codes.
pub struct Budget {
    deadline: Option<Instant>,
    max_candidates: Option<u64>,
    charged: AtomicU64,
}

impl Budget {
    /// Budget from optional bounds: `budget_ms` milliseconds of wall
    /// clock from now, and/or at most `max_candidates` evaluated
    /// candidates. `Budget::new(None, None)` never trips.
    pub fn new(budget_ms: Option<u64>, max_candidates: Option<u64>) -> Arc<Budget> {
        Arc::new(Budget {
            // an unrepresentable (astronomically far) deadline is no
            // deadline at all, not a panic
            deadline: budget_ms
                .and_then(|ms| Instant::now().checked_add(Duration::from_millis(ms))),
            max_candidates,
            charged: AtomicU64::new(0),
        })
    }

    /// Candidates charged by completed batches so far.
    pub fn charged(&self) -> u64 {
        self.charged.load(Ordering::Relaxed)
    }

    /// Whether the wall-clock deadline (if any) has passed. This is the
    /// cooperative cancellation probe the costing loop polls between
    /// candidates.
    pub fn deadline_expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Admission check for a batch of `upcoming` candidates. The
    /// deterministic candidate-count bound is checked first, then the
    /// wall clock; the error carries [`BUDGET_ERROR_PREFIX`] plus the
    /// reason code.
    pub fn check(&self, upcoming: usize) -> Result<(), String> {
        if let Some(max) = self.max_candidates {
            let would = self.charged().saturating_add(upcoming as u64);
            if would > max {
                return Err(format!(
                    "{BUDGET_ERROR_PREFIX}{BUDGET_REASON_CANDIDATES}: \
                     {would} candidates would exceed the budget of {max}"
                ));
            }
        }
        if self.deadline_expired() {
            return Err(format!(
                "{BUDGET_ERROR_PREFIX}{BUDGET_REASON_DEADLINE}: wall-clock budget expired"
            ));
        }
        Ok(())
    }

    fn charge(&self, n: usize) {
        self.charged.fetch_add(n as u64, Ordering::Relaxed);
    }
}

const MEMO_SHARDS: usize = 16;

type MemoEntry = (Arc<CompiledProgram>, Arc<ProgramHashes>);

/// Plan-signature-keyed compile memo: each distinct signature is
/// compiled once and stored as an `Arc<CompiledProgram>` next to its
/// precomputed structural hash tree.
///
/// The memo is internally synchronized (sharded mutexes, mirroring
/// [`CostCache`]) and designed to be shared: the serve daemon holds one
/// `Arc<PlanMemo>` and hands it to a fresh [`Evaluator`] per request
/// ([`Evaluator::with_parts`]). Signatures are published only after a
/// successful compile, so failed batches leave the memo consistent; if
/// two sharers race on one signature both compile (compilation is
/// deterministic) and the first insert wins.
pub struct PlanMemo {
    shards: Vec<Mutex<HashMap<Arc<str>, MemoEntry>>>,
}

impl Default for PlanMemo {
    fn default() -> Self {
        Self::new()
    }
}

impl PlanMemo {
    /// Empty memo.
    pub fn new() -> Self {
        PlanMemo { shards: (0..MEMO_SHARDS).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    /// Distinct plans compiled over the memo's lifetime.
    pub fn distinct(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).len()).sum()
    }

    fn shard(&self, sig: &str) -> &Mutex<HashMap<Arc<str>, MemoEntry>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        sig.hash(&mut h);
        &self.shards[(h.finish() as usize) % MEMO_SHARDS]
    }

    fn lookup(&self, sig: &str) -> Option<MemoEntry> {
        self.shard(sig).lock().unwrap_or_else(|e| e.into_inner()).get(sig).cloned()
    }

    /// Publish a compiled entry; if another sharer raced us to the same
    /// signature the earlier insert wins and is returned.
    fn insert_if_absent(&self, sig: Arc<str>, entry: MemoEntry) -> MemoEntry {
        let mut shard = self.shard(&sig).lock().unwrap_or_else(|e| e.into_inner());
        shard.entry(sig).or_insert(entry).clone()
    }

    /// Ensure every signature in `sigs` has a compiled plan. Distinct
    /// signatures not yet memoized compile concurrently; `compile(i)`
    /// must compile the plan for `sigs[i]` and is called once per new
    /// signature, at its first occurrence in the batch. Returns, aligned
    /// with `sigs`, `(entry, reused)` — `reused` is false only for the
    /// first occurrence this memo has ever seen of a signature.
    fn ensure(
        &self,
        sigs: &[Arc<str>],
        threads: usize,
        compile: impl Fn(usize) -> Result<CompiledProgram, String> + Sync,
    ) -> Result<Vec<(MemoEntry, bool)>, String> {
        let mut resolved: Vec<Option<MemoEntry>> =
            sigs.iter().map(|sig| self.lookup(sig)).collect();
        let mut missing: Vec<usize> = Vec::new();
        let mut seen_in_batch: HashSet<&str> = HashSet::new();
        for (i, sig) in sigs.iter().enumerate() {
            if resolved[i].is_none() && seen_in_batch.insert(sig.as_ref()) {
                missing.push(i);
            }
        }
        // compile + structural-hash each new plan on the worker threads
        let compiled: Vec<Result<(CompiledProgram, ProgramHashes), String>> =
            par::par_map(&missing, threads, |_, &cell| {
                let prog = compile(cell)?;
                let hashes = cache::program_hashes(&prog.runtime);
                Ok((prog, hashes))
            });
        for (&cell, r) in missing.iter().zip(compiled) {
            // publish the signature only once its compile succeeded, so a
            // failed batch leaves the memo consistent for retries
            let (prog, hashes) = r?;
            let entry = self
                .insert_if_absent(Arc::clone(&sigs[cell]), (Arc::new(prog), Arc::new(hashes)));
            resolved[cell] = Some(entry);
        }
        // in-batch duplicates of a fresh signature resolve from the memo
        Ok(sigs
            .iter()
            .enumerate()
            .map(|(i, sig)| {
                let entry = match resolved[i].take() {
                    Some(e) => e,
                    None => self.lookup(sig).expect("signature published above"),
                };
                // `missing` is ascending, so binary_search identifies the
                // fresh (first-occurrence) positions.
                (entry, missing.binary_search(&i).is_err())
            })
            .collect())
    }
}

/// The evaluator: a compile memo, an optional block-level cost cache and
/// the per-run duplicate-cost table, driving the four-stage pipeline in
/// the module docs. One instance serves a whole optimizer run (several
/// batches); sharing an instance across runs additionally keeps the
/// compile memo and cost cache warm (the steady state the
/// `costcache` bench measures). The memo and cache can also be shared
/// *across* evaluators ([`Self::with_parts`]) — the serve daemon's
/// multi-tenant configuration.
pub struct Evaluator {
    memo: Arc<PlanMemo>,
    cache: Option<Arc<CostCache>>,
    threads: usize,
    budget: Option<Arc<Budget>>,
    costed: HashMap<CostKey, CostStats>,
    duplicates_skipped: usize,
    cache_baseline: CacheStats,
}

impl Evaluator {
    /// Evaluator with block-level cost caching enabled (a fresh cache of
    /// [`CostCache::DEFAULT_CAPACITY`] entries).
    pub fn new(threads: usize) -> Self {
        Self::with_cache(threads, Some(Arc::new(CostCache::default())))
    }

    /// Evaluator with the cost cache disabled — the reference/baseline
    /// configuration (`--no-cost-cache`, the bench's "uncached" side).
    pub fn without_cost_cache(threads: usize) -> Self {
        Self::with_cache(threads, None)
    }

    /// Evaluator over an explicit (possibly shared, possibly absent)
    /// cost cache and a fresh private compile memo.
    pub fn with_cache(threads: usize, cache: Option<Arc<CostCache>>) -> Self {
        Self::with_parts(threads, Arc::new(PlanMemo::new()), cache)
    }

    /// Evaluator over explicitly shared parts: a compile memo and an
    /// optional cost cache, both of which may concurrently back other
    /// evaluators. This is the serve daemon's constructor — one memo and
    /// one cache, a fresh evaluator (run-local duplicate table, budget)
    /// per request.
    pub fn with_parts(
        threads: usize,
        memo: Arc<PlanMemo>,
        cache: Option<Arc<CostCache>>,
    ) -> Self {
        let mut e = Evaluator {
            memo,
            cache,
            threads: threads.max(1),
            budget: None,
            costed: HashMap::new(),
            duplicates_skipped: 0,
            cache_baseline: CacheStats::default(),
        };
        e.cache_baseline = e.cache_stats();
        e
    }

    /// Worker threads the evaluator fans compiles and costings out over.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The evaluator's cost cache (`None` when caching is disabled).
    /// Cloning the `Arc` lets callers snapshot the cache to disk after a
    /// run ([`crate::artifact::CacheSnapshot`]) or share it with another
    /// evaluator.
    pub fn cache(&self) -> Option<Arc<CostCache>> {
        self.cache.clone()
    }

    /// The evaluator's compile memo, shareable with other evaluators via
    /// [`Self::with_parts`].
    pub fn memo(&self) -> Arc<PlanMemo> {
        Arc::clone(&self.memo)
    }

    /// Attach (or detach, with `None`) a cooperative per-run budget.
    /// Subsequent [`Self::evaluate`] batches are admission-checked
    /// against it and charged to it; the costing loop polls its deadline
    /// between candidates.
    pub fn set_budget(&mut self, budget: Option<Arc<Budget>>) {
        self.budget = budget;
    }

    /// Begin a new optimizer run: resets the per-run duplicate-cost
    /// table and the cache-stats baseline. The compile memo and the cost
    /// cache intentionally survive, so repeated runs over the same
    /// candidate family skip straight to (cached) costing.
    pub fn begin_run(&mut self) {
        self.costed.clear();
        self.duplicates_skipped = 0;
        self.cache_baseline = self.cache_stats();
    }

    /// Distinct plans compiled over the (possibly shared) memo's
    /// lifetime.
    pub fn distinct_plans(&self) -> usize {
        self.memo.distinct()
    }

    /// Candidates of the current run whose costing was skipped as an
    /// exact duplicate of an earlier candidate.
    pub fn duplicates_skipped(&self) -> usize {
        self.duplicates_skipped
    }

    /// Absolute cost-cache counters (zeros when the cache is disabled).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_deref().map(CostCache::stats).unwrap_or_default()
    }

    /// Cost-cache counters accumulated since [`Self::begin_run`].
    pub fn run_cache_stats(&self) -> CacheStats {
        self.cache_stats().since(&self.cache_baseline)
    }

    /// Stage 1–2 only: signature-dedupe and memoized parallel compile,
    /// without costing. Used for classification probes (the GDF
    /// optimizer compiles an MR probe plan per base configuration when
    /// the default backend is CP). Probes honor the wall-clock budget
    /// but never charge the candidate count. Returns `(plan, reused)`
    /// per item.
    pub fn compile_batch<C: Candidate>(
        &mut self,
        items: &[C],
    ) -> Result<Vec<(Arc<CompiledProgram>, bool)>, String> {
        if let Some(b) = &self.budget {
            b.check(0)?;
        }
        let sigs: Vec<Arc<str>> =
            items.iter().map(|c| Arc::<str>::from(c.signature())).collect();
        let plan_of = self.memo.ensure(&sigs, self.threads, |i| items[i].compile())?;
        Ok(plan_of.into_iter().map(|((prog, _), reused)| (prog, reused)).collect())
    }

    /// Run the full pipeline over one batch of candidates. Results align
    /// with `items`; the error cases are a failed compile, a non-finite
    /// cost estimate (both carry the candidate's label) or an exhausted
    /// [`Budget`] (tagged with [`BUDGET_ERROR_PREFIX`]).
    pub fn evaluate<C: Candidate>(&mut self, items: &[C]) -> Result<Vec<Evaluated>, String> {
        if items.is_empty() {
            return Ok(Vec::new());
        }
        if let Some(b) = &self.budget {
            b.check(items.len())?;
        }
        let sigs: Vec<Arc<str>> =
            items.iter().map(|c| Arc::<str>::from(c.signature())).collect();
        let plan_of = self.memo.ensure(&sigs, self.threads, |i| items[i].compile())?;

        // Stage 3: duplicate-cost keys — (structural program hash,
        // knob fingerprint restricted to what the program can read).
        let keys: Vec<CostKey> = (0..items.len())
            .map(|i| {
                let hashes = &plan_of[i].0 .1;
                let ctx = items[i].context();
                let root = hashes.root();
                let (c1, c2) = cache::hash_context(
                    hashes.feats(),
                    ctx.cfg,
                    ctx.cc,
                    ctx.constants,
                    ctx.fault,
                );
                CostKey(root.0, root.1, c1, c2)
            })
            .collect();
        let mut fresh = vec![false; items.len()];
        let mut to_cost: Vec<usize> = Vec::new();
        {
            let mut seen: HashSet<CostKey> = HashSet::new();
            for (i, key) in keys.iter().enumerate() {
                if !self.costed.contains_key(key) && seen.insert(*key) {
                    fresh[i] = true;
                    to_cost.push(i);
                }
            }
        }

        // Stage 4: cost the first occurrences concurrently through the
        // block cache (totals-only fast path). The budget deadline is
        // polled cooperatively between candidates: an expiry abandons
        // the remaining costings but never a costing in flight, so the
        // shared cache only ever gains valid entries.
        let computed: Vec<Result<CostStats, String>> = {
            let plan_of = &plan_of;
            let cache = self.cache.as_deref();
            let budget = self.budget.as_deref();
            par::par_map(&to_cost, self.threads, |_, &i| {
                if let Some(b) = budget {
                    if b.deadline_expired() {
                        return Err(format!(
                            "{BUDGET_ERROR_PREFIX}{BUDGET_REASON_DEADLINE}: \
                             wall-clock budget expired during candidate evaluation"
                        ));
                    }
                }
                let (prog, hashes) = &plan_of[i].0;
                let ctx = items[i].context();
                let total = match cache {
                    Some(cache) => cost::cost_total_cached_faults(
                        &prog.runtime,
                        hashes,
                        ctx.cfg,
                        ctx.cc,
                        ctx.constants,
                        ctx.fault,
                        cache,
                    ),
                    None => cost::cost_total_faults(
                        &prog.runtime,
                        ctx.cfg,
                        ctx.cc,
                        ctx.constants,
                        ctx.fault,
                    ),
                };
                let (cp, mr, sp) = prog.runtime.size3();
                Ok(CostStats { total, cp, mr, sp })
            })
        };
        let mut computed_ok = Vec::with_capacity(computed.len());
        for r in computed {
            computed_ok.push(r?);
        }
        for (&i, stats) in to_cost.iter().zip(&computed_ok) {
            self.costed.insert(keys[i], *stats);
        }
        self.duplicates_skipped += items.len() - to_cost.len();
        if let Some(b) = &self.budget {
            b.charge(items.len());
        }

        let mut out = Vec::with_capacity(items.len());
        for i in 0..items.len() {
            let stats = self.costed[&keys[i]];
            if !stats.total.is_finite() {
                return Err(format!(
                    "non-finite cost estimate ({}) for {}",
                    stats.total,
                    items[i].label()
                ));
            }
            let (entry, reused) = &plan_of[i];
            out.push(Evaluated {
                plan: Arc::clone(&entry.0),
                plan_reused: *reused,
                cost_secs: stats.total,
                cp_insts: stats.cp,
                mr_jobs: stats.mr,
                spark_jobs: stats.sp,
                cost_reused: !fresh[i],
                sig: Arc::clone(&sigs[i]),
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{compile_with_meta, CompileOptions, Scenario};
    use crate::rtprog::ExecBackend;

    /// Minimal candidate: one Table-1 scenario on one backend, costed
    /// against an owned configuration triple.
    struct ScenCand {
        s: Scenario,
        backend: ExecBackend,
        cfg: SystemConfig,
        cc: ClusterConfig,
        k: CostConstants,
        fp: FaultProfile,
    }

    impl ScenCand {
        fn new(s: Scenario, backend: ExecBackend) -> Self {
            ScenCand {
                s,
                backend,
                cfg: SystemConfig::default(),
                cc: ClusterConfig::paper_cluster(),
                k: CostConstants::default(),
                fp: FaultProfile::none(),
            }
        }
    }

    impl Candidate for ScenCand {
        fn signature(&self) -> String {
            format!("{}@{}", self.s.name, self.backend.name())
        }
        fn compile(&self) -> Result<CompiledProgram, String> {
            let opts = CompileOptions { backend: self.backend, ..Default::default() };
            compile_with_meta(self.s.script(), &self.s.args(), &self.s.meta(1000), &opts)
        }
        fn context(&self) -> CostContext<'_> {
            CostContext { cfg: &self.cfg, cc: &self.cc, constants: &self.k, fault: &self.fp }
        }
        fn label(&self) -> String {
            self.signature()
        }
    }

    #[test]
    fn equal_signatures_share_one_arc_plan() {
        let items = vec![
            ScenCand::new(Scenario::xs(), ExecBackend::Mr),
            ScenCand::new(Scenario::xs(), ExecBackend::Mr),
            ScenCand::new(Scenario::xl1(), ExecBackend::Mr),
        ];
        let mut e = Evaluator::new(2);
        e.begin_run();
        let r = e.evaluate(&items).unwrap();
        assert_eq!(e.distinct_plans(), 2);
        assert!(Arc::ptr_eq(&r[0].plan, &r[1].plan), "same sig -> same Arc");
        assert!(!Arc::ptr_eq(&r[0].plan, &r[2].plan));
        assert!(!r[0].plan_reused && r[1].plan_reused && !r[2].plan_reused);
        // identical candidates are also cost-duplicates
        assert!(!r[0].cost_reused && r[1].cost_reused);
        assert_eq!(e.duplicates_skipped(), 1);
        assert_eq!(r[0].cost_secs.to_bits(), r[1].cost_secs.to_bits());
    }

    #[test]
    fn cached_and_uncached_evaluators_agree_bitwise() {
        let items: Vec<ScenCand> = Scenario::all()
            .into_iter()
            .flat_map(|s| ExecBackend::all().map(|b| ScenCand::new(s.clone(), b)))
            .collect();
        let mut cached = Evaluator::new(4);
        cached.begin_run();
        let a = cached.evaluate(&items).unwrap();
        let mut plain = Evaluator::without_cost_cache(4);
        plain.begin_run();
        let b = plain.evaluate(&items).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cost_secs.to_bits(), y.cost_secs.to_bits(), "{}", x.sig);
            assert_eq!(
                (x.cp_insts, x.mr_jobs, x.spark_jobs),
                (y.cp_insts, y.mr_jobs, y.spark_jobs)
            );
        }
        // re-evaluating the same batch after begin_run re-costs but the
        // warm cache answers from block hits
        cached.begin_run();
        let again = cached.evaluate(&items).unwrap();
        for (x, y) in a.iter().zip(&again) {
            assert_eq!(x.cost_secs.to_bits(), y.cost_secs.to_bits());
        }
        let stats = cached.run_cache_stats();
        assert!(stats.hits > 0, "warm rerun must hit the cache: {stats:?}");
    }

    #[test]
    fn calibrated_constants_are_never_cost_duplicates() {
        // identical candidates that differ only in their CostConstants —
        // the situation right after `repro calibrate` rewrites them —
        // must share the memoised plan but never the costed total
        let a = ScenCand::new(Scenario::xs(), ExecBackend::Mr);
        let mut b = ScenCand::new(Scenario::xs(), ExecBackend::Mr);
        b.k = crate::feedback::simulator_truth();
        let items = [a, b];
        let mut e = Evaluator::new(2);
        e.begin_run();
        let r = e.evaluate(&items).unwrap();
        assert_eq!(e.distinct_plans(), 1, "same signature -> one plan");
        assert!(Arc::ptr_eq(&r[0].plan, &r[1].plan));
        assert!(r[1].plan_reused);
        assert!(!r[0].cost_reused && !r[1].cost_reused, "constants changed: re-cost");
        assert_eq!(e.duplicates_skipped(), 0);
        assert_ne!(
            r[0].cost_secs.to_bits(),
            r[1].cost_secs.to_bits(),
            "calibrated constants must move the evaluated cost"
        );
    }

    #[test]
    fn compile_errors_carry_through() {
        struct Bad;
        impl Candidate for Bad {
            fn signature(&self) -> String {
                "bad".into()
            }
            fn compile(&self) -> Result<CompiledProgram, String> {
                Err("nope".into())
            }
            fn context(&self) -> CostContext<'_> {
                unreachable!("compile fails first")
            }
            fn label(&self) -> String {
                "bad".into()
            }
        }
        let mut e = Evaluator::new(1);
        assert!(e.evaluate(&[Bad]).unwrap_err().contains("nope"));
        // the memo stays consistent: nothing was recorded
        assert_eq!(e.distinct_plans(), 0);
    }

    #[test]
    fn shared_memo_backs_multiple_evaluators() {
        let memo = Arc::new(PlanMemo::new());
        let cache = Arc::new(CostCache::default());
        let mut a = Evaluator::with_parts(2, Arc::clone(&memo), Some(Arc::clone(&cache)));
        a.begin_run();
        let ra = a.evaluate(&[ScenCand::new(Scenario::xs(), ExecBackend::Mr)]).unwrap();
        assert!(!ra[0].plan_reused);
        // a second evaluator over the same parts reuses the compiled plan
        let mut b = Evaluator::with_parts(2, memo, Some(cache));
        b.begin_run();
        let rb = b.evaluate(&[ScenCand::new(Scenario::xs(), ExecBackend::Mr)]).unwrap();
        assert!(rb[0].plan_reused, "shared memo must answer the second evaluator");
        assert!(Arc::ptr_eq(&ra[0].plan, &rb[0].plan), "one Arc across evaluators");
        assert_eq!(b.distinct_plans(), 1);
        assert_eq!(ra[0].cost_secs.to_bits(), rb[0].cost_secs.to_bits());
        assert!(b.run_cache_stats().hits > 0, "shared cache must answer the re-cost");
    }

    #[test]
    fn candidate_budget_trips_deterministically() {
        let items = vec![
            ScenCand::new(Scenario::xs(), ExecBackend::Cp),
            ScenCand::new(Scenario::xs(), ExecBackend::Mr),
            ScenCand::new(Scenario::xs(), ExecBackend::Spark),
        ];
        let mut e = Evaluator::new(2);
        e.set_budget(Some(Budget::new(None, Some(2))));
        e.begin_run();
        let err = e.evaluate(&items).unwrap_err();
        assert!(err.starts_with(BUDGET_ERROR_PREFIX), "{err}");
        assert_eq!(budget_error_reason(&err), Some(BUDGET_REASON_CANDIDATES));
        // nothing was charged by the rejected batch; a batch within the
        // bound still evaluates
        let ok = e.evaluate(&items[..2]).unwrap();
        assert_eq!(ok.len(), 2);
        // ...and the next batch finds the budget exhausted
        let err = e.evaluate(&items[..1]).unwrap_err();
        assert_eq!(budget_error_reason(&err), Some(BUDGET_REASON_CANDIDATES));
    }

    #[test]
    fn expired_deadline_trips_before_work() {
        let mut e = Evaluator::new(2);
        e.set_budget(Some(Budget::new(Some(0), None)));
        e.begin_run();
        let err = e.evaluate(&[ScenCand::new(Scenario::xs(), ExecBackend::Mr)]).unwrap_err();
        assert_eq!(budget_error_reason(&err), Some(BUDGET_REASON_DEADLINE));
        assert_eq!(e.distinct_plans(), 0, "admission check precedes compilation");
        // detaching the budget restores normal operation bitwise
        e.set_budget(None);
        let r = e.evaluate(&[ScenCand::new(Scenario::xs(), ExecBackend::Mr)]).unwrap();
        let mut plain = Evaluator::new(2);
        plain.begin_run();
        let p = plain.evaluate(&[ScenCand::new(Scenario::xs(), ExecBackend::Mr)]).unwrap();
        assert_eq!(r[0].cost_secs.to_bits(), p[0].cost_secs.to_bits());
    }

    #[test]
    fn generous_budget_never_interferes() {
        let items = vec![
            ScenCand::new(Scenario::xs(), ExecBackend::Cp),
            ScenCand::new(Scenario::xs(), ExecBackend::Mr),
        ];
        let mut budgeted = Evaluator::new(2);
        budgeted.set_budget(Some(Budget::new(Some(3_600_000), Some(1_000_000))));
        budgeted.begin_run();
        let a = budgeted.evaluate(&items).unwrap();
        let mut plain = Evaluator::new(2);
        plain.begin_run();
        let b = plain.evaluate(&items).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cost_secs.to_bits(), y.cost_secs.to_bits());
        }
    }

    #[test]
    fn budget_reason_parser_roundtrips() {
        assert_eq!(
            budget_error_reason("budget-exceeded:deadline: wall-clock budget expired"),
            Some("deadline")
        );
        assert_eq!(
            budget_error_reason("budget-exceeded:candidates: 4 candidates would exceed"),
            Some("candidates")
        );
        assert_eq!(budget_error_reason("non-finite cost estimate"), None);
        assert_eq!(budget_error_reason("budget-exceeded:other"), None);
    }
}
