//! Global data flow optimization (paper §1: the cost model "is leveraged
//! by several advanced optimizers like resource optimization and **global
//! data flow optimization**") — the second named consumer, after the grid
//! resource optimizer ([`super::resource`]).
//!
//! Where the resource optimizer searches over *cluster configurations*
//! for a fixed compilation, the GDF optimizer enumerates **interesting
//! data-flow properties per DAG cut** and lets each candidate change the
//! *structure* of the generated runtime plan (cf. Boehm et al.'s fusion-
//! plan enumeration, PAPERS.md):
//!
//! * **block size** — bounds map-side `tsmm` feasibility
//!   (`ncol ≤ blocksize`, §2) and every blocking-derived estimate;
//! * **on-disk format** — binary-block vs text for the persistent inputs
//!   (text halves the effective scan bandwidth, §3.3);
//! * **partitioning decision** — the partitioned-broadcast threshold
//!   ([`crate::lop::partition_broadcast`]) that decides whether `mapmm`
//!   broadcasts are pre-partitioned CP-side;
//! * **forced execution backend per operator group** — every top-level
//!   program block (the cuts between HOP DAGs, where transient variables
//!   materialise) can be pinned to CP, MR or Spark via the per-group
//!   pipeline ([`crate::api::compile_with_groups`],
//!   [`crate::ir::exec_type::select_groups`],
//!   [`crate::rtprog::gen::generate_groups`]).
//!
//! Enumerating 3 backends over every cut would explode (`3^cuts`), so the
//! optimizer first compiles each base configuration under the default
//! backend and classifies the **interesting cuts** — the groups that
//! actually contain distributed jobs. Only those are enumerated; a group
//! whose operators all fit the CP budget generates the same plan under
//! every backend, so pinning it to the default is exact, not a
//! heuristic. Candidates run through the unified evaluation core
//! ([`crate::opt::evaluate`]) shared with the sweep engine and the
//! resource optimizer: memoized `Arc`-shared compiles, duplicate-cost
//! skipping (candidates whose plan and observable knobs match an
//! earlier candidate are not re-costed — surfaced in the decision
//! trace), and block-cached concurrent costing. Note that unlike those
//! grids (whose cost-only axes share plans), every enumerated GDF
//! configuration is plan-shaping, so each candidate compiles its own
//! plan by construction.
//!
//! The result is the argmin candidate plus a per-cut **decision trace**
//! (chosen backend, job counts before/after, partitioning/caching
//! decisions) and an EXPLAIN-style before/after **plan diff**.
//!
//! Entry points: [`optimize`] / [`crate::api::optimize_global_dataflow`]
//! and the `repro gdf` CLI subcommand.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

use crate::api::{compile_with_groups, ClusterConfigOpt, CompileOptions, CompiledProgram};
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig, MB};
use crate::cost::cache::CacheStats;
use crate::lop::SelectionHints;
use crate::matrix::Format;
use crate::rtprog::{CpOp, ExecBackend, Instr, RtBlock};
use crate::util::fmt::{fmt_secs, normalize_scratch_pid};
use crate::util::par;

use super::evaluate::{Candidate, CostContext, Evaluated, Evaluator};
use super::sweep::{plan_signature, DataScenario};

// ---------------------------------------------------------------------
// Specification
// ---------------------------------------------------------------------

/// Global-data-flow search space for one script + data scenario: the
/// per-cut property axes (block size, format, partition size, per-group
/// backend) plus the shared compilation and costing context.
#[derive(Clone, Debug)]
pub struct GdfSpec {
    /// DML source compiled per distinct plan shape.
    pub script: String,
    /// `$N` command-line bindings for the script.
    pub args: HashMap<usize, String>,
    /// Persistent-input metadata (dimensions per read path).
    pub scenario: DataScenario,
    /// Cluster the candidates are compiled and costed against.
    pub cc: ClusterConfig,
    /// Base compiler/system configuration; each candidate patches the
    /// block-size and partition axes onto it.
    pub cfg: SystemConfig,
    /// Physical-operator selection hints shared by all candidates.
    pub hints: SelectionHints,
    /// Cost-model constants shared by all candidates.
    pub constants: CostConstants,
    /// Failure profile shared by all candidates (`repro gdf
    /// --fault-profile`). [`FaultProfile::none`] is a bitwise no-op; a
    /// nonzero profile prices retries, backoff, and straggler tails into
    /// every distributed candidate, which can flip the per-cut backend
    /// argmin toward CP (retry-free) groups.
    pub fault: FaultProfile,
    /// Block-size axis (the default `cfg.blocksize` is always included).
    pub blocksizes: Vec<i64>,
    /// On-disk format axis for the persistent inputs (binary-block is
    /// always included as the baseline format).
    pub formats: Vec<Format>,
    /// Broadcast-partition-size axis in MB (the default
    /// `cfg.partition_bytes` is always included).
    pub partitions_mb: Vec<f64>,
    /// Backend candidates enumerated per interesting cut.
    pub backends: Vec<ExecBackend>,
    /// Backend of the *default* plan the argmin is compared against (and
    /// of every non-interesting group). The paper's default: MR.
    pub default_backend: ExecBackend,
    /// Cap on enumerated interesting cuts per base configuration
    /// (`backends^cuts` growth); beyond it the trailing cuts are pinned
    /// to the default backend and [`GdfReport::truncated_cuts`] is set.
    pub max_cuts: usize,
    /// Enable the block-level cost cache ([`crate::cost::cache`]).
    /// Results are bitwise identical either way; disable only for A/B
    /// measurements (`repro gdf --no-cost-cache`, the costcache bench).
    pub cost_cache: bool,
    /// Worker threads; `0` = available parallelism.
    pub threads: usize,
    /// Statically verify the argmin candidate's plan ([`crate::analysis`])
    /// after ranking (`repro gdf --verify`). Error-severity diagnostics
    /// fail the optimization; the decision trace records a verify line.
    pub verify: bool,
}

impl GdfSpec {
    /// Search space with the default axes (3 block sizes × 2 formats ×
    /// 2 partition sizes, all 3 backends per interesting cut) on the
    /// paper cluster.
    pub fn new(
        script: impl Into<String>,
        args: HashMap<usize, String>,
        scenario: DataScenario,
    ) -> Self {
        GdfSpec {
            script: script.into(),
            args,
            scenario,
            cc: ClusterConfig::paper_cluster(),
            cfg: SystemConfig::default(),
            hints: SelectionHints::default(),
            constants: CostConstants::default(),
            fault: FaultProfile::none(),
            blocksizes: vec![500, 1000, 2000],
            formats: vec![Format::BinaryBlock, Format::TextCell],
            partitions_mb: vec![8.0, 32.0],
            backends: ExecBackend::all().to_vec(),
            default_backend: ExecBackend::Mr,
            max_cuts: 4,
            cost_cache: true,
            threads: 0,
            verify: false,
        }
    }

    /// The LinReg CG search space on the given Table-1 scenario: the
    /// loop-heavy script where the per-group backend axis matters most
    /// (every iteration of a distributed loop pays per-job latency).
    pub fn linreg_cg(scenario: DataScenario, iterations: usize) -> Self {
        Self::new(
            crate::api::LINREG_CG,
            crate::api::linreg_cg_args(iterations),
            scenario,
        )
    }

    /// Reject empty or degenerate axes and configurations before any
    /// compile, so NaN costs become diagnostics instead of panics.
    pub fn validate(&self) -> Result<(), String> {
        self.cc.validate()?;
        self.constants.validate()?;
        self.fault.validate()?;
        if self.backends.is_empty() {
            return Err("empty GDF backend axis".to_string());
        }
        for &bs in &self.blocksizes {
            if bs < 1 {
                return Err(format!("invalid block-size axis value {bs} (must be >= 1)"));
            }
        }
        for &p in &self.partitions_mb {
            if !(p.is_finite() && p > 0.0) {
                return Err(format!(
                    "invalid partition axis value {p} MB (must be finite and > 0)"
                ));
            }
        }
        if self.cfg.blocksize < 1 {
            return Err(format!(
                "invalid base blocksize {} (must be >= 1)",
                self.cfg.blocksize
            ));
        }
        if self.max_cuts == 0 {
            return Err("max_cuts must be >= 1".to_string());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Results
// ---------------------------------------------------------------------

/// One candidate data-flow configuration with its costed plan statistics.
#[derive(Clone, Debug)]
pub struct GdfCandidate {
    /// Matrix block size of this candidate.
    pub blocksize: i64,
    /// On-disk format of the persistent inputs.
    pub format: Format,
    /// Broadcast partition size, MB.
    pub partition_mb: f64,
    /// Backend per top-level operator group (one entry per program cut).
    pub groups: Vec<ExecBackend>,
    /// Estimated execution time `C(P, cc)` in seconds.
    pub cost_secs: f64,
    /// CP instruction count of the generated plan.
    pub cp_insts: usize,
    /// MR-job count of the generated plan.
    pub mr_jobs: usize,
    /// Spark-job count of the generated plan.
    pub spark_jobs: usize,
    /// Whether this candidate reused a plan compiled earlier in the run.
    /// Every enumerated GDF axis is plan-shaping, so this is false for
    /// all candidates today; the field exists for parity with the sweep
    /// and resource reports (and future cost-only axes).
    pub plan_reused: bool,
    /// Whether costing was skipped because an earlier candidate had a
    /// structurally identical plan under identical cost-relevant knobs
    /// (e.g. partition-axis variants whose plans contain no MR job).
    /// The cost is a bitwise copy of that candidate's.
    pub cost_reused: bool,
}

impl GdfCandidate {
    /// Compact `bs/fmt/part/groups` label for tables and diagnostics.
    pub fn label(&self) -> String {
        format!(
            "bs={} fmt={} part={}MB groups={}",
            self.blocksize,
            self.format.name(),
            fmt_mb_axis(self.partition_mb),
            self.groups.iter().map(|b| b.name()).collect::<Vec<_>>().join(",")
        )
    }
}

/// Render a megabyte axis value without truncating fractional entries
/// (`32` but `0.5`, not `0`).
fn fmt_mb_axis(mb: f64) -> String {
    if mb.fract() == 0.0 {
        format!("{}", mb as i64)
    } else {
        format!("{mb}")
    }
}

/// The decision the optimizer took at one DAG cut (top-level program
/// block): the forced backend plus the observable plan consequences.
#[derive(Clone, Debug)]
pub struct CutDecision {
    /// Top-level block index (cut position in program order).
    pub cut: usize,
    /// Display label of the block, e.g. `FOR (lines 8-16)`.
    pub label: String,
    /// Backend chosen for this operator group.
    pub backend: ExecBackend,
    /// Distributed jobs in this group under the default plan.
    pub jobs_before: usize,
    /// Distributed jobs in this group under the optimized plan.
    pub jobs_after: usize,
    /// Whether the optimized plan pre-partitions a broadcast in this
    /// group (CP `partition` instruction, MR distributed cache).
    pub partitioned: bool,
    /// Broadcast/distributed-cache variables used by this group's jobs —
    /// the caching decision made for it.
    pub cached: usize,
}

/// Result of a GDF optimization: every candidate, the argmin, the per-cut
/// decision trace and the before/after EXPLAIN texts.
#[derive(Clone, Debug)]
pub struct GdfReport {
    /// All candidates; index 0 is always the default configuration.
    pub candidates: Vec<GdfCandidate>,
    /// Indices into `candidates`, cheapest first (ties keep enumeration
    /// order, so the default plan wins exact ties).
    pub ranking: Vec<usize>,
    /// Index of the cost-argmin candidate.
    pub best: usize,
    /// Index of the default-configuration candidate (always 0).
    pub baseline: usize,
    /// Per-cut decisions of the argmin candidate, in program order.
    pub trace: Vec<CutDecision>,
    /// Runtime EXPLAIN of the default plan (scratch PID normalised).
    pub before_explain: String,
    /// Runtime EXPLAIN of the argmin plan (scratch PID normalised).
    pub after_explain: String,
    /// Distinct plan shapes compiled across the run (including the MR
    /// classification probes used when the default backend is CP).
    pub distinct_plans: usize,
    /// Candidates that reused a memoized plan (0 today — all GDF axes
    /// are plan-shaping, so no two candidates share a signature).
    pub memo_hits: usize,
    /// Candidates whose costing was skipped as an exact duplicate of an
    /// earlier candidate (identical plan structure + identical
    /// cost-relevant knobs); reported in the decision trace.
    pub skipped_duplicates: usize,
    /// Block-level cost-cache hits accumulated during this run.
    pub cache_hits: u64,
    /// Block-level cost-cache misses accumulated during this run.
    pub cache_misses: u64,
    /// Whether interesting cuts were dropped by the `max_cuts` cap (the
    /// dropped cuts stay on the default backend — surfaced, not silent).
    pub truncated_cuts: bool,
    /// Wall-clock seconds spent in the optimization.
    pub wall_secs: f64,
    /// Worker threads used.
    pub threads: usize,
    /// Static verification of the argmin candidate's plan, present when
    /// the spec asked for it. Always clean — a dirty argmin fails the
    /// optimization instead.
    pub verify: Option<crate::analysis::VerifyReport>,
}

impl GdfReport {
    /// The cost-argmin candidate.
    pub fn best(&self) -> &GdfCandidate {
        &self.candidates[self.best]
    }

    /// The default-configuration candidate the argmin is compared to.
    pub fn baseline(&self) -> &GdfCandidate {
        &self.candidates[self.baseline]
    }

    /// Candidates in ranked (cheapest-first) order.
    pub fn ranked(&self) -> impl Iterator<Item = &GdfCandidate> {
        self.ranking.iter().map(move |&i| &self.candidates[i])
    }

    /// Relative improvement of the argmin over the default plan, in
    /// percent (0 when the default is already optimal).
    pub fn improvement_pct(&self) -> f64 {
        let base = self.baseline().cost_secs;
        if base > 0.0 {
            (base - self.best().cost_secs) / base * 100.0
        } else {
            0.0
        }
    }

    /// Aligned per-cut decision trace of the argmin plan (deterministic —
    /// no timings).
    pub fn decision_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<4} {:<26} {:<8} {:>14} {:>12} {:>7}\n",
            "cut", "block", "backend", "jobs (def->opt)", "partitioned", "cached"
        ));
        out.push_str(&"-".repeat(78));
        out.push('\n');
        for d in &self.trace {
            out.push_str(&format!(
                "{:<4} {:<26} {:<8} {:>7} -> {:<4} {:>12} {:>7}\n",
                d.cut,
                d.label,
                d.backend.name(),
                d.jobs_before,
                d.jobs_after,
                if d.partitioned { "yes" } else { "no" },
                d.cached
            ));
        }
        out.push_str(&format!(
            "duplicate candidates skipped (identical plan + knobs): {}\n",
            self.skipped_duplicates
        ));
        if let Some(v) = &self.verify {
            out.push_str(&v.summary());
            out.push('\n');
        }
        out
    }

    /// Unified EXPLAIN-style diff between the default and the optimized
    /// runtime plan (`- ` lines only in the default, `+ ` lines only in
    /// the optimized plan). Deterministic across runs and thread counts.
    pub fn explain_diff(&self) -> String {
        line_diff(&self.before_explain, &self.after_explain)
    }

    /// One-line execution summary (includes wall time — not part of the
    /// deterministic tables).
    pub fn summary(&self) -> String {
        let cache = CacheStats {
            hits: self.cache_hits,
            misses: self.cache_misses,
            ..CacheStats::default()
        };
        format!(
            "enumerated {} candidates in {:.3}s on {} threads; {} distinct plans compiled, {} duplicate costings skipped, cost-cache hit rate {:.0}%{}; best {} vs default {} ({:+.1}%)",
            self.candidates.len(),
            self.wall_secs,
            self.threads,
            self.distinct_plans,
            self.skipped_duplicates,
            cache.hit_rate() * 100.0,
            if self.truncated_cuts { " (interesting cuts truncated by max_cuts)" } else { "" },
            fmt_secs(self.best().cost_secs),
            fmt_secs(self.baseline().cost_secs),
            -self.improvement_pct()
        )
    }
}

// ---------------------------------------------------------------------
// Internals
// ---------------------------------------------------------------------

/// One base configuration: the global (non-per-cut) property axes.
struct BaseConfig {
    blocksize: i64,
    format: Format,
    partition_mb: f64,
    cfg: SystemConfig,
}

/// One candidate awaiting compilation: a base plus a full per-group
/// backend assignment (empty = all-default, the baseline of its base).
struct RawCand {
    base: usize,
    groups: Vec<ExecBackend>,
}

/// One GDF candidate (or MR classification probe, `backend = Mr`)
/// viewed as an evaluator candidate.
struct GdfCand<'a> {
    spec: &'a GdfSpec,
    bases: &'a [BaseConfig],
    cand: &'a RawCand,
    backend: ExecBackend,
}

impl Candidate for GdfCand<'_> {
    fn signature(&self) -> String {
        gdf_signature(self.spec, &self.bases[self.cand.base], &self.cand.groups, self.backend)
    }
    fn compile(&self) -> Result<CompiledProgram, String> {
        compile_candidate(self.spec, &self.bases[self.cand.base], &self.cand.groups, self.backend)
    }
    fn context(&self) -> CostContext<'_> {
        CostContext {
            cfg: &self.bases[self.cand.base].cfg,
            cc: &self.spec.cc,
            constants: &self.spec.constants,
            fault: &self.spec.fault,
        }
    }
    fn label(&self) -> String {
        let base = &self.bases[self.cand.base];
        let grp = if self.cand.groups.is_empty() {
            "default".to_string()
        } else {
            self.cand.groups.iter().map(|b| b.name()).collect::<Vec<_>>().join(",")
        };
        format!(
            "GDF candidate bs={} fmt={} part={}MB groups={}",
            base.blocksize,
            base.format.name(),
            fmt_mb_axis(base.partition_mb),
            grp
        )
    }
}

/// Wrap raw candidates as evaluator adapters against `backend`.
fn adapters<'a>(
    spec: &'a GdfSpec,
    bases: &'a [BaseConfig],
    raws: &'a [RawCand],
    backend: ExecBackend,
) -> Vec<GdfCand<'a>> {
    raws.iter().map(|cand| GdfCand { spec, bases, cand, backend }).collect()
}

/// Default-first axis: the baseline value, then the user's values.
fn with_default<T: PartialEq + Clone>(default: T, axis: &[T]) -> Vec<T> {
    let mut out = vec![default];
    for v in axis {
        if !out.contains(v) {
            out.push(v.clone());
        }
    }
    out
}

/// Distributed jobs / partition ops / cached (broadcast) vars in one
/// runtime block subtree.
fn block_stats(b: &RtBlock) -> (usize, bool, usize) {
    fn walk(b: &RtBlock, jobs: &mut usize, part: &mut bool, cached: &mut usize) {
        let insts = |insts: &[Instr], jobs: &mut usize, part: &mut bool, cached: &mut usize| {
            for i in insts {
                match i {
                    Instr::MrJob(j) => {
                        *jobs += 1;
                        *cached += j.dcache.len();
                    }
                    Instr::SparkJob(j) => {
                        *jobs += 1;
                        *cached += j.broadcasts.len();
                    }
                    Instr::Cp(c) if matches!(c.op, CpOp::Partition) => *part = true,
                    _ => {}
                }
            }
        };
        match b {
            RtBlock::Generic { insts: is, .. } => insts(is, jobs, part, cached),
            RtBlock::If { pred, then_blocks, else_blocks, .. } => {
                insts(&pred.insts, jobs, part, cached);
                for c in then_blocks.iter().chain(else_blocks) {
                    walk(c, jobs, part, cached);
                }
            }
            RtBlock::For { from, to, by, body, .. } => {
                insts(&from.insts, jobs, part, cached);
                insts(&to.insts, jobs, part, cached);
                if let Some(by) = by {
                    insts(&by.insts, jobs, part, cached);
                }
                for c in body {
                    walk(c, jobs, part, cached);
                }
            }
            RtBlock::While { pred, body, .. } => {
                insts(&pred.insts, jobs, part, cached);
                for c in body {
                    walk(c, jobs, part, cached);
                }
            }
            RtBlock::FCall { .. } => {}
        }
    }
    let (mut jobs, mut part, mut cached) = (0, false, 0);
    walk(b, &mut jobs, &mut part, &mut cached);
    (jobs, part, cached)
}

/// Display label of a top-level runtime block (cut).
fn rt_block_label(b: &RtBlock) -> String {
    match b {
        RtBlock::Generic { lines, .. } => format!("GENERIC (lines {}-{})", lines.0, lines.1),
        RtBlock::If { lines, .. } => format!("IF (lines {}-{})", lines.0, lines.1),
        RtBlock::For { parfor, lines, .. } => {
            let kind = if *parfor { "PARFOR" } else { "FOR" };
            format!("{kind} (lines {}-{})", lines.0, lines.1)
        }
        RtBlock::While { lines, .. } => format!("WHILE (lines {}-{})", lines.0, lines.1),
        RtBlock::FCall { fname, lines, .. } => {
            format!("FCALL {fname} (lines {}-{})", lines.0, lines.1)
        }
    }
}

/// Plain LCS line diff: shared lines indented, `- ` for lines only in
/// `before`, `+ ` for lines only in `after`. Also used by the plan
/// artifact loader to diff stored vs freshly generated EXPLAINs.
pub(crate) fn line_diff(before: &str, after: &str) -> String {
    let a: Vec<&str> = before.lines().collect();
    let b: Vec<&str> = after.lines().collect();
    let (n, m) = (a.len(), b.len());
    let mut dp = vec![vec![0u32; m + 1]; n + 1];
    for i in (0..n).rev() {
        for j in (0..m).rev() {
            dp[i][j] = if a[i] == b[j] {
                dp[i + 1][j + 1] + 1
            } else {
                dp[i + 1][j].max(dp[i][j + 1])
            };
        }
    }
    let mut out = String::new();
    let (mut i, mut j) = (0, 0);
    while i < n && j < m {
        if a[i] == b[j] {
            out.push_str("  ");
            out.push_str(a[i]);
            out.push('\n');
            i += 1;
            j += 1;
        } else if dp[i + 1][j] >= dp[i][j + 1] {
            out.push_str("- ");
            out.push_str(a[i]);
            out.push('\n');
            i += 1;
        } else {
            out.push_str("+ ");
            out.push_str(b[j]);
            out.push('\n');
            j += 1;
        }
    }
    for line in &a[i..] {
        out.push_str("- ");
        out.push_str(line);
        out.push('\n');
    }
    for line in &b[j..] {
        out.push_str("+ ");
        out.push_str(line);
        out.push('\n');
    }
    out
}

/// All per-cut backend assignments over the interesting cuts (every
/// other group pinned to `default`), minus the all-default assignment
/// (the baseline candidate covers it).
fn assignments(
    interesting: &[usize],
    backends: &[ExecBackend],
    n_blocks: usize,
    default: ExecBackend,
) -> Vec<Vec<ExecBackend>> {
    let mut out = vec![vec![default; n_blocks]];
    for &g in interesting {
        let mut next = Vec::with_capacity(out.len() * backends.len());
        for a in &out {
            for &b in backends {
                let mut v = a.clone();
                v[g] = b;
                next.push(v);
            }
        }
        out = next;
    }
    let mut seen: HashSet<Vec<ExecBackend>> = HashSet::new();
    out.retain(|a| seen.insert(a.clone()));
    out.retain(|a| a.iter().any(|&b| b != default));
    out
}

/// Candidate plan signature: the sweep signature (which already covers
/// block size, partition size, memory budgets and hints) extended with
/// the on-disk input format and the per-group backend assignment.
fn gdf_signature(
    spec: &GdfSpec,
    base: &BaseConfig,
    groups: &[ExecBackend],
    default_backend: ExecBackend,
) -> String {
    let grp = if groups.is_empty() {
        "default".to_string()
    } else {
        groups.iter().map(|b| b.name()).collect::<Vec<_>>().join(",")
    };
    format!(
        "{};fmt={};grp={}",
        plan_signature(
            &spec.script,
            &spec.args,
            &base.cfg,
            &spec.hints,
            &spec.cc,
            &spec.scenario,
            default_backend,
        ),
        base.format.name(),
        grp
    )
}

fn compile_candidate(
    spec: &GdfSpec,
    base: &BaseConfig,
    groups: &[ExecBackend],
    default_backend: ExecBackend,
) -> Result<CompiledProgram, String> {
    let opts = CompileOptions {
        cfg: base.cfg.clone(),
        cc: ClusterConfigOpt(spec.cc.clone()),
        hints: spec.hints.clone(),
        backend: default_backend,
    };
    let meta = spec.scenario.meta_fmt(base.blocksize, base.format);
    compile_with_groups(&spec.script, &spec.args, &meta, &opts, groups).map_err(|e| {
        format!(
            "compile failed for GDF candidate bs={} fmt={} part={}MB: {e}",
            base.blocksize,
            base.format.name(),
            fmt_mb_axis(base.partition_mb)
        )
    })
}

// ---------------------------------------------------------------------
// The optimizer
// ---------------------------------------------------------------------

/// Run the global data flow optimization: enumerate base configurations
/// (block size × format × partition size), classify the interesting cuts
/// of each base from its default-backend plan, enumerate per-cut backend
/// assignments over those cuts, and evaluate everything through the
/// unified candidate evaluator ([`crate::opt::evaluate`]): one memoized
/// parallel compile per distinct plan signature, duplicate-cost
/// skipping, block-cached concurrent costing. Returns the argmin with
/// its per-cut decision trace and before/after EXPLAIN diff. See the
/// module docs for the property model.
pub fn optimize(spec: &GdfSpec) -> Result<GdfReport, String> {
    let threads = if spec.threads == 0 { par::default_threads() } else { spec.threads };
    let mut eval = if spec.cost_cache {
        Evaluator::new(threads)
    } else {
        Evaluator::without_cost_cache(threads)
    };
    optimize_with(spec, &mut eval)
}

/// [`optimize`] on a caller-provided evaluator: the compile memo and the
/// block-level cost cache survive across calls, so re-optimizing the
/// same (or a nearby) search space skips straight to cached costing —
/// the incremental re-optimization workload the `costcache` bench
/// measures. Fan-out uses the evaluator's thread count; `spec.threads`
/// is ignored on this entry point.
pub fn optimize_with(spec: &GdfSpec, eval: &mut Evaluator) -> Result<GdfReport, String> {
    let t0 = Instant::now();
    spec.validate()?;
    let threads = eval.threads();
    eval.begin_run();

    // Base axes, default value first: candidate 0 is the default plan.
    let blocksizes = with_default(spec.cfg.blocksize, &spec.blocksizes);
    let formats = with_default(Format::BinaryBlock, &spec.formats);
    let partitions = with_default(spec.cfg.partition_bytes / MB, &spec.partitions_mb);
    let mut bases = Vec::new();
    for &bs in &blocksizes {
        for &fmt in &formats {
            for &part in &partitions {
                let mut cfg = spec.cfg.clone();
                cfg.blocksize = bs;
                cfg.partition_bytes = part * MB;
                bases.push(BaseConfig { blocksize: bs, format: fmt, partition_mb: part, cfg });
            }
        }
    }

    // Phase 1: compile + cost the all-default plan of every base.
    let base_raw: Vec<RawCand> =
        (0..bases.len()).map(|i| RawCand { base: i, groups: Vec::new() }).collect();
    let base_evals =
        eval.evaluate(&adapters(spec, &bases, &base_raw, spec.default_backend))?;

    // Classify the interesting cuts of every base: a cut is interesting
    // iff the *distributable* plan family places jobs in it. The MR plan
    // is the probe — exec-type selection is identical for MR and Spark,
    // and probing the default backend would see no jobs at all when the
    // default family is single-node CP. Probes are compiled (memoized),
    // never costed.
    let probe_plans: Vec<Arc<CompiledProgram>> = if spec.default_backend == ExecBackend::Cp {
        eval.compile_batch(&adapters(spec, &bases, &base_raw, ExecBackend::Mr))?
            .into_iter()
            .map(|(plan, _)| plan)
            .collect()
    } else {
        base_evals.iter().map(|e| Arc::clone(&e.plan)).collect()
    };

    let n_blocks = base_evals[0].plan.runtime.blocks.len();
    let mut truncated_cuts = false;
    let mut interesting_of: Vec<Vec<usize>> = Vec::with_capacity(bases.len());
    for prog in &probe_plans {
        let mut interesting: Vec<usize> = prog
            .runtime
            .blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| block_stats(b).0 > 0)
            .map(|(i, _)| i)
            .collect();
        if interesting.len() > spec.max_cuts {
            interesting.truncate(spec.max_cuts);
            truncated_cuts = true;
        }
        interesting_of.push(interesting);
    }

    // Phase 2: per-cut backend assignments over the interesting cuts,
    // evaluated through the same pipeline (duplicate-cost skipping fires
    // here: e.g. partition-axis variants whose assignment leaves no MR
    // job compile to identical plans with identical observable knobs).
    let mut rest_raw: Vec<RawCand> = Vec::new();
    for bi in 0..bases.len() {
        for groups in
            assignments(&interesting_of[bi], &spec.backends, n_blocks, spec.default_backend)
        {
            rest_raw.push(RawCand { base: bi, groups });
        }
    }
    let rest_evals =
        eval.evaluate(&adapters(spec, &bases, &rest_raw, spec.default_backend))?;

    let all_raw: Vec<&RawCand> = base_raw.iter().chain(&rest_raw).collect();
    let all_evals: Vec<&Evaluated> = base_evals.iter().chain(&rest_evals).collect();

    let candidates: Vec<GdfCandidate> = all_raw
        .iter()
        .zip(&all_evals)
        .map(|(cand, ev)| {
            let base = &bases[cand.base];
            GdfCandidate {
                blocksize: base.blocksize,
                format: base.format,
                partition_mb: base.partition_mb,
                groups: if cand.groups.is_empty() {
                    vec![spec.default_backend; n_blocks]
                } else {
                    cand.groups.clone()
                },
                cost_secs: ev.cost_secs,
                cp_insts: ev.cp_insts,
                mr_jobs: ev.mr_jobs,
                spark_jobs: ev.spark_jobs,
                plan_reused: ev.plan_reused,
                cost_reused: ev.cost_reused,
            }
        })
        .collect();

    // Ranking: cheapest first; exact ties keep enumeration order, so the
    // default plan (index 0) wins when nothing improves on it.
    let mut ranking: Vec<usize> = (0..candidates.len()).collect();
    ranking.sort_by(|&x, &y| {
        candidates[x].cost_secs.total_cmp(&candidates[y].cost_secs).then(x.cmp(&y))
    });
    let best = ranking[0];

    // Decision trace + before/after explains from the two relevant plans.
    let best_plan: &CompiledProgram = &all_evals[best].plan;
    let baseline_plan: &CompiledProgram = &base_evals[0].plan;
    let trace: Vec<CutDecision> = best_plan
        .runtime
        .blocks
        .iter()
        .enumerate()
        .map(|(i, b)| {
            let (jobs_after, partitioned, cached) = block_stats(b);
            let jobs_before =
                baseline_plan.runtime.blocks.get(i).map_or(0, |b| block_stats(b).0);
            CutDecision {
                cut: i,
                label: rt_block_label(b),
                backend: candidates[best].groups.get(i).copied().unwrap_or(spec.default_backend),
                jobs_before,
                jobs_after,
                partitioned,
                cached,
            }
        })
        .collect();
    let before_explain = normalize_scratch_pid(&crate::rtprog::explain::explain_runtime(
        &baseline_plan.runtime,
        crate::rtprog::explain::ExplainOpts::default(),
    ));
    let after_explain = normalize_scratch_pid(&crate::rtprog::explain::explain_runtime(
        &best_plan.runtime,
        crate::rtprog::explain::ExplainOpts::default(),
    ));

    // Statically verify the winning plan. The severity policy follows
    // the plan's *effective* backend: all-CP group assignments are the
    // CP-forced plan family (over-budget single-node operators are its
    // contract — warnings), anything else is held to the distributed
    // policy.
    let verify = if spec.verify {
        let all_cp = candidates[best].groups.iter().all(|&b| b == ExecBackend::Cp);
        let vbackend = if all_cp {
            ExecBackend::Cp
        } else if spec.default_backend != ExecBackend::Cp {
            spec.default_backend
        } else {
            ExecBackend::Mr
        };
        let report = crate::analysis::verify_faults(
            &best_plan.runtime,
            &bases[all_raw[best].base].cfg,
            &spec.cc,
            &spec.constants,
            &spec.fault,
            vbackend,
        );
        if !report.is_clean() {
            return Err(format!(
                "plan verification failed for argmin candidate ({}): {} error(s)\n{}",
                candidates[best].label(),
                report.errors(),
                report.render()
            ));
        }
        Some(report)
    } else {
        None
    };

    // Count memo hits from the per-candidate reuse flags: the distinct
    // count may include CP-probe compiles that are not candidates.
    let memo_hits = all_evals.iter().filter(|e| e.plan_reused).count();
    let cache_stats = eval.run_cache_stats();
    Ok(GdfReport {
        memo_hits,
        distinct_plans: eval.distinct_plans(),
        skipped_duplicates: eval.duplicates_skipped(),
        cache_hits: cache_stats.hits,
        cache_misses: cache_stats.misses,
        best,
        baseline: 0,
        ranking,
        trace,
        before_explain,
        after_explain,
        candidates,
        truncated_cuts,
        wall_secs: t0.elapsed().as_secs_f64(),
        threads,
        verify,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Scenario;

    fn tiny_spec() -> GdfSpec {
        let s = Scenario::xl1();
        let mut spec = GdfSpec::linreg_cg(DataScenario::from(&s), 10);
        // keep the unit-test grid small: one extra blocksize, no format /
        // partition variants beyond the defaults
        spec.blocksizes = vec![1000];
        spec.formats = vec![Format::BinaryBlock];
        spec.partitions_mb = vec![32.0];
        spec.threads = 2;
        spec
    }

    #[test]
    fn baseline_is_candidate_zero_and_best_beats_it() {
        let r = optimize(&tiny_spec()).unwrap();
        assert_eq!(r.baseline, 0);
        let base = r.baseline();
        assert_eq!(base.blocksize, 1000);
        assert_eq!(base.format, Format::BinaryBlock);
        assert!(base.groups.iter().all(|&b| b == ExecBackend::Mr));
        // CG on XL1: the Spark loop group must strictly beat the MR default
        assert!(
            r.best().cost_secs < base.cost_secs,
            "best {} !< default {}",
            r.best().cost_secs,
            base.cost_secs
        );
        assert!(r.improvement_pct() > 0.0);
    }

    #[test]
    fn ranking_is_cheapest_first_and_total() {
        let r = optimize(&tiny_spec()).unwrap();
        assert_eq!(r.ranking.len(), r.candidates.len());
        let costs: Vec<f64> = r.ranked().map(|c| c.cost_secs).collect();
        assert!(costs.windows(2).all(|w| w[0] <= w[1]), "{costs:?}");
        assert_eq!(r.ranking[0], r.best);
    }

    #[test]
    fn trace_covers_every_cut_and_matches_groups() {
        let r = optimize(&tiny_spec()).unwrap();
        assert_eq!(r.trace.len(), r.best().groups.len());
        for (i, d) in r.trace.iter().enumerate() {
            assert_eq!(d.cut, i);
            assert_eq!(d.backend, r.best().groups[i]);
        }
        // at least one cut is distributed in the default plan
        assert!(r.trace.iter().any(|d| d.jobs_before > 0), "{:#?}", r.trace);
        let table = r.decision_table();
        assert!(table.contains("backend"), "{table}");
        assert!(table.contains("GENERIC"), "{table}");
    }

    #[test]
    fn explain_diff_shows_both_plan_families() {
        let r = optimize(&tiny_spec()).unwrap();
        let diff = r.explain_diff();
        // default = MR, optimized = at least one Spark group
        assert!(diff.contains("- "), "{diff}");
        assert!(diff.contains("+ "), "{diff}");
        assert!(r.before_explain.contains("MR-Job["), "{}", r.before_explain);
        assert!(r.after_explain.contains("SPARK-Job["), "{}", r.after_explain);
        // pid normalisation keeps diffs stable across processes
        assert!(!r.before_explain.contains(&format!("_p{}", std::process::id())));
    }

    #[test]
    fn verify_flag_audits_the_argmin_and_traces_it() {
        let mut spec = tiny_spec();
        spec.verify = true;
        let r = optimize(&spec).unwrap();
        let v = r.verify.as_ref().expect("verify requested");
        assert!(v.is_clean(), "{}", v.render());
        let table = r.decision_table();
        assert!(table.contains("verify: "), "{table}");
        spec.verify = false;
        let r = optimize(&spec).unwrap();
        assert!(r.verify.is_none());
        assert!(!r.decision_table().contains("verify: "));
    }

    #[test]
    fn degenerate_specs_are_rejected() {
        let mut spec = tiny_spec();
        spec.backends.clear();
        assert!(optimize(&spec).is_err());
        let mut spec = tiny_spec();
        spec.blocksizes = vec![0];
        assert!(optimize(&spec).is_err());
        let mut spec = tiny_spec();
        spec.partitions_mb = vec![f64::NAN];
        assert!(optimize(&spec).is_err());
        let mut spec = tiny_spec();
        spec.cc.cp_heap_bytes = 0.0;
        let err = optimize(&spec).unwrap_err();
        assert!(err.contains("cp_heap_bytes"), "{err}");
    }

    #[test]
    fn fault_profile_inflates_distributed_candidates_only() {
        let base = optimize(&tiny_spec()).unwrap();
        // none() is a bitwise no-op on every candidate cost
        let mut spec = tiny_spec();
        spec.fault = FaultProfile::none();
        let none = optimize(&spec).unwrap();
        for (a, b) in base.candidates.iter().zip(&none.candidates) {
            assert_eq!(a.cost_secs.to_bits(), b.cost_secs.to_bits(), "{}", a.label());
        }
        // chaos strictly inflates candidates with distributed jobs and
        // leaves pure-CP candidates untouched
        spec.fault = FaultProfile::chaos();
        let chaos = optimize(&spec).unwrap();
        assert_eq!(base.candidates.len(), chaos.candidates.len());
        for (a, c) in base.candidates.iter().zip(&chaos.candidates) {
            if c.mr_jobs + c.spark_jobs == 0 {
                assert_eq!(a.cost_secs.to_bits(), c.cost_secs.to_bits(), "{}", c.label());
            } else {
                assert!(c.cost_secs > a.cost_secs, "{} not inflated", c.label());
            }
        }
        // degenerate profiles are rejected up front
        spec.fault.max_attempts = 0;
        assert!(optimize(&spec).unwrap_err().contains("FaultProfile"));
    }

    #[test]
    fn assignment_enumeration_excludes_all_default() {
        let all = ExecBackend::all().to_vec();
        let a = assignments(&[1, 3], &all, 5, ExecBackend::Mr);
        // 3^2 - 1 (all-default excluded)
        assert_eq!(a.len(), 8);
        for g in &a {
            assert_eq!(g.len(), 5);
            assert_eq!(g[0], ExecBackend::Mr);
            assert_eq!(g[2], ExecBackend::Mr);
            assert_eq!(g[4], ExecBackend::Mr);
            assert!(g[1] != ExecBackend::Mr || g[3] != ExecBackend::Mr);
        }
        // no interesting cuts -> nothing beyond the baseline
        assert!(assignments(&[], &all, 5, ExecBackend::Mr).is_empty());
    }

    #[test]
    fn mb_axis_labels_preserve_fractions() {
        assert_eq!(fmt_mb_axis(32.0), "32");
        assert_eq!(fmt_mb_axis(0.5), "0.5");
    }

    #[test]
    fn line_diff_marks_changes_only() {
        let d = line_diff("a\nb\nc\n", "a\nx\nc\n");
        assert_eq!(d, "  a\n- b\n+ x\n  c\n");
        let same = line_diff("a\nb\n", "a\nb\n");
        assert!(same.lines().all(|l| l.starts_with("  ")), "{same}");
    }
}
