//! Global plan comparison: cost a program under alternative physical
//! operator choices. This is how the ablation benches quantify the value
//! of each optimizer decision (tsmm vs cpmm vs rmm, the (yᵀX)ᵀ rewrite,
//! partitioned broadcasts).

use std::collections::HashMap;

use crate::api::{compile_with_meta, CompileOptions};
use crate::conf::CostConstants;
use crate::cost;
use crate::ir::build::MetaProvider;
use crate::lop::SelectionHints;

/// A named plan alternative.
#[derive(Clone, Debug)]
pub struct PlanAlternative {
    /// Variant label (`optimizer`, `force-cpmm`, …).
    pub name: String,
    /// Estimated execution time `C(P, cc)` in seconds.
    pub cost_secs: f64,
    /// Number of MR jobs in the generated plan.
    pub mr_jobs: usize,
}

/// Compare the optimizer's plan with forced alternatives.
pub fn compare_plans(
    src: &str,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    base: &CompileOptions,
) -> Result<Vec<PlanAlternative>, String> {
    base.cc.0.validate()?;
    let variants: Vec<(&str, SelectionHints)> = vec![
        ("optimizer", SelectionHints::default()),
        ("force-cpmm", SelectionHints { force_cpmm: true, ..Default::default() }),
        ("force-rmm", SelectionHints { force_rmm: true, ..Default::default() }),
        (
            "no-transpose-rewrite",
            SelectionHints { no_transpose_rewrite: true, ..Default::default() },
        ),
    ];
    let mut out = Vec::new();
    for (name, hints) in variants {
        let opts = CompileOptions { hints, ..base.clone() };
        let compiled = compile_with_meta(src, args, meta, &opts)?;
        let report = cost::cost_program(
            &compiled.runtime,
            &opts.cfg,
            &opts.cc.0,
            &CostConstants::default(),
        );
        out.push(PlanAlternative {
            name: name.to_string(),
            cost_secs: report.total,
            mr_jobs: compiled.runtime.mr_job_count(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Scenario;

    #[test]
    fn optimizer_beats_or_matches_forced_alternatives_on_xl1() {
        let s = Scenario::xl1();
        let alts = compare_plans(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &CompileOptions::default(),
        )
        .unwrap();
        let opt = alts.iter().find(|a| a.name == "optimizer").unwrap();
        for a in &alts {
            assert!(
                opt.cost_secs <= a.cost_secs * 1.001,
                "optimizer ({}) worse than {} ({})",
                opt.cost_secs,
                a.name,
                a.cost_secs
            );
        }
        // forcing cpmm on XL1 must be visibly worse (extra jobs + shuffle)
        let cpmm = alts.iter().find(|a| a.name == "force-cpmm").unwrap();
        assert!(cpmm.cost_secs > opt.cost_secs * 1.05, "cpmm {} vs {}", cpmm.cost_secs, opt.cost_secs);
        assert!(cpmm.mr_jobs > opt.mr_jobs);
    }

    #[test]
    fn xs_alternatives_are_all_cp() {
        let s = Scenario::xs();
        let alts = compare_plans(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &CompileOptions::default(),
        )
        .unwrap();
        for a in &alts {
            assert_eq!(a.mr_jobs, 0, "{}", a.name);
        }
    }
}
