//! Cost-model consumers (paper §1: "this cost model is leveraged by
//! several advanced optimizers like resource optimization and global data
//! flow optimization").
//!
//! * [`gdf::optimize`] — the global data flow optimizer: enumerate
//!   *interesting properties* per DAG cut (block size, on-disk format,
//!   broadcast partitioning, forced per-group execution backend),
//!   recompile each candidate into a runtime plan, cost it, and return
//!   the argmin plan with a per-cut decision trace and an EXPLAIN-style
//!   before/after plan diff — the first optimizer that changes plan
//!   *structure* rather than just the cluster configuration.
//! * [`resource::optimize_grid`] — the parallel grid resource optimizer:
//!   enumerate the joint heap × executor-memory × nodes × `k_local` ×
//!   backend space, compile once per distinct plan shape (memoization
//!   shared with the sweep engine), prune dominated points via the
//!   persistent-read IO floor, and return the cost argmin plus the
//!   (budget, time) Pareto frontier. [`resource::optimize`] /
//!   [`resource::optimize_backend`] are the legacy single-axis heap
//!   sweeps over the same costing.
//! * [`compare::compare_plans`] — cost a program under alternative
//!   physical-operator hints (cpmm vs mapmm vs rmm, rewrite on/off), the
//!   global-plan-comparison use case and the basis of the ablation benches.
//! * [`sweep::sweep`] — the batched, parallel scenario-sweep costing
//!   engine: a ClusterConfig × data-size grid compiled once per distinct
//!   plan shape and costed concurrently into a ranked comparison table
//!   (the paper's Table-1 workflow, automated).
//!
//! All three optimizers route their candidate fan-out through one shared
//! **evaluation core** ([`evaluate::Evaluator`]): signature-deduped
//! `Arc`-shared compiles, duplicate-cost skipping, and block-level cost
//! caching ([`crate::cost::cache`]) on a totals-only costing fast path —
//! with bitwise-identical results to the naive per-candidate
//! compile-and-cost loop.
//!
//! Every public item in this module tree carries rustdoc; the lint below
//! keeps it that way (satisfying the `cargo doc` CI gate).

#![warn(missing_docs)]

pub mod compare;
pub mod evaluate;
pub mod gdf;
pub mod resource;
pub mod sweep;
