//! Cost-model consumers (paper §1: "this cost model is leveraged by
//! several advanced optimizers like resource optimization and global data
//! flow optimization").
//!
//! * [`resource::optimize`] — enumerate cluster resource configurations
//!   (CP/map/reduce heap sizes), recompile the program under each, cost the
//!   generated plans, and return the cost-optimal configuration (the
//!   resource-optimizer use case).
//! * [`compare::compare_plans`] — cost a program under alternative
//!   physical-operator hints (cpmm vs mapmm vs rmm, rewrite on/off), the
//!   global-plan-comparison use case and the basis of the ablation benches.
//! * [`sweep::sweep`] — the batched, parallel scenario-sweep costing
//!   engine: a ClusterConfig × data-size grid compiled once per distinct
//!   plan shape and costed concurrently into a ranked comparison table
//!   (the paper's Table-1 workflow, automated).

pub mod compare;
pub mod resource;
pub mod sweep;
