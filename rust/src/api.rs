//! High-level compilation pipeline: DML source → HOP program → runtime
//! plan, plus the paper's Table-1 scenarios as ready-made inputs.
//!
//! ```no_run
//! use systemds::api::{CompileOptions, Scenario};
//!
//! let opts = CompileOptions::default();
//! let compiled = Scenario::xs().compile(&opts);
//! println!("{}", compiled.explain_hops(&opts));
//! ```

use std::collections::HashMap;

use crate::conf::{ClusterConfig, SystemConfig};
use crate::dml;
use crate::ir::{self, build::MetaProvider, build::StaticMeta, Program};
use crate::lop::SelectionHints;
use crate::matrix::{Format, MatrixCharacteristics};
use crate::rtprog::{self, RtProgram};

pub use crate::artifact::{
    ArgminRow, ArgminTable, Artifact, CacheSnapshot, CalibrationProfile, LoadedPlan,
    PlanArtifact, PLAN_FORMAT_VERSION,
};
pub use crate::conf::FaultProfile;
pub use crate::cost::cache::{CacheStats, CostCache};
pub use crate::feedback::{
    BlockClass, BlockRecord, CalibrateOptions, CalibrationReport, Corrections, MeasureMode,
    QErrorSummary, ReoptReport,
};
pub use crate::opt::evaluate::{
    budget_error_reason, Budget, Candidate, CostContext, Evaluated, Evaluator, PlanMemo,
    BUDGET_ERROR_PREFIX, BUDGET_REASON_CANDIDATES, BUDGET_REASON_DEADLINE,
};
pub use crate::opt::gdf::{CutDecision, GdfCandidate, GdfReport, GdfSpec};
pub use crate::opt::resource::{GridPoint, ResourceGrid, ResourceReport};
pub use crate::opt::sweep::{DataScenario, NamedCluster, SweepCell, SweepReport, SweepSpec};
pub use crate::analysis::{Diagnostic, Pass, Severity, VerifyReport};
pub use crate::rtprog::ExecBackend;

/// Statically verify a compiled runtime plan: dataflow lint, independent
/// shape & memory audit, and cost-invariant audit (see [`crate::analysis`]).
/// Returns the deterministically ordered diagnostic report; callers that
/// enforce well-formedness should check [`VerifyReport::is_clean`].
pub fn verify_plan(compiled: &CompiledProgram, opts: &CompileOptions) -> VerifyReport {
    verify_plan_faults(compiled, opts, &FaultProfile::none())
}

/// [`verify_plan`] under a failure profile: the cost-invariant pass
/// audits retry-aware costs (see [`FaultProfile`]), so plans picked by a
/// fault-aware optimizer are checked against the numbers that actually
/// decided them. [`FaultProfile::none`] is bitwise-identical to
/// [`verify_plan`].
pub fn verify_plan_faults(
    compiled: &CompiledProgram,
    opts: &CompileOptions,
    fault: &FaultProfile,
) -> VerifyReport {
    crate::analysis::verify_faults(
        &compiled.runtime,
        &opts.cfg,
        &opts.cc.0,
        &crate::conf::CostConstants::default(),
        fault,
        opts.backend,
    )
}

/// Run a parallel scenario sweep: compile the spec's script once per
/// distinct plan shape across the ClusterConfig × data-size grid, cost
/// every cell concurrently, and return the ranked comparison report
/// (the paper's Table-1 workflow, automated). Thin wrapper around
/// [`crate::opt::sweep::sweep`]; see that module for the pipeline.
pub fn sweep(spec: &SweepSpec) -> Result<SweepReport, String> {
    crate::opt::sweep::sweep(spec)
}

/// Run the parallel grid resource optimizer: enumerate the joint
/// heap × executor-memory × nodes × `k_local` × backend space, compile
/// once per distinct plan shape (plan-signature memoization shared with
/// [`sweep`]), prune dominated points via the persistent-read IO floor,
/// and return the cost-argmin configuration plus the (resource budget,
/// estimated time) Pareto frontier. Thin wrapper around
/// [`crate::opt::resource::optimize_grid`]; see that module for the
/// wave pipeline and the budget semantics.
pub fn optimize_resources(grid: &ResourceGrid) -> Result<ResourceReport, String> {
    crate::opt::resource::optimize_grid(grid)
}

/// Run the global data flow optimizer: enumerate *interesting properties*
/// per DAG cut — block size, on-disk format, broadcast-partitioning
/// decision and forced per-operator-group execution backend — recompile
/// each candidate configuration into a runtime plan (plan-signature
/// memoization shared with [`sweep`] and [`optimize_resources`]), cost
/// every candidate with the linearised time model, and return the argmin
/// plan with a per-cut decision trace plus an EXPLAIN-style before/after
/// plan diff. Thin wrapper around [`crate::opt::gdf::optimize`]; see that
/// module for the enumeration and pruning rules.
pub fn optimize_global_dataflow(spec: &GdfSpec) -> Result<GdfReport, String> {
    crate::opt::gdf::optimize(spec)
}

/// Persist an artifact — a compiled-plan record ([`PlanArtifact`]), a
/// cost-cache snapshot ([`CacheSnapshot`]) or a calibration profile
/// ([`CalibrationProfile`]) — to the versioned on-disk text form. The
/// write is atomic (temp file + rename), so a crashed save never leaves
/// a half-written artifact behind. Thin wrapper around
/// [`crate::artifact::save`].
pub fn save_artifact(path: &std::path::Path, artifact: &Artifact) -> Result<(), String> {
    crate::artifact::save(path, artifact)
}

/// Load any artifact kind back from disk, dispatching on the header's
/// kind token and verifying the trailing checksum before parsing;
/// corrupted, truncated or unknown-version files fail with a diagnostic
/// (never a panic). Thin wrapper around [`crate::artifact::load`].
pub fn load_artifact(path: &std::path::Path) -> Result<Artifact, String> {
    crate::artifact::load(path)
}

/// Run the measured-execution feedback loop: execute the bundled
/// calibration workloads with per-block instrumentation (or the
/// deterministic simulated proxy), fit multiplicative corrections to the
/// cost constants via robust regression, report before/after Q-error per
/// block class, and re-run the backend-choice optimization under the
/// calibrated constants. Thin wrapper around
/// [`crate::feedback::calibrate`]; see that module for the pipeline.
pub fn calibrate(opts: &CalibrateOptions) -> Result<CalibrationReport, String> {
    crate::feedback::calibrate(opts)
}

/// Compilation options: system config + cluster characteristics + hints +
/// execution backend (CP-only, hybrid CP/MR — the default — or hybrid
/// CP/Spark; see [`ExecBackend`]).
#[derive(Clone, Debug, Default)]
pub struct CompileOptions {
    pub cfg: SystemConfig,
    pub cc: ClusterConfigOpt,
    pub hints: SelectionHints,
    pub backend: ExecBackend,
}

/// Wrapper defaulting to the paper's cluster.
#[derive(Clone, Debug)]
pub struct ClusterConfigOpt(pub ClusterConfig);

impl Default for ClusterConfigOpt {
    fn default() -> Self {
        ClusterConfigOpt(ClusterConfig::paper_cluster())
    }
}

/// A fully compiled program: HOP level + runtime plan.
#[derive(Clone, Debug)]
pub struct CompiledProgram {
    pub hops: Program,
    pub runtime: RtProgram,
}

impl CompiledProgram {
    /// HOP-level EXPLAIN (Figure 1).
    pub fn explain_hops(&self, opts: &CompileOptions) -> String {
        ir::explain::explain_hops(&self.hops, &opts.cfg, &opts.cc.0)
    }

    /// Runtime-level EXPLAIN (Figures 2 and 3).
    pub fn explain_runtime(&self) -> String {
        rtprog::explain::explain_runtime(&self.runtime, rtprog::explain::ExplainOpts::default())
    }
}

/// Compile a DML script with `$N` argument bindings, reading matrix
/// metadata from `.mtd` sidecar files.
pub fn compile(
    src: &str,
    args: &HashMap<usize, String>,
    opts: &CompileOptions,
) -> Result<CompiledProgram, String> {
    compile_with_meta(src, args, &ir::build::FileMeta, opts)
}

/// Compile with explicit metadata (used by the paper-scale scenarios where
/// no data exists on disk).
pub fn compile_with_meta(
    src: &str,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    opts: &CompileOptions,
) -> Result<CompiledProgram, String> {
    compile_with_groups(src, args, meta, opts, &[])
}

/// Compile with a per-operator-group backend assignment: top-level block
/// `i` of the main program is exec-typed and code-generated against
/// `groups[i]` (nested blocks inherit their group's backend; blocks
/// beyond `groups.len()` and function bodies use `opts.backend`). This is
/// the pipeline the global data flow optimizer drives — an empty `groups`
/// is exactly [`compile_with_meta`].
///
/// Every public compile entry routes through here, so the cluster
/// configuration is always validated before any plan is generated: a
/// degenerate `cc` (zero heap, zero `k_local`, …) becomes a diagnostic
/// instead of NaN cost estimates downstream.
pub fn compile_with_groups(
    src: &str,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    opts: &CompileOptions,
    groups: &[ExecBackend],
) -> Result<CompiledProgram, String> {
    opts.cc.0.validate()?;
    let script = dml::frontend(src)?;
    let mut prog = ir::build::build_program(&script, args, meta, opts.cfg.blocksize)?;
    ir::rewrites::rewrite_program(&mut prog);
    ir::size_prop::propagate(&mut prog, opts.cfg.blocksize);
    ir::memory::annotate(&mut prog, &opts.cfg);
    ir::exec_type::select_groups(
        &mut prog,
        &opts.cfg,
        &opts.cc.0,
        opts.backend == ExecBackend::Cp,
        groups,
    );
    let runtime = rtprog::gen::generate_groups(
        &prog,
        &opts.cfg,
        &opts.cc.0,
        &opts.hints,
        opts.backend,
        groups,
    );
    Ok(CompiledProgram { hops: prog, runtime })
}

// ---------------------------------------------------------------------
// Paper scenarios (Table 1)
// ---------------------------------------------------------------------

/// The paper's running example: closed-form linear regression (LinReg DS).
pub const LINREG_DS: &str = r#"X = read($1);
y = read($2);
intercept = $3; lambda = 0.001;
if( intercept == 1 ) {
  ones = matrix(1, nrow(X), 1);
  X = append(X, ones);
}
I = matrix(1, ncol(X), 1);
A = t(X) %*% X + diag(I)*lambda;
b = t(X) %*% y;
beta = solve(A, b);
write(beta, $4);"#;

/// Iterative linear regression via conjugate gradient (LinReg CG): the
/// loop-heavy sibling of [`LINREG_DS`]. Each of the `$3` iterations runs
/// two large matrix-vector products (`X %*% p` and `t(X) %*% v`), so on
/// distributed backends every iteration submits jobs — the workload where
/// per-job latency dominates and backend choice flips with the iteration
/// count (Kaoudi et al. 2017).
pub const LINREG_CG: &str = r#"X = read($1);
y = read($2);
maxiter = $3; lambda = 0.001;
r = -(t(X) %*% y);
norm_r2 = sum(r * r);
p = -r;
w = matrix(0, ncol(X), 1);
for (i in 1:maxiter) {
  q = t(X) %*% (X %*% p) + lambda * p;
  alpha = norm_r2 / sum(p * q);
  w = w + alpha * p;
  old_norm_r2 = norm_r2;
  r = r + alpha * q;
  norm_r2 = sum(r * r);
  p = -r + (norm_r2 / old_norm_r2) * p;
}
write(w, $4);"#;

/// `$N` bindings for [`LINREG_CG`]: abstract paths plus the iteration
/// count bound to `$3`.
pub fn linreg_cg_args(iterations: usize) -> HashMap<usize, String> {
    let mut m = HashMap::new();
    m.insert(1, "data/X".to_string());
    m.insert(2, "data/y".to_string());
    m.insert(3, iterations.to_string());
    m.insert(4, "data/w".to_string());
    m
}

/// One of the paper's Table-1 input-size scenarios.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub x_rows: i64,
    pub x_cols: i64,
    /// Input size in bytes (decimal, as Table 1 reports).
    pub input_bytes: f64,
}

impl Scenario {
    pub fn xs() -> Self {
        Scenario { name: "XS", x_rows: 10_000, x_cols: 1_000, input_bytes: 80e6 }
    }
    pub fn xl1() -> Self {
        Scenario { name: "XL1", x_rows: 100_000_000, x_cols: 1_000, input_bytes: 800e9 }
    }
    pub fn xl2() -> Self {
        Scenario { name: "XL2", x_rows: 100_000_000, x_cols: 2_000, input_bytes: 1.6e12 }
    }
    pub fn xl3() -> Self {
        Scenario { name: "XL3", x_rows: 200_000_000, x_cols: 1_000, input_bytes: 1.6e12 }
    }
    pub fn xl4() -> Self {
        Scenario { name: "XL4", x_rows: 200_000_000, x_cols: 2_000, input_bytes: 3.2e12 }
    }

    pub fn all() -> Vec<Scenario> {
        vec![Self::xs(), Self::xl1(), Self::xl2(), Self::xl3(), Self::xl4()]
    }

    pub fn script(&self) -> &'static str {
        LINREG_DS
    }

    /// `$N` bindings (intercept = 0, abstract paths).
    pub fn args(&self) -> HashMap<usize, String> {
        let mut m = HashMap::new();
        m.insert(1, "data/X".to_string());
        m.insert(2, "data/y".to_string());
        m.insert(3, "0".to_string());
        m.insert(4, "data/beta".to_string());
        m
    }

    /// Static metadata matching Table 1 (dense binary-block).
    pub fn meta(&self, blocksize: i64) -> StaticMeta {
        StaticMeta::default()
            .with(
                "data/X",
                MatrixCharacteristics::dense(self.x_rows, self.x_cols, blocksize),
                Format::BinaryBlock,
            )
            .with(
                "data/y",
                MatrixCharacteristics::dense(self.x_rows, 1, blocksize),
                Format::BinaryBlock,
            )
    }

    /// Compile this scenario.
    pub fn compile(&self, opts: &CompileOptions) -> CompiledProgram {
        compile_with_meta(self.script(), &self.args(), &self.meta(opts.cfg.blocksize), opts)
            .expect("scenario compiles")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtprog::{CpOp, Instr, JobType, MrOp, RtBlock};

    fn insts_of(prog: &RtProgram, idx: usize) -> &[Instr] {
        match &prog.blocks[idx] {
            RtBlock::Generic { insts, .. } => insts,
            other => panic!("expected generic block, got {other:?}"),
        }
    }

    fn cp_codes(insts: &[Instr]) -> Vec<String> {
        insts
            .iter()
            .filter_map(|i| match i {
                Instr::Cp(c) => Some(c.op.code()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn xs_runtime_plan_matches_figure2() {
        let opts = CompileOptions::default();
        let c = Scenario::xs().compile(&opts);
        let (cp, mr) = c.runtime.size();
        assert_eq!(mr, 0, "XS is pure CP (Figure 2: size CP/MR = 34/0)");
        assert!(cp > 10);
        // Block 2 instructions (Figure 2): tsmm, rand, r'(y), rdiag, ba+*,
        // +, r', solve, write — same multiset; interleaving of independent
        // chains may differ from SystemML's emission order.
        let mut codes = cp_codes(insts_of(&c.runtime, 1));
        let mut expect =
            vec!["tsmm", "rand", "r'", "rdiag", "ba+*", "+", "r'", "solve", "write"];
        let ordered = codes.clone();
        codes.sort();
        expect.sort();
        assert_eq!(codes, expect, "Figure 2 instruction multiset");
        // key data dependencies must be respected
        let pos = |c: &str| ordered.iter().position(|x| x == c).unwrap();
        assert!(pos("tsmm") < pos("+"), "{ordered:?}");
        assert!(pos("rand") < pos("rdiag"));
        assert!(pos("ba+*") < pos("solve"));
        assert!(pos("+") < pos("solve"));
        assert!(pos("solve") < pos("write"));
        // the (y'X)' rewrite: no transpose of X (only of y and the product)
        let text = c.explain_runtime();
        assert!(text.contains("CP tsmm X.MATRIX.DOUBLE"), "{text}");
        assert!(text.contains("LEFT"));
        assert!(text.contains("CP r' y.MATRIX.DOUBLE"));
    }

    #[test]
    fn xs_block1_bookkeeping_matches_figure2() {
        let opts = CompileOptions::default();
        let c = Scenario::xs().compile(&opts);
        let insts = insts_of(&c.runtime, 0);
        let rendered: Vec<String> =
            insts.iter().map(crate::rtprog::explain::render_inst).collect();
        assert!(rendered.iter().any(|s| s.starts_with("CP createvar pREADX")), "{rendered:?}");
        assert!(rendered.iter().any(|s| s.contains("assignvar 0.SCALAR.INT.true intercept")));
        assert!(rendered.iter().any(|s| s.contains("assignvar 0.001.SCALAR.DOUBLE.true lambda")));
        assert!(rendered.iter().any(|s| s == "CP cpvar pREADX X"));
        assert!(rendered.iter().any(|s| s == "CP cpvar pREADy y"));
    }

    #[test]
    fn xl1_runtime_plan_matches_figure3() {
        let opts = CompileOptions::default();
        let c = Scenario::xl1().compile(&opts);
        let (_, mr) = c.runtime.size();
        assert_eq!(mr, 1, "XL1 packs into a single MR job (Figure 3)");
        let insts = insts_of(&c.runtime, 1);
        // CP partition of y before the job (partitioned broadcast)
        let codes = cp_codes(insts);
        assert!(codes.contains(&"partition".to_string()), "{codes:?}");
        // find the job
        let job = insts
            .iter()
            .find_map(|i| match i {
                Instr::MrJob(j) => Some(j),
                _ => None,
            })
            .unwrap();
        assert_eq!(job.job_type, JobType::Gmr);
        assert_eq!(job.map_insts.len(), 3, "tsmm, r', mapmm share the job");
        assert!(job.map_insts.iter().any(|i| matches!(i.op, MrOp::Tsmm { left: true })));
        assert!(job.map_insts.iter().any(|i| i.op == MrOp::Transpose));
        assert!(job.map_insts.iter().any(|i| matches!(i.op, MrOp::MapMM { right_part: true })));
        assert_eq!(job.agg_insts.len(), 2, "ak+ for tsmm and mapmm");
        assert_eq!(job.num_reducers, 12);
        assert_eq!(job.replication, 1);
        // solve and + remain CP after the job
        assert!(codes.contains(&"+".to_string()));
        assert!(codes.contains(&"solve".to_string()));
    }

    #[test]
    fn xl2_three_jobs_with_cpmm() {
        let opts = CompileOptions::default();
        let c = Scenario::xl2().compile(&opts);
        assert_eq!(c.runtime.mr_job_count(), 3, "XL2: MMCJ + 2 GMR");
        let insts = insts_of(&c.runtime, 1);
        let jobs: Vec<_> = insts
            .iter()
            .filter_map(|i| match i {
                Instr::MrJob(j) => Some(j),
                _ => None,
            })
            .collect();
        assert!(jobs.iter().any(|j| j.job_type == JobType::Mmcj));
        // transpose replicated into both the MMCJ and the mapmm GMR
        let transposes: usize = jobs
            .iter()
            .map(|j| j.all_insts().filter(|i| i.op == MrOp::Transpose).count())
            .sum();
        assert_eq!(transposes, 2, "transpose of X replicated into both jobs");
    }

    #[test]
    fn xl3_three_jobs() {
        let opts = CompileOptions::default();
        let c = Scenario::xl3().compile(&opts);
        assert_eq!(c.runtime.mr_job_count(), 3);
        // tsmm still map-side; X'y via cpmm
        let insts = insts_of(&c.runtime, 1);
        let jobs: Vec<_> = insts
            .iter()
            .filter_map(|i| match i {
                Instr::MrJob(j) => Some(j),
                _ => None,
            })
            .collect();
        assert!(jobs.iter().any(|j| j.all_insts().any(|i| matches!(i.op, MrOp::Tsmm { .. }))));
        assert!(jobs.iter().any(|j| j.all_insts().any(|i| i.op == MrOp::Cpmm)));
        assert!(!jobs.iter().any(|j| j.all_insts().any(|i| matches!(i.op, MrOp::MapMM { .. }))));
    }

    #[test]
    fn xl4_three_jobs_shared_agg() {
        let opts = CompileOptions::default();
        let c = Scenario::xl4().compile(&opts);
        assert_eq!(c.runtime.mr_job_count(), 3, "2 MMCJ + shared agg GMR");
        let insts = insts_of(&c.runtime, 1);
        let jobs: Vec<_> = insts
            .iter()
            .filter_map(|i| match i {
                Instr::MrJob(j) => Some(j),
                _ => None,
            })
            .collect();
        let mmcj = jobs.iter().filter(|j| j.job_type == JobType::Mmcj).count();
        assert_eq!(mmcj, 2);
        let shared = jobs.iter().find(|j| j.job_type == JobType::Gmr).unwrap();
        assert_eq!(shared.agg_insts.len(), 2, "both cpmm aggregations shared");
    }

    #[test]
    fn explain_runtime_contains_figure3_sections() {
        let opts = CompileOptions::default();
        let c = Scenario::xl1().compile(&opts);
        let text = c.explain_runtime();
        assert!(text.contains("PROGRAM ( size CP/MR ="), "{text}");
        assert!(text.contains("MR-Job["));
        assert!(text.contains("jobtype        = GMR"));
        assert!(text.contains("num reducers   = 12"));
        assert!(text.contains("CP partition"));
        assert!(text.contains("mapmm"));
        assert!(text.contains("RIGHT_PART"));
        assert!(text.contains("ak+"));
    }

    #[test]
    fn spark_backend_emits_fused_job_for_xl1() {
        let opts = CompileOptions { backend: ExecBackend::Spark, ..Default::default() };
        let s = Scenario::xl1();
        let c = compile_with_meta(LINREG_DS, &s.args(), &s.meta(1000), &opts).unwrap();
        let (_, mr, sp) = c.runtime.size3();
        assert_eq!(mr, 0, "spark backend emits no MR jobs");
        assert_eq!(sp, 1, "the XL1 wave fuses into one Spark job");
        let insts = insts_of(&c.runtime, 1);
        let job = insts
            .iter()
            .find_map(|i| match i {
                Instr::SparkJob(j) => Some(j),
                _ => None,
            })
            .unwrap();
        assert_eq!(job.stages.len(), 2, "narrow scan + wide aggregation");
        assert!(job.stages[0].insts.iter().any(|i| matches!(i.op, MrOp::Tsmm { .. })));
        assert!(job.stages[0].insts.iter().any(|i| matches!(i.op, MrOp::MapMM { .. })));
        assert!(job.stages[1].wide);
        // torrent broadcast replaces the partitioned dcache broadcast:
        // no CP partition instruction on the Spark backend
        assert!(!cp_codes(insts).contains(&"partition".to_string()));
        assert_eq!(job.broadcasts.len(), 1);
        let text = c.explain_runtime();
        assert!(text.contains("SPARK-Job["), "{text}");
        assert!(text.contains("size CP/MR/SPARK ="), "{text}");
    }

    #[test]
    fn spark_backend_fuses_xl2_cpmm_into_one_job() {
        // XL2 needs 3 MR jobs (MMCJ + 2 GMR); Spark's lazy stages need 1.
        let opts = CompileOptions { backend: ExecBackend::Spark, ..Default::default() };
        let s = Scenario::xl2();
        let c = compile_with_meta(LINREG_DS, &s.args(), &s.meta(1000), &opts).unwrap();
        assert_eq!(c.runtime.spark_job_count(), 1, "one fused job vs 3 MR jobs");
        let mr_opts = CompileOptions::default();
        let mr_c = compile_with_meta(LINREG_DS, &s.args(), &s.meta(1000), &mr_opts).unwrap();
        assert_eq!(mr_c.runtime.mr_job_count(), 3);
    }

    #[test]
    fn cp_backend_forces_single_node_plans() {
        let opts = CompileOptions { backend: ExecBackend::Cp, ..Default::default() };
        let s = Scenario::xl4();
        let c = compile_with_meta(LINREG_DS, &s.args(), &s.meta(1000), &opts).unwrap();
        assert_eq!(c.runtime.dist_job_count(), 0, "CP backend never distributes");
    }

    #[test]
    fn linreg_cg_compiles_on_every_backend() {
        for backend in ExecBackend::all() {
            let opts = CompileOptions { backend, ..Default::default() };
            let s = Scenario::xl1();
            let c = compile_with_meta(LINREG_CG, &linreg_cg_args(20), &s.meta(1000), &opts)
                .unwrap();
            // the loop compiled with a known trip count of 20
            let has_loop = c.runtime.blocks.iter().any(|b| matches!(
                b,
                RtBlock::For { known_trip: Some(t), .. } if *t == 20.0
            ));
            assert!(has_loop, "backend {}: CG loop missing", backend.name());
            match backend {
                ExecBackend::Cp => assert_eq!(c.runtime.dist_job_count(), 0),
                ExecBackend::Mr => assert!(c.runtime.mr_job_count() > 0),
                ExecBackend::Spark => {
                    assert!(c.runtime.spark_job_count() > 0);
                    assert_eq!(c.runtime.mr_job_count(), 0);
                }
            }
        }
    }

    #[test]
    fn intercept_branch_compiles_with_append() {
        let mut args = Scenario::xs().args();
        args.insert(3, "1".to_string());
        let opts = CompileOptions::default();
        let c = compile_with_meta(LINREG_DS, &args, &Scenario::xs().meta(1000), &opts).unwrap();
        let text = c.explain_runtime();
        assert!(text.contains("append"), "{text}");
    }

    #[test]
    fn control_flow_compiles_to_rt_blocks() {
        let src = r#"
X = read($1);
s = 0;
for (i in 1:10) { s = s + sum(X); }
while (s < 100) { s = s * 2; }
if (s > 5) { s = s - 1; }
write(s, $4);
"#;
        let opts = CompileOptions::default();
        let c = compile_with_meta(src, &Scenario::xs().args(), &Scenario::xs().meta(1000), &opts)
            .unwrap();
        let kinds: Vec<&str> = c
            .runtime
            .blocks
            .iter()
            .map(|b| match b {
                RtBlock::Generic { .. } => "g",
                RtBlock::If { .. } => "if",
                RtBlock::For { .. } => "for",
                RtBlock::While { .. } => "while",
                RtBlock::FCall { .. } => "fcall",
            })
            .collect();
        assert!(kinds.contains(&"for"));
        assert!(kinds.contains(&"while"));
        assert!(kinds.contains(&"if"));
    }

    #[test]
    fn rmvar_inserted_after_last_use() {
        let opts = CompileOptions::default();
        let c = Scenario::xs().compile(&opts);
        let insts = insts_of(&c.runtime, 1);
        // every _mVar temp must be rmvar'd eventually
        let created: Vec<String> = insts
            .iter()
            .filter_map(|i| match i {
                Instr::CreateVar { var, temp: true, .. } => Some(var.clone()),
                _ => None,
            })
            .collect();
        let removed: Vec<String> = insts
            .iter()
            .filter_map(|i| match i {
                Instr::RmVar { vars } => Some(vars.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        for v in created {
            assert!(removed.contains(&v), "{v} never removed");
        }
    }

    #[test]
    fn write_op_emitted_with_path() {
        let opts = CompileOptions::default();
        let c = Scenario::xs().compile(&opts);
        let insts = insts_of(&c.runtime, 1);
        let has_write = insts.iter().any(|i| {
            matches!(i, Instr::Cp(c) if matches!(&c.op, CpOp::Write { path, .. } if path == "data/beta"))
        });
        assert!(has_write);
    }
}
