//! Recursive-descent parser for DML.
//!
//! Operator precedence (low to high), following R/DML:
//! `|` < `&` < comparisons < `:` < `+ -` < `* /` < `%*% %% %/%` <
//! unary `- !` < `^` < primary.

use super::ast::{BinOp, Expr, Script, Stmt, UnOp};
use super::lexer::{lex, Tok, Token};

/// Parse DML source into a [`Script`].
pub fn parse(src: &str) -> Result<Script, String> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    let stmts = p.stmt_list(true)?;
    p.expect(Tok::Eof)?;
    Ok(Script { stmts })
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn peek2(&self) -> &Tok {
        &self.toks[(self.pos + 1).min(self.toks.len() - 1)].tok
    }

    fn line(&self) -> usize {
        self.toks[self.pos].line
    }

    fn bump(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos < self.toks.len() - 1 {
            self.pos += 1;
        }
        t
    }

    fn eat(&mut self, t: Tok) -> bool {
        if *self.peek() == t {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, t: Tok) -> Result<(), String> {
        if self.eat(t.clone()) {
            Ok(())
        } else {
            Err(format!("line {}: expected {:?}, found {:?}", self.line(), t, self.peek()))
        }
    }

    /// Statement list; `top` distinguishes top level (ends at EOF) from
    /// block level (ends at `}`).
    fn stmt_list(&mut self, top: bool) -> Result<Vec<Stmt>, String> {
        let mut stmts = Vec::new();
        loop {
            while self.eat(Tok::Semi) {}
            let end = if top { *self.peek() == Tok::Eof } else { *self.peek() == Tok::RBrace };
            if end {
                break;
            }
            stmts.push(self.stmt()?);
        }
        Ok(stmts)
    }

    fn block(&mut self) -> Result<Vec<Stmt>, String> {
        self.expect(Tok::LBrace)?;
        let stmts = self.stmt_list(false)?;
        self.expect(Tok::RBrace)?;
        Ok(stmts)
    }

    fn stmt(&mut self) -> Result<Stmt, String> {
        let line = self.line();
        match self.peek().clone() {
            Tok::If => {
                self.bump();
                self.expect(Tok::LParen)?;
                let cond = self.expr()?;
                self.expect(Tok::RParen)?;
                let then_branch = self.block_or_single()?;
                let else_branch = if self.eat(Tok::Else) {
                    if *self.peek() == Tok::If {
                        vec![self.stmt()?] // else if
                    } else {
                        self.block_or_single()?
                    }
                } else {
                    Vec::new()
                };
                Ok(Stmt::If { cond, then_branch, else_branch, line })
            }
            Tok::For | Tok::Parfor => {
                let parfor = self.bump() == Tok::Parfor;
                self.expect(Tok::LParen)?;
                let var = self.ident()?;
                self.expect(Tok::In)?;
                let from = self.expr_no_range()?;
                self.expect(Tok::Colon)?;
                let to = self.expr_no_range()?;
                // optional `, by` step — seq-style loops
                let by = if self.eat(Tok::Comma) { Some(self.expr()?) } else { None };
                self.expect(Tok::RParen)?;
                let body = self.block_or_single()?;
                Ok(Stmt::For { var, from, to, by, body, parfor, line })
            }
            Tok::While => {
                self.bump();
                self.expect(Tok::LParen)?;
                let cond = self.expr()?;
                self.expect(Tok::RParen)?;
                let body = self.block_or_single()?;
                Ok(Stmt::While { cond, body, line })
            }
            Tok::LBracket => {
                // [a, b] = f(...)
                self.bump();
                let mut targets = vec![self.ident()?];
                while self.eat(Tok::Comma) {
                    targets.push(self.ident()?);
                }
                self.expect(Tok::RBracket)?;
                self.expect(Tok::Assign)?;
                let expr = self.expr()?;
                Ok(Stmt::MultiAssign { targets, expr, line })
            }
            Tok::Ident(name) => {
                // write(...) / print(...) statements, function defs,
                // or plain assignment.
                if name == "write" && *self.peek2() == Tok::LParen {
                    self.bump();
                    self.bump();
                    let expr = self.expr()?;
                    self.expect(Tok::Comma)?;
                    let file = self.expr()?;
                    let mut format = None;
                    while self.eat(Tok::Comma) {
                        // named arg: format="text"
                        let key = self.ident()?;
                        self.expect(Tok::Assign)?;
                        let val = self.expr()?;
                        if key == "format" {
                            if let Expr::Str(s) = val {
                                format = Some(s);
                            }
                        }
                    }
                    self.expect(Tok::RParen)?;
                    return Ok(Stmt::Write { expr, file, format, line });
                }
                if name == "print" && *self.peek2() == Tok::LParen {
                    self.bump();
                    self.bump();
                    let expr = self.expr()?;
                    self.expect(Tok::RParen)?;
                    return Ok(Stmt::Print { expr, line });
                }
                let target = self.ident()?;
                self.expect(Tok::Assign)?;
                if *self.peek() == Tok::Function {
                    return self.func_def(target, line);
                }
                let expr = self.expr()?;
                Ok(Stmt::Assign { target, expr, line })
            }
            other => Err(format!("line {line}: unexpected token {other:?}")),
        }
    }

    fn block_or_single(&mut self) -> Result<Vec<Stmt>, String> {
        if *self.peek() == Tok::LBrace {
            self.block()
        } else {
            Ok(vec![self.stmt()?])
        }
    }

    /// `function (p1, p2) return (o1, o2) { body }`; parameter type
    /// annotations (`matrix[double] X`, `double s`) are recorded.
    fn func_def(&mut self, name: String, line: usize) -> Result<Stmt, String> {
        self.expect(Tok::Function)?;
        self.expect(Tok::LParen)?;
        let mut params = Vec::new();
        let mut param_kinds = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                let (p, kind) = self.typed_ident()?;
                params.push(p);
                param_kinds.push(kind);
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
        }
        self.expect(Tok::RParen)?;
        let mut outputs = Vec::new();
        if self.eat(Tok::Return) {
            self.expect(Tok::LParen)?;
            if *self.peek() != Tok::RParen {
                loop {
                    outputs.push(self.typed_ident()?.0);
                    if !self.eat(Tok::Comma) {
                        break;
                    }
                }
            }
            self.expect(Tok::RParen)?;
        }
        let body = self.block()?;
        Ok(Stmt::FuncDef { name, params, param_kinds, outputs, body, line })
    }

    /// Identifier, optionally preceded by a type annotation like
    /// `matrix[double]` or `double`. Returns (name, Some(is_matrix)).
    fn typed_ident(&mut self) -> Result<(String, Option<bool>), String> {
        let first = self.ident()?;
        if first == "matrix" && self.eat(Tok::LBracket) {
            // type annotation: matrix[double] X
            self.ident()?; // value type
            self.expect(Tok::RBracket)?;
            return Ok((self.ident()?, Some(true)));
        }
        // "double x" style annotation
        if matches!(first.as_str(), "double" | "integer" | "boolean" | "string" | "int")
            && matches!(self.peek(), Tok::Ident(_))
        {
            return Ok((self.ident()?, Some(false)));
        }
        Ok((first, None))
    }

    fn ident(&mut self) -> Result<String, String> {
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => Err(format!("line {}: expected identifier, found {other:?}", self.line())),
        }
    }

    // ---- expression parsing, precedence climbing ----

    fn expr(&mut self) -> Result<Expr, String> {
        self.or_expr(true)
    }

    /// Expression that stops at `:` (used in `for (i in a:b)`).
    fn expr_no_range(&mut self) -> Result<Expr, String> {
        self.or_expr(false)
    }

    fn or_expr(&mut self, range_ok: bool) -> Result<Expr, String> {
        let mut lhs = self.and_expr(range_ok)?;
        while self.eat(Tok::Or) {
            let rhs = self.and_expr(range_ok)?;
            lhs = Expr::Binary(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self, range_ok: bool) -> Result<Expr, String> {
        let mut lhs = self.cmp_expr(range_ok)?;
        while self.eat(Tok::And) {
            let rhs = self.cmp_expr(range_ok)?;
            lhs = Expr::Binary(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self, range_ok: bool) -> Result<Expr, String> {
        let mut lhs = self.range_expr(range_ok)?;
        loop {
            let op = match self.peek() {
                Tok::Lt => BinOp::Lt,
                Tok::Gt => BinOp::Gt,
                Tok::Le => BinOp::Le,
                Tok::Ge => BinOp::Ge,
                Tok::EqEq => BinOp::Eq,
                Tok::Ne => BinOp::Ne,
                _ => break,
            };
            self.bump();
            let rhs = self.range_expr(range_ok)?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn range_expr(&mut self, range_ok: bool) -> Result<Expr, String> {
        let lhs = self.add_expr()?;
        if range_ok && *self.peek() == Tok::Colon {
            self.bump();
            let rhs = self.add_expr()?;
            return Ok(Expr::Binary(BinOp::Range, Box::new(lhs), Box::new(rhs)));
        }
        Ok(lhs)
    }

    fn add_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.bump();
            let rhs = self.mul_expr()?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.matmul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                _ => break,
            };
            self.bump();
            let rhs = self.matmul_expr()?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn matmul_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Tok::MatMul => BinOp::MatMul,
                Tok::Mod => BinOp::Mod,
                Tok::IntDiv => BinOp::IntDiv,
                _ => break,
            };
            self.bump();
            let rhs = self.unary_expr()?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr, String> {
        if self.eat(Tok::Minus) {
            let e = self.unary_expr()?;
            return Ok(match e {
                Expr::Int(v) => Expr::Int(-v),
                Expr::Num(v) => Expr::Num(-v),
                other => Expr::Unary(UnOp::Neg, Box::new(other)),
            });
        }
        if self.eat(Tok::Not) {
            let e = self.unary_expr()?;
            return Ok(Expr::Unary(UnOp::Not, Box::new(e)));
        }
        self.pow_expr()
    }

    fn pow_expr(&mut self) -> Result<Expr, String> {
        let base = self.primary()?;
        if self.eat(Tok::Caret) {
            // right-associative
            let exp = self.unary_expr()?;
            return Ok(Expr::Binary(BinOp::Pow, Box::new(base), Box::new(exp)));
        }
        Ok(base)
    }

    fn primary(&mut self) -> Result<Expr, String> {
        let line = self.line();
        match self.bump() {
            Tok::Int(v) => Ok(Expr::Int(v)),
            Tok::Num(v) => Ok(Expr::Num(v)),
            Tok::Str(s) => Ok(Expr::Str(s)),
            Tok::True => Ok(Expr::Bool(true)),
            Tok::False => Ok(Expr::Bool(false)),
            Tok::Arg(i) => Ok(Expr::Arg(i)),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(name) => {
                if *self.peek() == Tok::LParen {
                    self.bump();
                    let mut args = Vec::new();
                    if *self.peek() != Tok::RParen {
                        loop {
                            // skip named args (rows=, cols=, ...) keeping order
                            if let (Tok::Ident(_), Tok::Assign) = (self.peek(), self.peek2()) {
                                self.bump();
                                self.bump();
                            }
                            args.push(self.expr()?);
                            if !self.eat(Tok::Comma) {
                                break;
                            }
                        }
                    }
                    self.expect(Tok::RParen)?;
                    Ok(Expr::Call(name, args))
                } else {
                    Ok(Expr::Ident(name))
                }
            }
            other => Err(format!("line {line}: unexpected token {other:?} in expression")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's running example (§1).
    pub const LINREG_DS: &str = r#"
X = read($1);
y = read($2);
intercept = $3; lambda = 0.001;
if( intercept == 1 ) {
  ones = matrix(1, nrow(X), 1);
  X = append(X, ones);
}
I = matrix(1, ncol(X), 1);
A = t(X) %*% X + diag(I)*lambda;
b = t(X) %*% y;
beta = solve(A, b);
write(beta, $4);
"#;

    #[test]
    fn parses_linreg_example() {
        let s = parse(LINREG_DS).unwrap();
        assert_eq!(s.stmts.len(), 10);
        assert!(matches!(&s.stmts[4], Stmt::If { .. }));
        assert!(matches!(&s.stmts[9], Stmt::Write { .. }));
    }

    #[test]
    fn matmul_precedence_over_add() {
        // t(X) %*% X + diag(I)*lambda parses as (t(X)%*%X) + (diag(I)*lambda)
        let s = parse("A = t(X) %*% X + diag(I)*lambda;").unwrap();
        let Stmt::Assign { expr, .. } = &s.stmts[0] else { panic!() };
        let Expr::Binary(BinOp::Add, l, r) = expr else { panic!("expected +, got {expr:?}") };
        assert!(matches!(**l, Expr::Binary(BinOp::MatMul, _, _)));
        assert!(matches!(**r, Expr::Binary(BinOp::Mul, _, _)));
    }

    #[test]
    fn matmul_binds_tighter_than_scalar_mul() {
        // a * X %*% y == a * (X %*% y)
        let s = parse("z = a * X %*% y;").unwrap();
        let Stmt::Assign { expr, .. } = &s.stmts[0] else { panic!() };
        let Expr::Binary(BinOp::Mul, _, r) = expr else { panic!() };
        assert!(matches!(**r, Expr::Binary(BinOp::MatMul, _, _)));
    }

    #[test]
    fn parses_for_while_parfor() {
        let src = r#"
s = 0;
for (i in 1:10) { s = s + i; }
parfor (j in 1:4) { s = s + j; }
while (s < 100) { s = s * 2; }
"#;
        let s = parse(src).unwrap();
        assert!(matches!(&s.stmts[1], Stmt::For { parfor: false, .. }));
        assert!(matches!(&s.stmts[2], Stmt::For { parfor: true, .. }));
        assert!(matches!(&s.stmts[3], Stmt::While { .. }));
    }

    #[test]
    fn parses_function_def_and_multi_assign() {
        let src = r#"
f = function(matrix[double] X, double s) return (matrix[double] Y, double z) {
  Y = X * s;
  z = sum(Y);
}
[A, v] = f(B, 2.0);
"#;
        let s = parse(src).unwrap();
        let Stmt::FuncDef { params, outputs, .. } = &s.stmts[0] else { panic!() };
        assert_eq!(params, &["X", "s"]);
        assert_eq!(outputs, &["Y", "z"]);
        let Stmt::MultiAssign { targets, .. } = &s.stmts[1] else { panic!() };
        assert_eq!(targets, &["A", "v"]);
    }

    #[test]
    fn line_numbers_recorded() {
        let s = parse("a = 1;\nb = 2;\n\nc = 3;").unwrap();
        assert_eq!(s.stmts.iter().map(|s| s.line()).collect::<Vec<_>>(), vec![1, 2, 4]);
    }

    #[test]
    fn else_if_chain() {
        let s = parse("if (a == 1) { b = 1; } else if (a == 2) { b = 2; } else { b = 3; }")
            .unwrap();
        let Stmt::If { else_branch, .. } = &s.stmts[0] else { panic!() };
        assert!(matches!(&else_branch[0], Stmt::If { .. }));
    }

    #[test]
    fn named_args_skipped() {
        let s = parse("R = rand(rows=10, cols=20, min=0, max=1);").unwrap();
        let Stmt::Assign { expr: Expr::Call(name, args), .. } = &s.stmts[0] else { panic!() };
        assert_eq!(name, "rand");
        assert_eq!(args.len(), 4);
    }

    #[test]
    fn unary_and_pow() {
        let s = parse("x = -a ^ 2;").unwrap(); // -(a^2) in R
        let Stmt::Assign { expr, .. } = &s.stmts[0] else { panic!() };
        assert!(matches!(expr, Expr::Unary(UnOp::Neg, _)));
    }

    #[test]
    fn errors_are_reported_with_line() {
        let err = parse("a = ;\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = parse("if (x { }").unwrap_err();
        assert!(err.contains("expected"), "{err}");
    }
}
