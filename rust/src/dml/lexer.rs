//! Hand-written lexer for DML.

/// Token kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Num(f64),
    Int(i64),
    Str(String),
    Arg(usize), // $1, $2, ...
    // punctuation / operators
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Assign, // = or <-
    Plus,
    Minus,
    Star,
    Slash,
    Caret,
    Colon,
    MatMul, // %*%
    Mod,    // %%
    IntDiv, // %/%
    Lt,
    Gt,
    Le,
    Ge,
    EqEq,
    Ne,
    Not,
    And,
    Or,
    // keywords
    If,
    Else,
    For,
    Parfor,
    While,
    Function,
    Return,
    In,
    True,
    False,
    Eof,
}

/// A token with its source line (1-based).
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

/// Tokenize DML source. `#` starts a line comment.
pub fn lex(src: &str) -> Result<Vec<Token>, String> {
    let mut out = Vec::new();
    let bytes: Vec<char> = src.chars().collect();
    let mut i = 0;
    let mut line = 1;
    let n = bytes.len();
    while i < n {
        let c = bytes[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            ' ' | '\t' | '\r' => i += 1,
            '#' => {
                while i < n && bytes[i] != '\n' {
                    i += 1;
                }
            }
            '(' => push(&mut out, Tok::LParen, line, &mut i),
            ')' => push(&mut out, Tok::RParen, line, &mut i),
            '{' => push(&mut out, Tok::LBrace, line, &mut i),
            '}' => push(&mut out, Tok::RBrace, line, &mut i),
            '[' => push(&mut out, Tok::LBracket, line, &mut i),
            ']' => push(&mut out, Tok::RBracket, line, &mut i),
            ',' => push(&mut out, Tok::Comma, line, &mut i),
            ';' => push(&mut out, Tok::Semi, line, &mut i),
            '+' => push(&mut out, Tok::Plus, line, &mut i),
            '-' => push(&mut out, Tok::Minus, line, &mut i),
            '*' => push(&mut out, Tok::Star, line, &mut i),
            '/' => push(&mut out, Tok::Slash, line, &mut i),
            '^' => push(&mut out, Tok::Caret, line, &mut i),
            ':' => push(&mut out, Tok::Colon, line, &mut i),
            '&' => {
                i += if bytes.get(i + 1) == Some(&'&') { 2 } else { 1 };
                out.push(Token { tok: Tok::And, line });
            }
            '|' => {
                i += if bytes.get(i + 1) == Some(&'|') { 2 } else { 1 };
                out.push(Token { tok: Tok::Or, line });
            }
            '%' => {
                if i + 2 < n && bytes[i + 1] == '*' && bytes[i + 2] == '%' {
                    out.push(Token { tok: Tok::MatMul, line });
                    i += 3;
                } else if i + 2 < n && bytes[i + 1] == '/' && bytes[i + 2] == '%' {
                    out.push(Token { tok: Tok::IntDiv, line });
                    i += 3;
                } else if i + 1 < n && bytes[i + 1] == '%' {
                    out.push(Token { tok: Tok::Mod, line });
                    i += 2;
                } else {
                    return Err(format!("line {line}: stray '%'"));
                }
            }
            '<' => {
                if bytes.get(i + 1) == Some(&'=') {
                    out.push(Token { tok: Tok::Le, line });
                    i += 2;
                } else if bytes.get(i + 1) == Some(&'-') {
                    out.push(Token { tok: Tok::Assign, line });
                    i += 2;
                } else {
                    out.push(Token { tok: Tok::Lt, line });
                    i += 1;
                }
            }
            '>' => {
                if bytes.get(i + 1) == Some(&'=') {
                    out.push(Token { tok: Tok::Ge, line });
                    i += 2;
                } else {
                    out.push(Token { tok: Tok::Gt, line });
                    i += 1;
                }
            }
            '=' => {
                if bytes.get(i + 1) == Some(&'=') {
                    out.push(Token { tok: Tok::EqEq, line });
                    i += 2;
                } else {
                    out.push(Token { tok: Tok::Assign, line });
                    i += 1;
                }
            }
            '!' => {
                if bytes.get(i + 1) == Some(&'=') {
                    out.push(Token { tok: Tok::Ne, line });
                    i += 2;
                } else {
                    out.push(Token { tok: Tok::Not, line });
                    i += 1;
                }
            }
            '$' => {
                let mut j = i + 1;
                while j < n && bytes[j].is_ascii_digit() {
                    j += 1;
                }
                if j == i + 1 {
                    return Err(format!("line {line}: expected digit after '$'"));
                }
                let idx: usize = bytes[i + 1..j].iter().collect::<String>().parse().unwrap();
                out.push(Token { tok: Tok::Arg(idx), line });
                i = j;
            }
            '"' | '\'' => {
                let quote = c;
                let mut j = i + 1;
                let mut s = String::new();
                while j < n && bytes[j] != quote {
                    if bytes[j] == '\n' {
                        return Err(format!("line {line}: unterminated string"));
                    }
                    s.push(bytes[j]);
                    j += 1;
                }
                if j >= n {
                    return Err(format!("line {line}: unterminated string"));
                }
                out.push(Token { tok: Tok::Str(s), line });
                i = j + 1;
            }
            c if c.is_ascii_digit() || (c == '.' && bytes.get(i + 1).is_some_and(|d| d.is_ascii_digit())) => {
                let mut j = i;
                let mut is_float = false;
                while j < n
                    && (bytes[j].is_ascii_digit()
                        || bytes[j] == '.'
                        || bytes[j] == 'e'
                        || bytes[j] == 'E'
                        || ((bytes[j] == '+' || bytes[j] == '-')
                            && j > i
                            && (bytes[j - 1] == 'e' || bytes[j - 1] == 'E')))
                {
                    if bytes[j] == '.' || bytes[j] == 'e' || bytes[j] == 'E' {
                        is_float = true;
                    }
                    j += 1;
                }
                let text: String = bytes[i..j].iter().collect();
                if is_float {
                    let v: f64 =
                        text.parse().map_err(|_| format!("line {line}: bad number '{text}'"))?;
                    out.push(Token { tok: Tok::Num(v), line });
                } else {
                    let v: i64 =
                        text.parse().map_err(|_| format!("line {line}: bad integer '{text}'"))?;
                    out.push(Token { tok: Tok::Int(v), line });
                }
                i = j;
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let mut j = i;
                while j < n
                    && (bytes[j].is_ascii_alphanumeric() || bytes[j] == '_' || bytes[j] == '.')
                {
                    j += 1;
                }
                let word: String = bytes[i..j].iter().collect();
                let tok = match word.as_str() {
                    "if" => Tok::If,
                    "else" => Tok::Else,
                    "for" => Tok::For,
                    "parfor" => Tok::Parfor,
                    "while" => Tok::While,
                    "function" => Tok::Function,
                    "return" => Tok::Return,
                    "in" => Tok::In,
                    "TRUE" | "true" => Tok::True,
                    "FALSE" | "false" => Tok::False,
                    _ => Tok::Ident(word),
                };
                out.push(Token { tok, line });
                i = j;
            }
            other => return Err(format!("line {line}: unexpected character '{other}'")),
        }
    }
    out.push(Token { tok: Tok::Eof, line });
    Ok(out)
}

fn push(out: &mut Vec<Token>, tok: Tok, line: usize, i: &mut usize) {
    out.push(Token { tok, line });
    *i += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lexes_linreg_line() {
        let toks = kinds("A = t(X) %*% X + diag(I)*lambda;");
        assert!(toks.contains(&Tok::MatMul));
        assert!(toks.contains(&Tok::Ident("t".into())));
        assert!(toks.contains(&Tok::Ident("diag".into())));
        assert_eq!(*toks.last().unwrap(), Tok::Eof);
    }

    #[test]
    fn lexes_args_and_numbers() {
        let toks = kinds("x = read($1); l = 0.001; n = 42; e = 1e-3;");
        assert!(toks.contains(&Tok::Arg(1)));
        assert!(toks.contains(&Tok::Num(0.001)));
        assert!(toks.contains(&Tok::Int(42)));
        assert!(toks.contains(&Tok::Num(1e-3)));
    }

    #[test]
    fn tracks_lines_and_comments() {
        let toks = lex("a = 1;\n# comment\nb = 2;").unwrap();
        let b_tok = toks.iter().find(|t| t.tok == Tok::Ident("b".into())).unwrap();
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn comparison_and_logical_ops() {
        let toks = kinds("if (a <= b & c != d | !e) {}");
        assert!(toks.contains(&Tok::Le));
        assert!(toks.contains(&Tok::And));
        assert!(toks.contains(&Tok::Ne));
        assert!(toks.contains(&Tok::Or));
        assert!(toks.contains(&Tok::Not));
    }

    #[test]
    fn strings_and_errors() {
        assert!(kinds("s = \"hello world\";").contains(&Tok::Str("hello world".into())));
        assert!(lex("s = \"unterminated").is_err());
        assert!(lex("x = 1 @ 2").is_err());
    }

    #[test]
    fn percent_operators() {
        let toks = kinds("a %% b %/% c %*% d");
        assert_eq!(
            toks[..7].iter().filter(|t| matches!(t, Tok::Mod | Tok::IntDiv | Tok::MatMul)).count(),
            3
        );
    }

    #[test]
    fn arrow_assignment() {
        assert!(kinds("x <- 3").contains(&Tok::Assign));
    }
}
