//! Abstract syntax tree for DML.

/// Binary operators, in DML surface syntax.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    MatMul, // %*%
    Mod,    // %%
    IntDiv, // %/%
    Lt,
    Gt,
    Le,
    Ge,
    Eq,
    Ne,
    And,
    Or,
    Range, // a:b (sequence in for loops)
}

impl BinOp {
    pub fn name(&self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Pow => "^",
            BinOp::MatMul => "%*%",
            BinOp::Mod => "%%",
            BinOp::IntDiv => "%/%",
            BinOp::Lt => "<",
            BinOp::Gt => ">",
            BinOp::Le => "<=",
            BinOp::Ge => ">=",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "&",
            BinOp::Or => "|",
            BinOp::Range => ":",
        }
    }
}

/// Unary operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Not,
}

/// Expressions.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// Numeric literal (DML doubles; integers are represented exactly).
    Num(f64),
    /// Integer literal.
    Int(i64),
    /// String literal.
    Str(String),
    /// Boolean literal (TRUE/FALSE).
    Bool(bool),
    /// Variable reference.
    Ident(String),
    /// Command-line argument `$1`, `$2`, ….
    Arg(usize),
    Unary(UnOp, Box<Expr>),
    Binary(BinOp, Box<Expr>, Box<Expr>),
    /// Builtin or user-defined function call, e.g. `t(X)`, `solve(A, b)`.
    Call(String, Vec<Expr>),
}

impl Expr {
    pub fn call(name: &str, args: Vec<Expr>) -> Expr {
        Expr::Call(name.to_string(), args)
    }
}

/// Statements. Every statement records its 1-based source line for the
/// program-block line ranges shown by EXPLAIN (e.g. `GENERIC (lines 1-3)`).
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// `x = expr;`
    Assign { target: String, expr: Expr, line: usize },
    /// `[a, b] = f(...);` multi-output function call.
    MultiAssign { targets: Vec<String>, expr: Expr, line: usize },
    /// `if (cond) { .. } else { .. }`
    If { cond: Expr, then_branch: Vec<Stmt>, else_branch: Vec<Stmt>, line: usize },
    /// `for (i in from:to) { .. }` / `parfor (...) { .. }`
    For {
        var: String,
        from: Expr,
        to: Expr,
        by: Option<Expr>,
        body: Vec<Stmt>,
        parfor: bool,
        line: usize,
    },
    /// `while (cond) { .. }`
    While { cond: Expr, body: Vec<Stmt>, line: usize },
    /// `f = function(a, b) return (c, d) { .. }`
    FuncDef {
        name: String,
        params: Vec<String>,
        /// `Some(true)` = matrix, `Some(false)` = scalar, `None` = untyped.
        param_kinds: Vec<Option<bool>>,
        outputs: Vec<String>,
        body: Vec<Stmt>,
        line: usize,
    },
    /// `write(expr, file [, format="..."]);`
    Write { expr: Expr, file: Expr, format: Option<String>, line: usize },
    /// `print(expr);`
    Print { expr: Expr, line: usize },
}

impl Stmt {
    pub fn line(&self) -> usize {
        match self {
            Stmt::Assign { line, .. }
            | Stmt::MultiAssign { line, .. }
            | Stmt::If { line, .. }
            | Stmt::For { line, .. }
            | Stmt::While { line, .. }
            | Stmt::FuncDef { line, .. }
            | Stmt::Write { line, .. }
            | Stmt::Print { line, .. } => *line,
        }
    }

    /// Last source line covered by this statement (for block line ranges).
    pub fn end_line(&self) -> usize {
        fn last(stmts: &[Stmt], fallback: usize) -> usize {
            stmts.last().map_or(fallback, |s| s.end_line())
        }
        match self {
            Stmt::If { then_branch, else_branch, line, .. } => {
                last(else_branch, last(then_branch, *line))
            }
            Stmt::For { body, line, .. }
            | Stmt::While { body, line, .. }
            | Stmt::FuncDef { body, line, .. } => last(body, *line),
            _ => self.line(),
        }
    }
}

/// A parsed script.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Script {
    pub stmts: Vec<Stmt>,
}

/// Names of builtin functions recognised by the compiler.
pub const BUILTINS: &[&str] = &[
    "read", "matrix", "rand", "seq", "nrow", "ncol", "length", "t", "diag", "solve", "append",
    "cbind", "rbind", "sum", "mean", "rowSums", "colSums", "rowMeans", "colMeans", "min", "max",
    "sqrt", "abs", "exp", "log", "round", "floor", "ceil", "as.scalar", "as.matrix", "trace",
    "nnz", "sign",
];

/// Is `name` a builtin function?
pub fn is_builtin(name: &str) -> bool {
    BUILTINS.contains(&name)
}
