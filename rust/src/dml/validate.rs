//! Semantic validation: use-before-definition, builtin arity, duplicate
//! function definitions, and `$N` argument collection.

use std::collections::HashSet;

use super::ast::{is_builtin, Expr, Script, Stmt};

/// Validate a script; returns an error string on the first problem found.
pub fn validate(script: &Script) -> Result<(), String> {
    let mut funcs: HashSet<String> = HashSet::new();
    // Pre-pass: collect function names (functions may be called before their
    // textual definition in DML).
    collect_funcs(&script.stmts, &mut funcs)?;
    let mut defined: HashSet<String> = HashSet::new();
    check_stmts(&script.stmts, &mut defined, &funcs)
}

/// Collect the maximum `$N` argument index used in the script.
pub fn max_arg_index(script: &Script) -> usize {
    let mut max = 0;
    visit_exprs(&script.stmts, &mut |e| {
        if let Expr::Arg(i) = e {
            max = max.max(*i);
        }
    });
    max
}

fn collect_funcs(stmts: &[Stmt], funcs: &mut HashSet<String>) -> Result<(), String> {
    for s in stmts {
        if let Stmt::FuncDef { name, line, .. } = s {
            if !funcs.insert(name.clone()) {
                return Err(format!("line {line}: duplicate function definition '{name}'"));
            }
        }
    }
    Ok(())
}

fn check_stmts(
    stmts: &[Stmt],
    defined: &mut HashSet<String>,
    funcs: &HashSet<String>,
) -> Result<(), String> {
    for s in stmts {
        match s {
            Stmt::Assign { target, expr, line } => {
                check_expr(expr, defined, funcs, *line)?;
                defined.insert(target.clone());
            }
            Stmt::MultiAssign { targets, expr, line } => {
                check_expr(expr, defined, funcs, *line)?;
                for t in targets {
                    defined.insert(t.clone());
                }
            }
            Stmt::If { cond, then_branch, else_branch, line } => {
                check_expr(cond, defined, funcs, *line)?;
                // Variables defined in only one branch are conditionally
                // defined; SystemML warns, we accept (the union is visible).
                let mut then_defined = defined.clone();
                check_stmts(then_branch, &mut then_defined, funcs)?;
                let mut else_defined = defined.clone();
                check_stmts(else_branch, &mut else_defined, funcs)?;
                defined.extend(then_defined);
                defined.extend(else_defined);
            }
            Stmt::For { var, from, to, by, body, line, .. } => {
                check_expr(from, defined, funcs, *line)?;
                check_expr(to, defined, funcs, *line)?;
                if let Some(by) = by {
                    check_expr(by, defined, funcs, *line)?;
                }
                defined.insert(var.clone());
                check_stmts(body, defined, funcs)?;
            }
            Stmt::While { cond, body, line } => {
                check_expr(cond, defined, funcs, *line)?;
                check_stmts(body, defined, funcs)?;
            }
            Stmt::FuncDef { params, outputs, body, line, .. } => {
                let mut scope: HashSet<String> = params.iter().cloned().collect();
                check_stmts(body, &mut scope, funcs)?;
                for o in outputs {
                    if !scope.contains(o) {
                        return Err(format!(
                            "line {line}: function output '{o}' is never assigned in body"
                        ));
                    }
                }
            }
            Stmt::Write { expr, file, line, .. } => {
                check_expr(expr, defined, funcs, *line)?;
                check_expr(file, defined, funcs, *line)?;
            }
            Stmt::Print { expr, line } => check_expr(expr, defined, funcs, *line)?,
        }
    }
    Ok(())
}

fn check_expr(
    e: &Expr,
    defined: &HashSet<String>,
    funcs: &HashSet<String>,
    line: usize,
) -> Result<(), String> {
    match e {
        Expr::Ident(name) => {
            if !defined.contains(name) {
                return Err(format!("line {line}: use of undefined variable '{name}'"));
            }
            Ok(())
        }
        Expr::Unary(_, a) => check_expr(a, defined, funcs, line),
        Expr::Binary(_, a, b) => {
            check_expr(a, defined, funcs, line)?;
            check_expr(b, defined, funcs, line)
        }
        Expr::Call(name, args) => {
            if !is_builtin(name) && !funcs.contains(name) {
                return Err(format!("line {line}: call to unknown function '{name}'"));
            }
            check_arity(name, args.len(), line)?;
            for a in args {
                check_expr(a, defined, funcs, line)?;
            }
            Ok(())
        }
        _ => Ok(()),
    }
}

fn check_arity(name: &str, n: usize, line: usize) -> Result<(), String> {
    let ok = match name {
        "read" => n == 1,
        "matrix" => n == 3,
        "rand" => (2..=6).contains(&n),
        "seq" => (2..=3).contains(&n),
        "nrow" | "ncol" | "length" | "t" | "diag" | "sum" | "mean" | "rowSums" | "colSums"
        | "rowMeans" | "colMeans" | "sqrt" | "abs" | "exp" | "log" | "round" | "floor"
        | "ceil" | "as.scalar" | "as.matrix" | "trace" | "nnz" | "sign" => n == 1,
        "solve" | "append" | "cbind" | "rbind" => n == 2,
        "min" | "max" => (1..=2).contains(&n),
        _ => return Ok(()), // user-defined: arity checked at HOP build
    };
    if ok {
        Ok(())
    } else {
        Err(format!("line {line}: wrong number of arguments ({n}) for '{name}'"))
    }
}

fn visit_exprs(stmts: &[Stmt], f: &mut impl FnMut(&Expr)) {
    fn walk(e: &Expr, f: &mut impl FnMut(&Expr)) {
        f(e);
        match e {
            Expr::Unary(_, a) => walk(a, f),
            Expr::Binary(_, a, b) => {
                walk(a, f);
                walk(b, f);
            }
            Expr::Call(_, args) => args.iter().for_each(|a| walk(a, f)),
            _ => {}
        }
    }
    for s in stmts {
        match s {
            Stmt::Assign { expr, .. } | Stmt::MultiAssign { expr, .. } | Stmt::Print { expr, .. } => {
                walk(expr, f)
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                walk(cond, f);
                visit_exprs(then_branch, f);
                visit_exprs(else_branch, f);
            }
            Stmt::For { from, to, by, body, .. } => {
                walk(from, f);
                walk(to, f);
                if let Some(by) = by {
                    walk(by, f);
                }
                visit_exprs(body, f);
            }
            Stmt::While { cond, body, .. } => {
                walk(cond, f);
                visit_exprs(body, f);
            }
            Stmt::FuncDef { body, .. } => visit_exprs(body, f),
            Stmt::Write { expr, file, .. } => {
                walk(expr, f);
                walk(file, f);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::parser::parse;

    #[test]
    fn linreg_validates() {
        let src = r#"
X = read($1);
y = read($2);
intercept = $3; lambda = 0.001;
if( intercept == 1 ) { ones = matrix(1, nrow(X), 1); X = append(X, ones); }
I = matrix(1, ncol(X), 1);
A = t(X) %*% X + diag(I)*lambda;
b = t(X) %*% y;
beta = solve(A, b);
write(beta, $4);
"#;
        let s = parse(src).unwrap();
        assert!(validate(&s).is_ok());
        assert_eq!(max_arg_index(&s), 4);
    }

    #[test]
    fn undefined_variable_rejected() {
        let s = parse("a = b + 1;").unwrap();
        let err = validate(&s).unwrap_err();
        assert!(err.contains("undefined variable 'b'"));
    }

    #[test]
    fn unknown_function_rejected() {
        let s = parse("a = frobnicate(1);").unwrap();
        assert!(validate(&s).unwrap_err().contains("unknown function"));
    }

    #[test]
    fn bad_arity_rejected() {
        let s = parse("a = solve(1);").unwrap();
        assert!(validate(&s).unwrap_err().contains("wrong number of arguments"));
    }

    #[test]
    fn branch_defined_vars_visible_after_if() {
        let s = parse("c = 1; if (c == 1) { x = 2; } else { x = 3; } y = x;").unwrap();
        assert!(validate(&s).is_ok());
    }

    #[test]
    fn function_output_must_be_assigned() {
        let s = parse("f = function(a) return (b) { c = a; }").unwrap();
        assert!(validate(&s).unwrap_err().contains("never assigned"));
    }

    #[test]
    fn function_called_before_definition_ok() {
        let s = parse("y = g(1);\ng = function(a) return (b) { b = a; }").unwrap();
        assert!(validate(&s).is_ok());
    }

    #[test]
    fn loop_var_defined_in_body() {
        let s = parse("s = 0; for (i in 1:10) { s = s + i; }").unwrap();
        assert!(validate(&s).is_ok());
    }

    #[test]
    fn duplicate_function_definition_rejected() {
        let s = parse(
            "f = function(a) return (b) { b = a; }\nf = function(a) return (b) { b = a + 1; }",
        )
        .unwrap();
        let err = validate(&s).unwrap_err();
        assert!(err.contains("duplicate function definition 'f'"), "{err}");
    }

    #[test]
    fn builtin_arities_are_enforced() {
        for (src, name) in [
            ("a = read($1, $2);", "read"),
            ("a = matrix(1, 2);", "matrix"),
            ("a = rand(1);", "rand"),
            ("a = seq(1, 10, 2, 4);", "seq"),
            ("a = sum(1, 2);", "sum"),
            ("a = min(1, 2, 3);", "min"),
            ("a = cbind(matrix(1, 2, 2));", "cbind"),
        ] {
            let s = parse(src).unwrap();
            let err = validate(&s).unwrap_err();
            assert!(
                err.contains("wrong number of arguments") && err.contains(name),
                "{src}: {err}"
            );
        }
    }

    #[test]
    fn undefined_variable_in_while_condition_rejected() {
        let s = parse("while (q > 0) { q = 1; }").unwrap();
        assert!(validate(&s).unwrap_err().contains("undefined variable 'q'"));
    }

    #[test]
    fn undefined_variable_in_for_bounds_rejected() {
        let s = parse("for (i in 1:n) { s = i; }").unwrap();
        assert!(validate(&s).unwrap_err().contains("undefined variable 'n'"));
    }

    #[test]
    fn undefined_variable_in_write_and_print_rejected() {
        let s = parse("write(beta, $1);").unwrap();
        assert!(validate(&s).unwrap_err().contains("undefined variable 'beta'"));
        let s = parse("print(msg);").unwrap();
        assert!(validate(&s).unwrap_err().contains("undefined variable 'msg'"));
    }

    #[test]
    fn function_body_does_not_see_outer_scope() {
        // DML functions close over nothing: only params are in scope.
        let s = parse("x = 1;\nf = function(a) return (b) { b = a + x; }\ny = f(x);").unwrap();
        let err = validate(&s).unwrap_err();
        assert!(err.contains("undefined variable 'x'"), "{err}");
    }

    #[test]
    fn error_messages_carry_the_line_number() {
        let s = parse("a = 1;\nb = a + c;").unwrap();
        let err = validate(&s).unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
    }
}
