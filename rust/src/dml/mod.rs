//! DML language frontend: an R-like declarative ML language (SystemML's
//! DML), sufficient for the paper's running example and far beyond it —
//! control flow (`if`/`for`/`while`/`parfor`), user-defined functions,
//! matrix builtins, and `$N` command-line arguments.
//!
//! ```text
//! X = read($1);
//! y = read($2);
//! intercept = $3; lambda = 0.001;
//! if (intercept == 1) {
//!   ones = matrix(1, nrow(X), 1);
//!   X = append(X, ones);
//! }
//! I = matrix(1, ncol(X), 1);
//! A = t(X) %*% X + diag(I) * lambda;
//! b = t(X) %*% y;
//! beta = solve(A, b);
//! write(beta, $4);
//! ```

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod validate;

pub use ast::{BinOp, Expr, Script, Stmt, UnOp};
pub use parser::parse;
pub use validate::validate;

/// Parse and validate a script in one step.
pub fn frontend(src: &str) -> Result<Script, String> {
    let script = parse(src)?;
    validate(&script)?;
    Ok(script)
}
