//! Real PJRT kernel registry (compiled only with `--features pjrt`).
//!
//! Requires the non-crates.io `xla` bindings (xla-rs / xla_extension) to
//! be added to `rust/Cargo.toml` manually — the offline default build
//! cannot fetch them, which is why this module is feature-gated and the
//! hermetic [`super::stub`] is the default. See README.md §PJRT
//! artifacts for the setup steps.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::matrix::DenseMatrix;
use crate::util::error::{anyhow, bail, Context, Result};

struct Kernel {
    exe: xla::PjRtLoadedExecutable,
}

/// Registry of AOT-compiled kernels on a PJRT CPU client.
pub struct KernelRegistry {
    client: xla::PjRtClient,
    kernels: Mutex<HashMap<String, Kernel>>,
    /// Paths discovered but not yet compiled (lazy compilation).
    pending: Mutex<HashMap<String, std::path::PathBuf>>,
    /// Adaptive-dispatch outcomes: key -> prefer PJRT over native. Shared
    /// process-wide so the first-call race is paid once per kernel.
    preference: Mutex<HashMap<String, bool>>,
}

impl KernelRegistry {
    /// Scan a directory for `*.hlo.txt` artifacts. Compilation is lazy:
    /// each artifact is compiled on first use.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut pending = HashMap::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(dir)? {
                let path = entry?.path();
                let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
                if let Some(key) = name.strip_suffix(".hlo.txt") {
                    pending.insert(key.to_string(), path);
                }
            }
        }
        Ok(KernelRegistry {
            client,
            kernels: Mutex::new(HashMap::new()),
            pending: Mutex::new(pending),
            preference: Mutex::new(HashMap::new()),
        })
    }

    /// Number of discovered artifacts.
    pub fn len(&self) -> usize {
        self.kernels.lock().unwrap().len() + self.pending.lock().unwrap().len()
    }

    /// Whether no artifacts were discovered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether a kernel exists for this key.
    pub fn has(&self, key: &str) -> bool {
        self.kernels.lock().unwrap().contains_key(key)
            || self.pending.lock().unwrap().contains_key(key)
    }

    fn ensure_compiled(&self, key: &str) -> Result<()> {
        if self.kernels.lock().unwrap().contains_key(key) {
            return Ok(());
        }
        let path = {
            let pending = self.pending.lock().unwrap();
            pending.get(key).cloned()
        };
        let Some(path) = path else {
            bail!("no artifact for kernel '{key}'");
        };
        // HLO *text* interchange: jax >= 0.5 emits protos with 64-bit ids
        // that xla_extension 0.5.1 rejects; the text parser reassigns ids.
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf-8")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {key}: {e:?}"))?;
        self.pending.lock().unwrap().remove(key);
        self.kernels.lock().unwrap().insert(key.to_string(), Kernel { exe });
        Ok(())
    }

    /// Recorded dispatch preference for a key (None = not yet raced).
    pub fn preference(&self, key: &str) -> Option<bool> {
        self.preference.lock().unwrap().get(key).copied()
    }

    /// Record the PJRT-vs-native dispatch decision for a key.
    pub fn set_preference(&self, key: &str, prefer_pjrt: bool) {
        self.preference.lock().unwrap().insert(key.to_string(), prefer_pjrt);
    }

    /// Execute a kernel; returns `None` when no artifact matches the key
    /// (caller falls back to native Rust kernels).
    pub fn execute(&self, key: &str, inputs: &[&DenseMatrix]) -> Option<Result<DenseMatrix>> {
        if !self.has(key) {
            return None;
        }
        Some(self.execute_inner(key, inputs))
    }

    fn execute_inner(&self, key: &str, inputs: &[&DenseMatrix]) -> Result<DenseMatrix> {
        self.ensure_compiled(key)?;
        let kernels = self.kernels.lock().unwrap();
        let kernel = kernels.get(key).expect("compiled above");
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|m| {
                xla::Literal::vec1(&m.values)
                    .reshape(&[m.rows as i64, m.cols as i64])
                    .map_err(|e| anyhow!("reshape input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = kernel
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {key}: {e:?}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = literal.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let shape = out.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
        let dims = shape.dims();
        let (rows, cols) = match dims.len() {
            2 => (dims[0] as usize, dims[1] as usize),
            1 => (dims[0] as usize, 1),
            0 => (1, 1),
            _ => bail!("unexpected output rank {}", dims.len()),
        };
        let values = out.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(DenseMatrix::from_vec(rows, cols, values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_dir_gives_empty_registry() {
        let dir = std::env::temp_dir().join("sysds_empty_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let reg = KernelRegistry::load(&dir).unwrap();
        assert!(reg.is_empty());
        assert!(reg.execute("tsmm_8x8", &[]).is_none());
    }

    /// Executes a real artifact when `make artifacts` has run.
    #[test]
    fn executes_artifact_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
        let reg = KernelRegistry::load(&dir).unwrap();
        let key = "tsmm_256x64";
        if !reg.has(key) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let x = DenseMatrix::rand(256, 64, -1.0, 1.0, 1.0, 42);
        let got = reg.execute(key, &[&x]).unwrap().unwrap();
        let expect = crate::matrix::ops::tsmm_left(&x, 2);
        assert_eq!((got.rows, got.cols), (64, 64));
        assert!(got.max_abs_diff(&expect) < 1e-9);
    }
}
