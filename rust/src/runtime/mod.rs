//! PJRT runtime bridge: loads AOT-compiled XLA artifacts (HLO text emitted
//! by `python/compile/aot.py` from JAX/Pallas) and executes them on the
//! compute hot path. Python never runs at execution time — `make artifacts`
//! is a build-time step.
//!
//! The real bridge (in [`pjrt`], gated behind the off-by-default `pjrt`
//! cargo feature) needs the non-crates.io `xla` bindings; the default
//! build is hermetic and compiles the no-op [`stub`] instead, whose
//! [`KernelRegistry`] never matches a kernel so the CP runtime always
//! falls back to the native Rust kernels in [`crate::matrix::ops`].
//! Both expose the same API, so no caller is feature-aware.
//!
//! Artifacts live in `artifacts/<key>.hlo.txt` where `<key>` encodes the
//! operation and the (static) input shapes, e.g. `tsmm_4096x256`,
//! `matmult_1x4096_4096x256`, `linreg_4096x256`. The CP runtime consults
//! [`KernelRegistry::execute`] first and falls back to the native Rust
//! kernels for unmatched shapes.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::KernelRegistry;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::KernelRegistry;

/// Environment variable that overrides kernel-artifact directory
/// resolution (see [`kernel_artifact_dir`]).
pub const KERNEL_DIR_ENV: &str = "SYSDS_KERNEL_DIR";

/// Locate the AOT kernel artifact directory.
///
/// `KernelRegistry::load(Path::new("artifacts"))` used to resolve the
/// directory against whatever the process cwd happened to be, so running
/// `repro` from outside the checkout silently lost the compiled kernels.
/// Resolution order:
///
/// 1. `SYSDS_KERNEL_DIR` — used as given, even if it does not exist: an
///    explicit override that points nowhere should be diagnosed by the
///    caller, not silently skipped;
/// 2. `artifacts/` under the current working directory;
/// 3. `artifacts/` next to the running executable, then up through its
///    ancestors (covers `target/release/repro` inside a checkout);
/// 4. `artifacts/` under the workspace root the crate was built from
///    (dev builds run from elsewhere).
///
/// Returns `None` when no candidate directory exists.
pub fn kernel_artifact_dir() -> Option<std::path::PathBuf> {
    use std::path::PathBuf;
    if let Ok(dir) = std::env::var(KERNEL_DIR_ENV) {
        return Some(PathBuf::from(dir));
    }
    let mut candidates: Vec<PathBuf> = vec![PathBuf::from("artifacts")];
    if let Ok(exe) = std::env::current_exe() {
        for dir in exe.ancestors().skip(1) {
            candidates.push(dir.join("artifacts"));
        }
    }
    if let Some(root) = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        candidates.push(root.join("artifacts"));
    }
    candidates.into_iter().find(|c| c.is_dir())
}

/// Load the kernel registry for measured execution, resolving the
/// artifact directory via [`kernel_artifact_dir`] and *warning* — instead
/// of silently continuing — when compiled kernels were expected but none
/// could be loaded. Returns `None` on any miss; callers fall back to the
/// native Rust kernels.
pub fn load_registry_or_warn(ctx: &str) -> Option<KernelRegistry> {
    let Some(dir) = kernel_artifact_dir() else {
        eprintln!(
            "warning: {ctx}: no kernel artifact directory found (run `make artifacts` \
             or set {KERNEL_DIR_ENV}); using native Rust kernels"
        );
        return None;
    };
    match KernelRegistry::load(&dir) {
        Ok(reg) if !reg.is_empty() => Some(reg),
        Ok(_) => {
            eprintln!(
                "warning: {ctx}: kernel artifact directory {} holds no loadable kernels; \
                 using native Rust kernels",
                dir.display()
            );
            None
        }
        Err(e) => {
            eprintln!(
                "warning: {ctx}: failed to load kernel registry from {}: {e}; \
                 using native Rust kernels",
                dir.display()
            );
            None
        }
    }
}

/// Build the registry key for an op over the given input shapes.
pub fn kernel_key(op: &str, shapes: &[(usize, usize)]) -> String {
    let mut k = op.to_string();
    for (m, n) in shapes {
        k.push_str(&format!("_{m}x{n}"));
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_dir_env_override_wins() {
        // The override is honoured verbatim even when it points nowhere:
        // an explicit path that is wrong must surface downstream, not be
        // silently replaced by a cwd-relative guess.
        std::env::set_var(KERNEL_DIR_ENV, "/nonexistent/kernels");
        let d = kernel_artifact_dir();
        std::env::remove_var(KERNEL_DIR_ENV);
        assert_eq!(d, Some(std::path::PathBuf::from("/nonexistent/kernels")));
    }

    #[test]
    fn kernel_key_format() {
        assert_eq!(kernel_key("tsmm", &[(4096, 256)]), "tsmm_4096x256");
        assert_eq!(
            kernel_key("matmult", &[(1, 4096), (4096, 256)]),
            "matmult_1x4096_4096x256"
        );
    }
}
