//! PJRT runtime bridge: loads AOT-compiled XLA artifacts (HLO text emitted
//! by `python/compile/aot.py` from JAX/Pallas) and executes them on the
//! compute hot path. Python never runs at execution time — `make artifacts`
//! is a build-time step.
//!
//! The real bridge (in [`pjrt`], gated behind the off-by-default `pjrt`
//! cargo feature) needs the non-crates.io `xla` bindings; the default
//! build is hermetic and compiles the no-op [`stub`] instead, whose
//! [`KernelRegistry`] never matches a kernel so the CP runtime always
//! falls back to the native Rust kernels in [`crate::matrix::ops`].
//! Both expose the same API, so no caller is feature-aware.
//!
//! Artifacts live in `artifacts/<key>.hlo.txt` where `<key>` encodes the
//! operation and the (static) input shapes, e.g. `tsmm_4096x256`,
//! `matmult_1x4096_4096x256`, `linreg_4096x256`. The CP runtime consults
//! [`KernelRegistry::execute`] first and falls back to the native Rust
//! kernels for unmatched shapes.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::KernelRegistry;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::KernelRegistry;

/// Build the registry key for an op over the given input shapes.
pub fn kernel_key(op: &str, shapes: &[(usize, usize)]) -> String {
    let mut k = op.to_string();
    for (m, n) in shapes {
        k.push_str(&format!("_{m}x{n}"));
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_key_format() {
        assert_eq!(kernel_key("tsmm", &[(4096, 256)]), "tsmm_4096x256");
        assert_eq!(
            kernel_key("matmult", &[(1, 4096), (4096, 256)]),
            "matmult_1x4096_4096x256"
        );
    }
}
