//! Hermetic stand-in for the PJRT kernel registry (compiled when the
//! `pjrt` feature is off, which is the default).
//!
//! The stub never discovers or matches a kernel: [`KernelRegistry::has`]
//! is always `false` and [`KernelRegistry::execute`] always returns
//! `None`, so the CP interpreter's adaptive dispatch
//! ([`crate::cp::interp`]) takes the native-kernel path unconditionally.
//! The API mirrors [`super::pjrt`] exactly so callers need no `cfg`.

use std::path::Path;

use crate::matrix::DenseMatrix;
use crate::util::error::Result;

/// No-op registry: pretends the artifact directory is empty.
pub struct KernelRegistry {
    _priv: (),
}

impl KernelRegistry {
    /// Accepts any directory and reports no artifacts.
    pub fn load(dir: &Path) -> Result<Self> {
        let _ = dir;
        Ok(KernelRegistry { _priv: () })
    }

    /// Number of discovered artifacts (always 0).
    pub fn len(&self) -> usize {
        0
    }

    /// Always true for the stub.
    pub fn is_empty(&self) -> bool {
        true
    }

    /// Whether a kernel exists for this key (always false).
    pub fn has(&self, key: &str) -> bool {
        let _ = key;
        false
    }

    /// Recorded dispatch preference for a key (always `None`).
    pub fn preference(&self, key: &str) -> Option<bool> {
        let _ = key;
        None
    }

    /// Record a dispatch decision (ignored by the stub).
    pub fn set_preference(&self, key: &str, prefer_pjrt: bool) {
        let _ = (key, prefer_pjrt);
    }

    /// Execute a kernel; the stub never matches, so callers always fall
    /// back to the native Rust kernels.
    pub fn execute(&self, key: &str, inputs: &[&DenseMatrix]) -> Option<Result<DenseMatrix>> {
        let _ = (key, inputs);
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_registry_is_always_empty() {
        let dir = std::env::temp_dir().join("sysds_stub_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let reg = KernelRegistry::load(&dir).unwrap();
        assert!(reg.is_empty());
        assert_eq!(reg.len(), 0);
        assert!(!reg.has("tsmm_8x8"));
        assert!(reg.execute("tsmm_8x8", &[]).is_none());
        assert!(reg.preference("tsmm_8x8").is_none());
        reg.set_preference("tsmm_8x8", true);
        assert!(reg.preference("tsmm_8x8").is_none(), "stub records nothing");
    }
}
