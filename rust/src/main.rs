//! `repro` — command-line driver for the systemds-rs reproduction.
//!
//! ```text
//! repro explain --scenario xs --level hops|runtime      Figure 1 / 2 / 3
//! repro cost    --scenario xl1                          Figure 4 / 5
//! repro verify  --scenario xl1 [--backend spark]        static plan verification
//! repro scenarios                                       Table 1 + §2 plans
//! repro run <script.dml> [-a N=value ...]               execute a script
//! repro resource --grid heaps=512,2048:nodes=2,6        grid resource optimizer
//! repro resource-opt --scenario xs                      legacy heap sweep
//! repro sweep [--heaps 512,...] [--serial]              parallel grid sweep
//! repro gdf --script cg                                 global data flow optimizer
//! repro calibrate [--quick] [--simulated]               measured-execution feedback
//! repro plan save|load|diff <path>                      persistent plan artifacts
//! ```
//!
//! The optimizer commands (`sweep`, `resource`, `gdf`) additionally take
//! `--warm-cache <path>` (pre-load a cost-cache snapshot), `--save-cache
//! <path>` (snapshot the cache after the run) and `--profile <path>`
//! (run under the calibrated constants of a saved calibration profile).

use std::collections::HashMap;
use std::path::Path;

use systemds::api::{
    compile, compile_with_meta, linreg_cg_args, verify_plan_faults, Artifact, Budget,
    CacheSnapshot, CalibrationProfile, CompileOptions, Evaluator, ExecBackend, PlanArtifact,
    Scenario, LINREG_CG, PLAN_FORMAT_VERSION,
};
use systemds::conf::{ClusterConfig, CostConstants, FaultProfile, MB};
use systemds::cost;
use systemds::cp::interp::{ExecStats, Executor};
use systemds::matrix::{io, ops, DenseMatrix, Format};
use systemds::opt::gdf;
use systemds::opt::resource;
use systemds::opt::sweep::{self, heap_clock_clusters, DataScenario, SweepSpec};
use systemds::serve::{serve_lines, serve_tcp, ServeOptions, ServeState};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("explain") => cmd_explain(&args[1..]),
        Some("cost") => cmd_cost(&args[1..]),
        Some("verify") => cmd_verify(&args[1..]),
        Some("scenarios") => cmd_scenarios(),
        Some("run") => cmd_run(&args[1..]),
        Some("resource") => cmd_resource(&args[1..]),
        Some("resource-opt") => cmd_resource_opt(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("gdf") => cmd_gdf(&args[1..]),
        Some("calibrate") => cmd_calibrate(&args[1..]),
        Some("plan") => cmd_plan(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("chaos") => cmd_chaos(&args[1..]),
        _ => {
            eprintln!(
                "usage: repro <explain|cost|verify|scenarios|run|resource|resource-opt|sweep|gdf|calibrate|plan|serve|chaos> [options]\n\
                 \n\
                 explain --scenario <xs|xl1..xl4> [--level hops|runtime]\n\
                 \x20       [--backend cp|mr|spark] [--script ds|cg] [--iters N]\n\
                 cost    --scenario <xs|xl1..xl4> [--backend cp|mr|spark]\n\
                 \x20       [--script ds|cg] [--iters N] [--fault-profile SPEC]\n\
                 verify  --scenario <xs|xl1..xl4> [--backend cp|mr|spark]\n\
                 \x20       [--script ds|cg] [--iters N] [--fault-profile SPEC]\n\
                 \x20       (exit 1 on error diagnostics)\n\
                 scenarios\n\
                 run <script.dml> [-a N=value ...] [--threads T] [--heap-mb H]\n\
                 resource [--scenario <name>] [--script ds|cg] [--iters N]\n\
                 \x20     [--grid heaps=512,2048:execmem=2048,20480:nodes=2,6:klocal=6,24]\n\
                 \x20     [--backends cp,mr,spark] [--threads T] [--no-prune]\n\
                 \x20     [--no-cost-cache] [--all] [--warm-cache F] [--save-cache F]\n\
                 \x20     [--profile F] [--verify] [--budget-ms N] [--budget-candidates N]\n\
                 \x20     [--fault-profile SPEC]\n\
                 resource-opt --scenario <name> [--heaps 256,512,...]\n\
                 \x20       [--backend cp|mr|spark]\n\
                 sweep [--scenarios xs,xl1,...] [--heaps 512,1024,...]\n\
                 \x20     [--backends cp,mr,spark] [--script ds|cg] [--iters N]\n\
                 \x20     [--threads T] [--serial] [--no-cost-cache]\n\
                 \x20     [--warm-cache F] [--save-cache F] [--profile F] [--verify]\n\
                 \x20     [--fault-profile SPEC]\n\
                 gdf [--scenario <name>] [--script cg|ds] [--iters N]\n\
                 \x20   [--blocksizes 500,1000,2000] [--formats binaryblock,textcell]\n\
                 \x20   [--partitions 8,32] [--backends cp,mr,spark]\n\
                 \x20   [--threads T] [--no-diff] [--no-cost-cache] [--all]\n\
                 \x20   [--warm-cache F] [--save-cache F] [--profile F] [--verify]\n\
                 \x20   [--budget-ms N] [--budget-candidates N] [--fault-profile SPEC]\n\
                 calibrate [--quick] [--simulated] [--noise F] [--seed N]\n\
                 \x20         [--threads T] [--scratch DIR] [--profile F]\n\
                 \x20         [--save-profile F] [--fault-profile SPEC]\n\
                 plan save <path> [--scenario <name>] [--script cg|ds] [--iters N]\n\
                 \x20              [--backend cp|mr|spark] [--profile F]\n\
                 plan load <path>      (verify; regenerate synthesized data if stale)\n\
                 plan diff <path>      (EXPLAIN diff: stored plan vs fresh compile)\n\
                 serve [--listen ADDR:PORT] [--threads T] [--no-cost-cache]\n\
                 \x20     [--warm-cache F] [--profile F] [--fault-profile SPEC]\n\
                 \x20     [--spill-argmin F] [--idle-timeout MS]\n\
                 \x20     (line protocol on stdin/stdout or TCP; see README \"Serving\")\n\
                 chaos [--seed N] [--fault-profile SPEC]   (failure-aware argmin-flip\n\
                 \x20     smoke: price faults, flip the backend choice, confirm by\n\
                 \x20     executing both winners under injected faults)\n\
                 \n\
                 SPEC for --fault-profile: 'none', 'chaos', or key=value pairs\n\
                 (mr, spark, frac, slow, attempts, backoff, speculative), e.g.\n\
                 'chaos,spark=0.3' — see docs/COST_MODEL.md \u{00a7}10"
            );
            2
        }
    };
    std::process::exit(code);
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Strictly parse the value of `--name <value>`. `Ok(None)` when the
/// flag is absent; a value that fails to parse is an error *naming the
/// flag and the offending value* — flags like `--heap-mb 2O48` used to
/// be swallowed by `.parse().ok().unwrap_or(default)` and silently run
/// with the default.
fn parse_flag_value<T: std::str::FromStr>(
    args: &[String],
    name: &str,
    expected: &str,
) -> Result<Option<T>, String> {
    match flag(args, name) {
        None => Ok(None),
        Some(v) => v
            .parse::<T>()
            .map(Some)
            .map_err(|_| format!("{name}: invalid value '{v}' (expected {expected})")),
    }
}

/// [`parse_flag_value`], printed: `Err` carries the CLI exit code.
fn parse_flag<T: std::str::FromStr>(
    args: &[String],
    name: &str,
    expected: &str,
) -> Result<Option<T>, i32> {
    parse_flag_value(args, name, expected).map_err(|e| {
        eprintln!("{e}");
        2
    })
}

/// Strictly parse a comma-separated `--name v1,v2,...` list of positive
/// finite numbers (MB axes). `Ok(None)` when the flag is absent.
fn parse_mb_list_flag(args: &[String], name: &str) -> Result<Option<Vec<f64>>, i32> {
    let Some(raw) = flag(args, name) else {
        return Ok(None);
    };
    let mut out = Vec::new();
    for part in raw.split(',').filter(|p| !p.is_empty()) {
        match part.trim().parse::<f64>() {
            Ok(x) if x.is_finite() && x > 0.0 => out.push(x),
            _ => {
                eprintln!("{name}: invalid entry '{part}' (expected positive MB values, e.g. 512,1024,2048)");
                return Err(2);
            }
        }
    }
    if out.is_empty() {
        eprintln!("{name}: empty list");
        return Err(2);
    }
    Ok(Some(out))
}

// ---------------------------------------------------------------------
// Artifact flags shared by the optimizer commands
// ---------------------------------------------------------------------

/// Build the evaluator for an optimizer run, honouring `--warm-cache
/// <path>` (pre-load a [`CacheSnapshot`] from disk). `Err` carries the
/// exit code.
fn warm_evaluator(args: &[String], threads: usize, cost_cache: bool) -> Result<Evaluator, i32> {
    let threads =
        if threads == 0 { systemds::util::par::default_threads() } else { threads };
    let Some(path) = flag(args, "--warm-cache") else {
        return Ok(if cost_cache {
            Evaluator::new(threads)
        } else {
            Evaluator::without_cost_cache(threads)
        });
    };
    if !cost_cache {
        eprintln!("--warm-cache: incompatible with --no-cost-cache");
        return Err(2);
    }
    match systemds::api::load_artifact(Path::new(&path)) {
        Ok(Artifact::CacheSnapshot(snap)) => {
            eprintln!("warm cache: {} entries loaded from {path}", snap.len());
            Ok(Evaluator::with_cache(threads, Some(snap.into_cache())))
        }
        Ok(other) => {
            eprintln!("--warm-cache: {path} holds a '{}' artifact, expected 'costcache'", other.kind());
            Err(2)
        }
        Err(e) => {
            eprintln!("--warm-cache: {e}");
            Err(2)
        }
    }
}

/// Honour `--budget-ms <N>` / `--budget-candidates <N>`: build the
/// cooperative [`Budget`] the evaluator checks between candidate
/// batches. `Ok(None)` when neither flag is present (unbudgeted runs
/// stay on the exact same code path as before). `Err` carries the exit
/// code.
fn budget_flag(args: &[String]) -> Result<Option<std::sync::Arc<Budget>>, i32> {
    let ms = parse_flag::<u64>(args, "--budget-ms", "a non-negative integer (milliseconds)")?;
    let cand = parse_flag::<u64>(args, "--budget-candidates", "a non-negative integer")?;
    if ms.is_none() && cand.is_none() {
        return Ok(None);
    }
    Ok(Some(Budget::new(ms, cand)))
}

/// Honour `--save-cache <path>` after a successful optimizer run:
/// snapshot the evaluator's cost cache to disk. `Err` carries the exit
/// code.
fn save_cache_flag(args: &[String], eval: &Evaluator) -> Result<(), i32> {
    let Some(path) = flag(args, "--save-cache") else {
        return Ok(());
    };
    let Some(cache) = eval.cache() else {
        eprintln!("--save-cache: the run kept no cost cache (--no-cost-cache?)");
        return Err(2);
    };
    let snap = CacheSnapshot::from_cache(&cache);
    let n = snap.len();
    match systemds::api::save_artifact(Path::new(&path), &Artifact::CacheSnapshot(snap)) {
        Ok(()) => {
            eprintln!("saved cost-cache snapshot: {n} entries -> {path}");
            Ok(())
        }
        Err(e) => {
            eprintln!("--save-cache: {e}");
            Err(1)
        }
    }
}

/// Honour `--profile <path>`: load a [`CalibrationProfile`] and return
/// its calibrated constants (`None` when the flag is absent). `Err`
/// carries the exit code.
fn profile_constants_flag(args: &[String]) -> Result<Option<CostConstants>, i32> {
    let Some(path) = flag(args, "--profile") else {
        return Ok(None);
    };
    match systemds::api::load_artifact(Path::new(&path)) {
        Ok(Artifact::Profile(p)) => {
            eprintln!("{}", p.summary());
            Ok(Some(p.constants().clone()))
        }
        Ok(other) => {
            eprintln!("--profile: {path} holds a '{}' artifact, expected 'profile'", other.kind());
            Err(2)
        }
        Err(e) => {
            eprintln!("--profile: {e}");
            Err(2)
        }
    }
}

fn scenario_by_name(name: &str) -> Option<Scenario> {
    Scenario::all().into_iter().find(|s| s.name.eq_ignore_ascii_case(name))
}

/// Parse `--backend cp|mr|spark` (default MR). `Err` carries the exit code.
fn parse_backend_flag(args: &[String]) -> Result<ExecBackend, i32> {
    match flag(args, "--backend") {
        None => Ok(ExecBackend::Mr),
        Some(b) => ExecBackend::parse(&b).ok_or_else(|| {
            eprintln!("--backend: unknown backend '{b}' (expected cp, mr or spark)");
            2
        }),
    }
}

/// Parse `--fault-profile <spec>` (`none`, `chaos`, or a `key=value`
/// list — see [`FaultProfile::parse`]). Absent flag means the identity
/// profile, keeping every command bitwise-identical to its fault-unaware
/// behaviour. `Err` carries the exit code.
fn parse_fault_flag(args: &[String]) -> Result<FaultProfile, i32> {
    match flag(args, "--fault-profile") {
        None => Ok(FaultProfile::none()),
        Some(spec) => FaultProfile::parse(&spec).map_err(|e| {
            eprintln!("--fault-profile: {e}");
            2
        }),
    }
}

/// Parse `--iters N` (default 20, N >= 1). `Err` carries the exit code.
fn parse_iters_flag(args: &[String]) -> Result<usize, i32> {
    match flag(args, "--iters") {
        None => Ok(20),
        Some(i) => match i.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => {
                eprintln!("--iters: invalid value '{i}' (expected a positive integer)");
                Err(2)
            }
        },
    }
}

/// Parse the shared `--backend`, `--script` and `--iters` flags and
/// compile the requested scenario. Returns `Err(exit_code)` on bad flags.
fn compile_flagged(
    args: &[String],
) -> Result<(systemds::api::CompiledProgram, CompileOptions), i32> {
    let name = flag(args, "--scenario").unwrap_or_else(|| "xs".into());
    let Some(s) = scenario_by_name(&name) else {
        eprintln!("unknown scenario '{name}'");
        return Err(2);
    };
    let backend = parse_backend_flag(args)?;
    let script = flag(args, "--script").unwrap_or_else(|| "ds".into());
    let iters = parse_iters_flag(args)?;
    let opts = CompileOptions { backend, ..Default::default() };
    let compiled = match script.as_str() {
        "cg" => compile_with_meta(
            LINREG_CG,
            &linreg_cg_args(iters),
            &s.meta(opts.cfg.blocksize),
            &opts,
        ),
        "ds" => Ok(s.compile(&opts)),
        other => {
            eprintln!("--script: unknown script '{other}' (expected ds or cg)");
            return Err(2);
        }
    };
    match compiled {
        Ok(c) => Ok((c, opts)),
        Err(e) => {
            eprintln!("compile error: {e}");
            Err(1)
        }
    }
}

fn cmd_explain(args: &[String]) -> i32 {
    let level = flag(args, "--level").unwrap_or_else(|| "runtime".into());
    let (compiled, opts) = match compile_flagged(args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    match level.as_str() {
        "hops" => print!("{}", compiled.explain_hops(&opts)),
        _ => print!("{}", compiled.explain_runtime()),
    }
    0
}

fn cmd_cost(args: &[String]) -> i32 {
    let (compiled, opts) = match compile_flagged(args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let fault = match parse_fault_flag(args) {
        Ok(f) => f,
        Err(code) => return code,
    };
    let report = cost::cost_program_faults(
        &compiled.runtime,
        &opts.cfg,
        &opts.cc.0,
        &CostConstants::default(),
        &fault,
    );
    print!("{}", cost::explain_costed(&report));
    0
}

fn cmd_verify(args: &[String]) -> i32 {
    let (compiled, opts) = match compile_flagged(args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let fault = match parse_fault_flag(args) {
        Ok(f) => f,
        Err(code) => return code,
    };
    let report = verify_plan_faults(&compiled, &opts, &fault);
    print!("{}", report.render());
    println!("{}", report.summary());
    if report.errors() == 0 {
        0
    } else {
        1
    }
}

fn cmd_scenarios() -> i32 {
    println!("{:<6} {:>14} {:>10} {:>8} {:>12}", "name", "X", "size", "MR jobs", "est. cost");
    let opts = CompileOptions::default();
    for s in Scenario::all() {
        let compiled = s.compile(&opts);
        let report = cost::cost_program(
            &compiled.runtime,
            &opts.cfg,
            &opts.cc.0,
            &CostConstants::default(),
        );
        println!(
            "{:<6} {:>7}x{:<6} {:>10} {:>8} {:>11.1}s",
            s.name,
            s.x_rows,
            s.x_cols,
            systemds::util::fmt::fmt_bytes(s.input_bytes),
            compiled.runtime.mr_job_count(),
            report.total
        );
    }
    0
}

fn cmd_run(args: &[String]) -> i32 {
    let Some(script_path) = args.first().filter(|a| !a.starts_with('-')) else {
        eprintln!("usage: repro run <script.dml> [-a N=value ...]");
        return 2;
    };
    let src = match std::fs::read_to_string(script_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot read {script_path}: {e}");
            return 1;
        }
    };
    let mut script_args: HashMap<usize, String> = HashMap::new();
    let mut i = 1;
    while i < args.len() {
        if args[i] == "-a" {
            if let Some(kv) = args.get(i + 1) {
                if let Some((k, v)) = kv.split_once('=') {
                    if let Ok(n) = k.parse::<usize>() {
                        script_args.insert(n, v.to_string());
                    }
                }
            }
            i += 2;
        } else {
            i += 1;
        }
    }
    let threads: usize = match parse_flag(args, "--threads", "a positive integer") {
        Ok(Some(0)) => {
            eprintln!("--threads: invalid value '0' (expected a positive integer)");
            return 2;
        }
        Ok(Some(n)) => n,
        Ok(None) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        Err(code) => return code,
    };
    let heap_mb: f64 = match parse_flag(args, "--heap-mb", "a positive size in MB") {
        Ok(Some(h)) if h.is_finite() && h > 0.0 => h,
        Ok(Some(h)) => {
            eprintln!("--heap-mb: invalid value '{h}' (expected a positive size in MB)");
            return 2;
        }
        Ok(None) => 2048.0,
        Err(code) => return code,
    };
    let opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(ClusterConfig::local(threads, heap_mb * MB)),
        ..Default::default()
    };
    let compiled = match compile(&src, &script_args, &opts) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("compile error: {e}");
            return 1;
        }
    };
    let report =
        cost::cost_program(&compiled.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default());
    eprintln!("estimated cost: {:.3}s", report.total);
    let registry = systemds::runtime::load_registry_or_warn("run");
    let scratch = std::env::temp_dir().join(format!("sysds_run_{}", std::process::id()));
    let mut exec = Executor::new(&opts.cfg, &opts.cc.0, registry.as_ref(), scratch);
    match exec.run(&compiled.runtime) {
        Ok(stats) => {
            eprintln!(
                "executed: {} CP insts, {} MR jobs, {} PJRT calls, {:.3}s",
                stats.cp_insts, stats.mr_jobs, stats.pjrt_calls, stats.elapsed_secs
            );
            0
        }
        Err(e) => {
            eprintln!("execution error: {e:#}");
            1
        }
    }
}

/// Parse `--backends cp,mr,spark` into a backend list (None = flag
/// absent). `Err` carries the exit code.
fn parse_backends_flag(args: &[String]) -> Result<Option<Vec<ExecBackend>>, i32> {
    let Some(backends) = flag(args, "--backends") else {
        return Ok(None);
    };
    let mut parsed = Vec::new();
    for part in backends.split(',').filter(|s| !s.is_empty()) {
        match ExecBackend::parse(part) {
            Some(b) => parsed.push(b),
            None => {
                eprintln!(
                    "--backends: unknown backend '{part}' (expected a list of cp, mr, spark)"
                );
                return Err(2);
            }
        }
    }
    Ok(Some(parsed))
}

/// Parse the `--grid key=v1,v2:key=...` axis specification onto a
/// [`ResourceGrid`]. Axes: `heaps` (MB), `execmem` (MB), `nodes`,
/// `klocal`; unspecified axes keep their defaults. `default` keeps all.
fn parse_grid_axes(spec: &str, grid: &mut resource::ResourceGrid) -> Result<(), String> {
    if spec == "default" {
        return Ok(());
    }
    for part in spec.split(':').filter(|p| !p.is_empty()) {
        let Some((key, vals)) = part.split_once('=') else {
            return Err(format!("--grid: expected <axis>=<v1,v2,...> in '{part}'"));
        };
        let f64s = |name: &str| -> Result<Vec<f64>, String> {
            vals.split(',')
                .map(|v| match v.trim().parse::<f64>() {
                    Ok(x) if x.is_finite() && x > 0.0 => Ok(x),
                    _ => Err(format!("--grid: invalid {name} entry '{v}' (positive MB)")),
                })
                .collect()
        };
        let usizes = |name: &str| -> Result<Vec<usize>, String> {
            vals.split(',')
                .map(|v| match v.trim().parse::<usize>() {
                    Ok(x) if x >= 1 => Ok(x),
                    _ => Err(format!("--grid: invalid {name} entry '{v}' (integer >= 1)")),
                })
                .collect()
        };
        match key {
            "heaps" => grid.heaps_mb = f64s("heaps")?,
            "execmem" => grid.exec_mem_mb = f64s("execmem")?,
            "nodes" => grid.nodes = usizes("nodes")?,
            "klocal" => grid.k_local = usizes("klocal")?,
            other => {
                return Err(format!(
                    "--grid: unknown axis '{other}' (expected heaps, execmem, nodes, klocal)"
                ))
            }
        }
    }
    Ok(())
}

/// Grid resource optimizer: enumerate the joint heap × executor-memory ×
/// nodes × k_local × backend space for one scenario/script, prune
/// dominated points via the read floor, and print the (budget, time)
/// Pareto frontier plus the argmin configuration.
fn cmd_resource(args: &[String]) -> i32 {
    let name = flag(args, "--scenario").unwrap_or_else(|| "xl1".into());
    let Some(s) = scenario_by_name(&name) else {
        eprintln!("unknown scenario '{name}'");
        return 2;
    };
    let script = flag(args, "--script").unwrap_or_else(|| "cg".into());
    let iters = match parse_iters_flag(args) {
        Ok(n) => n,
        Err(code) => return code,
    };
    let (src, script_args) = match script.as_str() {
        "cg" => (LINREG_CG.to_string(), linreg_cg_args(iters)),
        "ds" => (s.script().to_string(), s.args()),
        other => {
            eprintln!("--script: unknown script '{other}' (expected ds or cg)");
            return 2;
        }
    };
    let mut grid = resource::ResourceGrid::new(src, script_args, DataScenario::from(&s));
    match parse_backends_flag(args) {
        Ok(Some(backends)) => grid.backends = backends,
        Ok(None) => {}
        Err(code) => return code,
    }
    if let Some(g) = flag(args, "--grid") {
        if let Err(e) = parse_grid_axes(&g, &mut grid) {
            eprintln!("{e}");
            return 2;
        }
    }
    match parse_flag::<usize>(args, "--threads", "a non-negative integer") {
        Ok(Some(n)) => grid.threads = n,
        Ok(None) => {}
        Err(code) => return code,
    }
    if args.iter().any(|a| a == "--no-prune") {
        grid.prune = false;
    }
    if args.iter().any(|a| a == "--no-cost-cache") {
        grid.cost_cache = false;
    }
    if args.iter().any(|a| a == "--verify") {
        grid.verify = true;
    }
    match parse_fault_flag(args) {
        Ok(f) => grid.fault = f,
        Err(code) => return code,
    }
    match profile_constants_flag(args) {
        Ok(Some(k)) => grid.constants = k,
        Ok(None) => {}
        Err(code) => return code,
    }
    let mut eval = match warm_evaluator(args, grid.threads, grid.cost_cache) {
        Ok(e) => e,
        Err(code) => return code,
    };
    match budget_flag(args) {
        Ok(b) => eval.set_budget(b),
        Err(code) => return code,
    }
    let report = match resource::optimize_grid_with(&grid, &mut eval) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("resource optimization failed: {e}");
            return 1;
        }
    };
    if let Err(code) = save_cache_flag(args, &eval) {
        return code;
    }
    println!(
        "scenario {} / script {} — {} grid points (heap x exec-mem x nodes x k_local x backend)",
        s.name,
        script,
        grid.point_count()
    );
    println!("\nPareto frontier (budget ascending, est. time descending):");
    print!("{}", report.frontier_table());
    if args.iter().any(|a| a == "--all") {
        println!("\nall costed points:");
        let mut idx: Vec<usize> = (0..report.points.len()).collect();
        idx.sort_by(|&a, &b| {
            report.points[a].budget_mb.total_cmp(&report.points[b].budget_mb).then(a.cmp(&b))
        });
        for i in idx {
            let p = &report.points[i];
            match p.cost_secs {
                Some(c) => println!(
                    "  {:>8}MB  {}  {:>12}{}",
                    p.budget_mb as i64,
                    p.label(),
                    systemds::util::fmt::fmt_secs(c),
                    if p.plan_reused { "  (memo)" } else { "" }
                ),
                None => println!(
                    "  {:>8}MB  {}  pruned (floor {})",
                    p.budget_mb as i64,
                    p.label(),
                    systemds::util::fmt::fmt_secs(p.floor_secs)
                ),
            }
        }
    }
    let best = report.best();
    println!(
        "\nbest: {} — {} at budget {}MB",
        best.label(),
        systemds::util::fmt::fmt_secs(best.cost_secs.unwrap_or(f64::NAN)),
        best.budget_mb as i64
    );
    if let Some(v) = &report.verify {
        print!("{}", v.render());
        eprintln!("{}", v.summary());
    }
    eprintln!("{}", report.summary());
    0
}

fn cmd_resource_opt(args: &[String]) -> i32 {
    let name = flag(args, "--scenario").unwrap_or_else(|| "xs".into());
    let heaps: Vec<f64> = match parse_mb_list_flag(args, "--heaps") {
        Ok(Some(h)) => h,
        Ok(None) => vec![256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0],
        Err(code) => return code,
    };
    let Some(s) = scenario_by_name(&name) else {
        eprintln!("unknown scenario '{name}'");
        return 2;
    };
    let backend = match parse_backend_flag(args) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let choice = match resource::optimize_backend(
        s.script(),
        &s.args(),
        &s.meta(1000),
        &ClusterConfig::paper_cluster(),
        &heaps,
        backend,
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("{:>10} {:>8} {:>12}", "heap", "jobs", "est. cost");
    for p in &choice.points {
        println!(
            "{:>8}MB {:>8} {:>11.1}s",
            (p.heap_bytes / MB) as i64,
            p.mr_jobs + p.spark_jobs,
            p.cost_secs
        );
    }
    println!(
        "best: {}MB ({:.1}s)",
        (choice.best.heap_bytes / MB) as i64,
        choice.best.cost_secs
    );
    0
}

/// Global data flow optimizer: enumerate interesting per-cut data-flow
/// properties (block size, format, broadcast partitioning, per-group
/// backend) for one scenario/script, and print the decision trace, the
/// EXPLAIN-style before/after plan diff and the argmin configuration.
fn cmd_gdf(args: &[String]) -> i32 {
    let name = flag(args, "--scenario").unwrap_or_else(|| "xl1".into());
    let Some(s) = scenario_by_name(&name) else {
        eprintln!("unknown scenario '{name}'");
        return 2;
    };
    let script = flag(args, "--script").unwrap_or_else(|| "cg".into());
    let iters = match parse_iters_flag(args) {
        Ok(n) => n,
        Err(code) => return code,
    };
    let (src, script_args) = match script.as_str() {
        "cg" => (LINREG_CG.to_string(), linreg_cg_args(iters)),
        "ds" => (s.script().to_string(), s.args()),
        other => {
            eprintln!("--script: unknown script '{other}' (expected ds or cg)");
            return 2;
        }
    };
    let mut spec = gdf::GdfSpec::new(src, script_args, DataScenario::from(&s));
    match parse_backends_flag(args) {
        Ok(Some(backends)) => spec.backends = backends,
        Ok(None) => {}
        Err(code) => return code,
    }
    if let Some(bs) = flag(args, "--blocksizes") {
        let mut out = Vec::new();
        for part in bs.split(',').filter(|p| !p.is_empty()) {
            match part.trim().parse::<i64>() {
                Ok(b) if b >= 1 => out.push(b),
                _ => {
                    eprintln!("--blocksizes: invalid entry '{part}' (expected integers >= 1)");
                    return 2;
                }
            }
        }
        spec.blocksizes = out;
    }
    if let Some(fmts) = flag(args, "--formats") {
        let mut out = Vec::new();
        for part in fmts.split(',').filter(|p| !p.is_empty()) {
            match Format::parse(part.trim()) {
                Some(f) => out.push(f),
                None => {
                    eprintln!(
                        "--formats: unknown format '{part}' (expected binaryblock, textcell or csv)"
                    );
                    return 2;
                }
            }
        }
        spec.formats = out;
    }
    if let Some(parts) = flag(args, "--partitions") {
        let mut out = Vec::new();
        for part in parts.split(',').filter(|p| !p.is_empty()) {
            match part.trim().parse::<f64>() {
                Ok(p) if p.is_finite() && p > 0.0 => out.push(p),
                _ => {
                    eprintln!("--partitions: invalid entry '{part}' (expected positive MB)");
                    return 2;
                }
            }
        }
        spec.partitions_mb = out;
    }
    match parse_flag::<usize>(args, "--threads", "a non-negative integer") {
        Ok(Some(n)) => spec.threads = n,
        Ok(None) => {}
        Err(code) => return code,
    }
    if args.iter().any(|a| a == "--no-cost-cache") {
        spec.cost_cache = false;
    }
    if args.iter().any(|a| a == "--verify") {
        spec.verify = true;
    }
    match parse_fault_flag(args) {
        Ok(f) => spec.fault = f,
        Err(code) => return code,
    }
    match profile_constants_flag(args) {
        Ok(Some(k)) => spec.constants = k,
        Ok(None) => {}
        Err(code) => return code,
    }
    let mut eval = match warm_evaluator(args, spec.threads, spec.cost_cache) {
        Ok(e) => e,
        Err(code) => return code,
    };
    match budget_flag(args) {
        Ok(b) => eval.set_budget(b),
        Err(code) => return code,
    }
    let report = match gdf::optimize_with(&spec, &mut eval) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("global data flow optimization failed: {e}");
            return 1;
        }
    };
    if let Err(code) = save_cache_flag(args, &eval) {
        return code;
    }
    println!(
        "scenario {} / script {} — {} candidate data-flow configurations",
        s.name,
        script,
        report.candidates.len()
    );
    println!("\ndecision trace (per DAG cut, optimized plan):");
    print!("{}", report.decision_table());
    if args.iter().any(|a| a == "--all") {
        println!("\nall candidates (cheapest first):");
        for c in report.ranked() {
            println!("  {:>12}  {}", systemds::util::fmt::fmt_secs(c.cost_secs), c.label());
        }
    }
    if !args.iter().any(|a| a == "--no-diff") {
        println!("\nplan diff (default -> optimized):");
        print!("{}", report.explain_diff());
    }
    let (best, base) = (report.best(), report.baseline());
    println!(
        "\ndefault: {} — {}",
        systemds::util::fmt::fmt_secs(base.cost_secs),
        base.label()
    );
    println!(
        "best:    {} — {} ({:.1}% better)",
        systemds::util::fmt::fmt_secs(best.cost_secs),
        best.label(),
        report.improvement_pct()
    );
    eprintln!("{}", report.summary());
    0
}

/// Parallel scenario-sweep: cost a ClusterConfig × data-size × backend
/// grid for the LinReg DS (or CG, `--script cg`) script and print the
/// ranked plan-comparison table.
fn cmd_sweep(args: &[String]) -> i32 {
    let script = flag(args, "--script").unwrap_or_else(|| "ds".into());
    let iters = match parse_iters_flag(args) {
        Ok(n) => n,
        Err(code) => return code,
    };
    let mut spec = match script.as_str() {
        "ds" => SweepSpec::linreg_default(),
        "cg" => SweepSpec::linreg_cg(iters),
        other => {
            eprintln!("--script: unknown script '{other}' (expected ds or cg)");
            return 2;
        }
    };
    match parse_backends_flag(args) {
        Ok(Some(backends)) => spec.backends = backends,
        Ok(None) => {}
        Err(code) => return code,
    }
    if let Some(names) = flag(args, "--scenarios") {
        let mut scenarios = Vec::new();
        for name in names.split(',').filter(|s| !s.is_empty()) {
            let Some(s) = scenario_by_name(name) else {
                eprintln!("unknown scenario '{name}' (expected xs, xl1..xl4)");
                return 2;
            };
            scenarios.push(DataScenario::from(&s));
        }
        spec.scenarios = scenarios;
    }
    match parse_mb_list_flag(args, "--heaps") {
        Ok(Some(heaps_mb)) => spec.clusters = heap_clock_clusters(&heaps_mb),
        Ok(None) => {}
        Err(code) => return code,
    }
    match parse_flag::<usize>(args, "--threads", "a non-negative integer") {
        Ok(Some(n)) => spec.threads = n,
        Ok(None) => {}
        Err(code) => return code,
    }
    if args.iter().any(|a| a == "--no-cost-cache") {
        spec.cost_cache = false;
    }
    if args.iter().any(|a| a == "--verify") {
        spec.verify = true;
    }
    match parse_fault_flag(args) {
        Ok(f) => spec.fault = f,
        Err(code) => return code,
    }
    match profile_constants_flag(args) {
        Ok(Some(k)) => spec.constants = k,
        Ok(None) => {}
        Err(code) => return code,
    }
    let serial = args.iter().any(|a| a == "--serial");
    if serial && (flag(args, "--warm-cache").is_some() || flag(args, "--save-cache").is_some()) {
        eprintln!("--serial: incompatible with --warm-cache/--save-cache (the serial reference path keeps no evaluator)");
        return 2;
    }
    if serial && spec.verify {
        eprintln!("--serial: incompatible with --verify (the serial reference path keeps no winning plan to audit)");
        return 2;
    }
    let result = if serial {
        sweep::sweep_serial(&spec)
    } else {
        let mut eval = match warm_evaluator(args, spec.threads, spec.cost_cache) {
            Ok(e) => e,
            Err(code) => return code,
        };
        let r = sweep::sweep_with(&spec, &mut eval);
        if r.is_ok() {
            if let Err(code) = save_cache_flag(args, &eval) {
                return code;
            }
        }
        r
    };
    match result {
        Ok(report) => {
            print!("{}", report.table());
            if let Some(v) = &report.verify {
                print!("{}", v.render());
                eprintln!("{}", v.summary());
            }
            eprintln!("{}", report.summary());
            0
        }
        Err(e) => {
            eprintln!("sweep failed: {e}");
            1
        }
    }
}

/// Measured-execution feedback: run the bundled calibration workloads,
/// fit cost-constant corrections, and report before/after Q-error plus
/// the re-optimization outcome. `--simulated` replaces wall-clock
/// measurement with the deterministic simulator-truth proxy (what the CI
/// gate runs); `--quick` uses the small shapes.
fn cmd_calibrate(args: &[String]) -> i32 {
    let mut opts = systemds::api::CalibrateOptions {
        quick: args.iter().any(|a| a == "--quick"),
        ..Default::default()
    };
    match parse_flag::<u64>(args, "--seed", "an unsigned integer") {
        Ok(Some(n)) => opts.seed = n,
        Ok(None) => {}
        Err(code) => return code,
    }
    match parse_flag::<usize>(args, "--threads", "a non-negative integer") {
        Ok(Some(n)) => opts.threads = n,
        Ok(None) => {}
        Err(code) => return code,
    }
    if args.iter().any(|a| a == "--simulated") {
        let noise = match parse_flag::<f64>(args, "--noise", "a non-negative number") {
            Ok(Some(v)) if v.is_finite() && v >= 0.0 => v,
            Ok(Some(v)) => {
                eprintln!("--noise: invalid value '{v}' (expected a non-negative number)");
                return 2;
            }
            Ok(None) => 0.0,
            Err(code) => return code,
        };
        opts.mode = systemds::api::MeasureMode::Simulated { noise };
    }
    if let Some(dir) = flag(args, "--scratch") {
        opts.scratch = Some(std::path::PathBuf::from(dir));
    }
    match parse_fault_flag(args) {
        Ok(f) => opts.fault = f,
        Err(code) => return code,
    }
    // `--profile` continues calibration from an earlier run's calibrated
    // constants instead of the Hadoop-derived defaults.
    match profile_constants_flag(args) {
        Ok(Some(k)) => opts.constants = k,
        Ok(None) => {}
        Err(code) => return code,
    }
    let report = match systemds::api::calibrate(&opts) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("calibration failed: {e}");
            return 1;
        }
    };
    if let Some(path) = flag(args, "--save-profile") {
        let profile = CalibrationProfile::from_report(&report, &opts);
        match systemds::api::save_artifact(Path::new(&path), &Artifact::Profile(profile)) {
            Ok(()) => eprintln!("saved calibration profile -> {path}"),
            Err(e) => {
                eprintln!("--save-profile: {e}");
                return 1;
            }
        }
    }
    println!(
        "calibration: {} cases, {} block records ({})",
        report.cases,
        report.records.len(),
        if report.executed { "measured execution" } else { "simulated proxy" }
    );
    println!(
        "\n{:<12} {:>4} {:>12} {:>12} {:>10} {:>10} {:>9} {:>9}",
        "class", "n", "geo-q before", "geo-q after", "p95 before", "p95 after", "<=2x bef", "<=2x aft"
    );
    for c in &report.per_class {
        println!(
            "{:<12} {:>4} {:>12.3} {:>12.3} {:>10.2} {:>10.2} {:>8.0}% {:>8.0}%",
            c.class.name(),
            c.before.n,
            c.before.geo_mean,
            c.after.geo_mean,
            c.before.p95,
            c.after.p95,
            100.0 * c.before.within_2x,
            100.0 * c.after.within_2x
        );
    }
    println!(
        "{:<12} {:>4} {:>12.3} {:>12.3} {:>10.2} {:>10.2} {:>8.0}% {:>8.0}%",
        "all",
        report.before.n,
        report.before.geo_mean,
        report.after.geo_mean,
        report.before.p95,
        report.after.p95,
        100.0 * report.before.within_2x,
        100.0 * report.after.within_2x
    );
    let c = &report.corrections;
    println!(
        "\ncorrections: compute x{:.4}  read x{:.4}  write x{:.4}  latency x{:.6}  distributed x{:.4}",
        c.compute, c.read, c.write, c.latency, c.distributed
    );
    println!(
        "constants:   job_latency {:.3}s -> {:.5}s  hdfs_read {:.0} -> {:.0} MB/s  flop_eff {:.2} -> {:.2}",
        report.initial.job_latency,
        report.calibrated.job_latency,
        report.initial.hdfs_read_binaryblock / MB,
        report.calibrated.hdfs_read_binaryblock / MB,
        report.initial.flop_efficiency,
        report.calibrated.flop_efficiency
    );
    println!("\nre-optimization: {}", report.reopt.scenario);
    for choice in &report.reopt.choices {
        println!(
            "  {:<6} {:>12} -> {:>12}",
            choice.backend.name(),
            systemds::util::fmt::fmt_secs(choice.before_secs),
            systemds::util::fmt::fmt_secs(choice.after_secs)
        );
    }
    println!(
        "argmin: {} -> {}{}",
        report.reopt.argmin_before.name(),
        report.reopt.argmin_after.name(),
        if report.reopt.flipped() { "  (flipped)" } else { "" }
    );
    0
}

/// Persistent plan artifacts: `plan save <path>` compiles a scenario and
/// writes the stable+synthesized artifact, `plan load <path>` verifies
/// it against a fresh compile of the stable section (regenerating a
/// stale synthesized section), and `plan diff <path>` prints the EXPLAIN
/// diff between the stored plan and what the stable section compiles to
/// today.
fn cmd_plan(args: &[String]) -> i32 {
    const USAGE: &str = "usage: repro plan <save|load|diff> <path> \
                         [--scenario <xs|xl1..xl4>] [--script cg|ds] [--iters N] \
                         [--backend cp|mr|spark] [--profile F]";
    let (Some(action), Some(path_raw)) = (args.first(), args.get(1)) else {
        eprintln!("{USAGE}");
        return 2;
    };
    if path_raw.starts_with('-') {
        eprintln!("{USAGE}");
        return 2;
    }
    let path = Path::new(path_raw.as_str());
    match action.as_str() {
        "save" => cmd_plan_save(&args[2..], path),
        "load" => {
            let loaded = match load_plan_checked(path) {
                Ok(l) => l,
                Err(code) => return code,
            };
            println!("{}", loaded.artifact.describe());
            match &loaded.reason {
                Some(reason) => println!("synthesized section regenerated: {reason}"),
                None => println!(
                    "synthesized section verified (payload v{PLAN_FORMAT_VERSION}, structural hash match)"
                ),
            }
            0
        }
        "diff" => {
            let loaded = match load_plan_checked(path) {
                Ok(l) => l,
                Err(code) => return code,
            };
            if let Some(reason) = &loaded.reason {
                println!("stale synthesized section ({reason}); diffing against the regenerated plan:");
            }
            if loaded.plan_unchanged() {
                println!(
                    "plans identical: stored EXPLAIN matches the fresh compile ({} lines)",
                    loaded.artifact.explain.lines().count()
                );
            } else {
                print!("{}", loaded.explain_diff());
            }
            0
        }
        other => {
            eprintln!("plan: unknown action '{other}'\n{USAGE}");
            2
        }
    }
}

fn cmd_plan_save(args: &[String], path: &Path) -> i32 {
    let name = flag(args, "--scenario").unwrap_or_else(|| "xl1".into());
    let Some(s) = scenario_by_name(&name) else {
        eprintln!("unknown scenario '{name}'");
        return 2;
    };
    let script = flag(args, "--script").unwrap_or_else(|| "cg".into());
    let iters = match parse_iters_flag(args) {
        Ok(n) => n,
        Err(code) => return code,
    };
    let backend = match parse_backend_flag(args) {
        Ok(b) => b,
        Err(code) => return code,
    };
    let (src, script_args) = match script.as_str() {
        "cg" => (LINREG_CG.to_string(), linreg_cg_args(iters)),
        "ds" => (s.script().to_string(), s.args()),
        other => {
            eprintln!("--script: unknown script '{other}' (expected ds or cg)");
            return 2;
        }
    };
    let constants = match profile_constants_flag(args) {
        Ok(Some(k)) => k,
        Ok(None) => CostConstants::default(),
        Err(code) => return code,
    };
    let opts = CompileOptions { backend, ..Default::default() };
    let art = match PlanArtifact::capture(
        &src,
        &script_args,
        &s.meta(opts.cfg.blocksize),
        &opts,
        &constants,
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("plan save: {e}");
            return 1;
        }
    };
    println!("{}", art.describe());
    match systemds::api::save_artifact(path, &Artifact::Plan(art)) {
        Ok(()) => {
            println!("saved plan -> {}", path.display());
            0
        }
        Err(e) => {
            eprintln!("plan save: {e}");
            1
        }
    }
}

/// Load a plan artifact and validate it against a fresh compile of its
/// stable section. `Err` carries the exit code.
fn load_plan_checked(path: &Path) -> Result<systemds::api::LoadedPlan, i32> {
    let art = match systemds::api::load_artifact(path) {
        Ok(Artifact::Plan(p)) => p,
        Ok(other) => {
            eprintln!(
                "plan: {} holds a '{}' artifact, expected 'plan'",
                path.display(),
                other.kind()
            );
            return Err(2);
        }
        Err(e) => {
            eprintln!("plan: {e}");
            return Err(2);
        }
    };
    art.load_checked().map_err(|e| {
        eprintln!("plan: recompiling the stable section failed: {e}");
        1
    })
}

/// Optimizer-as-a-service: run the long-lived `repro serve` daemon.
/// Without `--listen` it speaks the line protocol on stdin/stdout (one
/// response line per request line, EOF ends the session); with
/// `--listen ADDR:PORT` it accepts concurrent TCP connections, all
/// sharing one plan memo and cost cache.
fn cmd_serve(args: &[String]) -> i32 {
    let mut opts = ServeOptions::default();
    match parse_flag::<usize>(args, "--threads", "a non-negative integer") {
        Ok(Some(n)) => opts.threads = n,
        Ok(None) => {}
        Err(code) => return code,
    }
    if args.iter().any(|a| a == "--no-cost-cache") {
        opts.no_cost_cache = true;
    }
    opts.warm_cache = flag(args, "--warm-cache").map(std::path::PathBuf::from);
    opts.profile = flag(args, "--profile").map(std::path::PathBuf::from);
    opts.spill_argmin = flag(args, "--spill-argmin").map(std::path::PathBuf::from);
    match parse_flag::<u64>(args, "--idle-timeout", "a non-negative integer (milliseconds)") {
        Ok(Some(ms)) => opts.idle_timeout_ms = ms,
        Ok(None) => {}
        Err(code) => return code,
    }
    match parse_fault_flag(args) {
        Ok(f) => opts.fault = f,
        Err(code) => return code,
    }
    let state = match ServeState::new(&opts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: {e}");
            return 2;
        }
    };
    // Banner goes to stderr: stdout carries only protocol responses.
    eprintln!("{}", state.boot_summary());
    match flag(args, "--listen") {
        Some(addr) => {
            let listener = match std::net::TcpListener::bind(&addr) {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("serve: bind {addr}: {e}");
                    return 1;
                }
            };
            match listener.local_addr() {
                Ok(a) => eprintln!("serve: listening on {a}"),
                Err(_) => eprintln!("serve: listening on {addr}"),
            }
            match serve_tcp(std::sync::Arc::new(state), listener) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("serve: {e}");
                    1
                }
            }
        }
        None => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            match serve_lines(&state, stdin.lock(), stdout.lock()) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("serve: {e}");
                    1
                }
            }
        }
    }
}

/// Failure-aware argmin-flip smoke (`repro chaos`): cost the bundled
/// MR-forced calibration scenario once per backend under the in-process
/// simulator-truth constants — fault-free and with the fault profile
/// priced in — then confirm the flipped choice by actually executing
/// both winners under deterministic seeded fault injection.
///
/// Fault-free, a distributed plan wins (8 slots, millisecond job
/// latency); under the chaos profile its retry expectation, backoff
/// latency and straggler tail price it above the CP plan, so the argmin
/// flips to `cp` — and the injected execution must show the same
/// ordering in measured seconds. Exit 0 only when the flip is confirmed
/// end to end.
fn cmd_chaos(args: &[String]) -> i32 {
    let fault = match flag(args, "--fault-profile") {
        None => FaultProfile::chaos(),
        Some(spec) => match FaultProfile::parse(&spec) {
            Ok(f) => f,
            Err(e) => {
                eprintln!("--fault-profile: {e}");
                return 2;
            }
        },
    };
    if fault.is_none() {
        eprintln!(
            "chaos: profile 'none' prices no failures — nothing to flip \
             (the default is the bundled chaos profile)"
        );
        return 2;
    }
    let base_seed = match parse_flag::<u64>(args, "--seed", "an unsigned integer") {
        Ok(Some(n)) => n,
        Ok(None) => 42,
        Err(code) => return code,
    };
    let case = systemds::feedback::REOPT_CASE;
    let cc = systemds::feedback::runner::cluster_for(8, &case);
    let k = systemds::feedback::simulator_truth();

    // Synthesize the scenario's data once; every backend reads it.
    let scratch = std::env::temp_dir().join(format!("sysds_chaos_{}", std::process::id()));
    if let Err(e) = std::fs::create_dir_all(&scratch) {
        eprintln!("chaos: cannot create scratch {}: {e}", scratch.display());
        return 1;
    }
    let x = DenseMatrix::rand(case.rows, case.cols, -1.0, 1.0, 1.0, 42);
    let beta = DenseMatrix::rand(case.cols, 1, -0.5, 0.5, 1.0, 43);
    let y = ops::matmult(&x, &beta, 8);
    let xp = scratch.join("X").to_string_lossy().to_string();
    let yp = scratch.join("y").to_string_lossy().to_string();
    for (path, m) in [(&xp, &x), (&yp, &y)] {
        if let Err(e) = io::write_binary_block(path, m, 1000) {
            eprintln!("chaos: cannot write scenario data: {e}");
            return 1;
        }
    }
    let mut script_args: HashMap<usize, String> = HashMap::new();
    script_args.insert(1, xp);
    script_args.insert(2, yp);
    script_args.insert(3, case.iters.to_string());
    script_args.insert(4, scratch.join("out").to_string_lossy().to_string());

    println!(
        "chaos scenario: {} (heap {} MB, 8 slots), in-process simulator-truth constants",
        case.name, case.heap_mb
    );
    println!("fault profile: {fault:?}");

    struct Cand {
        backend: ExecBackend,
        rt: systemds::rtprog::RtProgram,
        cfg: systemds::conf::SystemConfig,
        plain: f64,
        faulty: f64,
    }
    let mut cands: Vec<Cand> = Vec::new();
    for backend in ExecBackend::all() {
        let opts = CompileOptions {
            cc: systemds::api::ClusterConfigOpt(cc.clone()),
            backend,
            ..Default::default()
        };
        let compiled = match compile(case.script, &script_args, &opts) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("chaos: compile for {}: {e}", backend.name());
                return 1;
            }
        };
        let plain = cost::cost_total(&compiled.runtime, &opts.cfg, &cc, &k);
        let faulty = cost::cost_total_faults(&compiled.runtime, &opts.cfg, &cc, &k, &fault);
        cands.push(Cand { backend, rt: compiled.runtime, cfg: opts.cfg, plain, faulty });
    }
    println!("\n{:<6} {:>14} {:>14}", "plan", "fault-free", "fault-aware");
    for c in &cands {
        println!(
            "{:<6} {:>14} {:>14}",
            c.backend.name(),
            systemds::util::fmt::fmt_secs(c.plain),
            systemds::util::fmt::fmt_secs(c.faulty)
        );
    }
    let argmin = |f: &dyn Fn(&Cand) -> f64| -> usize {
        (0..cands.len()).min_by(|&a, &b| f(&cands[a]).total_cmp(&f(&cands[b]))).unwrap()
    };
    let i_plain = argmin(&|c| c.plain);
    let i_fault = argmin(&|c| c.faulty);
    let flipped = cands[i_plain].backend != cands[i_fault].backend;
    println!(
        "argmin: {} -> {}{}",
        cands[i_plain].backend.name(),
        cands[i_fault].backend.name(),
        if flipped { "  (flipped)" } else { "" }
    );
    if !flipped || cands[i_fault].backend != ExecBackend::Cp {
        eprintln!("chaos: FAIL — pricing the failures did not flip the argmin to cp");
        return 1;
    }

    // Execute both winners under injected faults. Seeds are scanned
    // deterministically from --seed until the distributed schedule fires
    // at least one retry (each retry accounts >= backoff_base seconds of
    // ledger delay, so the measured comparison has a real margin).
    let registry = systemds::runtime::load_registry_or_warn("chaos");
    let mut run_no = 0usize;
    let mut run_under = |rt: &systemds::rtprog::RtProgram,
                         cfg: &systemds::conf::SystemConfig,
                         seed: u64|
     -> Result<ExecStats, i32> {
        run_no += 1;
        let mut exec =
            Executor::new(cfg, &cc, registry.as_ref(), scratch.join(format!("run{run_no}")));
        exec.set_fault_injection(fault.clone(), seed);
        exec.run(rt).map_err(|e| {
            eprintln!("chaos: execution error: {e:#}");
            1
        })
    };
    let (dist, cp) = (&cands[i_plain], &cands[i_fault]);
    let mut chosen = None;
    for s in base_seed..base_seed + 16 {
        let stats = match run_under(&dist.rt, &dist.cfg, s) {
            Ok(st) => st,
            Err(code) => return code,
        };
        if stats.failed_attempts > 0 {
            chosen = Some((s, stats));
            break;
        }
    }
    let Some((seed, d1)) = chosen else {
        eprintln!(
            "chaos: FAIL — no retry fired on the {} plan in seeds {base_seed}..{}",
            dist.backend.name(),
            base_seed + 16
        );
        return 1;
    };
    // Bitwise replay: the same seed must reproduce the same schedule.
    let d2 = match run_under(&dist.rt, &dist.cfg, seed) {
        Ok(s) => s,
        Err(code) => return code,
    };
    if d1.failed_attempts != d2.failed_attempts
        || d1.straggler_tasks != d2.straggler_tasks
        || d1.speculative_copies != d2.speculative_copies
        || d1.fault_delay_secs.to_bits() != d2.fault_delay_secs.to_bits()
    {
        eprintln!("chaos: FAIL — the fault schedule did not replay bitwise across reruns");
        return 1;
    }
    let c1 = match run_under(&cp.rt, &cp.cfg, seed) {
        Ok(s) => s,
        Err(code) => return code,
    };
    println!("\nexecuted under injected faults (seed {seed}):");
    let show = |name: &str, s: &ExecStats| {
        println!(
            "  {:<6} elapsed {:>10}  ({} failed attempts, {} stragglers, {} speculative, {:.3}s backoff)",
            name,
            systemds::util::fmt::fmt_secs(s.elapsed_secs),
            s.failed_attempts,
            s.straggler_tasks,
            s.speculative_copies,
            s.fault_delay_secs
        );
    };
    show(dist.backend.name(), &d1);
    show(cp.backend.name(), &c1);
    if c1.elapsed_secs >= d1.elapsed_secs {
        eprintln!(
            "chaos: FAIL — the fault-aware winner (cp) did not run faster under injected faults"
        );
        return 1;
    }
    println!("\nchaos: OK — pricing failures flips the argmin to cp, and injected execution agrees");
    let _ = std::fs::remove_dir_all(&scratch);
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flag_absent_is_none() {
        let args = argv(&["--other", "3"]);
        assert_eq!(parse_flag_value::<usize>(&args, "--threads", "int").unwrap(), None);
    }

    #[test]
    fn parse_flag_valid_value_parses() {
        let args = argv(&["--threads", "8"]);
        assert_eq!(parse_flag_value::<usize>(&args, "--threads", "int").unwrap(), Some(8));
    }

    #[test]
    fn parse_flag_garbage_names_flag_and_value() {
        // the regression: `--heap-mb 2O48` (letter O) used to be swallowed
        // by `.parse().ok().unwrap_or(2048.0)` and silently run with the
        // default heap
        let args = argv(&["--heap-mb", "2O48"]);
        let err =
            parse_flag_value::<f64>(&args, "--heap-mb", "a positive size in MB").unwrap_err();
        assert!(err.contains("--heap-mb"), "{err}");
        assert!(err.contains("2O48"), "{err}");
    }

    #[test]
    fn parse_flag_missing_trailing_value_is_none() {
        // a trailing flag with no value behaves like an absent flag (the
        // `flag` helper's contract)
        let args = argv(&["--threads"]);
        assert_eq!(parse_flag_value::<usize>(&args, "--threads", "int").unwrap(), None);
    }

    #[test]
    fn mb_list_rejects_garbage_entries() {
        let bad = argv(&["--heaps", "512,1O24"]);
        assert!(parse_mb_list_flag(&bad, "--heaps").is_err());
        let good = argv(&["--heaps", "512,1024"]);
        assert_eq!(
            parse_mb_list_flag(&good, "--heaps").unwrap(),
            Some(vec![512.0, 1024.0])
        );
    }
}
