//! Runtime-plan EXPLAIN (paper Figures 2 and 3), optionally with cost
//! annotations (Figures 4 and 5 — the annotations themselves are produced
//! by [`crate::cost`]).

use super::*;
use crate::util::fmt::fmt_dim;

/// Options for runtime-plan rendering.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExplainOpts {
    /// Show rmvar instructions (the paper's figures hide them).
    pub show_rmvar: bool,
}

/// Render the whole runtime program (Figure 2/3 style). Programs compiled
/// for the Spark backend extend the size header with a `/SPARK` column.
pub fn explain_runtime(prog: &RtProgram, opts: ExplainOpts) -> String {
    let (cp, mr, sp) = prog.size3();
    let mut out = if sp > 0 {
        format!("PROGRAM ( size CP/MR/SPARK = {cp}/{mr}/{sp} )\n--MAIN PROGRAM\n")
    } else {
        format!("PROGRAM ( size CP/MR = {cp}/{mr} )\n--MAIN PROGRAM\n")
    };
    explain_blocks(&prog.blocks, &mut out, 4, opts);
    for (name, f) in &prog.funcs {
        out.push_str(&format!("--FUNCTION {name}\n"));
        explain_blocks(&f.blocks, &mut out, 4, opts);
    }
    out
}

fn dashes(n: usize) -> String {
    "-".repeat(n)
}

fn explain_blocks(blocks: &[RtBlock], out: &mut String, indent: usize, opts: ExplainOpts) {
    for b in blocks {
        match b {
            RtBlock::Generic { insts, lines, recompile } => {
                out.push_str(&format!(
                    "{}GENERIC (lines {}-{}) [recompile={}]\n",
                    dashes(indent),
                    lines.0,
                    lines.1,
                    recompile
                ));
                for inst in insts {
                    explain_inst(inst, out, indent + 2, opts);
                }
            }
            RtBlock::If { pred, then_blocks, else_blocks, lines } => {
                out.push_str(&format!("{}IF (lines {}-{})\n", dashes(indent), lines.0, lines.1));
                for inst in &pred.insts {
                    explain_inst(inst, out, indent + 2, opts);
                }
                explain_blocks(then_blocks, out, indent + 2, opts);
                if !else_blocks.is_empty() {
                    out.push_str(&format!("{}ELSE\n", dashes(indent)));
                    explain_blocks(else_blocks, out, indent + 2, opts);
                }
            }
            RtBlock::For { var, body, parfor, known_trip, lines, .. } => {
                let kind = if *parfor { "PARFOR" } else { "FOR" };
                let trip = known_trip.map_or("?".into(), |t| format!("{t}"));
                out.push_str(&format!(
                    "{}{kind} (lines {}-{}) [{var}, iterations={trip}]\n",
                    dashes(indent),
                    lines.0,
                    lines.1
                ));
                explain_blocks(body, out, indent + 2, opts);
            }
            RtBlock::While { body, lines, .. } => {
                out.push_str(&format!("{}WHILE (lines {}-{})\n", dashes(indent), lines.0, lines.1));
                explain_blocks(body, out, indent + 2, opts);
            }
            RtBlock::FCall { fname, args, outputs, lines } => {
                out.push_str(&format!(
                    "{}CP fcall {fname} [{}] [{}] (lines {}-{})\n",
                    dashes(indent),
                    args.join(","),
                    outputs.join(","),
                    lines.0,
                    lines.1
                ));
            }
        }
    }
}

/// Render one instruction (SystemML instruction-string style).
pub fn render_inst(inst: &Instr) -> String {
    match inst {
        Instr::CreateVar { var, path, temp, format, mc } => format!(
            "CP createvar {var} {path} {temp} {} {} {} {} {} {}",
            format.name(),
            fmt_dim(mc.rows),
            fmt_dim(mc.cols),
            fmt_dim(mc.brows),
            fmt_dim(mc.bcols),
            fmt_dim(mc.nnz)
        ),
        Instr::AssignVar { lit, var } => format!(
            "CP assignvar {}.SCALAR.{}.true {var}.SCALAR.{}",
            lit.render(),
            vt_str(lit),
            vt_str(lit)
        ),
        Instr::CpVar { src, dst } => format!("CP cpvar {src} {dst}"),
        Instr::RmVar { vars } => format!("CP rmvar {}", vars.join(" ")),
        Instr::Cp(c) => {
            let mut s = format!("CP {}", c.op.code());
            for i in &c.inputs {
                s.push(' ');
                s.push_str(&i.render());
            }
            s.push(' ');
            s.push_str(&c.output.render());
            match &c.op {
                CpOp::Tsmm { left } => {
                    s.push_str(if *left { " LEFT" } else { " RIGHT" });
                }
                CpOp::Rand { min, max, sparsity, seed } => {
                    s.push_str(&format!(" {min} {max} {sparsity} {seed} uniform"));
                }
                CpOp::Partition => s.push_str(" ROW_BLOCK_WISE_N"),
                CpOp::Write { path, format } => {
                    s.push_str(&format!(" {path}.SCALAR.STRING.true {}.SCALAR.STRING.true", format.name()));
                }
                _ => {}
            }
            s
        }
        Instr::MrJob(j) => render_job(j),
        Instr::SparkJob(j) => render_spark_job(j),
    }
}

fn vt_str(l: &Lit) -> &'static str {
    match l.vtype() {
        ValueType::Int => "INT",
        ValueType::Double => "DOUBLE",
        ValueType::Bool => "BOOLEAN",
        ValueType::Str => "STRING",
    }
}

fn render_mr_inst(i: &MrInst) -> String {
    render_dist_inst("MR", i)
}

fn render_dist_inst(prefix: &str, i: &MrInst) -> String {
    let mut s = format!("{prefix} {}", i.op.code());
    for idx in &i.inputs {
        s.push_str(&format!(" {idx}"));
    }
    s.push_str(&format!(" {}", i.output));
    match &i.op {
        MrOp::Tsmm { left } => s.push_str(if *left { " LEFT" } else { " RIGHT" }),
        MrOp::MapMM { right_part } => {
            s.push_str(if *right_part { " RIGHT_PART false" } else { " LEFT_PART false" })
        }
        MrOp::Agg { kahan } => s.push_str(if *kahan { " true NONE" } else { " false NONE" }),
        _ => {}
    }
    s
}

fn render_job(j: &MrJob) -> String {
    let fmt_list = |insts: &[MrInst]| {
        insts.iter().map(render_mr_inst).collect::<Vec<_>>().join(", ")
    };
    let mut s = String::from("MR-Job[\n");
    s.push_str(&format!("      jobtype        = {}\n", j.job_type.name()));
    s.push_str(&format!("      input labels   = [{}]\n", j.inputs.join(", ")));
    if !j.dcache.is_empty() {
        s.push_str(&format!("      dcache inputs  = [{}]\n", j.dcache.join(", ")));
    }
    s.push_str(&format!("      mapper inst    = {}\n", fmt_list(&j.map_insts)));
    s.push_str(&format!("      shuffle inst   = {}\n", fmt_list(&j.shuffle_insts)));
    s.push_str(&format!("      agg inst       = {}\n", fmt_list(&j.agg_insts)));
    s.push_str(&format!("      other inst     = {}\n", fmt_list(&j.other_insts)));
    s.push_str(&format!("      output labels  = [{}]\n", j.outputs.join(", ")));
    s.push_str(&format!(
        "      result indices = {}\n",
        j.result_indices.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    ));
    s.push_str(&format!("      num reducers   = {}\n", j.num_reducers));
    s.push_str(&format!("      replication    = {} ]", j.replication));
    s
}

/// Render one Spark job: the lazily fused stage DAG (narrow scan stage,
/// then shuffle-separated wide stages), broadcast variables and outputs.
fn render_spark_job(j: &SparkJob) -> String {
    let fmt_list = |insts: &[MrInst]| {
        insts.iter().map(|i| render_dist_inst("SPARK", i)).collect::<Vec<_>>().join(", ")
    };
    let wide = j.stages.iter().filter(|s| s.wide).count();
    let mut s = String::from("SPARK-Job[\n");
    s.push_str(&format!(
        "      stages         = {} ({} narrow, {} wide)\n",
        j.stages.len(),
        j.stages.len() - wide,
        wide
    ));
    s.push_str(&format!("      input labels   = [{}]\n", j.inputs.join(", ")));
    if !j.broadcasts.is_empty() {
        s.push_str(&format!("      broadcast vars = [{}]\n", j.broadcasts.join(", ")));
    }
    for (k, stage) in j.stages.iter().enumerate() {
        let kind = if stage.wide { "wide  " } else { "narrow" };
        s.push_str(&format!(
            "      stage {k} {kind} = {}\n",
            fmt_list(&stage.insts)
        ));
    }
    s.push_str(&format!("      output labels  = [{}]\n", j.outputs.join(", ")));
    s.push_str(&format!(
        "      result indices = {}\n",
        j.result_indices.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    ));
    s.push_str(&format!("      shuffle parts  = {}\n", j.num_reducers));
    s.push_str(&format!("      replication    = {} ]", j.replication));
    s
}

fn explain_inst(inst: &Instr, out: &mut String, indent: usize, opts: ExplainOpts) {
    if matches!(inst, Instr::RmVar { .. }) && !opts.show_rmvar {
        return;
    }
    let rendered = render_inst(inst);
    for (k, line) in rendered.lines().enumerate() {
        if k == 0 {
            out.push_str(&format!("{}{}\n", dashes(indent), line));
        } else {
            out.push_str(&format!("{}{}\n", dashes(indent), line));
        }
    }
}
