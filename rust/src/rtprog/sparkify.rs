//! Spark stage fusion — the Spark backend's counterpart to
//! [`crate::rtprog::piggyback`].
//!
//! Where piggybacking packs MR operations into a *minimal number of MR
//! jobs* (and still needs a second job for every cpmm aggregation),
//! Spark's lazy evaluation keeps one wave of distributed operators inside
//! a **single job**: narrow transformations (map-side ops) fuse into their
//! producer's stage, and every wide dependency — a cpmm/rmm shuffle join
//! or an `ak+` aggregation of partials — starts a new stage after a
//! shuffle boundary. The result is a stage DAG ([`SparkStage`] list in
//! topological order) triggered by one action.
//!
//! Byte indices follow the same scheme as [`piggyback::pack`]
//! (inputs `0..k-1`, then primary instruction outputs in node order, then
//! follow-up aggregation outputs), so EXPLAIN output, the cost model and
//! the simulator shim all share one dataflow encoding.

use std::collections::HashMap;

use super::piggyback::{MrDep, MrNode, Phase};
use super::*;

/// Result of fusing one wave: a single Spark job plus, for every node
/// whose output is consumed outside the wave, its variable name and
/// characteristics (paralleling [`piggyback::Packed`]).
pub struct SparkPacked {
    /// The fused stage-DAG job.
    pub job: SparkJob,
    /// Materialised outputs: `(variable, characteristics)` per external
    /// consumer, in node order.
    pub materialized: Vec<(String, MatrixCharacteristics)>,
}

/// Fuse one wave of MR nodes (in topological order) into a single Spark
/// job with shuffle-separated stages.
pub fn fuse(nodes: &[MrNode], num_reducers: usize, replication: usize) -> SparkPacked {
    // 1. intern job-input variables (byte indices 0..k-1); broadcast deps
    // become torrent broadcasts instead of distributed-cache reads.
    let mut inputs: Vec<String> = Vec::new();
    let mut broadcasts: Vec<String> = Vec::new();
    let mut var_idx: HashMap<String, usize> = HashMap::new();
    for n in nodes {
        for (k, d) in n.deps.iter().enumerate() {
            if let MrDep::Var(name, _) = d {
                let idx = match var_idx.get(name.as_str()) {
                    Some(&i) => i,
                    None => {
                        let i = inputs.len();
                        inputs.push(name.clone());
                        var_idx.insert(name.clone(), i);
                        i
                    }
                };
                if n.broadcast == Some(k) && !broadcasts.contains(&inputs[idx]) {
                    broadcasts.push(inputs[idx].clone());
                }
            }
        }
    }

    // 2. stage assignment: narrow ops run in the stage their inputs become
    // available in; shuffle/agg-phase ops and follow-up aggregations start
    // one stage later (wide dependency). Job inputs are available at
    // stage 0.
    let node_pos: HashMap<usize, usize> =
        nodes.iter().enumerate().map(|(i, n)| (n.nid, i)).collect();
    let mut inst_stage: Vec<usize> = vec![0; nodes.len()];
    let mut out_stage: Vec<usize> = vec![0; nodes.len()];
    for (i, n) in nodes.iter().enumerate() {
        let avail = n
            .deps
            .iter()
            .map(|d| match d {
                MrDep::Var(..) => 0,
                MrDep::Node(dep) => out_stage[node_pos[dep]],
            })
            .max()
            .unwrap_or(0);
        let s = avail + usize::from(n.phase != Phase::Map);
        inst_stage[i] = s;
        out_stage[i] = s + usize::from(n.agg.is_some());
    }

    // 3. byte indices: primary outputs first (node order), then follow-up
    // aggregation outputs — the piggybacking scheme.
    let mut next_idx = inputs.len();
    let mut node_pre_agg_idx: Vec<usize> = vec![0; nodes.len()];
    let mut node_out_idx: Vec<usize> = vec![0; nodes.len()];
    for i in 0..nodes.len() {
        node_pre_agg_idx[i] = next_idx;
        node_out_idx[i] = next_idx;
        next_idx += 1;
    }
    for (i, n) in nodes.iter().enumerate() {
        if n.agg.is_some() {
            node_out_idx[i] = next_idx;
            next_idx += 1;
        }
    }

    // 4. build stage instruction lists.
    let n_stages = nodes
        .iter()
        .enumerate()
        .map(|(i, n)| inst_stage[i] + usize::from(n.agg.is_some()))
        .max()
        .map_or(1, |m| m + 1);
    let mut stages: Vec<SparkStage> = (0..n_stages)
        .map(|s| SparkStage { wide: s > 0, insts: Vec::new() })
        .collect();
    for (i, n) in nodes.iter().enumerate() {
        let in_idx: Vec<usize> = n
            .deps
            .iter()
            .map(|d| match d {
                MrDep::Var(v, _) => var_idx[v.as_str()],
                MrDep::Node(dep) => node_out_idx[node_pos[dep]],
            })
            .collect();
        stages[inst_stage[i]].insts.push(MrInst {
            op: n.op.clone(),
            inputs: in_idx,
            output: node_pre_agg_idx[i],
            mc: n.mc,
        });
        if let Some(agg) = &n.agg {
            stages[inst_stage[i] + 1].insts.push(MrInst {
                op: agg.clone(),
                inputs: vec![node_pre_agg_idx[i]],
                output: node_out_idx[i],
                mc: n.mc,
            });
        }
    }

    // A wave whose earliest distributed op is wide (e.g. a lone cpmm, or
    // a reduce-side join of two materialised inputs) leaves stage 0
    // unpopulated — the scan is folded into the shuffle op here — so drop
    // empty stages rather than charging scheduling latency for them.
    // `wide` flags are per-boundary and survive the filter.
    let stages: Vec<SparkStage> = stages.into_iter().filter(|s| !s.insts.is_empty()).collect();

    // 5. outputs: only nodes consumed outside the wave materialise (every
    // in-wave consumer reads the fused RDD lineage instead).
    let mut outputs = Vec::new();
    let mut result_indices = Vec::new();
    let mut materialized = Vec::new();
    for (i, n) in nodes.iter().enumerate() {
        if n.out_needed {
            outputs.push(n.out_var.clone());
            result_indices.push(node_out_idx[i]);
            materialized.push((n.out_var.clone(), n.mc));
        }
    }

    SparkPacked {
        job: SparkJob {
            inputs,
            broadcasts,
            stages,
            outputs,
            result_indices,
            num_reducers,
            replication,
        },
        materialized,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::MatrixCharacteristics;

    fn mc(r: i64, c: i64) -> MatrixCharacteristics {
        MatrixCharacteristics::new(r, c, 1000, -1)
    }

    fn node(nid: usize, op: MrOp, deps: Vec<MrDep>) -> MrNode {
        MrNode {
            nid,
            op,
            agg: None,
            phase: Phase::Map,
            job_type: JobType::Gmr,
            replicable: false,
            deps,
            broadcast: None,
            out_var: format!("_mVar{}", nid + 10),
            mc: mc(1000, 1000),
            out_needed: false,
        }
    }

    fn xvar() -> MrDep {
        MrDep::Var("X".into(), mc(100_000_000, 1000))
    }

    /// The XL1 wave (tsmm + r' + mapmm + two aggs) fuses into ONE job of
    /// two stages: a narrow scan stage and a wide aggregation stage —
    /// where MR piggybacking also needs one job, Spark matches it.
    #[test]
    fn xl1_wave_fuses_into_two_stages() {
        let mut tsmm = node(0, MrOp::Tsmm { left: true }, vec![xvar()]);
        tsmm.agg = Some(MrOp::Agg { kahan: true });
        tsmm.out_needed = true;
        let mut tr = node(1, MrOp::Transpose, vec![xvar()]);
        tr.replicable = true;
        let mut mapmm = node(
            2,
            MrOp::MapMM { right_part: true },
            vec![MrDep::Node(1), MrDep::Var("_mVar3".into(), mc(100_000_000, 1))],
        );
        mapmm.agg = Some(MrOp::Agg { kahan: true });
        mapmm.broadcast = Some(1);
        mapmm.out_needed = true;
        let packed = fuse(&[tsmm, tr, mapmm], 12, 1);
        let j = &packed.job;
        assert_eq!(j.stages.len(), 2);
        assert!(!j.stages[0].wide);
        assert!(j.stages[1].wide);
        assert_eq!(j.stages[0].insts.len(), 3, "tsmm, r', mapmm fused narrow");
        assert_eq!(j.stages[1].insts.len(), 2, "two ak+ after the shuffle");
        assert_eq!(j.inputs, vec!["X".to_string(), "_mVar3".to_string()]);
        assert_eq!(j.broadcasts, vec!["_mVar3".to_string()]);
        // byte indices match the piggybacking scheme (Figure 3)
        assert_eq!(j.stages[0].insts[0].output, 2);
        assert_eq!(j.stages[0].insts[2].inputs, vec![3, 1]);
        assert_eq!(j.result_indices, vec![5, 6]);
        assert_eq!(packed.materialized.len(), 2);
    }

    /// A cpmm + follow-up aggregation needs TWO MR jobs under
    /// piggybacking but stays a single three-stage Spark job.
    #[test]
    fn cpmm_chain_is_one_job_three_stages() {
        let mut tr = node(0, MrOp::Transpose, vec![xvar()]);
        tr.replicable = true;
        let mut cpmm = node(1, MrOp::Cpmm, vec![MrDep::Node(0), xvar()]);
        cpmm.phase = Phase::Shuffle;
        cpmm.job_type = JobType::Mmcj;
        let mut agg = node(2, MrOp::Agg { kahan: true }, vec![MrDep::Node(1)]);
        agg.phase = Phase::Agg;
        agg.out_needed = true;
        let packed = fuse(&[tr, cpmm, agg], 12, 1);
        let j = &packed.job;
        assert_eq!(j.stages.len(), 3, "scan, shuffle-join, aggregate");
        assert_eq!(j.stages[0].insts[0].op, MrOp::Transpose);
        assert_eq!(j.stages[1].insts[0].op, MrOp::Cpmm);
        assert!(matches!(j.stages[2].insts[0].op, MrOp::Agg { .. }));
        assert_eq!(j.outputs.len(), 1, "only the final aggregate materialises");
    }

    /// A shuffle-only wave (cpmm of two materialised inputs, no map-phase
    /// riders) must not emit an empty narrow stage 0.
    #[test]
    fn shuffle_only_wave_has_no_empty_stage() {
        let mut cpmm = node(
            0,
            MrOp::Cpmm,
            vec![MrDep::Var("A".into(), mc(1_000, 100_000_000)), xvar()],
        );
        cpmm.phase = Phase::Shuffle;
        cpmm.job_type = JobType::Mmcj;
        let mut agg = node(1, MrOp::Agg { kahan: true }, vec![MrDep::Node(0)]);
        agg.phase = Phase::Agg;
        agg.out_needed = true;
        let packed = fuse(&[cpmm, agg], 12, 1);
        let j = &packed.job;
        assert_eq!(j.stages.len(), 2, "cpmm stage + agg stage, no empty scan");
        assert!(j.stages.iter().all(|s| !s.insts.is_empty()));
        assert!(j.stages.iter().all(|s| s.wide), "both stages follow shuffles");
        assert_eq!(j.stages[0].insts[0].op, MrOp::Cpmm);
    }

    /// Narrow chains fuse into one stage regardless of length.
    #[test]
    fn narrow_chain_fuses_into_single_stage() {
        let tr = node(0, MrOp::Transpose, vec![xvar()]);
        let mut sc = node(
            1,
            MrOp::ScalarBin { op: BinOp::Mul, scalar: 2.0, scalar_var: None, scalar_left: false },
            vec![MrDep::Node(0)],
        );
        sc.out_needed = true;
        let packed = fuse(&[tr, sc], 12, 1);
        assert_eq!(packed.job.stages.len(), 1);
        assert_eq!(packed.job.stages[0].insts.len(), 2);
        assert_eq!(packed.materialized.len(), 1);
    }
}
