//! Piggybacking: pack MR operations into a minimal number of MR jobs
//! (paper §2: "our piggybacking algorithm (that packs MR operations into a
//! minimal number of MR jobs) was able to pack all these operations into a
//! single MR job which (1) shares the scan of X, and prevents the
//! materialization of Xᵀ").
//!
//! The algorithm works in rounds over a list of [`MrNode`]s (one logical MR
//! operation each, in topological order):
//!
//! * **Shuffle nodes** (cpmm/rmm) each open their own MMCJ/MMRJ job; cheap
//!   map-phase producers (transpose, diag, datagen, scalar ops) are
//!   *replicated* into consumer jobs instead of being materialised — this
//!   reproduces the paper's XL2 observation that the transpose of X is
//!   replicated into both jobs.
//! * All remaining eligible **map/agg nodes of a round share one GMR job**
//!   (map→map and map→agg chaining inside the job is free; the job may read
//!   several inputs — XL1 packs tsmm, r' and mapmm over the shared scan of
//!   X). Aggregations of *prior-round* outputs (the cpmm follow-up `ak+`)
//!   enter the shared GMR as additional inputs — XL4's two cpmm
//!   aggregations share one job.
//!
//! Under these rules the paper's scenarios yield exactly 1 (XL1) and
//! 3 (XL2, XL3, XL4) MR jobs.

use std::collections::{HashMap, HashSet};

use super::*;

/// Execution phase of an MR node inside a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Map phase (scan-side operators).
    Map,
    /// Shuffle phase (cpmm/rmm joins).
    Shuffle,
    /// Aggregation phase (combiner/reducer `ak+`).
    Agg,
}

/// Dependency of an MR node.
#[derive(Clone, Debug, PartialEq)]
pub enum MrDep {
    /// A variable already resident on HDFS (or exported by CP).
    Var(String, MatrixCharacteristics),
    /// Output of another pending MR node.
    Node(usize),
}

/// One logical MR operation awaiting job assignment.
#[derive(Clone, Debug)]
pub struct MrNode {
    /// Node id (index in the wave, referenced by [`MrDep::Node`]).
    pub nid: usize,
    /// Primary operation of the node.
    pub op: MrOp,
    /// Follow-up same-job aggregation (`ak+` for tsmm/mapmm/uagg partials).
    pub agg: Option<MrOp>,
    /// Phase the primary operation runs in.
    pub phase: Phase,
    /// Job class the node requires (GMR / RAND / MMCJ / MMRJ).
    pub job_type: JobType,
    /// Cheap map-phase op that may be copied into consumer jobs.
    pub replicable: bool,
    /// Inputs of the node (variables or other wave nodes).
    pub deps: Vec<MrDep>,
    /// Index into `deps` read via distributed cache (broadcast).
    pub broadcast: Option<usize>,
    /// Materialization variable name (used when the output crosses jobs).
    pub out_var: String,
    /// Output characteristics.
    pub mc: MatrixCharacteristics,
    /// Output is consumed outside the MR subplan (CP instruction / final).
    pub out_needed: bool,
}

/// Result of packing: jobs in execution order plus, for every node whose
/// output was materialised, its variable name and characteristics.
pub struct Packed {
    /// MR jobs in execution order.
    pub jobs: Vec<MrJob>,
    /// Materialised outputs: `(variable, characteristics)` per external
    /// consumer, in node order.
    pub materialized: Vec<(String, MatrixCharacteristics)>,
}

/// Pack nodes into jobs.
pub fn pack(nodes: &[MrNode], num_reducers: usize, replication: usize) -> Packed {
    let by_id: HashMap<usize, &MrNode> = nodes.iter().map(|n| (n.nid, n)).collect();
    // consumers of each node
    let mut consumers: HashMap<usize, Vec<usize>> = HashMap::new();
    for n in nodes {
        for d in &n.deps {
            if let MrDep::Node(d) = d {
                consumers.entry(*d).or_default().push(n.nid);
            }
        }
    }

    let mut completed: HashSet<usize> = HashSet::new();
    let mut pending: Vec<usize> = nodes.iter().map(|n| n.nid).collect();
    let mut jobs = Vec::new();
    let mut materialized = Vec::new();

    // A replicable node can ride along if all of its own deps are vars or
    // completed nodes.
    let is_rideable = |nid: usize, completed: &HashSet<usize>| -> bool {
        let n = by_id[&nid];
        n.replicable
            && n.phase == Phase::Map
            && n.deps.iter().all(|d| match d {
                MrDep::Var(..) => true,
                MrDep::Node(d) => completed.contains(d),
            })
    };

    let mut guard = 0;
    while !pending.is_empty() {
        guard += 1;
        assert!(guard <= nodes.len() + 2, "piggybacking failed to make progress");
        let mut round_drafts: Vec<Vec<usize>> = Vec::new(); // node ids per draft

        // --- shuffle nodes: one job each, with rideable producers copied in
        let shuffle_ready: Vec<usize> = pending
            .iter()
            .copied()
            .filter(|&nid| {
                let n = by_id[&nid];
                n.phase == Phase::Shuffle
                    && n.deps.iter().all(|d| match d {
                        MrDep::Var(..) => true,
                        MrDep::Node(d) => completed.contains(d) || is_rideable(*d, &completed),
                    })
            })
            .collect();
        for nid in shuffle_ready {
            let n = by_id[&nid];
            let mut draft = Vec::new();
            for d in &n.deps {
                if let MrDep::Node(d) = d {
                    if !completed.contains(d) {
                        draft.push(*d); // replicated copy
                    }
                }
            }
            draft.push(nid);
            round_drafts.push(draft);
        }

        // --- shared GMR/RAND job for everything else that is ready.
        // Shuffle nodes placed above are excluded, but their *replicated
        // riders* may be copied into the shared job too (the paper's XL2:
        // r' rides both the MMCJ and the mapmm GMR).
        let placed_shuffle: HashSet<usize> = round_drafts
            .iter()
            .flatten()
            .copied()
            .filter(|nid| by_id[nid].phase == Phase::Shuffle)
            .collect();
        let mut shared: Vec<usize> = Vec::new();
        let mut shared_set: HashSet<usize> = HashSet::new();
        // iterate in order until fixpoint: map→map / map→agg chains allowed
        loop {
            let mut progress = false;
            for &nid in &pending {
                if shared_set.contains(&nid) || placed_shuffle.contains(&nid) {
                    continue;
                }
                let n = by_id[&nid];
                if n.phase == Phase::Shuffle {
                    continue;
                }
                // replicable nodes are never seeds: they enter jobs only as
                // riders of a consumer (otherwise a transpose whose
                // consumers were all packed into MMCJ jobs would open a
                // spurious extra GMR)
                if n.replicable && !n.out_needed {
                    continue;
                }
                let ok = n.deps.iter().all(|d| match d {
                    MrDep::Var(..) => true,
                    MrDep::Node(d) => {
                        if completed.contains(d) || shared_set.contains(d) {
                            // completed outputs are HDFS inputs; in-job
                            // chaining requires a map-phase producer without
                            // its own aggregation
                            !shared_set.contains(d) || {
                                let p = by_id[d];
                                p.phase == Phase::Map && p.agg.is_none()
                            }
                        } else {
                            is_rideable(*d, &completed)
                        }
                    }
                });
                if ok {
                    // pull rideable deps in as copies first
                    for d in &n.deps {
                        if let MrDep::Node(d) = d {
                            if !completed.contains(d) && !shared_set.contains(d) {
                                shared.push(*d);
                                shared_set.insert(*d);
                            }
                        }
                    }
                    shared.push(nid);
                    shared_set.insert(nid);
                    progress = true;
                }
            }
            if !progress {
                break;
            }
        }
        if !shared.is_empty() {
            round_drafts.push(shared);
        }

        assert!(!round_drafts.is_empty(), "piggybacking deadlock: no node placeable");

        // --- finalise drafts into MrJobs
        let mut newly_completed: Vec<usize> = Vec::new();
        for draft in &round_drafts {
            let draft_set: HashSet<usize> = draft.iter().copied().collect();
            let job = build_job(
                draft,
                &draft_set,
                &by_id,
                &consumers,
                &completed,
                num_reducers,
                replication,
                &mut materialized,
            );
            jobs.push(job);
            for &nid in draft {
                let n = by_id[&nid];
                // replicated copies stay pending until every consumer is done;
                // non-replicable nodes complete now
                if !n.replicable {
                    newly_completed.push(nid);
                } else {
                    let cons = consumers.get(&nid).cloned().unwrap_or_default();
                    let all_done = cons.iter().all(|c| {
                        draft_set.contains(c)
                            || completed.contains(c)
                            || newly_completed.contains(c)
                    });
                    // materialised copies complete too
                    if all_done || materialized.iter().any(|(v, _)| v == &n.out_var) {
                        newly_completed.push(nid);
                    }
                }
            }
        }
        for nid in newly_completed {
            completed.insert(nid);
            pending.retain(|&p| p != nid);
        }
    }

    Packed { jobs, materialized }
}

/// Build one MrJob from a draft (node ids in topological order).
#[allow(clippy::too_many_arguments)]
fn build_job(
    draft: &[usize],
    draft_set: &HashSet<usize>,
    by_id: &HashMap<usize, &MrNode>,
    consumers: &HashMap<usize, Vec<usize>>,
    completed: &HashSet<usize>,
    num_reducers: usize,
    replication: usize,
    materialized: &mut Vec<(String, MatrixCharacteristics)>,
) -> MrJob {
    // 1. collect job input variables (byte indices 0..k-1)
    let mut inputs: Vec<String> = Vec::new();
    let mut dcache: Vec<String> = Vec::new();
    let mut var_idx: HashMap<String, usize> = HashMap::new();
    let intern = |name: &str, inputs: &mut Vec<String>, var_idx: &mut HashMap<String, usize>| {
        if let Some(&i) = var_idx.get(name) {
            return i;
        }
        let i = inputs.len();
        inputs.push(name.to_string());
        var_idx.insert(name.to_string(), i);
        i
    };
    for &nid in draft {
        let n = by_id[&nid];
        for (k, d) in n.deps.iter().enumerate() {
            let name = match d {
                MrDep::Var(v, _) => v.clone(),
                MrDep::Node(d) if !draft_set.contains(d) => {
                    debug_assert!(completed.contains(d), "dep must be completed");
                    by_id[d].out_var.clone()
                }
                _ => continue,
            };
            let idx = intern(&name, &mut inputs, &mut var_idx);
            if n.broadcast == Some(k) && !dcache.contains(&inputs[idx]) {
                dcache.push(inputs[idx].clone());
            }
        }
    }

    // 2. assign output indices and build instructions. All map/shuffle
    // outputs are allocated before the follow-up aggregation outputs,
    // matching SystemML's byte-index scheme (Figure 3: tsmm→2, r'→3,
    // mapmm→4, then ak+→5 and ak+→6).
    let mut next_idx = inputs.len();
    let mut node_out_idx: HashMap<usize, usize> = HashMap::new();
    let mut node_pre_agg_idx: HashMap<usize, usize> = HashMap::new();
    let mut map_insts = Vec::new();
    let mut shuffle_insts = Vec::new();
    let mut agg_insts = Vec::new();
    let other_insts = Vec::new();
    for &nid in draft {
        let n = by_id[&nid];
        let in_idx: Vec<usize> = n
            .deps
            .iter()
            .map(|d| match d {
                MrDep::Var(v, _) => var_idx[v],
                MrDep::Node(d) => {
                    if draft_set.contains(d) && node_out_idx.contains_key(d) {
                        node_out_idx[d]
                    } else {
                        var_idx[&by_id[d].out_var]
                    }
                }
            })
            .collect();
        let out = next_idx;
        next_idx += 1;
        let inst = MrInst { op: n.op.clone(), inputs: in_idx, output: out, mc: n.mc };
        match n.phase {
            Phase::Map => map_insts.push(inst),
            Phase::Shuffle => shuffle_insts.push(inst),
            Phase::Agg => agg_insts.push(inst),
        }
        node_pre_agg_idx.insert(nid, out);
        if n.agg.is_none() {
            node_out_idx.insert(nid, out);
        }
    }
    // second pass: follow-up aggregations
    for &nid in draft {
        let n = by_id[&nid];
        if let Some(agg) = &n.agg {
            let aout = next_idx;
            next_idx += 1;
            agg_insts.push(MrInst {
                op: agg.clone(),
                inputs: vec![node_pre_agg_idx[&nid]],
                output: aout,
                mc: n.mc,
            });
            node_out_idx.insert(nid, aout);
        }
    }

    // 3. decide job outputs: nodes consumed outside this draft or by CP
    let mut outputs = Vec::new();
    let mut result_indices = Vec::new();
    for &nid in draft {
        let n = by_id[&nid];
        let external = n.out_needed
            || consumers
                .get(&nid)
                .map(|cs| cs.iter().any(|c| !draft_set.contains(c) && !completed.contains(c)))
                .unwrap_or(false);
        // replicated copies never materialise unless a CP consumer needs
        // them (`out_needed`): cross-job MR consumers get their own copy
        let external = external && (!n.replicable || n.out_needed);
        if external && !materialized.iter().any(|(v, _)| v == &n.out_var) {
            outputs.push(n.out_var.clone());
            result_indices.push(node_out_idx[&nid]);
            materialized.push((n.out_var.clone(), n.mc));
        }
    }

    let job_type = if draft.iter().any(|&nid| by_id[&nid].phase == Phase::Shuffle) {
        draft
            .iter()
            .map(|&nid| by_id[&nid])
            .find(|n| n.phase == Phase::Shuffle)
            .map(|n| n.job_type)
            .unwrap_or(JobType::Gmr)
    } else if draft.iter().any(|&nid| matches!(by_id[&nid].op, MrOp::DataGen { .. })) {
        JobType::Rand
    } else {
        JobType::Gmr
    };

    MrJob {
        job_type,
        inputs,
        dcache,
        map_insts,
        shuffle_insts,
        agg_insts,
        other_insts,
        outputs,
        result_indices,
        num_reducers,
        replication,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::MatrixCharacteristics;

    fn mc(r: i64, c: i64) -> MatrixCharacteristics {
        MatrixCharacteristics::new(r, c, 1000, -1)
    }

    fn node(nid: usize, op: MrOp, deps: Vec<MrDep>) -> MrNode {
        MrNode {
            nid,
            op,
            agg: None,
            phase: Phase::Map,
            job_type: JobType::Gmr,
            replicable: false,
            deps,
            broadcast: None,
            out_var: format!("_mVar{}", nid + 10),
            mc: mc(1000, 1000),
            out_needed: false,
        }
    }

    fn xvar() -> MrDep {
        MrDep::Var("X".into(), mc(100_000_000, 1000))
    }

    /// XL1: tsmm + r' + mapmm + two aggs -> a single GMR job (Figure 3).
    #[test]
    fn xl1_single_gmr_job() {
        let mut tsmm = node(0, MrOp::Tsmm { left: true }, vec![xvar()]);
        tsmm.agg = Some(MrOp::Agg { kahan: true });
        tsmm.out_needed = true;
        let mut tr = node(1, MrOp::Transpose, vec![xvar()]);
        tr.replicable = true;
        let mut mapmm = node(
            2,
            MrOp::MapMM { right_part: true },
            vec![MrDep::Node(1), MrDep::Var("_mVar3".into(), mc(100_000_000, 1))],
        );
        mapmm.agg = Some(MrOp::Agg { kahan: true });
        mapmm.broadcast = Some(1);
        mapmm.out_needed = true;
        let packed = pack(&[tsmm, tr, mapmm], 12, 1);
        assert_eq!(packed.jobs.len(), 1, "XL1 must pack into one job");
        let j = &packed.jobs[0];
        assert_eq!(j.job_type, JobType::Gmr);
        assert_eq!(j.inputs, vec!["X".to_string(), "_mVar3".to_string()]);
        assert_eq!(j.dcache, vec!["_mVar3".to_string()]);
        assert_eq!(j.map_insts.len(), 3); // tsmm, r', mapmm
        assert_eq!(j.agg_insts.len(), 2); // two ak+
        assert_eq!(j.outputs.len(), 2);
    }

    /// XL2: cpmm for X'X (MMCJ + agg) + mapmm GMR; r' replicated into both
    /// the MMCJ and the GMR job -> 3 jobs total.
    #[test]
    fn xl2_three_jobs_with_replicated_transpose() {
        let mut tr = node(0, MrOp::Transpose, vec![xvar()]);
        tr.replicable = true;
        let mut cpmm = node(1, MrOp::Cpmm, vec![MrDep::Node(0), xvar()]);
        cpmm.phase = Phase::Shuffle;
        cpmm.job_type = JobType::Mmcj;
        let mut cpmm_agg = node(2, MrOp::Agg { kahan: true }, vec![MrDep::Node(1)]);
        cpmm_agg.phase = Phase::Agg;
        cpmm_agg.out_needed = true;
        let mut mapmm = node(
            3,
            MrOp::MapMM { right_part: true },
            vec![MrDep::Node(0), MrDep::Var("_mVar3".into(), mc(100_000_000, 1))],
        );
        mapmm.agg = Some(MrOp::Agg { kahan: true });
        mapmm.broadcast = Some(1);
        mapmm.out_needed = true;
        let packed = pack(&[tr, cpmm, cpmm_agg, mapmm], 12, 1);
        assert_eq!(packed.jobs.len(), 3, "XL2 = MMCJ + GMR(mapmm) + GMR(agg)");
        // r' appears in two jobs (replication)
        let transposes: usize = packed
            .jobs
            .iter()
            .map(|j| j.all_insts().filter(|i| i.op == MrOp::Transpose).count())
            .sum();
        assert_eq!(transposes, 2, "transpose replicated into both jobs");
        assert_eq!(packed.jobs[0].job_type, JobType::Mmcj);
    }

    /// XL3: map-side tsmm (GMR) + cpmm for X'y (MMCJ + agg GMR) -> 3 jobs.
    #[test]
    fn xl3_three_jobs() {
        let mut tsmm = node(0, MrOp::Tsmm { left: true }, vec![xvar()]);
        tsmm.agg = Some(MrOp::Agg { kahan: true });
        tsmm.out_needed = true;
        let mut tr = node(1, MrOp::Transpose, vec![xvar()]);
        tr.replicable = true;
        let mut cpmm = node(
            2,
            MrOp::Cpmm,
            vec![MrDep::Node(1), MrDep::Var("y".into(), mc(200_000_000, 1))],
        );
        cpmm.phase = Phase::Shuffle;
        cpmm.job_type = JobType::Mmcj;
        let mut cpmm_agg = node(3, MrOp::Agg { kahan: true }, vec![MrDep::Node(2)]);
        cpmm_agg.phase = Phase::Agg;
        cpmm_agg.out_needed = true;
        let packed = pack(&[tsmm, tr, cpmm, cpmm_agg], 12, 1);
        assert_eq!(packed.jobs.len(), 3);
    }

    /// XL4: two cpmm (2 MMCJ jobs) + both aggregations share one GMR -> 3.
    #[test]
    fn xl4_shared_aggregation_job() {
        let mut tr = node(0, MrOp::Transpose, vec![xvar()]);
        tr.replicable = true;
        let mut cpmm1 = node(1, MrOp::Cpmm, vec![MrDep::Node(0), xvar()]);
        cpmm1.phase = Phase::Shuffle;
        cpmm1.job_type = JobType::Mmcj;
        let mut agg1 = node(2, MrOp::Agg { kahan: true }, vec![MrDep::Node(1)]);
        agg1.phase = Phase::Agg;
        agg1.out_needed = true;
        let mut cpmm2 = node(
            3,
            MrOp::Cpmm,
            vec![MrDep::Node(0), MrDep::Var("y".into(), mc(200_000_000, 1))],
        );
        cpmm2.phase = Phase::Shuffle;
        cpmm2.job_type = JobType::Mmcj;
        let mut agg2 = node(4, MrOp::Agg { kahan: true }, vec![MrDep::Node(3)]);
        agg2.phase = Phase::Agg;
        agg2.out_needed = true;
        let packed = pack(&[tr, cpmm1, agg1, cpmm2, agg2], 12, 1);
        assert_eq!(packed.jobs.len(), 3, "2 MMCJ + 1 shared agg GMR");
        let agg_job = packed.jobs.last().unwrap();
        assert_eq!(agg_job.job_type, JobType::Gmr);
        assert_eq!(agg_job.agg_insts.len(), 2, "both aggregations shared");
        assert_eq!(agg_job.inputs.len(), 2, "reads both MMCJ outputs");
    }

    /// Byte indices follow SystemML's scheme: inputs 0..k-1, then outputs.
    #[test]
    fn byte_index_assignment_matches_figure3() {
        let mut tsmm = node(0, MrOp::Tsmm { left: true }, vec![xvar()]);
        tsmm.agg = Some(MrOp::Agg { kahan: true });
        tsmm.out_needed = true;
        let mut tr = node(1, MrOp::Transpose, vec![xvar()]);
        tr.replicable = true;
        let mut mapmm = node(
            2,
            MrOp::MapMM { right_part: true },
            vec![MrDep::Node(1), MrDep::Var("_mVar3".into(), mc(100_000_000, 1))],
        );
        mapmm.agg = Some(MrOp::Agg { kahan: true });
        mapmm.broadcast = Some(1);
        mapmm.out_needed = true;
        let packed = pack(&[tsmm, tr, mapmm], 12, 1);
        let j = &packed.jobs[0];
        // Figure 3: tsmm 0->2, r' 0->3, mapmm (3,1)->4, ak+ 2->5, ak+ 4->6
        assert_eq!(j.map_insts[0].inputs, vec![0]);
        assert_eq!(j.map_insts[0].output, 2);
        assert_eq!(j.map_insts[1].inputs, vec![0]);
        assert_eq!(j.map_insts[1].output, 3);
        assert_eq!(j.map_insts[2].inputs, vec![3, 1]);
        assert_eq!(j.map_insts[2].output, 4);
        assert_eq!(j.agg_insts[0].inputs, vec![2]);
        assert_eq!(j.agg_insts[0].output, 5);
        assert_eq!(j.agg_insts[1].inputs, vec![4]);
        assert_eq!(j.agg_insts[1].output, 6);
        assert_eq!(j.result_indices, vec![5, 6]);
    }

    #[test]
    fn chain_of_aggregated_outputs_splits_jobs() {
        // map op consuming an aggregated output must go to the next job
        let mut a = node(0, MrOp::Tsmm { left: true }, vec![xvar()]);
        a.agg = Some(MrOp::Agg { kahan: true });
        let mut b = node(
            1,
            MrOp::ScalarBin { op: BinOp::Mul, scalar: 2.0, scalar_var: None, scalar_left: false },
            vec![MrDep::Node(0)],
        );
        b.out_needed = true;
        let packed = pack(&[a, b], 12, 1);
        assert_eq!(packed.jobs.len(), 2);
        // first job materialises the tsmm output for the second
        assert_eq!(packed.jobs[0].outputs.len(), 1);
        assert!(packed.jobs[1].inputs.contains(&packed.jobs[0].outputs[0]));
    }

    #[test]
    fn map_chain_shares_one_job() {
        // r' -> scalar multiply chain: one GMR job, no materialisation
        let tr = node(0, MrOp::Transpose, vec![xvar()]);
        let mut sc = node(
            1,
            MrOp::ScalarBin { op: BinOp::Mul, scalar: 2.0, scalar_var: None, scalar_left: false },
            vec![MrDep::Node(0)],
        );
        sc.out_needed = true;
        let packed = pack(&[tr, sc], 12, 1);
        assert_eq!(packed.jobs.len(), 1);
        assert_eq!(packed.jobs[0].outputs.len(), 1);
    }
}
