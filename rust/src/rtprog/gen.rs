//! Runtime-plan generation: HOP program → executable runtime program
//! (paper §2, Figures 2–3). CP hops become CP instructions with fresh
//! `_mVarN` temporaries; MR hops are collected into waves, converted to
//! piggybacking nodes, and packed into MR-job instructions.

use std::collections::{HashMap, HashSet};

use super::piggyback::{self, MrDep, MrNode, Phase};
use super::sparkify;
use super::*;
use crate::conf::{ClusterConfig, SystemConfig};
use crate::ir::{self, Block, DataGenOp, ExecType, HopDag, HopId, HopKind, Program, ReorgOp};
use crate::lop::{select_matmult_backend, MatMultMethod, SelectionHints};
use crate::matrix::Format;

/// Generation context threaded through the whole program.
pub struct GenCtx<'a> {
    /// Compiler/system configuration (block size, reducers, partition size).
    pub cfg: &'a SystemConfig,
    /// Cluster characteristics (memory budgets drive physical selection).
    pub cc: &'a ClusterConfig,
    /// Physical-operator selection hints (ablation knobs).
    pub hints: &'a SelectionHints,
    /// Backend of the block currently being generated (the global data
    /// flow optimizer rebinds this per top-level block, see
    /// [`generate_groups`]).
    pub backend: ExecBackend,
    var_counter: usize,
    scratch: String,
}

/// Generate the runtime program for a compiled (rewritten, size-propagated,
/// memory-annotated, exec-typed) HOP program against the default MR
/// backend. See [`generate_backend`] for the backend-parameterised entry.
pub fn generate(
    prog: &Program,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    hints: &SelectionHints,
) -> RtProgram {
    generate_backend(prog, cfg, cc, hints, ExecBackend::Mr)
}

/// Generate the runtime program for the given execution backend: MR waves
/// become piggybacked [`MrJob`]s on [`ExecBackend::Mr`] and lazily fused
/// stage DAGs ([`SparkJob`]) on [`ExecBackend::Spark`]. On
/// [`ExecBackend::Cp`] every hop was already forced to CP by execution-type
/// selection, so no distributed instructions are emitted.
pub fn generate_backend(
    prog: &Program,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    hints: &SelectionHints,
    backend: ExecBackend,
) -> RtProgram {
    generate_groups(prog, cfg, cc, hints, backend, &[])
}

/// Per-group plan generation for the global data flow optimizer
/// ([`crate::opt::gdf`]): top-level block `i` of the main program is
/// generated against the backend `groups[i]` (its nested blocks inherit
/// it), so one runtime program can mix, say, a CP-forced setup block, an
/// MR preprocessing group and a Spark iteration loop. Blocks beyond
/// `groups.len()` and function bodies use `default_backend`, so
/// `generate_groups(.., &[])` is exactly [`generate_backend`].
///
/// Execution-type selection must have been run with the *same* group
/// assignment ([`crate::ir::exec_type::select_groups`]) — a group forced
/// to CP has no MR-typed hops, and a distributed group's waves are turned
/// into piggybacked MR jobs or fused Spark stage DAGs by this backend
/// value.
pub fn generate_groups(
    prog: &Program,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    hints: &SelectionHints,
    default_backend: ExecBackend,
    groups: &[ExecBackend],
) -> RtProgram {
    let mut ctx = GenCtx {
        cfg,
        cc,
        hints,
        backend: default_backend,
        var_counter: 2,
        // A fixed token, not std::process::id(): temp paths are never
        // written to disk (the CP interpreter drops them — only
        // non-temp createvars keep their path), and a pid here would
        // leak into the structural plan hashes, making a persisted
        // plan artifact regenerate on every cross-process load.
        scratch: "scratch_space//_p0//_t0".to_string(),
    };
    let mut blocks = Vec::with_capacity(prog.blocks.len());
    for (i, b) in prog.blocks.iter().enumerate() {
        ctx.backend = groups.get(i).copied().unwrap_or(default_backend);
        blocks.push(gen_block(b, &mut ctx));
    }
    ctx.backend = default_backend;
    let mut funcs = std::collections::BTreeMap::new();
    for (name, f) in &prog.funcs {
        funcs.insert(
            name.clone(),
            RtFunction {
                params: f.params.clone(),
                outputs: f.outputs.clone(),
                blocks: gen_blocks(&f.body, &mut ctx),
            },
        );
    }
    RtProgram { blocks, funcs }
}

fn gen_blocks(blocks: &[Block], ctx: &mut GenCtx) -> Vec<RtBlock> {
    blocks.iter().map(|b| gen_block(b, ctx)).collect()
}

fn gen_block(b: &Block, ctx: &mut GenCtx) -> RtBlock {
    match b {
        Block::Generic(g) => RtBlock::Generic {
            insts: gen_dag(&g.dag, ctx),
            lines: g.lines,
            recompile: g.recompile,
        },
        Block::If { pred, then_blocks, else_blocks, lines } => RtBlock::If {
            pred: gen_pred(pred, ctx),
            then_blocks: gen_blocks(then_blocks, ctx),
            else_blocks: gen_blocks(else_blocks, ctx),
            lines: *lines,
        },
        Block::For { var, from, to, by, body, parfor, known_trip, lines } => RtBlock::For {
            var: var.clone(),
            from: gen_pred(from, ctx),
            to: gen_pred(to, ctx),
            by: by.as_ref().map(|b| gen_pred(b, ctx)),
            body: gen_blocks(body, ctx),
            parfor: *parfor,
            known_trip: *known_trip,
            lines: *lines,
        },
        Block::While { pred, body, lines } => RtBlock::While {
            pred: gen_pred(pred, ctx),
            body: gen_blocks(body, ctx),
            lines: *lines,
        },
        Block::FCall { fname, args, outputs, lines } => RtBlock::FCall {
            fname: fname.clone(),
            args: args.clone(),
            outputs: outputs.clone(),
            lines: *lines,
        },
    }
}

fn gen_pred(dag: &HopDag, ctx: &mut GenCtx) -> PredProg {
    let mut state = DagGen::new(dag, ctx);
    state.run();
    let result = dag.roots.first().map(|r| state.done[r].clone());
    // Free materialized temps here too — a matrix-valued predicate
    // sub-expression (e.g. `sum(X %*% v) > 0`) would otherwise leak its
    // intermediates for the rest of the program. The predicate result
    // itself must stay live for the enclosing control-flow block.
    let keep = result.as_ref().and_then(|o| o.name().map(str::to_string));
    let insts = insert_rmvars_except(state.insts, keep.as_deref());
    PredProg { insts, result }
}

/// Generate instructions for one DAG.
pub fn gen_dag(dag: &HopDag, ctx: &mut GenCtx) -> Vec<Instr> {
    let mut state = DagGen::new(dag, ctx);
    state.run();
    insert_rmvars(state.insts)
}

struct DagGen<'a, 'b> {
    dag: &'a HopDag,
    ctx: &'a mut GenCtx<'b>,
    topo: Vec<HopId>,
    consumers: HashMap<HopId, Vec<HopId>>,
    methods: HashMap<HopId, MatMultMethod>,
    suppressed: HashSet<HopId>,
    done: HashMap<HopId, Operand>,
    insts: Vec<Instr>,
    /// partition instructions already emitted for (broadcast var) -> temp
    partitions: HashMap<String, String>,
}

impl<'a, 'b> DagGen<'a, 'b> {
    fn new(dag: &'a HopDag, ctx: &'a mut GenCtx<'b>) -> Self {
        let topo = dag.topo_order();
        let mut consumers: HashMap<HopId, Vec<HopId>> = HashMap::new();
        for &id in &topo {
            for &i in &dag.hop(id).inputs {
                consumers.entry(i).or_default().push(id);
            }
        }
        // physical operator selection for matmults
        let mut methods = HashMap::new();
        for &id in &topo {
            if dag.hop(id).kind == HopKind::MatMult {
                methods.insert(
                    id,
                    select_matmult_backend(dag, id, ctx.cfg, ctx.cc, ctx.hints, ctx.backend),
                );
            }
        }
        // suppressed transposes: consumed only by tsmm (as the transposed
        // side) or by the (y'X)' rewrite
        let mut suppressed = HashSet::new();
        for &id in &topo {
            if dag.hop(id).kind != HopKind::Reorg(ReorgOp::Transpose) {
                continue;
            }
            let all_absorbed = consumers.get(&id).is_some_and(|cons| {
                !cons.is_empty()
                    && cons.iter().all(|&c| match methods.get(&c) {
                        Some(MatMultMethod::CpTsmm { left })
                        | Some(MatMultMethod::MrTsmm { left }) => {
                            let h = dag.hop(c);
                            (*left && h.inputs[0] == id) || (!*left && h.inputs[1] == id)
                        }
                        Some(MatMultMethod::CpMMTransposeRewrite) => dag.hop(c).inputs[0] == id,
                        _ => false,
                    })
            });
            if all_absorbed && !dag.roots.contains(&id) {
                suppressed.insert(id);
            }
        }
        DagGen {
            dag,
            ctx,
            topo,
            consumers,
            methods,
            suppressed,
            done: HashMap::new(),
            insts: Vec::new(),
            partitions: HashMap::new(),
        }
    }

    fn fresh_mvar(&mut self) -> String {
        let v = format!("_mVar{}", self.ctx.var_counter);
        self.ctx.var_counter += 1;
        v
    }

    fn scratch_path(&self) -> String {
        format!("{}/temp{}", self.ctx.scratch, self.ctx.var_counter)
    }

    /// Emit createvar + return the operand for a fresh matrix temp.
    fn new_matrix_temp(&mut self, mc: crate::matrix::MatrixCharacteristics) -> Operand {
        let path = self.scratch_path();
        let var = self.fresh_mvar();
        self.insts.push(Instr::CreateVar {
            var: var.clone(),
            path,
            temp: true,
            format: Format::BinaryBlock,
            mc,
        });
        Operand::Mat(var)
    }

    fn run(&mut self) {
        let mut remaining: Vec<HopId> = self.topo.clone();
        let mut guard = 0;
        while !remaining.is_empty() {
            guard += 1;
            assert!(guard <= self.topo.len() + 2, "runtime generation stuck");
            let mut progress = false;
            // CP pass
            let mut i = 0;
            while i < remaining.len() {
                let id = remaining[i];
                if self.cp_ready(id) {
                    self.emit_cp(id);
                    remaining.remove(i);
                    progress = true;
                } else {
                    i += 1;
                }
            }
            // MR wave
            let wave: Vec<HopId> = {
                let mut wave = Vec::new();
                let mut wave_set: HashSet<HopId> = HashSet::new();
                for &id in &remaining {
                    if self.is_mr(id)
                        && !self.suppressed.contains(&id)
                        && self.mr_ready(id, &wave_set)
                    {
                        wave.push(id);
                        wave_set.insert(id);
                    }
                }
                wave
            };
            if !wave.is_empty() {
                self.emit_mr_wave(&wave);
                remaining.retain(|id| !wave.contains(id));
                progress = true;
            }
            if !progress {
                break;
            }
        }
        debug_assert!(remaining.is_empty(), "unscheduled hops: {remaining:?}");
    }

    fn is_mr(&self, id: HopId) -> bool {
        self.dag.hop(id).exec == Some(ExecType::Mr)
    }

    /// Inputs that matter for scheduling (skip suppressed transposes by
    /// looking through them).
    fn sched_inputs(&self, id: HopId) -> Vec<HopId> {
        self.dag
            .hop(id)
            .inputs
            .iter()
            .map(|&i| if self.suppressed.contains(&i) { self.dag.hop(i).inputs[0] } else { i })
            .collect()
    }

    fn cp_ready(&self, id: HopId) -> bool {
        // Suppressed transposes are pure pass-throughs (they emit nothing),
        // regardless of their selected execution type — an MR-typed
        // suppressed transpose must NOT enter an MR wave, or it would be
        // spuriously materialised.
        if self.suppressed.contains(&id) {
            return self.sched_inputs(id).iter().all(|i| self.done.contains_key(i));
        }
        if self.is_mr(id) {
            return false;
        }
        self.sched_inputs(id).iter().all(|i| self.done.contains_key(i))
    }

    fn mr_ready(&self, id: HopId, wave: &HashSet<HopId>) -> bool {
        self.sched_inputs(id)
            .iter()
            .all(|i| self.done.contains_key(i) || (wave.contains(i) && self.is_mr(*i)))
    }

    /// Operand of a hop input (resolving suppressed transposes to their
    /// own input when requested by tsmm-style consumers).
    fn operand(&self, id: HopId) -> Operand {
        self.done[&id].clone()
    }

    // ----- CP emission -----

    fn emit_cp(&mut self, id: HopId) {
        use ir::UnOp;
        // reborrow the DAG reference out of `self` so `hop` does not pin
        // `self` (the arms below mutate `self.insts`/`self.done`); this
        // replaces a full `Hop` clone per emitted instruction
        let dag = self.dag;
        let hop = dag.hop(id);
        if self.suppressed.contains(&id) {
            // pass through: operand of the underlying input
            let inner = hop.inputs[0];
            let op = self.done[&inner].clone();
            self.done.insert(id, op);
            return;
        }
        match &hop.kind {
            HopKind::Literal(l) => {
                self.done.insert(id, Operand::Lit(l.clone()));
            }
            HopKind::TRead { name } => {
                let op = if hop.dtype.is_matrix() {
                    Operand::Mat(name.clone())
                } else {
                    let vt = match &hop.dtype {
                        ir::DataType::Scalar(vt) => *vt,
                        _ => ir::ValueType::Double,
                    };
                    Operand::Scalar(name.clone(), vt)
                };
                self.done.insert(id, op);
            }
            HopKind::PRead { name, path, format } => {
                let var = format!("pREAD{name}");
                self.insts.push(Instr::CreateVar {
                    var: var.clone(),
                    path: path.clone(),
                    temp: false,
                    format: *format,
                    mc: hop.mc,
                });
                self.done.insert(id, Operand::Mat(var));
            }
            HopKind::TWrite { name } => {
                let input = self.operand(hop.inputs[0]);
                match &input {
                    Operand::Lit(l) => {
                        self.insts.push(Instr::AssignVar { lit: l.clone(), var: name.clone() })
                    }
                    Operand::Mat(src) | Operand::Scalar(src, _) => self
                        .insts
                        .push(Instr::CpVar { src: src.clone(), dst: name.clone() }),
                }
                let out = match input {
                    Operand::Lit(l) => Operand::Scalar(name.clone(), l.vtype()),
                    Operand::Scalar(_, vt) => Operand::Scalar(name.clone(), vt),
                    Operand::Mat(_) => Operand::Mat(name.clone()),
                };
                self.done.insert(id, out);
            }
            HopKind::PWrite { path, format, .. } => {
                let input = self.operand(hop.inputs[0]);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Write { path: path.clone(), format: *format },
                    inputs: vec![input],
                    output: Operand::Scalar("_done".into(), ir::ValueType::Bool),
                }));
                self.done.insert(id, Operand::Lit(ir::Lit::Bool(true)));
            }
            HopKind::Print => {
                let input = self.operand(hop.inputs[0]);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Print,
                    inputs: vec![input],
                    output: Operand::Scalar("_print".into(), ir::ValueType::Str),
                }));
                self.done.insert(id, Operand::Lit(ir::Lit::Bool(true)));
            }
            HopKind::MatMult => self.emit_cp_matmult(id),
            HopKind::DataGen(DataGenOp::Rand { min, max, sparsity, seed }) => {
                let rows = self.operand(hop.inputs[0]);
                let cols = self.operand(hop.inputs[1]);
                let out = self.new_matrix_temp(hop.mc);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Rand { min: *min, max: *max, sparsity: *sparsity, seed: *seed },
                    inputs: vec![rows, cols],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
            HopKind::DataGen(DataGenOp::Seq { from, to, by }) => {
                let out = self.new_matrix_temp(hop.mc);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Seq { from: *from, to: *to, by: *by },
                    inputs: vec![],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
            HopKind::Reorg(r) => {
                let input = self.operand(hop.inputs[0]);
                let out = self.new_matrix_temp(hop.mc);
                let op = match r {
                    ReorgOp::Transpose => CpOp::Transpose,
                    ReorgOp::Diag => CpOp::Diag,
                };
                self.insts.push(Instr::Cp(CpInst { op, inputs: vec![input], output: out.clone() }));
                self.done.insert(id, out);
            }
            HopKind::Binary(b) => {
                let lhs = self.operand(hop.inputs[0]);
                let rhs = self.operand(hop.inputs[1]);
                let out = if hop.dtype.is_matrix() {
                    self.new_matrix_temp(hop.mc)
                } else {
                    let v = self.fresh_mvar();
                    Operand::Scalar(v, scalar_vt(&hop.dtype))
                };
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Binary(*b),
                    inputs: vec![lhs, rhs],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
            HopKind::Unary(u) => {
                // nrow/ncol on known sizes fold to literals at runtime-plan
                // level (SystemML compiles sizes into the plan)
                if matches!(u, UnOp::Nrow | UnOp::Ncol | UnOp::Length) {
                    let in_mc = self.dag.hop(hop.inputs[0]).mc;
                    let v = match u {
                        UnOp::Nrow if in_mc.rows >= 0 => Some(in_mc.rows),
                        UnOp::Ncol if in_mc.cols >= 0 => Some(in_mc.cols),
                        UnOp::Length if in_mc.dims_known() => Some(in_mc.rows * in_mc.cols),
                        _ => None,
                    };
                    if let Some(v) = v {
                        self.done.insert(id, Operand::Lit(ir::Lit::Int(v)));
                        return;
                    }
                }
                let input = self.operand(hop.inputs[0]);
                let out = if hop.dtype.is_matrix() {
                    self.new_matrix_temp(hop.mc)
                } else {
                    let v = self.fresh_mvar();
                    Operand::Scalar(v, scalar_vt(&hop.dtype))
                };
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Unary(*u),
                    inputs: vec![input],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
            HopKind::AggUnary(a, d) => {
                let input = self.operand(hop.inputs[0]);
                let out = if hop.dtype.is_matrix() {
                    self.new_matrix_temp(hop.mc)
                } else {
                    let v = self.fresh_mvar();
                    Operand::Scalar(v, ir::ValueType::Double)
                };
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::AggUnary(*a, *d),
                    inputs: vec![input],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
            HopKind::Append => {
                let a = self.operand(hop.inputs[0]);
                let b = self.operand(hop.inputs[1]);
                let out = self.new_matrix_temp(hop.mc);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Append,
                    inputs: vec![a, b],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
        }
    }

    fn emit_cp_matmult(&mut self, id: HopId) {
        let dag = self.dag;
        let hop = dag.hop(id); // reborrow, not clone (see emit_cp)
        let method = self.methods[&id].clone();
        match method {
            MatMultMethod::CpTsmm { left } => {
                // consume the non-transposed side directly
                let x = if left { hop.inputs[1] } else { hop.inputs[0] };
                let input = self.operand(x);
                let out = self.new_matrix_temp(hop.mc);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Tsmm { left },
                    inputs: vec![input],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
            MatMultMethod::CpMMTransposeRewrite => {
                // t(X) %*% y  =>  t(t(y) %*% X)  (Figure 2)
                let tx = hop.inputs[0];
                let x = if self.suppressed.contains(&tx) {
                    self.dag.hop(tx).inputs[0]
                } else {
                    // transpose materialised elsewhere: still valid to use X
                    self.dag.hop(tx).inputs[0]
                };
                let y = hop.inputs[1];
                let y_mc = self.dag.hop(y).mc;
                let ty_mc = crate::matrix::MatrixCharacteristics::new(
                    y_mc.cols, y_mc.rows, y_mc.brows, y_mc.nnz,
                );
                let y_op = self.operand(y);
                let ty = self.new_matrix_temp(ty_mc);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Transpose,
                    inputs: vec![y_op],
                    output: ty.clone(),
                }));
                let x_op = self.operand(x);
                let prod_mc = crate::matrix::MatrixCharacteristics::new(
                    hop.mc.cols, hop.mc.rows, hop.mc.brows, -1,
                );
                let prod = self.new_matrix_temp(prod_mc);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::MatMult,
                    inputs: vec![ty, x_op],
                    output: prod.clone(),
                }));
                let out = self.new_matrix_temp(hop.mc);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::Transpose,
                    inputs: vec![prod],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
            _ => {
                // plain CP matrix multiply
                let a = self.operand(hop.inputs[0]);
                let b = self.operand(hop.inputs[1]);
                let out = self.new_matrix_temp(hop.mc);
                self.insts.push(Instr::Cp(CpInst {
                    op: CpOp::MatMult,
                    inputs: vec![a, b],
                    output: out.clone(),
                }));
                self.done.insert(id, out);
            }
        }
    }

    // ----- MR wave emission -----

    fn emit_mr_wave(&mut self, wave: &[HopId]) {
        let wave_set: HashSet<HopId> = wave.iter().copied().collect();
        let mut nodes: Vec<MrNode> = Vec::new();
        // hop -> node id that produces its output
        let mut hop_node: HashMap<HopId, usize> = HashMap::new();
        for &id in wave {
            self.build_nodes(id, &wave_set, &mut nodes, &mut hop_node);
        }
        // mark out_needed: consumers outside the wave or DAG roots
        for &id in wave {
            let external = self
                .consumers
                .get(&id)
                .map(|cs| {
                    cs.iter().any(|c| {
                        !wave_set.contains(c)
                            || (self.suppressed.contains(c)
                                && self
                                    .consumers
                                    .get(c)
                                    .map(|cc| cc.iter().any(|c2| !wave_set.contains(c2)))
                                    .unwrap_or(true))
                    })
                })
                .unwrap_or(true)
                || self.dag.roots.contains(&id);
            if external {
                if let Some(&nid) = hop_node.get(&id) {
                    nodes[nid].out_needed = true;
                    nodes[nid].replicable = false;
                }
            }
        }
        if self.ctx.backend == ExecBackend::Spark {
            // Spark: the whole wave fuses into one lazily evaluated job.
            let packed =
                sparkify::fuse(&nodes, self.ctx.cfg.num_reducers, self.ctx.cfg.replication);
            for (var, mc) in packed.materialized {
                let path = self.scratch_path();
                self.insts.push(Instr::CreateVar {
                    var,
                    path,
                    temp: true,
                    format: Format::BinaryBlock,
                    mc,
                });
            }
            self.insts.push(Instr::SparkJob(packed.job));
            for (&id, &nid) in &hop_node {
                self.done.insert(id, Operand::Mat(nodes[nid].out_var.clone()));
            }
            return;
        }
        let packed = piggyback::pack(&nodes, self.ctx.cfg.num_reducers, self.ctx.cfg.replication);
        // createvars for materialised outputs (moved, not cloned), then
        // the jobs
        for (var, mc) in packed.materialized {
            let path = self.scratch_path();
            self.insts.push(Instr::CreateVar {
                var,
                path,
                temp: true,
                format: Format::BinaryBlock,
                mc,
            });
        }
        for job in packed.jobs {
            self.insts.push(Instr::MrJob(job));
        }
        // record hop results
        for (&id, &nid) in &hop_node {
            self.done.insert(id, Operand::Mat(nodes[nid].out_var.clone()));
        }
    }

    /// Dependency of an MR node on a hop input.
    fn mr_dep(
        &self,
        input: HopId,
        wave: &HashSet<HopId>,
        hop_node: &HashMap<HopId, usize>,
    ) -> MrDep {
        let input = if self.suppressed.contains(&input) {
            // suppressed transpose: MR consumers that absorbed it reference
            // the underlying matrix
            self.dag.hop(input).inputs[0]
        } else {
            input
        };
        if wave.contains(&input) {
            if let Some(&nid) = hop_node.get(&input) {
                return MrDep::Node(nid);
            }
        }
        match self.done.get(&input) {
            Some(Operand::Mat(name)) => MrDep::Var(name.clone(), self.dag.hop(input).mc),
            other => panic!("MR dep on non-matrix operand: {other:?}"),
        }
    }

    /// Create piggybacking node(s) for one MR hop.
    fn build_nodes(
        &mut self,
        id: HopId,
        wave: &HashSet<HopId>,
        nodes: &mut Vec<MrNode>,
        hop_node: &mut HashMap<HopId, usize>,
    ) {
        use ir::{AggOp, BinOp as IBinOp};
        let dag = self.dag;
        let hop = dag.hop(id); // reborrow, not clone (see emit_cp)
        let nid = nodes.len();
        let out_var = self.fresh_mvar();
        let base = MrNode {
            nid,
            op: MrOp::Transpose, // replaced below
            agg: None,
            phase: Phase::Map,
            job_type: JobType::Gmr,
            replicable: false,
            deps: vec![],
            broadcast: None,
            out_var,
            mc: hop.mc,
            out_needed: false,
        };
        match &hop.kind {
            HopKind::MatMult => {
                let method = self.methods[&id].clone();
                match method {
                    MatMultMethod::MrTsmm { left } => {
                        let x = if left { hop.inputs[1] } else { hop.inputs[0] };
                        let x_mc = self.dag.hop(x).mc;
                        let needs_agg = if left {
                            x_mc.rows > x_mc.brows
                        } else {
                            x_mc.cols > x_mc.bcols
                        };
                        let mut n = base;
                        n.op = MrOp::Tsmm { left };
                        n.deps = vec![self.mr_dep(x, wave, hop_node)];
                        n.agg = needs_agg.then_some(MrOp::Agg { kahan: true });
                        nodes.push(n);
                    }
                    MatMultMethod::MrMapMM { broadcast_input, partition } => {
                        let bc_hop_raw = hop.inputs[broadcast_input];
                        // resolve suppressed transposes to their input
                        let bc_hop = if self.suppressed.contains(&bc_hop_raw) {
                            self.dag.hop(bc_hop_raw).inputs[0]
                        } else {
                            bc_hop_raw
                        };
                        // partitioned broadcast: CP partition instruction —
                        // only possible for materialised variables, not for
                        // MR intermediates produced in this same wave
                        let bc_dep = if partition && self.done.contains_key(&bc_hop) {
                            let bc_op = self.operand(bc_hop);
                            let bc_name = bc_op.name().expect("broadcast must be a var").to_string();
                            let part_var = if let Some(p) = self.partitions.get(&bc_name) {
                                p.clone()
                            } else {
                                let out = self.new_matrix_temp(self.dag.hop(bc_hop).mc);
                                let part_var = out.name().unwrap().to_string();
                                self.insts.push(Instr::Cp(CpInst {
                                    op: CpOp::Partition,
                                    inputs: vec![bc_op],
                                    output: out,
                                }));
                                self.partitions.insert(bc_name, part_var.clone());
                                part_var
                            };
                            MrDep::Var(part_var, self.dag.hop(bc_hop).mc)
                        } else {
                            self.mr_dep(bc_hop, wave, hop_node)
                        };
                        let scan_input = hop.inputs[1 - broadcast_input];
                        let scan_dep = self.mr_dep(scan_input, wave, hop_node);
                        // contraction dimension: cols of input[0]
                        let k = self.dag.hop(hop.inputs[0]).mc.cols;
                        let needs_agg = k > self.ctx.cfg.blocksize;
                        let mut n = base;
                        n.op = MrOp::MapMM { right_part: broadcast_input == 1 };
                        n.deps = if broadcast_input == 1 {
                            vec![scan_dep, bc_dep]
                        } else {
                            vec![bc_dep, scan_dep]
                        };
                        n.broadcast = Some(broadcast_input);
                        n.agg = needs_agg.then_some(MrOp::Agg { kahan: true });
                        nodes.push(n);
                    }
                    MatMultMethod::MrCpmm => {
                        // node 1: shuffle cpmm (MMCJ)
                        let mut n1 = base;
                        n1.op = MrOp::Cpmm;
                        n1.phase = Phase::Shuffle;
                        n1.job_type = JobType::Mmcj;
                        n1.deps = vec![
                            self.mr_dep(hop.inputs[0], wave, hop_node),
                            self.mr_dep(hop.inputs[1], wave, hop_node),
                        ];
                        nodes.push(n1);
                        // node 2: follow-up aggregation (GMR)
                        let nid2 = nodes.len();
                        let out_var2 = self.fresh_mvar();
                        nodes.push(MrNode {
                            nid: nid2,
                            op: MrOp::Agg { kahan: true },
                            agg: None,
                            phase: Phase::Agg,
                            job_type: JobType::Gmr,
                            replicable: false,
                            deps: vec![MrDep::Node(nid)],
                            broadcast: None,
                            out_var: out_var2,
                            mc: hop.mc,
                            out_needed: false,
                        });
                        hop_node.insert(id, nid2);
                        return;
                    }
                    MatMultMethod::MrRmm => {
                        let mut n = base;
                        n.op = MrOp::Rmm;
                        n.phase = Phase::Shuffle;
                        n.job_type = JobType::Mmrj;
                        n.deps = vec![
                            self.mr_dep(hop.inputs[0], wave, hop_node),
                            self.mr_dep(hop.inputs[1], wave, hop_node),
                        ];
                        nodes.push(n);
                    }
                    other => panic!("CP matmult method {other:?} on MR hop"),
                }
            }
            HopKind::Reorg(r) => {
                let mut n = base;
                n.op = match r {
                    ReorgOp::Transpose => MrOp::Transpose,
                    ReorgOp::Diag => MrOp::Diag,
                };
                n.replicable = true;
                n.deps = vec![self.mr_dep(hop.inputs[0], wave, hop_node)];
                nodes.push(n);
            }
            HopKind::DataGen(DataGenOp::Rand { min, max, sparsity, seed }) => {
                let mut n = base;
                n.op = MrOp::DataGen {
                    min: *min,
                    max: *max,
                    sparsity: *sparsity,
                    seed: *seed,
                    rows: hop.mc.rows,
                    cols: hop.mc.cols,
                };
                n.job_type = JobType::Rand;
                n.replicable = min == max;
                nodes.push(n);
            }
            HopKind::DataGen(DataGenOp::Seq { from, to, by }) => {
                let mut n = base;
                n.op = MrOp::DataGen {
                    min: *from,
                    max: *to,
                    sparsity: *by,
                    seed: 0,
                    rows: hop.mc.rows,
                    cols: 1,
                };
                n.job_type = JobType::Rand;
                n.replicable = true;
                nodes.push(n);
            }
            HopKind::Binary(b) => {
                // matrix-scalar (map-side) vs matrix-matrix (reduce join)
                let a_scalar = !self.dag.hop(hop.inputs[0]).dtype.is_matrix();
                let b_scalar = !self.dag.hop(hop.inputs[1]).dtype.is_matrix();
                if a_scalar || b_scalar {
                    let (m, s) = if a_scalar {
                        (hop.inputs[1], hop.inputs[0])
                    } else {
                        (hop.inputs[0], hop.inputs[1])
                    };
                    let (scalar, scalar_var) = match self.operand(s) {
                        Operand::Lit(l) => (l.as_f64().unwrap_or(f64::NAN), None),
                        Operand::Scalar(v, _) => (f64::NAN, Some(v)),
                        Operand::Mat(_) => unreachable!("scalar operand expected"),
                    };
                    let mut n = base;
                    n.op = MrOp::ScalarBin {
                        op: *b,
                        scalar,
                        scalar_var,
                        scalar_left: a_scalar,
                    };
                    n.replicable = true;
                    n.deps = vec![self.mr_dep(m, wave, hop_node)];
                    nodes.push(n);
                } else {
                    let mut n = base;
                    n.op = MrOp::Binary(*b);
                    n.phase = Phase::Agg; // reduce-side join
                    n.deps = vec![
                        self.mr_dep(hop.inputs[0], wave, hop_node),
                        self.mr_dep(hop.inputs[1], wave, hop_node),
                    ];
                    nodes.push(n);
                }
            }
            HopKind::Unary(u) => {
                let mut n = base;
                n.op = MrOp::Unary(*u);
                n.replicable = true;
                n.deps = vec![self.mr_dep(hop.inputs[0], wave, hop_node)];
                nodes.push(n);
            }
            HopKind::AggUnary(a, d) => {
                let kahan = matches!(a, AggOp::Sum | AggOp::Mean | AggOp::Trace);
                let mut n = base;
                n.op = MrOp::AggUnaryMap(*a, *d);
                n.agg = Some(MrOp::Agg { kahan });
                n.deps = vec![self.mr_dep(hop.inputs[0], wave, hop_node)];
                nodes.push(n);
            }
            HopKind::Append => {
                let offset = self.dag.hop(hop.inputs[0]).mc.cols;
                let mut n = base;
                n.op = MrOp::Append { offset };
                n.deps = vec![
                    self.mr_dep(hop.inputs[0], wave, hop_node),
                    self.mr_dep(hop.inputs[1], wave, hop_node),
                ];
                n.broadcast = Some(1);
                nodes.push(n);
            }
            other => panic!("hop kind {other:?} cannot run on MR"),
        }
        // default: single node produced
        let _ = IBinOp::Add;
        hop_node.insert(id, nid);
    }
}

fn scalar_vt(dt: &ir::DataType) -> ir::ValueType {
    match dt {
        ir::DataType::Scalar(vt) => *vt,
        _ => ir::ValueType::Double,
    }
}

/// Insert `rmvar` instructions after the last use of each `_mVar` temp.
fn insert_rmvars(insts: Vec<Instr>) -> Vec<Instr> {
    insert_rmvars_except(insts, None)
}

/// [`insert_rmvars`], but `keep` (a predicate's result operand) is never
/// freed — the enclosing control-flow block reads it after the program.
fn insert_rmvars_except(insts: Vec<Instr>, keep: Option<&str>) -> Vec<Instr> {
    let mut last_use: HashMap<String, usize> = HashMap::new();
    let mut temps: HashSet<String> = HashSet::new();
    for (i, inst) in insts.iter().enumerate() {
        let mut touch = |name: &str| {
            last_use.insert(name.to_string(), i);
        };
        match inst {
            Instr::CreateVar { var, temp, .. } => {
                if *temp {
                    temps.insert(var.clone());
                }
                touch(var);
            }
            Instr::AssignVar { var, .. } => touch(var),
            Instr::CpVar { src, dst } => {
                touch(src);
                touch(dst);
            }
            Instr::RmVar { .. } => {}
            Instr::Cp(c) => {
                for op in &c.inputs {
                    if let Some(n) = op.name() {
                        touch(n);
                    }
                }
                if let Some(n) = c.output.name() {
                    touch(n);
                    if n.starts_with("_mVar") {
                        temps.insert(n.to_string());
                    }
                }
            }
            Instr::MrJob(j) => {
                for v in j.inputs.iter().chain(&j.outputs) {
                    touch(v);
                }
            }
            Instr::SparkJob(j) => {
                for v in j.inputs.iter().chain(&j.outputs) {
                    touch(v);
                }
            }
        }
    }
    let mut by_pos: HashMap<usize, Vec<String>> = HashMap::new();
    for (var, pos) in last_use {
        if temps.contains(&var) && keep != Some(var.as_str()) {
            by_pos.entry(pos).or_default().push(var);
        }
    }
    let mut out = Vec::with_capacity(insts.len());
    for (i, inst) in insts.into_iter().enumerate() {
        out.push(inst);
        if let Some(mut vars) = by_pos.remove(&i) {
            vars.sort();
            out.push(Instr::RmVar { vars });
        }
    }
    out
}
