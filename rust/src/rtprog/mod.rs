//! Executable runtime programs (paper §2, Figures 2–3): program blocks of
//! CP instructions and MR-job instructions, generated from HOP DAGs with
//! physical operator selection and piggybacking.

pub mod explain;
pub mod gen;
pub mod piggyback;

use std::collections::BTreeMap;

use crate::ir::{AggDir, AggOp, BinOp, Lit, UnOp, ValueType};
use crate::matrix::{Format, MatrixCharacteristics};

/// Instruction operand.
#[derive(Clone, Debug, PartialEq)]
pub enum Operand {
    /// Matrix variable.
    Mat(String),
    /// Scalar variable.
    Scalar(String, ValueType),
    /// Literal scalar.
    Lit(Lit),
}

impl Operand {
    pub fn name(&self) -> Option<&str> {
        match self {
            Operand::Mat(n) | Operand::Scalar(n, _) => Some(n),
            Operand::Lit(_) => None,
        }
    }

    /// SystemML-style rendering, e.g. `X.MATRIX.DOUBLE`, `0.SCALAR.INT.true`.
    pub fn render(&self) -> String {
        match self {
            Operand::Mat(n) => format!("{n}.MATRIX.DOUBLE"),
            Operand::Scalar(n, vt) => format!("{n}.SCALAR.{}", vt_name(*vt)),
            Operand::Lit(l) => format!("{}.SCALAR.{}.true", l.render(), vt_name(l.vtype())),
        }
    }
}

fn vt_name(vt: ValueType) -> &'static str {
    match vt {
        ValueType::Int => "INT",
        ValueType::Double => "DOUBLE",
        ValueType::Bool => "BOOLEAN",
        ValueType::Str => "STRING",
    }
}

/// CP (control program) operation codes.
#[derive(Clone, Debug, PartialEq)]
pub enum CpOp {
    /// Transpose-self matrix multiply (`tsmm ... LEFT`).
    Tsmm { left: bool },
    /// General matrix multiply `ba+*`.
    MatMult,
    /// Transpose `r'`.
    Transpose,
    /// Vector→diag matrix / matrix→diag vector `rdiag`.
    Diag,
    /// Data generation `rand` (rows/cols as operands, rest constant).
    Rand { min: f64, max: f64, sparsity: f64, seed: i64 },
    /// Sequence generation.
    Seq { from: f64, to: f64, by: f64 },
    /// Binary op (elementwise / matrix-scalar / scalar-scalar / solve).
    Binary(BinOp),
    /// Unary op.
    Unary(UnOp),
    /// Unary aggregate (`uak+`, `uark+`, `uack+`, ...).
    AggUnary(AggOp, AggDir),
    /// Horizontal concatenation.
    Append,
    /// Partition a matrix for partitioned broadcast (`ROW_BLOCK_WISE_N`).
    Partition,
    /// Persistent write.
    Write { path: String, format: Format },
    /// Print to stdout.
    Print,
}

impl CpOp {
    /// SystemML opcode string.
    pub fn code(&self) -> String {
        match self {
            CpOp::Tsmm { .. } => "tsmm".into(),
            CpOp::MatMult => "ba+*".into(),
            CpOp::Transpose => "r'".into(),
            CpOp::Diag => "rdiag".into(),
            CpOp::Rand { .. } => "rand".into(),
            CpOp::Seq { .. } => "seq".into(),
            CpOp::Binary(b) => b.code().into(),
            CpOp::Unary(u) => u.code().into(),
            CpOp::AggUnary(op, dir) => {
                let o = match op {
                    AggOp::Sum => "ak+",
                    AggOp::Mean => "amean",
                    AggOp::Min => "amin",
                    AggOp::Max => "amax",
                    AggOp::Trace => "aktrace",
                    AggOp::Nnz => "aknnz",
                };
                let d = match dir {
                    AggDir::All => "u",
                    AggDir::Row => "uar",
                    AggDir::Col => "uac",
                };
                format!("{d}{o}")
            }
            CpOp::Append => "append".into(),
            CpOp::Partition => "partition".into(),
            CpOp::Write { .. } => "write".into(),
            CpOp::Print => "print".into(),
        }
    }
}

/// One CP instruction.
#[derive(Clone, Debug, PartialEq)]
pub struct CpInst {
    pub op: CpOp,
    pub inputs: Vec<Operand>,
    pub output: Operand,
}

/// MR job types (SystemML's piggybacking classes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum JobType {
    /// Generic MR: map + (combine) + aggregate.
    Gmr,
    /// Data generation job.
    Rand,
    /// Cross-product join matmult (cpmm step 1).
    Mmcj,
    /// Replication-based matmult.
    Mmrj,
}

impl JobType {
    pub fn name(&self) -> &'static str {
        match self {
            JobType::Gmr => "GMR",
            JobType::Rand => "RAND",
            JobType::Mmcj => "MMCJ",
            JobType::Mmrj => "MMRJ",
        }
    }
}

/// MR instruction operators (operands are job-local byte indices).
#[derive(Clone, Debug, PartialEq)]
pub enum MrOp {
    Tsmm { left: bool },
    /// Broadcast matmult; `right_part` marks which side is the partitioned
    /// broadcast input (Figure 3: `mapmm 3 1 4 RIGHT_PART false`).
    MapMM { right_part: bool },
    /// Cross-product join partial products (shuffle phase of MMCJ).
    Cpmm,
    /// Replication-join matmult (MMRJ).
    Rmm,
    Transpose,
    Diag,
    /// Rand datagen in a RAND job.
    DataGen { min: f64, max: f64, sparsity: f64, seed: i64, rows: i64, cols: i64 },
    /// Elementwise matrix-matrix binary (reduce-side join).
    Binary(BinOp),
    /// Matrix-scalar binary (map-side). The scalar is a literal (`scalar`)
    /// or a runtime scalar variable (`scalar_var`) passed via job config.
    ScalarBin { op: BinOp, scalar: f64, scalar_var: Option<String>, scalar_left: bool },
    Unary(UnOp),
    /// Map-side partial aggregate, e.g. `uak+`.
    AggUnaryMap(AggOp, AggDir),
    /// Final aggregation `ak+` (kahan) in combiner/reducer.
    Agg { kahan: bool },
    /// Map-side append of a broadcast column block.
    Append { offset: i64 },
}

impl MrOp {
    pub fn code(&self) -> String {
        match self {
            MrOp::Tsmm { .. } => "tsmm".into(),
            MrOp::MapMM { .. } => "mapmm".into(),
            MrOp::Cpmm => "cpmm".into(),
            MrOp::Rmm => "rmm".into(),
            MrOp::Transpose => "r'".into(),
            MrOp::Diag => "rdiag".into(),
            MrOp::DataGen { .. } => "rand".into(),
            MrOp::Binary(b) => b.code().into(),
            MrOp::ScalarBin { op, .. } => format!("s{}", op.code()),
            MrOp::Unary(u) => u.code().into(),
            MrOp::AggUnaryMap(op, dir) => {
                let o = match op {
                    AggOp::Sum => "k+",
                    AggOp::Mean => "mean",
                    AggOp::Min => "min",
                    AggOp::Max => "max",
                    AggOp::Trace => "ktrace",
                    AggOp::Nnz => "knnz",
                };
                let d = match dir {
                    AggDir::All => "ua",
                    AggDir::Row => "uar",
                    AggDir::Col => "uac",
                };
                format!("{d}{o}")
            }
            MrOp::Agg { kahan } => if *kahan { "ak+" } else { "a+" }.into(),
            MrOp::Append { .. } => "append".into(),
        }
    }
}

/// One MR instruction with job-local operand indices.
#[derive(Clone, Debug, PartialEq)]
pub struct MrInst {
    pub op: MrOp,
    pub inputs: Vec<usize>,
    pub output: usize,
    /// Output characteristics (for costing shuffle/write volumes).
    pub mc: MatrixCharacteristics,
}

/// A generated MR-job instruction (Figure 3's `MR-Job[...]`).
#[derive(Clone, Debug, PartialEq)]
pub struct MrJob {
    pub job_type: JobType,
    /// Input labels: variables read from HDFS (index order = byte index).
    pub inputs: Vec<String>,
    /// Inputs read via distributed cache (subset of `inputs`).
    pub dcache: Vec<String>,
    pub map_insts: Vec<MrInst>,
    pub shuffle_insts: Vec<MrInst>,
    pub agg_insts: Vec<MrInst>,
    pub other_insts: Vec<MrInst>,
    /// Output variable labels, parallel to `result_indices`.
    pub outputs: Vec<String>,
    pub result_indices: Vec<usize>,
    pub num_reducers: usize,
    pub replication: usize,
}

impl MrJob {
    /// All instructions in execution order.
    pub fn all_insts(&self) -> impl Iterator<Item = &MrInst> {
        self.map_insts
            .iter()
            .chain(&self.shuffle_insts)
            .chain(&self.agg_insts)
            .chain(&self.other_insts)
    }
}

/// Runtime instructions.
#[derive(Clone, Debug, PartialEq)]
pub enum Instr {
    /// Create matrix variable metadata handle.
    CreateVar { var: String, path: String, temp: bool, format: Format, mc: MatrixCharacteristics },
    /// Bind a literal to a scalar variable.
    AssignVar { lit: Lit, var: String },
    /// Bind a variable to another name.
    CpVar { src: String, dst: String },
    /// Remove variables (end of live range).
    RmVar { vars: Vec<String> },
    Cp(CpInst),
    MrJob(MrJob),
}

/// Small instruction program computing a predicate / loop bound.
#[derive(Clone, Debug, Default)]
pub struct PredProg {
    pub insts: Vec<Instr>,
    pub result: Option<Operand>,
}

/// Runtime program blocks, mirroring [`crate::ir::Block`].
#[derive(Clone, Debug)]
pub enum RtBlock {
    Generic { insts: Vec<Instr>, lines: (usize, usize), recompile: bool },
    If {
        pred: PredProg,
        then_blocks: Vec<RtBlock>,
        else_blocks: Vec<RtBlock>,
        lines: (usize, usize),
    },
    For {
        var: String,
        from: PredProg,
        to: PredProg,
        by: Option<PredProg>,
        body: Vec<RtBlock>,
        parfor: bool,
        known_trip: Option<f64>,
        lines: (usize, usize),
    },
    While { pred: PredProg, body: Vec<RtBlock>, lines: (usize, usize) },
    FCall { fname: String, args: Vec<String>, outputs: Vec<String>, lines: (usize, usize) },
}

/// A runtime function.
#[derive(Clone, Debug)]
pub struct RtFunction {
    pub params: Vec<String>,
    pub outputs: Vec<String>,
    pub blocks: Vec<RtBlock>,
}

/// A complete runtime program.
#[derive(Clone, Debug, Default)]
pub struct RtProgram {
    pub blocks: Vec<RtBlock>,
    pub funcs: BTreeMap<String, RtFunction>,
}

impl RtProgram {
    /// Count (CP, MR) instructions — the `size CP/MR = 34/0` header of
    /// Figures 2 and 3.
    pub fn size(&self) -> (usize, usize) {
        fn count(blocks: &[RtBlock], cp: &mut usize, mr: &mut usize) {
            let count_insts = |insts: &[Instr], cp: &mut usize, mr: &mut usize| {
                for i in insts {
                    match i {
                        Instr::MrJob(_) => *mr += 1,
                        Instr::RmVar { .. } => {}
                        _ => *cp += 1,
                    }
                }
            };
            for b in blocks {
                match b {
                    RtBlock::Generic { insts, .. } => count_insts(insts, cp, mr),
                    RtBlock::If { pred, then_blocks, else_blocks, .. } => {
                        count_insts(&pred.insts, cp, mr);
                        count(then_blocks, cp, mr);
                        count(else_blocks, cp, mr);
                    }
                    RtBlock::For { from, to, by, body, .. } => {
                        count_insts(&from.insts, cp, mr);
                        count_insts(&to.insts, cp, mr);
                        if let Some(by) = by {
                            count_insts(&by.insts, cp, mr);
                        }
                        count(body, cp, mr);
                    }
                    RtBlock::While { pred, body, .. } => {
                        count_insts(&pred.insts, cp, mr);
                        count(body, cp, mr);
                    }
                    RtBlock::FCall { .. } => *cp += 1,
                }
            }
        }
        let (mut cp, mut mr) = (0, 0);
        count(&self.blocks, &mut cp, &mut mr);
        for f in self.funcs.values() {
            count(&f.blocks, &mut cp, &mut mr);
        }
        (cp, mr)
    }

    /// Total number of MR jobs in the program.
    pub fn mr_job_count(&self) -> usize {
        self.size().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operand_rendering_matches_systemml() {
        assert_eq!(Operand::Mat("X".into()).render(), "X.MATRIX.DOUBLE");
        assert_eq!(
            Operand::Lit(Lit::Int(0)).render(),
            "0.SCALAR.INT.true"
        );
        assert_eq!(
            Operand::Lit(Lit::Double(0.001)).render(),
            "0.001.SCALAR.DOUBLE.true"
        );
        assert_eq!(
            Operand::Scalar("intercept".into(), ValueType::Int).render(),
            "intercept.SCALAR.INT"
        );
    }

    #[test]
    fn opcodes_match_figures() {
        assert_eq!(CpOp::Tsmm { left: true }.code(), "tsmm");
        assert_eq!(CpOp::MatMult.code(), "ba+*");
        assert_eq!(CpOp::Transpose.code(), "r'");
        assert_eq!(CpOp::Diag.code(), "rdiag");
        assert_eq!(MrOp::Agg { kahan: true }.code(), "ak+");
        assert_eq!(MrOp::MapMM { right_part: true }.code(), "mapmm");
        assert_eq!(JobType::Gmr.name(), "GMR");
    }

    #[test]
    fn program_size_counts_cp_and_mr() {
        let mut prog = RtProgram::default();
        prog.blocks.push(RtBlock::Generic {
            insts: vec![
                Instr::AssignVar { lit: Lit::Int(1), var: "a".into() },
                Instr::RmVar { vars: vec!["a".into()] },
                Instr::MrJob(MrJob {
                    job_type: JobType::Gmr,
                    inputs: vec![],
                    dcache: vec![],
                    map_insts: vec![],
                    shuffle_insts: vec![],
                    agg_insts: vec![],
                    other_insts: vec![],
                    outputs: vec![],
                    result_indices: vec![],
                    num_reducers: 12,
                    replication: 1,
                }),
            ],
            lines: (1, 1),
            recompile: false,
        });
        assert_eq!(prog.size(), (1, 1)); // rmvar not counted
    }
}
