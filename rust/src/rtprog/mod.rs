//! Executable runtime programs (paper §2, Figures 2–3): program blocks of
//! CP instructions and MR-job instructions, generated from HOP DAGs with
//! physical operator selection and piggybacking.
//!
//! Every public item in this module tree carries rustdoc; the lint below
//! keeps it that way (satisfying the `cargo doc` CI gate).

#![warn(missing_docs)]

pub mod explain;
pub mod gen;
pub mod piggyback;
pub mod sparkify;

use std::collections::BTreeMap;

use crate::ir::{AggDir, AggOp, BinOp, Lit, UnOp, ValueType};
use crate::matrix::{Format, MatrixCharacteristics};

/// Execution backend a runtime plan is generated for (the paper's
/// abstract: "single node, in-memory computations to distributed
/// computations on MapReduce (MR) or similar frameworks like Spark").
///
/// * [`ExecBackend::Cp`] — single-node only: every operator is forced to
///   the control program regardless of memory estimates (the cost model
///   still charges the full IO + compute of oversized data, which is how
///   the sweep exposes where single-node execution stops paying off).
/// * [`ExecBackend::Mr`] — the default hybrid plan family of the paper:
///   operators exceeding the memory budget become piggybacked MR jobs.
/// * [`ExecBackend::Spark`] — hybrid CP/Spark: the same distributed
///   operators are emitted as lazily fused stage DAGs ([`SparkJob`])
///   with broadcast-vs-shuffle selection driven by executor memory.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ExecBackend {
    /// Single-node, in-memory control program only.
    Cp,
    /// Hybrid CP + piggybacked MapReduce jobs (the paper's default).
    #[default]
    Mr,
    /// Hybrid CP + lazily fused Spark stage DAGs.
    Spark,
}

impl ExecBackend {
    /// Lower-case label used in sweep tables and CLI flags.
    pub fn name(&self) -> &'static str {
        match self {
            ExecBackend::Cp => "cp",
            ExecBackend::Mr => "mr",
            ExecBackend::Spark => "spark",
        }
    }

    /// Parse a CLI label (`cp`, `mr`, `spark`), case-insensitive.
    pub fn parse(s: &str) -> Option<ExecBackend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "cp" => Some(ExecBackend::Cp),
            "mr" => Some(ExecBackend::Mr),
            "spark" => Some(ExecBackend::Spark),
            _ => None,
        }
    }

    /// All backends in canonical (table) order.
    pub fn all() -> [ExecBackend; 3] {
        [ExecBackend::Cp, ExecBackend::Mr, ExecBackend::Spark]
    }
}

/// Instruction operand.
#[derive(Clone, Debug, PartialEq)]
pub enum Operand {
    /// Matrix variable.
    Mat(String),
    /// Scalar variable.
    Scalar(String, ValueType),
    /// Literal scalar.
    Lit(Lit),
}

impl Operand {
    /// Variable name of the operand (`None` for literals).
    pub fn name(&self) -> Option<&str> {
        match self {
            Operand::Mat(n) | Operand::Scalar(n, _) => Some(n),
            Operand::Lit(_) => None,
        }
    }

    /// SystemML-style rendering, e.g. `X.MATRIX.DOUBLE`, `0.SCALAR.INT.true`.
    pub fn render(&self) -> String {
        match self {
            Operand::Mat(n) => format!("{n}.MATRIX.DOUBLE"),
            Operand::Scalar(n, vt) => format!("{n}.SCALAR.{}", vt_name(*vt)),
            Operand::Lit(l) => format!("{}.SCALAR.{}.true", l.render(), vt_name(l.vtype())),
        }
    }
}

fn vt_name(vt: ValueType) -> &'static str {
    match vt {
        ValueType::Int => "INT",
        ValueType::Double => "DOUBLE",
        ValueType::Bool => "BOOLEAN",
        ValueType::Str => "STRING",
    }
}

/// CP (control program) operation codes.
#[derive(Clone, Debug, PartialEq)]
pub enum CpOp {
    /// Transpose-self matrix multiply (`tsmm ... LEFT`).
    Tsmm { left: bool },
    /// General matrix multiply `ba+*`.
    MatMult,
    /// Transpose `r'`.
    Transpose,
    /// Vector→diag matrix / matrix→diag vector `rdiag`.
    Diag,
    /// Data generation `rand` (rows/cols as operands, rest constant).
    Rand { min: f64, max: f64, sparsity: f64, seed: i64 },
    /// Sequence generation.
    Seq { from: f64, to: f64, by: f64 },
    /// Binary op (elementwise / matrix-scalar / scalar-scalar / solve).
    Binary(BinOp),
    /// Unary op.
    Unary(UnOp),
    /// Unary aggregate (`uak+`, `uark+`, `uack+`, ...).
    AggUnary(AggOp, AggDir),
    /// Horizontal concatenation.
    Append,
    /// Partition a matrix for partitioned broadcast (`ROW_BLOCK_WISE_N`).
    Partition,
    /// Persistent write.
    Write { path: String, format: Format },
    /// Print to stdout.
    Print,
}

impl CpOp {
    /// SystemML opcode string.
    pub fn code(&self) -> String {
        match self {
            CpOp::Tsmm { .. } => "tsmm".into(),
            CpOp::MatMult => "ba+*".into(),
            CpOp::Transpose => "r'".into(),
            CpOp::Diag => "rdiag".into(),
            CpOp::Rand { .. } => "rand".into(),
            CpOp::Seq { .. } => "seq".into(),
            CpOp::Binary(b) => b.code().into(),
            CpOp::Unary(u) => u.code().into(),
            CpOp::AggUnary(op, dir) => {
                let o = match op {
                    AggOp::Sum => "ak+",
                    AggOp::Mean => "amean",
                    AggOp::Min => "amin",
                    AggOp::Max => "amax",
                    AggOp::Trace => "aktrace",
                    AggOp::Nnz => "aknnz",
                };
                let d = match dir {
                    AggDir::All => "u",
                    AggDir::Row => "uar",
                    AggDir::Col => "uac",
                };
                format!("{d}{o}")
            }
            CpOp::Append => "append".into(),
            CpOp::Partition => "partition".into(),
            CpOp::Write { .. } => "write".into(),
            CpOp::Print => "print".into(),
        }
    }
}

/// One CP instruction.
#[derive(Clone, Debug, PartialEq)]
pub struct CpInst {
    /// Operation code.
    pub op: CpOp,
    /// Input operands in positional order.
    pub inputs: Vec<Operand>,
    /// Output operand (matrix temp, scalar or bookkeeping sink).
    pub output: Operand,
}

/// MR job types (SystemML's piggybacking classes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum JobType {
    /// Generic MR: map + (combine) + aggregate.
    Gmr,
    /// Data generation job.
    Rand,
    /// Cross-product join matmult (cpmm step 1).
    Mmcj,
    /// Replication-based matmult.
    Mmrj,
}

impl JobType {
    /// EXPLAIN job-type label (`GMR`, `RAND`, `MMCJ`, `MMRJ`).
    pub fn name(&self) -> &'static str {
        match self {
            JobType::Gmr => "GMR",
            JobType::Rand => "RAND",
            JobType::Mmcj => "MMCJ",
            JobType::Mmrj => "MMRJ",
        }
    }
}

/// MR instruction operators (operands are job-local byte indices).
#[derive(Clone, Debug, PartialEq)]
pub enum MrOp {
    /// Map-side transpose-self matrix multiply (`LEFT` = t(X)%*%X).
    Tsmm { left: bool },
    /// Broadcast matmult; `right_part` marks which side is the partitioned
    /// broadcast input (Figure 3: `mapmm 3 1 4 RIGHT_PART false`).
    MapMM { right_part: bool },
    /// Cross-product join partial products (shuffle phase of MMCJ).
    Cpmm,
    /// Replication-join matmult (MMRJ).
    Rmm,
    /// Block-wise transpose `r'`.
    Transpose,
    /// Vector→diag matrix / matrix→diag vector `rdiag`.
    Diag,
    /// Rand datagen in a RAND job.
    DataGen { min: f64, max: f64, sparsity: f64, seed: i64, rows: i64, cols: i64 },
    /// Elementwise matrix-matrix binary (reduce-side join).
    Binary(BinOp),
    /// Matrix-scalar binary (map-side). The scalar is a literal (`scalar`)
    /// or a runtime scalar variable (`scalar_var`) passed via job config.
    ScalarBin { op: BinOp, scalar: f64, scalar_var: Option<String>, scalar_left: bool },
    /// Elementwise unary op (map-side).
    Unary(UnOp),
    /// Map-side partial aggregate, e.g. `uak+`.
    AggUnaryMap(AggOp, AggDir),
    /// Final aggregation `ak+` (kahan) in combiner/reducer.
    Agg { kahan: bool },
    /// Map-side append of a broadcast column block.
    Append { offset: i64 },
}

impl MrOp {
    /// SystemML opcode string (as printed by EXPLAIN).
    pub fn code(&self) -> String {
        match self {
            MrOp::Tsmm { .. } => "tsmm".into(),
            MrOp::MapMM { .. } => "mapmm".into(),
            MrOp::Cpmm => "cpmm".into(),
            MrOp::Rmm => "rmm".into(),
            MrOp::Transpose => "r'".into(),
            MrOp::Diag => "rdiag".into(),
            MrOp::DataGen { .. } => "rand".into(),
            MrOp::Binary(b) => b.code().into(),
            MrOp::ScalarBin { op, .. } => format!("s{}", op.code()),
            MrOp::Unary(u) => u.code().into(),
            MrOp::AggUnaryMap(op, dir) => {
                let o = match op {
                    AggOp::Sum => "k+",
                    AggOp::Mean => "mean",
                    AggOp::Min => "min",
                    AggOp::Max => "max",
                    AggOp::Trace => "ktrace",
                    AggOp::Nnz => "knnz",
                };
                let d = match dir {
                    AggDir::All => "ua",
                    AggDir::Row => "uar",
                    AggDir::Col => "uac",
                };
                format!("{d}{o}")
            }
            MrOp::Agg { kahan } => if *kahan { "ak+" } else { "a+" }.into(),
            MrOp::Append { .. } => "append".into(),
        }
    }
}

/// One MR instruction with job-local operand indices.
#[derive(Clone, Debug, PartialEq)]
pub struct MrInst {
    /// Operation code.
    pub op: MrOp,
    /// Job-local byte indices of the inputs.
    pub inputs: Vec<usize>,
    /// Job-local byte index of the output.
    pub output: usize,
    /// Output characteristics (for costing shuffle/write volumes).
    pub mc: MatrixCharacteristics,
}

/// A generated MR-job instruction (Figure 3's `MR-Job[...]`).
#[derive(Clone, Debug, PartialEq)]
pub struct MrJob {
    /// Piggybacking job class (GMR / RAND / MMCJ / MMRJ).
    pub job_type: JobType,
    /// Input labels: variables read from HDFS (index order = byte index).
    pub inputs: Vec<String>,
    /// Inputs read via distributed cache (subset of `inputs`).
    pub dcache: Vec<String>,
    /// Map-phase instructions.
    pub map_insts: Vec<MrInst>,
    /// Shuffle-phase instructions (cpmm/rmm joins).
    pub shuffle_insts: Vec<MrInst>,
    /// Combiner/reducer aggregation instructions (`ak+`).
    pub agg_insts: Vec<MrInst>,
    /// Reduce-side instructions outside the aggregation slot.
    pub other_insts: Vec<MrInst>,
    /// Output variable labels, parallel to `result_indices`.
    pub outputs: Vec<String>,
    /// Byte indices of the outputs within the job.
    pub result_indices: Vec<usize>,
    /// Reduce-task count requested for the job.
    pub num_reducers: usize,
    /// Replication factor for job outputs.
    pub replication: usize,
}

impl MrJob {
    /// All instructions in execution order.
    pub fn all_insts(&self) -> impl Iterator<Item = &MrInst> {
        self.map_insts
            .iter()
            .chain(&self.shuffle_insts)
            .chain(&self.agg_insts)
            .chain(&self.other_insts)
    }
}

/// One Spark stage: a pipeline of fused transformations executed without
/// materialisation. `wide` marks stages that begin after a shuffle
/// boundary (Spark's wide dependencies: cpmm/rmm joins and `ak+`
/// aggregations); stage 0 reads the job inputs directly (narrow).
#[derive(Clone, Debug, PartialEq)]
pub struct SparkStage {
    /// Stage begins after a shuffle boundary (wide dependency).
    pub wide: bool,
    /// Fused instructions, in dataflow order (operands are job-local byte
    /// indices, same scheme as [`MrInst`]).
    pub insts: Vec<MrInst>,
}

/// A generated Spark-job instruction: one action triggering a lazily
/// fused stage DAG. Where piggybacking packs MR operations into several
/// jobs (a cpmm needs a *second* job for its aggregation), Spark's lazy
/// evaluation keeps one wave of distributed operators inside a single
/// job whose stages are separated only by shuffle boundaries.
#[derive(Clone, Debug, PartialEq)]
pub struct SparkJob {
    /// Input labels: variables read from HDFS (index order = byte index).
    pub inputs: Vec<String>,
    /// Inputs distributed as torrent broadcasts (subset of `inputs`;
    /// the Spark analogue of the MR distributed cache).
    pub broadcasts: Vec<String>,
    /// Stage DAG in topological order; stage 0 is the narrow scan stage.
    pub stages: Vec<SparkStage>,
    /// Output variable labels, parallel to `result_indices`.
    pub outputs: Vec<String>,
    /// Byte indices of the outputs within the job.
    pub result_indices: Vec<usize>,
    /// Shuffle partitions for wide stages (reuses the reducer knob).
    pub num_reducers: usize,
    /// Replication factor for job outputs.
    pub replication: usize,
}

impl SparkJob {
    /// All instructions in stage order.
    pub fn all_insts(&self) -> impl Iterator<Item = &MrInst> {
        self.stages.iter().flat_map(|s| s.insts.iter())
    }

    /// Reassemble an equivalent [`MrJob`] for the deterministic cluster
    /// simulator (`repro run`): byte-index dataflow is shared between the
    /// two representations, so narrow-stage instructions become map
    /// instructions, cpmm/rmm become shuffle instructions and wide-stage
    /// instructions become aggregation instructions. This is a
    /// best-effort execution shim — costing uses the native
    /// [`crate::cost::spark`] model, never this conversion.
    pub fn as_mr_job(&self) -> MrJob {
        let mut map_insts = Vec::new();
        let mut shuffle_insts = Vec::new();
        let mut agg_insts = Vec::new();
        for stage in &self.stages {
            for inst in &stage.insts {
                match &inst.op {
                    MrOp::Cpmm | MrOp::Rmm => shuffle_insts.push(inst.clone()),
                    _ if stage.wide => agg_insts.push(inst.clone()),
                    _ => map_insts.push(inst.clone()),
                }
            }
        }
        MrJob {
            job_type: JobType::Gmr,
            inputs: self.inputs.clone(),
            dcache: self.broadcasts.clone(),
            map_insts,
            shuffle_insts,
            agg_insts,
            other_insts: Vec::new(),
            outputs: self.outputs.clone(),
            result_indices: self.result_indices.clone(),
            num_reducers: self.num_reducers,
            replication: self.replication,
        }
    }
}

/// Runtime instructions.
#[derive(Clone, Debug, PartialEq)]
pub enum Instr {
    /// Create matrix variable metadata handle.
    CreateVar { var: String, path: String, temp: bool, format: Format, mc: MatrixCharacteristics },
    /// Bind a literal to a scalar variable.
    AssignVar { lit: Lit, var: String },
    /// Bind a variable to another name.
    CpVar { src: String, dst: String },
    /// Remove variables (end of live range).
    RmVar { vars: Vec<String> },
    /// A CP (control program) instruction.
    Cp(CpInst),
    /// A piggybacked MR-job instruction (MR backend).
    MrJob(MrJob),
    /// A Spark action triggering a fused stage DAG (Spark backend).
    SparkJob(SparkJob),
}

/// Small instruction program computing a predicate / loop bound.
#[derive(Clone, Debug, Default)]
pub struct PredProg {
    /// Instructions evaluating the predicate expression.
    pub insts: Vec<Instr>,
    /// Operand holding the predicate value (if any).
    pub result: Option<Operand>,
}

/// Runtime program blocks, mirroring [`crate::ir::Block`].
#[derive(Clone, Debug)]
pub enum RtBlock {
    /// Straight-line instruction block (one compiled HOP DAG).
    Generic { insts: Vec<Instr>, lines: (usize, usize), recompile: bool },
    /// Conditional: predicate program plus then/else block lists.
    If {
        pred: PredProg,
        then_blocks: Vec<RtBlock>,
        else_blocks: Vec<RtBlock>,
        lines: (usize, usize),
    },
    /// (Par)for loop: bound programs, body blocks, and the statically
    /// known trip count when available.
    For {
        var: String,
        from: PredProg,
        to: PredProg,
        by: Option<PredProg>,
        body: Vec<RtBlock>,
        parfor: bool,
        known_trip: Option<f64>,
        lines: (usize, usize),
    },
    /// While loop: predicate program plus body blocks.
    While { pred: PredProg, body: Vec<RtBlock>, lines: (usize, usize) },
    /// Call to a runtime function, binding `args` to formals and
    /// function outputs back to `outputs`.
    FCall { fname: String, args: Vec<String>, outputs: Vec<String>, lines: (usize, usize) },
}

/// A runtime function.
#[derive(Clone, Debug)]
pub struct RtFunction {
    /// Formal parameter names.
    pub params: Vec<String>,
    /// Output variable names.
    pub outputs: Vec<String>,
    /// Function body blocks.
    pub blocks: Vec<RtBlock>,
}

/// A complete runtime program.
#[derive(Clone, Debug, Default)]
pub struct RtProgram {
    /// Top-level program blocks in program order.
    pub blocks: Vec<RtBlock>,
    /// Runtime functions by name.
    pub funcs: BTreeMap<String, RtFunction>,
}

impl RtProgram {
    /// Count (CP, MR, Spark) instructions — the `size CP/MR = 34/0`
    /// header of Figures 2 and 3, extended with the Spark backend.
    pub fn size3(&self) -> (usize, usize, usize) {
        fn count(blocks: &[RtBlock], cp: &mut usize, mr: &mut usize, sp: &mut usize) {
            let count_insts = |insts: &[Instr], cp: &mut usize, mr: &mut usize, sp: &mut usize| {
                for i in insts {
                    match i {
                        Instr::MrJob(_) => *mr += 1,
                        Instr::SparkJob(_) => *sp += 1,
                        Instr::RmVar { .. } => {}
                        _ => *cp += 1,
                    }
                }
            };
            for b in blocks {
                match b {
                    RtBlock::Generic { insts, .. } => count_insts(insts, cp, mr, sp),
                    RtBlock::If { pred, then_blocks, else_blocks, .. } => {
                        count_insts(&pred.insts, cp, mr, sp);
                        count(then_blocks, cp, mr, sp);
                        count(else_blocks, cp, mr, sp);
                    }
                    RtBlock::For { from, to, by, body, .. } => {
                        count_insts(&from.insts, cp, mr, sp);
                        count_insts(&to.insts, cp, mr, sp);
                        if let Some(by) = by {
                            count_insts(&by.insts, cp, mr, sp);
                        }
                        count(body, cp, mr, sp);
                    }
                    RtBlock::While { pred, body, .. } => {
                        count_insts(&pred.insts, cp, mr, sp);
                        count(body, cp, mr, sp);
                    }
                    RtBlock::FCall { .. } => *cp += 1,
                }
            }
        }
        let (mut cp, mut mr, mut sp) = (0, 0, 0);
        count(&self.blocks, &mut cp, &mut mr, &mut sp);
        for f in self.funcs.values() {
            count(&f.blocks, &mut cp, &mut mr, &mut sp);
        }
        (cp, mr, sp)
    }

    /// Count (CP, MR) instructions — the `size CP/MR = 34/0` header of
    /// Figures 2 and 3 (Spark jobs are not included; see [`Self::size3`]).
    pub fn size(&self) -> (usize, usize) {
        let (cp, mr, _) = self.size3();
        (cp, mr)
    }

    /// Total number of MR jobs in the program.
    pub fn mr_job_count(&self) -> usize {
        self.size3().1
    }

    /// Total number of Spark jobs in the program.
    pub fn spark_job_count(&self) -> usize {
        self.size3().2
    }

    /// Total distributed jobs (MR + Spark) — the sweep table's job column.
    pub fn dist_job_count(&self) -> usize {
        let (_, mr, sp) = self.size3();
        mr + sp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operand_rendering_matches_systemml() {
        assert_eq!(Operand::Mat("X".into()).render(), "X.MATRIX.DOUBLE");
        assert_eq!(
            Operand::Lit(Lit::Int(0)).render(),
            "0.SCALAR.INT.true"
        );
        assert_eq!(
            Operand::Lit(Lit::Double(0.001)).render(),
            "0.001.SCALAR.DOUBLE.true"
        );
        assert_eq!(
            Operand::Scalar("intercept".into(), ValueType::Int).render(),
            "intercept.SCALAR.INT"
        );
    }

    #[test]
    fn opcodes_match_figures() {
        assert_eq!(CpOp::Tsmm { left: true }.code(), "tsmm");
        assert_eq!(CpOp::MatMult.code(), "ba+*");
        assert_eq!(CpOp::Transpose.code(), "r'");
        assert_eq!(CpOp::Diag.code(), "rdiag");
        assert_eq!(MrOp::Agg { kahan: true }.code(), "ak+");
        assert_eq!(MrOp::MapMM { right_part: true }.code(), "mapmm");
        assert_eq!(JobType::Gmr.name(), "GMR");
    }

    #[test]
    fn program_size_counts_cp_and_mr() {
        let mut prog = RtProgram::default();
        prog.blocks.push(RtBlock::Generic {
            insts: vec![
                Instr::AssignVar { lit: Lit::Int(1), var: "a".into() },
                Instr::RmVar { vars: vec!["a".into()] },
                Instr::MrJob(MrJob {
                    job_type: JobType::Gmr,
                    inputs: vec![],
                    dcache: vec![],
                    map_insts: vec![],
                    shuffle_insts: vec![],
                    agg_insts: vec![],
                    other_insts: vec![],
                    outputs: vec![],
                    result_indices: vec![],
                    num_reducers: 12,
                    replication: 1,
                }),
            ],
            lines: (1, 1),
            recompile: false,
        });
        assert_eq!(prog.size(), (1, 1)); // rmvar not counted
    }

    #[test]
    fn backend_labels_round_trip() {
        for b in ExecBackend::all() {
            assert_eq!(ExecBackend::parse(b.name()), Some(b));
        }
        assert_eq!(ExecBackend::parse("SPARK"), Some(ExecBackend::Spark));
        assert_eq!(ExecBackend::parse("hadoop"), None);
        assert_eq!(ExecBackend::default(), ExecBackend::Mr);
    }

    #[test]
    fn spark_jobs_counted_separately() {
        let mc = MatrixCharacteristics::new(10, 10, 10, -1);
        let mut prog = RtProgram::default();
        prog.blocks.push(RtBlock::Generic {
            insts: vec![Instr::SparkJob(SparkJob {
                inputs: vec!["X".into()],
                broadcasts: vec![],
                stages: vec![SparkStage {
                    wide: false,
                    insts: vec![MrInst { op: MrOp::Transpose, inputs: vec![0], output: 1, mc }],
                }],
                outputs: vec!["out".into()],
                result_indices: vec![1],
                num_reducers: 12,
                replication: 1,
            })],
            lines: (1, 1),
            recompile: false,
        });
        assert_eq!(prog.size3(), (0, 0, 1));
        assert_eq!(prog.size(), (0, 0));
        assert_eq!(prog.spark_job_count(), 1);
        assert_eq!(prog.dist_job_count(), 1);
    }

    #[test]
    fn as_mr_job_classifies_stages_by_phase() {
        let mc = MatrixCharacteristics::new(10, 10, 10, -1);
        let job = SparkJob {
            inputs: vec!["X".into(), "y".into()],
            broadcasts: vec!["y".into()],
            stages: vec![
                SparkStage {
                    wide: false,
                    insts: vec![MrInst {
                        op: MrOp::MapMM { right_part: false },
                        inputs: vec![0, 1],
                        output: 2,
                        mc,
                    }],
                },
                SparkStage {
                    wide: true,
                    insts: vec![MrInst {
                        op: MrOp::Agg { kahan: true },
                        inputs: vec![2],
                        output: 3,
                        mc,
                    }],
                },
            ],
            outputs: vec!["out".into()],
            result_indices: vec![3],
            num_reducers: 12,
            replication: 1,
        };
        let mr = job.as_mr_job();
        assert_eq!(mr.map_insts.len(), 1);
        assert_eq!(mr.agg_insts.len(), 1);
        assert_eq!(mr.dcache, vec!["y".to_string()]);
        assert_eq!(mr.result_indices, vec![3]);
    }
}
