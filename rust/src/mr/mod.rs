//! Deterministic MapReduce cluster simulator — the substitute for the
//! paper's Hadoop testbed (DESIGN.md §Reproduction bands).
//!
//! An [`crate::rtprog::MrJob`] executes in faithful phases:
//!
//! 1. **Input splits**: each non-broadcast input is split into
//!    `⌈M'(X)/hdfs_block⌉` row ranges (the simulator's HDFS model).
//! 2. **Map tasks** (multi-threaded): each task runs the map-instruction
//!    chains rooted at its input split; broadcast inputs are served in
//!    full (distributed-cache model) and sliced by the task's key range
//!    where the operator requires alignment (mapmm, append).
//! 3. **Combine/shuffle**: per-task partials are accounted as shuffle
//!    volume.
//! 4. **Reduce**: `ak+` aggregations sum partials (Kahan), cpmm/rmm
//!    compute the cross-product join, reduce-side binaries join blocks.
//! 5. **Outputs** materialise into the executor's symbol table.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::error::{anyhow, bail, Result};

use crate::cp::interp::{agg_exec, bin_fn, un_fn, AggResult, Executor};
use crate::matrix::{ops, DenseMatrix};
use crate::rtprog::{MrInst, MrJob, MrOp};

/// Statistics of one simulated job.
#[derive(Clone, Debug, Default)]
pub struct MrRunReport {
    pub map_tasks: usize,
    pub reduce_groups: usize,
    pub shuffle_bytes: f64,
    pub input_bytes: f64,
    /// Injected map-task attempts that failed (each failed attempt ran
    /// the task body and discarded the result, like a re-executed
    /// Hadoop attempt). Zero unless fault injection is armed.
    pub failed_attempts: usize,
    /// Injected straggler tasks.
    pub stragglers: usize,
    /// Speculative backup copies launched for stragglers.
    pub speculative_copies: usize,
    /// Simulated retry-backoff seconds accrued to the delay ledger
    /// (accounted so measured times reflect waiting, never slept).
    pub fault_delay_secs: f64,
}

/// Deterministic per-task fault schedule, drawn from the counter-mode
/// RNG before any worker thread starts — the schedule (and therefore
/// the simulated result and every counter) is bitwise-identical for a
/// fixed `(seed, job)` regardless of `k_local` or thread interleaving.
#[derive(Clone, Copy, Debug, Default)]
struct TaskFaults {
    /// Failed attempts: the task body runs and its output is discarded.
    retries: usize,
    /// Straggler tail re-executions (discarded re-runs that stretch the
    /// task's wall time by ~`straggler_slowdown`×, or the one backup
    /// copy under speculative execution).
    extra_runs: usize,
    straggler: bool,
    speculative: bool,
    /// Retry-backoff seconds (base·2^(a−1) after the a-th failure).
    delay_secs: f64,
}

/// Draw the fault schedule for `n_tasks` map tasks of job `job`.
///
/// Attempt keys: `0` is reserved for the straggler draw; failure draws
/// use attempts `1..max_attempts`. The final attempt always completes —
/// the truncated-geometric expectation the cost model prices,
/// `E[attempts] = (1−p^m)/(1−p)`, is exactly the mean of this
/// success-by-the-last-attempt process, so measured and estimated
/// retry counts agree in distribution.
fn fault_schedule(
    fp: &crate::conf::FaultProfile,
    fail_p: f64,
    seed: u64,
    job: u64,
    n_tasks: usize,
) -> Vec<TaskFaults> {
    let mut schedule = vec![TaskFaults::default(); n_tasks];
    if fp.is_none() || (fail_p <= 0.0 && fp.straggler_frac <= 0.0) {
        return schedule;
    }
    for (t, tf) in schedule.iter_mut().enumerate() {
        for a in 1..fp.max_attempts as u64 {
            if crate::util::rng::fault_roll(seed, job, t as u64, a) < fail_p {
                tf.retries += 1;
                tf.delay_secs += fp.backoff_base * 2f64.powi(tf.retries as i32 - 1);
            } else {
                break;
            }
        }
        if fp.straggler_frac > 0.0
            && crate::util::rng::fault_roll(seed, job, t as u64, 0) < fp.straggler_frac
        {
            tf.straggler = true;
            if fp.speculative {
                // One backup copy; the effective slowdown is capped at
                // 2× (original + backup racing), as the cost model's
                // speculative tail assumes.
                tf.speculative = true;
                tf.extra_runs = 1;
            } else {
                tf.extra_runs = (fp.straggler_slowdown.ceil() as usize).saturating_sub(1);
            }
        }
    }
    schedule
}

/// Placement of a per-task partial in the final result.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Slice {
    /// Rows `r0..r1` of the full result.
    Rows(usize, usize),
    /// Columns `r0..r1` of the full result (after transpose).
    Cols(usize, usize),
    /// A full-shape partial that must be summed with its peers.
    Partial,
    /// Already the full result.
    Full,
}

type Partials = HashMap<usize, Vec<(Slice, DenseMatrix)>>;

/// Simulate one MR job against the executor's symbol table.
pub fn simulate(job: &MrJob, exec: &mut Executor) -> Result<MrRunReport> {
    let mut report = MrRunReport::default();

    // ---- fetch inputs
    let mut inputs: Vec<Arc<DenseMatrix>> = Vec::new();
    for v in &job.inputs {
        let m = exec
            .symbols
            .matrix_data(v, &mut exec.pool)
            .map_err(|e| anyhow!("MR input '{v}': {e}"))?;
        report.input_bytes += (m.values.len() * 8) as f64;
        inputs.push(m);
    }
    let dcache: Vec<bool> = job.inputs.iter().map(|v| job.dcache.contains(v)).collect();

    // ---- assign map instructions to driving inputs
    let n_in = inputs.len();
    let mut driver: HashMap<usize, usize> = HashMap::new(); // out idx -> input idx
    let mut inst_driver: Vec<Option<usize>> = Vec::new();
    for inst in &job.map_insts {
        let d = inst.inputs.iter().find_map(|&i| {
            if i < n_in {
                if dcache[i] {
                    None
                } else {
                    Some(i)
                }
            } else {
                driver.get(&i).copied()
            }
        });
        if let Some(d) = d {
            driver.insert(inst.output, d);
        }
        inst_driver.push(d);
    }

    // ---- map phase
    let hdfs_block = exec.cc.hdfs_block_bytes;
    let threads = exec.cc.k_local.max(1);
    let partials: Mutex<Partials> = Mutex::new(HashMap::new());
    let mut tasks: Vec<(usize, usize, usize)> = Vec::new(); // (input, r0, r1)
    for (i, m) in inputs.iter().enumerate() {
        if dcache[i] {
            continue;
        }
        // ops like diag / datagen run once; skip inputs that drive nothing
        if !inst_driver.iter().any(|d| *d == Some(i)) {
            continue;
        }
        let ser = (m.values.len() * 8) as f64;
        let splits = (ser / hdfs_block).ceil().max(1.0) as usize;
        let rows_per = (m.rows + splits - 1) / splits.max(1);
        let mut r0 = 0;
        while r0 < m.rows {
            let r1 = (r0 + rows_per).min(m.rows);
            tasks.push((i, r0, r1));
            r0 = r1;
        }
    }
    report.map_tasks = tasks.len();

    // ---- fault schedule (drawn before any thread runs; see TaskFaults)
    let fail_p =
        if exec.fault_spark { exec.fault.spark_fail_p } else { exec.fault.mr_fail_p };
    let job_id = exec.fault_jobs;
    exec.fault_jobs += 1;
    let schedule = fault_schedule(&exec.fault, fail_p, exec.fault_seed, job_id, tasks.len());
    for tf in &schedule {
        report.failed_attempts += tf.retries;
        report.stragglers += tf.straggler as usize;
        report.speculative_copies += tf.speculative as usize;
        report.fault_delay_secs += tf.delay_secs;
    }

    // full-input (non-sliceable) map instructions: datagen, diag
    let mut pre_full: Partials = HashMap::new();
    for inst in &job.map_insts {
        match &inst.op {
            MrOp::DataGen { min, max, sparsity, seed, rows, cols } => {
                let m = if min == max {
                    DenseMatrix::filled((*rows).max(0) as usize, (*cols).max(0) as usize, *min)
                } else {
                    DenseMatrix::rand(
                        (*rows).max(0) as usize,
                        (*cols).max(0) as usize,
                        *min,
                        *max,
                        *sparsity,
                        if *seed < 0 { 0xC0FFEE } else { *seed as u64 },
                    )
                };
                pre_full.entry(inst.output).or_default().push((Slice::Full, m));
            }
            MrOp::Diag => {
                let src = inst.inputs[0];
                if src < n_in {
                    let m = ops::diag(&inputs[src]);
                    pre_full.entry(inst.output).or_default().push((Slice::Full, m));
                }
            }
            _ => {}
        }
    }
    partials.lock().unwrap().extend(pre_full);

    // run tasks across a worker pool
    let work: Vec<((usize, usize, usize), TaskFaults)> =
        tasks.iter().copied().zip(schedule).collect();
    let chunk = (work.len() + threads - 1) / threads.max(1);
    if !work.is_empty() {
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::new();
            for tchunk in work.chunks(chunk.max(1)) {
                let inputs = &inputs;
                let partials = &partials;
                let job_ref = job;
                let inst_driver = &inst_driver;
                handles.push(s.spawn(move || -> Result<()> {
                    for &((input, r0, r1), tf) in tchunk {
                        // Failed attempts and straggler tail copies run
                        // the task body for real and discard the output
                        // — wall time inflates, the dataflow does not.
                        for _ in 0..tf.retries + tf.extra_runs {
                            let scrap: Mutex<Partials> = Mutex::new(HashMap::new());
                            run_map_task(job_ref, inputs, inst_driver, input, r0, r1, &scrap)?;
                        }
                        run_map_task(job_ref, inputs, inst_driver, input, r0, r1, partials)?;
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().map_err(|_| anyhow!("map task panicked"))??;
            }
            Ok(())
        })?;
    }
    let mut partials = partials.into_inner().unwrap();

    // ---- resolve full matrices per byte index (inputs or assembled)
    let mut resolved: HashMap<usize, DenseMatrix> = HashMap::new();
    for (i, m) in inputs.iter().enumerate() {
        resolved.insert(i, (**m).clone());
    }

    // shuffle volume from per-task partials feeding aggregations
    for agg in &job.agg_insts {
        if let Some(parts) = partials.get(&agg.inputs[0]) {
            report.shuffle_bytes +=
                parts.iter().map(|(_, m)| (m.values.len() * 8) as f64).sum::<f64>();
        }
    }

    // ---- reduce phase: shuffle joins (cpmm/rmm)
    for sh in &job.shuffle_insts {
        let a = assemble(sh.inputs[0], &mut partials, &resolved, sh)?;
        let b = assemble(sh.inputs[1], &mut partials, &resolved, sh)?;
        report.shuffle_bytes += ((a.values.len() + b.values.len()) * 8) as f64;
        let out = match &sh.op {
            MrOp::Cpmm | MrOp::Rmm => ops::matmult(&a, &b, threads),
            other => bail!("unsupported shuffle op {other:?}"),
        };
        resolved.insert(sh.output, out);
    }

    // ---- reduce phase: aggregations and reduce-side joins
    for agg in &job.agg_insts {
        let out = match &agg.op {
            MrOp::Agg { .. } => {
                let idx = agg.inputs[0];
                if let Some(parts) = partials.remove(&idx) {
                    sum_partials(parts, &agg_shape(agg))?
                } else if let Some(full) = resolved.get(&idx) {
                    // aggregation over a prior job's materialised partials
                    // (our cpmm simulation already summed them): identity
                    full.clone()
                } else {
                    bail!("aggregation input {idx} unavailable")
                }
            }
            // matrix-matrix binary executed reduce-side (block join)
            MrOp::Binary(op) => {
                let a = assemble(agg.inputs[0], &mut partials, &resolved, agg)?;
                let b = assemble(agg.inputs[1], &mut partials, &resolved, agg)?;
                report.shuffle_bytes += ((a.values.len() + b.values.len()) * 8) as f64;
                ops::ewise(&a, &b, bin_fn(*op)?)
            }
            other => bail!("unsupported agg op {other:?}"),
        };
        resolved.insert(agg.output, out);
        report.reduce_groups += 1;
    }

    // ---- reduce-side binaries
    for ot in &job.other_insts {
        let a = assemble(ot.inputs[0], &mut partials, &resolved, ot)?;
        let b = assemble(ot.inputs[1], &mut partials, &resolved, ot)?;
        report.shuffle_bytes += ((a.values.len() + b.values.len()) * 8) as f64;
        let MrOp::Binary(op) = &ot.op else { bail!("unsupported other inst {:?}", ot.op) };
        let out = ops::ewise(&a, &b, bin_fn(*op)?);
        resolved.insert(ot.output, out);
    }

    // ---- materialise outputs
    let blocksize = exec.cfg.blocksize;
    for (label, &ri) in job.outputs.iter().zip(&job.result_indices) {
        let m = if let Some(m) = resolved.remove(&ri) {
            m
        } else {
            let inst = job
                .all_insts()
                .find(|i| i.output == ri)
                .ok_or_else(|| anyhow!("no producer for result index {ri}"))?
                .clone();
            assemble(ri, &mut partials, &resolved, &inst)?
        };
        exec.symbols.bind_matrix(label, Arc::new(m), blocksize, &mut exec.pool)?;
    }
    Ok(report)
}

/// Final shape of an aggregation (from the instruction's characteristics).
fn agg_shape(inst: &MrInst) -> (usize, usize) {
    (inst.mc.rows.max(0) as usize, inst.mc.cols.max(0) as usize)
}

/// Execute all map instructions driven by `input` for one split.
fn run_map_task(
    job: &MrJob,
    inputs: &[Arc<DenseMatrix>],
    inst_driver: &[Option<usize>],
    input: usize,
    r0: usize,
    r1: usize,
    partials: &Mutex<Partials>,
) -> Result<()> {
    let n_in = inputs.len();
    // local values: byte index -> (slice placement, data)
    let mut local: HashMap<usize, (Slice, DenseMatrix)> = HashMap::new();
    let src = &inputs[input];
    let slice = submatrix(src, r0, r1);
    local.insert(input, (Slice::Rows(r0, r1), slice));

    let mut out: Vec<(usize, Slice, DenseMatrix)> = Vec::new();
    for (k, inst) in job.map_insts.iter().enumerate() {
        if inst_driver[k] != Some(input) {
            continue;
        }
        let get = |idx: usize,
                   local: &HashMap<usize, (Slice, DenseMatrix)>|
         -> Result<(Slice, DenseMatrix)> {
            if let Some((s, m)) = local.get(&idx) {
                return Ok((*s, m.clone()));
            }
            if idx < n_in {
                return Ok((Slice::Full, (*inputs[idx]).clone()));
            }
            bail!("map input {idx} not available in task")
        };
        let (res_slice, res) = match &inst.op {
            MrOp::Tsmm { left } => {
                let (_, x) = get(inst.inputs[0], &local)?;
                let r = if *left { ops::tsmm_left(&x, 1) } else { ops::tsmm_left(&ops::transpose(&x), 1) };
                (Slice::Partial, r)
            }
            MrOp::Transpose => {
                let (s, x) = get(inst.inputs[0], &local)?;
                let flipped = match s {
                    Slice::Rows(a, b) => Slice::Cols(a, b),
                    Slice::Cols(a, b) => Slice::Rows(a, b),
                    other => other,
                };
                (flipped, ops::transpose(&x))
            }
            MrOp::MapMM { .. } => {
                let (sa, a) = get(inst.inputs[0], &local)?;
                let (_, bc) = get(inst.inputs[1], &local)?;
                // align the broadcast with the task's contraction range
                let out = match sa {
                    Slice::Cols(a0, a1) => {
                        // a = t(X) column slice: multiply with bc rows a0..a1
                        let bslice = submatrix(&bc, a0, a1);
                        ops::matmult(&a, &bslice, 1)
                    }
                    Slice::Rows(_, _) | Slice::Full | Slice::Partial => {
                        // broadcast-left: bc columns align with a's rows —
                        // conservative full multiply on the slice
                        ops::matmult(&bc, &a, 1)
                    }
                };
                (Slice::Partial, out)
            }
            MrOp::ScalarBin { op, scalar, scalar_left, .. } => {
                let (s, x) = get(inst.inputs[0], &local)?;
                let f = bin_fn(*op)?;
                let r = if *scalar_left {
                    ops::ewise_scalar(&x, *scalar, |a, b| f(b, a))
                } else {
                    ops::ewise_scalar(&x, *scalar, f)
                };
                (s, r)
            }
            MrOp::Unary(op) => {
                let (s, x) = get(inst.inputs[0], &local)?;
                (s, ops::unary(&x, un_fn(*op)?))
            }
            MrOp::AggUnaryMap(op, dir) => {
                let (s, x) = get(inst.inputs[0], &local)?;
                let r = match agg_exec(*op, *dir, &x)? {
                    AggResult::Scalar(v) => DenseMatrix::from_vec(1, 1, vec![v]),
                    AggResult::Matrix(m) => m,
                };
                // row-direction partials are positioned; expand to full rows
                let positioned = match (dir, s) {
                    (crate::ir::AggDir::Row, Slice::Rows(a0, _)) => {
                        let total = inst.mc.rows.max(r.rows as i64) as usize;
                        let mut full = DenseMatrix::zeros(total, r.cols);
                        for i in 0..r.rows {
                            for c in 0..r.cols {
                                full.set(a0 + i, c, r.get(i, c));
                            }
                        }
                        full
                    }
                    _ => r,
                };
                (Slice::Partial, positioned)
            }
            MrOp::Append { .. } => {
                let (s, x) = get(inst.inputs[0], &local)?;
                let (_, bc) = get(inst.inputs[1], &local)?;
                let bslice = match s {
                    Slice::Rows(a0, a1) => submatrix(&bc, a0, a1),
                    _ => bc.clone(),
                };
                (s, ops::cbind(&x, &bslice))
            }
            MrOp::Diag | MrOp::DataGen { .. } => continue, // handled pre-task
            other => bail!("unsupported map op {other:?}"),
        };
        local.insert(inst.output, (res_slice, res.clone()));
        out.push((inst.output, res_slice, res));
    }
    let mut p = partials.lock().unwrap();
    for (idx, s, m) in out {
        p.entry(idx).or_default().push((s, m));
    }
    Ok(())
}

/// Row sub-slice copy.
fn submatrix(m: &DenseMatrix, r0: usize, r1: usize) -> DenseMatrix {
    let r1 = r1.min(m.rows);
    DenseMatrix::from_vec(r1 - r0, m.cols, m.values[r0 * m.cols..r1 * m.cols].to_vec())
}

/// Sum full-shape partials (combiner + reducer `ak+`).
fn sum_partials(parts: Vec<(Slice, DenseMatrix)>, _shape: &(usize, usize)) -> Result<DenseMatrix> {
    let mut iter = parts.into_iter();
    let (_, mut acc) = iter.next().ok_or_else(|| anyhow!("no partials to aggregate"))?;
    for (_, p) in iter {
        if p.rows != acc.rows || p.cols != acc.cols {
            bail!("partial shape mismatch {}x{} vs {}x{}", p.rows, p.cols, acc.rows, acc.cols);
        }
        for (a, b) in acc.values.iter_mut().zip(&p.values) {
            *a += b;
        }
    }
    Ok(acc)
}

/// Assemble the full matrix for a byte index from positional partials.
fn assemble(
    idx: usize,
    partials: &mut Partials,
    resolved: &HashMap<usize, DenseMatrix>,
    inst: &MrInst,
) -> Result<DenseMatrix> {
    if let Some(m) = resolved.get(&idx) {
        return Ok(m.clone());
    }
    let parts = partials
        .remove(&idx)
        .ok_or_else(|| anyhow!("no data for byte index {idx}"))?;
    // positional assembly (Rows/Cols) or partial summation
    if parts.iter().all(|(s, _)| matches!(s, Slice::Partial | Slice::Full)) {
        return sum_partials(parts, &agg_shape(inst));
    }
    let rows: usize = match parts[0].0 {
        Slice::Cols(..) => parts[0].1.rows,
        _ => parts.iter().map(|(s, m)| match s {
            Slice::Rows(_, b) => *b,
            _ => m.rows,
        }).max().unwrap_or(0),
    };
    let cols: usize = match parts[0].0 {
        Slice::Cols(..) => parts.iter().map(|(s, _)| match s {
            Slice::Cols(_, b) => *b,
            _ => 0,
        }).max().unwrap_or(0),
        _ => parts[0].1.cols,
    };
    let mut full = DenseMatrix::zeros(rows, cols);
    for (s, m) in parts {
        match s {
            Slice::Rows(a0, _) => {
                for i in 0..m.rows {
                    for c in 0..m.cols {
                        full.set(a0 + i, c, m.get(i, c));
                    }
                }
            }
            Slice::Cols(a0, _) => {
                for i in 0..m.rows {
                    for c in 0..m.cols {
                        full.set(i, a0 + c, m.get(i, c));
                    }
                }
            }
            Slice::Full | Slice::Partial => {
                for i in 0..m.rows.min(full.rows) {
                    for c in 0..m.cols.min(full.cols) {
                        full.set(i, c, m.get(i, c));
                    }
                }
            }
        }
    }
    Ok(full)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conf::{ClusterConfig, SystemConfig};
    use crate::ir::{BinOp, Lit};
    use crate::matrix::Format;
    use crate::matrix::MatrixCharacteristics;
    use crate::rtprog::JobType;

    fn test_exec<'a>(
        cfg: &'a SystemConfig,
        cc: &'a ClusterConfig,
    ) -> Executor<'a> {
        let scratch = std::env::temp_dir().join(format!("sysds_mr_{}", std::process::id()));
        Executor::new(cfg, cc, None, scratch)
    }

    fn tiny_cluster() -> ClusterConfig {
        let mut cc = ClusterConfig::local(4, 256.0 * 1024.0 * 1024.0);
        cc.hdfs_block_bytes = 16.0 * 1024.0; // force many splits
        cc
    }

    fn bind(exec: &mut Executor, name: &str, m: DenseMatrix) {
        exec.symbols
            .bind_matrix(name, Arc::new(m), 1000, &mut exec.pool)
            .unwrap();
    }

    fn mc(r: i64, c: i64) -> MatrixCharacteristics {
        MatrixCharacteristics::new(r, c, 1000, -1)
    }

    #[test]
    fn simulated_tsmm_job_matches_native() {
        let cfg = SystemConfig::default();
        let cc = tiny_cluster();
        let mut exec = test_exec(&cfg, &cc);
        let x = DenseMatrix::rand(200, 30, -1.0, 1.0, 1.0, 5);
        bind(&mut exec, "X", x.clone());
        exec.exec_inst(&crate::rtprog::Instr::CreateVar {
            var: "out".into(),
            path: String::new(),
            temp: true,
            format: Format::BinaryBlock,
            mc: mc(30, 30),
        })
        .unwrap();
        let job = MrJob {
            job_type: JobType::Gmr,
            inputs: vec!["X".into()],
            dcache: vec![],
            map_insts: vec![MrInst {
                op: MrOp::Tsmm { left: true },
                inputs: vec![0],
                output: 1,
                mc: mc(30, 30),
            }],
            shuffle_insts: vec![],
            agg_insts: vec![MrInst {
                op: MrOp::Agg { kahan: true },
                inputs: vec![1],
                output: 2,
                mc: mc(30, 30),
            }],
            other_insts: vec![],
            outputs: vec!["out".into()],
            result_indices: vec![2],
            num_reducers: 4,
            replication: 1,
        };
        let report = simulate(&job, &mut exec).unwrap();
        assert!(report.map_tasks > 1, "splits: {}", report.map_tasks);
        let got = exec.symbols.matrix_data("out", &mut exec.pool).unwrap();
        let expect = ops::tsmm_left(&x, 2);
        assert!(got.max_abs_diff(&expect) < 1e-9);
    }

    fn tsmm_job() -> MrJob {
        MrJob {
            job_type: JobType::Gmr,
            inputs: vec!["X".into()],
            dcache: vec![],
            map_insts: vec![MrInst {
                op: MrOp::Tsmm { left: true },
                inputs: vec![0],
                output: 1,
                mc: mc(30, 30),
            }],
            shuffle_insts: vec![],
            agg_insts: vec![MrInst {
                op: MrOp::Agg { kahan: true },
                inputs: vec![1],
                output: 2,
                mc: mc(30, 30),
            }],
            other_insts: vec![],
            outputs: vec!["out".into()],
            result_indices: vec![2],
            num_reducers: 4,
            replication: 1,
        }
    }

    #[test]
    fn fault_injection_replays_bitwise_across_thread_counts() {
        let cfg = SystemConfig::default();
        let x = DenseMatrix::rand(200, 30, -1.0, 1.0, 1.0, 5);
        let job = tsmm_job();
        let mut runs = Vec::new();
        for k_local in [1usize, 4] {
            let mut cc = ClusterConfig::local(k_local, 256.0 * 1024.0 * 1024.0);
            cc.hdfs_block_bytes = 16.0 * 1024.0;
            let mut exec = test_exec(&cfg, &cc);
            exec.set_fault_injection(crate::conf::FaultProfile::chaos(), 42);
            bind(&mut exec, "X", x.clone());
            exec.exec_inst(&crate::rtprog::Instr::CreateVar {
                var: "out".into(),
                path: String::new(),
                temp: true,
                format: Format::BinaryBlock,
                mc: mc(30, 30),
            })
            .unwrap();
            let report = simulate(&job, &mut exec).unwrap();
            let out = exec.symbols.matrix_data("out", &mut exec.pool).unwrap();
            runs.push((report, (*out).clone()));
        }
        let (r1, m1) = &runs[0];
        let (r4, m4) = &runs[1];
        // schedule is drawn before the pool runs: counters and delay
        // ledger are identical no matter how many workers execute it
        assert_eq!(r1.failed_attempts, r4.failed_attempts);
        assert_eq!(r1.stragglers, r4.stragglers);
        assert_eq!(r1.speculative_copies, r4.speculative_copies);
        assert_eq!(r1.fault_delay_secs.to_bits(), r4.fault_delay_secs.to_bits());
        // chaos has a 10% straggler fraction and 8% failure rate over
        // many splits: a deterministic seed=42 draw hits at least one
        assert!(
            r1.failed_attempts + r1.stragglers > 0,
            "chaos @ seed 42 drew no faults over {} tasks",
            r1.map_tasks
        );
        // and the simulated result is unchanged by the injected faults
        // (partials sum in completion order, so equality is numeric,
        // not bitwise — same tolerance as the fault-free tests)
        assert!(m1.max_abs_diff(m4) < 1e-9);
        let expect = ops::tsmm_left(&x, 2);
        assert!(m1.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn disarmed_fault_injection_reports_zero_faults() {
        let cfg = SystemConfig::default();
        let cc = tiny_cluster();
        let mut exec = test_exec(&cfg, &cc);
        let x = DenseMatrix::rand(200, 30, -1.0, 1.0, 1.0, 5);
        bind(&mut exec, "X", x.clone());
        exec.exec_inst(&crate::rtprog::Instr::CreateVar {
            var: "out".into(),
            path: String::new(),
            temp: true,
            format: Format::BinaryBlock,
            mc: mc(30, 30),
        })
        .unwrap();
        let report = simulate(&tsmm_job(), &mut exec).unwrap();
        assert_eq!(report.failed_attempts, 0);
        assert_eq!(report.stragglers, 0);
        assert_eq!(report.speculative_copies, 0);
        assert_eq!(report.fault_delay_secs, 0.0);
        let got = exec.symbols.matrix_data("out", &mut exec.pool).unwrap();
        assert!(got.max_abs_diff(&ops::tsmm_left(&x, 2)) < 1e-9);
    }

    #[test]
    fn simulated_figure3_job_matches_native() {
        // tsmm + r' + mapmm with broadcast y in one GMR job.
        let cfg = SystemConfig::default();
        let cc = tiny_cluster();
        let mut exec = test_exec(&cfg, &cc);
        let x = DenseMatrix::rand(300, 20, -1.0, 1.0, 1.0, 7);
        let y = DenseMatrix::rand(300, 1, -1.0, 1.0, 1.0, 8);
        bind(&mut exec, "X", x.clone());
        bind(&mut exec, "ypart", y.clone());
        for (name, m) in [("outA", mc(20, 20)), ("outb", mc(20, 1))] {
            exec.exec_inst(&crate::rtprog::Instr::CreateVar {
                var: name.into(),
                path: String::new(),
                temp: true,
                format: Format::BinaryBlock,
                mc: m,
            })
            .unwrap();
        }
        let job = MrJob {
            job_type: JobType::Gmr,
            inputs: vec!["X".into(), "ypart".into()],
            dcache: vec!["ypart".into()],
            map_insts: vec![
                MrInst { op: MrOp::Tsmm { left: true }, inputs: vec![0], output: 2, mc: mc(20, 20) },
                MrInst { op: MrOp::Transpose, inputs: vec![0], output: 3, mc: mc(20, 300) },
                MrInst {
                    op: MrOp::MapMM { right_part: true },
                    inputs: vec![3, 1],
                    output: 4,
                    mc: mc(20, 1),
                },
            ],
            shuffle_insts: vec![],
            agg_insts: vec![
                MrInst { op: MrOp::Agg { kahan: true }, inputs: vec![2], output: 5, mc: mc(20, 20) },
                MrInst { op: MrOp::Agg { kahan: true }, inputs: vec![4], output: 6, mc: mc(20, 1) },
            ],
            other_insts: vec![],
            outputs: vec!["outA".into(), "outb".into()],
            result_indices: vec![5, 6],
            num_reducers: 4,
            replication: 1,
        };
        simulate(&job, &mut exec).unwrap();
        let got_a = exec.symbols.matrix_data("outA", &mut exec.pool).unwrap();
        let got_b = exec.symbols.matrix_data("outb", &mut exec.pool).unwrap();
        let xt = ops::transpose(&x);
        assert!(got_a.max_abs_diff(&ops::tsmm_left(&x, 2)) < 1e-9);
        assert!(got_b.max_abs_diff(&ops::matmult_st(&xt, &y)) < 1e-9);
    }

    #[test]
    fn simulated_cpmm_matches_native() {
        let cfg = SystemConfig::default();
        let cc = tiny_cluster();
        let mut exec = test_exec(&cfg, &cc);
        let x = DenseMatrix::rand(150, 25, -1.0, 1.0, 1.0, 9);
        bind(&mut exec, "X", x.clone());
        exec.exec_inst(&crate::rtprog::Instr::CreateVar {
            var: "out".into(),
            path: String::new(),
            temp: true,
            format: Format::BinaryBlock,
            mc: mc(25, 25),
        })
        .unwrap();
        // MMCJ: r' (map) + cpmm (shuffle)
        let job = MrJob {
            job_type: JobType::Mmcj,
            inputs: vec!["X".into()],
            dcache: vec![],
            map_insts: vec![MrInst {
                op: MrOp::Transpose,
                inputs: vec![0],
                output: 1,
                mc: mc(25, 150),
            }],
            shuffle_insts: vec![MrInst {
                op: MrOp::Cpmm,
                inputs: vec![1, 0],
                output: 2,
                mc: mc(25, 25),
            }],
            agg_insts: vec![],
            other_insts: vec![],
            outputs: vec!["out".into()],
            result_indices: vec![2],
            num_reducers: 4,
            replication: 1,
        };
        let report = simulate(&job, &mut exec).unwrap();
        assert!(report.shuffle_bytes > 0.0);
        let got = exec.symbols.matrix_data("out", &mut exec.pool).unwrap();
        let expect = ops::matmult_st(&ops::transpose(&x), &x);
        assert!(got.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn scalar_bin_and_unary_chain() {
        let cfg = SystemConfig::default();
        let cc = tiny_cluster();
        let mut exec = test_exec(&cfg, &cc);
        let x = DenseMatrix::rand(100, 10, 0.5, 2.0, 1.0, 11);
        bind(&mut exec, "X", x.clone());
        exec.exec_inst(&crate::rtprog::Instr::CreateVar {
            var: "out".into(),
            path: String::new(),
            temp: true,
            format: Format::BinaryBlock,
            mc: mc(100, 10),
        })
        .unwrap();
        let job = MrJob {
            job_type: JobType::Gmr,
            inputs: vec!["X".into()],
            dcache: vec![],
            map_insts: vec![
                MrInst {
                    op: MrOp::ScalarBin {
                        op: BinOp::Mul,
                        scalar: 2.0,
                        scalar_var: None,
                        scalar_left: false,
                    },
                    inputs: vec![0],
                    output: 1,
                    mc: mc(100, 10),
                },
                MrInst {
                    op: MrOp::Unary(crate::ir::UnOp::Sqrt),
                    inputs: vec![1],
                    output: 2,
                    mc: mc(100, 10),
                },
            ],
            shuffle_insts: vec![],
            agg_insts: vec![],
            other_insts: vec![],
            outputs: vec!["out".into()],
            result_indices: vec![2],
            num_reducers: 4,
            replication: 1,
        };
        simulate(&job, &mut exec).unwrap();
        let got = exec.symbols.matrix_data("out", &mut exec.pool).unwrap();
        let expect = ops::unary(&ops::ewise_scalar(&x, 2.0, |a, b| a * b), f64::sqrt);
        assert!(got.max_abs_diff(&expect) < 1e-12);
        let _ = Lit::Int(0);
    }
}
