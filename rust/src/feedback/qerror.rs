//! Q-error: the standard multiplicative prediction-error metric for cost
//! models (`max(pred/actual, actual/pred)`, always ≥ 1). The paper's §3.4
//! accuracy claim — "estimated costs were within 2x of the actual
//! execution time" — is a within-2x-rate statement in this metric.

/// Multiplicative prediction error `max(pred/meas, meas/pred)` (≥ 1, with
/// 1 meaning a perfect prediction). Non-positive or non-finite inputs
/// yield `+inf`: a cost model that predicts 0 or NaN seconds for work
/// that took measurable time is maximally wrong, not "close".
pub fn qerror(predicted_secs: f64, measured_secs: f64) -> f64 {
    // NaN inputs fail the finiteness checks, so `<= 0.0` (false for NaN)
    // is safe here.
    if predicted_secs <= 0.0
        || measured_secs <= 0.0
        || !predicted_secs.is_finite()
        || !measured_secs.is_finite()
    {
        return f64::INFINITY;
    }
    (predicted_secs / measured_secs).max(measured_secs / predicted_secs)
}

/// Aggregate Q-error statistics over a set of per-block records.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QErrorSummary {
    /// Number of records summarised.
    pub n: usize,
    /// Geometric mean of the Q-errors (`exp(mean(ln q))`) — the standard
    /// headline figure; robust to the metric's multiplicative scale.
    pub geo_mean: f64,
    /// 95th-percentile Q-error (nearest-rank).
    pub p95: f64,
    /// Fraction of records with Q-error ≤ 2 (the paper's §3.4 claim).
    pub within_2x: f64,
}

impl QErrorSummary {
    /// Summary of an empty record set: `n = 0`, NaN aggregates.
    pub fn empty() -> Self {
        QErrorSummary { n: 0, geo_mean: f64::NAN, p95: f64::NAN, within_2x: 0.0 }
    }
}

/// Summarise a set of Q-errors (see [`qerror`]). Infinite Q-errors are
/// counted (they push the geometric mean to `inf`) rather than dropped —
/// hiding catastrophic mispredictions would defeat the gate.
pub fn summarize(qs: &[f64]) -> QErrorSummary {
    if qs.is_empty() {
        return QErrorSummary::empty();
    }
    let n = qs.len();
    let mean_log = qs.iter().map(|q| q.ln()).sum::<f64>() / n as f64;
    let mut sorted: Vec<f64> = qs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((0.95 * n as f64).ceil() as usize).clamp(1, n);
    let within = qs.iter().filter(|q| **q <= 2.0).count() as f64 / n as f64;
    QErrorSummary { n, geo_mean: mean_log.exp(), p95: sorted[rank - 1], within_2x: within }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qerror_symmetric_and_floored_at_one() {
        assert_eq!(qerror(2.0, 1.0), 2.0);
        assert_eq!(qerror(1.0, 2.0), 2.0);
        assert_eq!(qerror(3.0, 3.0), 1.0);
    }

    #[test]
    fn qerror_degenerate_inputs_are_infinite() {
        assert_eq!(qerror(0.0, 1.0), f64::INFINITY);
        assert_eq!(qerror(1.0, 0.0), f64::INFINITY);
        assert_eq!(qerror(-1.0, 1.0), f64::INFINITY);
        assert_eq!(qerror(f64::NAN, 1.0), f64::INFINITY);
        assert_eq!(qerror(1.0, f64::INFINITY), f64::INFINITY);
    }

    #[test]
    fn summary_of_known_set() {
        let s = summarize(&[1.0, 2.0, 4.0, 8.0]);
        assert_eq!(s.n, 4);
        // geo-mean of 1,2,4,8 = (64)^(1/4) = 2sqrt(2)
        assert!((s.geo_mean - 8.0f64.sqrt() * 1.0).abs() < 1e-12 || (s.geo_mean - 2.0 * 2.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.p95, 8.0);
        assert_eq!(s.within_2x, 0.5);
    }

    #[test]
    fn empty_summary() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert!(s.geo_mean.is_nan());
    }
}
