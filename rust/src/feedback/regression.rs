//! Robust multiplicative regression from per-block records onto
//! [`CostConstants`] corrections (the "retrofitting" estimator).
//!
//! For every constant group ([`BlockClass`]) the estimator takes the
//! blocks *dominated* by that group (≥ 50 % of the predicted seconds),
//! computes their log-ratios `ln(measured/predicted)`, rejects outliers
//! beyond 3 MADs of the median (a Theil–Sen-flavoured median estimator:
//! resistant to a constant fraction of corrupted measurements — GC
//! pauses, cold caches), and fits the group's time-scale correction as
//! `exp(median(kept))`. The median of log-ratios minimises the mean
//! absolute log error, i.e. the geometric-mean Q-error, over a
//! single-scale family.
//!
//! The fit is *safeguarded*: the per-group corrections compete against a
//! single global scale and against the identity, and whichever minimises
//! the geometric-mean Q-error on the records wins — so applying a fit can
//! never make the geo-mean Q-error on its own records worse. The whole
//! estimator is a pure, sequential function of the record list (plus a
//! seed used only to subsample oversized record sets), hence
//! bitwise-deterministic regardless of how many threads produced the
//! records.

use crate::conf::CostConstants;
use crate::util::rng::Rng;

use super::records::{BlockClass, BlockRecord};

/// Per-group multiplicative *time* corrections: a scale `s` for group `g`
/// means "the measured time of g-dominated blocks is `s ×` the predicted
/// time", and [`Corrections::apply`] rescales the group's constants so
/// predictions grow by exactly `s`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Corrections {
    /// Compute-time scale (applied to `flop_efficiency`, `mem_bw`,
    /// `bookkeeping`).
    pub compute: f64,
    /// Read-IO scale (applied to `hdfs_read_*`, `dcache_read`,
    /// `local_read`, `spark_broadcast_bw`).
    pub read: f64,
    /// Write-IO scale (applied to `hdfs_write_*`, `local_write`).
    pub write: f64,
    /// Latency scale (applied to `job_latency`, `task_latency`,
    /// `spark_*_latency`).
    pub latency: f64,
    /// Shuffle scale (applied to `shuffle_bw`, `spark_shuffle_*`).
    pub distributed: f64,
}

/// Fitted scales are clamped into `[MIN_SCALE, MAX_SCALE]` so applying
/// them can never produce zero, negative or non-finite constants.
pub const MIN_SCALE: f64 = 1e-6;
/// Upper clamp for fitted scales (see [`MIN_SCALE`]).
pub const MAX_SCALE: f64 = 1e6;

/// Dominance threshold: a block votes on a group's correction only when
/// the group carries at least this share of the block's prediction.
const DOMINANCE: f64 = 0.5;

/// Cap on the number of records the estimator fits on; larger sets are
/// subsampled deterministically with the caller's seed.
const MAX_FIT_RECORDS: usize = 4096;

impl Corrections {
    /// The no-op correction (all scales 1).
    pub fn identity() -> Self {
        Corrections { compute: 1.0, read: 1.0, write: 1.0, latency: 1.0, distributed: 1.0 }
    }

    /// True when every scale is exactly 1.
    pub fn is_identity(&self) -> bool {
        *self == Corrections::identity()
    }

    /// Scale for `class`.
    pub fn get(&self, class: BlockClass) -> f64 {
        match class {
            BlockClass::Compute => self.compute,
            BlockClass::Read => self.read,
            BlockClass::Write => self.write,
            BlockClass::Latency => self.latency,
            BlockClass::Distributed => self.distributed,
        }
    }

    fn set(&mut self, class: BlockClass, v: f64) {
        match class {
            BlockClass::Compute => self.compute = v,
            BlockClass::Read => self.read = v,
            BlockClass::Write => self.write = v,
            BlockClass::Latency => self.latency = v,
            BlockClass::Distributed => self.distributed = v,
        }
    }

    /// Rescale `k` so each group's predicted time grows by the group's
    /// scale: bandwidths and efficiencies divide by it, latencies multiply
    /// by it. Scales are clamped (see [`MIN_SCALE`]) so the result always
    /// passes [`CostConstants::validate`] when `k` does.
    pub fn apply(&self, k: &CostConstants) -> CostConstants {
        let s = |v: f64| v.clamp(MIN_SCALE, MAX_SCALE);
        let (compute, read, write, latency, distributed) =
            (s(self.compute), s(self.read), s(self.write), s(self.latency), s(self.distributed));
        let mut out = k.clone();
        // compute: time ∝ 1/(clock·eff) and 1/mem_bw; bookkeeping is a
        // flat per-inst compute charge
        out.flop_efficiency = k.flop_efficiency / compute;
        out.mem_bw = k.mem_bw / compute;
        out.bookkeeping = k.bookkeeping * compute;
        // read-IO bandwidths
        out.hdfs_read_binaryblock = k.hdfs_read_binaryblock / read;
        out.hdfs_read_text = k.hdfs_read_text / read;
        out.dcache_read = k.dcache_read / read;
        out.local_read = k.local_read / read;
        out.spark_broadcast_bw = k.spark_broadcast_bw / read;
        // write-IO bandwidths
        out.hdfs_write_binaryblock = k.hdfs_write_binaryblock / write;
        out.hdfs_write_text = k.hdfs_write_text / write;
        out.local_write = k.local_write / write;
        // latencies
        out.job_latency = k.job_latency * latency;
        out.task_latency = k.task_latency * latency;
        out.spark_job_latency = k.spark_job_latency * latency;
        out.spark_stage_latency = k.spark_stage_latency * latency;
        out.spark_task_latency = k.spark_task_latency * latency;
        // shuffle bandwidths
        out.shuffle_bw = k.shuffle_bw / distributed;
        out.spark_shuffle_write = k.spark_shuffle_write / distributed;
        out.spark_shuffle_read = k.spark_shuffle_read / distributed;
        out
    }
}

/// Fit corrections from records (see the module docs for the estimator).
/// Deterministic given `records` and `seed`; returns the identity when no
/// record has positive finite predicted and measured seconds.
pub fn fit(records: &[BlockRecord], seed: u64) -> Corrections {
    let mut usable: Vec<&BlockRecord> = records
        .iter()
        .filter(|r| {
            r.predicted_secs > 0.0
                && r.predicted_secs.is_finite()
                && r.measured_secs > 0.0
                && r.measured_secs.is_finite()
        })
        .collect();
    if usable.is_empty() {
        return Corrections::identity();
    }
    if usable.len() > MAX_FIT_RECORDS {
        usable = subsample(usable, MAX_FIT_RECORDS, seed);
    }

    // per-group medians over dominated blocks, outliers rejected
    let mut grouped = Corrections::identity();
    for class in BlockClass::ALL {
        let logs: Vec<f64> = usable
            .iter()
            .filter(|r| r.dominance(class) >= DOMINANCE)
            .map(|r| (r.measured_secs / r.predicted_secs).ln())
            .collect();
        if logs.is_empty() {
            continue;
        }
        let kept = reject_outliers(&logs);
        grouped.set(class, median(&kept).exp().clamp(MIN_SCALE, MAX_SCALE));
    }

    // single global scale: the exact geo-mean-Q-error minimiser over the
    // one-parameter family
    let all_logs: Vec<f64> = usable
        .iter()
        .map(|r| (r.measured_secs / r.predicted_secs).ln())
        .collect();
    let g = median(&all_logs).exp().clamp(MIN_SCALE, MAX_SCALE);
    let global = Corrections { compute: g, read: g, write: g, latency: g, distributed: g };

    // safeguarded selection: never worse than doing nothing. A candidate
    // must improve by a relative margin so that floating-point noise from
    // ln/exp round-trips cannot displace the identity — this is what makes
    // a second fit on already-corrected records an exact fixpoint.
    let improves = |q: f64, best: f64| q < best * (1.0 - 1e-9);
    let mut best = (geo_mean_q(&usable, &Corrections::identity()), Corrections::identity());
    let qg = geo_mean_q(&usable, &global);
    if improves(qg, best.0) {
        best = (qg, global);
    }
    let qc = geo_mean_q(&usable, &grouped);
    if improves(qc, best.0) {
        best = (qc, grouped);
    }
    best.1
}

/// Re-derive each record's prediction under `corrections` by scaling its
/// breakdown per group (measured seconds are unchanged). For blocks whose
/// cost is linear in the corrected constants — which holds for every
/// group by construction of [`Corrections::apply`] — this matches
/// re-costing the program with the corrected constants.
pub fn repredict(records: &[BlockRecord], corrections: &Corrections) -> Vec<BlockRecord> {
    records
        .iter()
        .map(|r| {
            let mut b = r.breakdown;
            for c in BlockClass::ALL {
                *b.get_mut(c) *= corrections.get(c);
            }
            BlockRecord { predicted_secs: b.total(), breakdown: b, ..r.clone() }
        })
        .collect()
}

/// Geometric-mean Q-error of `records` under `corrections` (via the same
/// per-group linear scaling as [`repredict`]).
fn geo_mean_q(records: &[&BlockRecord], corrections: &Corrections) -> f64 {
    let mut sum = 0.0;
    for r in records {
        let pred: f64 = BlockClass::ALL
            .iter()
            .map(|&c| r.breakdown.get(c) * corrections.get(c))
            .sum();
        sum += super::qerror::qerror(pred, r.measured_secs).ln();
    }
    (sum / records.len() as f64).exp()
}

/// Median of a non-empty slice (midpoint of the two central elements for
/// even lengths).
fn median(xs: &[f64]) -> f64 {
    debug_assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Keep values within 3 median-absolute-deviations of the median (plus a
/// tiny epsilon so an all-equal set keeps everything).
fn reject_outliers(xs: &[f64]) -> Vec<f64> {
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    let mad = median(&devs);
    let tol = 3.0 * mad + 1e-9;
    let kept: Vec<f64> = xs.iter().copied().filter(|x| (x - m).abs() <= tol).collect();
    if kept.is_empty() {
        xs.to_vec()
    } else {
        kept
    }
}

/// Deterministic subsample of `n` records (partial Fisher–Yates on the
/// index vector, then restored to record order).
fn subsample<'a>(records: Vec<&'a BlockRecord>, n: usize, seed: u64) -> Vec<&'a BlockRecord> {
    let mut rng = Rng::new(seed);
    let mut idx: Vec<usize> = (0..records.len()).collect();
    for i in 0..n {
        let j = i + rng.below((idx.len() - i) as u64) as usize;
        idx.swap(i, j);
    }
    let mut take: Vec<usize> = idx[..n].to_vec();
    take.sort_unstable();
    take.into_iter().map(|i| records[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::super::records::CostBreakdown;
    use super::*;

    fn rec(class: BlockClass, predicted: f64, measured: f64) -> BlockRecord {
        let mut b = CostBreakdown::default();
        *b.get_mut(class) = predicted;
        BlockRecord {
            hash: (0, 0),
            label: String::new(),
            predicted_secs: predicted,
            measured_secs: measured,
            breakdown: b,
        }
    }

    #[test]
    fn fits_pure_class_scales_exactly() {
        let recs: Vec<BlockRecord> = (0..9)
            .flat_map(|i| {
                let p = 1.0 + i as f64;
                vec![rec(BlockClass::Compute, p, p * 0.25), rec(BlockClass::Latency, p, p * 8.0)]
            })
            .collect();
        let c = fit(&recs, 1);
        assert!((c.compute - 0.25).abs() < 1e-12, "compute={}", c.compute);
        assert!((c.latency - 8.0).abs() < 1e-11, "latency={}", c.latency);
        // classes with no dominated blocks keep the identity
        assert_eq!(c.write, 1.0);
    }

    #[test]
    fn outliers_are_rejected() {
        let mut recs: Vec<BlockRecord> =
            (0..20).map(|i| rec(BlockClass::Read, 1.0 + i as f64, (1.0 + i as f64) * 2.0)).collect();
        recs.push(rec(BlockClass::Read, 1.0, 5000.0)); // GC pause
        let c = fit(&recs, 1);
        assert!((c.read - 2.0).abs() < 1e-12, "read={}", c.read);
    }

    #[test]
    fn empty_and_degenerate_records_fit_identity() {
        assert!(fit(&[], 1).is_identity());
        let recs = vec![rec(BlockClass::Compute, 0.0, 1.0), rec(BlockClass::Read, 1.0, f64::NAN)];
        assert!(fit(&recs, 1).is_identity());
    }

    #[test]
    fn apply_keeps_constants_valid_under_extreme_scales() {
        let k = CostConstants::default();
        for s in [1e-30, 1e-6, 1.0, 1e6, 1e30, f64::INFINITY] {
            let c = Corrections { compute: s, read: s, write: s, latency: s, distributed: s };
            assert!(c.apply(&k).validate().is_ok(), "scale {s}");
        }
    }

    #[test]
    fn second_fit_on_repredicted_records_is_identity() {
        let recs: Vec<BlockRecord> = (0..7)
            .flat_map(|i| {
                let p = 0.5 + i as f64;
                vec![
                    rec(BlockClass::Compute, p, p * 0.1),
                    rec(BlockClass::Read, p, p * 3.0),
                    rec(BlockClass::Latency, p, p * 0.01),
                ]
            })
            .collect();
        let c1 = fit(&recs, 7);
        assert!(!c1.is_identity());
        let recs2 = repredict(&recs, &c1);
        let c2 = fit(&recs2, 7);
        assert!(c2.is_identity(), "second pass drifted: {c2:?}");
    }
}
