//! Measurement runner: compiles the bundled calibration workloads,
//! predicts their per-block cost, then *measures* the same blocks —
//! either for real (CP instructions on [`Executor`], MR/Spark jobs on the
//! deterministic [`crate::mr`] simulator) or via a deterministic proxy —
//! and joins both sides into [`BlockRecord`]s.
//!
//! Two measurement modes:
//!
//! * [`MeasureMode::Execute`] — run the plan with
//!   [`Executor::run_instrumented`] and take the best of three warm
//!   wall-clock timings per block. This is what `repro calibrate` does.
//! * [`MeasureMode::Simulated`] — "measured" times are re-costings under
//!   a fixed *simulator truth* constants profile ([`simulator_truth`])
//!   with seeded multiplicative noise. Bitwise-deterministic regardless
//!   of machine load or thread count, which is what the property tests
//!   and the CI gate need; the truth profile itself was measured once
//!   against the in-process runtime (no JVM: millisecond job latencies,
//!   memory-speed IO).

use std::collections::HashMap;
use std::path::Path;

use crate::api::{compile, compile_with_meta, ClusterConfigOpt, CompileOptions, LINREG_DS};
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig, GB, MB};
use crate::cost::cache::{program_hashes, ProgramHashes};
use crate::cost::cost_program_faults;
use crate::cp::interp::{ExecStats, Executor};
use crate::ir::build::StaticMeta;
use crate::matrix::{io, ops, DenseMatrix, Format, MatrixCharacteristics};
use crate::rtprog::RtProgram;
use crate::runtime::KernelRegistry;
use crate::util::rng::Rng;

use super::records::{collect_records, BlockRecord};

/// A loop workload exercising the Eq.-1 control-flow aggregation.
pub const LOOP_SCRIPT: &str = r#"X = read($1);
y = read($2);
s = 0;
for (i in 1:10) {
  s = s + sum(X);
}
b = t(X) %*% y;
r = sum(b) + s;
write(r, $4);"#;

/// One bundled calibration workload: a script compiled at a concrete
/// shape against a concrete heap (the heap controls CP-vs-MR plan shape).
#[derive(Clone, Copy, Debug)]
pub struct CalibrationCase {
    /// Display name.
    pub name: &'static str,
    /// DML source (reads `$1`/`$2`, writes `$4`).
    pub script: &'static str,
    /// Rows of the generated X.
    pub rows: usize,
    /// Columns of the generated X.
    pub cols: usize,
    /// Client/task heap in MB; tiny heaps force MR jobs.
    pub heap_mb: f64,
    /// Value bound to `$3`: the intercept flag for [`LINREG_DS`] /
    /// [`LOOP_SCRIPT`] (0 = off) or the iteration count for
    /// [`crate::api::LINREG_CG`].
    pub iters: usize,
}

/// The bundled calibration workloads: CP-resident linear regression at
/// two shapes, an MR-forced shape (heap far below the data size), a
/// control-flow loop, and the iterative conjugate-gradient variant
/// (every iteration touches X twice — the per-iteration job-latency
/// workload the retry-aware fault pricing leans on). `quick` halves the
/// shapes for test/CI budgets.
pub fn bundled_cases(quick: bool) -> Vec<CalibrationCase> {
    if quick {
        vec![
            CalibrationCase { name: "linreg CP 512x64", script: LINREG_DS, rows: 512, cols: 64, heap_mb: 2048.0, iters: 0 },
            CalibrationCase { name: "linreg CP 1024x96", script: LINREG_DS, rows: 1024, cols: 96, heap_mb: 2048.0, iters: 0 },
            CalibrationCase { name: "linreg MR 4096x128", script: LINREG_DS, rows: 4096, cols: 128, heap_mb: 0.12, iters: 0 },
            CalibrationCase { name: "loop   CP 512x64", script: LOOP_SCRIPT, rows: 512, cols: 64, heap_mb: 2048.0, iters: 0 },
            CalibrationCase { name: "linreg CG 512x64", script: crate::api::LINREG_CG, rows: 512, cols: 64, heap_mb: 2048.0, iters: 4 },
        ]
    } else {
        vec![
            CalibrationCase { name: "linreg CP 2048x128", script: LINREG_DS, rows: 2048, cols: 128, heap_mb: 2048.0, iters: 0 },
            CalibrationCase { name: "linreg CP 4096x256", script: LINREG_DS, rows: 4096, cols: 256, heap_mb: 2048.0, iters: 0 },
            CalibrationCase { name: "linreg MR 8192x256", script: LINREG_DS, rows: 8192, cols: 256, heap_mb: 0.12, iters: 0 },
            CalibrationCase { name: "loop   CP 2048x128", script: LOOP_SCRIPT, rows: 2048, cols: 128, heap_mb: 2048.0, iters: 0 },
            CalibrationCase { name: "linreg CG 2048x128", script: crate::api::LINREG_CG, rows: 2048, cols: 128, heap_mb: 2048.0, iters: 8 },
        ]
    }
}

/// The local single-node cluster a calibration case compiles and runs
/// against: `threads` CP/map/reduce slots and 2 MB HDFS blocks so even
/// small matrices split into several map tasks.
pub fn cluster_for(threads: usize, case: &CalibrationCase) -> ClusterConfig {
    let mut cc = ClusterConfig::local(threads, case.heap_mb * MB);
    cc.hdfs_block_bytes = 2.0 * MB;
    cc.k_map = threads;
    cc.k_reduce = threads;
    cc
}

/// Fixed reference profile of the in-process runtime, used as the ground
/// truth of [`MeasureMode::Simulated`]: the simulator spawns threads
/// instead of JVMs (millisecond job latency), reads the local page cache
/// instead of a DataNode (near-memory bandwidth), and runs SIMD kernels
/// (FLOP efficiency > 1 relative to the paper's 2.15 GHz effective
/// clock). Measured once against `Executor` runs on the bundled cases.
pub fn simulator_truth() -> CostConstants {
    CostConstants {
        hdfs_read_binaryblock: 900.0 * MB,
        hdfs_read_text: 450.0 * MB,
        hdfs_write_binaryblock: 700.0 * MB,
        hdfs_write_text: 350.0 * MB,
        local_read: 900.0 * MB,
        local_write: 700.0 * MB,
        dcache_read: 900.0 * MB,
        shuffle_bw: 700.0 * MB,
        mem_bw: 8.0 * GB,
        job_latency: 2e-3,
        task_latency: 2e-5,
        dop_scale: 1.0,
        spark_job_latency: 1e-3,
        spark_stage_latency: 3e-4,
        spark_task_latency: 5e-5,
        flop_efficiency: 4.0,
        ..CostConstants::default()
    }
}

/// How a calibration case is "measured" (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MeasureMode {
    /// Real execution: CP on the interpreter, MR/Spark on the simulator,
    /// best-of-3 warm wall-clock per block.
    Execute,
    /// Deterministic proxy: re-cost under [`simulator_truth`] with
    /// log-uniform noise of half-width `noise` (0.0 = noise-free).
    Simulated {
        /// Log-uniform noise half-width applied per block.
        noise: f64,
    },
}

/// A measured calibration case: the compiled plan, its structural hashes
/// and the per-block records, plus everything needed to re-cost it under
/// calibrated constants.
#[derive(Debug)]
pub struct MeasuredCase {
    /// Case display name.
    pub name: &'static str,
    /// The compiled runtime plan.
    pub rt: RtProgram,
    /// Structural hashes of `rt` (computed once, reused for caching).
    pub hashes: ProgramHashes,
    /// System configuration the plan was compiled under.
    pub cfg: SystemConfig,
    /// Cluster configuration the plan was compiled and measured under.
    pub cc: ClusterConfig,
    /// Per-top-level-block calibration records.
    pub records: Vec<BlockRecord>,
    /// Executor statistics (real-execution mode only).
    pub stats: Option<ExecStats>,
}

/// Compile, predict and measure one calibration case. `threads` sizes the
/// cluster in [`MeasureMode::Execute`]; [`MeasureMode::Simulated`] pins a
/// fixed 8-slot geometry so its output is independent of the machine.
/// `k0` is the constants the *predictions* are made with; `scratch` holds
/// generated data and spill files in execute mode.
pub fn measure_case(
    case: &CalibrationCase,
    mode: MeasureMode,
    threads: usize,
    k0: &CostConstants,
    seed: u64,
    scratch: &Path,
    registry: Option<&KernelRegistry>,
) -> Result<MeasuredCase, String> {
    measure_case_faults(case, mode, threads, k0, &FaultProfile::none(), seed, scratch, registry)
}

/// [`measure_case`] under a failure profile. Predictions are priced with
/// the retry-aware cost model; measurements see the same profile —
/// execute mode arms deterministic fault injection on the interpreter
/// (failed attempts re-run task bodies, backoff accrues to the measured
/// block times), simulated mode re-costs the truth profile fault-aware.
/// [`FaultProfile::none`] is bitwise-identical to [`measure_case`].
#[allow(clippy::too_many_arguments)]
pub fn measure_case_faults(
    case: &CalibrationCase,
    mode: MeasureMode,
    threads: usize,
    k0: &CostConstants,
    fault: &FaultProfile,
    seed: u64,
    scratch: &Path,
    registry: Option<&KernelRegistry>,
) -> Result<MeasuredCase, String> {
    let geometry = match mode {
        MeasureMode::Execute => threads.max(1),
        MeasureMode::Simulated { .. } => 8,
    };
    let cc = cluster_for(geometry, case);
    let cfg = SystemConfig::default();
    let opts = CompileOptions { cc: ClusterConfigOpt(cc.clone()), ..Default::default() };

    match mode {
        MeasureMode::Simulated { noise } => {
            let tag = format!("calib/{}x{}", case.rows, case.cols);
            let args = case_args(&tag, case.iters);
            let meta = StaticMeta::default()
                .with(
                    &format!("{tag}/X"),
                    MatrixCharacteristics::dense(case.rows as i64, case.cols as i64, opts.cfg.blocksize),
                    Format::BinaryBlock,
                )
                .with(
                    &format!("{tag}/y"),
                    MatrixCharacteristics::dense(case.rows as i64, 1, opts.cfg.blocksize),
                    Format::BinaryBlock,
                );
            let compiled = compile_with_meta(case.script, &args, &meta, &opts)?;
            let rt = compiled.runtime;
            let hashes = program_hashes(&rt);
            let report = cost_program_faults(&rt, &opts.cfg, &cc, k0, fault);
            let truth =
                cost_program_faults(&rt, &opts.cfg, &cc, &simulator_truth(), fault);
            let mut rng = Rng::new(seed ^ fnv64(case.name));
            let block_secs: Vec<f64> = truth
                .nodes
                .iter()
                .map(|n| {
                    let f = if noise > 0.0 { rng.uniform(-noise, noise).exp() } else { 1.0 };
                    n.total() * f
                })
                .collect();
            let records = collect_records(&report, &hashes, &block_secs);
            Ok(MeasuredCase { name: case.name, rt, hashes, cfg, cc, records, stats: None })
        }
        MeasureMode::Execute => {
            let tag = format!("{}x{}_{}", case.rows, case.cols, case.heap_mb);
            let x = DenseMatrix::rand(case.rows, case.cols, -1.0, 1.0, 1.0, 42);
            let beta = DenseMatrix::rand(case.cols, 1, -0.5, 0.5, 1.0, 43);
            let y = ops::matmult(&x, &beta, geometry);
            let xp = scratch.join(format!("X_{tag}")).to_string_lossy().to_string();
            let yp = scratch.join(format!("y_{tag}")).to_string_lossy().to_string();
            io::write_binary_block(&xp, &x, 1000).map_err(|e| e.to_string())?;
            io::write_binary_block(&yp, &y, 1000).map_err(|e| e.to_string())?;
            let mut args = HashMap::new();
            args.insert(1, xp);
            args.insert(2, yp);
            args.insert(3, case.iters.to_string());
            args.insert(4, scratch.join(format!("out_{tag}")).to_string_lossy().to_string());

            let compiled = compile(case.script, &args, &opts)?;
            let rt = compiled.runtime;
            let hashes = program_hashes(&rt);
            let report = cost_program_faults(&rt, &opts.cfg, &cc, k0, fault);

            // Warm run first (adaptive PJRT dispatch settles once per
            // process), then keep the per-block minimum of three
            // instrumented runs — the robust estimator downstream still
            // sees scheduler noise, this just trims the worst of it.
            let scratch_dir = |i: usize| scratch.join(format!("scratch_{tag}_{i}"));
            let mut warm = Executor::new(&opts.cfg, &cc, registry, scratch_dir(0));
            warm.set_fault_injection(fault.clone(), seed);
            warm.run(&rt).map_err(|e| e.to_string())?;
            let mut best: Vec<f64> = vec![f64::INFINITY; rt.blocks.len()];
            let mut stats = None;
            for i in 1..=3 {
                let mut exec = Executor::new(&opts.cfg, &cc, registry, scratch_dir(i));
                exec.set_fault_injection(fault.clone(), seed);
                let (s, secs) = exec.run_instrumented(&rt).map_err(|e| e.to_string())?;
                for (b, m) in best.iter_mut().zip(secs) {
                    *b = b.min(m);
                }
                stats = Some(s);
            }
            let records = collect_records(&report, &hashes, &best);
            Ok(MeasuredCase { name: case.name, rt, hashes, cfg, cc, records, stats })
        }
    }
}

/// `$N` bindings shared by the bundled scripts (`$3` is the case's
/// intercept flag or iteration count).
fn case_args(tag: &str, iters: usize) -> HashMap<usize, String> {
    let mut args = HashMap::new();
    args.insert(1, format!("{tag}/X"));
    args.insert(2, format!("{tag}/y"));
    args.insert(3, iters.to_string());
    args.insert(4, format!("{tag}/out"));
    args
}

/// FNV-1a of a name — a stable per-case stream selector for the noise RNG.
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
