//! Measured-execution feedback and online cost-model calibration
//! (ROADMAP item 2, closing the paper's §3.4 accuracy loop).
//!
//! The white-box cost model ([`crate::cost`]) predicts plan execution
//! time from analytical [`CostConstants`]; this module *checks* those
//! predictions against the runtime and *fits* the constants from the
//! discrepancy, in three stages:
//!
//! 1. **Measure** ([`runner`]) — compile the bundled calibration
//!    workloads, predict per-block cost, then execute them (CP
//!    instructions on [`crate::cp::interp::Executor`], MR/Spark jobs on
//!    the deterministic [`crate::mr`] simulator) with per-block timing.
//! 2. **Record** ([`records`]) — join predictions and measurements into
//!    per-block records keyed by the structural block hashes of
//!    [`crate::cost::cache`], each carrying a breakdown of the predicted
//!    seconds by constant group.
//! 3. **Fit** ([`regression`]) — robust median-of-log-ratios regression
//!    (Theil–Sen flavoured, outlier-rejecting, deterministic given a
//!    seed) of one multiplicative correction per group, safeguarded so
//!    the geometric-mean Q-error ([`qerror`]) never increases.
//!
//! [`calibrate`] runs the full loop and additionally *re-optimizes*: it
//! re-costs the bundled backend-choice scenario under the calibrated
//! constants through a shared [`crate::cost::cache::CostCache`]
//! (exercising the constants knob-fingerprint invalidation) and reports
//! whether the argmin backend flipped — on the bundled workloads it does,
//! because the defaults assume Hadoop's 20 s job startup while the
//! in-process runtime launches jobs in milliseconds.

pub mod qerror;
pub mod records;
pub mod regression;
pub mod runner;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::api::{compile_with_meta, ClusterConfigOpt, CompileOptions};
use crate::conf::{CostConstants, FaultProfile};
use crate::cost::cache::{program_hashes, CostCache};
use crate::cost::{cost_program_cached_faults, cost_total_cached_faults};
use crate::ir::build::StaticMeta;
use crate::matrix::{Format, MatrixCharacteristics};
use crate::rtprog::ExecBackend;

pub use qerror::{qerror, summarize, QErrorSummary};
pub use records::{BlockClass, BlockRecord, CostBreakdown};
pub use regression::{fit, repredict, Corrections};
pub use runner::{
    bundled_cases, measure_case, measure_case_faults, simulator_truth, CalibrationCase,
    MeasureMode, MeasuredCase,
};

/// Options for [`calibrate`].
#[derive(Clone, Debug)]
pub struct CalibrateOptions {
    /// RNG seed for the regression subsampler and the simulated-mode
    /// noise streams. The whole pipeline is deterministic given the seed
    /// (in [`MeasureMode::Simulated`]; wall-clock measurement is
    /// inherently noisy).
    pub seed: u64,
    /// Use the smaller bundled shapes (test/CI budgets).
    pub quick: bool,
    /// Execution threads for [`MeasureMode::Execute`] (0 = all cores).
    /// Never affects the fit itself: fitting is sequential, and the
    /// simulated mode pins a fixed cluster geometry.
    pub threads: usize,
    /// How blocks are measured.
    pub mode: MeasureMode,
    /// Starting constants the predictions are made with (and the fit
    /// corrects).
    pub constants: CostConstants,
    /// Data/spill directory for execute mode. `None` (the default) uses
    /// a per-run unique subdirectory of the system temp dir — derived
    /// from the process id, the seed and a process-wide counter, so
    /// concurrent calibrations never collide — which is removed again
    /// when calibration succeeds. An explicit path is used as given and
    /// never cleaned up.
    pub scratch: Option<PathBuf>,
    /// Failure model both sides of the loop run under: executions inject
    /// deterministic seeded faults, predictions price their retry-aware
    /// expectation, and the re-optimization re-costs each backend with
    /// the same profile. [`FaultProfile::none`] (the default) keeps the
    /// whole pipeline bitwise-identical to fault-unaware calibration.
    pub fault: FaultProfile,
}

impl Default for CalibrateOptions {
    fn default() -> Self {
        CalibrateOptions {
            seed: 42,
            quick: false,
            threads: 0,
            mode: MeasureMode::Execute,
            constants: CostConstants::default(),
            scratch: None,
            fault: FaultProfile::none(),
        }
    }
}

/// Before/after Q-error for one block class.
#[derive(Clone, Copy, Debug)]
pub struct ClassQError {
    /// The dominating constant group.
    pub class: BlockClass,
    /// Q-error summary under the starting constants.
    pub before: QErrorSummary,
    /// Q-error summary under the calibrated constants.
    pub after: QErrorSummary,
}

/// Cost of one backend's plan for the re-optimization scenario, before
/// and after calibration.
#[derive(Clone, Copy, Debug)]
pub struct ReoptChoice {
    /// The execution backend the plan was compiled for.
    pub backend: ExecBackend,
    /// `C(P, cc)` under the starting constants.
    pub before_secs: f64,
    /// `C(P, cc)` under the calibrated constants.
    pub after_secs: f64,
}

/// Result of re-running the backend-choice optimization with calibrated
/// constants (the paper's "what-if" loop closed with measured data).
#[derive(Clone, Debug)]
pub struct ReoptReport {
    /// Scenario description.
    pub scenario: String,
    /// Per-backend plan costs before/after calibration.
    pub choices: Vec<ReoptChoice>,
    /// Cheapest backend under the starting constants.
    pub argmin_before: ExecBackend,
    /// Cheapest backend under the calibrated constants.
    pub argmin_after: ExecBackend,
}

impl ReoptReport {
    /// Did calibration change the optimizer's choice?
    pub fn flipped(&self) -> bool {
        self.argmin_before != self.argmin_after
    }
}

/// Full calibration report: records, fitted corrections, calibrated
/// constants and before/after accuracy.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    /// Every per-block record, in case/program order.
    pub records: Vec<BlockRecord>,
    /// Number of bundled cases measured.
    pub cases: usize,
    /// Whether blocks were measured by real execution (vs simulated).
    pub executed: bool,
    /// The fitted per-group corrections (identity if calibration could
    /// not improve the geo-mean Q-error).
    pub corrections: Corrections,
    /// The starting constants.
    pub initial: CostConstants,
    /// The corrected constants (`corrections.apply(&initial)`).
    pub calibrated: CostConstants,
    /// Q-error over all records under the starting constants.
    pub before: QErrorSummary,
    /// Q-error over all records under the calibrated constants,
    /// recomputed by re-costing every plan (never worse than `before` on
    /// the geometric mean, by construction).
    pub after: QErrorSummary,
    /// Per-class before/after Q-error (classes with no records omitted).
    pub per_class: Vec<ClassQError>,
    /// The re-optimization outcome.
    pub reopt: ReoptReport,
}

/// Distinguishes concurrent defaulted-scratch calibrations within one
/// process; the process id distinguishes processes (no wall clock or RNG
/// involved, so runs stay reproducible).
static SCRATCH_COUNTER: AtomicU64 = AtomicU64::new(0);

fn default_scratch(seed: u64) -> PathBuf {
    let n = SCRATCH_COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir()
        .join("sysds_feedback")
        .join(format!("run_{}_{}_{}", std::process::id(), seed, n))
}

/// Run the full feedback loop: measure the bundled workloads, fit
/// constant corrections, re-cost everything under the calibrated
/// constants (through a shared cost cache, exercising the knob
/// fingerprint) and re-run the backend-choice optimization. See the
/// module docs for the pipeline.
pub fn calibrate(opts: &CalibrateOptions) -> Result<CalibrationReport, String> {
    opts.constants.validate()?;
    opts.fault.validate()?;
    let threads = if opts.threads == 0 {
        crate::util::par::default_threads()
    } else {
        opts.threads
    };
    // A defaulted scratch is unique per run (pid + seed + counter): a
    // fixed path here used to make concurrent calibrations overwrite each
    // other's measured inputs, and the directory was never cleaned up.
    let owns_scratch = opts.scratch.is_none();
    let scratch = opts.scratch.clone().unwrap_or_else(|| default_scratch(opts.seed));
    let executed = matches!(opts.mode, MeasureMode::Execute);
    let registry = if executed {
        std::fs::create_dir_all(&scratch).map_err(|e| e.to_string())?;
        crate::runtime::load_registry_or_warn("calibrate")
    } else {
        None
    };

    // 1+2: measure every bundled case into records
    let cases = bundled_cases(opts.quick);
    let mut measured: Vec<MeasuredCase> = Vec::with_capacity(cases.len());
    for case in &cases {
        measured.push(measure_case_faults(
            case,
            opts.mode,
            threads,
            &opts.constants,
            &opts.fault,
            opts.seed,
            &scratch,
            registry.as_ref(),
        )?);
    }
    let records: Vec<BlockRecord> =
        measured.iter().flat_map(|m| m.records.iter().cloned()).collect();

    // 3: fit, apply
    let mut corrections = fit(&records, opts.seed);
    let mut calibrated = corrections.apply(&opts.constants);
    calibrated.validate()?;

    // Re-cost every plan under the calibrated constants through a shared
    // cache (the before-costing warms it; the constants participate in
    // the knob fingerprint, so the after-costing must miss and recompute).
    let cache = CostCache::new(CostCache::DEFAULT_CAPACITY);
    let before_q: Vec<f64> = records.iter().map(|r| r.qerror()).collect();
    let after_q_of = |k: &CostConstants| -> Vec<f64> {
        let mut qs = Vec::with_capacity(before_q.len());
        for m in &measured {
            let rep =
                cost_program_cached_faults(&m.rt, &m.hashes, &m.cfg, &m.cc, k, &opts.fault, &cache);
            for (node, r0) in rep.nodes.iter().zip(&m.records) {
                qs.push(qerror(node.total(), r0.measured_secs));
            }
        }
        qs
    };
    // warm the cache with the starting constants, then re-cost calibrated
    let _ = after_q_of(&opts.constants);
    let mut after_q = after_q_of(&calibrated);
    let before = summarize(&before_q);
    let mut after = summarize(&after_q);

    // outer safeguard (the fit's internal one works on linearly rescaled
    // breakdowns; this one re-runs the real cost model): calibration must
    // never regress the geo-mean Q-error on its own records
    if before.n > 0 && (after.geo_mean > before.geo_mean || after.geo_mean.is_nan()) {
        corrections = Corrections::identity();
        calibrated = opts.constants.clone();
        after_q = before_q.clone();
        after = before;
    }

    // per-class split
    let mut per_class = Vec::new();
    for class in BlockClass::ALL {
        let idx: Vec<usize> =
            (0..records.len()).filter(|&i| records[i].class() == class).collect();
        if idx.is_empty() {
            continue;
        }
        let b: Vec<f64> = idx.iter().map(|&i| before_q[i]).collect();
        let a: Vec<f64> = idx.iter().map(|&i| after_q[i]).collect();
        per_class.push(ClassQError { class, before: summarize(&b), after: summarize(&a) });
    }

    let reopt = reoptimize(&opts.constants, &calibrated, &opts.fault, &cache)?;
    if owns_scratch && executed {
        // Calibration succeeded, so the per-run scratch (measured
        // inputs/outputs) is no longer needed; on failure it is left in
        // place for post-mortems. Best-effort: a failed removal must not
        // fail the calibration.
        let _ = std::fs::remove_dir_all(&scratch);
    }
    Ok(CalibrationReport {
        records,
        cases: cases.len(),
        executed,
        corrections,
        initial: opts.constants.clone(),
        calibrated,
        before,
        after,
        per_class,
        reopt,
    })
}

/// The bundled re-optimization scenario: linear regression at a shape
/// whose data is far larger than the task heap, compiled once per
/// backend. Under the Hadoop-calibrated defaults the distributed plans
/// pay seconds of startup latency per job (20 s MR, 1 s + 0.3 s/stage
/// Spark) that dwarf the ~1 s single-threaded CP plan, so CP wins; once
/// calibration collapses the latency constants to the in-process
/// runtime's milliseconds, the distributed plans' parallel reads and
/// dop-divided exec win the argmin back. The shape is sized so both
/// margins are wide (CP beats the Spark latency floor before; an 8-slot
/// dop beats single-threaded CP by ~4x after).
/// Also the scenario `repro chaos` and the chaos integration tests price
/// failures against: under the in-process [`runner::simulator_truth`]
/// constants the distributed plans win it fault-free, and the chaos
/// [`FaultProfile`]'s retry expectation, per-wave backoff and straggler
/// tail price them back above CP.
pub const REOPT_CASE: CalibrationCase = CalibrationCase {
    name: "linreg 16384x256",
    script: crate::api::LINREG_DS,
    rows: 16_384,
    cols: 256,
    heap_mb: 0.12,
    iters: 0,
};

fn reoptimize(
    k_before: &CostConstants,
    k_after: &CostConstants,
    fault: &FaultProfile,
    cache: &CostCache,
) -> Result<ReoptReport, String> {
    // fixed 8-slot geometry: the report is about constants, not machines
    let cc = runner::cluster_for(8, &REOPT_CASE);
    let tag = format!("reopt/{}x{}", REOPT_CASE.rows, REOPT_CASE.cols);
    let mut args = std::collections::HashMap::new();
    args.insert(1, format!("{tag}/X"));
    args.insert(2, format!("{tag}/y"));
    args.insert(3, "0".to_string());
    args.insert(4, format!("{tag}/out"));

    let mut choices = Vec::new();
    for backend in ExecBackend::all() {
        let opts = CompileOptions {
            cc: ClusterConfigOpt(cc.clone()),
            backend,
            ..Default::default()
        };
        let meta = StaticMeta::default()
            .with(
                &format!("{tag}/X"),
                MatrixCharacteristics::dense(
                    REOPT_CASE.rows as i64,
                    REOPT_CASE.cols as i64,
                    opts.cfg.blocksize,
                ),
                Format::BinaryBlock,
            )
            .with(
                &format!("{tag}/y"),
                MatrixCharacteristics::dense(REOPT_CASE.rows as i64, 1, opts.cfg.blocksize),
                Format::BinaryBlock,
            );
        let compiled = compile_with_meta(REOPT_CASE.script, &args, &meta, &opts)?;
        let hashes = program_hashes(&compiled.runtime);
        let before_secs = cost_total_cached_faults(
            &compiled.runtime,
            &hashes,
            &opts.cfg,
            &cc,
            k_before,
            fault,
            cache,
        );
        let after_secs = cost_total_cached_faults(
            &compiled.runtime,
            &hashes,
            &opts.cfg,
            &cc,
            k_after,
            fault,
            cache,
        );
        choices.push(ReoptChoice { backend, before_secs, after_secs });
    }
    let argmin = |f: &dyn Fn(&ReoptChoice) -> f64| {
        choices
            .iter()
            .min_by(|a, b| {
                f(a).partial_cmp(&f(b)).unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|c| c.backend)
            .unwrap_or_default()
    };
    Ok(ReoptReport {
        scenario: format!("{} (heap {} MB, 8 slots)", REOPT_CASE.name, REOPT_CASE.heap_mb),
        choices: choices.clone(),
        argmin_before: argmin(&|c| c.before_secs),
        argmin_after: argmin(&|c| c.after_secs),
    })
}
