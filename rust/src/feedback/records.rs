//! Per-block `(predicted_cost, measured_time)` records: the join of the
//! cost model's per-block report with instrumented execution times, keyed
//! by the structural block hashes of [`crate::cost::cache`].
//!
//! Each record also carries a *breakdown* of the predicted seconds by
//! correctable constant group (compute / read / write / latency /
//! distributed-shuffle), extracted from the [`CostNode`] annotation tree.
//! The robust regression in [`super::regression`] fits one multiplicative
//! correction per group, attributing each block to the group that
//! dominates its prediction.

use crate::cost::cache::ProgramHashes;
use crate::cost::{CostNode, CostReport, InstCost};

use super::qerror::qerror;

/// The cost-model component group a block's predicted cost is dominated
/// by — each group maps onto a disjoint set of [`crate::conf::CostConstants`]
/// fields that a multiplicative correction rescales linearly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BlockClass {
    /// FLOP/memory-bound compute (`flop_efficiency`, `mem_bw`,
    /// `bookkeeping`; includes MR map/reduce and Spark stage exec).
    Compute,
    /// Read IO: HDFS/dcache/broadcast reads (`hdfs_read_*`, `dcache_read`,
    /// `local_read`, `spark_broadcast_bw`).
    Read,
    /// Write IO: persistent writes and in-memory exports (`hdfs_write_*`,
    /// `local_write`).
    Write,
    /// Job/stage/task startup latency (`job_latency`, `task_latency`,
    /// `spark_*_latency`).
    Latency,
    /// Distributed shuffle (`shuffle_bw`, `spark_shuffle_*`).
    Distributed,
}

impl BlockClass {
    /// Every class, in the order used for deterministic tie-breaking.
    pub const ALL: [BlockClass; 5] = [
        BlockClass::Compute,
        BlockClass::Read,
        BlockClass::Write,
        BlockClass::Latency,
        BlockClass::Distributed,
    ];

    /// Lower-case display name.
    pub fn name(&self) -> &'static str {
        match self {
            BlockClass::Compute => "compute",
            BlockClass::Read => "read",
            BlockClass::Write => "write",
            BlockClass::Latency => "latency",
            BlockClass::Distributed => "distributed",
        }
    }
}

/// Predicted seconds of one block split by constant group (sums to the
/// block's Eq.-1 weighted total).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    /// Compute seconds (CP compute, MR map/reduce exec, Spark stage exec).
    pub compute: f64,
    /// Read-IO seconds.
    pub read: f64,
    /// Write-IO seconds.
    pub write: f64,
    /// Startup-latency seconds.
    pub latency: f64,
    /// Shuffle seconds.
    pub distributed: f64,
}

impl CostBreakdown {
    /// Sum of all components.
    pub fn total(&self) -> f64 {
        self.compute + self.read + self.write + self.latency + self.distributed
    }

    /// Component seconds for `class`.
    pub fn get(&self, class: BlockClass) -> f64 {
        match class {
            BlockClass::Compute => self.compute,
            BlockClass::Read => self.read,
            BlockClass::Write => self.write,
            BlockClass::Latency => self.latency,
            BlockClass::Distributed => self.distributed,
        }
    }

    /// Mutable component for `class`.
    pub fn get_mut(&mut self, class: BlockClass) -> &mut f64 {
        match class {
            BlockClass::Compute => &mut self.compute,
            BlockClass::Read => &mut self.read,
            BlockClass::Write => &mut self.write,
            BlockClass::Latency => &mut self.latency,
            BlockClass::Distributed => &mut self.distributed,
        }
    }

    /// The class with the largest share (ties break in [`BlockClass::ALL`]
    /// order, so the result is deterministic).
    pub fn dominant(&self) -> BlockClass {
        let mut best = BlockClass::Compute;
        let mut best_v = f64::NEG_INFINITY;
        for c in BlockClass::ALL {
            let v = self.get(c);
            if v > best_v {
                best_v = v;
                best = c;
            }
        }
        best
    }
}

/// One calibration record: top-level block `i` of a measured program run.
#[derive(Clone, Debug)]
pub struct BlockRecord {
    /// 128-bit structural hash of the block
    /// ([`ProgramHashes::block_roots`]) — stable across reruns and across
    /// structurally identical plans.
    pub hash: (u64, u64),
    /// Display label of the block (e.g. `GENERIC (lines 1-3)`).
    pub label: String,
    /// `C(block, cc)` — the cost model's Eq.-1 weighted prediction.
    pub predicted_secs: f64,
    /// Wall-clock (or deterministic-proxy) seconds the block actually took.
    pub measured_secs: f64,
    /// Predicted seconds split by constant group; sums to
    /// `predicted_secs`.
    pub breakdown: CostBreakdown,
}

impl BlockRecord {
    /// The constant group dominating this block's prediction.
    pub fn class(&self) -> BlockClass {
        self.breakdown.dominant()
    }

    /// Q-error of the prediction (see [`super::qerror::qerror`]).
    pub fn qerror(&self) -> f64 {
        qerror(self.predicted_secs, self.measured_secs)
    }

    /// Share of the prediction attributed to `class` (0 when the
    /// prediction is zero).
    pub fn dominance(&self, class: BlockClass) -> f64 {
        let t = self.breakdown.total();
        if t > 0.0 {
            self.breakdown.get(class) / t
        } else {
            0.0
        }
    }
}

/// Join a per-block cost report with per-block measured times into
/// calibration records. `report` must come from an annotating costing
/// ([`crate::cost::cost_program`]) of the same program `hashes` was
/// computed from, and `block_secs` must be the aligned per-top-level-block
/// timings of [`crate::cp::interp::Executor::run_instrumented`] — all
/// three vectors are in program order, one entry per top-level block.
pub fn collect_records(
    report: &CostReport,
    hashes: &ProgramHashes,
    block_secs: &[f64],
) -> Vec<BlockRecord> {
    let roots = hashes.block_roots();
    debug_assert_eq!(report.nodes.len(), roots.len());
    debug_assert_eq!(report.nodes.len(), block_secs.len());
    report
        .nodes
        .iter()
        .zip(roots)
        .zip(block_secs)
        .map(|((node, hash), &measured)| {
            let label = match node {
                CostNode::Block { label, .. } => label.clone(),
                CostNode::Inst { rendered, .. } => rendered.clone(),
            };
            BlockRecord {
                hash,
                label,
                predicted_secs: node.total(),
                measured_secs: measured,
                breakdown: breakdown_of(node),
            }
        })
        .collect()
}

/// Extract the per-group breakdown of a block's predicted cost from its
/// annotation subtree, rescaled so the components sum to the block's
/// Eq.-1 weighted total (loop bodies are annotated once but weighted by
/// their trip count in the block total).
fn breakdown_of(node: &CostNode) -> CostBreakdown {
    let mut b = CostBreakdown::default();
    accumulate(node, &mut b);
    let raw = b.total();
    let total = node.total();
    if raw > 0.0 && total.is_finite() {
        let s = total / raw;
        for c in BlockClass::ALL {
            *b.get_mut(c) *= s;
        }
        b
    } else {
        // no leaf annotations (or a zero-cost subtree): attribute the
        // whole weighted total to compute
        CostBreakdown { compute: total, ..CostBreakdown::default() }
    }
}

fn accumulate(node: &CostNode, b: &mut CostBreakdown) {
    match node {
        CostNode::Block { children, .. } => {
            for c in children {
                accumulate(c, b);
            }
        }
        CostNode::Inst { cost, .. } => add_inst(cost, b),
    }
}

fn add_inst(c: &InstCost, b: &mut CostBreakdown) {
    if let Some(m) = &c.mr {
        b.latency += m.latency;
        b.read += m.hdfs_read + m.dcache_read;
        b.write += m.export + m.hdfs_write;
        b.compute += m.map_exec + m.red_exec;
        b.distributed += m.shuffle;
    } else if let Some(s) = &c.spark {
        b.latency += s.latency;
        b.read += s.hdfs_read + s.broadcast;
        b.write += s.export + s.hdfs_write;
        b.compute += s.exec;
        b.distributed += s.shuffle;
    } else {
        b.compute += c.compute;
        b.write += c.io_write;
        b.read += c.io - c.io_write;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominant_breaks_ties_deterministically() {
        let b = CostBreakdown { compute: 1.0, read: 1.0, ..Default::default() };
        assert_eq!(b.dominant(), BlockClass::Compute);
        let b = CostBreakdown { read: 2.0, write: 1.0, ..Default::default() };
        assert_eq!(b.dominant(), BlockClass::Read);
    }

    #[test]
    fn cp_inst_splits_read_write() {
        let mut b = CostBreakdown::default();
        add_inst(
            &InstCost { io: 3.0, io_write: 1.0, compute: 2.0, ..Default::default() },
            &mut b,
        );
        assert_eq!(b.read, 2.0);
        assert_eq!(b.write, 1.0);
        assert_eq!(b.compute, 2.0);
    }
}
