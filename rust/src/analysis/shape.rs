//! Shape & memory audit: independent re-propagation of matrix
//! dimensions through the runtime plan, double-entry-checked against the
//! sizes `ir/size_prop.rs` stamped into `createvar` handles and MR/Spark
//! instruction metadata, plus a static operand-memory check against the
//! configured budgets.
//!
//! Dimension rules are re-derived per runtime operator (not reused from
//! the compiler) so a bug in size propagation and a bug in plan
//! generation cannot cancel out. Matrix-multiply shapes are checked
//! transpose-tolerantly: plan generation may suppress an explicit `r'`
//! and feed the untransposed operand to `mapmm`/`cpmm`, so the declared
//! product is accepted when *any* orientation of the two operands
//! produces it — a declared shape unrelated to both operands is still a
//! contradiction.
//!
//! Memory policy (see [`super::Severity`]): a CP operator whose operand
//! footprint exceeds the CP budget is an **error** on the distributed
//! backends (execution-type selection promised it would fit) but only a
//! **warning** on the CP-forced backend, where oversized single-node
//! operators are the plan family's contract. Distributed-cache and
//! broadcast pressure are always warnings: partitioned broadcasts read
//! one partition at a time, so exceeding the budget is suspicious, not
//! fatal.

use std::collections::BTreeMap;

use super::{Finding, Severity};
use crate::conf::{ClusterConfig, SystemConfig};
use crate::ir::{AggDir, BinOp, UnOp};
use crate::matrix::MatrixCharacteristics;
use crate::rtprog::{
    CpInst, CpOp, ExecBackend, Instr, MrInst, MrJob, MrOp, Operand, PredProg, RtBlock, RtProgram,
    SparkJob,
};

const MB: f64 = 1024.0 * 1024.0;

struct Ctx<'a> {
    rt: &'a RtProgram,
    findings: Vec<Finding>,
    sparse_threshold: f64,
    blocksize: i64,
    partition_bytes: f64,
    cp_budget: f64,
    map_budget: f64,
    broadcast_budget: f64,
    /// Severity for over-budget CP operators (warning on the CP backend).
    cp_over: Severity,
    stack: Vec<String>,
}

/// Run the shape & memory audit over a whole runtime program.
pub(crate) fn audit(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    backend: ExecBackend,
) -> Vec<Finding> {
    let mut ctx = Ctx {
        rt,
        findings: Vec::new(),
        sparse_threshold: cfg.sparse_threshold,
        blocksize: cfg.blocksize,
        partition_bytes: cfg.partition_bytes,
        cp_budget: cfg.cp_budget(cc),
        map_budget: cfg.map_budget(cc),
        broadcast_budget: cfg.spark_broadcast_budget(cc),
        cp_over: if backend == ExecBackend::Cp { Severity::Warning } else { Severity::Error },
        stack: Vec::new(),
    };
    let mut env: BTreeMap<String, MatrixCharacteristics> = BTreeMap::new();
    for (i, b) in rt.blocks.iter().enumerate() {
        walk_block(b, &mut env, i, &mut ctx);
    }
    ctx.findings
}

/// Known (rows, cols) of a non-scalar characteristics value.
fn dims(mc: &MatrixCharacteristics) -> Option<(i64, i64)> {
    if mc.dims_known() && !mc.is_scalar() {
        Some((mc.rows, mc.cols))
    } else {
        None
    }
}

/// Characteristics of a CP operand: variable lookup for matrices,
/// scalar characteristics for scalar variables and literals.
fn operand_mc(
    op: &Operand,
    env: &BTreeMap<String, MatrixCharacteristics>,
) -> Option<MatrixCharacteristics> {
    match op {
        Operand::Mat(n) => env.get(n).copied(),
        Operand::Scalar(..) | Operand::Lit(_) => Some(MatrixCharacteristics::scalar()),
    }
}

/// In-memory size of a CP operand (infinite when unknown — callers skip
/// non-finite footprints rather than flag them).
fn operand_mem(op: &Operand, env: &BTreeMap<String, MatrixCharacteristics>, st: f64) -> f64 {
    match operand_mc(op, env) {
        Some(mc) => mc.mem_estimate(st),
        None => f64::INFINITY,
    }
}

/// Does any orientation of `l` × `r` produce the declared `out` product?
/// (Plan generation may suppress explicit transposes on either side.)
fn matmult_consistent(l: (i64, i64), r: (i64, i64), out: (i64, i64)) -> bool {
    for la in [l, (l.1, l.0)] {
        for ra in [r, (r.1, r.0)] {
            if la.1 == ra.0 && out == (la.0, ra.1) {
                return true;
            }
        }
    }
    false
}

/// Operand shape class for elementwise derivation.
#[derive(Clone, Copy)]
enum Shape {
    /// Scalar variable or literal.
    Scalar,
    /// Matrix with known (rows, cols).
    Known((i64, i64)),
    /// Matrix of unknown extent (or unbound name).
    Unknown,
}

/// Elementwise binary with broadcast: per-dimension equal-or-one.
/// Returns `None` (no finding) when shapes are compatible-unknown and an
/// error string when two non-unit extents conflict.
fn broadcast_dims(a: (i64, i64), b: (i64, i64)) -> Result<(i64, i64), ()> {
    let dim = |x: i64, y: i64| {
        if x == y || y == 1 {
            Ok(x.max(y))
        } else if x == 1 {
            Ok(y)
        } else {
            Err(())
        }
    };
    Ok((dim(a.0, b.0)?, dim(a.1, b.1)?))
}

fn walk_blocks(
    blocks: &[RtBlock],
    env: &mut BTreeMap<String, MatrixCharacteristics>,
    idx: usize,
    ctx: &mut Ctx,
) {
    for b in blocks {
        walk_block(b, env, idx, ctx);
    }
}

fn walk_block(
    block: &RtBlock,
    env: &mut BTreeMap<String, MatrixCharacteristics>,
    idx: usize,
    ctx: &mut Ctx,
) {
    match block {
        RtBlock::Generic { insts, lines, .. } => {
            let loc = format!("lines {}-{}", lines.0, lines.1);
            walk_insts(insts, env, &loc, idx, ctx);
        }
        RtBlock::If { pred, then_blocks, else_blocks, lines } => {
            let loc = format!("if predicate, lines {}-{}", lines.0, lines.1);
            walk_pred(pred, env, &loc, idx, ctx);
            let mut then_e = env.clone();
            let mut else_e = env.clone();
            walk_blocks(then_blocks, &mut then_e, idx, ctx);
            walk_blocks(else_blocks, &mut else_e, idx, ctx);
            // Keep only entries both branches agree on.
            env.clear();
            for (k, v) in &then_e {
                if else_e.get(k) == Some(v) {
                    env.insert(k.clone(), *v);
                }
            }
        }
        RtBlock::For { from, to, by, body, lines, .. } => {
            let loc = format!("for bounds, lines {}-{}", lines.0, lines.1);
            walk_pred(from, env, &loc, idx, ctx);
            walk_pred(to, env, &loc, idx, ctx);
            if let Some(by) = by {
                walk_pred(by, env, &loc, idx, ctx);
            }
            walk_blocks(body, env, idx, ctx);
        }
        RtBlock::While { pred, body, lines } => {
            let loc = format!("while predicate, lines {}-{}", lines.0, lines.1);
            walk_pred(pred, env, &loc, idx, ctx);
            walk_blocks(body, env, idx, ctx);
        }
        RtBlock::FCall { fname, .. } => {
            if let Some(func) = ctx.rt.funcs.get(fname) {
                if !ctx.stack.iter().any(|f| f == fname) {
                    ctx.stack.push(fname.clone());
                    let mut fenv = BTreeMap::new();
                    let blocks = func.blocks.clone();
                    walk_blocks(&blocks, &mut fenv, idx, ctx);
                    ctx.stack.pop();
                }
            }
        }
    }
}

fn walk_pred(
    pred: &PredProg,
    env: &mut BTreeMap<String, MatrixCharacteristics>,
    loc: &str,
    idx: usize,
    ctx: &mut Ctx,
) {
    walk_insts(&pred.insts, env, loc, idx, ctx);
}

fn walk_insts(
    insts: &[Instr],
    env: &mut BTreeMap<String, MatrixCharacteristics>,
    loc: &str,
    idx: usize,
    ctx: &mut Ctx,
) {
    for inst in insts {
        match inst {
            Instr::CreateVar { var, mc, .. } => {
                env.insert(var.clone(), *mc);
            }
            Instr::AssignVar { var, .. } => {
                env.insert(var.clone(), MatrixCharacteristics::scalar());
            }
            Instr::CpVar { src, dst } => {
                if let Some(mc) = env.get(src).copied() {
                    env.insert(dst.clone(), mc);
                }
            }
            Instr::RmVar { .. } => {}
            Instr::Cp(c) => check_cp(c, env, loc, idx, ctx),
            Instr::MrJob(j) => check_mr_job(j, env, loc, idx, ctx),
            Instr::SparkJob(j) => check_spark_job(j, env, loc, idx, ctx),
        }
    }
}

/// Audit one CP instruction: operand-memory footprint against the CP
/// budget, then output-shape double entry.
fn check_cp(
    c: &CpInst,
    env: &mut BTreeMap<String, MatrixCharacteristics>,
    loc: &str,
    idx: usize,
    ctx: &mut Ctx,
) {
    let st = ctx.sparse_threshold;
    let in_mem: f64 = c.inputs.iter().map(|o| operand_mem(o, env, st)).sum();
    let out_mem = operand_mem(&c.output, env, st);
    // Mirrors `ir/memory.rs` op_mem: inputs + op intermediates + output.
    // `partition` never went through execution-type selection — it is a
    // generated streaming operator staging one partition at a time.
    let footprint = match &c.op {
        CpOp::Partition => in_mem.min(ctx.partition_bytes),
        CpOp::Write { .. } => in_mem,
        CpOp::Print => 0.0,
        CpOp::Binary(BinOp::Solve) => {
            in_mem + c.inputs.first().map_or(0.0, |a| operand_mem(a, env, st)) + out_mem
        }
        _ => in_mem + out_mem,
    };
    if footprint.is_finite() && footprint > ctx.cp_budget * (1.0 + 1e-9) {
        ctx.findings.push((
            idx,
            ctx.cp_over,
            format!(
                "CP '{}' operand footprint {:.0} MB exceeds the CP memory budget {:.0} MB ({loc})",
                c.op.code(),
                footprint / MB,
                ctx.cp_budget / MB
            ),
        ));
    }

    let Operand::Mat(out_name) = &c.output else {
        return; // scalar results carry no matrix shape
    };
    let declared = env.get(out_name).copied();
    let in_dims = |i: usize| c.inputs.get(i).and_then(|o| operand_mc(o, env)).and_then(|m| dims(&m));
    let derived: Option<(i64, i64)> = match &c.op {
        CpOp::Tsmm { left } => {
            in_dims(0).map(|(r, co)| if *left { (co, co) } else { (r, r) })
        }
        CpOp::MatMult => {
            if let (Some(l), Some(r), Some(d)) =
                (in_dims(0), in_dims(1), declared.as_ref().and_then(dims))
            {
                if !matmult_consistent(l, r, d) {
                    ctx.findings.push((
                        idx,
                        Severity::Error,
                        format!(
                            "shape mismatch: '{out_name}' declared {}x{} is not a product of \
                             {}x{} and {}x{} under any orientation ({loc})",
                            d.0, d.1, l.0, l.1, r.0, r.1
                        ),
                    ));
                }
            }
            None
        }
        CpOp::Transpose => in_dims(0).map(|(r, co)| (co, r)),
        CpOp::Diag => in_dims(0).map(|(r, co)| if co == 1 { (r, r) } else { (r, 1) }),
        CpOp::AggUnary(_, dir) => match dir {
            AggDir::Row => in_dims(0).map(|(r, _)| (r, 1)),
            AggDir::Col => in_dims(0).map(|(_, co)| (1, co)),
            AggDir::All => None,
        },
        CpOp::Append => {
            if let (Some(a), Some(b)) = (in_dims(0), in_dims(1)) {
                if a.0 != b.0 {
                    ctx.findings.push((
                        idx,
                        Severity::Error,
                        format!(
                            "shape mismatch: append of {}x{} and {}x{} with unequal row counts ({loc})",
                            a.0, a.1, b.0, b.1
                        ),
                    ));
                    None
                } else {
                    Some((a.0, a.1 + b.1))
                }
            } else {
                None
            }
        }
        CpOp::Partition => in_dims(0),
        CpOp::Binary(BinOp::Solve) => {
            if let (Some(a), Some(b)) = (in_dims(0), in_dims(1)) {
                Some((a.1, b.1))
            } else {
                None
            }
        }
        CpOp::Binary(_) => {
            let side = |i: usize| -> Shape {
                match c.inputs.get(i).and_then(|o| operand_mc(o, env)) {
                    Some(m) if m.is_scalar() => Shape::Scalar,
                    Some(m) => dims(&m).map_or(Shape::Unknown, Shape::Known),
                    None => Shape::Unknown,
                }
            };
            match (side(0), side(1)) {
                (Shape::Known(a), Shape::Known(b)) => match broadcast_dims(a, b) {
                    Ok(d) => Some(d),
                    Err(()) => {
                        ctx.findings.push((
                            idx,
                            Severity::Error,
                            format!(
                                "shape mismatch: elementwise '{}' of incompatible \
                                 {}x{} and {}x{} ({loc})",
                                c.op.code(),
                                a.0, a.1, b.0, b.1
                            ),
                        ));
                        None
                    }
                },
                // Matrix ⊙ scalar keeps the matrix shape exactly. With an
                // unknown matrix on the other side, known extents > 1 pin
                // the result, but a unit extent could still be broadcast
                // over — derive nothing then.
                (Shape::Known(a), Shape::Scalar) | (Shape::Scalar, Shape::Known(a)) => Some(a),
                (Shape::Known(a), Shape::Unknown) | (Shape::Unknown, Shape::Known(a))
                    if a.0 > 1 && a.1 > 1 =>
                {
                    Some(a)
                }
                _ => None,
            }
        }
        CpOp::Unary(UnOp::CastMatrix) => Some((1, 1)),
        CpOp::Unary(_) => in_dims(0),
        CpOp::Rand { .. } | CpOp::Seq { .. } | CpOp::Write { .. } | CpOp::Print => None,
    };
    if let (Some(d), Some(want)) = (derived, declared.as_ref().and_then(dims)) {
        if d != want {
            ctx.findings.push((
                idx,
                Severity::Error,
                format!(
                    "shape mismatch: '{out_name}' declared {}x{} but '{}' derives {}x{} ({loc})",
                    want.0, want.1,
                    c.op.code(),
                    d.0, d.1
                ),
            ));
        }
    }
    // Double entry only: the declared size keeps feeding downstream
    // derivations so one mismatch cannot cascade.
    if declared.is_none() {
        if let Some((r, co)) = derived {
            env.insert(out_name.clone(), MatrixCharacteristics::new(r, co, ctx.blocksize, -1));
        }
    }
}

/// Audit one distributed instruction against its declared metadata,
/// using a job-local byte-index environment.
fn check_dist_inst(
    mi: &MrInst,
    jenv: &mut BTreeMap<usize, MatrixCharacteristics>,
    job: &str,
    loc: &str,
    idx: usize,
    ctx: &mut Ctx,
) {
    let in_dims =
        |i: usize| mi.inputs.get(i).and_then(|ix| jenv.get(ix)).and_then(dims);
    let declared = dims(&mi.mc);
    let derived: Option<(i64, i64)> = match &mi.op {
        MrOp::Tsmm { left } => {
            in_dims(0).map(|(r, co)| if *left { (co, co) } else { (r, r) })
        }
        MrOp::MapMM { .. } | MrOp::Cpmm | MrOp::Rmm => {
            if let (Some(l), Some(r), Some(d)) = (in_dims(0), in_dims(1), declared) {
                if !matmult_consistent(l, r, d) {
                    ctx.findings.push((
                        idx,
                        Severity::Error,
                        format!(
                            "shape mismatch: {job} '{}' declares {}x{} which is not a product of \
                             {}x{} and {}x{} under any orientation ({loc})",
                            mi.op.code(),
                            d.0, d.1, l.0, l.1, r.0, r.1
                        ),
                    ));
                }
            }
            None
        }
        MrOp::Transpose => in_dims(0).map(|(r, co)| (co, r)),
        MrOp::Diag => in_dims(0).map(|(r, co)| if co == 1 { (r, r) } else { (r, 1) }),
        MrOp::DataGen { rows, cols, .. } => Some((*rows, *cols)),
        MrOp::Binary(_) => match (in_dims(0), in_dims(1)) {
            (Some(a), Some(b)) => match broadcast_dims(a, b) {
                Ok(d) => Some(d),
                Err(()) => {
                    ctx.findings.push((
                        idx,
                        Severity::Error,
                        format!(
                            "shape mismatch: {job} elementwise '{}' of incompatible \
                             {}x{} and {}x{} ({loc})",
                            mi.op.code(),
                            a.0, a.1, b.0, b.1
                        ),
                    ));
                    None
                }
            },
            _ => None,
        },
        MrOp::ScalarBin { .. } | MrOp::Unary(_) => in_dims(0),
        // Partial-result metadata (map-side aggregates, final ak+,
        // offset appends) legitimately differs from a naive derivation.
        MrOp::AggUnaryMap(..) | MrOp::Agg { .. } | MrOp::Append { .. } => None,
    };
    if let (Some(d), Some(want)) = (derived, declared) {
        if d != want {
            ctx.findings.push((
                idx,
                Severity::Error,
                format!(
                    "shape mismatch: {job} '{}' declares {}x{} but inputs derive {}x{} ({loc})",
                    mi.op.code(),
                    want.0, want.1, d.0, d.1
                ),
            ));
        }
    }
    jenv.insert(mi.output, mi.mc);
}

fn seed_job_env(
    inputs: &[String],
    env: &BTreeMap<String, MatrixCharacteristics>,
) -> BTreeMap<usize, MatrixCharacteristics> {
    let mut jenv = BTreeMap::new();
    for (i, name) in inputs.iter().enumerate() {
        if let Some(mc) = env.get(name) {
            jenv.insert(i, *mc);
        }
    }
    jenv
}

fn export_job_outputs(
    outputs: &[String],
    result_indices: &[usize],
    jenv: &BTreeMap<usize, MatrixCharacteristics>,
    env: &mut BTreeMap<String, MatrixCharacteristics>,
) {
    for (k, name) in outputs.iter().enumerate() {
        if let Some(mc) = result_indices.get(k).and_then(|ri| jenv.get(ri)) {
            env.insert(name.clone(), *mc);
        }
    }
}

fn check_mr_job(
    job: &MrJob,
    env: &mut BTreeMap<String, MatrixCharacteristics>,
    loc: &str,
    idx: usize,
    ctx: &mut Ctx,
) {
    let label = format!("MR-{}", job.job_type.name());
    let mut jenv = seed_job_env(&job.inputs, env);
    for mi in job.all_insts() {
        check_dist_inst(mi, &mut jenv, &label, loc, idx, ctx);
    }
    export_job_outputs(&job.outputs, &job.result_indices, &jenv, env);
    let dcache_mem: f64 = job
        .dcache
        .iter()
        .map(|n| env.get(n).map_or(f64::INFINITY, |m| m.mem_estimate(ctx.sparse_threshold)))
        .sum();
    if dcache_mem.is_finite() && dcache_mem > ctx.map_budget {
        ctx.findings.push((
            idx,
            Severity::Warning,
            format!(
                "{label} distributed-cache inputs ({:.0} MB) exceed the map-task budget \
                 {:.0} MB ({loc})",
                dcache_mem / MB,
                ctx.map_budget / MB
            ),
        ));
    }
}

fn check_spark_job(
    job: &SparkJob,
    env: &mut BTreeMap<String, MatrixCharacteristics>,
    loc: &str,
    idx: usize,
    ctx: &mut Ctx,
) {
    let mut jenv = seed_job_env(&job.inputs, env);
    for mi in job.all_insts() {
        check_dist_inst(mi, &mut jenv, "SPARK", loc, idx, ctx);
    }
    export_job_outputs(&job.outputs, &job.result_indices, &jenv, env);
    let bc_mem: f64 = job
        .broadcasts
        .iter()
        .map(|n| env.get(n).map_or(f64::INFINITY, |m| m.mem_estimate(ctx.sparse_threshold)))
        .sum();
    if bc_mem.is_finite() && bc_mem > ctx.broadcast_budget {
        ctx.findings.push((
            idx,
            Severity::Warning,
            format!(
                "SPARK broadcast inputs ({:.0} MB) exceed the broadcast budget {:.0} MB ({loc})",
                bc_mem / MB,
                ctx.broadcast_budget / MB
            ),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conf::{ClusterConfig, SystemConfig};
    use crate::matrix::Format;

    fn mat(n: &str) -> Operand {
        Operand::Mat(n.into())
    }

    fn createvar(var: &str, rows: i64, cols: i64) -> Instr {
        Instr::CreateVar {
            var: var.into(),
            path: format!("scratch/{var}"),
            temp: true,
            format: Format::BinaryBlock,
            mc: MatrixCharacteristics::dense(rows, cols, 1000),
        }
    }

    fn prog(insts: Vec<Instr>) -> RtProgram {
        RtProgram {
            blocks: vec![RtBlock::Generic { insts, lines: (1, 1), recompile: false }],
            funcs: Default::default(),
        }
    }

    fn run(rt: &RtProgram, backend: ExecBackend) -> Vec<Finding> {
        audit(rt, &SystemConfig::default(), &ClusterConfig::paper_cluster(), backend)
    }

    #[test]
    fn transpose_shape_mismatch_is_an_error() {
        let rt = prog(vec![
            createvar("X", 100, 10),
            createvar("_mVar1", 100, 10), // should be 10x100
            Instr::Cp(CpInst {
                op: CpOp::Transpose,
                inputs: vec![mat("X")],
                output: mat("_mVar1"),
            }),
        ]);
        let f = run(&rt, ExecBackend::Mr);
        assert!(
            f.iter().any(|(_, s, m)| *s == Severity::Error && m.contains("shape mismatch")),
            "{f:?}"
        );
    }

    #[test]
    fn consistent_shapes_are_clean() {
        let rt = prog(vec![
            createvar("X", 100, 10),
            createvar("_mVar1", 10, 100),
            Instr::Cp(CpInst {
                op: CpOp::Transpose,
                inputs: vec![mat("X")],
                output: mat("_mVar1"),
            }),
        ]);
        assert!(run(&rt, ExecBackend::Mr).is_empty());
    }

    #[test]
    fn matmult_accepts_any_orientation_but_not_nonsense() {
        let mm = |out: &str| {
            Instr::Cp(CpInst {
                op: CpOp::MatMult,
                inputs: vec![mat("A"), mat("B")],
                output: mat(out),
            })
        };
        // A: 100x10, B: 100x1 — valid only as t(A) %*% B = 10x1.
        let ok = prog(vec![
            createvar("A", 100, 10),
            createvar("B", 100, 1),
            createvar("ok", 10, 1),
            mm("ok"),
        ]);
        assert!(run(&ok, ExecBackend::Mr).is_empty(), "{:?}", run(&ok, ExecBackend::Mr));
        let bad = prog(vec![
            createvar("A", 100, 10),
            createvar("B", 100, 1),
            createvar("bad", 7, 3),
            mm("bad"),
        ]);
        let f = run(&bad, ExecBackend::Mr);
        assert!(
            f.iter().any(|(_, s, m)| *s == Severity::Error && m.contains("not a product")),
            "{f:?}"
        );
    }

    #[test]
    fn over_budget_cp_operator_severity_follows_backend() {
        // 200M x 2000 dense = 3.2 TB, far over the 1.4 GB paper budget.
        let rt = prog(vec![
            createvar("X", 200_000_000, 2_000),
            createvar("_mVar1", 2_000, 200_000_000),
            Instr::Cp(CpInst {
                op: CpOp::Transpose,
                inputs: vec![mat("X")],
                output: mat("_mVar1"),
            }),
        ]);
        let on_mr = run(&rt, ExecBackend::Mr);
        assert!(
            on_mr.iter().any(|(_, s, m)| *s == Severity::Error && m.contains("exceeds the CP")),
            "{on_mr:?}"
        );
        let on_cp = run(&rt, ExecBackend::Cp);
        assert!(
            on_cp.iter().any(|(_, s, m)| *s == Severity::Warning && m.contains("exceeds the CP")),
            "{on_cp:?}"
        );
        assert!(on_cp.iter().all(|(_, s, _)| *s == Severity::Warning), "{on_cp:?}");
    }

    #[test]
    fn elementwise_conflict_is_an_error() {
        let rt = prog(vec![
            createvar("A", 100, 10),
            createvar("B", 100, 7),
            createvar("C", 100, 10),
            Instr::Cp(CpInst {
                op: CpOp::Binary(BinOp::Add),
                inputs: vec![mat("A"), mat("B")],
                output: mat("C"),
            }),
        ]);
        let f = run(&rt, ExecBackend::Mr);
        assert!(
            f.iter().any(|(_, s, m)| *s == Severity::Error && m.contains("incompatible")),
            "{f:?}"
        );
    }
}
