//! Cost-invariant audit: re-costs the plan and checks the annotation
//! tree against the properties every optimizer assumes.
//!
//! * every block and instruction cost is **finite and non-negative**
//!   (an infinite or NaN cost silently corrupts every argmin built on
//!   top of it);
//! * block totals satisfy the paper's **Eq.-1 aggregation identities**:
//!   Generic / If / FCall totals are recomputed exactly from their
//!   children; For / While totals — whose steady-state iteration cost is
//!   not materialised in the tree — are checked against the bounds
//!   `pred + first ≤ total ≤ pred + w·first` implied by the §3.2
//!   first/steady read-cost split (steady ≤ first), with the exact value
//!   `pred + w·first` required when `w < 1`;
//! * the **block-level cost cache** reproduces the uncached program
//!   total bitwise ([`crate::cost::cost_total_cached`] against a fresh
//!   cache) and the report total equals the sum of its top-level nodes.
//!
//! The walk mirrors the estimator's tree layout (leading `Inst` children
//! for predicate/generic instructions, trailing `Block` children for
//! nested blocks). A layout the walk does not recognise is reported as a
//! structural *warning* and skipped, never guessed at.

use super::{Finding, Severity, PROGRAM_SCOPE};
use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::cost::cache::{self, CostCache};
use crate::cost::{cost_program_faults, cost_total_cached_faults, CostNode};
use crate::rtprog::{RtBlock, RtProgram};

/// Relative comparison tolerance for exactly-recomputable totals. The
/// recomputation replays the estimator's own summation order, so this
/// only has to absorb noise, not reassociation.
fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * b.abs().max(1.0)
}

struct Ctx<'a> {
    rt: &'a RtProgram,
    cfg: &'a SystemConfig,
    cc: &'a ClusterConfig,
    findings: Vec<Finding>,
    call_stack: Vec<String>,
}

/// Run the cost-invariant audit over a whole runtime program.
pub(crate) fn audit(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
) -> Vec<Finding> {
    audit_faults(rt, cfg, cc, k, &FaultProfile::none())
}

/// [`audit`] under a failure profile: the plan is re-costed with the
/// same retry/straggler pricing the optimizer used, so the Eq.-1
/// identities and the bitwise cache-replay check audit the costs that
/// actually decided the plan — not a fault-free shadow of them. With
/// [`FaultProfile::none`] this is bitwise-identical to [`audit`].
pub(crate) fn audit_faults(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fault: &FaultProfile,
) -> Vec<Finding> {
    let report = cost_program_faults(rt, cfg, cc, k, fault);
    let mut ctx = Ctx { rt, cfg, cc, findings: Vec::new(), call_stack: Vec::new() };
    if report.nodes.len() != rt.blocks.len() {
        ctx.findings.push((
            PROGRAM_SCOPE,
            Severity::Warning,
            format!(
                "cost tree shape mismatch: {} annotation nodes for {} blocks",
                report.nodes.len(),
                rt.blocks.len()
            ),
        ));
        return ctx.findings;
    }
    for (i, (b, n)) in rt.blocks.iter().zip(report.nodes.iter()).enumerate() {
        check_block(b, n, i, &mut ctx);
    }
    let top_sum: f64 = report.nodes.iter().map(|n| n.total()).sum();
    if !close(report.total, top_sum) {
        ctx.findings.push((
            PROGRAM_SCOPE,
            Severity::Error,
            format!(
                "program total {} is not the sum of its top-level block costs {}",
                report.total, top_sum
            ),
        ));
    }
    let hashes = cache::program_hashes(rt);
    let cached =
        cost_total_cached_faults(rt, &hashes, cfg, cc, k, fault, &CostCache::default());
    if cached.to_bits() != report.total.to_bits() {
        ctx.findings.push((
            PROGRAM_SCOPE,
            Severity::Error,
            format!(
                "cached cost total {cached} diverges from the uncached total {} \
                 (block cache is not a bitwise replay)",
                report.total
            ),
        ));
    }
    ctx.findings
}

fn structural_warning(what: &str, idx: usize, ctx: &mut Ctx) {
    ctx.findings.push((
        idx,
        Severity::Warning,
        format!("cost tree shape mismatch at {what}; skipping Eq.-1 recomputation"),
    ));
}

/// Check one instruction-annotation node: finite, non-negative.
fn check_inst_node(node: &CostNode, idx: usize, ctx: &mut Ctx) {
    let CostNode::Inst { rendered, cost } = node else {
        return;
    };
    let t = cost.total();
    if !t.is_finite() || t < 0.0 {
        let mut short = rendered.trim().to_string();
        if short.len() > 60 {
            short.truncate(60);
            short.push('…');
        }
        ctx.findings.push((
            idx,
            Severity::Error,
            format!("instruction cost {t} is not finite and non-negative: '{short}'"),
        ));
    }
}

/// Split a Block node's children into the leading `Inst` prefix
/// (predicate / generic instructions) and the trailing `Block` suffix
/// (nested blocks). Returns `None` when the layout is interleaved.
fn split_children(children: &[CostNode]) -> Option<(&[CostNode], &[CostNode])> {
    let n = children.iter().take_while(|c| matches!(c, CostNode::Inst { .. })).count();
    if children[n..].iter().all(|c| matches!(c, CostNode::Block { .. })) {
        Some(children.split_at(n))
    } else {
        None
    }
}

fn sum(nodes: &[CostNode]) -> f64 {
    nodes.iter().map(|n| n.total()).sum()
}

fn check_block(b: &RtBlock, node: &CostNode, idx: usize, ctx: &mut Ctx) {
    let CostNode::Block { label, total, children } = node else {
        structural_warning("a block annotated as an instruction", idx, ctx);
        return;
    };
    if !total.is_finite() || *total < 0.0 {
        ctx.findings.push((
            idx,
            Severity::Error,
            format!("block cost {total} is not finite and non-negative ({label})"),
        ));
        // Still walk the children: the offending instruction pins the
        // finding to its source.
    }
    for c in children {
        check_inst_node(c, idx, ctx);
    }
    let Some((insts, blocks)) = split_children(children) else {
        structural_warning(label, idx, ctx);
        return;
    };
    match b {
        RtBlock::Generic { insts: rins, .. } => {
            if insts.len() != rins.len() || !blocks.is_empty() {
                structural_warning(label, idx, ctx);
                return;
            }
            let expected = sum(insts);
            if total.is_finite() && !close(*total, expected) {
                ctx.findings.push((
                    idx,
                    Severity::Error,
                    format!(
                        "{label}: total {total} deviates from the sum of its \
                         instruction costs {expected}"
                    ),
                ));
            }
        }
        RtBlock::If { pred, then_blocks, else_blocks, .. } => {
            if insts.len() != pred.insts.len()
                || blocks.len() != then_blocks.len() + else_blocks.len()
            {
                structural_warning(label, idx, ctx);
                return;
            }
            let (tn, en) = blocks.split_at(then_blocks.len());
            for (rb, cn) in then_blocks.iter().zip(tn).chain(else_blocks.iter().zip(en)) {
                check_block(rb, cn, idx, ctx);
            }
            let pt = sum(insts);
            let (tt, et) = (sum(tn), sum(en));
            // Eq. 1: branch weight 1/2 per successor; a missing else is an
            // empty branch costing 0.
            let expected =
                if else_blocks.is_empty() { pt + tt / 2.0 } else { pt + (tt + et) / 2.0 };
            if total.is_finite() && !close(*total, expected) {
                ctx.findings.push((
                    idx,
                    Severity::Error,
                    format!("{label}: total {total} deviates from the Eq.-1 value {expected}"),
                ));
            }
        }
        RtBlock::For { from, to, by, body, parfor, known_trip, .. } => {
            let np = from.insts.len() + to.insts.len() + by.as_ref().map_or(0, |p| p.insts.len());
            if insts.len() != np || blocks.len() != body.len() {
                structural_warning(label, idx, ctx);
                return;
            }
            for (rb, cn) in body.iter().zip(blocks) {
                check_block(rb, cn, idx, ctx);
            }
            let n_iter = known_trip.unwrap_or(ctx.cfg.unknown_iterations).max(0.0);
            let w = if *parfor {
                (n_iter / ctx.cc.k_local.max(1) as f64).ceil()
            } else {
                n_iter
            };
            check_loop_bounds(label, *total, sum(insts), sum(blocks), w, idx, ctx);
        }
        RtBlock::While { pred, body, .. } => {
            if insts.len() != pred.insts.len() || blocks.len() != body.len() {
                structural_warning(label, idx, ctx);
                return;
            }
            for (rb, cn) in body.iter().zip(blocks) {
                check_block(rb, cn, idx, ctx);
            }
            let n_iter = ctx.cfg.unknown_iterations.max(0.0);
            // The predicate runs N̂+1 times, the body follows the For
            // first/steady split with weight N̂.
            check_loop_bounds(label, *total, sum(insts) * (n_iter + 1.0), sum(blocks), n_iter, idx, ctx);
        }
        RtBlock::FCall { fname, .. } => {
            let recursive = ctx.call_stack.iter().any(|f| f == fname);
            let func = ctx.rt.funcs.get(fname);
            if recursive || func.is_none() {
                // The estimator prices unknown / recursive calls at 0.
                if *total != 0.0 || !children.is_empty() {
                    ctx.findings.push((
                        idx,
                        Severity::Error,
                        format!(
                            "{label}: a {} call must cost exactly 0, got {total}",
                            if recursive { "recursive" } else { "unknown-function" }
                        ),
                    ));
                }
                return;
            }
            let func = func.unwrap();
            if !insts.is_empty() || blocks.len() != func.blocks.len() {
                structural_warning(label, idx, ctx);
                return;
            }
            ctx.call_stack.push(fname.clone());
            for (rb, cn) in func.blocks.iter().zip(blocks) {
                check_block(rb, cn, idx, ctx);
            }
            ctx.call_stack.pop();
            let expected = sum(blocks);
            if total.is_finite() && !close(*total, expected) {
                ctx.findings.push((
                    idx,
                    Severity::Error,
                    format!(
                        "{label}: total {total} deviates from the sum of the \
                         function body costs {expected}"
                    ),
                ));
            }
        }
    }
}

/// Bound-check a loop total. The tree materialises only the *first*
/// iteration's body nodes; the steady-state cost satisfies
/// `0 ≤ steady ≤ first`, so for `w ≥ 1`:
/// `pred + first ≤ total ≤ pred + w·first`, and for `w < 1` the exact
/// value `pred + w·first` is required.
fn check_loop_bounds(
    label: &str,
    total: f64,
    pred: f64,
    first: f64,
    w: f64,
    idx: usize,
    ctx: &mut Ctx,
) {
    if !total.is_finite() || !pred.is_finite() || !first.is_finite() {
        return; // finiteness already reported at the source
    }
    if w >= 1.0 {
        let lo = pred + first;
        let hi = pred + w * first;
        let eps = 1e-9 * hi.abs().max(1.0);
        if total < lo - eps || total > hi + eps {
            ctx.findings.push((
                idx,
                Severity::Error,
                format!(
                    "{label}: total {total} outside the Eq.-1 bounds \
                     [{lo}, {hi}] (w={w})"
                ),
            ));
        }
    } else {
        let expected = pred + w * first;
        if !close(total, expected) {
            ctx.findings.push((
                idx,
                Severity::Error,
                format!("{label}: total {total} deviates from the Eq.-1 value {expected} (w={w})"),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{CompileOptions, Scenario};
    use crate::ir::Lit;
    use crate::matrix::{Format, MatrixCharacteristics};
    use crate::rtprog::{CpInst, CpOp, Instr, Operand, PredProg};

    fn defaults() -> (SystemConfig, ClusterConfig, CostConstants) {
        (SystemConfig::default(), ClusterConfig::paper_cluster(), CostConstants::default())
    }

    #[test]
    fn bundled_plans_satisfy_all_invariants() {
        let (cfg, cc, k) = defaults();
        for backend in crate::rtprog::ExecBackend::all() {
            let opts = CompileOptions { backend, ..CompileOptions::default() };
            let c = Scenario::xs().compile(&opts);
            let f = audit(&c.runtime, &cfg, &cc, &k);
            assert!(f.is_empty(), "[{}] {f:?}", backend.name());
        }
    }

    #[test]
    fn bundled_plans_satisfy_all_invariants_under_faults() {
        // The Eq.-1 identities and the bitwise cache replay must hold
        // for fault-priced costs too — retries inflate the numbers, not
        // the structure of the aggregation.
        let (cfg, cc, k) = defaults();
        let chaos = FaultProfile::chaos();
        for backend in crate::rtprog::ExecBackend::all() {
            let opts = CompileOptions { backend, ..CompileOptions::default() };
            let c = Scenario::xs().compile(&opts);
            let f = audit_faults(&c.runtime, &cfg, &cc, &k, &chaos);
            assert!(f.is_empty(), "[{}] {f:?}", backend.name());
        }
    }

    #[test]
    fn non_finite_cost_is_an_error() {
        // Zero HDFS bandwidth prices the persistent read at +inf.
        let (cfg, cc, _) = defaults();
        let k = CostConstants { hdfs_read_binaryblock: 0.0, ..CostConstants::default() };
        let rt = RtProgram {
            blocks: vec![RtBlock::Generic {
                insts: vec![
                    Instr::CreateVar {
                        var: "X".into(),
                        path: "data/X".into(),
                        temp: false,
                        format: Format::BinaryBlock,
                        mc: MatrixCharacteristics::dense(10_000, 1_000, 1_000),
                    },
                    Instr::Cp(CpInst {
                        op: CpOp::AggUnary(crate::ir::AggOp::Sum, crate::ir::AggDir::All),
                        inputs: vec![Operand::Mat("X".into())],
                        output: Operand::Scalar("s".into(), crate::ir::ValueType::Double),
                    }),
                ],
                lines: (1, 1),
                recompile: false,
            }],
            funcs: Default::default(),
        };
        let f = audit(&rt, &cfg, &cc, &k);
        assert!(
            f.iter().any(|(_, s, m)| *s == Severity::Error && m.contains("not finite")),
            "{f:?}"
        );
    }

    #[test]
    fn while_loop_bounds_hold_on_a_synthetic_plan() {
        let (cfg, cc, k) = defaults();
        let body = RtBlock::Generic {
            insts: vec![Instr::AssignVar { lit: Lit::Int(1), var: "t".into() }],
            lines: (2, 2),
            recompile: false,
        };
        let rt = RtProgram {
            blocks: vec![
                RtBlock::Generic {
                    insts: vec![Instr::AssignVar { lit: Lit::Bool(true), var: "c".into() }],
                    lines: (1, 1),
                    recompile: false,
                },
                RtBlock::While {
                    pred: PredProg {
                        insts: vec![],
                        result: Some(Operand::Scalar("c".into(), crate::ir::ValueType::Bool)),
                    },
                    body: vec![body],
                    lines: (2, 3),
                },
            ],
            funcs: Default::default(),
        };
        assert!(audit(&rt, &cfg, &cc, &k).is_empty(), "{:?}", audit(&rt, &cfg, &cc, &k));
    }
}
