//! Dataflow lint: def-use / liveness analysis over runtime plans.
//!
//! Walks the runtime program exactly as the interpreter would — straight
//! through generic blocks, into predicate programs, across If joins,
//! through (par)for / while bodies and into called functions — threading
//! a variable scope split into *definitely defined* and *conditionally
//! defined* (`maybe`) names. Four lints share the walk:
//!
//! * **use-before-definition** (error) — an instruction reads a name no
//!   prior instruction on every path defines;
//! * **conditional definition** (warning) — a read of a variable written
//!   in only one If-branch, or only inside a loop body that may execute
//!   zero times;
//! * **dead instruction** (warning) — a CP instruction or distributed
//!   job whose temp results are never consumed by anything but `rmvar`;
//! * **leaked temp** (warning) — a temp intermediate created inside a
//!   block but never freed by an `rmvar` before the block ends (a leak
//!   candidate for a long-lived serve daemon).

use std::collections::{BTreeMap, BTreeSet};

use super::{Finding, Severity};
use crate::rtprog::{CpOp, Instr, MrOp, PredProg, RtBlock, RtProgram};

/// Variable scope at a program point.
#[derive(Clone, Default)]
struct Scope {
    /// Defined on every path reaching this point.
    defined: BTreeSet<String>,
    /// Defined on some but not all paths; value is the reason shown in
    /// the conditional-definition warning.
    maybe: BTreeMap<String, &'static str>,
}

impl Scope {
    fn define(&mut self, name: &str) {
        self.defined.insert(name.to_string());
        self.maybe.remove(name);
    }

    fn remove(&mut self, name: &str) {
        self.defined.remove(name);
        self.maybe.remove(name);
    }
}

struct Ctx<'a> {
    rt: &'a RtProgram,
    findings: Vec<Finding>,
    /// Dedupe key: (kind, variable, location) — each lint fires once per
    /// variable per location, not once per read.
    reported: BTreeSet<(&'static str, String, String)>,
    /// Active function-call stack (recursion guard).
    stack: Vec<String>,
    /// `"in function f: "` while walking a function body, else empty.
    fn_prefix: String,
}

impl Ctx<'_> {
    fn emit(&mut self, kind: &'static str, var: &str, loc: &str, idx: usize, sev: Severity, msg: String) {
        let key = (kind, var.to_string(), loc.to_string());
        if self.reported.insert(key) {
            self.findings.push((idx, sev, msg));
        }
    }
}

/// Run the dataflow lint over a whole runtime program.
pub(crate) fn lint(rt: &RtProgram) -> Vec<Finding> {
    let mut ctx = Ctx {
        rt,
        findings: Vec::new(),
        reported: BTreeSet::new(),
        stack: Vec::new(),
        fn_prefix: String::new(),
    };
    let mut scope = Scope::default();
    for (i, b) in rt.blocks.iter().enumerate() {
        walk_block(b, &mut scope, i, &mut ctx);
    }
    ctx.findings
}

/// Invoke `f` for every variable name an instruction reads.
fn for_each_read(inst: &Instr, f: &mut dyn FnMut(&str)) {
    match inst {
        Instr::CreateVar { .. } | Instr::AssignVar { .. } => {}
        Instr::CpVar { src, .. } => f(src),
        Instr::RmVar { .. } => {} // handled separately (removal, not a value read)
        Instr::Cp(c) => {
            for op in &c.inputs {
                if let Some(n) = op.name() {
                    f(n);
                }
            }
        }
        Instr::MrJob(j) => {
            for n in &j.inputs {
                f(n);
            }
            for mi in j.all_insts() {
                if let MrOp::ScalarBin { scalar_var: Some(v), .. } = &mi.op {
                    f(v);
                }
            }
        }
        Instr::SparkJob(j) => {
            for n in &j.inputs {
                f(n);
            }
            for mi in j.all_insts() {
                if let MrOp::ScalarBin { scalar_var: Some(v), .. } = &mi.op {
                    f(v);
                }
            }
        }
    }
}

/// Invoke `f` for every variable name an instruction defines.
fn for_each_def(inst: &Instr, f: &mut dyn FnMut(&str)) {
    match inst {
        Instr::CreateVar { var, .. } | Instr::AssignVar { var, .. } => f(var),
        Instr::CpVar { dst, .. } => f(dst),
        Instr::RmVar { .. } => {}
        Instr::Cp(c) => {
            if let Some(n) = c.output.name() {
                f(n);
            }
        }
        Instr::MrJob(j) => {
            for n in &j.outputs {
                f(n);
            }
        }
        Instr::SparkJob(j) => {
            for n in &j.outputs {
                f(n);
            }
        }
    }
}

/// Collect every name a block list can define (used to pre-seed loop
/// bodies so loop-carried reads resolve as *conditional*, not undefined).
fn collect_defs(blocks: &[RtBlock], out: &mut BTreeSet<String>) {
    let mut collect_insts = |insts: &[Instr], out: &mut BTreeSet<String>| {
        for i in insts {
            for_each_def(i, &mut |n| {
                out.insert(n.to_string());
            });
        }
    };
    for b in blocks {
        match b {
            RtBlock::Generic { insts, .. } => collect_insts(insts, out),
            RtBlock::If { pred, then_blocks, else_blocks, .. } => {
                collect_insts(&pred.insts, out);
                collect_defs(then_blocks, out);
                collect_defs(else_blocks, out);
            }
            RtBlock::For { var, from, to, by, body, .. } => {
                out.insert(var.clone());
                collect_insts(&from.insts, out);
                collect_insts(&to.insts, out);
                if let Some(by) = by {
                    collect_insts(&by.insts, out);
                }
                collect_defs(body, out);
            }
            RtBlock::While { pred, body, .. } => {
                collect_insts(&pred.insts, out);
                collect_defs(body, out);
            }
            RtBlock::FCall { outputs, .. } => {
                for o in outputs {
                    out.insert(o.clone());
                }
            }
        }
    }
}

/// Check one read against the scope.
fn read_var(name: &str, scope: &Scope, loc: &str, idx: usize, ctx: &mut Ctx) {
    if scope.defined.contains(name) {
        return;
    }
    if let Some(reason) = scope.maybe.get(name).copied() {
        ctx.emit(
            "maybe",
            name,
            loc,
            idx,
            Severity::Warning,
            format!("{}read of '{name}' {reason} ({loc})", ctx.fn_prefix),
        );
        return;
    }
    ctx.emit(
        "undef",
        name,
        loc,
        idx,
        Severity::Error,
        format!("{}use of undefined variable '{name}' ({loc})", ctx.fn_prefix),
    );
}

/// Walk a straight-line instruction list, checking reads/defs in order.
fn walk_insts(insts: &[Instr], scope: &mut Scope, loc: &str, idx: usize, ctx: &mut Ctx) {
    for inst in insts {
        let mut reads: Vec<String> = Vec::new();
        for_each_read(inst, &mut |n| reads.push(n.to_string()));
        for n in &reads {
            read_var(n, scope, loc, idx, ctx);
        }
        if let Instr::RmVar { vars } = inst {
            for v in vars {
                if !scope.defined.contains(v) && !scope.maybe.contains_key(v) {
                    ctx.emit(
                        "undef",
                        v,
                        loc,
                        idx,
                        Severity::Error,
                        format!("{}rmvar of undefined variable '{v}' ({loc})", ctx.fn_prefix),
                    );
                }
                scope.remove(v);
            }
        }
        let mut defs: Vec<String> = Vec::new();
        for_each_def(inst, &mut |n| defs.push(n.to_string()));
        for n in &defs {
            scope.define(n);
        }
    }
}

/// Is this name a temp intermediate (the same convention
/// `rtprog/gen.rs::insert_rmvars` frees by): a `createvar ... true`
/// handle or a generated `_mVar` result name?
fn temp_set(insts: &[Instr]) -> BTreeSet<String> {
    let mut temps = BTreeSet::new();
    for inst in insts {
        if let Instr::CreateVar { var, temp: true, .. } = inst {
            temps.insert(var.clone());
        }
        let mut defs: Vec<String> = Vec::new();
        for_each_def(inst, &mut |n| defs.push(n.to_string()));
        for n in defs {
            if n.starts_with("_mVar") {
                temps.insert(n);
            }
        }
    }
    temps
}

/// Dead-instruction + leaked-temp lint over one straight-line list.
/// `keep` exempts a predicate program's result operand (consumed by the
/// control-flow machinery, not by an instruction).
fn liveness_lint(insts: &[Instr], keep: Option<&str>, loc: &str, idx: usize, ctx: &mut Ctx) {
    let temps = temp_set(insts);
    // Dead instructions: every temp result unconsumed downstream.
    for (j, inst) in insts.iter().enumerate() {
        let op_code = match inst {
            Instr::Cp(c) => match &c.op {
                CpOp::Write { .. } | CpOp::Print => continue, // side effects
                op => op.code(),
            },
            Instr::MrJob(job) => format!("MR-{}", job.job_type.name()),
            Instr::SparkJob(_) => "SPARK".to_string(),
            _ => continue, // bookkeeping
        };
        let mut outs: Vec<String> = Vec::new();
        for_each_def(inst, &mut |n| outs.push(n.to_string()));
        if outs.is_empty()
            || !outs.iter().all(|o| temps.contains(o) && Some(o.as_str()) != keep)
        {
            continue;
        }
        let consumed = outs.iter().any(|o| {
            insts[j + 1..].iter().any(|later| {
                let mut hit = false;
                for_each_read(later, &mut |n| hit |= n == o);
                hit
            })
        });
        if !consumed {
            let out = outs.join(", ");
            ctx.emit(
                "dead",
                &out,
                loc,
                idx,
                Severity::Warning,
                format!(
                    "{}dead instruction: result '{out}' of {op_code} is never consumed ({loc})",
                    ctx.fn_prefix
                ),
            );
        }
    }
    // Leaked temps: created but never freed before the block ends.
    let mut freed = BTreeSet::new();
    for inst in insts {
        if let Instr::RmVar { vars } = inst {
            for v in vars {
                freed.insert(v.clone());
            }
        }
    }
    for t in &temps {
        if !freed.contains(t) && Some(t.as_str()) != keep {
            ctx.emit(
                "leak",
                t,
                loc,
                idx,
                Severity::Warning,
                format!(
                    "{}temp '{t}' is created but never freed — leak candidate ({loc})",
                    ctx.fn_prefix
                ),
            );
        }
    }
}

/// Walk one predicate program in the enclosing scope.
fn walk_pred(pred: &PredProg, scope: &mut Scope, loc: &str, idx: usize, ctx: &mut Ctx) {
    walk_insts(&pred.insts, scope, loc, idx, ctx);
    if let Some(r) = &pred.result {
        if let Some(n) = r.name() {
            read_var(n, scope, loc, idx, ctx);
        }
    }
    let keep = pred.result.as_ref().and_then(|r| r.name());
    liveness_lint(&pred.insts, keep, loc, idx, ctx);
}

fn walk_blocks(blocks: &[RtBlock], scope: &mut Scope, idx: usize, ctx: &mut Ctx) {
    for b in blocks {
        walk_block(b, scope, idx, ctx);
    }
}

fn walk_block(block: &RtBlock, scope: &mut Scope, idx: usize, ctx: &mut Ctx) {
    match block {
        RtBlock::Generic { insts, lines, .. } => {
            let loc = format!("lines {}-{}", lines.0, lines.1);
            walk_insts(insts, scope, &loc, idx, ctx);
            liveness_lint(insts, None, &loc, idx, ctx);
        }
        RtBlock::If { pred, then_blocks, else_blocks, lines } => {
            let loc = format!("if predicate, lines {}-{}", lines.0, lines.1);
            walk_pred(pred, scope, &loc, idx, ctx);
            let mut then_s = scope.clone();
            let mut else_s = scope.clone();
            walk_blocks(then_blocks, &mut then_s, idx, ctx);
            walk_blocks(else_blocks, &mut else_s, idx, ctx);
            let defined: BTreeSet<String> =
                then_s.defined.intersection(&else_s.defined).cloned().collect();
            let one_sided: Vec<String> = then_s
                .defined
                .symmetric_difference(&else_s.defined)
                .cloned()
                .collect();
            let mut maybe = then_s.maybe;
            for (k, v) in else_s.maybe {
                maybe.entry(k).or_insert(v);
            }
            for v in one_sided {
                maybe.entry(v).or_insert("defined in only one If-branch");
            }
            for v in &defined {
                maybe.remove(v);
            }
            scope.defined = defined;
            scope.maybe = maybe;
        }
        RtBlock::For { var, from, to, by, body, known_trip, lines, .. } => {
            let loc = format!("for bounds, lines {}-{}", lines.0, lines.1);
            walk_pred(from, scope, &loc, idx, ctx);
            walk_pred(to, scope, &loc, idx, ctx);
            if let Some(by) = by {
                walk_pred(by, scope, &loc, idx, ctx);
            }
            scope.define(var);
            walk_loop_body(body, scope, idx, ctx, known_trip.is_some_and(|n| n >= 1.0));
        }
        RtBlock::While { pred, body, lines } => {
            let loc = format!("while predicate, lines {}-{}", lines.0, lines.1);
            walk_pred(pred, scope, &loc, idx, ctx);
            walk_loop_body(body, scope, idx, ctx, false);
        }
        RtBlock::FCall { fname, args, outputs, lines } => {
            let loc = format!("fcall {fname}, lines {}-{}", lines.0, lines.1);
            for a in args {
                read_var(a, scope, &loc, idx, ctx);
            }
            if let Some(func) = ctx.rt.funcs.get(fname) {
                if !ctx.stack.iter().any(|f| f == fname) {
                    ctx.stack.push(fname.clone());
                    let saved_prefix =
                        std::mem::replace(&mut ctx.fn_prefix, format!("in function {fname}: "));
                    let mut fscope = Scope::default();
                    for p in &func.params {
                        fscope.define(p);
                    }
                    walk_blocks(&func.blocks, &mut fscope, idx, ctx);
                    ctx.fn_prefix = saved_prefix;
                    ctx.stack.pop();
                }
            } else {
                ctx.emit(
                    "undef",
                    fname,
                    &loc,
                    idx,
                    Severity::Error,
                    format!("{}call to unknown function '{fname}' ({loc})", ctx.fn_prefix),
                );
            }
            for o in outputs {
                scope.define(o);
            }
        }
    }
}

/// Walk a loop body: pre-seed all body definitions as *conditional* so
/// loop-carried reads resolve without false use-before-def errors, then
/// downgrade anything newly defined back to conditional unless the loop
/// is statically known to run at least once.
fn walk_loop_body(
    body: &[RtBlock],
    scope: &mut Scope,
    idx: usize,
    ctx: &mut Ctx,
    runs_at_least_once: bool,
) {
    let mut body_defs = BTreeSet::new();
    collect_defs(body, &mut body_defs);
    for d in &body_defs {
        if !scope.defined.contains(d) {
            scope
                .maybe
                .entry(d.clone())
                .or_insert("defined only inside a loop that may run zero times");
        }
    }
    let before: BTreeSet<String> = scope.defined.clone();
    walk_blocks(body, scope, idx, ctx);
    if !runs_at_least_once {
        let new_defs: Vec<String> = scope.defined.difference(&before).cloned().collect();
        for d in new_defs {
            scope.defined.remove(&d);
            scope
                .maybe
                .entry(d)
                .or_insert("defined only inside a loop that may run zero times");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Lit;
    use crate::matrix::{Format, MatrixCharacteristics};
    use crate::rtprog::{CpInst, Operand};

    fn mat(n: &str) -> Operand {
        Operand::Mat(n.into())
    }

    fn createvar(var: &str, temp: bool) -> Instr {
        Instr::CreateVar {
            var: var.into(),
            path: format!("scratch/{var}"),
            temp,
            format: Format::BinaryBlock,
            mc: MatrixCharacteristics::dense(10, 10, 10),
        }
    }

    fn transpose(input: &str, output: &str) -> Instr {
        Instr::Cp(CpInst {
            op: CpOp::Transpose,
            inputs: vec![mat(input)],
            output: mat(output),
        })
    }

    fn generic(insts: Vec<Instr>) -> RtBlock {
        RtBlock::Generic { insts, lines: (1, 1), recompile: false }
    }

    fn prog(blocks: Vec<RtBlock>) -> RtProgram {
        RtProgram { blocks, funcs: BTreeMap::new() }
    }

    #[test]
    fn use_before_def_is_an_error() {
        let rt = prog(vec![generic(vec![transpose("X", "_mVar1")])]);
        let f = lint(&rt);
        assert!(
            f.iter().any(|(_, s, m)| *s == Severity::Error
                && m.contains("use of undefined variable 'X'")),
            "{f:?}"
        );
    }

    #[test]
    fn clean_block_has_no_findings() {
        let rt = prog(vec![generic(vec![
            createvar("X", false),
            createvar("_mVar1", true),
            transpose("X", "_mVar1"),
            Instr::Cp(CpInst {
                op: CpOp::Write { path: "out".into(), format: Format::BinaryBlock },
                inputs: vec![mat("_mVar1")],
                output: Operand::Lit(Lit::Str("out".into())),
            }),
            Instr::RmVar { vars: vec!["_mVar1".into()] },
        ])]);
        assert!(lint(&rt).is_empty(), "{:?}", lint(&rt));
    }

    #[test]
    fn dead_instruction_and_leak_are_warnings() {
        let rt = prog(vec![generic(vec![
            createvar("X", false),
            transpose("X", "_mVar1"), // never consumed, never freed
        ])]);
        let f = lint(&rt);
        assert!(f.iter().any(|(_, s, m)| *s == Severity::Warning
            && m.contains("dead instruction")), "{f:?}");
        assert!(f.iter().any(|(_, s, m)| *s == Severity::Warning
            && m.contains("never freed")), "{f:?}");
        assert!(f.iter().all(|(_, s, _)| *s == Severity::Warning), "{f:?}");
    }

    #[test]
    fn one_sided_branch_write_read_after_join_warns() {
        let assign = |v: &str| Instr::AssignVar { lit: Lit::Int(1), var: v.into() };
        let read_q = Instr::Cp(CpInst {
            op: CpOp::Print,
            inputs: vec![Operand::Scalar("q".into(), crate::ir::ValueType::Int)],
            output: Operand::Lit(Lit::Int(0)),
        });
        let rt = prog(vec![
            generic(vec![assign("c")]),
            RtBlock::If {
                pred: PredProg {
                    insts: vec![],
                    result: Some(Operand::Scalar("c".into(), crate::ir::ValueType::Int)),
                },
                then_blocks: vec![generic(vec![assign("q")])],
                else_blocks: vec![],
                lines: (2, 4),
            },
            generic(vec![read_q]),
        ]);
        let f = lint(&rt);
        assert!(
            f.iter().any(|(_, s, m)| *s == Severity::Warning
                && m.contains("read of 'q'")
                && m.contains("only one If-branch")),
            "{f:?}"
        );
    }

    #[test]
    fn loop_carried_defs_do_not_false_positive() {
        // while body defines t then reads it next iteration: warning at
        // worst (conditional), never an undefined-variable error.
        let assign = |v: &str| Instr::AssignVar { lit: Lit::Int(1), var: v.into() };
        let rt = prog(vec![
            generic(vec![assign("c")]),
            RtBlock::While {
                pred: PredProg {
                    insts: vec![],
                    result: Some(Operand::Scalar("c".into(), crate::ir::ValueType::Int)),
                },
                body: vec![generic(vec![
                    Instr::Cp(CpInst {
                        op: CpOp::Print,
                        inputs: vec![Operand::Scalar("t".into(), crate::ir::ValueType::Int)],
                        output: Operand::Lit(Lit::Int(0)),
                    }),
                    assign("t"),
                ])],
                lines: (2, 5),
            },
        ]);
        let f = lint(&rt);
        assert!(f.iter().all(|(_, s, _)| *s == Severity::Warning), "{f:?}");
        assert!(
            f.iter().any(|(_, _, m)| m.contains("read of 't'") && m.contains("loop")),
            "{f:?}"
        );
    }

    #[test]
    fn for_with_known_trip_keeps_body_defs_definite() {
        let assign = |v: &str| Instr::AssignVar { lit: Lit::Int(1), var: v.into() };
        let read = |v: &str| {
            Instr::Cp(CpInst {
                op: CpOp::Print,
                inputs: vec![Operand::Scalar(v.into(), crate::ir::ValueType::Int)],
                output: Operand::Lit(Lit::Int(0)),
            })
        };
        let rt = prog(vec![
            RtBlock::For {
                var: "i".into(),
                from: PredProg { insts: vec![], result: Some(Operand::Lit(Lit::Int(1))) },
                to: PredProg { insts: vec![], result: Some(Operand::Lit(Lit::Int(3))) },
                by: None,
                body: vec![generic(vec![assign("acc")])],
                parfor: false,
                known_trip: Some(3.0),
                lines: (1, 3),
            },
            generic(vec![read("acc")]),
        ]);
        assert!(lint(&rt).is_empty(), "{:?}", lint(&rt));
    }
}
