//! Static plan verification: a three-pass analyzer over compiled
//! [`RtProgram`]s that proves a winning plan is well-formed *before* it
//! is reported, executed or persisted as an artifact.
//!
//! The paper's central claim — costing generated runtime plans
//! "automatically reflects all successive optimization phases" — cuts
//! both ways: every optimizer bug (sweep, resource grid, gdf rewrites,
//! per-group backends) surfaces as a silently mispriced or semantically
//! broken plan. The passes here re-derive, independently of plan
//! generation, the properties the cost model takes on faith:
//!
//! 1. **dataflow lint** ([`dataflow`]) — def-use and liveness analysis
//!    across runtime blocks and control flow, flagging
//!    use-before-definition, dead instructions whose results are never
//!    consumed, temp intermediates that are created but never freed
//!    (leak candidates), and variables written in only one If-branch but
//!    read after the join;
//! 2. **shape & memory audit** ([`shape`]) — an independent
//!    re-propagation of matrix dimensions through the runtime plan
//!    (double-entry bookkeeping against the sizes
//!    `ir/size_prop.rs` stamped into `createvar`/job metadata), plus a
//!    static peak-operand-memory check per block against the configured
//!    CP heap and broadcast budgets;
//! 3. **cost-invariant audit** ([`invariants`]) — every costed block
//!    must be finite, non-negative and consistent with the paper's
//!    Eq.-1 control-flow aggregation identities, and the block-level
//!    cost cache must reproduce the uncached total bitwise.
//!
//! Diagnostics are structured ([`Diagnostic`]) and deterministically
//! ordered, keyed by the same 128-bit structural block hashes the cost
//! cache uses ([`crate::cost::cache::program_hashes`]), so a diagnostic
//! survives re-compilation of an identical plan. Entry points:
//! [`verify`] here, [`crate::api::verify_plan`] for compiled programs,
//! the `repro verify` subcommand, and the `--verify` flag on the sweep /
//! resource / gdf optimizers (which audits the winning candidate and
//! fails the run on error severity).

#![warn(missing_docs)]

pub mod dataflow;
pub mod invariants;
pub mod shape;

use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::cost::cache;
use crate::rtprog::{ExecBackend, RtProgram};

/// Sentinel block index for program-level findings (e.g. the cached
/// total diverging from the uncached total); mapped to the program's
/// root hash instead of a block hash.
pub(crate) const PROGRAM_SCOPE: usize = usize::MAX;

/// Analyzer pass that produced a diagnostic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Pass {
    /// Def-use / liveness lint over blocks and control flow.
    Dataflow,
    /// Independent shape re-propagation + static memory-budget audit.
    Shape,
    /// Finite/non-negative/Eq.-1/cache-consistency cost audit.
    CostInvariants,
}

impl Pass {
    /// Short lower-case label used in rendered diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            Pass::Dataflow => "dataflow",
            Pass::Shape => "shape",
            Pass::CostInvariants => "cost",
        }
    }
}

/// Severity of a diagnostic.
///
/// Policy: **error** marks a plan the interpreter could execute
/// incorrectly or not at all (use of an undefined variable, a shape
/// contradiction, an over-budget operator on a distributed backend, a
/// non-finite or inconsistent cost); **warning** marks waste or a
/// deliberate degradation (dead instructions, leaked temps,
/// conditionally-defined reads, over-budget operators on the CP-forced
/// backend — where oversized single-node execution is the *point* of
/// the plan family and the cost model charges it honestly).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Suspicious but executable: waste, leaks, conditional definitions.
    Warning,
    /// The plan is malformed; optimizer `--verify` runs fail on these.
    Error,
}

impl Severity {
    /// Short lower-case label used in rendered diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warning => "warn",
            Severity::Error => "error",
        }
    }
}

/// One structured finding of the static analyzer.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    /// Pass that produced the finding.
    pub pass: Pass,
    /// Error-vs-warning classification (see [`Severity`] for the policy).
    pub severity: Severity,
    /// Structural hash (`h1` of the cost cache's 128-bit block hash) of
    /// the enclosing *top-level* block — stable across re-compilations
    /// of an identical plan; the program root hash for program-level
    /// findings.
    pub block_hash: u64,
    /// Human-readable description of the finding.
    pub message: String,
}

impl Diagnostic {
    /// One-line rendering: `[pass] severity block=<16-hex> message`.
    pub fn render(&self) -> String {
        format!(
            "[{}] {} block={:016x} {}",
            self.pass.name(),
            self.severity.name(),
            self.block_hash,
            self.message
        )
    }
}

/// Result of verifying one runtime plan: all diagnostics from all
/// passes, in deterministic order (pass, block index, severity,
/// message).
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    /// All findings, deterministically ordered.
    pub diagnostics: Vec<Diagnostic>,
    /// Number of top-level blocks audited.
    pub blocks: usize,
    /// Backend the severity policy was applied for.
    pub backend: ExecBackend,
}

impl VerifyReport {
    /// Number of error-severity diagnostics.
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    /// Number of warning-severity diagnostics.
    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// A plan is clean when no error-severity diagnostic was raised.
    pub fn is_clean(&self) -> bool {
        self.errors() == 0
    }

    /// Deterministic multi-line rendering of every diagnostic (empty
    /// string when the plan has none).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.render());
            out.push('\n');
        }
        out
    }

    /// One-line summary, e.g.
    /// `verify: 2 diagnostics (0 errors, 2 warnings) over 4 blocks [mr]`.
    pub fn summary(&self) -> String {
        format!(
            "verify: {} diagnostics ({} errors, {} warnings) over {} blocks [{}]",
            self.diagnostics.len(),
            self.errors(),
            self.warnings(),
            self.blocks,
            self.backend.name()
        )
    }
}

/// A raw finding as the passes produce it: top-level block index (or
/// [`PROGRAM_SCOPE`]), severity, message. The orchestrator attaches the
/// pass tag and resolves the index to a structural hash.
pub(crate) type Finding = (usize, Severity, String);

/// Run all three verification passes over a runtime plan and return the
/// deterministically ordered report.
///
/// `backend` is the plan's (effective) execution backend and only
/// steers the severity policy: over-budget CP operators are warnings on
/// [`ExecBackend::Cp`] (forcing oversized data through the single node
/// is that plan family's contract) and errors otherwise.
pub fn verify(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    backend: ExecBackend,
) -> VerifyReport {
    verify_faults(rt, cfg, cc, k, &FaultProfile::none(), backend)
}

/// [`verify`] under a failure profile: the cost-invariant pass re-costs
/// the plan with the same retry/straggler pricing the optimizer used
/// (see [`crate::conf::FaultProfile`]), so a `--verify` run audits the
/// exact numbers that decided the plan. Dataflow and shape passes are
/// fault-independent. With [`FaultProfile::none`] this is
/// bitwise-identical to [`verify`].
pub fn verify_faults(
    rt: &RtProgram,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    k: &CostConstants,
    fault: &FaultProfile,
    backend: ExecBackend,
) -> VerifyReport {
    let hashes = cache::program_hashes(rt);
    let roots = hashes.block_roots();
    let mut raw: Vec<(Pass, usize, Severity, String)> = Vec::new();
    for (b, s, m) in dataflow::lint(rt) {
        raw.push((Pass::Dataflow, b, s, m));
    }
    for (b, s, m) in shape::audit(rt, cfg, cc, backend) {
        raw.push((Pass::Shape, b, s, m));
    }
    for (b, s, m) in invariants::audit_faults(rt, cfg, cc, k, fault) {
        raw.push((Pass::CostInvariants, b, s, m));
    }
    raw.sort_by(|a, b| {
        a.0.cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)).then(a.3.cmp(&b.3))
    });
    let diagnostics = raw
        .into_iter()
        .map(|(pass, block, severity, message)| Diagnostic {
            pass,
            severity,
            block_hash: match roots.get(block) {
                Some(&(h1, _)) => h1,
                None => hashes.root().0,
            },
            message,
        })
        .collect();
    VerifyReport { diagnostics, blocks: rt.blocks.len(), backend }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{CompileOptions, Scenario};

    #[test]
    fn severity_orders_warning_before_error() {
        assert!(Severity::Warning < Severity::Error);
    }

    #[test]
    fn clean_scenario_verifies_without_errors() {
        let opts = CompileOptions::default();
        let c = Scenario::xs().compile(&opts);
        let r = verify(
            &c.runtime,
            &opts.cfg,
            &opts.cc.0,
            &CostConstants::default(),
            opts.backend,
        );
        assert!(r.is_clean(), "XS/MR should verify clean:\n{}", r.render());
        assert_eq!(r.blocks, c.runtime.blocks.len());
    }

    #[test]
    fn report_rendering_is_deterministic() {
        let opts = CompileOptions::default();
        let c = Scenario::xl1().compile(&opts);
        let k = CostConstants::default();
        let a = verify(&c.runtime, &opts.cfg, &opts.cc.0, &k, opts.backend);
        let b = verify(&c.runtime, &opts.cfg, &opts.cc.0, &k, opts.backend);
        assert_eq!(a.render(), b.render());
        assert_eq!(a.summary(), b.summary());
    }

    #[test]
    fn diagnostic_renders_pass_severity_and_hash() {
        let d = Diagnostic {
            pass: Pass::Dataflow,
            severity: Severity::Error,
            block_hash: 0xabcd,
            message: "boom".into(),
        };
        let s = d.render();
        assert!(s.starts_with("[dataflow] error block=000000000000abcd boom"), "{s}");
    }
}
