//! Static HOP rewrites (paper §2, Figure 1 discussion):
//!
//! 1. **Constant folding + inter-block constant propagation** — `intercept
//!    == 1` with `intercept = $3 = 0` folds to `FALSE`.
//! 2. **Branch removal** — constant-predicate `if` blocks are spliced away
//!    (the paper's lines 4–7 disappear from the XS plan).
//! 3. **Dead transient-write elimination** — TWrites of variables never
//!    read later are dropped (Figure 1's second block has no TWrites).
//! 4. **Algebraic simplification** — e.g. `diag(matrix(1,…))*λ →
//!    diag(matrix(λ,…))`, `t(t(X)) → X`, `X*1 → X`, "which prevents one
//!    unnecessary intermediate".
//! 5. **Common subexpression elimination** — `t(X)` is computed once and
//!    shared by both matrix multiplications (HOP 52 in Figure 1).

use std::collections::{HashMap, HashSet};

use super::*;

/// Run the full static rewrite pipeline.
pub fn rewrite_program(prog: &mut Program) {
    // Constant propagation and branch removal interact; iterate to fixpoint
    // (bounded — each removal strictly shrinks the block tree).
    for _ in 0..8 {
        let mut consts = HashMap::new();
        const_propagate(&mut prog.blocks, &mut consts, &prog.funcs.clone());
        if !remove_branches(&mut prog.blocks) {
            break;
        }
    }
    remove_dead_twrites(prog);
    prog.for_each_dag_mut(&mut |dag| {
        algebraic_dag(dag);
        fold_dag(dag, &HashMap::new());
        cse_dag(dag);
    });
}

// ---------------------------------------------------------------------
// Constant folding + propagation
// ---------------------------------------------------------------------

type ConstTab = HashMap<String, Lit>;

/// Fold scalar expressions inside each DAG and propagate scalar literals
/// across blocks (forward). Conservative at loops and branches.
fn const_propagate(
    blocks: &mut [Block],
    consts: &mut ConstTab,
    funcs: &std::collections::BTreeMap<String, Function>,
) {
    for b in blocks {
        match b {
            Block::Generic(g) => {
                fold_dag(&mut g.dag, consts);
                // harvest TWrite literals / invalidate reassigned vars
                for &root in &g.dag.roots.clone() {
                    if let HopKind::TWrite { name } = &g.dag.hop(root).kind.clone() {
                        let input = g.dag.hop(root).inputs[0];
                        match g.dag.hop(input).literal() {
                            Some(l) => {
                                consts.insert(name.clone(), l.clone());
                            }
                            None => {
                                consts.remove(name);
                            }
                        }
                    }
                }
            }
            Block::If { pred, then_blocks, else_blocks, .. } => {
                fold_dag(pred, consts);
                let mut t_tab = consts.clone();
                const_propagate(then_blocks, &mut t_tab, funcs);
                let mut e_tab = consts.clone();
                const_propagate(else_blocks, &mut e_tab, funcs);
                // intersection of agreeing constants
                consts.retain(|k, v| t_tab.get(k) == Some(v) && e_tab.get(k) == Some(v));
                for (k, v) in &t_tab {
                    if e_tab.get(k) == Some(v) {
                        consts.entry(k.clone()).or_insert_with(|| v.clone());
                    }
                }
            }
            Block::For { from, to, by, body, var, .. } => {
                fold_dag(from, consts);
                fold_dag(to, consts);
                if let Some(by) = by {
                    fold_dag(by, consts);
                }
                // vars assigned in the body (plus the loop var) are not
                // constant inside/after it
                let mut assigned = HashSet::new();
                collect_assigned(body, &mut assigned);
                assigned.insert(var.clone());
                for v in &assigned {
                    consts.remove(v);
                }
                const_propagate(body, &mut consts.clone(), funcs);
                for v in &assigned {
                    consts.remove(v);
                }
            }
            Block::While { pred, body, .. } => {
                let mut assigned = HashSet::new();
                collect_assigned(body, &mut assigned);
                for v in &assigned {
                    consts.remove(v);
                }
                fold_dag(pred, consts);
                const_propagate(body, &mut consts.clone(), funcs);
                for v in &assigned {
                    consts.remove(v);
                }
            }
            Block::FCall { outputs, .. } => {
                for o in outputs {
                    consts.remove(o);
                }
            }
        }
    }
}

fn collect_assigned(blocks: &[Block], out: &mut HashSet<String>) {
    for b in blocks {
        match b {
            Block::Generic(g) => {
                for &r in &g.dag.roots {
                    if let HopKind::TWrite { name } = &g.dag.hop(r).kind {
                        out.insert(name.clone());
                    }
                }
            }
            Block::If { then_blocks, else_blocks, .. } => {
                collect_assigned(then_blocks, out);
                collect_assigned(else_blocks, out);
            }
            Block::For { body, var, .. } => {
                out.insert(var.clone());
                collect_assigned(body, out);
            }
            Block::While { body, .. } => collect_assigned(body, out),
            Block::FCall { outputs, .. } => out.extend(outputs.iter().cloned()),
        }
    }
}

/// Fold scalar constants within one DAG; `consts` supplies known literal
/// values for transient reads.
pub fn fold_dag(dag: &mut HopDag, consts: &ConstTab) {
    for id in dag.topo_order() {
        let hop = dag.hop(id).clone();
        // Note: folding keys off *literal inputs*, not the recorded dtype —
        // TReads of scalars are built with a provisional Matrix dtype, and a
        // binary over two scalar literals is necessarily scalar.
        let folded: Option<Lit> = match &hop.kind {
            HopKind::TRead { name } => consts.get(name).cloned(),
            HopKind::Unary(op) if !matches!(op, UnOp::CastMatrix) => {
                dag.hop(hop.inputs[0]).literal().and_then(|l| op.fold(l))
            }
            HopKind::Binary(op) => {
                match (dag.hop(hop.inputs[0]).literal(), dag.hop(hop.inputs[1]).literal()) {
                    (Some(a), Some(b)) => op.fold(a, b),
                    _ => None,
                }
            }
            _ => None,
        };
        if let Some(l) = folded {
            let h = dag.hop_mut(id);
            h.dtype = DataType::Scalar(l.vtype());
            h.kind = HopKind::Literal(l);
            h.inputs.clear();
        }
    }
}

// ---------------------------------------------------------------------
// Branch removal
// ---------------------------------------------------------------------

/// Splice away `if` blocks whose predicate folded to a literal. Returns
/// true if anything changed.
fn remove_branches(blocks: &mut Vec<Block>) -> bool {
    let mut changed = false;
    let mut i = 0;
    while i < blocks.len() {
        // recurse first
        match &mut blocks[i] {
            Block::If { then_blocks, else_blocks, .. } => {
                changed |= remove_branches(then_blocks);
                changed |= remove_branches(else_blocks);
            }
            Block::For { body, .. } | Block::While { body, .. } => {
                changed |= remove_branches(body);
            }
            _ => {}
        }
        let take = match &blocks[i] {
            Block::If { pred, .. } => {
                let root = pred.roots.first().copied();
                root.and_then(|r| pred.hop(r).literal()).and_then(|l| l.as_bool())
            }
            _ => None,
        };
        if let Some(cond) = take {
            let Block::If { then_blocks, else_blocks, .. } = blocks.remove(i) else {
                unreachable!()
            };
            let taken = if cond { then_blocks } else { else_blocks };
            let n = taken.len();
            for (k, tb) in taken.into_iter().enumerate() {
                blocks.insert(i + k, tb);
            }
            i += n;
            changed = true;
        } else {
            i += 1;
        }
    }
    changed
}

// ---------------------------------------------------------------------
// Dead transient-write elimination (backward liveness)
// ---------------------------------------------------------------------

/// Remove TWrite roots for variables never read afterwards. Matches
/// SystemML's liveness pass: Figure 1's second GENERIC block carries no
/// TWrites because I, A, b, beta are not read by later blocks.
fn remove_dead_twrites(prog: &mut Program) {
    let funcs = prog.funcs.clone();
    let mut live: HashSet<String> = HashSet::new();
    liveness_blocks(&mut prog.blocks, &mut live, &funcs);
    for (name, f) in funcs.clone() {
        // function outputs are live at function end
        let mut live: HashSet<String> = f.outputs.iter().cloned().collect();
        if let Some(func_mut) = prog.funcs.get_mut(&name) {
            liveness_blocks(&mut func_mut.body, &mut live, &funcs);
        }
    }
}

/// Backward pass; `live` is the live-out set, updated to live-in.
fn liveness_blocks(
    blocks: &mut [Block],
    live: &mut HashSet<String>,
    funcs: &std::collections::BTreeMap<String, Function>,
) {
    for b in blocks.iter_mut().rev() {
        match b {
            Block::Generic(g) => {
                // Drop dead TWrites — except scalar literals: SystemML keeps
                // those as cheap assignvars (Figure 1 shows TWrite intercept
                // and TWrite lambda although constant propagation removed
                // their readers).
                let dead: Vec<HopId> = g
                    .dag
                    .roots
                    .iter()
                    .copied()
                    .filter(|&r| match &g.dag.hop(r).kind {
                        HopKind::TWrite { name } => {
                            !live.contains(name)
                                && !g.dag.hop(g.dag.hop(r).inputs[0]).is_literal()
                        }
                        _ => false,
                    })
                    .collect();
                g.dag.roots.retain(|r| !dead.contains(r));
                // update liveness: writes kill, reads gen
                for &r in &g.dag.roots {
                    if let HopKind::TWrite { name } = &g.dag.hop(r).kind {
                        live.remove(name);
                    }
                }
                for id in g.dag.topo_order() {
                    if let HopKind::TRead { name } = &g.dag.hop(id).kind {
                        live.insert(name.clone());
                    }
                }
            }
            Block::If { pred, then_blocks, else_blocks, .. } => {
                let mut t_live = live.clone();
                liveness_blocks(then_blocks, &mut t_live, funcs);
                let mut e_live = live.clone();
                liveness_blocks(else_blocks, &mut e_live, funcs);
                *live = t_live.union(&e_live).cloned().collect();
                add_dag_reads(pred, live);
            }
            Block::For { from, to, by, body, var, .. } => {
                // anything read anywhere in the body is live at body end
                // (next iteration); run liveness with that conservative set
                let mut body_reads = HashSet::new();
                collect_reads(body, &mut body_reads);
                let mut inner: HashSet<String> =
                    live.union(&body_reads).cloned().collect();
                liveness_blocks(body, &mut inner, funcs);
                *live = live.union(&inner).cloned().collect();
                live.remove(var);
                add_dag_reads(from, live);
                add_dag_reads(to, live);
                if let Some(by) = by {
                    add_dag_reads(by, live);
                }
            }
            Block::While { pred, body, .. } => {
                let mut body_reads = HashSet::new();
                collect_reads(body, &mut body_reads);
                add_dag_reads(pred, &mut body_reads);
                let mut inner: HashSet<String> = live.union(&body_reads).cloned().collect();
                liveness_blocks(body, &mut inner, funcs);
                *live = live.union(&inner).cloned().collect();
                add_dag_reads(pred, live);
            }
            Block::FCall { args, outputs, .. } => {
                for o in outputs.iter() {
                    live.remove(o);
                }
                live.extend(args.iter().cloned());
            }
        }
    }
}

fn add_dag_reads(dag: &HopDag, live: &mut HashSet<String>) {
    for id in dag.topo_order() {
        if let HopKind::TRead { name } = &dag.hop(id).kind {
            live.insert(name.clone());
        }
    }
}

fn collect_reads(blocks: &[Block], out: &mut HashSet<String>) {
    for b in blocks {
        match b {
            Block::Generic(g) => add_dag_reads(&g.dag, out),
            Block::If { pred, then_blocks, else_blocks, .. } => {
                add_dag_reads(pred, out);
                collect_reads(then_blocks, out);
                collect_reads(else_blocks, out);
            }
            Block::For { from, to, by, body, .. } => {
                add_dag_reads(from, out);
                add_dag_reads(to, out);
                if let Some(by) = by {
                    add_dag_reads(by, out);
                }
                collect_reads(body, out);
            }
            Block::While { pred, body, .. } => {
                add_dag_reads(pred, out);
                collect_reads(body, out);
            }
            Block::FCall { args, .. } => out.extend(args.iter().cloned()),
        }
    }
}

// ---------------------------------------------------------------------
// Algebraic simplification
// ---------------------------------------------------------------------

/// Pattern-based algebraic rewrites within one DAG.
pub fn algebraic_dag(dag: &mut HopDag) {
    // Fixpoint over a few passes: each rewrite may expose another.
    for _ in 0..4 {
        let mut changed = false;
        for id in dag.topo_order() {
            changed |= rewrite_hop(dag, id);
        }
        if !changed {
            break;
        }
    }
}

/// Returns true if the hop was rewritten (in place).
fn rewrite_hop(dag: &mut HopDag, id: HopId) -> bool {
    let hop = dag.hop(id).clone();
    match &hop.kind {
        // t(t(X)) -> X : replace this hop with a pass-through of X by
        // rewiring parents. We instead rewrite this hop into an identity
        // alias: not representable — so rewire by replacing *this* hop's
        // kind/inputs with those of X's definition is wrong (shared). We
        // handle it by searching parents below instead.
        HopKind::Reorg(ReorgOp::Transpose) => {
            let inner = dag.hop(hop.inputs[0]).clone();
            if let HopKind::Reorg(ReorgOp::Transpose) = inner.kind {
                // replace usages of `id` with inner's input
                let target = inner.inputs[0];
                replace_uses(dag, id, target);
                return true;
            }
            false
        }
        HopKind::Binary(BinOp::Mul) => {
            let (a, b) = (hop.inputs[0], hop.inputs[1]);
            // X * 1 or 1 * X  ->  X
            for (m, s) in [(a, b), (b, a)] {
                if dag.hop(m).dtype.is_matrix() {
                    if let Some(l) = dag.hop(s).literal() {
                        if l.as_f64() == Some(1.0) {
                            replace_uses(dag, id, m);
                            return true;
                        }
                    }
                }
            }
            // diag(rand_const c) * s  ->  diag(rand_const c*s)
            // rand_const c * s        ->  rand_const c*s
            for (m, s) in [(a, b), (b, a)] {
                let Some(l) = dag.hop(s).literal() else { continue };
                let Some(sv) = l.as_f64() else { continue };
                // m = diag(dg) or dg
                let (dg_id, via_diag) = match &dag.hop(m).kind {
                    HopKind::Reorg(ReorgOp::Diag) => (dag.hop(m).inputs[0], true),
                    HopKind::DataGen(_) => (m, false),
                    _ => continue,
                };
                let HopKind::DataGen(DataGenOp::Rand { min, max, sparsity, seed }) =
                    dag.hop(dg_id).kind.clone()
                else {
                    continue;
                };
                if min != max {
                    continue; // only constant matrices are scaled safely
                }
                let rows_cols = dag.hop(dg_id).inputs.clone();
                let new_dg = dag.add(
                    HopKind::DataGen(DataGenOp::Rand {
                        min: min * sv,
                        max: max * sv,
                        sparsity,
                        seed,
                    }),
                    rows_cols,
                    DataType::Matrix,
                );
                let replacement = if via_diag {
                    dag.add(HopKind::Reorg(ReorgOp::Diag), vec![new_dg], DataType::Matrix)
                } else {
                    new_dg
                };
                replace_uses(dag, id, replacement);
                return true;
            }
            false
        }
        HopKind::Binary(BinOp::Add) | HopKind::Binary(BinOp::Sub) => {
            let (a, b) = (hop.inputs[0], hop.inputs[1]);
            // X + 0 / X - 0 -> X ; 0 + X -> X
            let candidates: &[(usize, usize)] =
                if matches!(hop.kind, HopKind::Binary(BinOp::Add)) { &[(0, 1), (1, 0)] } else { &[(0, 1)] };
            for &(mi, si) in candidates {
                let (m, s) = (hop.inputs[mi], hop.inputs[si]);
                let _ = (a, b);
                if dag.hop(m).dtype.is_matrix() {
                    if let Some(l) = dag.hop(s).literal() {
                        if l.as_f64() == Some(0.0) {
                            replace_uses(dag, id, m);
                            return true;
                        }
                    }
                }
            }
            false
        }
        HopKind::Binary(BinOp::Div) | HopKind::Binary(BinOp::Pow) => {
            // X / 1 -> X ; X ^ 1 -> X
            let (m, s) = (hop.inputs[0], hop.inputs[1]);
            if dag.hop(m).dtype.is_matrix() {
                if let Some(l) = dag.hop(s).literal() {
                    if l.as_f64() == Some(1.0) {
                        replace_uses(dag, id, m);
                        return true;
                    }
                }
            }
            false
        }
        _ => false,
    }
}

/// Rewire all uses of `old` (including roots) to `new`.
fn replace_uses(dag: &mut HopDag, old: HopId, new: HopId) {
    for h in dag.hops.iter_mut() {
        for i in h.inputs.iter_mut() {
            if *i == old {
                *i = new;
            }
        }
    }
    for r in dag.roots.iter_mut() {
        if *r == old {
            *r = new;
        }
    }
}

// ---------------------------------------------------------------------
// Common subexpression elimination
// ---------------------------------------------------------------------

/// Merge structurally identical hops (same kind + same input ids). Roots
/// (TWrite/PWrite/Print) and non-constant DataGen are never merged.
pub fn cse_dag(dag: &mut HopDag) {
    let mut canon: HashMap<String, HopId> = HashMap::new();
    let mut remap: HashMap<HopId, HopId> = HashMap::new();
    for id in dag.topo_order() {
        let hop = dag.hop(id).clone();
        // apply pending remaps to inputs first
        let inputs: Vec<HopId> =
            hop.inputs.iter().map(|i| *remap.get(i).unwrap_or(i)).collect();
        dag.hop_mut(id).inputs = inputs.clone();
        if !cse_eligible(&hop.kind) {
            continue;
        }
        let key = format!("{:?}|{:?}", hop.kind, inputs);
        match canon.get(&key) {
            Some(&prev) => {
                remap.insert(id, prev);
            }
            None => {
                canon.insert(key, id);
            }
        }
    }
    if remap.is_empty() {
        return;
    }
    for h in dag.hops.iter_mut() {
        for i in h.inputs.iter_mut() {
            if let Some(&n) = remap.get(i) {
                *i = n;
            }
        }
    }
    for r in dag.roots.iter_mut() {
        if let Some(&n) = remap.get(r) {
            *r = n;
        }
    }
}

fn cse_eligible(kind: &HopKind) -> bool {
    match kind {
        HopKind::TWrite { .. } | HopKind::PWrite { .. } | HopKind::Print => false,
        // rand with a true random range is not CSE-safe; constants are
        HopKind::DataGen(DataGenOp::Rand { min, max, .. }) => min == max,
        _ => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml;
    use crate::ir::build::{build_program, tests::linreg_args, tests::xs_meta, tests::LINREG_DS};

    fn compile(src: &str) -> Program {
        let script = dml::frontend(src).unwrap();
        let mut prog = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap();
        rewrite_program(&mut prog);
        prog
    }

    #[test]
    fn branch_removed_for_constant_predicate() {
        // intercept = $3 = 0, so `if (intercept == 1)` disappears (Fig. 1).
        let prog = compile(LINREG_DS);
        assert_eq!(prog.blocks.len(), 2, "if block must be removed");
        assert!(prog.blocks.iter().all(|b| matches!(b, Block::Generic(_))));
        let Block::Generic(g2) = &prog.blocks[1] else { panic!() };
        assert_eq!(g2.lines, (8, 12));
    }

    #[test]
    fn branch_kept_when_predicate_unknown() {
        let mut args = linreg_args();
        args.insert(3, "1".to_string()); // intercept = 1: branch taken
        let script = dml::frontend(LINREG_DS).unwrap();
        let mut prog = build_program(&script, &args, &xs_meta(), 1000).unwrap();
        rewrite_program(&mut prog);
        // then-branch spliced in: 3 generic blocks (1-3, 5-6, 8-12)
        assert_eq!(prog.blocks.len(), 3);
        let Block::Generic(g) = &prog.blocks[1] else { panic!() };
        assert!(g.dag.hops.iter().any(|h| h.kind == HopKind::Append));
    }

    #[test]
    fn diag_lambda_rewrite_applied() {
        // diag(matrix(1,...)) * 0.001 -> diag(matrix(0.001,...))
        let prog = compile(LINREG_DS);
        let Block::Generic(g) = &prog.blocks[1] else { panic!() };
        let live = g.dag.topo_order();
        let rands: Vec<_> = live
            .iter()
            .filter_map(|&id| match &g.dag.hop(id).kind {
                HopKind::DataGen(DataGenOp::Rand { min, max, .. }) => Some((*min, *max)),
                _ => None,
            })
            .collect();
        assert!(
            rands.contains(&(0.001, 0.001)),
            "expected rand const 0.001, got {rands:?}"
        );
        // and no live b(*) with the lambda literal remains
        let muls = live
            .iter()
            .filter(|&&id| g.dag.hop(id).kind == HopKind::Binary(BinOp::Mul))
            .count();
        assert_eq!(muls, 0, "scalar multiply should be folded into datagen");
    }

    #[test]
    fn cse_shares_transpose() {
        // t(X) used by both t(X)%*%X and t(X)%*%y must be a single hop.
        let prog = compile(LINREG_DS);
        let Block::Generic(g) = &prog.blocks[1] else { panic!() };
        let live = g.dag.topo_order();
        let transposes = live
            .iter()
            .filter(|&&id| g.dag.hop(id).kind == HopKind::Reorg(ReorgOp::Transpose))
            .count();
        assert_eq!(transposes, 1);
    }

    #[test]
    fn dead_twrites_removed() {
        // I, A, b, beta are never read later: block 2 has only PWrite root.
        let prog = compile(LINREG_DS);
        let Block::Generic(g) = &prog.blocks[1] else { panic!() };
        let twrites = g
            .dag
            .roots
            .iter()
            .filter(|&&r| matches!(g.dag.hop(r).kind, HopKind::TWrite { .. }))
            .count();
        assert_eq!(twrites, 0);
        // but block 1 keeps X and y TWrites (read by block 2)
        let Block::Generic(g1) = &prog.blocks[0] else { panic!() };
        let names: Vec<_> = g1
            .dag
            .roots
            .iter()
            .filter_map(|&r| match &g1.dag.hop(r).kind {
                HopKind::TWrite { name } => Some(name.as_str()),
                _ => None,
            })
            .collect();
        assert!(names.contains(&"X") && names.contains(&"y"));
    }

    #[test]
    fn loop_live_variables_kept() {
        let src = r#"
s = 0;
acc = 0;
for (i in 1:10) {
  acc = acc + s;
  s = s + 1;
}
write(acc, $4);
"#;
        let prog = compile(src);
        // s is read at loop top from previous iteration: its TWrite in the
        // loop body must survive.
        let Block::For { body, .. } =
            prog.blocks.iter().find(|b| matches!(b, Block::For { .. })).unwrap()
        else {
            panic!()
        };
        let Block::Generic(g) = &body[0] else { panic!() };
        let names: Vec<_> = g
            .dag
            .roots
            .iter()
            .filter_map(|&r| match &g.dag.hop(r).kind {
                HopKind::TWrite { name } => Some(name.as_str()),
                _ => None,
            })
            .collect();
        assert!(names.contains(&"s"), "{names:?}");
        assert!(names.contains(&"acc"));
    }

    #[test]
    fn transpose_of_transpose_eliminated() {
        let prog = compile("X = read($1); Z = t(t(X)); s = sum(Z); write(s, $4);");
        let mut transposes = 0;
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    if g.dag.hop(id).kind == HopKind::Reorg(ReorgOp::Transpose) {
                        transposes += 1;
                    }
                }
            }
        }
        assert_eq!(transposes, 0);
    }

    #[test]
    fn mul_by_one_eliminated() {
        let prog = compile("X = read($1); Z = X * 1; s = sum(Z); write(s, $4);");
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    assert_ne!(g.dag.hop(id).kind, HopKind::Binary(BinOp::Mul));
                }
            }
        }
    }

    #[test]
    fn constant_propagation_across_blocks() {
        let src = r#"
n = 5;
c = 2;
if (c == 2) { m = n + 1; } else { m = 0; }
write(m, $4);
"#;
        let prog = compile(src);
        // both the if and the arithmetic fold: the surviving write block
        // stores literal 6
        let mut found = false;
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    if g.dag.hop(id).literal() == Some(&Lit::Int(6)) {
                        found = true;
                    }
                }
            }
        }
        assert!(found);
    }
}
