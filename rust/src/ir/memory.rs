//! Operation memory estimates (paper §2: "computed the individual operation
//! memory estimates (input, intermediate, and output memory requirements)").
//!
//! For each HOP we estimate the output in-memory size `M̂` from its
//! characteristics, then the operation estimate as the sum of the inputs'
//! output sizes, op-specific intermediates, and the own output — the values
//! printed in Figure 1 (e.g. `r(t)` on a 76MB matrix = 153MB).

use super::*;
use crate::conf::SystemConfig;

/// Annotate `out_mem` and `op_mem` on every hop of every DAG.
pub fn annotate(prog: &mut Program, cfg: &SystemConfig) {
    let sparse_threshold = cfg.sparse_threshold;
    prog.for_each_dag_mut(&mut |dag| annotate_dag(dag, sparse_threshold));
}

/// Annotate one DAG (topological order so input estimates exist).
pub fn annotate_dag(dag: &mut HopDag, sparse_threshold: f64) {
    for id in dag.topo_order() {
        let hop = dag.hop(id).clone();
        let out_mem = if hop.dtype.is_matrix() {
            hop.mc.mem_estimate(sparse_threshold)
        } else {
            64.0 // scalars
        };
        let input_mem: f64 = hop.inputs.iter().map(|&i| dag.hop(i).out_mem).sum();
        let intermediate = intermediate_mem(&hop, dag);
        let op_mem = match &hop.kind {
            // Reads/writes/literals don't hold inputs+outputs twice.
            HopKind::PRead { .. } | HopKind::Literal(_) | HopKind::TRead { .. } => out_mem,
            HopKind::TWrite { .. } | HopKind::PWrite { .. } | HopKind::Print => out_mem,
            _ => input_mem + intermediate + out_mem,
        };
        let h = dag.hop_mut(id);
        h.out_mem = out_mem;
        h.op_mem = op_mem;
    }
}

/// Op-specific intermediate memory.
fn intermediate_mem(hop: &Hop, dag: &HopDag) -> f64 {
    match &hop.kind {
        // LU factorisation copies A (and the pivot/permutation vectors).
        HopKind::Binary(BinOp::Solve) => dag.hop(hop.inputs[0]).out_mem,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml;
    use crate::ir::build::{build_program, tests::linreg_args, tests::xs_meta, tests::LINREG_DS};
    use crate::ir::{rewrites, size_prop};

    const MB: f64 = 1024.0 * 1024.0;

    fn compiled() -> Program {
        let script = dml::frontend(LINREG_DS).unwrap();
        let mut prog = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap();
        rewrites::rewrite_program(&mut prog);
        size_prop::propagate(&mut prog, 1000);
        annotate(&mut prog, &SystemConfig::default());
        prog
    }

    fn hop_mem(prog: &Program, pred: impl Fn(&Hop) -> bool) -> f64 {
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    let h = g.dag.hop(id);
                    if pred(h) {
                        return h.op_mem;
                    }
                }
            }
        }
        panic!("hop not found");
    }

    #[test]
    fn transpose_memory_estimate_matches_figure1() {
        // Figure 1: r(t) 153MB (76MB in + 76MB out).
        let prog = compiled();
        let m = hop_mem(&prog, |h| {
            h.kind == HopKind::Reorg(ReorgOp::Transpose) && h.mc.rows == 1000 && h.mc.cols == 10_000
        }) / MB;
        assert_eq!(m.round() as i64, 153);
    }

    #[test]
    fn pread_memory_estimate_matches_figure1() {
        // Figure 1: PRead X 76MB.
        let prog = compiled();
        let m = hop_mem(&prog, |h| matches!(&h.kind, HopKind::PRead { name, .. } if name.contains('X'))) / MB;
        assert_eq!(m.round() as i64, 76);
    }

    #[test]
    fn matmult_memory_estimate_close_to_figure1() {
        // Figure 1: ba(+*) X'X 168MB (SystemML adds small per-thread
        // partials; our estimate is 76+76+8 = 160MB — within 5%).
        let prog = compiled();
        let m = hop_mem(&prog, |h| h.kind == HopKind::MatMult && h.mc.cols == 1000) / MB;
        assert!((m - 160.0).abs() < 8.0, "got {m}MB");
    }

    #[test]
    fn solve_includes_intermediate_copy() {
        // Figure 1: b(solve) 15MB = A(7.6) + b(0) + copy(7.6) + out(0).
        let prog = compiled();
        let m = hop_mem(&prog, |h| h.kind == HopKind::Binary(BinOp::Solve)) / MB;
        assert_eq!(m.round() as i64, 15);
    }

    #[test]
    fn elementwise_add_matches_figure1() {
        // Figure 1: b(+) 15MB.
        let prog = compiled();
        let m = hop_mem(&prog, |h| {
            h.kind == HopKind::Binary(BinOp::Add) && h.dtype.is_matrix()
        }) / MB;
        assert_eq!(m.round() as i64, 15);
    }

    #[test]
    fn unknown_dims_give_infinite_estimate() {
        let mut dag = HopDag::default();
        let x = dag.add(HopKind::TRead { name: "X".into() }, vec![], DataType::Matrix);
        let t = dag.add(HopKind::Reorg(ReorgOp::Transpose), vec![x], DataType::Matrix);
        let w = dag.add(HopKind::TWrite { name: "Y".into() }, vec![t], DataType::Matrix);
        dag.roots.push(w);
        annotate_dag(&mut dag, 0.4);
        assert!(dag.hop(t).op_mem.is_infinite());
    }
}
