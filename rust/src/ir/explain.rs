//! HOP-level EXPLAIN (paper Figure 1 format):
//!
//! ```text
//! # Memory Budget local/remote = 1434MB/1434MB
//! # Degree of Parallelism (vcores) local/remote = 24/144/72
//! PROGRAM
//! --MAIN PROGRAM
//! ----GENERIC (lines 1-3) [recompile=false]
//! ------(10) PRead X [1e4,1e3,1e3,1e3,1e7] [76MB] CP
//! ...
//! ```
//!
//! HOP ids are global across the program like SystemML's.

use super::*;
use crate::conf::{ClusterConfig, SystemConfig};
use crate::util::fmt::fmt_mb;

/// Render the program at HOP level.
pub fn explain_hops(prog: &Program, cfg: &SystemConfig, cc: &ClusterConfig) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "# Memory Budget local/remote = {}/{}\n",
        fmt_mb(cfg.cp_budget(cc)),
        fmt_mb(cfg.map_budget(cc))
    ));
    out.push_str(&format!(
        "# Degree of Parallelism (vcores) local/remote = {}/{}/{}\n",
        cc.k_local,
        cc.effective_k_map(),
        cc.effective_k_reduce()
    ));
    out.push_str("PROGRAM\n--MAIN PROGRAM\n");
    let mut ids = IdGen { next: 10 };
    explain_blocks(&prog.blocks, &mut out, 4, &mut ids);
    for (name, f) in &prog.funcs {
        out.push_str(&format!("--FUNCTION {name}\n"));
        explain_blocks(&f.body, &mut out, 4, &mut ids);
    }
    out
}

struct IdGen {
    next: usize,
}

impl IdGen {
    fn take(&mut self, n: usize) -> usize {
        let base = self.next;
        // SystemML ids advance with internal hops; approximate the look by
        // skipping a couple per DAG.
        self.next += n + 2;
        base
    }
}

fn dashes(n: usize) -> String {
    "-".repeat(n)
}

fn explain_blocks(blocks: &[Block], out: &mut String, indent: usize, ids: &mut IdGen) {
    for b in blocks {
        match b {
            Block::Generic(g) => {
                let (l0, l1) = g.lines;
                out.push_str(&format!(
                    "{}GENERIC (lines {l0}-{l1}) [recompile={}]\n",
                    dashes(indent),
                    g.recompile
                ));
                explain_dag(&g.dag, out, indent + 2, ids);
            }
            Block::If { pred, then_blocks, else_blocks, lines } => {
                out.push_str(&format!(
                    "{}IF (lines {}-{})\n",
                    dashes(indent),
                    lines.0,
                    lines.1
                ));
                out.push_str(&format!("{}IF PREDICATE\n", dashes(indent + 2)));
                explain_dag(pred, out, indent + 4, ids);
                out.push_str(&format!("{}IF BODY\n", dashes(indent + 2)));
                explain_blocks(then_blocks, out, indent + 4, ids);
                if !else_blocks.is_empty() {
                    out.push_str(&format!("{}ELSE BODY\n", dashes(indent + 2)));
                    explain_blocks(else_blocks, out, indent + 4, ids);
                }
            }
            Block::For { var, from, to, body, parfor, known_trip, lines, .. } => {
                let kind = if *parfor { "PARFOR" } else { "FOR" };
                let trip = known_trip.map_or("unknown".to_string(), |t| format!("{t}"));
                out.push_str(&format!(
                    "{}{kind} (lines {}-{}) [var={var}, iterations={trip}]\n",
                    dashes(indent),
                    lines.0,
                    lines.1
                ));
                out.push_str(&format!("{}FROM\n", dashes(indent + 2)));
                explain_dag(from, out, indent + 4, ids);
                out.push_str(&format!("{}TO\n", dashes(indent + 2)));
                explain_dag(to, out, indent + 4, ids);
                out.push_str(&format!("{}BODY\n", dashes(indent + 2)));
                explain_blocks(body, out, indent + 4, ids);
            }
            Block::While { pred, body, lines } => {
                out.push_str(&format!(
                    "{}WHILE (lines {}-{})\n",
                    dashes(indent),
                    lines.0,
                    lines.1
                ));
                out.push_str(&format!("{}WHILE PREDICATE\n", dashes(indent + 2)));
                explain_dag(pred, out, indent + 4, ids);
                out.push_str(&format!("{}BODY\n", dashes(indent + 2)));
                explain_blocks(body, out, indent + 4, ids);
            }
            Block::FCall { fname, args, outputs, lines } => {
                out.push_str(&format!(
                    "{}FCALL {fname}({}) -> ({}) (lines {}-{})\n",
                    dashes(indent),
                    args.join(","),
                    outputs.join(","),
                    lines.0,
                    lines.1
                ));
            }
        }
    }
}

fn explain_dag(dag: &HopDag, out: &mut String, indent: usize, ids: &mut IdGen) {
    let order = dag.topo_order();
    let base = ids.take(order.len());
    // local id -> printed id
    let mut printed: std::collections::HashMap<HopId, usize> = std::collections::HashMap::new();
    for (k, &id) in order.iter().enumerate() {
        printed.insert(id, base + k);
    }
    for &id in &order {
        let h = dag.hop(id);
        // literals are inlined in SystemML's explain; skip bare literals
        if h.is_literal() {
            continue;
        }
        let refs: Vec<String> = h
            .inputs
            .iter()
            .filter(|&&i| !dag.hop(i).is_literal())
            .map(|i| printed[i].to_string())
            .collect();
        let refs = if refs.is_empty() { String::new() } else { format!(" ({})", refs.join(",")) };
        let mem = if h.op_mem.is_finite() { fmt_mb(h.op_mem) } else { "?MB".to_string() };
        let exec = h.exec.map(|e| e.name()).unwrap_or("");
        out.push_str(&format!(
            "{}({}) {}{} {} [{}] {}\n",
            dashes(indent),
            printed[&id],
            h.kind.opcode(),
            refs,
            h.mc.explain(),
            mem,
            exec
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conf::{ClusterConfig, SystemConfig};
    use crate::dml;
    use crate::ir::build::{build_program, tests::linreg_args, tests::xs_meta, tests::LINREG_DS};
    use crate::ir::{exec_type, memory, rewrites, size_prop};

    fn compiled() -> Program {
        let script = dml::frontend(LINREG_DS).unwrap();
        let mut prog = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap();
        rewrites::rewrite_program(&mut prog);
        size_prop::propagate(&mut prog, 1000);
        memory::annotate(&mut prog, &SystemConfig::default());
        exec_type::select(&mut prog, &SystemConfig::default(), &ClusterConfig::paper_cluster());
        prog
    }

    #[test]
    fn explain_matches_figure1_shape() {
        let prog = compiled();
        let text = explain_hops(&prog, &SystemConfig::default(), &ClusterConfig::paper_cluster());
        // Header lines
        assert!(text.contains("# Memory Budget local/remote = 1434MB/1434MB"));
        assert!(text.contains("# Degree of Parallelism (vcores) local/remote = 24/144/72"));
        // Program structure
        assert!(text.contains("PROGRAM\n--MAIN PROGRAM"));
        assert!(text.contains("GENERIC (lines 1-3) [recompile=false]"));
        assert!(text.contains("GENERIC (lines 8-12) [recompile=false]"));
        // Key hops with sizes and exec types
        assert!(text.contains("PRead X [1e4,1e3,1e3,1e3,1e7] [76MB] CP"), "{text}");
        assert!(text.contains("r(t)"));
        assert!(text.contains("ba(+*)"));
        assert!(text.contains("b(solve)"));
        assert!(text.contains("dg(rand)"));
        assert!(text.contains("r(diag)"));
        assert!(text.contains("PWrite beta"));
    }

    #[test]
    fn explain_references_use_printed_ids() {
        let prog = compiled();
        let text = explain_hops(&prog, &SystemConfig::default(), &ClusterConfig::paper_cluster());
        // the transpose must be referenced by both matmults: its printed id
        // appears at least three times (definition + two refs)
        let t_line = text.lines().find(|l| l.contains("r(t)")).unwrap();
        let t_id: String =
            t_line.trim_start_matches('-').chars().skip(1).take_while(|c| *c != ')').collect();
        let refs = text.matches(&format!("({t_id})")).count()
            + text.matches(&format!("({t_id},")).count()
            + text.matches(&format!(",{t_id})")).count();
        assert!(refs >= 3, "transpose not shared: {refs}\n{text}");
    }
}
