//! AST → HOP-DAG construction.
//!
//! Straight-line statement runs become generic blocks with one DAG each;
//! control-flow statements become nested blocks. Variables live across
//! block boundaries via `TWrite`/`TRead` pairs, exactly like the two
//! GENERIC blocks in the paper's Figure 1.

use std::collections::{BTreeMap, HashMap};

use super::*;
use crate::dml::ast as dast;
use crate::matrix::{Format, MatrixCharacteristics};

/// Source of matrix metadata for `read()` inputs: either `.mtd` sidecar
/// files on disk, or statically provided characteristics (used to compile
/// the paper's terabyte scenarios without materialising data).
pub trait MetaProvider {
    fn stats(&self, path: &str) -> Option<(MatrixCharacteristics, Format)>;
}

/// Reads `<path>.mtd` sidecars written by [`crate::matrix::io`].
pub struct FileMeta;

impl MetaProvider for FileMeta {
    fn stats(&self, path: &str) -> Option<(MatrixCharacteristics, Format)> {
        crate::matrix::io::read_mtd(path).ok()
    }
}

/// Static path → characteristics map.
#[derive(Default)]
pub struct StaticMeta(pub HashMap<String, (MatrixCharacteristics, Format)>);

impl StaticMeta {
    pub fn with(mut self, path: &str, mc: MatrixCharacteristics, format: Format) -> Self {
        self.0.insert(path.to_string(), (mc, format));
        self
    }
}

impl MetaProvider for StaticMeta {
    fn stats(&self, path: &str) -> Option<(MatrixCharacteristics, Format)> {
        self.0.get(path).copied()
    }
}

/// Build a [`Program`] of HOP DAGs from a validated AST.
///
/// `args` provides the `$N` command-line bindings; `meta` resolves
/// dimensions of persistent reads; `blocksize` stamps block metadata.
pub fn build_program(
    script: &dast::Script,
    args: &HashMap<usize, String>,
    meta: &dyn MetaProvider,
    blocksize: i64,
) -> Result<Program, String> {
    let mut b = Builder { args, meta, blocksize, temp_counter: 0 };
    let mut funcs = BTreeMap::new();
    // Compile function bodies first (they cannot reference $N args directly
    // in our subset, but can use all builtins).
    for s in &script.stmts {
        if let dast::Stmt::FuncDef { name, params, param_kinds, outputs, body, .. } = s {
            let blocks = b.build_blocks(body)?;
            funcs.insert(
                name.clone(),
                Function {
                    params: params.clone(),
                    param_kinds: param_kinds.clone(),
                    outputs: outputs.clone(),
                    body: blocks,
                },
            );
        }
    }
    let blocks = b.build_blocks(&script.stmts)?;
    Ok(Program { blocks, funcs })
}

struct Builder<'a> {
    args: &'a HashMap<usize, String>,
    meta: &'a dyn MetaProvider,
    blocksize: i64,
    temp_counter: usize,
}

/// State while building one generic block.
struct DagCtx {
    dag: HopDag,
    /// variable -> defining hop in this DAG
    vars: HashMap<String, HopId>,
    /// variables assigned in this block, in order (need TWrite at flush)
    assigned: Vec<String>,
    first_line: usize,
    last_line: usize,
}

impl DagCtx {
    fn new() -> Self {
        DagCtx {
            dag: HopDag::default(),
            vars: HashMap::new(),
            assigned: Vec::new(),
            first_line: 0,
            last_line: 0,
        }
    }

    fn touch_line(&mut self, line: usize) {
        if self.first_line == 0 {
            self.first_line = line;
        }
        self.last_line = self.last_line.max(line);
    }

    fn is_empty(&self) -> bool {
        self.dag.hops.is_empty()
    }
}

impl<'a> Builder<'a> {
    fn build_blocks(&mut self, stmts: &[dast::Stmt]) -> Result<Vec<Block>, String> {
        let mut blocks = Vec::new();
        let mut ctx = DagCtx::new();
        for s in stmts {
            match s {
                dast::Stmt::FuncDef { .. } => {} // compiled separately
                dast::Stmt::Assign { target, expr, line } => {
                    if let dast::Expr::Call(name, cargs) = expr {
                        if !dast::is_builtin(name) {
                            // user-defined function call
                            self.emit_fcall(
                                &mut blocks,
                                &mut ctx,
                                name,
                                cargs,
                                std::slice::from_ref(target),
                                *line,
                            )?;
                            continue;
                        }
                    }
                    ctx.touch_line(*line);
                    let h = self.expr(&mut ctx, expr)?;
                    // `X = read(...)`: SystemML names the PRead hop after the
                    // target variable (EXPLAIN prints `PRead X`).
                    if let HopKind::PRead { name, .. } = &mut ctx.dag.hop_mut(h).kind {
                        *name = target.clone();
                    }
                    ctx.vars.insert(target.clone(), h);
                    if !ctx.assigned.contains(target) {
                        ctx.assigned.push(target.clone());
                    }
                }
                dast::Stmt::MultiAssign { targets, expr, line } => {
                    let dast::Expr::Call(name, cargs) = expr else {
                        return Err(format!(
                            "line {line}: multi-assignment requires a function call"
                        ));
                    };
                    self.emit_fcall(&mut blocks, &mut ctx, name, cargs, targets, *line)?;
                }
                dast::Stmt::Write { expr, file, format, line } => {
                    ctx.touch_line(*line);
                    let h = self.expr(&mut ctx, expr)?;
                    let path = self.path_of(&mut ctx, file)?;
                    let fmt = format
                        .as_deref()
                        .and_then(Format::parse)
                        .unwrap_or(Format::TextCell);
                    let dt = ctx.dag.hop(h).dtype.clone();
                    let name = match expr {
                        dast::Expr::Ident(n) => n.clone(),
                        _ => format!("_wtmp{}", ctx.dag.hops.len()),
                    };
                    let w = ctx.dag.add(HopKind::PWrite { name, path, format: fmt }, vec![h], dt);
                    ctx.dag.roots.push(w);
                }
                dast::Stmt::Print { expr, line } => {
                    ctx.touch_line(*line);
                    let h = self.expr(&mut ctx, expr)?;
                    let p = ctx.dag.add(HopKind::Print, vec![h], DataType::Scalar(ValueType::Str));
                    ctx.dag.roots.push(p);
                }
                dast::Stmt::If { cond, then_branch, else_branch, line } => {
                    self.flush(&mut blocks, &mut ctx);
                    let pred = self.pred_dag(cond)?;
                    let then_blocks = self.build_blocks(then_branch)?;
                    let else_blocks = self.build_blocks(else_branch)?;
                    let end = s.end_line();
                    blocks.push(Block::If { pred, then_blocks, else_blocks, lines: (*line, end) });
                }
                dast::Stmt::For { var, from, to, by, body, parfor, line } => {
                    self.flush(&mut blocks, &mut ctx);
                    let from_dag = self.pred_dag(from)?;
                    let to_dag = self.pred_dag(to)?;
                    let by_dag = by.as_ref().map(|b| self.pred_dag(b)).transpose()?;
                    let body_blocks = self.build_blocks(body)?;
                    blocks.push(Block::For {
                        var: var.clone(),
                        from: from_dag,
                        to: to_dag,
                        by: by_dag,
                        body: body_blocks,
                        parfor: *parfor,
                        known_trip: None,
                        lines: (*line, s.end_line()),
                    });
                }
                dast::Stmt::While { cond, body, line } => {
                    self.flush(&mut blocks, &mut ctx);
                    let pred = self.pred_dag(cond)?;
                    let body_blocks = self.build_blocks(body)?;
                    blocks.push(Block::While { pred, body: body_blocks, lines: (*line, s.end_line()) });
                }
            }
        }
        self.flush(&mut blocks, &mut ctx);
        Ok(blocks)
    }

    /// Close the current generic block: add TWrites for assigned vars.
    fn flush(&mut self, blocks: &mut Vec<Block>, ctx: &mut DagCtx) {
        if ctx.is_empty() {
            *ctx = DagCtx::new();
            return;
        }
        let assigned = std::mem::take(&mut ctx.assigned);
        for name in assigned {
            let h = ctx.vars[&name];
            let dt = ctx.dag.hop(h).dtype.clone();
            let w = ctx.dag.add(HopKind::TWrite { name: name.clone() }, vec![h], dt);
            ctx.dag.roots.push(w);
        }
        let old = std::mem::replace(ctx, DagCtx::new());
        blocks.push(Block::Generic(GenericBlock {
            dag: old.dag,
            lines: (old.first_line, old.last_line),
            recompile: false,
        }));
    }

    /// Emit a user-function call block: ensure args are named variables
    /// (introducing temps for expressions), then flush and add FCall.
    fn emit_fcall(
        &mut self,
        blocks: &mut Vec<Block>,
        ctx: &mut DagCtx,
        fname: &str,
        cargs: &[dast::Expr],
        targets: &[String],
        line: usize,
    ) -> Result<(), String> {
        let mut argnames = Vec::new();
        for a in cargs {
            if let dast::Expr::Ident(n) = a {
                argnames.push(n.clone());
            } else {
                ctx.touch_line(line);
                let h = self.expr(ctx, a)?;
                let tmp = format!("_fvar{}", self.temp_counter);
                self.temp_counter += 1;
                ctx.vars.insert(tmp.clone(), h);
                ctx.assigned.push(tmp.clone());
                argnames.push(tmp);
            }
        }
        self.flush(blocks, ctx);
        blocks.push(Block::FCall {
            fname: fname.to_string(),
            args: argnames,
            outputs: targets.to_vec(),
            lines: (line, line),
        });
        Ok(())
    }

    /// Compile a predicate / loop-bound expression into its own small DAG;
    /// the last hop is the DAG's single root.
    fn pred_dag(&mut self, e: &dast::Expr) -> Result<HopDag, String> {
        let mut ctx = DagCtx::new();
        let h = self.expr(&mut ctx, e)?;
        ctx.dag.roots.push(h);
        Ok(ctx.dag)
    }

    /// Resolve a `$N`/string expression to a file path.
    fn path_of(&mut self, ctx: &mut DagCtx, e: &dast::Expr) -> Result<String, String> {
        match e {
            dast::Expr::Str(s) => Ok(s.clone()),
            dast::Expr::Arg(i) => self
                .args
                .get(i)
                .cloned()
                .ok_or_else(|| format!("missing command-line argument ${i}")),
            other => {
                // allow a variable holding a string literal in the same DAG
                let h = self.expr(ctx, other)?;
                match ctx.dag.hop(h).literal() {
                    Some(Lit::Str(s)) => Ok(s.clone()),
                    _ => Err("file path must be a string literal or $N argument".into()),
                }
            }
        }
    }

    fn lit(&self, ctx: &mut DagCtx, l: Lit) -> HopId {
        let dt = DataType::Scalar(l.vtype());
        ctx.dag.add(HopKind::Literal(l), vec![], dt)
    }

    /// Fold an expression to a constant f64 if trivially possible (literals
    /// and arithmetic on literals — full constant folding runs later as a
    /// rewrite; this handles rand()/matrix() parameters).
    fn const_f64(&mut self, e: &dast::Expr) -> Option<f64> {
        match e {
            dast::Expr::Int(v) => Some(*v as f64),
            dast::Expr::Num(v) => Some(*v),
            dast::Expr::Bool(b) => Some(if *b { 1.0 } else { 0.0 }),
            dast::Expr::Arg(i) => self.args.get(i).and_then(|s| s.parse().ok()),
            dast::Expr::Unary(dast::UnOp::Neg, a) => Some(-self.const_f64(a)?),
            dast::Expr::Binary(op, a, b) => {
                let (x, y) = (self.const_f64(a)?, self.const_f64(b)?);
                match op {
                    dast::BinOp::Add => Some(x + y),
                    dast::BinOp::Sub => Some(x - y),
                    dast::BinOp::Mul => Some(x * y),
                    dast::BinOp::Div => Some(x / y),
                    _ => None,
                }
            }
            _ => None,
        }
    }

    fn expr(&mut self, ctx: &mut DagCtx, e: &dast::Expr) -> Result<HopId, String> {
        match e {
            dast::Expr::Int(v) => Ok(self.lit(ctx, Lit::Int(*v))),
            dast::Expr::Num(v) => Ok(self.lit(ctx, Lit::Double(*v))),
            dast::Expr::Str(s) => Ok(self.lit(ctx, Lit::Str(s.clone()))),
            dast::Expr::Bool(b) => Ok(self.lit(ctx, Lit::Bool(*b))),
            dast::Expr::Arg(i) => {
                let s = self
                    .args
                    .get(i)
                    .ok_or_else(|| format!("missing command-line argument ${i}"))?;
                let l = if let Ok(v) = s.parse::<i64>() {
                    Lit::Int(v)
                } else if let Ok(v) = s.parse::<f64>() {
                    Lit::Double(v)
                } else {
                    Lit::Str(s.clone())
                };
                Ok(self.lit(ctx, l))
            }
            dast::Expr::Ident(name) => {
                if let Some(&h) = ctx.vars.get(name) {
                    return Ok(h);
                }
                // Transient read of a variable defined in an earlier block.
                // Data type is unknown until size propagation; assume matrix
                // (scalars are corrected by the inter-block propagation).
                let h = ctx.dag.add(HopKind::TRead { name: name.clone() }, vec![], DataType::Matrix);
                ctx.vars.insert(name.clone(), h);
                Ok(h)
            }
            dast::Expr::Unary(op, a) => {
                let ah = self.expr(ctx, a)?;
                let dt = ctx.dag.hop(ah).dtype.clone();
                let uop = match op {
                    dast::UnOp::Neg => UnOp::Neg,
                    dast::UnOp::Not => UnOp::Not,
                };
                Ok(ctx.dag.add(HopKind::Unary(uop), vec![ah], dt))
            }
            dast::Expr::Binary(op, a, b) => {
                let ah = self.expr(ctx, a)?;
                let bh = self.expr(ctx, b)?;
                let bop = match op {
                    dast::BinOp::Add => BinOp::Add,
                    dast::BinOp::Sub => BinOp::Sub,
                    dast::BinOp::Mul => BinOp::Mul,
                    dast::BinOp::Div => BinOp::Div,
                    dast::BinOp::Pow => BinOp::Pow,
                    dast::BinOp::Mod => BinOp::Mod,
                    dast::BinOp::IntDiv => BinOp::IntDiv,
                    dast::BinOp::Lt => BinOp::Lt,
                    dast::BinOp::Gt => BinOp::Gt,
                    dast::BinOp::Le => BinOp::Le,
                    dast::BinOp::Ge => BinOp::Ge,
                    dast::BinOp::Eq => BinOp::Eq,
                    dast::BinOp::Ne => BinOp::Ne,
                    dast::BinOp::And => BinOp::And,
                    dast::BinOp::Or => BinOp::Or,
                    dast::BinOp::MatMul => {
                        return Ok(ctx.dag.add(HopKind::MatMult, vec![ah, bh], DataType::Matrix));
                    }
                    dast::BinOp::Range => {
                        return Err("':' range is only allowed in for-loop bounds".into());
                    }
                };
                let dt = self.binary_dtype(ctx, bop, ah, bh);
                Ok(ctx.dag.add(HopKind::Binary(bop), vec![ah, bh], dt))
            }
            dast::Expr::Call(name, args) => self.call(ctx, name, args),
        }
    }

    fn binary_dtype(&self, ctx: &DagCtx, op: BinOp, a: HopId, b: HopId) -> DataType {
        let am = ctx.dag.hop(a).dtype.is_matrix();
        let bm = ctx.dag.hop(b).dtype.is_matrix();
        if am || bm {
            return DataType::Matrix;
        }
        match op {
            BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge | BinOp::Eq | BinOp::Ne | BinOp::And
            | BinOp::Or => DataType::Scalar(ValueType::Bool),
            BinOp::Div | BinOp::Pow => DataType::Scalar(ValueType::Double),
            _ => {
                let ai = matches!(ctx.dag.hop(a).dtype, DataType::Scalar(ValueType::Int));
                let bi = matches!(ctx.dag.hop(b).dtype, DataType::Scalar(ValueType::Int));
                if ai && bi {
                    DataType::Scalar(ValueType::Int)
                } else {
                    DataType::Scalar(ValueType::Double)
                }
            }
        }
    }

    fn call(&mut self, ctx: &mut DagCtx, name: &str, args: &[dast::Expr]) -> Result<HopId, String> {
        match name {
            "read" => {
                let path = self.path_of(ctx, &args[0])?;
                let (mc, format) = self
                    .meta
                    .stats(&path)
                    .unwrap_or((MatrixCharacteristics::unknown(), Format::BinaryBlock));
                let mut mc = mc;
                if mc.brows < 0 {
                    mc.brows = self.blocksize;
                    mc.bcols = self.blocksize;
                }
                let varname = format!("pREAD{}", sanitize(&path));
                let h = ctx.dag.add(
                    HopKind::PRead { name: varname, path, format },
                    vec![],
                    DataType::Matrix,
                );
                ctx.dag.hop_mut(h).mc = mc;
                Ok(h)
            }
            "matrix" => {
                let v = self
                    .const_f64(&args[0])
                    .ok_or("matrix() fill value must be a constant")?;
                let rows = self.expr(ctx, &args[1])?;
                let cols = self.expr(ctx, &args[2])?;
                Ok(ctx.dag.add(
                    HopKind::DataGen(DataGenOp::Rand { min: v, max: v, sparsity: 1.0, seed: -1 }),
                    vec![rows, cols],
                    DataType::Matrix,
                ))
            }
            "rand" => {
                let rows = self.expr(ctx, &args[0])?;
                let cols = self.expr(ctx, &args[1])?;
                let min = args.get(2).map(|a| self.const_f64(a)).flatten().unwrap_or(0.0);
                let max = args.get(3).map(|a| self.const_f64(a)).flatten().unwrap_or(1.0);
                let sparsity = args.get(4).map(|a| self.const_f64(a)).flatten().unwrap_or(1.0);
                let seed =
                    args.get(5).map(|a| self.const_f64(a)).flatten().unwrap_or(-1.0) as i64;
                Ok(ctx.dag.add(
                    HopKind::DataGen(DataGenOp::Rand { min, max, sparsity, seed }),
                    vec![rows, cols],
                    DataType::Matrix,
                ))
            }
            "seq" => {
                let from = self.const_f64(&args[0]).ok_or("seq() bounds must be constants")?;
                let to = self.const_f64(&args[1]).ok_or("seq() bounds must be constants")?;
                let by = args
                    .get(2)
                    .map(|a| self.const_f64(a).ok_or("seq() step must be constant"))
                    .transpose()?
                    .unwrap_or(if from <= to { 1.0 } else { -1.0 });
                Ok(ctx.dag.add(
                    HopKind::DataGen(DataGenOp::Seq { from, to, by }),
                    vec![],
                    DataType::Matrix,
                ))
            }
            "nrow" | "ncol" | "length" => {
                let a = self.expr(ctx, &args[0])?;
                let op = match name {
                    "nrow" => UnOp::Nrow,
                    "ncol" => UnOp::Ncol,
                    _ => UnOp::Length,
                };
                Ok(ctx.dag.add(HopKind::Unary(op), vec![a], DataType::Scalar(ValueType::Int)))
            }
            "t" => {
                let a = self.expr(ctx, &args[0])?;
                Ok(ctx.dag.add(HopKind::Reorg(ReorgOp::Transpose), vec![a], DataType::Matrix))
            }
            "diag" => {
                let a = self.expr(ctx, &args[0])?;
                Ok(ctx.dag.add(HopKind::Reorg(ReorgOp::Diag), vec![a], DataType::Matrix))
            }
            "solve" => {
                let a = self.expr(ctx, &args[0])?;
                let b = self.expr(ctx, &args[1])?;
                Ok(ctx.dag.add(HopKind::Binary(BinOp::Solve), vec![a, b], DataType::Matrix))
            }
            "append" | "cbind" => {
                let a = self.expr(ctx, &args[0])?;
                let b = self.expr(ctx, &args[1])?;
                Ok(ctx.dag.add(HopKind::Append, vec![a, b], DataType::Matrix))
            }
            "rbind" => Err("rbind is not supported by the HOP compiler yet".into()),
            "sum" | "mean" | "trace" | "nnz" => {
                let a = self.expr(ctx, &args[0])?;
                let op = match name {
                    "sum" => AggOp::Sum,
                    "mean" => AggOp::Mean,
                    "trace" => AggOp::Trace,
                    _ => AggOp::Nnz,
                };
                Ok(ctx.dag.add(
                    HopKind::AggUnary(op, AggDir::All),
                    vec![a],
                    DataType::Scalar(ValueType::Double),
                ))
            }
            "rowSums" | "rowMeans" => {
                let a = self.expr(ctx, &args[0])?;
                let op = if name == "rowSums" { AggOp::Sum } else { AggOp::Mean };
                Ok(ctx.dag.add(HopKind::AggUnary(op, AggDir::Row), vec![a], DataType::Matrix))
            }
            "colSums" | "colMeans" => {
                let a = self.expr(ctx, &args[0])?;
                let op = if name == "colSums" { AggOp::Sum } else { AggOp::Mean };
                Ok(ctx.dag.add(HopKind::AggUnary(op, AggDir::Col), vec![a], DataType::Matrix))
            }
            "min" | "max" => {
                let a = self.expr(ctx, &args[0])?;
                if args.len() == 2 {
                    let b = self.expr(ctx, &args[1])?;
                    let op = if name == "min" { BinOp::Min } else { BinOp::Max };
                    let dt = self.binary_dtype(ctx, op, a, b);
                    Ok(ctx.dag.add(HopKind::Binary(op), vec![a, b], dt))
                } else {
                    let op = if name == "min" { AggOp::Min } else { AggOp::Max };
                    Ok(ctx.dag.add(
                        HopKind::AggUnary(op, AggDir::All),
                        vec![a],
                        DataType::Scalar(ValueType::Double),
                    ))
                }
            }
            "sqrt" | "abs" | "exp" | "log" | "round" | "floor" | "ceil" | "sign" => {
                let a = self.expr(ctx, &args[0])?;
                let dt = ctx.dag.hop(a).dtype.clone();
                let op = match name {
                    "sqrt" => UnOp::Sqrt,
                    "abs" => UnOp::Abs,
                    "exp" => UnOp::Exp,
                    "log" => UnOp::Log,
                    "round" => UnOp::Round,
                    "floor" => UnOp::Floor,
                    "ceil" => UnOp::Ceil,
                    _ => UnOp::Sign,
                };
                let dt = if dt.is_matrix() { dt } else { DataType::Scalar(ValueType::Double) };
                Ok(ctx.dag.add(HopKind::Unary(op), vec![a], dt))
            }
            "as.scalar" => {
                let a = self.expr(ctx, &args[0])?;
                Ok(ctx.dag.add(
                    HopKind::Unary(UnOp::CastScalar),
                    vec![a],
                    DataType::Scalar(ValueType::Double),
                ))
            }
            "as.matrix" => {
                let a = self.expr(ctx, &args[0])?;
                Ok(ctx.dag.add(HopKind::Unary(UnOp::CastMatrix), vec![a], DataType::Matrix))
            }
            other => Err(format!("user-defined function '{other}' may only be called as a statement")),
        }
    }
}

fn sanitize(path: &str) -> String {
    path.rsplit('/').next().unwrap_or(path).replace(['.', '-'], "_")
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::dml;

    pub const LINREG_DS: &str = r#"X = read($1);
y = read($2);
intercept = $3; lambda = 0.001;
if( intercept == 1 ) {
  ones = matrix(1, nrow(X), 1);
  X = append(X, ones);
}
I = matrix(1, ncol(X), 1);
A = t(X) %*% X + diag(I)*lambda;
b = t(X) %*% y;
beta = solve(A, b);
write(beta, $4);"#;

    pub fn linreg_args() -> HashMap<usize, String> {
        let mut m = HashMap::new();
        m.insert(1, "data/X".to_string());
        m.insert(2, "data/y".to_string());
        m.insert(3, "0".to_string());
        m.insert(4, "data/beta".to_string());
        m
    }

    pub fn xs_meta() -> StaticMeta {
        StaticMeta::default()
            .with("data/X", MatrixCharacteristics::dense(10_000, 1_000, 1000), Format::BinaryBlock)
            .with("data/y", MatrixCharacteristics::dense(10_000, 1, 1000), Format::BinaryBlock)
    }

    #[test]
    fn linreg_builds_three_blocks_before_rewrites() {
        // Before branch removal: generic(lines 1-3), if(4-7), generic(8-12).
        let script = dml::frontend(LINREG_DS).unwrap();
        let prog = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap();
        assert_eq!(prog.blocks.len(), 3);
        assert!(matches!(prog.blocks[0], Block::Generic(_)));
        assert!(matches!(prog.blocks[1], Block::If { .. }));
        assert!(matches!(prog.blocks[2], Block::Generic(_)));
        let Block::Generic(g) = &prog.blocks[0] else { panic!() };
        assert_eq!(g.lines, (1, 3));
    }

    #[test]
    fn pread_gets_metadata() {
        let script = dml::frontend("X = read($1); s = sum(X); write(s, $4);").unwrap();
        let prog = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap();
        let Block::Generic(g) = &prog.blocks[0] else { panic!() };
        let pread = g.dag.hops.iter().find(|h| matches!(h.kind, HopKind::PRead { .. })).unwrap();
        assert_eq!(pread.mc.rows, 10_000);
        assert_eq!(pread.mc.nnz, 10_000_000);
    }

    #[test]
    fn arg_binds_to_literal() {
        let script = dml::frontend("i = $3; write(i, $4);").unwrap();
        let prog = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap();
        let Block::Generic(g) = &prog.blocks[0] else { panic!() };
        assert!(g.dag.hops.iter().any(|h| h.literal() == Some(&Lit::Int(0))));
    }

    #[test]
    fn transient_reads_created_for_cross_block_vars() {
        let script =
            dml::frontend("c = 1; if (c == 1) { d = 2; } e = c + 1; write(e, \"out\");").unwrap();
        let prog = build_program(&script, &HashMap::new(), &StaticMeta::default(), 1000).unwrap();
        // last block reads c transiently
        let Block::Generic(g) = prog.blocks.last().unwrap() else { panic!() };
        assert!(g
            .dag
            .hops
            .iter()
            .any(|h| matches!(&h.kind, HopKind::TRead { name } if name == "c")));
    }

    #[test]
    fn function_call_becomes_fcall_block() {
        let src = r#"
f = function(a) return (b) { b = a * 2; }
x = 3;
y = f(x);
write(y, "out");
"#;
        let script = dml::frontend(src).unwrap();
        let prog = build_program(&script, &HashMap::new(), &StaticMeta::default(), 1000).unwrap();
        assert!(prog.funcs.contains_key("f"));
        assert!(prog.blocks.iter().any(|b| matches!(b, Block::FCall { fname, .. } if fname == "f")));
    }

    #[test]
    fn fcall_with_expr_arg_introduces_temp() {
        let src = r#"
f = function(a) return (b) { b = a * 2; }
x = 3;
y = f(x + 1);
write(y, "out");
"#;
        let script = dml::frontend(src).unwrap();
        let prog = build_program(&script, &HashMap::new(), &StaticMeta::default(), 1000).unwrap();
        let Some(Block::FCall { args, .. }) =
            prog.blocks.iter().find(|b| matches!(b, Block::FCall { .. }))
        else {
            panic!()
        };
        assert!(args[0].starts_with("_fvar"));
    }

    #[test]
    fn missing_arg_is_error() {
        let script = dml::frontend("X = read($9); write(X, \"o\");").unwrap();
        let err = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap_err();
        assert!(err.contains("$9"));
    }

    #[test]
    fn twrite_roots_in_assignment_order() {
        let script = dml::frontend("a = 1; b = 2; write(b, \"o\");").unwrap();
        let prog = build_program(&script, &HashMap::new(), &StaticMeta::default(), 1000).unwrap();
        let Block::Generic(g) = &prog.blocks[0] else { panic!() };
        let names: Vec<String> = g
            .dag
            .roots
            .iter()
            .filter_map(|&r| match &g.dag.hop(r).kind {
                HopKind::TWrite { name } => Some(name.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(names, vec!["a", "b"]);
    }
}
