//! Inter-procedural size propagation (paper §2: "we propagated the input
//! dimension sizes over the entire program"): computes output
//! [`MatrixCharacteristics`] (dims, blocking, nnz) for every HOP, walking
//! program blocks in execution order with a symbol table of live-variable
//! statistics, handling loops (vars whose size changes across iterations
//! are reset to unknown), branches (merge = keep only agreeing sizes), and
//! function calls (with a call-stack guard against recursion).

use std::collections::HashMap;

use super::*;
use crate::matrix::MatrixCharacteristics;

/// Per-variable compile-time statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct SymInfo {
    pub mc: MatrixCharacteristics,
    pub dtype: DataType,
    /// Known literal value for scalars (drives `nrow(X)`-style folding and
    /// branch removal).
    pub lit: Option<Lit>,
}

impl SymInfo {
    pub fn scalar(lit: Option<Lit>, vt: ValueType) -> Self {
        SymInfo { mc: MatrixCharacteristics::scalar(), dtype: DataType::Scalar(vt), lit }
    }

    pub fn matrix(mc: MatrixCharacteristics) -> Self {
        SymInfo { mc, dtype: DataType::Matrix, lit: None }
    }
}

pub type SymTab = HashMap<String, SymInfo>;

/// Propagate sizes over the whole program. Also resolves `known_trip` on
/// for-loops whose bounds are literals.
pub fn propagate(prog: &mut Program, blocksize: i64) {
    let funcs = prog.funcs.clone();
    let mut symtab = SymTab::new();
    let mut stack = Vec::new();
    propagate_blocks(&mut prog.blocks, &mut symtab, &funcs, blocksize, &mut stack);
    // Also annotate the stored function bodies (the compiled-once runtime
    // versions) using the declared parameter kinds: scalar params become
    // scalar symbols, matrix/untyped params are unknown-size matrices
    // (SystemML's conservative non-IPA function compilation).
    for (name, func) in prog.funcs.iter_mut() {
        let mut ft = SymTab::new();
        for (i, p) in func.params.iter().enumerate() {
            let info = match func.param_kinds.get(i).copied().flatten() {
                Some(false) => SymInfo::scalar(None, ValueType::Double),
                _ => SymInfo::matrix(MatrixCharacteristics::unknown()),
            };
            ft.insert(p.clone(), info);
        }
        let mut st = vec![name.clone()];
        propagate_blocks(&mut func.body, &mut ft, &funcs, blocksize, &mut st);
    }
}

fn propagate_blocks(
    blocks: &mut [Block],
    symtab: &mut SymTab,
    funcs: &std::collections::BTreeMap<String, Function>,
    blocksize: i64,
    call_stack: &mut Vec<String>,
) {
    for b in blocks {
        match b {
            Block::Generic(g) => {
                propagate_dag(&mut g.dag, symtab, blocksize);
            }
            Block::If { pred, then_blocks, else_blocks, .. } => {
                propagate_dag(pred, symtab, blocksize);
                let mut then_tab = symtab.clone();
                propagate_blocks(then_blocks, &mut then_tab, funcs, blocksize, call_stack);
                let mut else_tab = symtab.clone();
                propagate_blocks(else_blocks, &mut else_tab, funcs, blocksize, call_stack);
                *symtab = merge_tabs(&then_tab, &else_tab);
            }
            Block::For { from, to, by, body, known_trip, .. } => {
                propagate_dag(from, symtab, blocksize);
                propagate_dag(to, symtab, blocksize);
                if let Some(by) = by {
                    propagate_dag(by, symtab, blocksize);
                }
                *known_trip = trip_count(from, to, by.as_ref());
                propagate_loop_body(body, symtab, funcs, blocksize, call_stack);
            }
            Block::While { pred, body, .. } => {
                propagate_dag(pred, symtab, blocksize);
                propagate_loop_body(body, symtab, funcs, blocksize, call_stack);
            }
            Block::FCall { fname, args, outputs, .. } => {
                let Some(func) = funcs.get(fname) else { continue };
                if call_stack.contains(fname) {
                    // Recursive call: outputs unknown (§3.2 function stack).
                    for o in outputs {
                        o_insert_unknown(symtab, o);
                    }
                    continue;
                }
                call_stack.push(fname.clone());
                let mut ftab = SymTab::new();
                for (p, a) in func.params.iter().zip(args.iter()) {
                    if let Some(info) = symtab.get(a) {
                        // Literal values do not cross the call boundary in
                        // SystemML unless IPA proves it; be conservative.
                        let mut info = info.clone();
                        info.lit = None;
                        ftab.insert(p.clone(), info);
                    } else {
                        ftab.insert(p.clone(), SymInfo::matrix(MatrixCharacteristics::unknown()));
                    }
                }
                let mut body = func.body.clone();
                propagate_blocks(&mut body, &mut ftab, funcs, blocksize, call_stack);
                call_stack.pop();
                for (caller_name, fn_out) in outputs.iter().zip(func.outputs.iter()) {
                    if let Some(info) = ftab.get(fn_out) {
                        symtab.insert(caller_name.clone(), info.clone());
                    } else {
                        o_insert_unknown(symtab, caller_name);
                    }
                }
            }
        }
    }
}

fn o_insert_unknown(symtab: &mut SymTab, name: &str) {
    symtab.insert(name.to_string(), SymInfo::matrix(MatrixCharacteristics::unknown()));
}

/// Loop bodies run an unknown number of times: propagate once on a copy,
/// reset any variable whose statistics changed (it varies per iteration)
/// to unknown, then propagate the body again with the stable statistics.
fn propagate_loop_body(
    body: &mut [Block],
    symtab: &mut SymTab,
    funcs: &std::collections::BTreeMap<String, Function>,
    blocksize: i64,
    call_stack: &mut Vec<String>,
) {
    let before = symtab.clone();
    let mut first = symtab.clone();
    // Literal values assigned before the loop may change inside it; clear
    // literals of any variable the body could reassign. We detect
    // reassignment by running the body once and diffing.
    propagate_blocks(body, &mut first, funcs, blocksize, call_stack);
    let mut stable = before.clone();
    for (name, after_info) in &first {
        match before.get(name) {
            Some(b) if b == after_info => {}
            Some(b) => {
                // changed inside the loop: wipe what differs
                let mut mc = b.mc;
                if b.mc.rows != after_info.mc.rows {
                    mc.rows = -1;
                }
                if b.mc.cols != after_info.mc.cols {
                    mc.cols = -1;
                }
                mc.nnz = -1;
                stable.insert(
                    name.clone(),
                    SymInfo { mc, dtype: after_info.dtype.clone(), lit: None },
                );
            }
            None => {
                // defined only inside the loop; sizes from the first
                // iteration may not hold for later ones — keep dims only if
                // they match a second propagation below.
                stable.insert(name.clone(), after_info.clone());
            }
        }
    }
    *symtab = stable;
    propagate_blocks(body, symtab, funcs, blocksize, call_stack);
}

/// Merge symbol tables after if/else: statistics survive only if both
/// branches agree; otherwise dims/nnz degrade to unknown.
fn merge_tabs(a: &SymTab, b: &SymTab) -> SymTab {
    let mut out = SymTab::new();
    for (name, ai) in a {
        match b.get(name) {
            Some(bi) if ai == bi => {
                out.insert(name.clone(), ai.clone());
            }
            Some(bi) => {
                let mc = MatrixCharacteristics {
                    rows: if ai.mc.rows == bi.mc.rows { ai.mc.rows } else { -1 },
                    cols: if ai.mc.cols == bi.mc.cols { ai.mc.cols } else { -1 },
                    brows: ai.mc.brows,
                    bcols: ai.mc.bcols,
                    nnz: if ai.mc.nnz == bi.mc.nnz { ai.mc.nnz } else { -1 },
                };
                out.insert(name.clone(), SymInfo { mc, dtype: ai.dtype.clone(), lit: None });
            }
            None => {
                out.insert(name.clone(), ai.clone());
            }
        }
    }
    for (name, bi) in b {
        out.entry(name.clone()).or_insert_with(|| bi.clone());
    }
    out
}

/// Static trip count of a for loop when bounds are literals.
fn trip_count(from: &HopDag, to: &HopDag, by: Option<&HopDag>) -> Option<f64> {
    let f = root_literal(from)?;
    let t = root_literal(to)?;
    let b = match by {
        Some(dag) => root_literal(dag)?,
        None => {
            if f <= t {
                1.0
            } else {
                -1.0
            }
        }
    };
    if b == 0.0 {
        return None;
    }
    Some((((t - f) / b).floor() + 1.0).max(0.0))
}

fn root_literal(dag: &HopDag) -> Option<f64> {
    let root = *dag.roots.first()?;
    dag.hop(root).literal().and_then(|l| l.as_f64())
}

/// Propagate sizes (and scalar literal values) through a single DAG given
/// the live-variable symbol table; updates the table at TWrites.
pub fn propagate_dag(dag: &mut HopDag, symtab: &mut SymTab, blocksize: i64) {
    let order = dag.topo_order();
    let mut values: Vec<Option<Lit>> = vec![None; dag.hops.len()];
    for id in order {
        // Pull scalar input values first (immutable pass).
        let hop = dag.hop(id).clone();
        let in_mc: Vec<MatrixCharacteristics> =
            hop.inputs.iter().map(|&i| dag.hop(i).mc).collect();
        let in_val: Vec<Option<Lit>> = hop.inputs.iter().map(|&i| values[i].clone()).collect();
        let (mc, val, dtype) = infer(dag, &hop, &in_mc, &in_val, symtab, blocksize);
        let h = dag.hop_mut(id);
        h.mc = mc;
        if let Some(dt) = dtype {
            h.dtype = dt;
        }
        values[id] = val;
        if let HopKind::TWrite { name } = &dag.hop(id).kind {
            let h = dag.hop(id);
            symtab.insert(
                name.clone(),
                SymInfo { mc: h.mc, dtype: h.dtype.clone(), lit: values[id].clone() },
            );
        }
    }
}

/// Size/value inference for one HOP. Returns (mc, scalar value, dtype fix).
fn infer(
    _dag: &HopDag,
    hop: &Hop,
    in_mc: &[MatrixCharacteristics],
    in_val: &[Option<Lit>],
    symtab: &SymTab,
    blocksize: i64,
) -> (MatrixCharacteristics, Option<Lit>, Option<DataType>) {
    use HopKind::*;
    let scalar = MatrixCharacteristics::scalar;
    match &hop.kind {
        Literal(l) => (scalar(), Some(l.clone()), None),
        PRead { .. } => (hop.mc, None, None), // set at build from metadata
        PWrite { .. } | TWrite { .. } => (
            in_mc.first().copied().unwrap_or_else(MatrixCharacteristics::unknown),
            in_val.first().cloned().flatten(),
            // dtype follows the (already-corrected) input hop — TReads are
            // provisionally typed Matrix at build time
            hop.inputs.first().map(|&i| _dag.hop(i).dtype.clone()),
        ),
        TRead { name } => match symtab.get(name) {
            Some(info) => (info.mc, info.lit.clone(), Some(info.dtype.clone())),
            None => (MatrixCharacteristics::unknown(), None, None),
        },
        DataGen(DataGenOp::Rand { min, max, sparsity, .. }) => {
            let rows = in_val.first().and_then(|v| v.as_ref()).and_then(|l| l.as_f64());
            let cols = in_val.get(1).and_then(|v| v.as_ref()).and_then(|l| l.as_f64());
            let (r, c) = (rows.map_or(-1, |v| v as i64), cols.map_or(-1, |v| v as i64));
            let mut mc = MatrixCharacteristics::new(r, c, blocksize, -1);
            if r >= 0 && c >= 0 {
                mc.nnz = if *min == 0.0 && *max == 0.0 {
                    0
                } else {
                    ((r as f64 * c as f64) * sparsity.clamp(0.0, 1.0)) as i64
                };
            }
            (mc, None, None)
        }
        DataGen(DataGenOp::Seq { from, to, by }) => {
            let n = if *by != 0.0 { (((to - from) / by).floor() + 1.0).max(0.0) as i64 } else { -1 };
            (MatrixCharacteristics::new(n, 1, blocksize, n), None, None)
        }
        Reorg(ReorgOp::Transpose) => {
            let i = in_mc[0];
            (MatrixCharacteristics { rows: i.cols, cols: i.rows, ..i }, None, None)
        }
        Reorg(ReorgOp::Diag) => {
            let i = in_mc[0];
            if i.cols == 1 {
                // vector -> diagonal matrix
                (MatrixCharacteristics::new(i.rows, i.rows, blocksize, i.nnz), None, None)
            } else {
                // square matrix -> diagonal vector
                let nnz = if i.nnz >= 0 { i.nnz.min(i.rows) } else { -1 };
                (MatrixCharacteristics::new(i.rows, 1, blocksize, nnz), None, None)
            }
        }
        MatMult => {
            let (a, b) = (in_mc[0], in_mc[1]);
            (MatrixCharacteristics::new(a.rows, b.cols, blocksize, -1), None, None)
        }
        Binary(op) => {
            let am = hop.inputs.first().map(|_| in_mc[0]);
            let a_is_m = in_mc[0].rows != 0 || in_mc[0].cols != 0; // scalar mc is (0,0)
            let b_is_m = in_mc.len() > 1 && (in_mc[1].rows != 0 || in_mc[1].cols != 0);
            if *op == BinOp::Solve {
                let (a, b) = (in_mc[0], in_mc[1]);
                return (
                    MatrixCharacteristics::new(a.cols, b.cols, blocksize, -1),
                    None,
                    None,
                );
            }
            match (a_is_m, b_is_m) {
                (false, false) => {
                    // scalar op scalar: fold value if both known; also fix
                    // the dtype (TReads are provisionally typed Matrix)
                    let v = match (&in_val[0], &in_val[1]) {
                        (Some(x), Some(y)) => op.fold(x, y),
                        _ => None,
                    };
                    let vt = match op {
                        BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge | BinOp::Eq | BinOp::Ne
                        | BinOp::And | BinOp::Or => ValueType::Bool,
                        _ => ValueType::Double,
                    };
                    (scalar(), v, Some(DataType::Scalar(vt)))
                }
                (true, false) => {
                    let mut mc = am.unwrap();
                    mc.nnz = scalar_op_nnz(*op, mc.nnz, &in_val[1]);
                    (mc, None, None)
                }
                (false, true) => {
                    let mut mc = in_mc[1];
                    mc.nnz = scalar_op_nnz(*op, mc.nnz, &in_val[0]);
                    (mc, None, None)
                }
                (true, true) => {
                    // elementwise (or broadcast vector) — result dims are the
                    // larger input's dims
                    let (a, b) = (in_mc[0], in_mc[1]);
                    let rows = a.rows.max(b.rows);
                    let cols = a.cols.max(b.cols);
                    let nnz = match op {
                        BinOp::Mul => {
                            if a.nnz >= 0 && b.nnz >= 0 {
                                a.nnz.min(b.nnz)
                            } else {
                                -1
                            }
                        }
                        BinOp::Add | BinOp::Sub => {
                            if a.nnz >= 0 && b.nnz >= 0 {
                                (a.nnz + b.nnz).min(rows.saturating_mul(cols))
                            } else {
                                -1
                            }
                        }
                        _ => -1,
                    };
                    (MatrixCharacteristics::new(rows, cols, blocksize, nnz), None, None)
                }
            }
        }
        Unary(op) => {
            let is_matrix = hop.dtype.is_matrix()
                || (!in_mc.is_empty() && (in_mc[0].rows != 0 || in_mc[0].cols != 0));
            match op {
                UnOp::Nrow | UnOp::Ncol | UnOp::Length => {
                    let i = in_mc[0];
                    let v = match op {
                        UnOp::Nrow if i.rows >= 0 => Some(Lit::Int(i.rows)),
                        UnOp::Ncol if i.cols >= 0 => Some(Lit::Int(i.cols)),
                        UnOp::Length if i.dims_known() => Some(Lit::Int(i.rows * i.cols)),
                        _ => None,
                    };
                    (scalar(), v, Some(DataType::Scalar(ValueType::Int)))
                }
                UnOp::CastScalar => (scalar(), in_val[0].clone(), None),
                UnOp::CastMatrix => {
                    (MatrixCharacteristics::new(1, 1, blocksize, -1), None, None)
                }
                _ if !is_matrix => {
                    let v = in_val[0].as_ref().and_then(|l| op.fold(l));
                    (scalar(), v, None)
                }
                _ => {
                    let mut mc = in_mc[0];
                    mc.nnz = match op {
                        UnOp::Sqrt | UnOp::Abs | UnOp::Sign | UnOp::Round | UnOp::Floor
                        | UnOp::Ceil | UnOp::Neg => mc.nnz,
                        _ => -1,
                    };
                    (mc, None, None)
                }
            }
        }
        AggUnary(_, AggDir::All) => (scalar(), None, None),
        AggUnary(_, AggDir::Row) => {
            let i = in_mc[0];
            (MatrixCharacteristics::new(i.rows, 1, blocksize, -1), None, None)
        }
        AggUnary(_, AggDir::Col) => {
            let i = in_mc[0];
            (MatrixCharacteristics::new(1, i.cols, blocksize, -1), None, None)
        }
        Append => {
            let (a, b) = (in_mc[0], in_mc[1]);
            let cols = if a.cols >= 0 && b.cols >= 0 { a.cols + b.cols } else { -1 };
            let nnz = if a.nnz >= 0 && b.nnz >= 0 { a.nnz + b.nnz } else { -1 };
            (MatrixCharacteristics::new(a.rows, cols, blocksize, nnz), None, None)
        }
        Print => (scalar(), None, None),
    }
}

/// nnz after a matrix-scalar op, when the scalar value may be known.
fn scalar_op_nnz(op: BinOp, nnz: i64, scalar: &Option<Lit>) -> i64 {
    match op {
        BinOp::Mul | BinOp::Div => nnz, // zero stays zero
        BinOp::Add | BinOp::Sub => match scalar.as_ref().and_then(|l| l.as_f64()) {
            Some(v) if v == 0.0 => nnz,
            _ => -1,
        },
        _ => -1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml;
    use crate::ir::build::{build_program, tests::linreg_args, tests::xs_meta, tests::LINREG_DS};

    fn build_and_prop(src: &str) -> Program {
        let script = dml::frontend(src).unwrap();
        let mut prog = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap();
        // Figure 1 shows sizes *after* rewrites (branch removal in
        // particular — without it X's columns are conservatively unknown).
        crate::ir::rewrites::rewrite_program(&mut prog);
        propagate(&mut prog, 1000);
        prog
    }

    fn find_mc(prog: &Program, pred: impl Fn(&Hop) -> bool) -> MatrixCharacteristics {
        let mut found = None;
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    // live hops only — rewrites leave dead hops in the arena
                    let h = g.dag.hop(id);
                    if pred(h) {
                        found = Some(h.mc);
                    }
                }
            }
        }
        found.expect("hop not found")
    }

    #[test]
    fn linreg_sizes_match_figure1() {
        let prog = build_and_prop(LINREG_DS);
        // r(t): [1e3, 1e4]
        let t = find_mc(&prog, |h| h.kind == HopKind::Reorg(ReorgOp::Transpose) && h.mc.rows != 0);
        assert_eq!((t.rows, t.cols), (1_000, 10_000));
        // dg(rand) for I: [1e3, 1] — requires ncol(X) scalar propagation
        let rand = find_mc(&prog, |h| matches!(h.kind, HopKind::DataGen(_)));
        assert_eq!((rand.rows, rand.cols, rand.nnz), (1_000, 1, 1_000));
        // r(diag): [1e3, 1e3] with nnz 1e3
        let diag = find_mc(&prog, |h| h.kind == HopKind::Reorg(ReorgOp::Diag));
        assert_eq!((diag.rows, diag.cols, diag.nnz), (1_000, 1_000, 1_000));
        // b(solve): [1e3, 1]
        let solve = find_mc(&prog, |h| h.kind == HopKind::Binary(BinOp::Solve));
        assert_eq!((solve.rows, solve.cols), (1_000, 1));
    }

    #[test]
    fn matmult_dims_and_unknown_nnz() {
        let prog = build_and_prop(LINREG_DS);
        let mut seen = Vec::new();
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for h in &g.dag.hops {
                    if h.kind == HopKind::MatMult {
                        seen.push(h.mc);
                    }
                }
            }
        }
        assert!(seen.iter().any(|m| (m.rows, m.cols) == (1_000, 1_000)));
        assert!(seen.iter().any(|m| (m.rows, m.cols) == (1_000, 1)));
        assert!(seen.iter().all(|m| m.nnz == -1));
    }

    #[test]
    fn loop_changing_sizes_reset_to_unknown() {
        let src = r#"
X = read($1);
for (i in 1:3) {
  X = append(X, matrix(1, nrow(X), 1));
}
write(X, $4);
"#;
        let prog = build_and_prop(src);
        // Inside the loop, cols of X change each iteration -> unknown.
        let Block::For { body, .. } =
            prog.blocks.iter().find(|b| matches!(b, Block::For { .. })).unwrap()
        else {
            panic!()
        };
        let Block::Generic(g) = &body[0] else { panic!() };
        let tread = g
            .dag
            .hops
            .iter()
            .find(|h| matches!(&h.kind, HopKind::TRead { name } if name == "X"))
            .unwrap();
        assert_eq!(tread.mc.rows, 10_000); // rows stable
        assert_eq!(tread.mc.cols, -1); // cols vary
    }

    #[test]
    fn for_trip_count_literal_bounds() {
        let src = "s = 0; for (i in 1:10) { s = s + 1; } write(s, $4);";
        let prog = build_and_prop(src);
        let Block::For { known_trip, .. } =
            prog.blocks.iter().find(|b| matches!(b, Block::For { .. })).unwrap()
        else {
            panic!()
        };
        assert_eq!(*known_trip, Some(10.0));
    }

    #[test]
    fn if_merge_keeps_agreeing_sizes() {
        let src = r#"
X = read($1);
c = 1;
if (c == 1) { Z = X * 2; } else { Z = X + 1; }
s = sum(Z);
write(s, $4);
"#;
        let prog = build_and_prop(src);
        // Z has the same dims in both branches -> known after merge.
        let last = prog.blocks.iter().rev().find_map(|b| match b {
            Block::Generic(g) => g
                .dag
                .hops
                .iter()
                .find(|h| matches!(&h.kind, HopKind::TRead { name } if name == "Z"))
                .map(|h| h.mc),
            _ => None,
        });
        let mc = last.expect("TRead Z");
        assert_eq!((mc.rows, mc.cols), (10_000, 1_000));
    }

    #[test]
    fn function_call_propagates_output_size() {
        let src = r#"
f = function(A) return (B) { B = t(A); }
X = read($1);
Y = f(X);
s = sum(Y);
write(s, $4);
"#;
        let prog = build_and_prop(src);
        let mc = prog
            .blocks
            .iter()
            .rev()
            .find_map(|b| match b {
                Block::Generic(g) => g
                    .dag
                    .hops
                    .iter()
                    .find(|h| matches!(&h.kind, HopKind::TRead { name } if name == "Y"))
                    .map(|h| h.mc),
                _ => None,
            })
            .expect("TRead Y");
        assert_eq!((mc.rows, mc.cols), (1_000, 10_000));
    }

    #[test]
    fn recursive_function_outputs_unknown() {
        let src = r#"
f = function(A) return (B) { B = f(A); }
X = read($1);
Y = f(X);
s = sum(Y);
write(s, $4);
"#;
        let script = dml::frontend(src).unwrap();
        let mut prog = build_program(&script, &linreg_args(), &xs_meta(), 1000).unwrap();
        propagate(&mut prog, 1000); // must terminate
        let mc = prog
            .blocks
            .iter()
            .rev()
            .find_map(|b| match b {
                Block::Generic(g) => g
                    .dag
                    .hops
                    .iter()
                    .find(|h| matches!(&h.kind, HopKind::TRead { name } if name == "Y"))
                    .map(|h| h.mc),
                _ => None,
            })
            .expect("TRead Y");
        assert_eq!(mc.rows, -1);
    }
}
