//! High-level operator (HOP) intermediate representation.
//!
//! A DML script compiles into a [`Program`]: a hierarchy of program blocks
//! ([`Block`]) where straight-line statement sequences form *generic* blocks
//! holding one HOP DAG each, and control-flow constructs (if/for/while/
//! parfor/function call) nest child blocks — exactly the structure SystemML's
//! `EXPLAIN hops` prints (paper Figure 1). Variables crossing block
//! boundaries materialise as transient reads/writes (`TRead`/`TWrite`).

pub mod build;
pub mod exec_type;
pub mod explain;
pub mod memory;
pub mod rewrites;
pub mod size_prop;

use std::collections::BTreeMap;

use crate::matrix::{Format, MatrixCharacteristics};

/// HOP identifier: index into the owning [`HopDag`] arena.
pub type HopId = usize;

/// Scalar value types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueType {
    Int,
    Double,
    Bool,
    Str,
}

/// Literal scalar values.
#[derive(Clone, Debug, PartialEq)]
pub enum Lit {
    Int(i64),
    Double(f64),
    Bool(bool),
    Str(String),
}

impl Lit {
    pub fn vtype(&self) -> ValueType {
        match self {
            Lit::Int(_) => ValueType::Int,
            Lit::Double(_) => ValueType::Double,
            Lit::Bool(_) => ValueType::Bool,
            Lit::Str(_) => ValueType::Str,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Lit::Int(v) => Some(*v as f64),
            Lit::Double(v) => Some(*v),
            Lit::Bool(b) => Some(if *b { 1.0 } else { 0.0 }),
            Lit::Str(_) => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Lit::Bool(b) => Some(*b),
            Lit::Int(v) => Some(*v != 0),
            Lit::Double(v) => Some(*v != 0.0),
            Lit::Str(_) => None,
        }
    }

    pub fn render(&self) -> String {
        match self {
            Lit::Int(v) => v.to_string(),
            Lit::Double(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    format!("{v:.1}")
                } else {
                    format!("{v}")
                }
            }
            Lit::Bool(b) => if *b { "true" } else { "false" }.to_string(),
            Lit::Str(s) => s.clone(),
        }
    }
}

/// Data type of a HOP's output.
#[derive(Clone, Debug, PartialEq)]
pub enum DataType {
    Matrix,
    Scalar(ValueType),
}

impl DataType {
    pub fn is_matrix(&self) -> bool {
        matches!(self, DataType::Matrix)
    }
}

/// Execution type chosen for a HOP (paper §2: CP = single-node in-memory
/// control program, MR = distributed MapReduce).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExecType {
    Cp,
    Mr,
}

impl ExecType {
    pub fn name(&self) -> &'static str {
        match self {
            ExecType::Cp => "CP",
            ExecType::Mr => "MR",
        }
    }
}

/// Reorganisation ops (`r(...)` in EXPLAIN).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReorgOp {
    Transpose, // r(t)
    Diag,      // r(diag)
}

/// Elementwise / scalar binary ops (`b(...)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Min,
    Max,
    Solve,
    Lt,
    Gt,
    Le,
    Ge,
    Eq,
    Ne,
    And,
    Or,
    Mod,
    IntDiv,
}

impl BinOp {
    pub fn code(&self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Pow => "^",
            BinOp::Min => "min",
            BinOp::Max => "max",
            BinOp::Solve => "solve",
            BinOp::Lt => "<",
            BinOp::Gt => ">",
            BinOp::Le => "<=",
            BinOp::Ge => ">=",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "&&",
            BinOp::Or => "||",
            BinOp::Mod => "%%",
            BinOp::IntDiv => "%/%",
        }
    }

    /// Apply to two scalar literals (constant folding).
    pub fn fold(&self, a: &Lit, b: &Lit) -> Option<Lit> {
        use BinOp::*;
        let (x, y) = (a.as_f64()?, b.as_f64()?);
        let num = |v: f64| {
            if matches!((a, b), (Lit::Int(_), Lit::Int(_)))
                && v.fract() == 0.0
                && !matches!(self, Div | Pow)
            {
                Lit::Int(v as i64)
            } else {
                Lit::Double(v)
            }
        };
        Some(match self {
            Add => num(x + y),
            Sub => num(x - y),
            Mul => num(x * y),
            Div => Lit::Double(x / y),
            Pow => Lit::Double(x.powf(y)),
            Min => num(x.min(y)),
            Max => num(x.max(y)),
            Mod => num(x - (x / y).floor() * y),
            IntDiv => num((x / y).floor()),
            Lt => Lit::Bool(x < y),
            Gt => Lit::Bool(x > y),
            Le => Lit::Bool(x <= y),
            Ge => Lit::Bool(x >= y),
            Eq => Lit::Bool(x == y),
            Ne => Lit::Bool(x != y),
            And => Lit::Bool(x != 0.0 && y != 0.0),
            Or => Lit::Bool(x != 0.0 || y != 0.0),
            Solve => return None,
        })
    }
}

/// Unary ops (`u(...)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Nrow,
    Ncol,
    Length,
    Sqrt,
    Abs,
    Exp,
    Log,
    Round,
    Floor,
    Ceil,
    Sign,
    Not,
    Neg,
    CastScalar, // as.scalar
    CastMatrix, // as.matrix
}

impl UnOp {
    pub fn code(&self) -> &'static str {
        match self {
            UnOp::Nrow => "nrow",
            UnOp::Ncol => "ncol",
            UnOp::Length => "length",
            UnOp::Sqrt => "sqrt",
            UnOp::Abs => "abs",
            UnOp::Exp => "exp",
            UnOp::Log => "log",
            UnOp::Round => "round",
            UnOp::Floor => "floor",
            UnOp::Ceil => "ceil",
            UnOp::Sign => "sign",
            UnOp::Not => "!",
            UnOp::Neg => "-",
            UnOp::CastScalar => "castdts",
            UnOp::CastMatrix => "castdtm",
        }
    }

    pub fn fold(&self, a: &Lit) -> Option<Lit> {
        let x = a.as_f64()?;
        Some(match self {
            UnOp::Sqrt => Lit::Double(x.sqrt()),
            UnOp::Abs => Lit::Double(x.abs()),
            UnOp::Exp => Lit::Double(x.exp()),
            UnOp::Log => Lit::Double(x.ln()),
            UnOp::Round => Lit::Double(x.round()),
            UnOp::Floor => Lit::Double(x.floor()),
            UnOp::Ceil => Lit::Double(x.ceil()),
            UnOp::Sign => Lit::Double(x.signum()),
            UnOp::Not => Lit::Bool(x == 0.0),
            UnOp::Neg => match a {
                Lit::Int(v) => Lit::Int(-v),
                _ => Lit::Double(-x),
            },
            _ => return None,
        })
    }
}

/// Full/row/column aggregation ops (`ua(...)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggOp {
    Sum,
    Mean,
    Min,
    Max,
    Trace,
    Nnz,
}

/// Aggregation direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggDir {
    All, // RC -> scalar
    Row, // R  -> column vector of row aggregates? (SystemML: uark+ -> m x 1)
    Col, // C  -> 1 x n
}

/// Data-generating ops (`dg(...)`).
#[derive(Clone, Debug, PartialEq)]
pub enum DataGenOp {
    /// rand(rows, cols, min, max, sparsity, seed); `matrix(v, r, c)` is
    /// Rand with min == max == v (SystemML does the same — Figure 2 shows
    /// `rand ... 0.0010 0.0010 1.0` for `matrix(lambda, ncol(X), 1)`).
    Rand { min: f64, max: f64, sparsity: f64, seed: i64 },
    /// seq(from, to, by)
    Seq { from: f64, to: f64, by: f64 },
}

/// HOP operation kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum HopKind {
    /// Persistent read from (simulated) HDFS.
    PRead { name: String, path: String, format: Format },
    /// Persistent write; `path` may come from a `$N` argument.
    PWrite { name: String, path: String, format: Format },
    /// Transient read of a live variable.
    TRead { name: String },
    /// Transient write of a live variable (a DAG root).
    TWrite { name: String },
    /// Scalar literal.
    Literal(Lit),
    /// Data generation: inputs are [rows, cols] scalar HOPs.
    DataGen(DataGenOp),
    /// Reorganisation: transpose / diag.
    Reorg(ReorgOp),
    /// Matrix multiplication `ba(+*)`.
    MatMult,
    /// Elementwise or matrix-scalar binary op / solve.
    Binary(BinOp),
    /// Unary op (matrix elementwise or scalar meta like nrow).
    Unary(UnOp),
    /// Unary aggregate, e.g. `ua(+RC)` = sum.
    AggUnary(AggOp, AggDir),
    /// Horizontal append (cbind).
    Append,
    /// Print (root).
    Print,
}

impl HopKind {
    /// EXPLAIN operator name, matching SystemML (paper Figure 1).
    pub fn opcode(&self) -> String {
        match self {
            HopKind::PRead { name, .. } => format!("PRead {name}"),
            HopKind::PWrite { name, .. } => format!("PWrite {name}"),
            HopKind::TRead { name } => format!("TRead {name}"),
            HopKind::TWrite { name } => format!("TWrite {name}"),
            HopKind::Literal(l) => format!("lit({})", l.render()),
            HopKind::DataGen(DataGenOp::Rand { .. }) => "dg(rand)".into(),
            HopKind::DataGen(DataGenOp::Seq { .. }) => "dg(seq)".into(),
            HopKind::Reorg(ReorgOp::Transpose) => "r(t)".into(),
            HopKind::Reorg(ReorgOp::Diag) => "r(diag)".into(),
            HopKind::MatMult => "ba(+*)".into(),
            HopKind::Binary(op) => format!("b({})", op.code()),
            HopKind::Unary(op) => format!("u({})", op.code()),
            HopKind::AggUnary(op, dir) => {
                let o = match op {
                    AggOp::Sum => "+",
                    AggOp::Mean => "mean",
                    AggOp::Min => "min",
                    AggOp::Max => "max",
                    AggOp::Trace => "trace",
                    AggOp::Nnz => "nnz",
                };
                let d = match dir {
                    AggDir::All => "RC",
                    AggDir::Row => "R",
                    AggDir::Col => "C",
                };
                format!("ua({o}{d})")
            }
            HopKind::Append => "append".into(),
            HopKind::Print => "u(print)".into(),
        }
    }
}

/// One high-level operator.
#[derive(Clone, Debug)]
pub struct Hop {
    pub id: HopId,
    pub kind: HopKind,
    pub inputs: Vec<HopId>,
    pub dtype: DataType,
    /// Output size information (rows, cols, blocking, nnz).
    pub mc: MatrixCharacteristics,
    /// Output memory estimate `M̂` in bytes.
    pub out_mem: f64,
    /// Operation memory estimate (inputs + intermediates + output).
    pub op_mem: f64,
    /// Selected execution type (None before selection).
    pub exec: Option<ExecType>,
}

impl Hop {
    pub fn is_literal(&self) -> bool {
        matches!(self.kind, HopKind::Literal(_))
    }

    pub fn literal(&self) -> Option<&Lit> {
        match &self.kind {
            HopKind::Literal(l) => Some(l),
            _ => None,
        }
    }
}

/// A HOP DAG stored as an arena; `roots` are outputs in program order
/// (TWrite/PWrite/Print hops).
#[derive(Clone, Debug, Default)]
pub struct HopDag {
    pub hops: Vec<Hop>,
    pub roots: Vec<HopId>,
}

impl HopDag {
    pub fn add(&mut self, kind: HopKind, inputs: Vec<HopId>, dtype: DataType) -> HopId {
        let id = self.hops.len();
        self.hops.push(Hop {
            id,
            kind,
            inputs,
            dtype,
            mc: MatrixCharacteristics::unknown(),
            out_mem: f64::INFINITY,
            op_mem: f64::INFINITY,
            exec: None,
        });
        id
    }

    pub fn hop(&self, id: HopId) -> &Hop {
        &self.hops[id]
    }

    pub fn hop_mut(&mut self, id: HopId) -> &mut Hop {
        &mut self.hops[id]
    }

    /// Topological order over live hops (those reachable from roots),
    /// children before parents.
    pub fn topo_order(&self) -> Vec<HopId> {
        let mut visited = vec![false; self.hops.len()];
        let mut order = Vec::with_capacity(self.hops.len());
        // Iterative DFS to avoid recursion limits on deep DAGs.
        for &root in &self.roots {
            if visited[root] {
                continue;
            }
            let mut stack = vec![(root, 0usize)];
            visited[root] = true;
            while let Some((id, child_idx)) = stack.pop() {
                let inputs = &self.hops[id].inputs;
                if child_idx < inputs.len() {
                    stack.push((id, child_idx + 1));
                    let c = inputs[child_idx];
                    if !visited[c] {
                        visited[c] = true;
                        stack.push((c, 0));
                    }
                } else {
                    order.push(id);
                }
            }
        }
        order
    }

    /// Number of live (reachable) hops.
    pub fn live_count(&self) -> usize {
        self.topo_order().len()
    }
}

/// A generic (straight-line) program block holding one HOP DAG.
#[derive(Clone, Debug)]
pub struct GenericBlock {
    pub dag: HopDag,
    pub lines: (usize, usize),
    /// Dynamic-recompilation marker, printed by EXPLAIN.
    pub recompile: bool,
}

/// Program blocks (§3.2: "hierarchy of program blocks and instructions").
#[derive(Clone, Debug)]
pub enum Block {
    Generic(GenericBlock),
    If {
        pred: HopDag,
        then_blocks: Vec<Block>,
        else_blocks: Vec<Block>,
        lines: (usize, usize),
    },
    For {
        var: String,
        from: HopDag,
        to: HopDag,
        by: Option<HopDag>,
        body: Vec<Block>,
        parfor: bool,
        /// Trip count when statically known.
        known_trip: Option<f64>,
        lines: (usize, usize),
    },
    While {
        pred: HopDag,
        body: Vec<Block>,
        lines: (usize, usize),
    },
    /// Call to a user-defined function: binds `args` (live variable names)
    /// to formals, executes the function body, binds outputs back.
    FCall {
        fname: String,
        args: Vec<String>,
        outputs: Vec<String>,
        lines: (usize, usize),
    },
}

impl Block {
    pub fn lines(&self) -> (usize, usize) {
        match self {
            Block::Generic(g) => g.lines,
            Block::If { lines, .. }
            | Block::For { lines, .. }
            | Block::While { lines, .. }
            | Block::FCall { lines, .. } => *lines,
        }
    }
}

/// A user-defined function.
#[derive(Clone, Debug)]
pub struct Function {
    pub params: Vec<String>,
    /// Declared parameter kinds: `Some(true)` matrix, `Some(false)` scalar.
    pub param_kinds: Vec<Option<bool>>,
    pub outputs: Vec<String>,
    pub body: Vec<Block>,
}

/// A compiled program: main block list plus function definitions.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub blocks: Vec<Block>,
    pub funcs: BTreeMap<String, Function>,
}

impl Program {
    /// Visit every HOP DAG in the program (main + functions), in order.
    pub fn for_each_dag_mut(&mut self, f: &mut impl FnMut(&mut HopDag)) {
        fn walk(blocks: &mut [Block], f: &mut impl FnMut(&mut HopDag)) {
            for b in blocks {
                match b {
                    Block::Generic(g) => f(&mut g.dag),
                    Block::If { pred, then_blocks, else_blocks, .. } => {
                        f(pred);
                        walk(then_blocks, f);
                        walk(else_blocks, f);
                    }
                    Block::For { from, to, by, body, .. } => {
                        f(from);
                        f(to);
                        if let Some(by) = by {
                            f(by);
                        }
                        walk(body, f);
                    }
                    Block::While { pred, body, .. } => {
                        f(pred);
                        walk(body, f);
                    }
                    Block::FCall { .. } => {}
                }
            }
        }
        walk(&mut self.blocks, f);
        for func in self.funcs.values_mut() {
            walk(&mut func.body, f);
        }
    }

    /// Total number of live hops across all DAGs (compile statistics).
    pub fn total_hops(&self) -> usize {
        let mut n = 0;
        let mut me = self.clone();
        me.for_each_dag_mut(&mut |d| n += d.live_count());
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dag_topo_order_children_first() {
        let mut dag = HopDag::default();
        let x = dag.add(HopKind::TRead { name: "X".into() }, vec![], DataType::Matrix);
        let t = dag.add(HopKind::Reorg(ReorgOp::Transpose), vec![x], DataType::Matrix);
        let m = dag.add(HopKind::MatMult, vec![t, x], DataType::Matrix);
        let w = dag.add(HopKind::TWrite { name: "A".into() }, vec![m], DataType::Matrix);
        dag.roots.push(w);
        let order = dag.topo_order();
        let pos = |id| order.iter().position(|&h| h == id).unwrap();
        assert!(pos(x) < pos(t));
        assert!(pos(t) < pos(m));
        assert!(pos(m) < pos(w));
        assert_eq!(order.len(), 4);
    }

    #[test]
    fn dead_hops_not_in_topo() {
        let mut dag = HopDag::default();
        let _dead = dag.add(HopKind::Literal(Lit::Int(1)), vec![], DataType::Scalar(ValueType::Int));
        let live = dag.add(HopKind::Literal(Lit::Int(2)), vec![], DataType::Scalar(ValueType::Int));
        let w = dag.add(HopKind::TWrite { name: "x".into() }, vec![live], DataType::Scalar(ValueType::Int));
        dag.roots.push(w);
        assert_eq!(dag.live_count(), 2);
    }

    #[test]
    fn binop_fold_arith_and_compare() {
        assert_eq!(BinOp::Add.fold(&Lit::Int(2), &Lit::Int(3)), Some(Lit::Int(5)));
        assert_eq!(BinOp::Mul.fold(&Lit::Double(2.5), &Lit::Int(2)), Some(Lit::Double(5.0)));
        assert_eq!(BinOp::Eq.fold(&Lit::Int(0), &Lit::Int(1)), Some(Lit::Bool(false)));
        assert_eq!(BinOp::Div.fold(&Lit::Int(1), &Lit::Int(2)), Some(Lit::Double(0.5)));
        assert_eq!(BinOp::Solve.fold(&Lit::Int(1), &Lit::Int(2)), None);
    }

    #[test]
    fn opcodes_match_systemml_explain() {
        assert_eq!(HopKind::MatMult.opcode(), "ba(+*)");
        assert_eq!(HopKind::Reorg(ReorgOp::Transpose).opcode(), "r(t)");
        assert_eq!(HopKind::Reorg(ReorgOp::Diag).opcode(), "r(diag)");
        assert_eq!(HopKind::Binary(BinOp::Solve).opcode(), "b(solve)");
        assert_eq!(
            HopKind::DataGen(DataGenOp::Rand { min: 0.0, max: 0.0, sparsity: 1.0, seed: -1 })
                .opcode(),
            "dg(rand)"
        );
        assert_eq!(HopKind::AggUnary(AggOp::Sum, AggDir::All).opcode(), "ua(+RC)");
        assert_eq!(HopKind::Unary(UnOp::Ncol).opcode(), "u(ncol)");
    }

    #[test]
    fn lit_conversions() {
        assert_eq!(Lit::Int(3).as_f64(), Some(3.0));
        assert_eq!(Lit::Bool(true).as_bool(), Some(true));
        assert_eq!(Lit::Double(0.0).as_bool(), Some(false));
        assert_eq!(Lit::Str("x".into()).as_f64(), None);
        assert_eq!(Lit::Double(0.001).render(), "0.001");
        assert_eq!(Lit::Double(2.0).render(), "2.0");
    }
}
