//! Execution-type selection (paper §2): each HOP picks CP (single-node
//! in-memory) when its operation memory estimate fits the local budget and
//! its sizes are known; otherwise MR. Some operators are CP-only (`solve`,
//! scalar ops, bookkeeping); persistent reads feeding MR consumers stay on
//! HDFS (no CP read op is materialised).

use super::*;
use crate::conf::{ClusterConfig, SystemConfig};
use crate::rtprog::ExecBackend;

/// Select execution types for all hops in the program, and set per-block
/// `recompile` flags (blocks with MR operators or unknowns are marked for
/// dynamic recompilation, cf. Figure 3's `[recompile=true]`).
pub fn select(prog: &mut Program, cfg: &SystemConfig, cc: &ClusterConfig) {
    select_with(prog, cfg, cc, false)
}

/// Backend-parameterised selection: with `force_cp` every operator stays
/// in the control program regardless of its memory estimate — the
/// single-node (`ExecBackend::Cp`) plan family, where the cost model
/// rather than the compiler exposes when data outgrows one machine.
pub fn select_with(prog: &mut Program, cfg: &SystemConfig, cc: &ClusterConfig, force_cp: bool) {
    select_groups(prog, cfg, cc, force_cp, &[])
}

/// Per-group selection for the global data flow optimizer
/// ([`crate::opt::gdf`]): top-level block `i` of the main program is
/// selected under the forced backend `groups[i]` — an infinite budget
/// (everything CP) when the group is forced to [`ExecBackend::Cp`], the
/// regular §2 memory-budget rule otherwise (MR and Spark share the CP-vs-
/// distributed split; they differ later, at plan generation). Blocks
/// beyond `groups.len()` and function bodies fall back to
/// `default_force_cp`, so `select_groups(.., &[])` is exactly
/// [`select_with`].
pub fn select_groups(
    prog: &mut Program,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    default_force_cp: bool,
    groups: &[ExecBackend],
) {
    let budget_of =
        |force_cp: bool| if force_cp { f64::INFINITY } else { cfg.cp_budget(cc) };
    let mut blocks = std::mem::take(&mut prog.blocks);
    for (i, b) in blocks.iter_mut().enumerate() {
        let force = groups.get(i).map_or(default_force_cp, |&b| b == ExecBackend::Cp);
        select_blocks(std::slice::from_mut(b), budget_of(force));
    }
    prog.blocks = blocks;
    for f in prog.funcs.values_mut() {
        select_blocks(&mut f.body, budget_of(default_force_cp));
    }
}

fn select_blocks(blocks: &mut [Block], budget: f64) {
    for b in blocks {
        match b {
            Block::Generic(g) => {
                select_dag(&mut g.dag, budget);
                g.recompile = dag_needs_recompile(&g.dag);
            }
            Block::If { pred, then_blocks, else_blocks, .. } => {
                select_dag(pred, budget);
                select_blocks(then_blocks, budget);
                select_blocks(else_blocks, budget);
            }
            Block::For { from, to, by, body, .. } => {
                select_dag(from, budget);
                select_dag(to, budget);
                if let Some(by) = by {
                    select_dag(by, budget);
                }
                select_blocks(body, budget);
            }
            Block::While { pred, body, .. } => {
                select_dag(pred, budget);
                select_blocks(body, budget);
            }
            Block::FCall { .. } => {}
        }
    }
}

/// Per-DAG selection.
pub fn select_dag(dag: &mut HopDag, budget: f64) {
    for id in dag.topo_order() {
        let hop = dag.hop(id).clone();
        let exec = choose(&hop, budget);
        dag.hop_mut(id).exec = Some(exec);
    }
}

fn choose(hop: &Hop, budget: f64) -> ExecType {
    // Scalar ops, bookkeeping, prints: always CP.
    if !hop.dtype.is_matrix() {
        return ExecType::Cp;
    }
    match &hop.kind {
        // Variable bookkeeping is CP; the data may still live on HDFS.
        HopKind::TRead { .. } | HopKind::TWrite { .. } | HopKind::PRead { .. }
        | HopKind::PWrite { .. } | HopKind::Literal(_) => ExecType::Cp,
        // solve is CP-only in SystemML (LAPACK-style kernel); the optimizer
        // must produce plans where its inputs fit in memory.
        HopKind::Binary(BinOp::Solve) => ExecType::Cp,
        _ => {
            if hop.op_mem <= budget {
                ExecType::Cp
            } else {
                ExecType::Mr
            }
        }
    }
}

fn dag_needs_recompile(dag: &HopDag) -> bool {
    dag.topo_order().iter().any(|&id| {
        let h = dag.hop(id);
        h.exec == Some(ExecType::Mr) || (h.dtype.is_matrix() && !h.mc.dims_known())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conf::{ClusterConfig, SystemConfig};
    use crate::dml;
    use crate::ir::build::{build_program, tests::linreg_args, StaticMeta};
    use crate::ir::{memory, rewrites, size_prop};
    use crate::matrix::{Format, MatrixCharacteristics};

    fn compile_with_meta(meta: &StaticMeta) -> Program {
        let script = dml::frontend(crate::ir::build::tests::LINREG_DS).unwrap();
        let mut prog = build_program(&script, &linreg_args(), meta, 1000).unwrap();
        rewrites::rewrite_program(&mut prog);
        size_prop::propagate(&mut prog, 1000);
        memory::annotate(&mut prog, &SystemConfig::default());
        select(&mut prog, &SystemConfig::default(), &ClusterConfig::paper_cluster());
        prog
    }

    fn exec_of(prog: &Program, pred: impl Fn(&Hop) -> bool) -> Vec<ExecType> {
        let mut v = Vec::new();
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    let h = g.dag.hop(id);
                    if pred(h) {
                        v.push(h.exec.unwrap());
                    }
                }
            }
        }
        v
    }

    fn xs() -> StaticMeta {
        StaticMeta::default()
            .with("data/X", MatrixCharacteristics::dense(10_000, 1_000, 1000), Format::BinaryBlock)
            .with("data/y", MatrixCharacteristics::dense(10_000, 1, 1000), Format::BinaryBlock)
    }

    fn xl1() -> StaticMeta {
        StaticMeta::default()
            .with(
                "data/X",
                MatrixCharacteristics::dense(100_000_000, 1_000, 1000),
                Format::BinaryBlock,
            )
            .with(
                "data/y",
                MatrixCharacteristics::dense(100_000_000, 1, 1000),
                Format::BinaryBlock,
            )
    }

    #[test]
    fn xs_is_all_cp() {
        // Figure 1: every operator CP for the 80MB scenario.
        let prog = compile_with_meta(&xs());
        let execs = exec_of(&prog, |h| h.dtype.is_matrix());
        assert!(!execs.is_empty());
        assert!(execs.iter().all(|e| *e == ExecType::Cp));
    }

    #[test]
    fn xl1_puts_large_ops_on_mr() {
        // Paper §2: "memory estimates of HOPs 52, 53, and 59 are >1 TB ...
        // hence we select the execution type MR for these operators".
        let prog = compile_with_meta(&xl1());
        let t = exec_of(&prog, |h| h.kind == HopKind::Reorg(ReorgOp::Transpose));
        assert_eq!(t, vec![ExecType::Mr]);
        let mm = exec_of(&prog, |h| h.kind == HopKind::MatMult);
        assert_eq!(mm, vec![ExecType::Mr, ExecType::Mr]);
        // but solve and the small add remain CP (hybrid plan)
        let solve = exec_of(&prog, |h| h.kind == HopKind::Binary(BinOp::Solve));
        assert_eq!(solve, vec![ExecType::Cp]);
        let add = exec_of(&prog, |h| h.kind == HopKind::Binary(BinOp::Add) && h.dtype.is_matrix());
        assert_eq!(add, vec![ExecType::Cp]);
    }

    #[test]
    fn recompile_flags_set_for_mr_blocks() {
        let prog = compile_with_meta(&xl1());
        let Block::Generic(g1) = &prog.blocks[0] else { panic!() };
        let Block::Generic(g2) = &prog.blocks[1] else { panic!() };
        assert!(!g1.recompile, "read-only block stays static");
        assert!(g2.recompile, "MR block marked for recompilation");
    }

    #[test]
    fn force_cp_keeps_xl1_single_node() {
        // The CP backend forces every operator in-memory even at 800 GB;
        // the cost model, not the compiler, then exposes the blow-up.
        let script = dml::frontend(crate::ir::build::tests::LINREG_DS).unwrap();
        let mut prog = build_program(&script, &linreg_args(), &xl1(), 1000).unwrap();
        rewrites::rewrite_program(&mut prog);
        size_prop::propagate(&mut prog, 1000);
        memory::annotate(&mut prog, &SystemConfig::default());
        select_with(
            &mut prog,
            &SystemConfig::default(),
            &ClusterConfig::paper_cluster(),
            true,
        );
        let execs = exec_of(&prog, |h| h.dtype.is_matrix());
        assert!(!execs.is_empty());
        assert!(execs.iter().all(|e| *e == ExecType::Cp));
    }

    #[test]
    fn per_group_force_cp_only_affects_its_block() {
        // GDF per-cut overrides: forcing CP on the computation block of
        // XL1 keeps its 1 TB operators in the control program while an
        // unforced sibling program still selects MR for them.
        let script = dml::frontend(crate::ir::build::tests::LINREG_DS).unwrap();
        let mut prog = build_program(&script, &linreg_args(), &xl1(), 1000).unwrap();
        rewrites::rewrite_program(&mut prog);
        size_prop::propagate(&mut prog, 1000);
        memory::annotate(&mut prog, &SystemConfig::default());
        let n_blocks = prog.blocks.len();
        let mut groups = vec![ExecBackend::Mr; n_blocks];
        for g in groups.iter_mut().skip(1) {
            *g = ExecBackend::Cp;
        }
        select_groups(
            &mut prog,
            &SystemConfig::default(),
            &ClusterConfig::paper_cluster(),
            false,
            &groups,
        );
        let execs = exec_of(&prog, |h| h.dtype.is_matrix());
        assert!(!execs.is_empty());
        assert!(execs.iter().all(|e| *e == ExecType::Cp), "{execs:?}");
    }

    #[test]
    fn scalars_always_cp() {
        let prog = compile_with_meta(&xl1());
        let scalars = exec_of(&prog, |h| !h.dtype.is_matrix());
        assert!(scalars.iter().all(|e| *e == ExecType::Cp));
    }
}
