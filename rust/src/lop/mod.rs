//! Low-level (physical) operator selection — the HOP→LOP step (paper §2).
//!
//! The interesting decisions reproduced from the paper:
//!
//! * **CP tsmm** for `t(X) %*% X` ("exploit the unary input characteristic
//!   and the known result symmetry which allows to do only half the
//!   computation") — Figure 2.
//! * **`(yᵀX)ᵀ` HOP-LOP rewrite** for CP `t(X) %*% y`, applied only when the
//!   small transpose fits the memory budget ("it exhibits additional memory
//!   constraints") — applied in XS, rejected in XL1 because `t(y)` would
//!   exceed the budget and spawn an MR job.
//! * **MR tsmm** requires whole rows per block: `ncol ≤ blocksize`
//!   (violated in XL2/XL4 → cpmm).
//! * **MR mapmm** broadcasts the smaller input through distributed cache,
//!   requires `M̂'(small) ≤ map budget` (violated in XL3/XL4 → cpmm), and
//!   partitions the broadcast when it spans multiple partitions.
//! * **MR cpmm** (cross-product join) as the robust fallback; it implies a
//!   *second* MR job for the final aggregation.

use crate::conf::{ClusterConfig, SystemConfig};
use crate::ir::*;
use crate::matrix::Format;
use crate::rtprog::ExecBackend;

/// Physical operator chosen for a matrix-multiplication HOP.
#[derive(Clone, Debug, PartialEq)]
pub enum MatMultMethod {
    /// CP transpose-self: `tsmm LEFT` (t(X)%*%X) or `RIGHT` (X%*%t(X)).
    CpTsmm { left: bool },
    /// Plain CP matrix multiply.
    CpMM,
    /// CP `t(X)%*%y` executed as `t(t(y)%*%X)` — the Figure 2 rewrite.
    CpMMTransposeRewrite,
    /// Map-side MR transpose-self (requires ncol <= blocksize).
    MrTsmm { left: bool },
    /// Broadcast matrix multiplication: `side` is the broadcast input
    /// (0 = left, 1 = right); `partition` requests a CP partition op.
    MrMapMM { broadcast_input: usize, partition: bool },
    /// Cross-product join MMCJ + follow-up aggregation GMR (two jobs).
    MrCpmm,
    /// Replication-based matmult (single job, heavy shuffle); only chosen
    /// when forced via [`SelectionHints`] (ablation benchmarks).
    MrRmm,
}

/// Optional knobs for ablation studies.
#[derive(Clone, Debug, Default)]
pub struct SelectionHints {
    /// Force cpmm for all MR matmults (disables tsmm/mapmm).
    pub force_cpmm: bool,
    /// Force rmm for all MR matmults.
    pub force_rmm: bool,
    /// Disable the (yᵀX)ᵀ rewrite.
    pub no_transpose_rewrite: bool,
}

/// Select the physical matmult operator for HOP `id` in `dag` against the
/// default MR backend (see [`select_matmult_backend`]).
///
/// `exec` is the HOP's selected execution type; sizes must be propagated.
pub fn select_matmult(
    dag: &HopDag,
    id: HopId,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    hints: &SelectionHints,
) -> MatMultMethod {
    select_matmult_backend(dag, id, cfg, cc, hints, ExecBackend::Mr)
}

/// Backend-aware physical matmult selection. The CP-side decisions (tsmm,
/// the `(yᵀX)ᵀ` rewrite) are backend-independent; for distributed hops the
/// broadcast feasibility of `mapmm` differs per backend:
///
/// * **MR**: the broadcast must fit the per-task *map container* budget
///   (2 GB heaps on the paper cluster) and is partitioned through the
///   distributed cache when it spans multiple partitions.
/// * **Spark**: the broadcast must fit the *executor* budget
///   ([`SystemConfig::spark_broadcast_budget`]) — fat, long-lived
///   executors admit broadcasts MR rejects (the XL3 flip) — and torrent
///   broadcasts are never partitioned, so no CP `partition` op is emitted.
pub fn select_matmult_backend(
    dag: &HopDag,
    id: HopId,
    cfg: &SystemConfig,
    cc: &ClusterConfig,
    hints: &SelectionHints,
    backend: ExecBackend,
) -> MatMultMethod {
    let hop = dag.hop(id);
    debug_assert_eq!(hop.kind, HopKind::MatMult);
    let (a, b) = (hop.inputs[0], hop.inputs[1]);
    let exec = hop.exec.unwrap_or(ExecType::Cp);

    // transpose-self patterns
    let left_self = transpose_input_of(dag, a) == Some(b); // t(X) %*% X
    let right_self = transpose_input_of(dag, b) == Some(a); // X %*% t(X)

    match exec {
        ExecType::Cp => {
            if left_self {
                return MatMultMethod::CpTsmm { left: true };
            }
            if right_self {
                return MatMultMethod::CpTsmm { left: false };
            }
            // (y'X)' rewrite: t(X) %*% y with y a vector; beneficial when it
            // avoids materialising t(X); valid when t(y) fits the budget.
            if !hints.no_transpose_rewrite && transpose_input_of(dag, a).is_some() {
                let y = dag.hop(b);
                if y.mc.cols == 1 {
                    let ty_op_mem = 2.0 * y.out_mem;
                    if ty_op_mem <= cfg.cp_budget(cc) {
                        return MatMultMethod::CpMMTransposeRewrite;
                    }
                }
            }
            MatMultMethod::CpMM
        }
        ExecType::Mr => {
            if hints.force_rmm {
                return MatMultMethod::MrRmm;
            }
            if hints.force_cpmm {
                return MatMultMethod::MrCpmm;
            }
            // MR tsmm: needs entire rows in one block.
            if left_self {
                let x = dag.hop(b);
                if x.mc.cols >= 0 && x.mc.cols <= cfg.blocksize {
                    return MatMultMethod::MrTsmm { left: true };
                }
            }
            if right_self {
                let x = dag.hop(a);
                if x.mc.rows >= 0 && x.mc.rows <= cfg.blocksize {
                    return MatMultMethod::MrTsmm { left: false };
                }
            }
            // mapmm: broadcast the smaller input if it fits the backend's
            // broadcast budget (map container for MR, executor for Spark).
            let (am, bm) = (dag.hop(a), dag.hop(b));
            let a_ser = am.mc.serialized_size(Format::BinaryBlock);
            let b_ser = bm.mc.serialized_size(Format::BinaryBlock);
            let bc_budget = match backend {
                ExecBackend::Spark => cfg.spark_broadcast_budget(cc),
                _ => cfg.map_budget(cc),
            };
            let (bc_input, bc_size) = if a_ser <= b_ser { (0, a_ser) } else { (1, b_ser) };
            if bc_size.is_finite() && bc_size <= bc_budget {
                let partition = partition_broadcast(backend, bc_size, cfg);
                return MatMultMethod::MrMapMM { broadcast_input: bc_input, partition };
            }
            MatMultMethod::MrCpmm
        }
    }
}

/// The broadcast-partitioning decision — one of the *interesting
/// properties* the global data flow optimizer ([`crate::opt::gdf`])
/// enumerates per DAG cut (via [`crate::conf::SystemConfig::partition_bytes`]).
/// MR distributed-cache broadcasts larger than one partition are
/// pre-partitioned by a CP `partition` instruction so each map task
/// streams only the partitions it touches; Spark torrent broadcasts are
/// fetched whole from peers and are never partitioned.
pub fn partition_broadcast(backend: ExecBackend, bc_size: f64, cfg: &SystemConfig) -> bool {
    backend != ExecBackend::Spark && bc_size > cfg.partition_bytes
}

/// If `id` is a transpose hop, return the id of its input.
pub fn transpose_input_of(dag: &HopDag, id: HopId) -> Option<HopId> {
    let h = dag.hop(id);
    if h.kind == HopKind::Reorg(ReorgOp::Transpose) {
        Some(h.inputs[0])
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conf::{ClusterConfig, SystemConfig};
    use crate::dml;
    use crate::ir::build::{build_program, tests::linreg_args, StaticMeta};
    use crate::ir::{exec_type, memory, rewrites, size_prop};
    use crate::matrix::{Format, MatrixCharacteristics};

    fn compile(meta: &StaticMeta) -> Program {
        let script = dml::frontend(crate::ir::build::tests::LINREG_DS).unwrap();
        let cfg = SystemConfig::default();
        let cc = ClusterConfig::paper_cluster();
        let mut prog = build_program(&script, &linreg_args(), meta, cfg.blocksize).unwrap();
        rewrites::rewrite_program(&mut prog);
        size_prop::propagate(&mut prog, cfg.blocksize);
        memory::annotate(&mut prog, &cfg);
        exec_type::select(&mut prog, &cfg, &cc);
        prog
    }

    fn scenario(rows: i64, cols: i64, yrows: i64) -> StaticMeta {
        StaticMeta::default()
            .with("data/X", MatrixCharacteristics::dense(rows, cols, 1000), Format::BinaryBlock)
            .with("data/y", MatrixCharacteristics::dense(yrows, 1, 1000), Format::BinaryBlock)
    }

    /// Collect the matmult methods of the main computation block, ordered
    /// (X'X first, then X'y — by output size).
    fn methods(prog: &Program) -> Vec<MatMultMethod> {
        let cfg = SystemConfig::default();
        let cc = ClusterConfig::paper_cluster();
        let mut out = Vec::new();
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    if g.dag.hop(id).kind == HopKind::MatMult {
                        out.push((
                            g.dag.hop(id).mc.cols,
                            select_matmult(&g.dag, id, &cfg, &cc, &SelectionHints::default()),
                        ));
                    }
                }
            }
        }
        out.sort_by_key(|(cols, _)| -cols);
        out.into_iter().map(|(_, m)| m).collect()
    }

    #[test]
    fn xs_selects_cp_tsmm_and_transpose_rewrite() {
        // Figure 2: tsmm LEFT for X'X and the (y'X)' rewrite for X'y.
        let prog = compile(&scenario(10_000, 1_000, 10_000));
        let m = methods(&prog);
        assert_eq!(m[0], MatMultMethod::CpTsmm { left: true });
        assert_eq!(m[1], MatMultMethod::CpMMTransposeRewrite);
    }

    #[test]
    fn xl1_selects_mr_tsmm_and_mapmm_with_partition() {
        // Figure 3: MR tsmm + mapmm (broadcast y, CP partition), no rewrite.
        let prog = compile(&scenario(100_000_000, 1_000, 100_000_000));
        let m = methods(&prog);
        assert_eq!(m[0], MatMultMethod::MrTsmm { left: true });
        assert_eq!(m[1], MatMultMethod::MrMapMM { broadcast_input: 1, partition: true });
    }

    #[test]
    fn xl2_wide_x_forces_cpmm_for_tsmm() {
        // §2: 2000 columns > blocksize prevents map-side tsmm -> cpmm.
        let prog = compile(&scenario(100_000_000, 2_000, 100_000_000));
        let m = methods(&prog);
        assert_eq!(m[0], MatMultMethod::MrCpmm);
        // X'y mapmm still fine (y is 800MB < 1434MB budget)
        assert_eq!(m[1], MatMultMethod::MrMapMM { broadcast_input: 1, partition: true });
    }

    #[test]
    fn xl3_large_y_forces_cpmm_for_mapmm() {
        // §2: y = 1.6GB > 1434MB map budget -> cpmm instead of mapmm.
        let prog = compile(&scenario(200_000_000, 1_000, 200_000_000));
        let m = methods(&prog);
        assert_eq!(m[0], MatMultMethod::MrTsmm { left: true });
        assert_eq!(m[1], MatMultMethod::MrCpmm);
    }

    #[test]
    fn xl4_both_cpmm() {
        let prog = compile(&scenario(200_000_000, 2_000, 200_000_000));
        let m = methods(&prog);
        assert_eq!(m[0], MatMultMethod::MrCpmm);
        assert_eq!(m[1], MatMultMethod::MrCpmm);
    }

    /// XL3's 1.6 GB y exceeds the 1434 MB MR map budget (-> cpmm) but fits
    /// the 14 GB Spark executor budget (-> torrent-broadcast mapmm, no
    /// partition op) — backend choice flips the physical operator.
    #[test]
    fn spark_executor_memory_flips_xl3_cpmm_to_mapmm() {
        let prog = compile(&scenario(200_000_000, 1_000, 200_000_000));
        let cfg = SystemConfig::default();
        let cc = ClusterConfig::paper_cluster();
        let mut methods = Vec::new();
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    if g.dag.hop(id).kind == HopKind::MatMult {
                        methods.push((
                            g.dag.hop(id).mc.cols,
                            select_matmult_backend(
                                &g.dag,
                                id,
                                &cfg,
                                &cc,
                                &SelectionHints::default(),
                                ExecBackend::Spark,
                            ),
                        ));
                    }
                }
            }
        }
        methods.sort_by_key(|(cols, _)| -cols);
        // X'X stays tsmm; X'y becomes an unpartitioned broadcast mapmm
        assert_eq!(methods[0].1, MatMultMethod::MrTsmm { left: true });
        assert_eq!(
            methods[1].1,
            MatMultMethod::MrMapMM { broadcast_input: 1, partition: false }
        );
    }

    #[test]
    fn hints_force_alternatives() {
        let prog = compile(&scenario(100_000_000, 1_000, 100_000_000));
        let cfg = SystemConfig::default();
        let cc = ClusterConfig::paper_cluster();
        for b in &prog.blocks {
            if let Block::Generic(g) = b {
                for id in g.dag.topo_order() {
                    if g.dag.hop(id).kind == HopKind::MatMult {
                        let m = select_matmult(
                            &g.dag,
                            id,
                            &cfg,
                            &cc,
                            &SelectionHints { force_rmm: true, ..Default::default() },
                        );
                        assert_eq!(m, MatMultMethod::MrRmm);
                    }
                }
            }
        }
    }
}
